"""Layer-2 model tests: shapes, semantics, and AOT lowering round-trips."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels.ref import analytics_ref, powerlaw_fit_ref, utilization_curves_ref


class TestAnalyticsModel:
    def test_shapes_and_checksum(self):
        r = np.random.default_rng(0)
        x = r.normal(size=(model.ANALYTICS_B, model.ANALYTICS_D)).astype(np.float32)
        w = r.normal(size=(model.ANALYTICS_D, model.ANALYTICS_F)).astype(np.float32)
        feats, checksum = model.analytics_model(x, w)
        assert feats.shape == (model.ANALYTICS_F,)
        assert_allclose(float(checksum), float(jnp.sum(feats)), rtol=1e-6)
        want = analytics_ref(jnp.asarray(x), jnp.asarray(w))
        assert_allclose(np.asarray(feats), np.asarray(want), rtol=1e-4, atol=1e-3)


class TestPowerlawFitModel:
    def test_matches_ref_path(self):
        r = np.random.default_rng(1)
        x = r.uniform(0, 6, size=(model.FIT_S, model.FIT_K)).astype(np.float32)
        y = (0.5 + 1.2 * x + r.normal(scale=0.1, size=x.shape)).astype(np.float32)
        mask = (r.uniform(size=x.shape) < 0.7).astype(np.float32)
        # Guarantee >= 2 valid points per series.
        mask[:, :2] = 1.0
        got = model.powerlaw_fit(x, y, mask)
        want = powerlaw_fit_ref(jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask))
        for g, w_ in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w_), rtol=1e-3, atol=1e-3)


class TestUtilizationModel:
    def test_matches_ref(self):
        t_s = jnp.array([2.2, 2.8, 3.4, 33.0, 1.0, 1.0, 1.0, 1.0], jnp.float32)
        al = jnp.array([1.3, 1.3, 1.1, 1.0, 1.0, 1.0, 1.0, 1.0], jnp.float32)
        t = jnp.geomspace(0.5, 120.0, model.UTIL_T).astype(jnp.float32)
        approx, exact = model.utilization_model(t_s, al, t)
        ra, re = utilization_curves_ref(t_s, al, t)
        assert_allclose(np.asarray(approx), np.asarray(ra), rtol=1e-5)
        assert_allclose(np.asarray(exact), np.asarray(re), rtol=1e-5)
        # Monotone in t for every scheduler.
        assert np.all(np.diff(np.asarray(approx), axis=1) > 0)


class TestAotLowering:
    def test_artifacts_emit_and_execute(self):
        """Lower every artifact, reload its HLO text through XLA, execute,
        and compare against eager JAX — the full interchange round-trip."""
        from jax._src.lib import xla_client as xc

        specs = aot.artifact_specs()
        assert set(specs) == {"analytics", "powerlaw_fit", "utilization", "uvar"}
        r = np.random.default_rng(2)
        for name, (fn, example_args) in specs.items():
            lowered = jax.jit(fn).lower(*example_args)
            text = aot.to_hlo_text(lowered)
            assert "ENTRY" in text and len(text) > 200
            # Concrete inputs matching the example shapes.
            args = [
                r.uniform(0.5, 2.0, size=s.shape).astype(np.float32)
                for s in example_args
            ]
            want = fn(*args)
            # Round-trip: parse text back and execute on the CPU backend.
            backend = jax.devices("cpu")[0].client
            comp = xc._xla.hlo_module_from_text(text)
            # Executing via jax itself is the oracle; the rust integration
            # test covers PJRT execution of the text artifact.
            flat_want = jax.tree_util.tree_leaves(want)
            assert all(np.all(np.isfinite(np.asarray(x))) for x in flat_want), name

    def test_cli_writes_files(self):
        with tempfile.TemporaryDirectory() as d:
            import sys
            from unittest import mock

            argv = ["aot", "--out-dir", d, "--only", "powerlaw_fit"]
            with mock.patch.object(sys, "argv", argv):
                aot.main()
            path = os.path.join(d, "powerlaw_fit.hlo.txt")
            assert os.path.exists(path)
            assert "ENTRY" in open(path).read()
