"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and dtypes; numpy cross-checks the regression
algebra against an independent implementation (polyfit).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels.analytics import analytics
from compile.kernels.powerlaw import powerlaw_moments
from compile.kernels.ref import (
    analytics_ref,
    powerlaw_fit_ref,
    powerlaw_moments_ref,
    utilization_curves_ref,
)

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def rng(seed):
    return np.random.default_rng(seed)


class TestAnalyticsKernel:
    def test_matches_ref_basic(self):
        r = rng(0)
        x = r.normal(size=(256, 64)).astype(np.float32)
        w = r.normal(size=(64, 32)).astype(np.float32)
        got = analytics(x, w)
        want = analytics_ref(jnp.asarray(x), jnp.asarray(w))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)

    @hypothesis.given(
        tiles=st.integers(1, 6),
        tile_b=st.sampled_from([8, 16, 64]),
        d=st.integers(1, 96),
        f=st.integers(1, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_shape_sweep(self, tiles, tile_b, d, f, seed):
        r = rng(seed)
        b = tiles * tile_b
        x = r.normal(size=(b, d)).astype(np.float32)
        w = r.normal(size=(d, f)).astype(np.float32)
        got = analytics(x, w, tile_b=tile_b)
        want = analytics_ref(jnp.asarray(x), jnp.asarray(w))
        assert got.shape == (f,)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)

    @hypothesis.given(seed=st.integers(0, 2**31 - 1))
    def test_bfloat16_inputs(self, seed):
        r = rng(seed)
        x = jnp.asarray(r.normal(size=(128, 32)), dtype=jnp.bfloat16)
        w = jnp.asarray(r.normal(size=(32, 16)), dtype=jnp.bfloat16)
        got = analytics(x, w, tile_b=64)
        want = analytics_ref(x, w)
        # bf16 matmul accumulated in f32 on both paths.
        assert got.dtype == jnp.float32
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-1)

    def test_relu_zeroes_negative_features(self):
        # With all-negative projections the ReLU must zero everything.
        x = jnp.ones((64, 8), jnp.float32)
        w = -jnp.ones((8, 4), jnp.float32)
        got = analytics(x, w, tile_b=32)
        assert_allclose(np.asarray(got), np.zeros(4, np.float32))

    def test_accumulates_across_tiles(self):
        # Sum over B is tile-order independent: one tile vs many.
        r = rng(3)
        x = r.normal(size=(256, 16)).astype(np.float32)
        w = r.normal(size=(16, 8)).astype(np.float32)
        one = analytics(x, w, tile_b=256)
        many = analytics(x, w, tile_b=8)
        assert_allclose(np.asarray(one), np.asarray(many), rtol=1e-4, atol=1e-3)

    def test_rejects_misaligned_batch(self):
        with pytest.raises(AssertionError):
            analytics(jnp.ones((100, 8)), jnp.ones((8, 4)), tile_b=64)


class TestPowerlawKernel:
    @hypothesis.given(
        s=st.integers(1, 8),
        k=st.integers(2, 32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_moments_match_ref(self, s, k, seed):
        r = rng(seed)
        x = r.uniform(0.0, 6.0, size=(s, k)).astype(np.float32)
        y = r.uniform(-2.0, 9.0, size=(s, k)).astype(np.float32)
        mask = (r.uniform(size=(s, k)) < 0.8).astype(np.float32)
        got = powerlaw_moments(x, y, mask)
        want = powerlaw_moments_ref(jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)

    def test_fit_recovers_exact_power_law(self):
        # The paper's Table 10 values as synthetic truth.
        t_s = np.array([2.2, 2.8, 3.4, 33.0], np.float32)
        alpha = np.array([1.3, 1.3, 1.1, 1.0], np.float32)
        ns = np.array([4.0, 8.0, 48.0, 240.0], np.float32)
        x = np.log(np.tile(ns, (4, 1))).astype(np.float32)
        y = (np.log(t_s)[:, None] + alpha[:, None] * x).astype(np.float32)
        mask = np.ones_like(x)
        ts_hat, al_hat, r2 = powerlaw_fit_ref(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask)
        )
        assert_allclose(np.asarray(ts_hat), t_s, rtol=1e-3)
        assert_allclose(np.asarray(al_hat), alpha, rtol=1e-3)
        assert_allclose(np.asarray(r2), np.ones(4), atol=1e-3)

    @hypothesis.given(seed=st.integers(0, 2**31 - 1))
    def test_fit_matches_numpy_polyfit(self, seed):
        r = rng(seed)
        k = 12
        x = np.sort(r.uniform(0.0, 5.5, size=k)).astype(np.float32)
        y = (0.7 + 1.25 * x + r.normal(scale=0.05, size=k)).astype(np.float32)
        xs = np.tile(x, (2, 1))
        ys = np.tile(y, (2, 1))
        mask = np.ones_like(xs)
        ts_hat, al_hat, _ = powerlaw_fit_ref(
            jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(mask)
        )
        slope_np, intercept_np = np.polyfit(x.astype(np.float64), y.astype(np.float64), 1)
        assert_allclose(float(al_hat[0]), slope_np, rtol=1e-3)
        assert_allclose(float(jnp.log(ts_hat[0])), intercept_np, rtol=1e-2, atol=1e-3)

    def test_mask_excludes_padding(self):
        # Padding rows with garbage must not affect the fit.
        x_clean = np.log(np.array([4.0, 8.0, 48.0, 240.0], np.float32))
        y_clean = np.float32(np.log(2.2)) + np.float32(1.3) * x_clean
        x = np.concatenate([x_clean, np.full(4, 99.0, np.float32)])[None, :]
        y = np.concatenate([y_clean, np.full(4, -99.0, np.float32)])[None, :]
        mask = np.concatenate([np.ones(4), np.zeros(4)]).astype(np.float32)[None, :]
        ts_hat, al_hat, _ = powerlaw_fit_ref(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask)
        )
        assert_allclose(float(ts_hat[0]), 2.2, rtol=1e-3)
        assert_allclose(float(al_hat[0]), 1.3, rtol=1e-3)


class TestUtilizationRef:
    def test_half_utilization_at_ts_equals_t(self):
        approx, _ = utilization_curves_ref(
            jnp.array([2.0]), jnp.array([1.0]), jnp.array([2.0])
        )
        assert_allclose(float(approx[0, 0]), 0.5, rtol=1e-6)

    def test_exact_equals_approx_at_alpha_one(self):
        t = jnp.array([1.0, 5.0, 30.0, 60.0])
        approx, exact = utilization_curves_ref(
            jnp.array([3.4]), jnp.array([1.0]), t
        )
        assert_allclose(np.asarray(exact), np.asarray(approx), rtol=1e-6)

    def test_alpha_above_one_lowers_exact_utilization(self):
        t = jnp.array([1.0])
        _, exact13 = utilization_curves_ref(jnp.array([2.2]), jnp.array([1.3]), t)
        _, exact10 = utilization_curves_ref(jnp.array([2.2]), jnp.array([1.0]), t)
        assert float(exact13[0, 0]) < float(exact10[0, 0])


class TestUvarKernel:
    @hypothesis.given(
        tiles=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
        ts=st.floats(0.1, 40.0),
    )
    def test_matches_ref(self, tiles, seed, ts):
        from compile.kernels.ref import uvar_ref
        from compile.kernels.uvar import uvar_moments

        r = rng(seed)
        p = tiles * 256
        t_p = r.uniform(0.5, 60.0, size=p).astype(np.float32)
        mask = (r.uniform(size=p) < 0.9).astype(np.float32)
        if mask.sum() == 0:
            mask[0] = 1.0
        ts_arr = np.array([ts], np.float32)
        mom = uvar_moments(jnp.asarray(t_p), jnp.asarray(mask), jnp.asarray(ts_arr))
        got = float(mom[1] / mom[0])
        want = float(uvar_ref(jnp.asarray(t_p), jnp.asarray(mask), jnp.asarray(ts_arr)[0]))
        assert_allclose(got, want, rtol=1e-3)

    def test_uniform_tasks_reduce_to_constant_model(self):
        from compile.kernels.uvar import uvar_moments

        # All processors at t=5, t_s=2.2: U = 1/(1+2.2/5).
        t_p = np.full(256, 5.0, np.float32)
        mask = np.ones(256, np.float32)
        mom = uvar_moments(
            jnp.asarray(t_p), jnp.asarray(mask), jnp.asarray([2.2], np.float32)
        )
        got = float(mom[1] / mom[0])
        assert_allclose(got, 1.0 / (1.0 + 2.2 / 5.0), rtol=1e-5)

    def test_padding_ignored(self):
        from compile.kernels.uvar import uvar_moments

        t_p = np.concatenate([np.full(128, 10.0), np.zeros(128)]).astype(np.float32)
        mask = np.concatenate([np.ones(128), np.zeros(128)]).astype(np.float32)
        mom = uvar_moments(
            jnp.asarray(t_p), jnp.asarray(mask), jnp.asarray([1.0], np.float32)
        )
        got = float(mom[1] / mom[0])
        assert_allclose(got, 1.0 / 1.1, rtol=1e-5)
