"""Layer-2 JAX model: the compute graphs that get AOT-lowered for the
rust coordinator.

Three graphs, all calling the Layer-1 Pallas kernels:

* ``analytics_model``  — the analytics map-task payload executed by the
  realtime mini-cluster's workers (the paper's "data analysis job").
* ``powerlaw_fit``     — Table 10's fit: batched masked log-log OLS over
  (n, ΔT) observations, moments computed by the Pallas kernel.
* ``utilization_model``— the Figure 5/7 model curves U_c(t) (approx and
  exact) for a batch of fitted (t_s, α_s).

Python runs ONCE at build time (`make artifacts`); the rust binary
executes the lowered HLO through PJRT.
"""

import jax.numpy as jnp

from .kernels.analytics import analytics
from .kernels.powerlaw import powerlaw_moments
from .kernels.uvar import uvar_moments

# Fixed AOT shapes (the rust side pads to these).
ANALYTICS_B = 256
ANALYTICS_D = 64
ANALYTICS_F = 32
FIT_S = 8  # max series (schedulers) per fit call
FIT_K = 32  # max observations per series
UTIL_T = 64  # task-time grid length
UVAR_P = 2048  # padded processor count for the U_v reduction


def analytics_model(x, w):
    """Map-task payload: features + a scalar checksum for verification.

    Args:
      x: (B, D) record batch.
      w: (D, F) projection.

    Returns:
      (features, checksum): (F,) activation totals and their sum.
    """
    feats = analytics(x, w)
    return feats, jnp.sum(feats)


def powerlaw_fit(log_n, log_dt, mask):
    """Batched power-law fit ΔT = t_s·n^α_s (log-log OLS).

    Args:
      log_n: (S, K) log tasks-per-processor.
      log_dt: (S, K) log ΔT.
      mask: (S, K) 1.0 valid / 0.0 padding.

    Returns:
      (t_s, alpha, r2): three (S,) vectors.
    """
    mom = powerlaw_moments(log_n, log_dt, mask)
    n = mom[:, 0]
    sx, sy, sxx, sxy, syy = mom[:, 1], mom[:, 2], mom[:, 3], mom[:, 4], mom[:, 5]
    denom = n * sxx - sx * sx
    safe = jnp.where(jnp.abs(denom) > 1e-30, denom, 1.0)
    slope = (n * sxy - sx * sy) / safe
    intercept = (sy - slope * sx) / jnp.maximum(n, 1.0)
    ss_tot = syy - sy * sy / jnp.maximum(n, 1.0)
    ss_res = (
        syy
        - 2.0 * (intercept * sy + slope * sxy)
        + intercept * intercept * n
        + 2.0 * intercept * slope * sx
        + slope * slope * sxx
    )
    r2 = jnp.where(
        ss_tot > 0.0, 1.0 - ss_res / jnp.where(ss_tot > 0.0, ss_tot, 1.0), 1.0
    )
    return jnp.exp(intercept), slope, r2


def utilization_model(t_s, alpha, t_grid):
    """Model utilization curves (paper Section 4 / Figure 5).

    Args:
      t_s: (S,) marginal latencies.
      alpha: (S,) exponents.
      t_grid: (T,) task times.

    Returns:
      (approx, exact): (S, T) arrays; n is derived from the paper's fixed
      T_job = 240 s per processor.
    """
    t_job = 240.0
    ts = t_s[:, None]
    al = alpha[:, None]
    t = t_grid[None, :]
    n = t_job / t
    approx = 1.0 / (1.0 + ts / t)
    exact = 1.0 / (1.0 + ts * jnp.power(n, al) / (t * n))
    return approx, exact


def uvar_model(t_p, mask, t_s):
    """Variable-task-time utilization U_v (paper §4, per-processor
    averaging), reduced by the Pallas kernel.

    Args:
      t_p: (P,) per-processor mean task times.
      mask: (P,) validity mask.
      t_s: (1,) marginal latency.

    Returns:
      scalar U.
    """
    mom = uvar_moments(t_p, mask, t_s)
    return mom[1] / mom[0]
