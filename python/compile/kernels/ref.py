"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every Pallas kernel in this
package is checked against its function here by pytest (including
hypothesis sweeps over shapes and dtypes) before the AOT artifacts are
emitted.
"""

import jax.numpy as jnp


def analytics_ref(x, w):
    """Analytics map-task payload, reference implementation.

    The "data analysis job" of the paper's motivation: project a batch of
    records through a feature matrix, apply a ReLU nonlinearity, and
    reduce per-feature over the batch.

    Args:
      x: (B, D) record batch.
      w: (D, F) feature projection.

    Returns:
      (F,) per-feature activation totals.
    """
    h = jnp.maximum(jnp.dot(x, w, preferred_element_type=jnp.float32), 0.0)
    return jnp.sum(h, axis=0)


def powerlaw_moments_ref(x, y, mask):
    """Masked regression moments, reference implementation.

    For each series s computes the six accumulated moments needed for a
    weighted least-squares line fit of y on x:
      [Σm, Σmx, Σmy, Σmxx, Σmxy, Σmyy]

    Args:
      x: (S, K) abscissae (log n).
      y: (S, K) ordinates (log ΔT).
      mask: (S, K) 1.0 for valid points, 0.0 for padding.

    Returns:
      (S, 6) moment matrix.
    """
    m = mask
    cols = [
        jnp.sum(m, axis=1),
        jnp.sum(m * x, axis=1),
        jnp.sum(m * y, axis=1),
        jnp.sum(m * x * x, axis=1),
        jnp.sum(m * x * y, axis=1),
        jnp.sum(m * y * y, axis=1),
    ]
    return jnp.stack(cols, axis=1)


def powerlaw_fit_ref(x, y, mask):
    """Full power-law fit from moments: returns (t_s, alpha, r2) per series.

    Matches rust `util::fit::fit_power_law` (OLS in log-log space) —
    inputs are already logs; t_s = exp(intercept).
    """
    mom = powerlaw_moments_ref(x, y, mask)
    n = mom[:, 0]
    sx, sy, sxx, sxy, syy = mom[:, 1], mom[:, 2], mom[:, 3], mom[:, 4], mom[:, 5]
    denom = n * sxx - sx * sx
    safe = jnp.where(jnp.abs(denom) > 1e-30, denom, 1.0)
    slope = (n * sxy - sx * sy) / safe
    intercept = (sy - slope * sx) / jnp.maximum(n, 1.0)
    # R^2 = 1 - SS_res/SS_tot, expanded in terms of the moments.
    ss_tot = syy - sy * sy / jnp.maximum(n, 1.0)
    ss_res = (
        syy
        - 2.0 * (intercept * sy + slope * sxy)
        + intercept * intercept * n
        + 2.0 * intercept * slope * sx
        + slope * slope * sxx
    )
    r2 = jnp.where(ss_tot > 0.0, 1.0 - ss_res / jnp.where(ss_tot > 0.0, ss_tot, 1.0), 1.0)
    return jnp.exp(intercept), slope, r2


def utilization_curves_ref(t_s, alpha, t_grid, t_job=240.0):
    """Model utilization curves for Figure 5, reference implementation.

    Args:
      t_s: (S,) fitted marginal latencies.
      alpha: (S,) fitted exponents.
      t_grid: (T,) task times.
      t_job: per-processor isolated job time (paper: 240 s), so
        n = t_job / t.

    Returns:
      (approx, exact): two (S, T) arrays —
        approx: U^-1 = 1 + t_s/t        (Figure 5a dotted lines)
        exact:  U^-1 = 1 + t_s n^α/(tn) (Figure 5b dashed lines)
    """
    ts = t_s[:, None]
    al = alpha[:, None]
    t = t_grid[None, :]
    n = t_job / t
    approx = 1.0 / (1.0 + ts / t)
    exact = 1.0 / (1.0 + ts * jnp.power(n, al) / (t * n))
    return approx, exact


def uvar_ref(t_p, mask, t_s):
    """Variable-task-time utilization, reference implementation.

    U^-1 = (Σ m·(1 + t_s/t(p))) / Σ m  over masked processors.
    """
    import jax.numpy as jnp

    safe = jnp.where(t_p > 0.0, t_p, 1.0)
    inv = 1.0 + t_s / safe
    num = jnp.sum(mask * inv)
    den = jnp.sum(mask)
    return den / num
