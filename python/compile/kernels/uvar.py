"""Layer-1 Pallas kernel: variable-task-time utilization reduction.

The paper's Section 4 closes with: "If the scheduler releases a
processor as it completes its work, then the overall utilization is the
average of the per-processor utilization — U^-1 ≈ P^-1 Σ_p U_c(t(p))^-1".
This kernel performs that masked average over the per-processor mean
task times t(p) in one VMEM-resident pass: for each processor,
U_c(t(p))^-1 = 1 + t_s / t(p); the output is [Σ m·(1 + t_s/t(p)), Σ m].
Layer 2 finishes U = Σm / Σ(...).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _uvar_kernel(tp_ref, mask_ref, ts_ref, o_ref):
    """Masked accumulation of per-processor inverse utilizations."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tp = tp_ref[...]
    m = mask_ref[...]
    ts = ts_ref[0]
    # Guard padded entries (tp=0) before dividing.
    safe_tp = jnp.where(tp > 0.0, tp, 1.0)
    inv_u = 1.0 + ts / safe_tp
    o_ref[0] += jnp.sum(m * inv_u)
    o_ref[1] += jnp.sum(m)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def uvar_moments(t_p, mask, t_s, *, tile=256, interpret=True):
    """Masked U_v reduction moments.

    Args:
      t_p: (P,) per-processor mean task times (padded entries arbitrary).
      mask: (P,) 1.0 for real processors, 0.0 for padding.
      t_s: (1,) marginal scheduler latency.
      tile: processors per VMEM tile.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      (2,) float32: [Σ m·U_c(t(p))^-1, Σ m].
    """
    (p,) = t_p.shape
    assert mask.shape == (p,) and t_s.shape == (1,)
    assert p % tile == 0, f"P={p} not a multiple of tile={tile}"
    return pl.pallas_call(
        _uvar_kernel,
        grid=(p // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        interpret=interpret,
    )(t_p, mask, t_s)
