"""Layer-1 Pallas kernel: the analytics map-task payload.

The hot-spot of the "data analysis job" the paper's big-data workloads
motivate: batched feature projection (matmul -> MXU) + ReLU + per-feature
batch reduction, tiled over the batch dimension so each (tile_b, D) x
(D, F) step is VMEM-resident.

TPU mapping (DESIGN.md §Hardware-Adaptation): the BlockSpec grid walks
HBM->VMEM batch tiles; the (D, F) weight block stays pinned in VMEM; the
matmul targets the MXU. On this image the kernel runs with
interpret=True (CPU PJRT cannot execute Mosaic custom-calls) — numerics
are identical, performance is modeled in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _analytics_kernel(x_ref, w_ref, o_ref):
    """One grid step: o += sum(relu(x_tile @ w), axis=0)."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    h = jnp.maximum(
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32), 0.0
    )
    o_ref[...] += jnp.sum(h, axis=0)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def analytics(x, w, *, tile_b=64, interpret=True):
    """Analytics payload: (B, D) records x (D, F) weights -> (F,) totals.

    Args:
      x: (B, D) record batch; B must be a multiple of tile_b.
      w: (D, F) projection matrix.
      tile_b: batch tile size (VMEM sizing knob).
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      (F,) float32 per-feature activation totals.
    """
    b, d = x.shape
    d2, f = w.shape
    assert d == d2, f"shape mismatch: {x.shape} @ {w.shape}"
    assert b % tile_b == 0, f"B={b} not a multiple of tile_b={tile_b}"
    grid = (b // tile_b,)
    return pl.pallas_call(
        _analytics_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((f,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((f,), jnp.float32),
        interpret=interpret,
    )(x, w)
