"""Layer-1 Pallas kernel: masked regression-moment accumulation.

The measurement side of the paper's Table 10: fitting
log ΔT = log t_s + α_s · log n per scheduler. The kernel reduces, for a
batch of S series of up to K (log n, log ΔT) observations with a
validity mask, the six moments a weighted OLS line fit needs — one
single-pass VMEM-resident reduction per series tile. Layer 2
(`compile.model.powerlaw_fit`) finishes the scalar algebra.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moments_kernel(x_ref, y_ref, m_ref, o_ref):
    """Per-series moment reduction: o[s] = [Σm, Σmx, Σmy, Σmxx, Σmxy, Σmyy]."""
    x = x_ref[...]
    y = y_ref[...]
    m = m_ref[...]
    o_ref[..., 0] = jnp.sum(m, axis=1)
    o_ref[..., 1] = jnp.sum(m * x, axis=1)
    o_ref[..., 2] = jnp.sum(m * y, axis=1)
    o_ref[..., 3] = jnp.sum(m * x * x, axis=1)
    o_ref[..., 4] = jnp.sum(m * x * y, axis=1)
    o_ref[..., 5] = jnp.sum(m * y * y, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def powerlaw_moments(x, y, mask, *, interpret=True):
    """Masked per-series regression moments.

    Args:
      x: (S, K) log-n values.
      y: (S, K) log-ΔT values.
      mask: (S, K) 1.0 valid / 0.0 padding.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      (S, 6) float32 moments [Σm, Σmx, Σmy, Σmxx, Σmxy, Σmyy].
    """
    s, k = x.shape
    assert y.shape == (s, k) and mask.shape == (s, k)
    return pl.pallas_call(
        _moments_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((s, k), lambda i: (0, 0)),
            pl.BlockSpec((s, k), lambda i: (0, 0)),
            pl.BlockSpec((s, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((s, 6), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, 6), jnp.float32),
        interpret=interpret,
    )(x, y, mask)
