"""AOT compile path: lower the Layer-2 graphs to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 serializes HloModuleProto with 64-bit instruction ids
which the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
`make artifacts` wraps this and is a no-op when inputs are unchanged.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs():
    """Name -> (fn, example_args) for every AOT artifact."""
    return {
        "analytics": (
            model.analytics_model,
            (f32(model.ANALYTICS_B, model.ANALYTICS_D),
             f32(model.ANALYTICS_D, model.ANALYTICS_F)),
        ),
        "powerlaw_fit": (
            model.powerlaw_fit,
            (f32(model.FIT_S, model.FIT_K),
             f32(model.FIT_S, model.FIT_K),
             f32(model.FIT_S, model.FIT_K)),
        ),
        "utilization": (
            model.utilization_model,
            (f32(model.FIT_S), f32(model.FIT_S), f32(model.UTIL_T)),
        ),
        "uvar": (
            model.uvar_model,
            (f32(model.UVAR_P), f32(model.UVAR_P), f32(1)),
        ),
    }


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="emit just one artifact by name"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, (fn, example_args) in artifact_specs().items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>8} chars  {path}")


if __name__ == "__main__":
    main()
