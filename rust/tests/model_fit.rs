//! Property + golden suite for the fitted-model layer (the `model`
//! experiment): the hardened power-law fitter recovers known
//! parameters under seeded noise, degenerate sweeps fail as errors
//! instead of aborting, the auto-tuned bundle size moves monotonically
//! with task duration and scheduler latency, the experiment's CSV is
//! byte-identical for any worker count, and a self-seeding golden
//! snapshot pins the fitted parameters and derived bundle sizes
//! bit-for-bit.

use sssched::config::ExperimentConfig;
use sssched::harness::{self, ModelReport};
use sssched::model::{derive_bundle_size, fit_sweep, predicted_bundled_utilization};
use sssched::multilevel::MultilevelParams;
use sssched::sched::{RunOptions, ShardedSim};
use sssched::util::fit::{try_fit_power_law, try_linear_regression, FitError};
use sssched::util::prng::Prng;
use sssched::workload::WorkloadBuilder;
use std::path::PathBuf;

/// Small config shared by the end-to-end tests: 4 nodes × 32 cores,
/// one trial, three sweep points.
fn tiny_cfg(jobs: u32) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.scale_down = 11; // 4 nodes, 128 cores — fast in tests
    cfg.trials = 1;
    cfg.model_ns = vec![4, 8, 48];
    cfg.jobs = jobs;
    cfg
}

// ---------------------------------------------------------------------
// Property: the fitter recovers known (t_s, α_s) under seeded noise.
// ---------------------------------------------------------------------

#[test]
fn fitter_recovers_known_parameters_under_noise() {
    let ns = [4u32, 8, 16, 32, 48, 96, 240];
    for case in 0..20u64 {
        let mut rng = Prng::new(0xF17_0000 + case);
        let t_s = rng.range_f64(0.5, 40.0);
        let alpha = rng.range_f64(0.9, 1.5);
        // Three "trials" per n with multiplicative lognormal noise
        // (mean 1, cv 5 %) — the same shape as pooled sweep trials.
        let mut pts: Vec<(f64, f64)> = Vec::new();
        for &n in &ns {
            for _ in 0..3 {
                let dt = t_s * (n as f64).powf(alpha) * rng.lognormal_mean_cv(1.0, 0.05);
                pts.push((n as f64, dt));
            }
        }
        let f = fit_sweep("synthetic", &pts).unwrap();
        assert!(!f.zero_overhead);
        assert!(
            (f.t_s - t_s).abs() / t_s < 0.25,
            "case {case}: t_s {} vs true {t_s}",
            f.t_s
        );
        assert!(
            (f.alpha_s - alpha).abs() < 0.05,
            "case {case}: alpha {} vs true {alpha}",
            f.alpha_s
        );
        assert!(f.r2 > 0.95, "case {case}: r2 {}", f.r2);
    }
}

// ---------------------------------------------------------------------
// Satellite bugfix: degenerate fits are contextual errors, not panics.
// ---------------------------------------------------------------------

#[test]
fn degenerate_fits_are_errors_not_panics() {
    assert_eq!(
        try_linear_regression(&[1.0], &[1.0]).unwrap_err(),
        FitError::TooFewPoints { usable: 1, total: 1 }
    );
    assert_eq!(
        try_linear_regression(&[2.0, 2.0, 2.0], &[1.0, 2.0, 3.0]).unwrap_err(),
        FitError::DegenerateX
    );
    // All-zero ΔT: every point filtered, none usable.
    assert_eq!(
        try_fit_power_law(&[4.0, 8.0, 16.0], &[0.0, 0.0, 0.0]).unwrap_err(),
        FitError::TooFewPoints { usable: 0, total: 3 }
    );
    // The experiment-level wrapper adds scheduler + n-range context.
    let e = fit_sweep("PathologicalSched", &[(4.0, 0.0), (8.0, 0.0), (48.0, 3.0)]).unwrap_err();
    assert!(e.contains("PathologicalSched"), "{e}");
    assert!(e.contains("[4, 48]"), "{e}");
    let e = fit_sweep("PathologicalSched", &[(8.0, 3.0), (8.0, 3.1)]).unwrap_err();
    assert!(e.contains("degenerate"), "{e}");
    // An all-noise sweep is the zero-overhead convention, not an error.
    let f = fit_sweep("Ideal", &[(4.0, 0.0), (8.0, 1e-9)]).unwrap();
    assert!(f.zero_overhead);
    assert_eq!((f.t_s, f.alpha_s, f.r2), (0.0, 1.0, 1.0));
}

// ---------------------------------------------------------------------
// Property: auto-tuned bundle size is monotone in t and inverse in t_s.
// ---------------------------------------------------------------------

#[test]
fn bundle_size_monotone_non_increasing_in_task_duration() {
    let p = MultilevelParams::default();
    let mut last_k = u64::MAX;
    for &t in &[0.5, 1.0, 2.0, 5.0, 15.0, 60.0] {
        let c = derive_bundle_size(3.0, 1.2, &p, t, 960, 0.9);
        assert!(
            c.bundle_size <= last_k,
            "t={t}: bundle {} grew past {last_k}",
            c.bundle_size
        );
        last_k = c.bundle_size;
    }
    // Long tasks need almost no aggregation; short tasks need a lot.
    let short = derive_bundle_size(3.0, 1.2, &p, 0.5, 960, 0.9);
    let long = derive_bundle_size(3.0, 1.2, &p, 60.0, 960, 0.9);
    assert!(short.bundle_size > long.bundle_size);
}

#[test]
fn bundle_size_inverse_monotone_in_ts() {
    let p = MultilevelParams::default();
    let mut last_k = 0u64;
    for &t_s in &[0.1, 1.0, 2.2, 3.4, 10.0, 33.0] {
        let c = derive_bundle_size(t_s, 1.1, &p, 1.0, 960, 0.9);
        assert!(
            c.bundle_size >= last_k,
            "t_s={t_s}: bundle {} shrank below {last_k}",
            c.bundle_size
        );
        last_k = c.bundle_size;
    }
}

#[test]
fn predicted_utilization_is_monotone_in_m_and_capped_choice_is_sane() {
    let p = MultilevelParams::default();
    let mut last = f64::INFINITY;
    for m in 1..=960u32 {
        let u = predicted_bundled_utilization(2.8, 1.3, &p, 1.0, 960.0, m as f64);
        assert!(u <= last + 1e-12, "m={m}");
        last = u;
    }
    let c = derive_bundle_size(1.0e9, 1.3, &p, 1.0, 960, 0.9);
    assert!(c.capped && c.bundles_per_proc == 1 && c.bundle_size == 960);
}

// ---------------------------------------------------------------------
// Satellite: sharding restrictions are validated errors.
// ---------------------------------------------------------------------

#[test]
fn sharding_rejects_crossing_fault_plans_and_dag_workloads() {
    use sssched::cluster::FaultPlan;
    let plain = WorkloadBuilder::constant(1.0).tasks(16).jobs(16).build();
    ShardedSim::validate_shardable(&plain, &RunOptions::default(), 4, 2).unwrap();
    // Confined to one node group (nodes 0..2 under 2 shards of 4
    // nodes): accepted — events route to the owning shard.
    ShardedSim::validate_shardable(
        &plain,
        &RunOptions::with_faults(FaultPlan::none().fail(1.0, 0)),
        4,
        2,
    )
    .unwrap();
    // Crossing groups (nodes 0 and 3): rejected with a diagnostic.
    let e = ShardedSim::validate_shardable(
        &plain,
        &RunOptions::with_faults(FaultPlan::none().fail(1.0, 0).fail(1.0, 3)),
        4,
        2,
    )
    .unwrap_err();
    assert!(e.contains("fault plans"), "{e}");
    let dag = WorkloadBuilder::constant(1.0).tasks(12).dag_chains(4).build();
    let e = ShardedSim::validate_shardable(&dag, &RunOptions::default(), 4, 2).unwrap_err();
    assert!(e.contains("dependency-free"), "{e}");
}

// ---------------------------------------------------------------------
// Determinism: model.csv is byte-identical for any --jobs.
// ---------------------------------------------------------------------

#[test]
fn model_csv_byte_identical_across_jobs() {
    let r1 = harness::model(&tiny_cfg(1), true);
    let r4 = harness::model(&tiny_cfg(4), true);
    assert_eq!(
        r1.to_csv(),
        r4.to_csv(),
        "model.csv must be byte-identical for --jobs 1 vs --jobs 4"
    );
}

// ---------------------------------------------------------------------
// Golden snapshot: fitted parameters + derived bundle sizes, pinned
// bit-for-bit (self-seeding, tests/golden_array.rs pattern).
// ---------------------------------------------------------------------

fn snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("model_fit.txt")
}

/// Bits-formatted lines for every fit, tune, and churn row of the tiny
/// pinned-seed model run.
fn compute_model_lines(rep: &ModelReport) -> Vec<String> {
    let mut lines = Vec::new();
    for row in &rep.fits {
        let name = row.scheduler.replace(' ', "_");
        match &row.fit {
            Ok(f) => lines.push(format!(
                "fit {name} {:016x} {:016x} {:016x} {}",
                f.t_s.to_bits(),
                f.alpha_s.to_bits(),
                f.r2.to_bits(),
                if f.zero_overhead { "zero" } else { "fitted" }
            )),
            Err(e) => lines.push(format!("fit {name} ERR {}", e.replace(' ', "_"))),
        }
    }
    for row in &rep.tune {
        lines.push(format!(
            "tune {} m={} k={} pred={:016x} sim={:016x}",
            row.scheduler.replace(' ', "_"),
            row.bundle.bundles_per_proc,
            row.bundle.bundle_size,
            row.bundle.predicted_u.to_bits(),
            row.mean_utilization().to_bits(),
        ));
    }
    for row in rep.churn.iter().flatten() {
        let name = row.scheduler.replace(' ', "_");
        match &row.fit {
            Ok(f) => lines.push(format!(
                "churn {name} {:016x} {:016x}",
                f.t_s.to_bits(),
                f.alpha_s.to_bits(),
            )),
            Err(e) => lines.push(format!("churn {name} ERR {}", e.replace(' ', "_"))),
        }
    }
    lines
}

fn assert_snapshot(path: &std::path::Path, lines: &[String]) {
    match std::fs::read_to_string(path) {
        Ok(expected) => {
            let expected: Vec<&str> = expected.lines().filter(|l| !l.is_empty()).collect();
            assert_eq!(
                expected.len(),
                lines.len(),
                "snapshot {} has {} lines, run produced {}",
                path.display(),
                expected.len(),
                lines.len()
            );
            for (e, got) in expected.iter().zip(lines) {
                assert_eq!(
                    *e, got,
                    "result drifted from golden snapshot {}",
                    path.display()
                );
            }
        }
        Err(_) => {
            std::fs::create_dir_all(path.parent().expect("has parent"))
                .expect("create tests/golden");
            std::fs::write(path, lines.join("\n") + "\n").expect("write snapshot");
            eprintln!(
                "golden snapshot seeded at {} — commit it to pin results",
                path.display()
            );
        }
    }
}

#[test]
fn golden_model_fit_and_tune_are_pinned() {
    let rep = harness::model(&tiny_cfg(1), true);
    // Structural expectations first, so a drifted run fails with a
    // readable message before any bit comparison.
    assert_eq!(rep.fits.len(), 6);
    assert_eq!(rep.tune.len(), 6);
    assert!(rep.fits.iter().all(|r| r.fit.is_ok()));
    assert_snapshot(&snapshot_path(), &compute_model_lines(&rep));
}

#[test]
fn golden_model_recomputation_is_stable() {
    let a = compute_model_lines(&harness::model(&tiny_cfg(1), true));
    let b = compute_model_lines(&harness::model(&tiny_cfg(1), true));
    assert_eq!(a, b, "model experiment must be deterministic per process");
}
