//! Fixture: `wall-clock` fires exactly once, on the clock read below.
//! A comment naming the wall-clock types must not fire.

pub fn elapsed_ms() -> u64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_millis() as u64
}
