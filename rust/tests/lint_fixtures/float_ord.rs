//! Fixture: `float-ord` fires exactly once, on the sort in `sort_floats`.

use std::cmp::Ordering;

pub struct Key(pub f64);

impl Key {
    /// A *definition* named partial_cmp is trait plumbing, not a float
    /// ordering hazard — it must not fire.
    pub fn partial_cmp(&self, other: &Key) -> Option<Ordering> {
        Some(self.0.total_cmp(&other.0))
    }
}

pub fn sort_floats(xs: &mut [f64]) {
    // The same word inside a string literal must not fire either:
    let _doc = "call partial_cmp to compare floats";
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
