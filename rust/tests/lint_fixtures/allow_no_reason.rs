//! Fixture: a reason-less allow is an error AND suppresses nothing.

pub fn bad(xs: &mut [f64]) {
    // pallas: allow(float-ord)
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
