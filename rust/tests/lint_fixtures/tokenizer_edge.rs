//! Fixture: tokenizer edge cases — every hazard word below sits inside
//! a string, comment, or char literal, so NOTHING may fire.

pub fn edges() -> usize {
    let a = r#"HashMap partial_cmp Instant::now() thread::spawn"#;
    let b = r##"SystemTime "quoted" RandomState"##;
    let c = "partial_cmp inside a cooked string \" with an escaped quote";
    let d = b"HashSet in a byte string";
    /* block comment: Instant::now()
       /* nested block comment: partial_cmp */
       still inside the outer comment: HashMap */
    let e = 'h'; // a char literal, not the start of a lifetime
    let f: &'static str = "lifetime then string: thread::scope";
    a.len() + b.len() + c.len() + d.len() + (e as usize) + f.len()
}
