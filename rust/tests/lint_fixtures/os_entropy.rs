//! Fixture: `os-entropy` fires exactly once, on the RandomState draw.

pub fn unseeded() -> u64 {
    let s = std::collections::hash_map::RandomState::new();
    let _ = &s;
    0
}
