//! Fixture: `hash-iteration` fires exactly once, on the use declaration.
//! (Never compiled — scanned by the linter under a synthetic src/ path.)
use std::collections::HashMap;

pub fn build() -> usize {
    // BTreeMap is the sanctioned replacement and must not fire.
    let m: std::collections::BTreeMap<u32, u32> = std::collections::BTreeMap::new();
    m.len()
}
