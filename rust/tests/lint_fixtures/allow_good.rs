//! Fixture: well-formed `pallas: allow` directives, leading and
//! trailing — both must suppress and produce zero diagnostics.

pub fn leading(xs: &mut [f64]) {
    // pallas: allow(float-ord) — fixture inputs are hand-picked finite constants
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn trailing(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); // pallas: allow(float-ord) — same finite set
}
