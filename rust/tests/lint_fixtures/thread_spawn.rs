//! Fixture: `thread-spawn` fires exactly once, on the spawn call.

pub fn reduce(xs: &[u64]) -> u64 {
    let h = std::thread::spawn(move || 0u64);
    // thread::sleep is not a reduction hazard and must not fire:
    std::thread::sleep(std::time::Duration::from_millis(1));
    h.join().unwrap_or(0) + xs.len() as u64
}
