//! Fixture: `fault-hooks` fires exactly once, on the incomplete impl.
//! (Never compiled, so the trait need not resolve.)

pub struct Incomplete;
pub struct Complete;

impl SchedPolicy for Incomplete {
    fn on_node_fail(&mut self) {}
}

impl SchedPolicy for Complete {
    fn on_node_fail(&mut self) {}
    fn on_node_suspected(&mut self) {}
    fn on_node_drain(&mut self) {}
    fn on_node_recover(&mut self) {}
}

#[cfg(test)]
mod tests {
    // Test-harness policies are scaffolding and must not fire.
    impl SchedPolicy for TestOnly {}
}
