//! Fixture: an allow naming an unknown rule is an error.

pub fn noop() {
    // pallas: allow(no-such-rule) — typo'd rule names must be caught
    let _x = 0u32;
}
