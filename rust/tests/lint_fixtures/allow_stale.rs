//! Fixture: an allow whose hazard was since fixed is a stale-allow error.

pub fn fixed(xs: &mut [f64]) {
    // pallas: allow(float-ord) — nothing below trips the rule any more
    xs.sort_by(|a, b| a.total_cmp(b));
}
