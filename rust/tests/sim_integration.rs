//! Cross-module integration: workload → scheduler → model → trace I/O,
//! plus failure injection (down nodes) and submission-mode ablation.

use sssched::cluster::{ClusterSpec, NodeState};
use sssched::config::SchedulerChoice;
use sssched::model::{u_constant_approx, u_constant_exact};
use sssched::sched::{make_scheduler, RunOptions};
use sssched::workload::{read_trace, write_trace, WorkloadBuilder};

#[test]
fn trace_roundtrip_through_disk() {
    let cluster = ClusterSpec::homogeneous(2, 4, 32 * 1024, 2);
    let sched = make_scheduler(SchedulerChoice::Slurm);
    let w = WorkloadBuilder::constant(2.0).tasks(32).label("io").build();
    let r = sched.run(&w, &cluster, 5, &RunOptions::with_trace());
    let trace = r.trace.clone().unwrap();
    let path = std::env::temp_dir().join("sssched_sim_trace.csv");
    write_trace(&path, &trace).unwrap();
    let back = read_trace(&path).unwrap();
    assert_eq!(back.len(), trace.len());
    for (a, b) in trace.iter().zip(&back) {
        assert_eq!(a.task, b.task);
        assert!((a.start - b.start).abs() < 1e-5);
        assert!((a.end - b.end).abs() < 1e-5);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn down_nodes_stretch_makespan() {
    let mut cluster = ClusterSpec::homogeneous(4, 4, 32 * 1024, 2);
    let sched = make_scheduler(SchedulerChoice::Mesos);
    let w = WorkloadBuilder::constant(5.0).tasks(64).build();
    let healthy = sched.run(&w, &cluster, 3, &RunOptions::default());
    cluster.set_state(0, NodeState::Down);
    cluster.set_state(1, NodeState::Draining);
    let degraded = sched.run(&w, &cluster, 3, &RunOptions::default());
    assert_eq!(degraded.processors, 8);
    assert!(
        degraded.t_total > healthy.t_total * 1.5,
        "half the cluster down: {} vs {}",
        degraded.t_total,
        healthy.t_total
    );
    degraded.check_invariants().unwrap();
}

#[test]
fn measured_utilization_tracks_model() {
    // The sim's U(t) curve should sit near the paper's U_c(t) model
    // evaluated at the sim's own fitted t_s — self-consistency of
    // Section 4 vs Section 5.
    let cluster = ClusterSpec::supercloud();
    let sched = make_scheduler(SchedulerChoice::Slurm);
    let p = cluster.total_cores();
    let mut points = Vec::new();
    for n in [8u64, 48, 240] {
        let t = 240.0 / n as f64;
        let w = WorkloadBuilder::constant(t).tasks(n * p).build();
        let r = sched.run(&w, &cluster, 11, &RunOptions::default());
        points.push((n as f64, t, r.delta_t(), r.utilization()));
    }
    let fit = sssched::util::fit::fit_power_law(
        &points.iter().map(|p| p.0).collect::<Vec<_>>(),
        &points.iter().map(|p| p.2).collect::<Vec<_>>(),
    );
    for &(n, t, _, u_measured) in &points {
        let u_exact = u_constant_exact(fit.t_s, fit.alpha_s, t, n);
        assert!(
            (u_measured - u_exact).abs() < 0.12,
            "n={n}: measured U={u_measured:.3} vs model {u_exact:.3}"
        );
        let _ = u_constant_approx(fit.t_s, t);
    }
}

#[test]
fn array_vs_individual_submission_ablation() {
    // The paper: "jobs were submitted as job arrays because they
    // introduce much less scheduler latency than ... individual jobs".
    // Individual submission pays the per-job submit cost N times
    // serially; arrays amortize it. We model this by comparing the
    // array submit cost (base + per-task) against N individual
    // submissions (N × base).
    use sssched::sched::calibration::slurm_params;
    let p = slurm_params();
    let n = 10_000.0;
    let array_cost = p.submit_cost_base + p.submit_cost_per_task * n;
    let individual_cost = p.submit_cost_base * n;
    assert!(
        individual_cost > array_cost * 100.0,
        "individual {individual_cost}s vs array {array_cost}s"
    );
}

#[test]
fn variable_task_times_average_like_constant() {
    // Section 4's claim: constant-task-time curves predict variable
    // mixes via the per-processor average task time.
    let cluster = ClusterSpec::homogeneous(4, 8, 64 * 1024, 2);
    let sched = make_scheduler(SchedulerChoice::Slurm);
    let p = cluster.total_cores();
    let n = 16u64;
    // Variable: lognormal mean 5 s.
    let wv = WorkloadBuilder::with_dist(sssched::workload::TaskTimeDist::Lognormal {
        mean: 5.0,
        cv: 0.5,
    })
    .tasks(n * p)
    .seed(3)
    .build();
    let rv = sched.run(&wv, &cluster, 3, &RunOptions::default());
    // Constant 5 s.
    let wc = WorkloadBuilder::constant(5.0).tasks(n * p).build();
    let rc = sched.run(&wc, &cluster, 3, &RunOptions::default());
    assert!(
        (rv.utilization() - rc.utilization()).abs() < 0.12,
        "variable U={:.3} vs constant U={:.3}",
        rv.utilization(),
        rc.utilization()
    );
}

#[test]
fn waits_grow_with_queue_depth() {
    let cluster = ClusterSpec::homogeneous(2, 4, 32 * 1024, 2);
    let sched = make_scheduler(SchedulerChoice::GridEngine);
    let shallow = WorkloadBuilder::constant(1.0).tasks(8).build();
    let deep = WorkloadBuilder::constant(1.0).tasks(400).build();
    let r1 = sched.run(&shallow, &cluster, 9, &RunOptions::default());
    let r2 = sched.run(&deep, &cluster, 9, &RunOptions::default());
    assert!(r2.waits.mean() > r1.waits.mean() * 2.0);
    assert!(r2.waits.max() > r1.waits.max());
}
