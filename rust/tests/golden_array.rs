//! Golden test: array-workload results across every backend are pinned
//! bit-for-bit against a checked-in snapshot, so future refactors of
//! the kernel/policy split cannot silently change simulation output.
//!
//! The kernel refactor was constructed to replay the pre-refactor
//! per-backend event loops exactly (same RNG-draw order, same event
//! sequence) for 1-core dep-free batch workloads; the analytic cases
//! below (IdealFIFO) verify that directly, and the snapshot freezes the
//! stochastic backends. On first run (no snapshot file) the snapshot is
//! written and the test passes; commit the generated file to pin the
//! results.

use sssched::cluster::{ClusterSpec, FaultPlan};
use sssched::config::SchedulerChoice;
use sssched::multilevel::{Multilevel, MultilevelParams};
use sssched::sched::batchq::{BatchJob, BatchQueueSim, QueuePolicy};
use sssched::sched::combinators::{make_preemptive, Order};
use sssched::sched::{make_scheduler, RunOptions, Scheduler};
use sssched::sim::Kernel;
use sssched::workload::{TaskSpec, Workload, WorkloadBuilder};
use std::path::PathBuf;

fn snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("array_t_total.txt")
}

fn preempt_snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("preempt_t_total.txt")
}

fn churn_snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("churn_slurm.txt")
}

fn cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(2, 8, 32 * 1024, 2)
}

/// `name seed t_total_bits` lines for every backend × seed.
fn compute_lines() -> Vec<String> {
    let cluster = cluster();
    let w = WorkloadBuilder::constant(1.0).tasks(200).label("golden").build();
    let mut lines = Vec::new();
    for seed in [1u64, 2, 3] {
        for choice in SchedulerChoice::all_simulated() {
            let sched = make_scheduler(choice);
            let r = sched.run(&w, &cluster, seed, &RunOptions::default());
            lines.push(format!(
                "{} {seed} {:016x}",
                choice.name().replace(' ', "_"),
                r.t_total.to_bits()
            ));
        }
        // Multilevel wrapper over the Slurm-like backend.
        let inner = make_scheduler(SchedulerChoice::Slurm);
        let ml = Multilevel::new(inner.as_ref(), MultilevelParams::default());
        let r = ml.run(&w, &cluster, seed, &RunOptions::default());
        lines.push(format!("Multilevel+Slurm {seed} {:016x}", r.t_total.to_bits()));
        // Batch-queue FCFS over rigid 1..8-core jobs.
        let jobs: Vec<BatchJob> = (0..64)
            .map(|id| BatchJob {
                id,
                user: id % 3,
                cores: 1 + (id % 8),
                duration: 5.0 + (id % 4) as f64,
                priority: 0,
                submit_at: 0.0,
            })
            .collect();
        let b = BatchQueueSim::new(QueuePolicy::FcfsBackfill)
            .run(&jobs, &cluster)
            .unwrap();
        lines.push(format!("BatchQueue {seed} {:016x}", b.makespan.to_bits()));
    }
    lines
}

/// Deterministic preemption workload: 24 preemptible 8 s background
/// tasks + 8 priority-10 2 s foreground tasks arriving on a fixed
/// stagger. Exercises evict / checkpoint-drain / resume on the
/// centralized backend.
fn preempt_workload() -> Workload {
    let mut tasks: Vec<TaskSpec> = Vec::new();
    for i in 0..24u32 {
        let mut t = TaskSpec::array(i, i, 8.0);
        t.preemptible = true;
        t.checkpoint_cost = 0.5;
        t.user = i % 2;
        tasks.push(t);
    }
    for k in 0..8u32 {
        let mut t = TaskSpec::array(24 + k, 24 + k, 2.0);
        t.priority = 10;
        t.user = 2;
        t.submit_at = 1.5 * k as f64 + 0.25;
        tasks.push(t);
    }
    Workload {
        tasks,
        label: "golden-preempt".into(),
    }
}

/// `name seed t_total_bits preemptions` lines for the preemption /
/// fairness-combinator runs (separate snapshot so the pre-existing
/// array snapshot stays byte-identical).
fn compute_preempt_lines() -> Vec<String> {
    let cluster = cluster();
    let mut lines = Vec::new();
    let wp = preempt_workload();
    let wf = WorkloadBuilder::constant(1.0)
        .tasks(200)
        .users(3)
        .label("golden-fair")
        .build();
    for seed in [1u64, 2, 3] {
        // Preemption-enabled centralized run (Slurm + priority +
        // preemption wrapper).
        let pre = make_preemptive(SchedulerChoice::Slurm, 1, Order::Priority);
        let r = pre.run(&wp, &cluster, seed, &RunOptions::default());
        lines.push(format!(
            "Slurm+prio+preempt {seed} {:016x} {}",
            r.t_total.to_bits(),
            r.preemptions
        ));
        // Fairshare-combinator run: the Slurm policy under
        // combinators::Ordered(Fairshare) on a 3-user array workload.
        let slurm = make_scheduler(SchedulerChoice::Slurm);
        let inner = slurm.make_policy(seed).expect("slurm is kernel-driven");
        let mut policy =
            sssched::sched::combinators::Ordered::new(Order::Fairshare, inner);
        let r = Kernel::run(
            &mut policy,
            &wf,
            &cluster,
            &RunOptions::default(),
            &mut sssched::sched::SimScratch::new(),
        );
        lines.push(format!("Slurm+fair {seed} {:016x} 0", r.t_total.to_bits()));
    }
    lines
}

/// `Slurm+churn seed goodput_bits wasted_bits kills failed_set retry_hist`
/// lines for a fixed 3-event fault plan: node 0 dies mid-run and
/// returns, node 1 drains and stays out. Pins the fault subsystem's
/// goodput, kill/retry accounting and exact failed-task set on the
/// Slurm-like backend (separate snapshot so the pre-existing ones stay
/// byte-identical).
fn compute_churn_lines() -> Vec<String> {
    let cluster = cluster();
    let n = 200usize;
    let mut w = WorkloadBuilder::constant(1.0)
        .tasks(n as u64)
        .label("golden-churn")
        .build();
    for t in &mut w.tasks {
        // Alternating 0/1 retry budgets: half of the kills on node 0
        // requeue once, the other half fail permanently.
        t.max_retries = t.id % 2;
    }
    let plan = FaultPlan::none().fail(2.7, 0).drain(5.3, 1).recover(6.1, 0);
    let opts = RunOptions {
        collect_trace: true,
        // Generous window: every task completes or fails well inside
        // it, so the failed set is exactly the trace's complement.
        horizon: Some(60.0),
        faults: plan,
        ..Default::default()
    };
    w.validate_for(&opts).unwrap();
    let mut lines = Vec::new();
    for seed in [1u64, 2, 3] {
        let r = make_scheduler(SchedulerChoice::Slurm).run(&w, &cluster, seed, &opts);
        let trace = r.trace.as_ref().expect("traced run");
        let mut done = vec![false; n];
        for rec in trace {
            done[rec.task as usize] = true;
        }
        let failed: Vec<String> = (0..n).filter(|&i| !done[i]).map(|i| i.to_string()).collect();
        assert_eq!(failed.len() as u64, r.failed, "trace/failed-count mismatch");
        let mut dispatches = vec![0u32; n];
        for s in r.spans.as_ref().expect("faulted run collects spans") {
            dispatches[s.task as usize] += 1;
        }
        let mut hist = [0u64; 3]; // retries 0, 1, 2+ (budgets are 0/1)
        for &d in &dispatches {
            hist[(d.saturating_sub(1) as usize).min(2)] += 1;
        }
        lines.push(format!(
            "Slurm+churn {seed} {:016x} {:016x} kills={} failed=[{}] retries={:?}",
            r.goodput_utilization().to_bits(),
            r.wasted_core_seconds.to_bits(),
            r.kills,
            failed.join(","),
            hist
        ));
    }
    lines
}

fn assert_snapshot(path: &std::path::Path, lines: &[String]) {
    match std::fs::read_to_string(path) {
        Ok(expected) => {
            let expected: Vec<&str> = expected.lines().filter(|l| !l.is_empty()).collect();
            assert_eq!(
                expected.len(),
                lines.len(),
                "snapshot {} has {} lines, run produced {}",
                path.display(),
                expected.len(),
                lines.len()
            );
            for (e, got) in expected.iter().zip(lines) {
                assert_eq!(
                    *e, got,
                    "result drifted from golden snapshot {}",
                    path.display()
                );
            }
        }
        Err(_) => {
            std::fs::create_dir_all(path.parent().expect("has parent"))
                .expect("create tests/golden");
            std::fs::write(path, lines.join("\n") + "\n").expect("write snapshot");
            eprintln!(
                "golden snapshot seeded at {} — commit it to pin results",
                path.display()
            );
        }
    }
}

#[test]
fn golden_preempt_results_are_pinned() {
    assert_snapshot(&preempt_snapshot_path(), &compute_preempt_lines());
}

#[test]
fn golden_preempt_recomputation_is_stable() {
    assert_eq!(compute_preempt_lines(), compute_preempt_lines());
}

#[test]
fn golden_array_results_are_pinned() {
    assert_snapshot(&snapshot_path(), &compute_lines());
}

#[test]
fn golden_churn_results_are_pinned() {
    assert_snapshot(&churn_snapshot_path(), &compute_churn_lines());
}

#[test]
fn golden_churn_recomputation_is_stable() {
    assert_eq!(compute_churn_lines(), compute_churn_lines());
}

#[test]
fn ideal_fifo_analytic_goldens() {
    // These values are derivable by hand and were exact in the
    // pre-kernel implementation: the kernel must reproduce them to the
    // last bit of floating-point arithmetic.
    let cluster = cluster(); // 16 slots
    let ideal = make_scheduler(SchedulerChoice::IdealFifo);
    // 200 × 1 s tasks on 16 slots: ceil(200/16) = 13 waves -> 13 s.
    let w = WorkloadBuilder::constant(1.0).tasks(200).build();
    let r = ideal.run(&w, &cluster, 0, &RunOptions::default());
    assert_eq!(r.t_total, 13.0);
    // 64 × 3 s tasks: 4 waves -> 12 s, utilization exactly 1.
    let w = WorkloadBuilder::constant(3.0).tasks(64).build();
    let r = ideal.run(&w, &cluster, 0, &RunOptions::default());
    assert_eq!(r.t_total, 12.0);
    assert!((r.utilization() - 1.0).abs() < 1e-12);
}

#[test]
fn goldens_are_scratch_and_seed_stable() {
    // The snapshot is only meaningful if recomputation is stable:
    // two fresh computations must agree bit-for-bit.
    assert_eq!(compute_lines(), compute_lines());
}
