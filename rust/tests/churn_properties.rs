//! Property suite for the fault-injection kernel: random fault plans ×
//! every simulated backend × {array, gang, DAG, service} workload
//! shapes, checked against the failure model's invariants.
//!
//! For every run the suite reconstructs each node's lifecycle windows
//! from the `FaultPlan` and asserts, from the execution spans:
//!
//! - **No span overlaps a down window.** Between a node's `Fail` and
//!   its `Recover`, no execution span may occupy any of its slots —
//!   killed runs end exactly at the fail instant, restarts begin at or
//!   after recovery.
//! - **No span starts in an unplaceable window.** From the first
//!   `Drain`/`Fail` of a lifecycle cycle until `Recover`, the node
//!   accepts no new placements (drains let already-running work
//!   finish, so only span *starts* are constrained).
//! - **Retries never exceed budget.** A batch task is dispatched at
//!   most `max_retries + 1` times; every non-final span ends at a kill
//!   instant (its node's fail time — or any fail time for gang
//!   members, which die atomically with the member on the dead node).
//! - **Kill/waste accounting is exact.** Dispatches = kills +
//!   completions, and `wasted_core_seconds` equals the span-seconds of
//!   exactly the killed runs.
//! - **Failure is completion's complement** (horizonless runs):
//!   `completed + failed == n`, the trace holds precisely the
//!   completed tasks, DAG dependents of a failed task fail too, and no
//!   gang member's span runs through an instant at which its
//!   gang-mates were killed (kill atomicity over running members).
//! - **Warm scratch ≡ fresh.** Every faulted run is executed twice —
//!   once on a reused `SimScratch`, once fresh — and must be
//!   bit-identical (the `churn` experiment additionally pins
//!   `--jobs 1 ≡ --jobs N` in the harness tests).

use std::collections::BTreeMap;

use sssched::cluster::{ClusterSpec, FaultKind, FaultPlan};
use sssched::config::SchedulerChoice;
use sssched::sched::{make_scheduler, RunOptions, RunResult, SimScratch};
use sssched::util::prng::Prng;
use sssched::workload::{ArrivalProcess, JobKind, Workload, WorkloadBuilder};

const NODES: u32 = 6;
const CORES: u32 = 4;
const TASK_T: f64 = 2.0;
const EPS: f64 = 1e-9;

fn cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(NODES, CORES, 32 * 1024, 3)
}

/// Random node-lifecycle plan: each node gets (with probability) one
/// fail or drain cycle — drains sometimes dying outright mid-drain —
/// and possibly a second fail cycle. Every cycle is closed with a
/// `Recover`, so horizonless runs always regain full capacity and
/// terminate.
fn random_plan(rng: &mut Prng, span: f64) -> FaultPlan {
    let mut plan = FaultPlan::none();
    let mut any = false;
    for node in 0..NODES {
        if !rng.chance(0.7) {
            continue;
        }
        any = true;
        let a = rng.range_f64(span * 0.04, span * 0.55);
        let b = a + rng.range_f64(span * 0.03, span * 0.25);
        if rng.chance(0.5) {
            plan = plan.fail(a, node);
        } else {
            plan = plan.drain(a, node);
            if rng.chance(0.4) {
                // The drained node dies before it finishes draining.
                plan = plan.fail(a + (b - a) * 0.5, node);
            }
        }
        plan = plan.recover(b, node);
        if rng.chance(0.3) {
            let c = b + rng.range_f64(span * 0.02, span * 0.2);
            let d = c + rng.range_f64(span * 0.02, span * 0.15);
            plan = plan.fail(c, node).recover(d, node);
        }
    }
    if !any {
        plan = plan.fail(span * 0.25, 0).recover(span * 0.35, 0);
    }
    plan.validate().expect("generated plan must be valid");
    plan
}

#[derive(Default, Clone)]
struct NodeWindows {
    /// `(fail, recover)`: no span may overlap the interior.
    down: Vec<(f64, f64)>,
    /// `(first drain/fail, recover)`: no span may start inside.
    no_place: Vec<(f64, f64)>,
    /// Kill instants (fail times) on this node.
    fails: Vec<f64>,
}

/// Replay the plan in firing order into per-node lifecycle windows.
fn fault_windows(plan: &FaultPlan) -> Vec<NodeWindows> {
    let mut order: Vec<usize> = (0..plan.events.len()).collect();
    order.sort_by(|&a, &b| plan.events[a].at.total_cmp(&plan.events[b].at));
    let mut win = vec![NodeWindows::default(); NODES as usize];
    let mut down_at = vec![None; NODES as usize];
    let mut gone_at = vec![None; NODES as usize];
    for &i in &order {
        let e = &plan.events[i];
        let n = e.node as usize;
        match e.kind {
            FaultKind::Fail => {
                win[n].fails.push(e.at);
                if down_at[n].is_none() {
                    down_at[n] = Some(e.at);
                }
                if gone_at[n].is_none() {
                    gone_at[n] = Some(e.at);
                }
            }
            FaultKind::Drain => {
                if gone_at[n].is_none() {
                    gone_at[n] = Some(e.at);
                }
            }
            FaultKind::Recover => {
                if let Some(s) = down_at[n].take() {
                    win[n].down.push((s, e.at));
                }
                if let Some(s) = gone_at[n].take() {
                    win[n].no_place.push((s, e.at));
                }
            }
        }
    }
    for n in 0..NODES as usize {
        assert!(
            down_at[n].is_none() && gone_at[n].is_none(),
            "generator must close every lifecycle cycle (node {n} left open)"
        );
    }
    win
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.t_total.to_bits(), b.t_total.to_bits(), "{what}: t_total");
    assert_eq!(a.events, b.events, "{what}: events");
    assert_eq!(a.kills, b.kills, "{what}: kills");
    assert_eq!(a.failed, b.failed, "{what}: failed");
    assert_eq!(a.completed, b.completed, "{what}: completed");
    assert_eq!(
        a.wasted_core_seconds.to_bits(),
        b.wasted_core_seconds.to_bits(),
        "{what}: wasted_core_seconds"
    );
    assert_eq!(
        a.busy_core_seconds.to_bits(),
        b.busy_core_seconds.to_bits(),
        "{what}: busy_core_seconds"
    );
    assert_eq!(a.trace, b.trace, "{what}: trace");
    assert_eq!(a.spans, b.spans, "{what}: spans");
}

/// Check every fault-model property a single run must satisfy.
fn check_run(w: &Workload, plan: &FaultPlan, r: &RunResult, label: &str) {
    r.check_invariants().unwrap_or_else(|e| panic!("{label}: {e}"));
    assert_eq!(r.preemptions, 0, "{label}: no preemption in these workloads");
    let horizonless = r.horizon.is_none();
    let win = fault_windows(plan);
    let spans = r.spans.as_ref().expect("faulted traced runs collect spans");
    let all_fails: Vec<f64> = win.iter().flat_map(|nw| nw.fails.iter().copied()).collect();

    // -- spatial: spans vs node lifecycle windows --
    for s in spans {
        let node = (s.slot / CORES) as usize;
        assert!(s.end >= s.start - EPS, "{label}: span of task {} inverted", s.task);
        if let Some(h) = r.horizon {
            assert!(
                s.start >= -EPS && s.end <= h + EPS,
                "{label}: task {} span [{}, {}] escapes window [0, {h}]",
                s.task,
                s.start,
                s.end
            );
        }
        for &(a, b) in &win[node].down {
            assert!(
                s.end <= a + EPS || s.start >= b - EPS,
                "{label}: task {} span [{}, {}] overlaps down window [{a}, {b}] on node {node}",
                s.task,
                s.start,
                s.end
            );
        }
        for &(a, b) in &win[node].no_place {
            // Strict at the left edge: a fault event at t fires before
            // any same-instant Start, so a launch exactly at the
            // drain/fail instant must have been aborted.
            assert!(
                s.start < a || s.start >= b - EPS,
                "{label}: task {} span starts at {} inside unplaceable window [{a}, {b}) \
                 on node {node}",
                s.task,
                s.start
            );
        }
    }

    // -- per-task: retry budgets, kill-aligned span ends, no overlap --
    let n = w.tasks.len();
    let mut per_task: Vec<Vec<&sssched::sched::ExecSpan>> = vec![Vec::new(); n];
    for s in spans {
        per_task[s.task as usize].push(s);
    }
    for (tid, ts) in per_task.iter_mut().enumerate() {
        let spec = &w.tasks[tid];
        ts.sort_by(|x, y| x.start.total_cmp(&y.start));
        for pair in ts.windows(2) {
            assert!(
                pair[0].end <= pair[1].start + EPS,
                "{label}: task {tid} spans overlap in time"
            );
        }
        if spec.kind != JobKind::Service {
            assert!(
                ts.len() as u32 <= spec.max_retries + 1,
                "{label}: task {tid} dispatched {} times, retry budget {}",
                ts.len(),
                spec.max_retries
            );
        }
        if ts.is_empty() {
            continue;
        }
        // Every non-final span is a killed run: it must end exactly at
        // a fail instant — on its own node, except gang members, which
        // die atomically when any member's node fails.
        for s in &ts[..ts.len() - 1] {
            let node = (s.slot / CORES) as usize;
            let killed_at = if spec.kind == JobKind::Parallel {
                all_fails.iter().any(|&ft| (ft - s.end).abs() <= EPS)
            } else {
                win[node].fails.iter().any(|&ft| (ft - s.end).abs() <= EPS)
            };
            assert!(
                killed_at,
                "{label}: task {tid} non-final span ends at {} which is not a kill instant",
                s.end
            );
        }
    }

    // -- global accounting (horizonless batch shapes: every span ends
    //    in either a kill or a completion, and all tasks are 1-core) --
    if horizonless {
        assert_eq!(
            r.completed + r.failed,
            n as u64,
            "{label}: horizonless runs finish or fail every task"
        );
        assert_eq!(
            r.kills,
            spans.len() as u64 - r.completed,
            "{label}: dispatches = kills + completions"
        );

        let trace = r.trace.as_ref().expect("traced run");
        let mut done = vec![false; n];
        for rec in trace {
            done[rec.task as usize] = true;
        }
        assert_eq!(
            done.iter().filter(|&&d| d).count() as u64,
            r.completed,
            "{label}: trace holds exactly the completed tasks"
        );

        // Wasted = span-seconds of exactly the killed runs: everything
        // except each completed task's final (completing) span.
        let total: f64 = spans.iter().map(|s| s.end - s.start).sum();
        let finished: f64 = per_task
            .iter()
            .enumerate()
            .filter(|(tid, _)| done[*tid])
            .filter_map(|(_, ts)| ts.last().map(|s| s.end - s.start))
            .sum();
        assert!(
            (r.wasted_core_seconds - (total - finished)).abs() <= 1e-6 * total.max(1.0),
            "{label}: wasted_core_seconds {} != span-seconds of killed runs {}",
            r.wasted_core_seconds,
            total - finished
        );
        for t in &w.tasks {
            // A failed task that ever ran was killed on its last span.
            if !done[t.id as usize] {
                if let Some(last) = per_task[t.id as usize].last() {
                    let node = (last.slot / CORES) as usize;
                    let killed = if t.kind == JobKind::Parallel {
                        all_fails.iter().any(|&ft| (ft - last.end).abs() <= EPS)
                    } else {
                        win[node].fails.iter().any(|&ft| (ft - last.end).abs() <= EPS)
                    };
                    assert!(
                        killed,
                        "{label}: failed task {} last span must end at a kill instant",
                        t.id
                    );
                }
            }
            // Cascade: a task can never outlive a failed dependency.
            for &d in &t.deps {
                if !done[d as usize] {
                    assert!(
                        !done[t.id as usize],
                        "{label}: task {} completed though dependency {d} failed",
                        t.id
                    );
                }
            }
        }

        // Gang kill atomicity: when any gang member dies at a fail
        // instant, every member *running* at that instant dies with it
        // — no member's span may run through a kill that took its
        // gang-mates. (Members whose launch was still in flight are
        // not running yet; they abort or proceed individually, so only
        // spans covering the instant are constrained.)
        let mut gangs: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
        for t in &w.tasks {
            if t.kind == JobKind::Parallel {
                gangs.entry(t.job).or_default().push(t.id);
            }
        }
        for (job, members) in &gangs {
            for &tf in &all_fails {
                let gang_killed = members.iter().any(|&m| {
                    let ts = &per_task[m as usize];
                    ts.iter().enumerate().any(|(k, s)| {
                        // A span ending at tf is a kill unless it is
                        // the member's completing (final, done) span.
                        (s.end - tf).abs() <= EPS && !(k == ts.len() - 1 && done[m as usize])
                    })
                });
                if !gang_killed {
                    continue;
                }
                for &m in members {
                    for s in &per_task[m as usize] {
                        assert!(
                            s.start >= tf - EPS || s.end <= tf + EPS,
                            "{label}: gang {job} member {m} span [{}, {}] runs through \
                             the gang kill at t={tf}",
                            s.start,
                            s.end
                        );
                    }
                }
            }
        }
    }
}

/// Drive one workload shape through every simulated backend × several
/// random plans, asserting warm-scratch ≡ fresh bit-identity and all
/// fault-model properties on each run.
fn drive(shape: &str, w: &Workload, span: f64, horizon: Option<f64>, plan_seed: u64) {
    let cl = cluster();
    let mut rng = Prng::new(plan_seed);
    let mut scratch = SimScratch::new();
    for choice in SchedulerChoice::all_simulated() {
        let sched = make_scheduler(choice);
        for trial in 0..3u64 {
            let plan = random_plan(&mut rng, span);
            let opts = RunOptions {
                collect_trace: true,
                horizon,
                faults: plan.clone(),
                ..Default::default()
            };
            w.validate_for(&opts).unwrap();
            let label = format!("{shape}/{}/trial{trial}", choice.name());
            let warm = sched.run_with_scratch(w, &cl, 0xC0DE + trial, &opts, &mut scratch);
            let fresh = sched.run(w, &cl, 0xC0DE + trial, &opts);
            assert_bit_identical(&warm, &fresh, &label);
            check_run(w, &plan, &warm, &label);
        }
    }
}

fn batch_base(n: u64, seed: u64) -> WorkloadBuilder {
    WorkloadBuilder::constant(TASK_T)
        .tasks(n)
        .seed(seed)
        .label("churn-prop")
}

#[test]
fn array_tasks_respect_fault_windows_and_budgets() {
    let mut w = batch_base(48, 0xA1)
        .arrivals(ArrivalProcess::Poisson { rate: 10.0 })
        .build();
    for t in &mut w.tasks {
        t.max_retries = t.id % 4;
    }
    drive("array", &w, 12.0, None, 0x0A11);
}

#[test]
fn gangs_die_atomically_under_churn() {
    let mut w = batch_base(48, 0xB2).gangs(4).build();
    for t in &mut w.tasks {
        // Uniform budget inside each gang: members share kill counts,
        // so they exhaust their budgets in lockstep.
        t.max_retries = 2;
    }
    drive("gang", &w, 12.0, None, 0x0B22);
}

#[test]
fn dag_dependents_cascade_with_failed_dependencies() {
    let mut w = batch_base(48, 0xC3).dag_chains(4).build();
    for t in &mut w.tasks {
        t.max_retries = t.id % 2;
    }
    drive("dag", &w, 16.0, None, 0x0C33);
}

#[test]
fn services_restart_and_batch_windows_hold_under_churn() {
    let horizon = 30.0;
    let mut w = batch_base(40, 0xD4)
        .arrivals(ArrivalProcess::Poisson { rate: 4.0 })
        .services(4, 1)
        .build();
    for t in &mut w.tasks {
        if t.kind != JobKind::Service {
            t.max_retries = t.id % 3;
        }
    }
    drive("service", &w, horizon, Some(horizon), 0x0D44);
}
