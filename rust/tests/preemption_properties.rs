//! Property suite locking down the kernel preemption subsystem: across
//! random workload mixes (plain, multi-core, gang-scheduled) on every
//! backend wrapped by the ordering + preemption combinators, assert
//!
//! * **no lost work** — every task's executed span lengths sum to its
//!   duration (never more), even through arbitrary evict/resume chains;
//! * **no double-allocated slots** — execution spans on one slot never
//!   overlap after evict/requeue cycles;
//! * **gang atomicity** — no gang member keeps running across a
//!   sibling's eviction instant (whole-gang all-or-nothing);
//! * **determinism** — warm-scratch reuse is bit-identical, and the
//!   `preempt` experiment is bit-identical for every `--jobs` value.

use sssched::config::{ExperimentConfig, SchedulerChoice};
use sssched::harness;
use sssched::sched::combinators::{make_preemptive, Order};
use sssched::sched::{RunOptions, RunResult, SimScratch};
use sssched::util::prng::Prng;
use sssched::util::prop::{ensure, forall, PropConfig};
use sssched::workload::{JobKind, TaskSpec, Workload};

#[derive(Debug, Clone, Copy, PartialEq)]
enum Flavor {
    Plain,
    Multicore,
    Gang,
}

#[derive(Debug)]
struct Case {
    choice: SchedulerChoice,
    order: Order,
    flavor: Flavor,
    bg: u64,
    fg: u64,
    bg_time: f64,
    cost: f64,
    seed: u64,
}

fn gen_case(rng: &mut Prng) -> Case {
    let choices = SchedulerChoice::all_simulated();
    let orders = [Order::Priority, Order::Fairshare];
    let flavors = [Flavor::Plain, Flavor::Multicore, Flavor::Gang];
    Case {
        choice: choices[rng.choose_index(choices.len())],
        order: orders[rng.choose_index(orders.len())],
        flavor: flavors[rng.choose_index(flavors.len())],
        bg: rng.range_u64(4, 40),
        fg: rng.range_u64(1, 12),
        bg_time: rng.range_f64(2.0, 10.0),
        cost: if rng.chance(0.5) {
            0.0
        } else {
            rng.range_f64(0.0, 1.0)
        },
        seed: rng.next_u64(),
    }
}

fn cluster() -> sssched::cluster::ClusterSpec {
    // 2 nodes × 8 cores: headroom for 4-wide gangs of 2-core tasks.
    sssched::cluster::ClusterSpec::homogeneous(2, 8, 64 * 1024, 2)
}

/// Preemptible background (flavored) + high-priority staggered
/// foreground arrivals, deterministic in `case.seed`.
fn build_workload(case: &Case) -> Workload {
    let mut rng = Prng::new(case.seed ^ 0x9EE4_5EED);
    let mut tasks: Vec<TaskSpec> = Vec::new();
    let bg = match case.flavor {
        Flavor::Gang => (case.bg / 4).max(1) * 4, // whole gangs of 4
        _ => case.bg,
    };
    for i in 0..bg {
        let job = if case.flavor == Flavor::Gang {
            (i / 4) as u32
        } else {
            i as u32
        };
        let mut t = TaskSpec::array(i as u32, job, case.bg_time);
        t.preemptible = true;
        t.checkpoint_cost = case.cost;
        t.user = (i % 3) as u32;
        match case.flavor {
            Flavor::Multicore => t.cores = 2,
            Flavor::Gang => t.kind = JobKind::Parallel,
            Flavor::Plain => {}
        }
        tasks.push(t);
    }
    let span = (bg as f64 * case.bg_time / 16.0).max(case.bg_time);
    for k in 0..case.fg {
        let id = (bg + k) as u32;
        let mut t = TaskSpec::array(id, id, case.bg_time / 4.0);
        t.priority = 10;
        t.user = 3;
        t.submit_at = rng.range_f64(0.0, span);
        tasks.push(t);
    }
    let w = Workload {
        tasks,
        label: "prop-preempt".into(),
    };
    w.validate().expect("generated workload valid");
    w
}

/// Last completion instant per task (= end of its final span).
fn last_ends(r: &RunResult) -> Vec<f64> {
    let spans = r.spans.as_ref().expect("preempt runs record spans");
    let mut last = vec![f64::NEG_INFINITY; r.n_tasks as usize];
    for s in spans {
        if s.end > last[s.task as usize] {
            last[s.task as usize] = s.end;
        }
    }
    last
}

#[test]
fn prop_no_lost_work_and_no_slot_overlap() {
    forall(
        PropConfig {
            cases: 60,
            seed: 0x9E4E,
        },
        gen_case,
        |case| {
            let w = build_workload(case);
            let sched = make_preemptive(case.choice, 1, case.order);
            let r = sched.run(&w, &cluster(), case.seed, &RunOptions::with_trace());
            r.check_invariants()?;
            let spans = r.spans.as_ref().expect("spans collected");

            // Executed work per task: sum of span lengths must equal
            // the duration — never more (no duplicated execution),
            // never less (completed tasks ran fully).
            let mut executed = vec![0.0f64; w.len()];
            for s in spans {
                ensure(
                    s.end >= s.start - 1e-9,
                    format!("negative span {s:?}"),
                )?;
                executed[s.task as usize] += s.end - s.start;
            }
            for t in &w.tasks {
                let ex = executed[t.id as usize];
                ensure(
                    (ex - t.duration).abs() < 1e-6,
                    format!(
                        "task {} executed {ex}, duration {} (lost or duplicated work)",
                        t.id, t.duration
                    ),
                )?;
            }

            // Spans on one slot never overlap: evict/requeue cannot
            // double-allocate a slot.
            let mut by_slot: std::collections::BTreeMap<u32, Vec<(f64, f64)>> =
                std::collections::BTreeMap::new();
            for s in spans {
                by_slot.entry(s.slot).or_default().push((s.start, s.end));
            }
            for (slot, list) in by_slot.iter_mut() {
                list.sort_by(|a, b| a.0.total_cmp(&b.0));
                for pair in list.windows(2) {
                    ensure(
                        pair[1].0 >= pair[0].1 - 1e-9,
                        format!(
                            "slot {slot} double-allocated: spans {:?} and {:?} overlap",
                            pair[0], pair[1]
                        ),
                    )?;
                }
            }

            // Eviction count consistency: spans = tasks + evictions.
            ensure(
                spans.len() as u64 == w.len() as u64 + r.preemptions,
                format!(
                    "{} spans for {} tasks and {} evictions",
                    spans.len(),
                    w.len(),
                    r.preemptions
                ),
            )?;
            Ok(())
        },
    );
}

#[test]
fn prop_gang_eviction_atomicity() {
    forall(
        PropConfig {
            cases: 40,
            seed: 0x6A46,
        },
        |rng| {
            let mut case = gen_case(rng);
            case.flavor = Flavor::Gang;
            case
        },
        |case| {
            let w = build_workload(case);
            let sched = make_preemptive(case.choice, 1, case.order);
            let r = sched.run(&w, &cluster(), case.seed, &RunOptions::with_trace());
            r.check_invariants()?;
            let spans = r.spans.as_ref().expect("spans collected");
            let last = last_ends(&r);

            // For every non-final (eviction-ended) span of a gang
            // member, no sibling may keep running across that instant:
            // its spans either end by then or start after.
            for sa in spans {
                let ta = &w.tasks[sa.task as usize];
                if ta.kind != JobKind::Parallel {
                    continue;
                }
                if sa.end >= last[sa.task as usize] - 1e-9 {
                    continue; // final span (completion, not eviction)
                }
                let evict_at = sa.end;
                for sb in spans {
                    let tb = &w.tasks[sb.task as usize];
                    if sb.task == sa.task
                        || tb.kind != JobKind::Parallel
                        || tb.job != ta.job
                    {
                        continue;
                    }
                    ensure(
                        sb.end <= evict_at + 1e-6 || sb.start >= evict_at - 1e-6,
                        format!(
                            "gang {} atomicity violated: member {} ran {:?} across \
                             member {}'s eviction at {evict_at}",
                            ta.job,
                            sb.task,
                            (sb.start, sb.end),
                            sa.task
                        ),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_preempt_scratch_reuse_bit_identical() {
    let mut scratch = SimScratch::new();
    forall(
        PropConfig {
            cases: 25,
            seed: 0x5C4A,
        },
        gen_case,
        |case| {
            let w = build_workload(case);
            let sched = make_preemptive(case.choice, 1, case.order);
            let warm = sched.run_with_scratch(
                &w,
                &cluster(),
                case.seed,
                &RunOptions::with_trace(),
                &mut scratch,
            );
            let fresh = sched.run(&w, &cluster(), case.seed, &RunOptions::with_trace());
            ensure(
                warm.t_total.to_bits() == fresh.t_total.to_bits(),
                format!("t_total differs: {} vs {}", warm.t_total, fresh.t_total),
            )?;
            ensure(warm.events == fresh.events, "event count differs")?;
            ensure(warm.preemptions == fresh.preemptions, "preemptions differ")?;
            ensure(warm.trace == fresh.trace, "traces differ")?;
            ensure(warm.spans == fresh.spans, "spans differ")
        },
    );
}

#[test]
fn preempt_experiment_bit_identical_for_any_jobs() {
    let mut base = ExperimentConfig::default();
    base.scale_down = 11; // 4 nodes × 32 cores
    base.trials = 1;
    base.scenario_n = 4;
    let mut a_cfg = base.clone();
    a_cfg.jobs = 1;
    let mut b_cfg = base.clone();
    b_cfg.jobs = 4;
    let a = harness::preempt(&a_cfg);
    let b = harness::preempt(&b_cfg);
    assert_eq!(a.cells.len(), b.cells.len());
    assert!(!a.cells.is_empty());
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        assert_eq!(ca.scheduler, cb.scheduler);
        for (ra, rb) in ca.trials.iter().zip(&cb.trials) {
            assert_eq!(
                ra.t_total.to_bits(),
                rb.t_total.to_bits(),
                "{} cost {}",
                ca.scheduler,
                ca.cost_frac
            );
            assert_eq!(ra.events, rb.events);
            assert_eq!(ra.preemptions, rb.preemptions);
            assert_eq!(ra.waits.mean().to_bits(), rb.waits.mean().to_bits());
            assert_eq!(ra.spans, rb.spans);
        }
    }
}
