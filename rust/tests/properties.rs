//! Property-based tests over the coordinator invariants: conservation
//! of tasks, causality, utilization bounds, monotonicity, and fit
//! round-trips — across random workloads, clusters and all scheduler
//! models (the proptest role; see util::prop for the harness).

use sssched::cluster::ClusterSpec;
use sssched::config::SchedulerChoice;
use sssched::multilevel::{MapMode, Multilevel, MultilevelParams};
use sssched::sched::{make_scheduler, RunOptions, Scheduler};
use sssched::util::fit::fit_power_law;
use sssched::util::prng::Prng;
use sssched::util::prop::{ensure, forall, PropConfig};
use sssched::workload::{TaskTimeDist, Workload, WorkloadBuilder};

struct Case {
    choice: SchedulerChoice,
    nodes: u32,
    cores: u32,
    n_tasks: u64,
    dist: TaskTimeDist,
    seed: u64,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Case({:?}, {}x{}, {} tasks, {:?}, seed {})",
            self.choice, self.nodes, self.cores, self.n_tasks, self.dist, self.seed
        )
    }
}

fn gen_case(rng: &mut Prng) -> Case {
    let choices = [
        SchedulerChoice::Slurm,
        SchedulerChoice::GridEngine,
        SchedulerChoice::Mesos,
        SchedulerChoice::Yarn,
        SchedulerChoice::IdealFifo,
    ];
    let dists = [
        TaskTimeDist::Constant(rng.range_f64(0.5, 60.0)),
        TaskTimeDist::Uniform(0.5, rng.range_f64(1.0, 30.0)),
        TaskTimeDist::Exponential(rng.range_f64(1.0, 20.0)),
        TaskTimeDist::Lognormal {
            mean: rng.range_f64(1.0, 20.0),
            cv: rng.range_f64(0.1, 1.0),
        },
    ];
    Case {
        choice: choices[rng.choose_index(choices.len())],
        nodes: rng.range_u64(1, 4) as u32,
        cores: rng.range_u64(2, 8) as u32,
        n_tasks: rng.range_u64(1, 400),
        dist: dists[rng.choose_index(dists.len())],
        seed: rng.next_u64(),
    }
}

fn run_case(case: &Case) -> (sssched::sched::RunResult, Workload) {
    let cluster = ClusterSpec::homogeneous(case.nodes, case.cores, 64 * 1024, 2);
    let w = WorkloadBuilder::with_dist(case.dist)
        .tasks(case.n_tasks)
        .seed(case.seed)
        .label("prop")
        .build();
    let sched = make_scheduler(case.choice);
    let r = sched.run(&w, &cluster, case.seed, &RunOptions::with_trace());
    (r, w)
}

#[test]
fn prop_all_tasks_complete_exactly_once() {
    forall(
        PropConfig { cases: 40, seed: 0xA11 },
        gen_case,
        |case| {
            let (r, w) = run_case(case);
            let trace = r.trace.as_ref().unwrap();
            ensure(
                trace.len() == w.len(),
                format!("{} records for {} tasks", trace.len(), w.len()),
            )?;
            let mut ids: Vec<u32> = trace.iter().map(|t| t.task).collect();
            ids.sort_unstable();
            ids.dedup();
            ensure(ids.len() == w.len(), "duplicate or missing task ids")
        },
    );
}

#[test]
fn prop_no_core_oversubscription() {
    // At no instant do more tasks run on a slot than the slot can hold:
    // per-slot intervals must not overlap.
    forall(
        PropConfig { cases: 30, seed: 0xB22 },
        gen_case,
        |case| {
            let (r, _) = run_case(case);
            let trace = r.trace.as_ref().unwrap();
            let mut by_slot: std::collections::BTreeMap<u32, Vec<(f64, f64)>> =
                Default::default();
            for rec in trace {
                by_slot.entry(rec.slot).or_default().push((rec.start, rec.end));
            }
            for (slot, mut iv) in by_slot {
                iv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                for w in iv.windows(2) {
                    ensure(
                        w[1].0 >= w[0].1 - 1e-9,
                        format!("slot {slot}: overlap {:?} then {:?}", w[0], w[1]),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_result_invariants_and_bounds() {
    forall(
        PropConfig { cases: 40, seed: 0xC33 },
        gen_case,
        |case| {
            let (r, w) = run_case(case);
            r.check_invariants()?;
            ensure(r.n_tasks == w.len() as u64, "task count")?;
            let u = r.utilization();
            ensure((0.0..=1.0 + 1e-9).contains(&u), format!("U={u}"))?;
            ensure(r.delta_t() >= -1e-9, format!("ΔT={}", r.delta_t()))?;
            // Makespan at least the longest single task.
            let max_task = w.tasks.iter().map(|t| t.duration).fold(0.0, f64::max);
            ensure(
                r.t_total >= max_task - 1e-9,
                format!("t_total {} < longest task {max_task}", r.t_total),
            )
        },
    );
}

#[test]
fn prop_ideal_fifo_is_a_lower_bound() {
    forall(
        PropConfig { cases: 25, seed: 0xD44 },
        gen_case,
        |case| {
            let (r, w) = run_case(case);
            let cluster = ClusterSpec::homogeneous(case.nodes, case.cores, 64 * 1024, 2);
            let ideal = make_scheduler(SchedulerChoice::IdealFifo).run(
                &w,
                &cluster,
                0,
                &RunOptions::default(),
            );
            ensure(
                r.t_total >= ideal.t_total - 1e-6,
                format!(
                    "{:?} beat the zero-overhead bound: {} < {}",
                    case.choice, r.t_total, ideal.t_total
                ),
            )
        },
    );
}

#[test]
fn prop_multilevel_never_loses_work() {
    forall(
        PropConfig { cases: 25, seed: 0xE55 },
        |rng| {
            let mut c = gen_case(rng);
            c.choice = [
                SchedulerChoice::Slurm,
                SchedulerChoice::GridEngine,
                SchedulerChoice::Mesos,
            ][rng.choose_index(3)];
            (c, rng.chance(0.5))
        },
        |(case, siso)| {
            let cluster = ClusterSpec::homogeneous(case.nodes, case.cores, 64 * 1024, 2);
            let w = WorkloadBuilder::with_dist(case.dist)
                .tasks(case.n_tasks)
                .seed(case.seed)
                .build();
            let inner = make_scheduler(case.choice);
            let params = MultilevelParams {
                mode: if *siso { MapMode::Siso } else { MapMode::Mimo },
                ..Default::default()
            };
            let ml = Multilevel::new(inner.as_ref(), params);
            let agg = ml.aggregate(&w, cluster.total_cores(), case.seed);
            agg.validate()?;
            ensure(
                agg.total_work() >= w.total_work() - 1e-9,
                "aggregation lost work",
            )?;
            ensure(
                agg.len() <= w.len().max(cluster.total_cores() as usize),
                "more bundles than inputs",
            )?;
            let r = ml.run(&w, &cluster, case.seed, &RunOptions::default());
            r.check_invariants()?;
            // ΔT accounting vs the ORIGINAL workload.
            ensure(
                (r.t_job - w.t_job_per_proc(cluster.total_cores())).abs() < 1e-9,
                "t_job must reference the original workload",
            )
        },
    );
}

#[test]
fn prop_fit_recovers_synthetic_parameters() {
    forall(
        PropConfig { cases: 60, seed: 0xF66 },
        |rng| {
            let t_s = rng.range_f64(0.5, 40.0);
            let alpha = rng.range_f64(0.8, 1.6);
            let k = rng.range_u64(3, 12) as usize;
            let noise = rng.range_f64(0.0, 0.02);
            (t_s, alpha, k, noise, rng.next_u64())
        },
        |&(t_s, alpha, k, noise, seed)| {
            let mut rng = Prng::new(seed);
            let ns: Vec<f64> = (0..k).map(|i| 2f64.powi(i as i32 + 1)).collect();
            let dts: Vec<f64> = ns
                .iter()
                .map(|&n| t_s * n.powf(alpha) * (1.0 + noise * (rng.f64() - 0.5)))
                .collect();
            let fit = fit_power_law(&ns, &dts);
            ensure(
                (fit.alpha_s - alpha).abs() < 0.05 + noise * 5.0,
                format!("alpha {} vs {alpha}", fit.alpha_s),
            )?;
            ensure(
                (fit.t_s / t_s - 1.0).abs() < 0.10 + noise * 10.0,
                format!("t_s {} vs {t_s}", fit.t_s),
            )
        },
    );
}

#[test]
fn prop_determinism_across_runs() {
    forall(
        PropConfig { cases: 20, seed: 0x1777 },
        gen_case,
        |case| {
            let (a, _) = run_case(case);
            let (b, _) = run_case(case);
            ensure(a.t_total == b.t_total, "same seed, different makespan")?;
            ensure(a.events == b.events, "same seed, different event count")
        },
    );
}

#[test]
fn prop_scheduler_overhead_monotone_in_task_count() {
    // More tasks at the same task time never finish sooner.
    forall(
        PropConfig { cases: 20, seed: 0x1888 },
        |rng| {
            let mut c = gen_case(rng);
            c.dist = TaskTimeDist::Constant(rng.range_f64(1.0, 10.0));
            c.n_tasks = rng.range_u64(10, 200);
            c
        },
        |case| {
            let cluster = ClusterSpec::homogeneous(case.nodes, case.cores, 64 * 1024, 2);
            let sched = make_scheduler(case.choice);
            let w1 = WorkloadBuilder::with_dist(case.dist)
                .tasks(case.n_tasks)
                .seed(case.seed)
                .build();
            let w2 = WorkloadBuilder::with_dist(case.dist)
                .tasks(case.n_tasks * 2)
                .seed(case.seed)
                .build();
            let r1 = sched.run(&w1, &cluster, case.seed, &RunOptions::default());
            let r2 = sched.run(&w2, &cluster, case.seed, &RunOptions::default());
            ensure(
                r2.t_total >= r1.t_total * 0.95,
                format!("2x tasks finished early: {} vs {}", r2.t_total, r1.t_total),
            )
        },
    );
}
