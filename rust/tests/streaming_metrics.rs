//! Streaming-metrics equivalence and engine-mode identity tests.
//!
//! The kernel folds every task wait into O(1) streaming state (Welford
//! summary, P² quantile markers, bounded reservoir) instead of keeping
//! a whole-run trace. The exact traced mode stays available behind
//! `RunOptions::with_trace` as a differential oracle, which is exactly
//! how these tests use it:
//!
//! * at n ≤ `WAIT_SAMPLE_CAP` the reservoir holds every wait, so the
//!   result's `wait_sample` must equal the sorted trace-derived waits
//!   bitwise, and the P² estimates must land near the exact empirical
//!   quantiles;
//! * enabling the trace is pure observability — no streamed statistic
//!   may move by a single bit;
//! * `ShardedSim` with one shard is the identity wrapper, sharded
//!   results are independent of the worker count, and on 1-core
//!   constant tasks neither sharding (when the shard count divides the
//!   core count evenly) nor node-granular packing can change the
//!   ideal-FIFO wave schedule.

use sssched::cluster::ClusterSpec;
use sssched::config::SchedulerChoice;
use sssched::sched::combinators::{Order, OrderedSim};
use sssched::sched::{make_scheduler, NodeGranularSim, RunOptions, Scheduler, ShardedSim};
use sssched::util::stats::{percentile_sorted, WAIT_SAMPLE_CAP};
use sssched::workload::{TraceRecord, Workload, WorkloadBuilder};

fn cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(4, 25, 64 * 1024, 2)
}

/// Constant-duration batch with one task per job, so `job % G` routing
/// spreads the work across every shard of a `ShardedSim`.
fn workload(n: u64) -> Workload {
    WorkloadBuilder::constant(2.0)
        .tasks(n)
        .jobs(n as u32)
        .label("stream")
        .build()
}

/// Every simulated backend plus an ordered-combinator row, so the
/// streaming path is exercised through `make_policy` wrappers too.
fn backends() -> Vec<Box<dyn Scheduler>> {
    let mut v: Vec<Box<dyn Scheduler>> = SchedulerChoice::all_simulated()
        .iter()
        .map(|&c| make_scheduler(c))
        .collect();
    v.push(Box::new(OrderedSim::new(
        make_scheduler(SchedulerChoice::IdealFifo),
        Order::Priority,
        "IdealFIFO+prio",
    )));
    v
}

/// Exact sorted wait population reconstructed from the trace oracle.
fn trace_waits(trace: &[TraceRecord]) -> Vec<f64> {
    let mut waits: Vec<f64> = trace.iter().map(|t| t.start - t.submit).collect();
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    waits
}

#[test]
fn streamed_waits_match_the_traced_oracle_at_small_n() {
    let n = 300u64;
    assert!((n as usize) <= WAIT_SAMPLE_CAP, "reservoir must hold every wait");
    let w = workload(n);
    let cl = cluster();
    for sched in backends() {
        let r = sched.run(&w, &cl, 7, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        let exact = trace_waits(r.trace.as_ref().expect("traced run"));
        assert_eq!(exact.len() as u64, r.waits.count(), "{}", r.scheduler);
        // Under capacity the reservoir is lossless: the streamed sample
        // is the exact sorted wait population, bit for bit.
        assert_eq!(r.wait_sample, exact, "{}", r.scheduler);
        // Welford mean vs naive sum/n: same value up to rounding noise.
        let mean = exact.iter().sum::<f64>() / exact.len() as f64;
        assert!(
            (mean - r.waits.mean()).abs() < 1e-9,
            "{}: streamed mean {} vs exact {}",
            r.scheduler,
            r.waits.mean(),
            mean
        );
        // P² estimates stay inside the observed range and land near the
        // exact empirical quantiles from the trace.
        let span = (r.waits.max() - r.waits.min()).max(0.0);
        for (q, est) in [(0.5, r.wait_p50), (0.95, r.wait_p95), (0.99, r.wait_p99)] {
            assert!(
                est >= r.waits.min() - 1e-9 && est <= r.waits.max() + 1e-9,
                "{} p{q}: estimate {est} outside sample range",
                r.scheduler
            );
            let exact_q = percentile_sorted(&exact, q);
            assert!(
                (est - exact_q).abs() <= 0.30 * span + 1e-9,
                "{} p{q}: P² {est} vs exact {exact_q} (span {span})",
                r.scheduler
            );
        }
        assert!(r.wait_p50 <= r.wait_p95 + 1e-9 && r.wait_p95 <= r.wait_p99 + 1e-9);
    }
}

#[test]
fn tracing_is_pure_observability() {
    let w = workload(300);
    let cl = cluster();
    for sched in backends() {
        let plain = sched.run(&w, &cl, 11, &RunOptions::default());
        let traced = sched.run(&w, &cl, 11, &RunOptions::with_trace());
        assert!(plain.trace.is_none());
        assert!(traced.trace.is_some());
        let who = &plain.scheduler;
        assert_eq!(plain.t_total.to_bits(), traced.t_total.to_bits(), "{who}");
        assert_eq!(plain.events, traced.events, "{who}");
        assert_eq!(plain.completed, traced.completed, "{who}");
        assert_eq!(plain.waits.count(), traced.waits.count(), "{who}");
        assert_eq!(plain.waits.mean().to_bits(), traced.waits.mean().to_bits(), "{who}");
        assert_eq!(plain.wait_p50.to_bits(), traced.wait_p50.to_bits(), "{who}");
        assert_eq!(plain.wait_p95.to_bits(), traced.wait_p95.to_bits(), "{who}");
        assert_eq!(plain.wait_p99.to_bits(), traced.wait_p99.to_bits(), "{who}");
        assert_eq!(plain.wait_sample, traced.wait_sample, "{who}");
    }
}

#[test]
fn single_shard_wrapper_is_the_identity_for_ideal_and_sparrow() {
    // G = 1 routes every job to shard 0 with the caller's exact seed,
    // an identity task re-id, and a merge that starts from an empty
    // summary — so even the randomized Sparrow backend must reproduce
    // the plain run bit for bit. (Quantile fields are excluded: the
    // merged run recomputes them from the condensed sample rather than
    // the per-shard P² markers.)
    let w = workload(240);
    let cl = cluster();
    for choice in [SchedulerChoice::IdealFifo, SchedulerChoice::Sparrow] {
        let plain = make_scheduler(choice).run(&w, &cl, 13, &RunOptions::with_trace());
        let sharded = ShardedSim::new(make_scheduler(choice), 1, 1, "g1")
            .run(&w, &cl, 13, &RunOptions::with_trace());
        assert_eq!(plain.t_total.to_bits(), sharded.t_total.to_bits(), "{choice:?}");
        assert_eq!(plain.events, sharded.events, "{choice:?}");
        assert_eq!(plain.completed, sharded.completed, "{choice:?}");
        assert_eq!(plain.waits.count(), sharded.waits.count(), "{choice:?}");
        assert_eq!(plain.waits.mean().to_bits(), sharded.waits.mean().to_bits(), "{choice:?}");
        assert_eq!(plain.waits.min().to_bits(), sharded.waits.min().to_bits(), "{choice:?}");
        assert_eq!(plain.waits.max().to_bits(), sharded.waits.max().to_bits(), "{choice:?}");
        // The merged trace is sorted by task id; bring the plain trace
        // into the same order before comparing.
        let mut reference = plain.trace.clone().expect("traced run");
        reference.sort_by_key(|t| t.task);
        assert_eq!(Some(reference), sharded.trace, "{choice:?}");
    }
}

#[test]
fn sharded_sparrow_is_deterministic_across_worker_counts() {
    let w = workload(240);
    let cl = cluster();
    let reference = ShardedSim::new(make_scheduler(SchedulerChoice::Sparrow), 4, 1, "s4")
        .run(&w, &cl, 17, &RunOptions::with_trace());
    for jobs in [2, 8] {
        let r = ShardedSim::new(make_scheduler(SchedulerChoice::Sparrow), 4, jobs, "s4")
            .run(&w, &cl, 17, &RunOptions::with_trace());
        assert_eq!(reference.t_total.to_bits(), r.t_total.to_bits(), "jobs={jobs}");
        assert_eq!(reference.events, r.events, "jobs={jobs}");
        assert_eq!(reference.waits.mean().to_bits(), r.waits.mean().to_bits(), "jobs={jobs}");
        assert_eq!(reference.trace, r.trace, "jobs={jobs}");
        assert_eq!(reference.wait_sample, r.wait_sample, "jobs={jobs}");
    }
}

#[test]
fn engine_modes_preserve_the_ideal_wave_schedule_bitwise() {
    // 1-core constant tasks on a homogeneous cluster: ideal FIFO runs
    // ceil(n / P) waves. Splitting the 4 nodes into 2 or 4 contiguous
    // groups divides both the tasks and the cores evenly, and
    // node-granular packing only changes which slot a task lands on —
    // neither may move t_total by a bit.
    let w = workload(300);
    let cl = cluster();
    let ideal = make_scheduler(SchedulerChoice::IdealFifo);
    let plain = ideal.run(&w, &cl, 19, &RunOptions::default());
    for g in [2usize, 4] {
        let r = ShardedSim::new(make_scheduler(SchedulerChoice::IdealFifo), g, g, "gx")
            .run(&w, &cl, 19, &RunOptions::default());
        assert_eq!(plain.t_total.to_bits(), r.t_total.to_bits(), "G={g}");
        assert_eq!(plain.completed, r.completed, "G={g}");
    }
    let ng = NodeGranularSim::new(make_scheduler(SchedulerChoice::IdealFifo), "IdealFIFO+node")
        .run(&w, &cl, 19, &RunOptions::default());
    assert_eq!("IdealFIFO+node", ng.scheduler);
    assert_eq!(plain.t_total.to_bits(), ng.t_total.to_bits());
    assert_eq!(plain.waits.mean().to_bits(), ng.waits.mean().to_bits());
    assert_eq!(plain.completed, ng.completed);
}
