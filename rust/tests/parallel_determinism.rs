//! Parallel-executor determinism: a fig4-style sweep run with `jobs=1`
//! and `jobs=4` must produce bit-identical `RunResult`s per cell
//! (t_total, events, daemon_busy, waits), and scratch reuse must be
//! observationally identical to fresh allocation.

use sssched::config::{ExperimentConfig, SchedulerChoice};
use sssched::harness::{run_sweeps, SchedulerSweep, SweepSpec};
use sssched::multilevel::MultilevelParams;

fn cfg_with_jobs(jobs: u32) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.scale_down = 11; // 4 nodes × 32 = 128 cores — fast in CI
    cfg.trials = 2;
    cfg.jobs = jobs;
    cfg
}

fn assert_sweeps_bit_identical(a: &[SchedulerSweep], b: &[SchedulerSweep]) {
    assert_eq!(a.len(), b.len());
    for (sa, sb) in a.iter().zip(b) {
        assert_eq!(sa.scheduler, sb.scheduler);
        assert_eq!(sa.skipped, sb.skipped, "{}: skipped set", sa.scheduler);
        assert_eq!(sa.points.len(), sb.points.len(), "{}", sa.scheduler);
        for (pa, pb) in sa.points.iter().zip(&sb.points) {
            assert_eq!(pa.n, pb.n);
            assert_eq!(pa.trials.len(), pb.trials.len());
            for (trial, (ra, rb)) in pa.trials.iter().zip(&pb.trials).enumerate() {
                let ctx = format!("{} n={} trial={trial}", sa.scheduler, pa.n);
                assert_eq!(ra.n_tasks, rb.n_tasks, "{ctx}: n_tasks");
                assert_eq!(
                    ra.t_total.to_bits(),
                    rb.t_total.to_bits(),
                    "{ctx}: t_total {} vs {}",
                    ra.t_total,
                    rb.t_total
                );
                assert_eq!(ra.events, rb.events, "{ctx}: events");
                assert_eq!(
                    ra.daemon_busy.to_bits(),
                    rb.daemon_busy.to_bits(),
                    "{ctx}: daemon_busy"
                );
                assert_eq!(ra.waits.count(), rb.waits.count(), "{ctx}: wait count");
                assert_eq!(
                    ra.waits.mean().to_bits(),
                    rb.waits.mean().to_bits(),
                    "{ctx}: wait mean"
                );
                assert_eq!(
                    ra.waits.min().to_bits(),
                    rb.waits.min().to_bits(),
                    "{ctx}: wait min"
                );
                assert_eq!(
                    ra.waits.max().to_bits(),
                    rb.waits.max().to_bits(),
                    "{ctx}: wait max"
                );
            }
        }
    }
}

#[test]
fn fig4_sweep_bit_identical_jobs_1_vs_4() {
    let n_values = [4u32, 8, 16, 48];
    let specs: Vec<SweepSpec> = SchedulerChoice::paper_four()
        .iter()
        .map(|&c| (c, None))
        .collect();
    let serial = run_sweeps(&specs, &cfg_with_jobs(1), &n_values);
    let parallel = run_sweeps(&specs, &cfg_with_jobs(4), &n_values);
    assert_sweeps_bit_identical(&serial, &parallel);
    // Sanity: the sweep actually simulated something.
    assert!(serial
        .iter()
        .any(|s| s.points.iter().any(|p| !p.trials.is_empty())));
}

#[test]
fn multilevel_sweep_bit_identical_jobs_1_vs_4() {
    let ml = MultilevelParams::default();
    let n_values = [8u32, 48, 240];
    let specs: Vec<SweepSpec> = vec![
        (SchedulerChoice::Slurm, None),
        (SchedulerChoice::Slurm, Some(&ml)),
        (SchedulerChoice::Mesos, Some(&ml)),
    ];
    let serial = run_sweeps(&specs, &cfg_with_jobs(1), &n_values);
    let parallel = run_sweeps(&specs, &cfg_with_jobs(4), &n_values);
    assert_sweeps_bit_identical(&serial, &parallel);
}

#[test]
fn oversubscribed_jobs_still_identical() {
    // More workers than cells: executor must not duplicate or drop.
    let n_values = [4u32, 8];
    let specs: Vec<SweepSpec> = vec![(SchedulerChoice::GridEngine, None)];
    let mut cfg = cfg_with_jobs(1);
    cfg.trials = 1;
    let serial = run_sweeps(&specs, &cfg, &n_values);
    cfg.jobs = 16;
    let wide = run_sweeps(&specs, &cfg, &n_values);
    assert_sweeps_bit_identical(&serial, &wide);
}

#[test]
fn scratch_reuse_matches_fresh_runs_across_backends() {
    use sssched::cluster::ClusterSpec;
    use sssched::sched::{make_scheduler, RunOptions, SimScratch};
    use sssched::workload::WorkloadBuilder;

    let cluster = ClusterSpec::homogeneous(2, 8, 32 * 1024, 2);
    let w_small = WorkloadBuilder::constant(2.0).tasks(48).build();
    let w_big = WorkloadBuilder::constant(1.0).tasks(200).build();
    let mut scratch = SimScratch::new();
    for choice in [
        SchedulerChoice::Slurm,
        SchedulerChoice::GridEngine,
        SchedulerChoice::Mesos,
        SchedulerChoice::Yarn,
        SchedulerChoice::IdealFifo,
    ] {
        let sched = make_scheduler(choice);
        // Interleave workload sizes so each reuse shrinks or grows the
        // buffers — the cases where stale state would show.
        for (w, seed) in [(&w_big, 11u64), (&w_small, 12), (&w_big, 13)] {
            let warm = sched.run_with_scratch(
                w,
                &cluster,
                seed,
                &RunOptions::with_trace(),
                &mut scratch,
            );
            let fresh = sched.run(w, &cluster, seed, &RunOptions::with_trace());
            assert_eq!(
                warm.t_total.to_bits(),
                fresh.t_total.to_bits(),
                "{}: t_total",
                sched.name()
            );
            assert_eq!(warm.events, fresh.events, "{}: events", sched.name());
            assert_eq!(
                warm.trace.as_ref().unwrap(),
                fresh.trace.as_ref().unwrap(),
                "{}: trace",
                sched.name()
            );
        }
    }
}
