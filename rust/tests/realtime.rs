//! Realtime-mode integration: the wall-clock mini-cluster with the PJRT
//! analytics payload, and its agreement with the paper's model.
//! Requires `make artifacts`.

use sssched::exec::{RealtimeCoordinator, RealtimeParams, RtTask, RtWork};
use sssched::model::u_constant_approx;

fn artifacts() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

#[test]
fn analytics_payload_runs_through_pjrt() {
    let coord = RealtimeCoordinator::new(RealtimeParams {
        workers: 2,
        dispatch_overhead: 0.0,
        artifacts_dir: Some(artifacts()),
    });
    let tasks: Vec<RtTask> = (0..8)
        .map(|id| RtTask {
            id,
            nominal: 0.01,
            work: RtWork::Analytics {
                batches: 4,
                seed: id as u64,
            },
        })
        .collect();
    let r = coord.run(&tasks).unwrap();
    r.check_invariants().unwrap();
    assert_eq!(r.n_tasks, 8);
    assert!(r.t_total > 0.0);
    // Both workers exercised PJRT.
    let trace = r.trace.as_ref().unwrap();
    let mut nodes: Vec<u32> = trace.iter().map(|t| t.node).collect();
    nodes.sort_unstable();
    nodes.dedup();
    assert_eq!(nodes.len(), 2);
}

#[test]
fn injected_overhead_matches_model_on_sleep_tasks() {
    // Sleep payload: t = 40 ms, injected t_s = 20 ms on 2 workers.
    // Leader serializes dispatches → per-worker marginal ≈ t_s·workers/workers.
    let (t, ts) = (0.04, 0.02);
    let coord = RealtimeCoordinator::new(RealtimeParams {
        workers: 2,
        dispatch_overhead: ts,
        artifacts_dir: None,
    });
    let tasks: Vec<RtTask> = (0..40)
        .map(|id| RtTask {
            id,
            nominal: t,
            work: RtWork::Sleep(t),
        })
        .collect();
    let r = coord.run(&tasks).unwrap();
    let u_model = u_constant_approx(ts * 2.0, t); // 2 workers share one leader
    // Generous band: CI machines are noisy; the *shape* is what matters.
    assert!(
        (r.utilization() - u_model).abs() < 0.25,
        "U measured {:.3} vs model {:.3}",
        r.utilization(),
        u_model
    );
    assert!(r.utilization() < 0.9, "overhead must be visible");
}

#[test]
fn zero_overhead_utilization_is_high() {
    let coord = RealtimeCoordinator::new(RealtimeParams {
        workers: 2,
        dispatch_overhead: 0.0,
        artifacts_dir: None,
    });
    let tasks: Vec<RtTask> = (0..8)
        .map(|id| RtTask {
            id,
            nominal: 0.05,
            work: RtWork::Sleep(0.05),
        })
        .collect();
    let r = coord.run(&tasks).unwrap();
    assert!(
        r.utilization() > 0.85,
        "sleep tasks, no overhead: U={:.3}",
        r.utilization()
    );
}

#[test]
fn realtime_trace_is_causal_and_complete() {
    let coord = RealtimeCoordinator::new(RealtimeParams {
        workers: 3,
        dispatch_overhead: 0.001,
        artifacts_dir: None,
    });
    let tasks: Vec<RtTask> = (0..30)
        .map(|id| RtTask {
            id,
            nominal: 0.005,
            work: RtWork::Spin(0.005),
        })
        .collect();
    let r = coord.run(&tasks).unwrap();
    let trace = r.trace.as_ref().unwrap();
    assert_eq!(trace.len(), 30);
    for rec in trace {
        assert!(rec.end >= rec.start);
        assert!(rec.end <= r.t_total + 1e-6);
    }
    // Per-worker serial execution.
    let mut by_worker: std::collections::BTreeMap<u32, Vec<(f64, f64)>> = Default::default();
    for rec in trace {
        by_worker.entry(rec.node).or_default().push((rec.start, rec.end));
    }
    for (_, mut iv) in by_worker {
        iv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in iv.windows(2) {
            assert!(w[1].0 >= w[0].1 - 1e-6, "worker ran two tasks at once");
        }
    }
}
