//! Tests for the `pallas-lint` engine itself: every rule fires exactly
//! once on its fixture (and nowhere else), the allow machinery
//! suppresses/ errors as specified, the tokenizer doesn't false-positive
//! on strings/comments/char literals, and the cross-file rules work on
//! synthetic crate roots under `target/`.

use std::fs;
use std::path::PathBuf;

use sssched::lint::{self, FileReport};

fn fixture(name: &str) -> String {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("fixture {name}: {e}"))
}

fn rule_names(rep: &FileReport) -> Vec<&'static str> {
    rep.diagnostics.iter().map(|d| d.rule).collect()
}

fn hits(rep: &FileReport, rule: &str) -> usize {
    rep.rule_hits
        .iter()
        .find(|(n, _)| *n == rule)
        .map(|(_, c)| *c)
        .unwrap_or_else(|| panic!("rule {rule} missing from rule_hits"))
}

fn line_containing(src: &str, needle: &str) -> u32 {
    src.lines()
        .position(|l| l.contains(needle))
        .map(|i| i as u32 + 1)
        .unwrap_or_else(|| panic!("fixture lost its `{needle}` line"))
}

#[test]
fn hash_iteration_fires_once_and_only_in_scope() {
    let src = fixture("hash_map.rs");
    let rep = lint::lint_source("src/sim/fixture.rs", &src);
    assert_eq!(rule_names(&rep), vec!["hash-iteration"]);
    assert_eq!(rep.diagnostics[0].line, line_containing(&src, "HashMap"));
    assert_eq!(hits(&rep, "hash-iteration"), 1);
    // util/ is outside the deterministic scope: same source, no finding.
    let out = lint::lint_source("src/util/fixture.rs", &src);
    assert!(out.diagnostics.is_empty(), "{:?}", out.diagnostics);
}

#[test]
fn float_ord_fires_once_sparing_definitions_and_strings() {
    let src = fixture("float_ord.rs");
    let rep = lint::lint_source("src/harness/fixture.rs", &src);
    assert_eq!(rule_names(&rep), vec!["float-ord"]);
    assert_eq!(rep.diagnostics[0].line, line_containing(&src, "xs.sort_by"));
}

#[test]
fn wall_clock_fires_once_outside_the_exempt_files() {
    let src = fixture("wall_clock.rs");
    let rep = lint::lint_source("src/sim/fixture.rs", &src);
    assert_eq!(rule_names(&rep), vec!["wall-clock"]);
    assert_eq!(rep.diagnostics[0].line, line_containing(&src, "Instant::now"));
    for exempt in ["src/exec/realtime.rs", "src/harness/scale.rs", "tests/fixture.rs"] {
        let out = lint::lint_source(exempt, &src);
        assert!(out.diagnostics.is_empty(), "{exempt}: {:?}", out.diagnostics);
    }
}

#[test]
fn os_entropy_fires_once() {
    let src = fixture("os_entropy.rs");
    let rep = lint::lint_source("src/workload/fixture.rs", &src);
    assert_eq!(rule_names(&rep), vec!["os-entropy"]);
}

#[test]
fn thread_spawn_fires_once_outside_merge_modules() {
    let src = fixture("thread_spawn.rs");
    let rep = lint::lint_source("src/sim/fixture.rs", &src);
    assert_eq!(rule_names(&rep), vec!["thread-spawn"]);
    assert_eq!(rep.diagnostics[0].line, line_containing(&src, "thread::spawn"));
    for exempt in ["src/harness/parallel.rs", "src/sched/sharded.rs", "src/exec/worker.rs"] {
        let out = lint::lint_source(exempt, &src);
        assert!(out.diagnostics.is_empty(), "{exempt}: {:?}", out.diagnostics);
    }
}

#[test]
fn fault_hooks_fires_once_on_the_incomplete_impl() {
    let src = fixture("fault_hooks.rs");
    let rep = lint::lint_source("src/sched/fixture.rs", &src);
    assert_eq!(rule_names(&rep), vec!["fault-hooks"]);
    let d = &rep.diagnostics[0];
    assert_eq!(d.line, line_containing(&src, "impl SchedPolicy for Incomplete"));
    assert!(d.msg.contains("on_node_drain") && d.msg.contains("on_node_recover"));
    // The degraded-control-plane hook is required alongside the
    // legacy fail/drain/recover trio.
    assert!(d.msg.contains("on_node_suspected"), "{}", d.msg);
}

#[test]
fn allow_with_reason_suppresses_leading_and_trailing() {
    let src = fixture("allow_good.rs");
    let rep = lint::lint_source("src/sim/fixture.rs", &src);
    assert!(rep.diagnostics.is_empty(), "{:?}", rep.diagnostics);
    assert_eq!(rep.suppressed, 2);
    // Hits are counted pre-suppression for the perf trajectory.
    assert_eq!(hits(&rep, "float-ord"), 2);
}

#[test]
fn allow_without_reason_errors_and_does_not_suppress() {
    let src = fixture("allow_no_reason.rs");
    let rep = lint::lint_source("src/sim/fixture.rs", &src);
    assert_eq!(rule_names(&rep), vec!["allow-missing-reason", "float-ord"]);
    assert_eq!(rep.suppressed, 0);
}

#[test]
fn allow_with_unknown_rule_errors() {
    let src = fixture("allow_unknown.rs");
    let rep = lint::lint_source("src/sim/fixture.rs", &src);
    assert_eq!(rule_names(&rep), vec!["unknown-rule"]);
    assert!(rep.diagnostics[0].msg.contains("no-such-rule"));
}

#[test]
fn stale_allow_errors() {
    let src = fixture("allow_stale.rs");
    let rep = lint::lint_source("src/sim/fixture.rs", &src);
    assert_eq!(rule_names(&rep), vec!["stale-allow"]);
    assert_eq!(
        rep.diagnostics[0].line,
        line_containing(&src, "pallas: allow(float-ord)")
    );
}

#[test]
fn tokenizer_edges_produce_no_findings() {
    let src = fixture("tokenizer_edge.rs");
    let rep = lint::lint_source("src/sim/fixture.rs", &src);
    assert!(rep.diagnostics.is_empty(), "{:?}", rep.diagnostics);
    assert_eq!(rep.suppressed, 0);
    let total: usize = rep.rule_hits.iter().map(|(_, c)| *c).sum();
    assert_eq!(total, 0);
}

/// Fresh synthetic crate root under `target/` (gitignored) for the
/// cross-file rules.
fn scratch_root(name: &str) -> PathBuf {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target/lint-scratch")
        .join(name);
    let _ = fs::remove_dir_all(&p);
    fs::create_dir_all(p.join("src")).unwrap();
    fs::write(p.join("src/lib.rs"), "pub fn placeholder() {}\n").unwrap();
    p
}

#[test]
fn golden_exists_flags_missing_refs_and_orphans() {
    let root = scratch_root("golden");
    let gdir = root.join("tests/golden");
    fs::create_dir_all(&gdir).unwrap();
    fs::write(gdir.join("pinned.txt"), "1\n").unwrap();
    fs::write(gdir.join("orphan.txt"), "1\n").unwrap();
    // No `fn assert_snapshot` here, so a missing referenced snapshot
    // is a finding, and so is the unreferenced orphan file.
    let refs = r#"
fn base() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests")
}

#[test]
fn pins() {
    let _a = base().join("golden").join("pinned.txt");
    let _b = base().join("golden").join("missing.txt");
}
"#;
    fs::write(root.join("tests/refs.rs"), refs).unwrap();
    let rep = lint::lint_tree(&root).unwrap();
    let rules: Vec<&str> = rep.diagnostics.iter().map(|d| d.rule).collect();
    assert_eq!(rules, vec!["golden-exists", "golden-exists"], "{}", rep.render());
    assert!(rep.diagnostics.iter().any(|d| d.msg.contains("missing.txt")));
    assert!(rep
        .diagnostics
        .iter()
        .any(|d| d.file.contains("orphan.txt") && d.msg.contains("not referenced")));
}

#[test]
fn golden_exists_respects_self_seeding_tests() {
    let root = scratch_root("golden-seed");
    fs::create_dir_all(root.join("tests")).unwrap();
    // The repo convention: tests defining `fn assert_snapshot` create a
    // missing golden on first run, so absence is bootstrap, not a bug.
    let seeded = r#"
fn assert_snapshot(path: &std::path::Path, got: &str) {
    let _ = (path, got);
}

#[test]
fn pins() {
    let p = std::path::Path::new("tests").join("golden").join("boot.txt");
    assert_snapshot(&p, "v");
}
"#;
    fs::write(root.join("tests/seeded.rs"), seeded).unwrap();
    let rep = lint::lint_tree(&root).unwrap();
    assert!(rep.is_clean(), "{}", rep.render());
}

#[test]
fn experiment_wiring_flags_unwired_names() {
    let parent = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target/lint-scratch")
        .join("wiring");
    let _ = fs::remove_dir_all(&parent);
    let root = parent.join("rust");
    fs::create_dir_all(root.join("src/config")).unwrap();
    fs::write(
        parent.join("README.md"),
        "# demo\n\n## EXPERIMENTS\n\n| `alpha` | ok |\n\n## Next\n",
    )
    .unwrap();
    fs::write(
        root.join("src/config/schema.rs"),
        "pub const EXPERIMENT_NAMES: &[&str] = &[\"alpha\", \"beta\"];\n",
    )
    .unwrap();
    // `alpha` is fully wired (dispatch arm + validate check + README
    // row); `beta` is wired nowhere → three findings, all about beta.
    fs::write(
        root.join("src/main.rs"),
        "pub const WIRED: &[&str] = &[\"alpha\", \"alpha shapes\"];\n",
    )
    .unwrap();
    let rep = lint::lint_tree(&root).unwrap();
    let wiring: Vec<&lint::Diagnostic> = rep
        .diagnostics
        .iter()
        .filter(|d| d.rule == "experiment-wiring")
        .collect();
    assert_eq!(wiring.len(), 3, "{}", rep.render());
    assert!(wiring.iter().all(|d| d.msg.contains("beta")));
    assert_eq!(rep.diagnostics.len(), 3, "only wiring findings: {}", rep.render());
}
