//! Differential equivalence suites for the indexed hot-path structures.
//!
//! The perf tentpole replaced three quadratic structures — the
//! `SlotPool` free stack, the kernel's pending-queue scans and the
//! `Ordered` combinator's per-event full sort — with incrementally
//! maintained indexed ones, under a bit-identity contract. This suite
//! pins that contract from three angles:
//!
//! 1. **Pool vs verbatim legacy copy** — [`LegacySlotPool`] below is
//!    the pre-index implementation, copied verbatim (O(P) `rposition`
//!    scan + `Vec::remove`). Randomized alloc/release sequences shaped
//!    like each backend's allocation pattern (uniform-memory arrays,
//!    LIFO completions, random completions, multi-core bursts with
//!    failure rollback, memory pressure) must produce identical
//!    slot-id pop sequences.
//! 2. **Incremental ordered queue vs the eager sort oracle** —
//!    end-to-end runs of `Ordered`/`Preemptive` policies over random
//!    priority/user/core/arrival workloads, executed once with the
//!    incremental `OrderIndex` and once with `new_eager` (rebuild by
//!    full legacy-style sort before every dispatch hook), must be
//!    bit-identical in makespan, event counts, waits and traces.
//! 3. **Backends under memory pressure** — every scheduler family run
//!    on a memory-constrained cluster (forcing the pool's slow path
//!    inside the kernel) stays bit-identical across scratch reuse and
//!    passes all result invariants.

use sssched::cluster::{ClusterSpec, NodeState, SlotPool};
use sssched::config::SchedulerChoice;
use sssched::sched::combinators::{Order, OrderedSim, PreemptiveSim};
use sssched::sched::{make_scheduler, RunOptions, RunResult, Scheduler, SimScratch};
use sssched::util::prng::Prng;
use sssched::workload::{JobKind, TaskSpec, Workload};

// ---- 1. the verbatim legacy pool -----------------------------------------

/// The pre-index `SlotPool`, kept verbatim as the differential oracle:
/// one global free stack, `rposition` scan for memory-constrained
/// allocations, `Vec::remove` for mid-stack extraction. Node lifecycle
/// (retire/restore for the fault kernel) is the obvious O(P)
/// filter-the-stack implementation — the oracle for the indexed pool's
/// lazy parked-slot machinery.
struct LegacySlotPool {
    node_of: Vec<u32>,
    free: Vec<u32>,
    busy: Vec<bool>,
    mem_free: Vec<i64>,
    mem_total: Vec<i64>,
    busy_count: usize,
    placeable: Vec<bool>,
    parked: Vec<Vec<u32>>,
}

impl LegacySlotPool {
    fn new(spec: &ClusterSpec) -> Self {
        let mut pool = Self {
            node_of: Vec::new(),
            free: Vec::new(),
            busy: Vec::new(),
            mem_free: Vec::new(),
            mem_total: Vec::new(),
            busy_count: 0,
            placeable: vec![true; spec.nodes.len()],
            parked: vec![Vec::new(); spec.nodes.len()],
        };
        for node in &spec.nodes {
            if node.state != NodeState::Up {
                continue;
            }
            for _ in 0..node.cores {
                let id = pool.node_of.len() as u32;
                pool.node_of.push(node.id);
                pool.free.push(id);
            }
        }
        // Pop order: slot 0 first (free is a stack).
        pool.free.reverse();
        pool.busy.resize(pool.node_of.len(), false);
        pool.mem_total
            .extend(spec.nodes.iter().map(|n| n.mem_mb as i64));
        pool.mem_free.extend_from_slice(&pool.mem_total);
        pool
    }

    fn alloc(&mut self, mem_mb: i64) -> Option<u32> {
        let pos = self
            .free
            .iter()
            .rposition(|&s| self.mem_free[self.node_of[s as usize] as usize] >= mem_mb)?;
        let slot = self.free.remove(pos);
        let node = self.node_of[slot as usize] as usize;
        self.mem_free[node] -= mem_mb;
        assert!(!self.busy[slot as usize], "double allocation of slot {slot}");
        self.busy[slot as usize] = true;
        self.busy_count += 1;
        Some(slot)
    }

    fn release(&mut self, slot: u32, mem_mb: i64) {
        let idx = slot as usize;
        assert!(self.busy[idx], "release of free slot {slot}");
        self.busy[idx] = false;
        self.busy_count -= 1;
        let node = self.node_of[idx] as usize;
        self.mem_free[node] += mem_mb;
        assert!(
            self.mem_free[node] <= self.mem_total[node],
            "memory over-release on node {node}"
        );
        if !self.placeable[node] {
            self.parked[node].push(slot);
            return;
        }
        self.free.push(slot);
    }

    /// Retire a node: its free slots leave the stack (order of the rest
    /// preserved) and park in stack order; busy slots park on release.
    fn retire_node(&mut self, node: u32) {
        let n = node as usize;
        if !self.placeable[n] {
            return;
        }
        self.placeable[n] = false;
        let mut kept = Vec::with_capacity(self.free.len());
        for &s in &self.free {
            if self.node_of[s as usize] == node {
                self.parked[n].push(s);
            } else {
                kept.push(s);
            }
        }
        self.free = kept;
    }

    /// Restore a node: parked slots re-enter the stack in parked order
    /// (the last parked slot becomes the new top).
    fn restore_node(&mut self, node: u32) {
        let n = node as usize;
        if self.placeable[n] {
            return;
        }
        self.placeable[n] = true;
        for s in std::mem::take(&mut self.parked[n]) {
            self.free.push(s);
        }
    }

    fn free_count(&self) -> usize {
        self.free.len()
    }
}

/// Drive both pools with the same operation sequence, asserting
/// identical observable behaviour after every step.
struct PoolPair {
    indexed: SlotPool,
    legacy: LegacySlotPool,
    /// (slot, mem) currently held, shared by construction.
    held: Vec<(u32, i64)>,
}

impl PoolPair {
    fn new(spec: &ClusterSpec) -> Self {
        Self {
            indexed: SlotPool::new(spec),
            legacy: LegacySlotPool::new(spec),
            held: Vec::new(),
        }
    }

    fn alloc(&mut self, mem: i64) -> Option<u32> {
        let a = self.indexed.alloc(mem);
        let b = self.legacy.alloc(mem);
        assert_eq!(a, b, "pop order diverged for mem={mem}");
        assert_eq!(self.indexed.free_count(), self.legacy.free_count());
        self.indexed.check_invariants().unwrap();
        if let Some(s) = a {
            self.held.push((s, mem));
        }
        a
    }

    fn release_at(&mut self, i: usize) {
        let (s, mem) = self.held.swap_remove(i);
        self.indexed.release(s, mem);
        self.legacy.release(s, mem);
        assert_eq!(self.indexed.free_count(), self.legacy.free_count());
        self.indexed.check_invariants().unwrap();
    }

    fn release_last(&mut self) {
        if !self.held.is_empty() {
            let i = self.held.len() - 1;
            self.release_at(i);
        }
    }

    fn retire(&mut self, node: u32) {
        self.indexed.retire_node(node);
        self.legacy.retire_node(node);
        assert_eq!(
            self.indexed.free_count(),
            self.legacy.free_count(),
            "free count diverged after retiring node {node}"
        );
        self.indexed.check_invariants().unwrap();
    }

    fn restore(&mut self, node: u32) {
        self.indexed.restore_node(node);
        self.legacy.restore_node(node);
        assert_eq!(
            self.indexed.free_count(),
            self.legacy.free_count(),
            "free count diverged after restoring node {node}"
        );
        self.indexed.check_invariants().unwrap();
    }
}

fn small_cluster() -> ClusterSpec {
    // 6 nodes × 4 cores, 1000 MB each: tight enough that 300–900 MB
    // tasks hit per-node memory pressure constantly.
    ClusterSpec::homogeneous(6, 4, 1000, 2)
}

#[test]
fn pool_differential_uniform_memory_lifo() {
    // Array/table9 shape: every task the same memory, completions in
    // LIFO order (the homogeneous fast path must stay on the legacy
    // pop order throughout).
    let mut pair = PoolPair::new(&small_cluster());
    let mut rng = Prng::new(0xA11C);
    for _ in 0..500 {
        if rng.chance(0.6) {
            pair.alloc(200);
        } else {
            pair.release_last();
        }
    }
}

#[test]
fn pool_differential_random_release_order() {
    // Poisson-completion shape: tasks end in arbitrary order, so the
    // lazy stack accumulates dead entries that must be skimmed
    // identically to the legacy mid-stack removals.
    let mut rng = Prng::new(0xBEEF);
    for trial in 0..20 {
        let mut pair = PoolPair::new(&small_cluster());
        for _ in 0..300 {
            if rng.chance(0.55) {
                let mem = [0i64, 150, 400, 900][rng.below(4) as usize];
                pair.alloc(mem);
            } else if !pair.held.is_empty() {
                let i = rng.below(pair.held.len() as u64) as usize;
                pair.release_at(i);
            }
        }
        assert_eq!(
            pair.indexed.busy_count(),
            pair.held.len(),
            "trial {trial}"
        );
    }
}

#[test]
fn pool_differential_multicore_burst_with_rollback() {
    // Kernel alloc_task shape: one memory-carrying primary plus k
    // zero-memory extras, rolled back in reverse on failure — exactly
    // the gang/multi-core rollback path.
    let mut rng = Prng::new(0xC0DE);
    for _ in 0..20 {
        let mut pair = PoolPair::new(&small_cluster());
        for _ in 0..120 {
            if rng.chance(0.6) {
                let mem = [300i64, 600, 900][rng.below(3) as usize];
                let cores = 1 + rng.below(6) as usize;
                // All-or-nothing: primary with memory, extras at 0.
                let start = pair.held.len();
                if pair.alloc(mem).is_some() {
                    let mut ok = true;
                    for _ in 1..cores {
                        if pair.alloc(0).is_none() {
                            ok = false;
                            break;
                        }
                    }
                    if !ok {
                        // Roll back in reverse allocation order.
                        while pair.held.len() > start {
                            pair.release_last();
                        }
                    }
                }
            } else if !pair.held.is_empty() {
                let i = rng.below(pair.held.len() as u64) as usize;
                pair.release_at(i);
            }
        }
    }
}

#[test]
fn pool_differential_exhaustion_and_refill() {
    // Drain the whole cluster at mixed sizes, then refill, repeatedly:
    // stresses the None paths and full-stack turnover.
    let mut pair = PoolPair::new(&small_cluster());
    let mut rng = Prng::new(0xF112);
    for _ in 0..6 {
        loop {
            let mem = [0i64, 250, 500][rng.below(3) as usize];
            if pair.alloc(mem).is_none() && pair.alloc(0).is_none() {
                break; // truly exhausted
            }
        }
        assert_eq!(pair.indexed.free_count(), 0);
        while !pair.held.is_empty() {
            let i = rng.below(pair.held.len() as u64) as usize;
            pair.release_at(i);
        }
    }
}

#[test]
fn pool_differential_mid_run_retire_restore() {
    // Fault-kernel shape: nodes retire (fail/drain) and restore mid-run
    // while memory-constrained allocs and random-order releases keep
    // flowing. The indexed pool's lazily invalidated parked-slot
    // machinery must reproduce the legacy filter-the-stack pop order
    // exactly, including releases that park onto retired nodes and
    // stale lazy-stack entries left by slow-path allocations.
    let mut rng = Prng::new(0xFA17);
    for trial in 0..15 {
        let mut pair = PoolPair::new(&small_cluster());
        for _ in 0..400 {
            match rng.below(10) {
                0..=4 => {
                    let mem = [0i64, 150, 400, 900][rng.below(4) as usize];
                    pair.alloc(mem);
                }
                5..=6 => {
                    if !pair.held.is_empty() {
                        let i = rng.below(pair.held.len() as u64) as usize;
                        pair.release_at(i);
                    }
                }
                7..=8 => pair.retire(rng.below(6) as u32),
                _ => pair.restore(rng.below(6) as u32),
            }
        }
        // Restore everything and drain both pools to empty: the tail
        // pop order (over freshly restored seqs) must agree too.
        for node in 0..6 {
            pair.restore(node);
        }
        while !pair.held.is_empty() {
            let i = rng.below(pair.held.len() as u64) as usize;
            pair.release_at(i);
        }
        while pair.alloc(0).is_some() {}
        assert_eq!(pair.indexed.free_count(), 0, "trial {trial}");
    }
}

#[test]
fn pool_differential_with_down_nodes() {
    let mut spec = small_cluster();
    spec.set_state(2, NodeState::Down);
    let mut pair = PoolPair::new(&spec);
    let mut rng = Prng::new(0xD03);
    for _ in 0..300 {
        if rng.chance(0.6) {
            let mem = [0i64, 400, 800][rng.below(3) as usize];
            pair.alloc(mem);
        } else if !pair.held.is_empty() {
            let i = rng.below(pair.held.len() as u64) as usize;
            pair.release_at(i);
        }
    }
}

// ---- 2. incremental ordered queue vs the eager sort oracle ----------------

/// Random workload mixing priorities, users, core counts, staggered
/// arrivals and (optionally) preemptible background + gangs.
fn random_ordered_workload(rng: &mut Prng, n: u64, preempt: bool, gangs: bool) -> Workload {
    let mut tasks: Vec<TaskSpec> = Vec::new();
    let mut id = 0u32;
    if preempt {
        // Saturating preemptible background the foreground can evict.
        for _ in 0..8 {
            let mut t = TaskSpec::array(id, id, rng.range_f64(5.0, 15.0));
            t.preemptible = true;
            t.checkpoint_cost = if rng.chance(0.5) { 0.0 } else { 0.25 };
            t.user = rng.below(3) as u32;
            tasks.push(t);
            id += 1;
        }
    }
    if gangs {
        let size = 2 + rng.below(3) as u32;
        let job = 900;
        for _ in 0..size {
            let mut t = TaskSpec::array(id, job, rng.range_f64(0.5, 3.0));
            t.kind = JobKind::Parallel;
            t.priority = rng.below(5) as i32;
            t.user = rng.below(3) as u32;
            t.submit_at = rng.range_f64(0.0, 2.0);
            tasks.push(t);
            id += 1;
        }
    }
    for _ in 0..n {
        let mut t = TaskSpec::array(id, id, rng.range_f64(0.2, 4.0));
        t.priority = rng.below(8) as i32;
        t.user = rng.below(3) as u32;
        t.cores = 1 + rng.below(2) as u32;
        if rng.chance(0.5) {
            t.submit_at = rng.range_f64(0.0, 10.0);
        }
        tasks.push(t);
        id += 1;
    }
    let w = Workload {
        tasks,
        label: "ordered-diff".into(),
    };
    w.validate().expect("random workload valid");
    w
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.t_total.to_bits(), b.t_total.to_bits(), "{what}: t_total");
    assert_eq!(a.events, b.events, "{what}: events");
    assert_eq!(a.preemptions, b.preemptions, "{what}: preemptions");
    assert_eq!(a.waits.count(), b.waits.count(), "{what}: wait count");
    assert_eq!(
        a.waits.mean().to_bits(),
        b.waits.mean().to_bits(),
        "{what}: wait mean"
    );
    assert_eq!(a.trace, b.trace, "{what}: trace");
    assert_eq!(a.spans, b.spans, "{what}: spans");
}

fn diff_cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(2, 4, 32 * 1024, 2)
}

#[test]
fn ordered_incremental_matches_eager_oracle() {
    let cl = diff_cluster();
    for order in [Order::Priority, Order::Fairshare] {
        for inner in [SchedulerChoice::IdealFifo, SchedulerChoice::Slurm] {
            let mut rng = Prng::new(0x0DD + order.label().len() as u64);
            for seed in 0..8u64 {
                let gangs = seed % 2 == 1;
                let w = random_ordered_workload(&mut rng, 24, false, gangs);
                let incr = OrderedSim::new(make_scheduler(inner), order, "diff");
                let eager = OrderedSim::new_eager(make_scheduler(inner), order, "diff");
                let a = incr.run(&w, &cl, seed, &RunOptions::with_trace());
                let b = eager.run(&w, &cl, seed, &RunOptions::with_trace());
                a.check_invariants().unwrap();
                assert_bit_identical(
                    &a,
                    &b,
                    &format!("{inner:?}+{} seed {seed} gangs {gangs}", order.label()),
                );
            }
        }
    }
}

#[test]
fn preemptive_incremental_matches_eager_oracle() {
    let cl = diff_cluster();
    for order in [Order::Priority, Order::Fairshare] {
        let mut rng = Prng::new(0x9E3 + order.label().len() as u64);
        for seed in 0..8u64 {
            let w = random_ordered_workload(&mut rng, 20, true, false);
            let incr = PreemptiveSim::new(
                make_scheduler(SchedulerChoice::IdealFifo),
                order,
                "diff+preempt",
            );
            let eager = PreemptiveSim::new_eager(
                make_scheduler(SchedulerChoice::IdealFifo),
                order,
                "diff+preempt",
            );
            let a = incr.run(&w, &cl, seed, &RunOptions::with_trace());
            let b = eager.run(&w, &cl, seed, &RunOptions::with_trace());
            a.check_invariants().unwrap();
            assert_bit_identical(&a, &b, &format!("preempt+{} seed {seed}", order.label()));
        }
    }
}

#[test]
fn ordered_warm_scratch_matches_fresh() {
    // The incremental index lives in SimScratch: reuse across runs of
    // different shapes must stay bit-identical to fresh scratches.
    let cl = diff_cluster();
    let mut rng = Prng::new(0x5C4A);
    let w1 = random_ordered_workload(&mut rng, 30, false, true);
    let w2 = random_ordered_workload(&mut rng, 12, true, false);
    let ordered = OrderedSim::new(
        make_scheduler(SchedulerChoice::IdealFifo),
        Order::Fairshare,
        "warm",
    );
    let pre = PreemptiveSim::new(
        make_scheduler(SchedulerChoice::IdealFifo),
        Order::Priority,
        "warm+preempt",
    );
    let mut scratch = SimScratch::new();
    for seed in 0..3u64 {
        let warm_o = ordered.run_with_scratch(&w1, &cl, seed, &RunOptions::with_trace(), &mut scratch);
        let fresh_o = ordered.run(&w1, &cl, seed, &RunOptions::with_trace());
        assert_bit_identical(&warm_o, &fresh_o, &format!("ordered warm seed {seed}"));
        let warm_p = pre.run_with_scratch(&w2, &cl, seed, &RunOptions::with_trace(), &mut scratch);
        let fresh_p = pre.run(&w2, &cl, seed, &RunOptions::with_trace());
        assert_bit_identical(&warm_p, &fresh_p, &format!("preempt warm seed {seed}"));
    }
}

// ---- 3. backends under memory pressure ------------------------------------

/// Memory-hungry workload on a memory-tight cluster: forces the
/// indexed pool's slow path inside every backend's kernel run.
fn mem_pressure_workload(rng: &mut Prng, n: u64) -> Workload {
    let tasks = (0..n)
        .map(|i| {
            let mut t = TaskSpec::array(i as u32, i as u32, rng.range_f64(0.5, 3.0));
            t.mem_mb = [256i64, 512, 900][rng.below(3) as usize];
            if rng.chance(0.4) {
                t.submit_at = rng.range_f64(0.0, 5.0);
            }
            t
        })
        .collect();
    Workload {
        tasks,
        label: "mem-pressure".into(),
    }
}

#[test]
fn all_backends_bit_identical_under_memory_pressure() {
    // 1000 MB nodes, 4 cores each: three 256 MB tasks fill a node's
    // memory before its cores, so allocations constantly skip the top
    // of the free stack.
    let cl = ClusterSpec::homogeneous(4, 4, 1000, 2);
    let mut rng = Prng::new(0x3E3);
    let w = mem_pressure_workload(&mut rng, 48);
    let mut scratch = SimScratch::new();
    for choice in SchedulerChoice::all_simulated() {
        let sched = make_scheduler(choice);
        let fresh = sched.run(&w, &cl, 11, &RunOptions::with_trace());
        fresh.check_invariants().unwrap_or_else(|e| {
            panic!("{} under memory pressure: {e}", sched.name())
        });
        let warm = sched.run_with_scratch(&w, &cl, 11, &RunOptions::with_trace(), &mut scratch);
        assert_bit_identical(&warm, &fresh, sched.name());
        // Every task must have run somewhere memory allowed: per-node
        // concurrent memory is checked by the pool's own asserts during
        // the run; here we double-check the trace landed each task on a
        // real node.
        let trace = fresh.trace.as_ref().unwrap();
        assert_eq!(trace.len(), w.len());
    }
}
