//! Harness shape checks at CI scale: every table/figure runner executes
//! and its paper-shape assertions pass on the shape-preserving
//! scaled-down cluster (see make_scheduler_scaled).

use sssched::config::ExperimentConfig;
use sssched::harness;
use sssched::multilevel::MultilevelParams;

fn ci_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.scale_down = 8; // 5 nodes × 32 = 160-ish cores
    cfg.trials = 1;
    cfg
}

fn artifacts() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

#[test]
fn table9_runs_and_checks() {
    let rep = harness::table9(&ci_cfg());
    assert_eq!(rep.sweeps.len(), 4);
    rep.check_shape(0.35).unwrap();
    let rendered = rep.render().render();
    assert!(rendered.contains("Slurm"));
    assert!(rendered.contains("abandoned"));
}

#[test]
fn table10_runs_and_checks() {
    let rep = harness::table10(&ci_cfg(), Some(artifacts()));
    rep.check_shape().unwrap();
    // PJRT path actually used.
    assert!(
        rep.fits.iter().all(|f| f.pjrt_fit.is_some()),
        "PJRT fit missing"
    );
}

#[test]
fn fig4_runs_and_checks() {
    let rep = harness::fig4(&ci_cfg());
    rep.check_shape().unwrap();
    let plots = rep.render_plots();
    assert!(plots.contains("Figure 4a"));
    assert!(plots.contains("Figure 4d"));
    let csv = rep.to_csv();
    assert!(csv.lines().count() > 20);
}

#[test]
fn fig5_runs_and_checks() {
    let rep = harness::fig5(&ci_cfg(), Some(artifacts()));
    rep.check_shape().unwrap();
    assert!(rep.used_pjrt, "fig5 model curves should use the artifact");
    assert!(rep.render_plot().contains("Figure 5"));
}

#[test]
fn fig6_runs_and_checks() {
    let rep = harness::fig6(&ci_cfg(), &MultilevelParams::default());
    rep.check_shape().unwrap();
    assert_eq!(rep.panels.len(), 3);
    for p in &rep.panels {
        let red = p.reduction_at_max_n().unwrap();
        assert!(red >= 10.0, "{}: reduction {red:.0}x", p.scheduler);
    }
}

#[test]
fn fig7_runs_and_checks() {
    let rep = harness::fig7(&ci_cfg(), &MultilevelParams::default());
    rep.check_shape().unwrap();
    let table = rep.render_table().render();
    assert!(table.contains("U multilevel"));
}

#[test]
fn service_runs_and_checks() {
    let mut cfg = ci_cfg();
    cfg.service_horizon = 120.0;
    let rep = harness::service(&cfg);
    rep.check_shape(cfg.trials).unwrap();
    let table = rep.render_table().render();
    assert!(table.contains("U(window)"));
    assert!(table.contains("batch started"));
    // Every trial is horizon-bounded with windowed accounting.
    for c in &rep.cells {
        for r in &c.trials {
            assert_eq!(r.horizon, Some(120.0));
            assert!(r.busy_core_seconds > 0.0, "{}", c.scheduler);
        }
    }
}

#[test]
fn features_render_all_tables() {
    for cat in sssched::features::FeatureCategory::all() {
        let t = sssched::features::feature_table(cat);
        assert!(!t.is_empty());
    }
}
