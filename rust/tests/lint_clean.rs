//! Tier-1 gate: the production tree must pass `pallas-lint` with zero
//! diagnostics, so introducing a determinism hazard (or letting an
//! allow go stale) fails `cargo test -q` — not just the dedicated CI
//! step.

use std::path::PathBuf;

use sssched::lint;

#[test]
fn tree_is_lint_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let report = lint::lint_tree(&root).expect("lint walks the crate");
    assert!(
        report.is_clean(),
        "pallas-lint found determinism-contract violations:\n{}",
        report.render()
    );
    // Sanity: the walk actually covered the tree (src/** plus
    // top-level tests), and the suppression machinery is exercised by
    // the linter binary's own documented wall-clock allow.
    assert!(
        report.files_scanned > 50,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
    assert!(
        report.suppressed >= 1,
        "expected at least the pallas-lint self-timing allow to be honoured"
    );
}

#[test]
fn rule_hits_are_reported_for_every_rule() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let report = lint::lint_tree(&root).expect("lint walks the crate");
    // The pre-suppression hit counts keep a stable shape (one entry
    // per rule, fixed order) so BENCH_perf.json rows stay comparable
    // across commits.
    let names: Vec<&str> = report.rule_hits.iter().map(|(n, _)| *n).collect();
    let expected: Vec<&str> = lint::RULES.iter().map(|r| r.name).collect();
    assert_eq!(names, expected);
    // Everything that fired was suppressed (the tree is clean), so the
    // total pre-suppression count equals the suppression count.
    let total: usize = report.rule_hits.iter().map(|(_, n)| *n).sum();
    assert_eq!(total, report.suppressed);
}
