//! Property suite for the degraded control plane: heartbeat failure
//! detection, lossy/delayed control messages, and speculative
//! re-execution, checked across every simulated backend.
//!
//! The invariants asserted from execution spans and counters:
//!
//! - **No start after detection.** Once a failed node is suspected
//!   (at `fail + detect_timeout`), nothing may start there until it
//!   recovers; inside the undetected window `[fail, detect)` doomed
//!   launches are allowed (and must die at the detection instant).
//!   Completions on a failed-but-undetected node cannot be observed,
//!   so no span may end strictly inside the window, and every
//!   detection latency equals the configured timeout.
//! - **Duplicated completions are exactly-once.** Under heavy
//!   completion duplication every task still completes exactly once:
//!   one trace record and one span per task.
//! - **Speculative duplicates never both count.** A duplicate and its
//!   primary produce one completion; the loser's span-seconds are
//!   exactly the run's `wasted_core_seconds` (fault-free runs: the
//!   primary always wins, so `spec_kills == spec_launches`).
//! - **Backoff retries respect the cap.** Under launch loss the
//!   zero-overhead baseline's makespan is bounded by the task time
//!   plus the full capped backoff schedule.
//! - **Bit-identity.** Every perturbed run is executed warm
//!   (reused [`SimScratch`]), fresh, and through the harness's
//!   parallel executor at `--jobs 1` vs `--jobs 4` — all four must
//!   agree bit-for-bit, degraded counters included.

use sssched::cluster::{ClusterSpec, FaultPlan, MessagePlan};
use sssched::config::SchedulerChoice;
use sssched::harness::run_cells;
use sssched::sched::{make_scheduler, RunOptions, RunResult, SimScratch};
use sssched::util::prng::Prng;
use sssched::workload::{ArrivalProcess, Workload, WorkloadBuilder};

const NODES: u32 = 6;
const CORES: u32 = 4;
const TASK_T: f64 = 2.0;
const EPS: f64 = 1e-9;

fn cluster() -> ClusterSpec {
    ClusterSpec::homogeneous(NODES, CORES, 32 * 1024, 3)
}

fn array_poisson(n: u64, seed: u64, rate: f64, label: &str) -> Workload {
    WorkloadBuilder::constant(TASK_T)
        .tasks(n)
        .seed(seed)
        .arrivals(ArrivalProcess::Poisson { rate })
        .label(label)
        .build()
}

/// Bit-identity over every observable, degraded counters included.
fn assert_bit_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.t_total.to_bits(), b.t_total.to_bits(), "{what}: t_total");
    assert_eq!(a.events, b.events, "{what}: events");
    assert_eq!(a.kills, b.kills, "{what}: kills");
    assert_eq!(a.failed, b.failed, "{what}: failed");
    assert_eq!(a.completed, b.completed, "{what}: completed");
    assert_eq!(
        a.wasted_core_seconds.to_bits(),
        b.wasted_core_seconds.to_bits(),
        "{what}: wasted_core_seconds"
    );
    assert_eq!(
        a.busy_core_seconds.to_bits(),
        b.busy_core_seconds.to_bits(),
        "{what}: busy_core_seconds"
    );
    assert_eq!(
        a.undetected_lost_core_seconds.to_bits(),
        b.undetected_lost_core_seconds.to_bits(),
        "{what}: undetected_lost_core_seconds"
    );
    let da: Vec<u64> = a.detection_latencies.iter().map(|x| x.to_bits()).collect();
    let db: Vec<u64> = b.detection_latencies.iter().map(|x| x.to_bits()).collect();
    assert_eq!(da, db, "{what}: detection_latencies");
    assert_eq!(a.messages_lost, b.messages_lost, "{what}: messages_lost");
    assert_eq!(
        a.messages_duplicated, b.messages_duplicated,
        "{what}: messages_duplicated"
    );
    assert_eq!(a.spec_launches, b.spec_launches, "{what}: spec_launches");
    assert_eq!(a.spec_kills, b.spec_kills, "{what}: spec_kills");
    assert_eq!(a.retry_hist, b.retry_hist, "{what}: retry_hist");
    assert_eq!(a.trace, b.trace, "{what}: trace");
    assert_eq!(a.spans, b.spans, "{what}: spans");
}

/// One fail/recover cycle per chosen node; `detected == false` cycles
/// recover inside the detection window (free false alarms).
fn random_fail_plan(rng: &mut Prng, span: f64, detect: f64) -> FaultPlan {
    let mut plan = FaultPlan::none();
    let mut any = false;
    for node in 0..NODES {
        if !rng.chance(0.6) {
            continue;
        }
        any = true;
        let a = rng.range_f64(span * 0.05, span * 0.5);
        let b = if rng.chance(0.4) {
            // False alarm: back before the detector can fire.
            a + detect * rng.range_f64(0.2, 0.9)
        } else {
            a + detect + rng.range_f64(span * 0.05, span * 0.3)
        };
        plan = plan.fail(a, node).recover(b, node);
    }
    if !any {
        plan = plan.fail(span * 0.2, 0).recover(span * 0.2 + 2.0 * detect, 0);
    }
    plan.validate().expect("generated plan must be valid");
    plan
}

fn random_message_plan(rng: &mut Prng) -> MessagePlan {
    let mut plan = MessagePlan::seeded(rng.next_u64());
    if rng.chance(0.8) {
        plan = plan.with_latency(
            rng.range_f64(0.01, 0.4),
            rng.range_f64(0.01, 0.4),
            rng.range_f64(0.01, 0.2),
        );
    }
    if rng.chance(0.8) {
        let base = rng.range_f64(0.05, 0.2);
        let cap = base * rng.range_f64(1.0, 4.0);
        let retries = 1 + (rng.next_u64() % 4) as u32;
        plan = plan.with_loss(rng.range_f64(0.05, 0.45), base, cap, retries);
    }
    if rng.chance(0.6) {
        plan = plan.with_duplication(rng.range_f64(0.05, 0.45));
    }
    plan.validate().expect("generated message plan must be valid");
    plan
}

#[test]
fn no_task_starts_on_a_node_after_its_detection_instant() {
    // Three real failures (recover well past detection), one false
    // alarm (recover inside the window). detect_timeout = 1.0.
    const DETECT: f64 = 1.0;
    let fails = [(0u32, 1.0f64), (1, 1.8), (2, 2.6)];
    let mut plan = FaultPlan::none();
    for &(node, at) in &fails {
        plan = plan.fail(at, node).recover(at + 5.0, node);
    }
    plan = plan.fail(1.4, 3).recover(1.9, 3); // false alarm on node 3
    plan.validate().unwrap();

    let mut w = array_poisson(48, 0xE1, 10.0, "degraded-prop-detect");
    for t in &mut w.tasks {
        t.max_retries = t.id % 4;
    }
    let cl = cluster();
    let opts = RunOptions {
        collect_trace: true,
        faults: plan,
        ..Default::default()
    }
    .detection(DETECT, 0.5 * DETECT);
    w.validate_for(&opts).unwrap();

    let mut scratch = SimScratch::new();
    let (mut doomed_starts, mut detections, mut undetected) = (0u64, 0u64, 0.0f64);
    for choice in SchedulerChoice::all_simulated() {
        let label = choice.name();
        let sched = make_scheduler(choice);
        let r = sched.run_with_scratch(&w, &cl, 0xD07E, &opts, &mut scratch);
        r.check_invariants().unwrap_or_else(|e| panic!("{label}: {e}"));
        assert_eq!(
            r.completed + r.failed,
            w.tasks.len() as u64,
            "{label}: horizonless runs finish or fail every task"
        );

        // Every detection is a real failure seen exactly detect_timeout
        // after the fact; the false alarm never shows up.
        assert!(
            r.detection_latencies.len() <= fails.len(),
            "{label}: more detections than real failures"
        );
        for &lat in &r.detection_latencies {
            assert!(
                (lat - DETECT).abs() <= EPS,
                "{label}: detection latency {lat} != detect_timeout {DETECT}"
            );
        }
        detections += r.detection_latencies.len() as u64;
        undetected += r.undetected_lost_core_seconds;

        let spans = r.spans.as_ref().expect("traced degraded runs collect spans");
        let mut per_task: Vec<Vec<&sssched::sched::ExecSpan>> =
            vec![Vec::new(); w.tasks.len()];
        for s in spans {
            let node = s.slot / CORES;
            per_task[s.task as usize].push(s);
            let Some(&(_, fail)) = fails.iter().find(|&&(n, _)| n == node) else {
                continue;
            };
            let det = fail + DETECT;
            let recover = fail + 5.0;
            // Suspected nodes accept nothing until recovery.
            assert!(
                !(s.start > det + EPS && s.start < recover - EPS),
                "{label}: task {} starts at {} on node {node} after its \
                 detection at {det} (recover {recover})",
                s.task,
                s.start
            );
            // The detection kill sweeps the node: no span crosses it.
            assert!(
                !(s.start < det - EPS && s.end > det + EPS),
                "{label}: task {} span [{}, {}] on node {node} runs through \
                 the detection instant {det}",
                s.task,
                s.start,
                s.end
            );
            // Ends inside (fail, det) are unobservable: an End there is
            // deferred to the suspicion instant, where the kill wins.
            assert!(
                !(s.end > fail + EPS && s.end < det - EPS),
                "{label}: task {} span ends at {} inside the undetected \
                 window ({fail}, {det}) on node {node}",
                s.task,
                s.end
            );
            // Doomed launch: allowed in the window, dead at detection.
            if s.start >= fail - EPS && s.start < det - EPS {
                assert!(
                    (s.end - det).abs() <= EPS,
                    "{label}: doomed task {} started at {} must die at the \
                     detection instant {det}, span ends at {}",
                    s.task,
                    s.start,
                    s.end
                );
                doomed_starts += 1;
            }
        }

        // Retry budgets hold, and every non-final span is a detection
        // kill on its own node.
        for (tid, ts) in per_task.iter_mut().enumerate() {
            ts.sort_by(|x, y| x.start.total_cmp(&y.start));
            assert!(
                ts.len() as u32 <= w.tasks[tid].max_retries + 1,
                "{label}: task {tid} dispatched {} times, retry budget {}",
                ts.len(),
                w.tasks[tid].max_retries
            );
            for s in ts.iter().take(ts.len().saturating_sub(1)) {
                let node = s.slot / CORES;
                let at_det = fails
                    .iter()
                    .any(|&(n, f)| n == node && (s.end - (f + DETECT)).abs() <= EPS);
                assert!(
                    at_det,
                    "{label}: task {tid} non-final span ends at {} which is \
                     not its node's detection instant",
                    s.end
                );
            }
        }
    }
    assert!(detections > 0, "the plan's real failures were never detected");
    assert!(
        doomed_starts > 0,
        "no launch ever targeted a failed-but-undetected node"
    );
    assert!(
        undetected > 0.0,
        "detection kills never charged undetected work"
    );
}

#[test]
fn duplicated_completions_complete_each_task_exactly_once() {
    let n = 30u64;
    let w = WorkloadBuilder::constant(1.5)
        .tasks(n)
        .seed(0xE2)
        .label("degraded-prop-dup")
        .build();
    let cl = cluster();
    let plan = MessagePlan::seeded(7)
        .with_latency(0.0, 0.05, 0.0)
        .with_duplication(0.9);
    let opts = RunOptions::with_trace().messages(plan);
    w.validate_for(&opts).unwrap();

    let mut scratch = SimScratch::new();
    for choice in SchedulerChoice::all_simulated() {
        let label = choice.name();
        let sched = make_scheduler(choice);
        let warm = sched.run_with_scratch(&w, &cl, 0xD0B1, &opts, &mut scratch);
        let fresh = sched.run(&w, &cl, 0xD0B1, &opts);
        assert_bit_identical(&warm, &fresh, label);
        warm.check_invariants().unwrap_or_else(|e| panic!("{label}: {e}"));
        assert_eq!(warm.completed, n, "{label}: every task completes once");
        assert!(
            warm.messages_duplicated > 0,
            "{label}: 0.9 duplication over {n} completions never fired"
        );
        let trace = warm.trace.as_ref().expect("traced run");
        assert_eq!(trace.len(), n as usize, "{label}: one trace record per task");
        let spans = warm.spans.as_ref().expect("degraded runs collect spans");
        assert_eq!(spans.len(), n as usize, "{label}: one span per task");
        let mut seen = vec![false; n as usize];
        for rec in trace {
            assert!(
                !seen[rec.task as usize],
                "{label}: task {} completed twice",
                rec.task
            );
            seen[rec.task as usize] = true;
        }
    }
}

#[test]
fn speculative_duplicates_never_both_count_as_goodput() {
    // Undersubscribed batches: sixteen 2 s seeds at t=0 feed the
    // Array-class runtime estimate, two 12 s stragglers submitted
    // afterwards trip the ×3 speculation deadline (start + 6 s, well
    // before their own end), and a short tail keeps the stream going.
    // The 24-slot pool is never full, so every duplicate finds a slot.
    let mut w = WorkloadBuilder::constant(TASK_T)
        .tasks(24)
        .seed(0xE3)
        .label("degraded-prop-spec")
        .build();
    for t in &mut w.tasks {
        match t.id {
            16 | 17 => {
                t.duration = 6.0 * TASK_T;
                t.submit_at = if t.id == 16 { 6.0 } else { 7.0 };
            }
            18..=23 => t.submit_at = 8.0,
            _ => {}
        }
    }
    let cl = cluster();
    let opts = RunOptions::with_trace().speculation(3.0);
    w.validate_for(&opts).unwrap();

    let mut scratch = SimScratch::new();
    for choice in SchedulerChoice::all_simulated() {
        let label = choice.name();
        let sched = make_scheduler(choice);
        let warm = sched.run_with_scratch(&w, &cl, 0x5BEC, &opts, &mut scratch);
        let fresh = sched.run(&w, &cl, 0x5BEC, &opts);
        assert_bit_identical(&warm, &fresh, label);
        warm.check_invariants().unwrap_or_else(|e| panic!("{label}: {e}"));
        assert_eq!(warm.completed, w.tasks.len() as u64, "{label}: all complete");
        assert!(
            warm.spec_launches > 0,
            "{label}: the stragglers never tripped speculation"
        );
        // Fault-free, the earlier-started primary always wins: every
        // duplicate is killed, none completes.
        assert_eq!(
            warm.spec_kills, warm.spec_launches,
            "{label}: a duplicate survived its primary in a fault-free run"
        );

        let spans = warm.spans.as_ref().expect("degraded runs collect spans");
        let mut count = vec![0u32; w.tasks.len()];
        let mut total = 0.0;
        for s in spans {
            count[s.task as usize] += 1;
            total += s.end - s.start;
        }
        for (tid, &c) in count.iter().enumerate() {
            assert!(
                c <= 2,
                "{label}: task {tid} has {c} spans (primary + at most one duplicate)"
            );
        }
        assert_eq!(
            count.iter().filter(|&&c| c == 2).count() as u64,
            warm.spec_launches,
            "{label}: exactly the speculated tasks carry a duplicate span"
        );
        // Exactly one span per task counts toward goodput: the rest of
        // the span-seconds — the losing duplicates — are the waste.
        let durations: f64 = w.tasks.iter().map(|t| t.duration).sum();
        assert!(
            (warm.wasted_core_seconds - (total - durations)).abs() <= 1e-6 * total.max(1.0),
            "{label}: wasted {} != duplicate span-seconds {}",
            warm.wasted_core_seconds,
            total - durations
        );
    }
}

#[test]
fn lost_launch_retries_respect_the_backoff_cap() {
    // 24 × 1 s tasks on 24 slots under 90 % launch loss, backoff
    // 0.1/0.2/0.4 (capped), 3 retries: the attempt after the budget is
    // force-delivered, so no start slips past t = 0.7 on the
    // zero-overhead baseline and the makespan is bounded by 1.7 s.
    let n = 24u64;
    let w = WorkloadBuilder::constant(1.0)
        .tasks(n)
        .seed(0xE4)
        .label("degraded-prop-loss")
        .build();
    let cl = cluster();
    let plan = MessagePlan::seeded(3).with_loss(0.9, 0.1, 0.4, 3);
    let backoff_budget: f64 = (1..=3).map(|a| plan.backoff_delay(a)).sum();
    assert!((backoff_budget - 0.7).abs() <= EPS);
    let opts = RunOptions::with_trace().messages(plan);
    w.validate_for(&opts).unwrap();

    let mut scratch = SimScratch::new();
    for choice in SchedulerChoice::all_simulated() {
        let label = choice.name();
        let sched = make_scheduler(choice);
        let r = sched.run_with_scratch(&w, &cl, 0x1057, &opts, &mut scratch);
        r.check_invariants().unwrap_or_else(|e| panic!("{label}: {e}"));
        assert_eq!(r.completed, n, "{label}: loss delays but never drops a task");
        assert!(
            r.messages_lost > 0,
            "{label}: 0.9 loss over {n} launches never lost"
        );
        assert!(
            r.messages_lost <= n * 3,
            "{label}: {} losses exceed the {n}-task × 3-retry budget",
            r.messages_lost
        );
        if choice == SchedulerChoice::IdealFifo {
            // Zero dispatch overhead isolates the backoff schedule.
            assert!(r.t_total > 1.0, "{label}: a lost launch must delay its task");
            assert!(
                r.t_total <= 1.0 + backoff_budget + EPS,
                "{label}: backoff cap exceeded: t_total={}",
                r.t_total
            );
        }
    }
}

#[test]
fn degraded_runs_are_bit_identical_warm_fresh_and_across_jobs() {
    let mut w = array_poisson(48, 0xE5, 8.0, "degraded-prop-bits");
    for t in &mut w.tasks {
        t.max_retries = t.id % 4;
    }
    let cl = cluster();

    // Random degraded option sets: message perturbation × fault plan ×
    // detection × (sometimes) speculation, per scheduler × trial.
    let mut rng = Prng::new(0x0E55);
    let mut cells: Vec<(SchedulerChoice, u64, RunOptions)> = Vec::new();
    for choice in SchedulerChoice::all_simulated() {
        for trial in 0..3u64 {
            let detect = rng.range_f64(0.4, 1.2);
            let spec = if rng.chance(0.7) {
                rng.range_f64(2.0, 4.0)
            } else {
                0.0
            };
            let opts = RunOptions {
                collect_trace: true,
                faults: random_fail_plan(&mut rng, 12.0, detect),
                ..Default::default()
            }
            .messages(random_message_plan(&mut rng))
            .detection(detect, 0.5 * detect)
            .speculation(spec);
            w.validate_for(&opts).unwrap();
            cells.push((choice, trial, opts));
        }
    }

    let work = |cell: &(SchedulerChoice, u64, RunOptions), scratch: &mut SimScratch| {
        make_scheduler(cell.0).run_with_scratch(&w, &cl, 0xDEC0 + cell.1, &cell.2, scratch)
    };
    let serial = run_cells(1, &cells, work);
    let threaded = run_cells(4, &cells, work);
    assert_eq!(serial.len(), cells.len());

    for (i, cell) in cells.iter().enumerate() {
        let label = format!("{}/trial{}", cell.0.name(), cell.1);
        serial[i]
            .check_invariants()
            .unwrap_or_else(|e| panic!("{label}: {e}"));
        assert_bit_identical(&serial[i], &threaded[i], &format!("{label}: jobs 1 vs 4"));
        let fresh = make_scheduler(cell.0).run(&w, &cl, 0xDEC0 + cell.1, &cell.2);
        assert_bit_identical(&serial[i], &fresh, &format!("{label}: warm vs fresh"));
    }

    // The random plans must actually exercise the machinery somewhere
    // in the pool, or the identity checks prove nothing.
    assert!(
        serial
            .iter()
            .map(|r| r.messages_lost + r.messages_duplicated)
            .sum::<u64>()
            > 0,
        "no run ever lost or duplicated a message"
    );
    assert!(
        serial.iter().any(|r| !r.detection_latencies.is_empty()),
        "no run ever detected a failure"
    );
    assert!(
        serial.iter().map(|r| r.kills).sum::<u64>() > 0,
        "no run ever killed a task"
    );
}
