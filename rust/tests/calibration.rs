//! Calibration integration tests: the simulated Table 9 runtimes and
//! Table 10 fits land near the paper's measurements at full scale.

use sssched::cluster::ClusterSpec;
use sssched::config::SchedulerChoice;
use sssched::model::fit_from_runs;
use sssched::sched::{make_scheduler, RunOptions};
use sssched::workload::table9_sets;

fn full_cluster() -> ClusterSpec {
    ClusterSpec::supercloud()
}

/// Simulate one Table 9 cell (single trial).
fn simulate(choice: SchedulerChoice, set_idx: usize) -> f64 {
    let cluster = full_cluster();
    let sched = make_scheduler(choice);
    let set = table9_sets()[set_idx];
    let w = set.workload(cluster.total_cores());
    sched
        .run(&w, &cluster, 99, &RunOptions::default())
        .t_total
}

#[test]
fn slurm_table9_within_tolerance() {
    let paper = [2783.7, 610.3, 271.0, 283.7];
    for (i, &expect) in paper.iter().enumerate() {
        let got = simulate(SchedulerChoice::Slurm, i);
        let ratio = got / expect;
        assert!(
            (0.7..1.3).contains(&ratio),
            "slurm set {i}: sim {got:.0}s vs paper {expect:.0}s (ratio {ratio:.2})"
        );
    }
}

#[test]
fn gridengine_table9_within_tolerance() {
    let paper = [3070.7, 626.3, 278.0, 276.7];
    for (i, &expect) in paper.iter().enumerate() {
        let got = simulate(SchedulerChoice::GridEngine, i);
        let ratio = got / expect;
        assert!(
            (0.7..1.3).contains(&ratio),
            "ge set {i}: sim {got:.0} vs paper {expect:.0} (ratio {ratio:.2})"
        );
    }
}

#[test]
fn mesos_table9_within_tolerance() {
    let paper = [1793.7, 365.7, 280.3, 305.7];
    for (i, &expect) in paper.iter().enumerate() {
        let got = simulate(SchedulerChoice::Mesos, i);
        let ratio = got / expect;
        assert!(
            (0.7..1.3).contains(&ratio),
            "mesos set {i}: sim {got:.0} vs paper {expect:.0} (ratio {ratio:.2})"
        );
    }
}

#[test]
fn yarn_table9_within_tolerance_and_rapid_prohibitive() {
    let paper = [1840.3, 487.0, 378.0]; // fast, medium, long
    for (i, &expect) in paper.iter().enumerate() {
        let got = simulate(SchedulerChoice::Yarn, i + 1);
        let ratio = got / expect;
        assert!(
            (0.7..1.3).contains(&ratio),
            "yarn set {}: sim {got:.0} vs paper {expect:.0} (ratio {ratio:.2})",
            i + 1
        );
    }
    // Rapid set projected prohibitive, like the paper's abandoned runs.
    let cluster = full_cluster();
    let sched = make_scheduler(SchedulerChoice::Yarn);
    let rapid = table9_sets()[0].workload(cluster.total_cores());
    assert!(sched.projected_runtime(&rapid, &cluster) > 3600.0);
}

#[test]
fn table10_fits_near_paper() {
    // Fit over the four Table 9 points, one trial each (the bench does
    // the full fig4 sweep; this is the cheap regression guard).
    let cluster = full_cluster();
    let tolerances = [
        (SchedulerChoice::Slurm, 2.2, 1.3, 0.8, 0.15),
        (SchedulerChoice::GridEngine, 2.8, 1.3, 0.9, 0.15),
        (SchedulerChoice::Mesos, 3.4, 1.1, 1.2, 0.15),
    ];
    for (choice, ts_paper, al_paper, ts_tol, al_tol) in tolerances {
        let sched = make_scheduler(choice);
        let runs: Vec<_> = table9_sets()
            .iter()
            .map(|set| {
                let w = set.workload(cluster.total_cores());
                sched.run(&w, &cluster, 123, &RunOptions::default())
            })
            .collect();
        let fit = fit_from_runs(&runs);
        assert!(
            (fit.t_s - ts_paper).abs() < ts_tol,
            "{}: t_s {:.2} vs paper {ts_paper}",
            sched.name(),
            fit.t_s
        );
        assert!(
            (fit.alpha_s - al_paper).abs() < al_tol,
            "{}: alpha {:.2} vs paper {al_paper}",
            sched.name(),
            fit.alpha_s
        );
    }
}

#[test]
fn trial_scatter_is_small_like_paper() {
    // Table 9 triples scatter by <2%; our jitter should match.
    let cluster = full_cluster();
    let sched = make_scheduler(SchedulerChoice::Slurm);
    let set = table9_sets()[1]; // fast
    let w = set.workload(cluster.total_cores());
    let runs: Vec<f64> = (0..3)
        .map(|s| sched.run(&w, &cluster, 500 + s, &RunOptions::default()).t_total)
        .collect();
    let mean = runs.iter().sum::<f64>() / 3.0;
    for r in &runs {
        assert!(
            (r / mean - 1.0).abs() < 0.05,
            "trial scatter too large: {runs:?}"
        );
    }
    // ...but not zero (the paper's trials differ).
    assert!(runs.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-6));
}

#[test]
fn utilization_below_10pct_for_one_second_tasks() {
    // The abstract's headline: "utilization ... decreases to <10% for
    // computations lasting only a few seconds" (1 s tasks).
    for choice in [SchedulerChoice::Slurm, SchedulerChoice::GridEngine] {
        let cluster = full_cluster();
        let sched = make_scheduler(choice);
        let w = table9_sets()[0].workload(cluster.total_cores());
        let r = sched.run(&w, &cluster, 7, &RunOptions::default());
        assert!(
            r.utilization() < 0.10,
            "{}: U={:.3}",
            sched.name(),
            r.utilization()
        );
    }
}

#[test]
fn paper_anchor_daemon_throughput() {
    // N / T_total on the rapid set ≈ paper-implied daemon throughput.
    let got = simulate(SchedulerChoice::Slurm, 0);
    let throughput = 337_920.0 / got;
    assert!(
        (throughput - 121.0).abs() < 15.0,
        "slurm daemon throughput {throughput:.0}/s vs paper-implied ~121/s"
    );
}
