//! Property tests for the unified control-plane kernel: every backend,
//! run through `sim::Kernel` on random workloads mixing DAG chains,
//! gangs, multi-core tasks and arrival processes, must satisfy the
//! result invariants, complete every task exactly once, respect
//! dependencies, and stay bit-identical under scratch reuse.

use sssched::cluster::ClusterSpec;
use sssched::config::SchedulerChoice;
use sssched::sched::{make_scheduler, RunOptions, SimScratch};
use sssched::util::prng::Prng;
use sssched::util::prop::{ensure, forall, PropConfig};
use sssched::workload::{ArrivalProcess, Workload, WorkloadBuilder};

#[derive(Debug, Clone, Copy, PartialEq)]
enum Shape {
    Array,
    Multicore,
    DagChain,
    Gang,
    Poisson,
    Burst,
}

#[derive(Debug)]
struct Case {
    choice: SchedulerChoice,
    shape: Shape,
    n_tasks: u64,
    task_time: f64,
    seed: u64,
}

fn gen_case(rng: &mut Prng) -> Case {
    let choices = SchedulerChoice::all_simulated();
    let shapes = [
        Shape::Array,
        Shape::Multicore,
        Shape::DagChain,
        Shape::Gang,
        Shape::Poisson,
        Shape::Burst,
    ];
    Case {
        choice: choices[rng.choose_index(choices.len())],
        shape: shapes[rng.choose_index(shapes.len())],
        n_tasks: rng.range_u64(1, 160),
        task_time: rng.range_f64(0.5, 8.0),
        seed: rng.next_u64(),
    }
}

fn cluster() -> ClusterSpec {
    // 2 nodes × 8 cores: enough headroom for 4-wide gangs of 2-core
    // tasks on every backend.
    ClusterSpec::homogeneous(2, 8, 64 * 1024, 2)
}

fn build_workload(case: &Case) -> Workload {
    let b = WorkloadBuilder::constant(case.task_time)
        .tasks(case.n_tasks)
        .seed(case.seed)
        .label("prop");
    match case.shape {
        Shape::Array => b.build(),
        Shape::Multicore => b.cores(2).build(),
        Shape::DagChain => b.dag_chains(4).build(),
        Shape::Gang => b.gangs(4).build(),
        Shape::Poisson => b.arrivals(ArrivalProcess::Poisson { rate: 4.0 }).build(),
        Shape::Burst => b
            .arrivals(ArrivalProcess::Bursty {
                burst: 16,
                period: 5.0,
            })
            .build(),
    }
}

#[test]
fn prop_kernel_backends_complete_all_workload_shapes() {
    forall(
        PropConfig {
            cases: 60,
            seed: 0x2B1D,
        },
        gen_case,
        |case| {
            let w = build_workload(case);
            w.validate()?;
            let sched = make_scheduler(case.choice);
            let r = sched.run(&w, &cluster(), case.seed, &RunOptions::with_trace());
            r.check_invariants()?;
            let trace = r.trace.as_ref().expect("trace collected");
            ensure(
                trace.len() == w.len(),
                format!("{} records for {} tasks", trace.len(), w.len()),
            )?;
            let mut ids: Vec<u32> = trace.iter().map(|t| t.task).collect();
            ids.sort_unstable();
            ids.dedup();
            ensure(ids.len() == w.len(), "duplicate or missing task ids")?;

            // Dependencies: children never start before parents end.
            let mut start = vec![0.0f64; w.len()];
            let mut end = vec![0.0f64; w.len()];
            for rec in trace {
                start[rec.task as usize] = rec.start;
                end[rec.task as usize] = rec.end;
            }
            for t in &w.tasks {
                for &d in &t.deps {
                    ensure(
                        start[t.id as usize] >= end[d as usize] - 1e-9,
                        format!("task {} started before dep {d} finished", t.id),
                    )?;
                }
                ensure(
                    start[t.id as usize] >= t.submit_at - 1e-9,
                    format!("task {} started before submission", t.id),
                )?;
            }

            // Gangs: members are dispatched in one all-or-nothing pass,
            // so their starts differ only by per-task launch overheads
            // (zero for IdealFIFO, synchronized exactly for Sparrow),
            // never by a scheduling wave.
            if case.shape == Shape::Gang {
                let exact = matches!(
                    case.choice,
                    SchedulerChoice::IdealFifo | SchedulerChoice::Sparrow
                );
                // Non-exact backends: bounded by launch-overhead jitter
                // (YARN's ~31 s AM startups dominate); a missed wave
                // would skew by a full task time + AM (> 30 s).
                let tol = if exact { 1e-9 } else { 15.0 };
                for t in &w.tasks {
                    let first = w
                        .tasks
                        .iter()
                        .find(|o| o.job == t.job)
                        .expect("job has members");
                    ensure(
                        (start[t.id as usize] - start[first.id as usize]).abs() <= tol,
                        format!("gang {} start skew on task {}", t.job, t.id),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scratch_reuse_bit_identical_across_shapes() {
    let mut scratch = SimScratch::new();
    forall(
        PropConfig {
            cases: 30,
            seed: 0x3C2E,
        },
        gen_case,
        |case| {
            let w = build_workload(case);
            let sched = make_scheduler(case.choice);
            let warm = sched.run_with_scratch(
                &w,
                &cluster(),
                case.seed,
                &RunOptions::with_trace(),
                &mut scratch,
            );
            let fresh = sched.run(&w, &cluster(), case.seed, &RunOptions::with_trace());
            ensure(
                warm.t_total.to_bits() == fresh.t_total.to_bits(),
                format!("t_total differs: {} vs {}", warm.t_total, fresh.t_total),
            )?;
            ensure(warm.events == fresh.events, "event count differs")?;
            ensure(
                warm.daemon_busy.to_bits() == fresh.daemon_busy.to_bits(),
                "daemon_busy differs",
            )?;
            ensure(
                warm.trace.as_ref() == fresh.trace.as_ref(),
                "traces differ",
            )
        },
    );
}

#[test]
fn individual_submission_still_runs_through_kernel() {
    let options = RunOptions {
        individual_submission: true,
        collect_trace: true,
    };
    let w = WorkloadBuilder::constant(2.0).tasks(48).label("ind").build();
    for choice in SchedulerChoice::all_simulated() {
        let sched = make_scheduler(choice);
        let r = sched.run(&w, &cluster(), 5, &options);
        r.check_invariants()
            .unwrap_or_else(|e| panic!("{}: {e}", sched.name()));
        assert_eq!(r.trace.as_ref().unwrap().len(), 48, "{}", sched.name());
    }
}
