//! Property tests for the unified control-plane kernel: every backend,
//! run through `sim::Kernel` on random workloads mixing DAG chains,
//! gangs, multi-core tasks and arrival processes, must satisfy the
//! result invariants, complete every task exactly once, respect
//! dependencies, and stay bit-identical under scratch reuse.

use sssched::cluster::ClusterSpec;
use sssched::config::SchedulerChoice;
use sssched::sched::combinators::{make_preemptive, Order};
use sssched::sched::{make_scheduler, RunOptions, RunResult, SimScratch};
use sssched::util::prng::Prng;
use sssched::util::prop::{ensure, forall, PropConfig};
use sssched::workload::{ArrivalProcess, TaskSpec, Workload, WorkloadBuilder};

#[derive(Debug, Clone, Copy, PartialEq)]
enum Shape {
    Array,
    Multicore,
    DagChain,
    Gang,
    Poisson,
    Burst,
}

#[derive(Debug)]
struct Case {
    choice: SchedulerChoice,
    shape: Shape,
    n_tasks: u64,
    task_time: f64,
    seed: u64,
}

fn gen_case(rng: &mut Prng) -> Case {
    let choices = SchedulerChoice::all_simulated();
    let shapes = [
        Shape::Array,
        Shape::Multicore,
        Shape::DagChain,
        Shape::Gang,
        Shape::Poisson,
        Shape::Burst,
    ];
    Case {
        choice: choices[rng.choose_index(choices.len())],
        shape: shapes[rng.choose_index(shapes.len())],
        n_tasks: rng.range_u64(1, 160),
        task_time: rng.range_f64(0.5, 8.0),
        seed: rng.next_u64(),
    }
}

fn cluster() -> ClusterSpec {
    // 2 nodes × 8 cores: enough headroom for 4-wide gangs of 2-core
    // tasks on every backend.
    ClusterSpec::homogeneous(2, 8, 64 * 1024, 2)
}

fn build_workload(case: &Case) -> Workload {
    let b = WorkloadBuilder::constant(case.task_time)
        .tasks(case.n_tasks)
        .seed(case.seed)
        .label("prop");
    match case.shape {
        Shape::Array => b.build(),
        Shape::Multicore => b.cores(2).build(),
        Shape::DagChain => b.dag_chains(4).build(),
        Shape::Gang => b.gangs(4).build(),
        Shape::Poisson => b.arrivals(ArrivalProcess::Poisson { rate: 4.0 }).build(),
        Shape::Burst => b
            .arrivals(ArrivalProcess::Bursty {
                burst: 16,
                period: 5.0,
            })
            .build(),
    }
}

#[test]
fn prop_kernel_backends_complete_all_workload_shapes() {
    forall(
        PropConfig {
            cases: 60,
            seed: 0x2B1D,
        },
        gen_case,
        |case| {
            let w = build_workload(case);
            w.validate()?;
            let sched = make_scheduler(case.choice);
            let r = sched.run(&w, &cluster(), case.seed, &RunOptions::with_trace());
            r.check_invariants()?;
            let trace = r.trace.as_ref().expect("trace collected");
            ensure(
                trace.len() == w.len(),
                format!("{} records for {} tasks", trace.len(), w.len()),
            )?;
            let mut ids: Vec<u32> = trace.iter().map(|t| t.task).collect();
            ids.sort_unstable();
            ids.dedup();
            ensure(ids.len() == w.len(), "duplicate or missing task ids")?;

            // Dependencies: children never start before parents end.
            let mut start = vec![0.0f64; w.len()];
            let mut end = vec![0.0f64; w.len()];
            for rec in trace {
                start[rec.task as usize] = rec.start;
                end[rec.task as usize] = rec.end;
            }
            for t in &w.tasks {
                for &d in &t.deps {
                    ensure(
                        start[t.id as usize] >= end[d as usize] - 1e-9,
                        format!("task {} started before dep {d} finished", t.id),
                    )?;
                }
                ensure(
                    start[t.id as usize] >= t.submit_at - 1e-9,
                    format!("task {} started before submission", t.id),
                )?;
            }

            // Gangs: members are dispatched in one all-or-nothing pass,
            // so their starts differ only by per-task launch overheads
            // (zero for IdealFIFO, synchronized exactly for Sparrow),
            // never by a scheduling wave.
            if case.shape == Shape::Gang {
                let exact = matches!(
                    case.choice,
                    SchedulerChoice::IdealFifo | SchedulerChoice::Sparrow
                );
                // Non-exact backends: bounded by launch-overhead jitter
                // (YARN's ~31 s AM startups dominate); a missed wave
                // would skew by a full task time + AM (> 30 s).
                let tol = if exact { 1e-9 } else { 15.0 };
                for t in &w.tasks {
                    let first = w
                        .tasks
                        .iter()
                        .find(|o| o.job == t.job)
                        .expect("job has members");
                    ensure(
                        (start[t.id as usize] - start[first.id as usize]).abs() <= tol,
                        format!("gang {} start skew on task {}", t.job, t.id),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scratch_reuse_bit_identical_across_shapes() {
    let mut scratch = SimScratch::new();
    forall(
        PropConfig {
            cases: 30,
            seed: 0x3C2E,
        },
        gen_case,
        |case| {
            let w = build_workload(case);
            let sched = make_scheduler(case.choice);
            let warm = sched.run_with_scratch(
                &w,
                &cluster(),
                case.seed,
                &RunOptions::with_trace(),
                &mut scratch,
            );
            let fresh = sched.run(&w, &cluster(), case.seed, &RunOptions::with_trace());
            ensure(
                warm.t_total.to_bits() == fresh.t_total.to_bits(),
                format!("t_total differs: {} vs {}", warm.t_total, fresh.t_total),
            )?;
            ensure(warm.events == fresh.events, "event count differs")?;
            ensure(
                warm.daemon_busy.to_bits() == fresh.daemon_busy.to_bits(),
                "daemon_busy differs",
            )?;
            ensure(
                warm.trace.as_ref() == fresh.trace.as_ref(),
                "traces differ",
            )
        },
    );
}

// ---- service-in-the-mix window properties ---------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum BatchShape {
    Array,
    DagChain,
    Gang,
}

#[derive(Debug)]
struct SvcCase {
    choice: SchedulerChoice,
    shape: BatchShape,
    services: u64,
    n_batch: u64,
    task_time: f64,
    horizon: f64,
    preemptible: bool,
    seed: u64,
}

fn gen_svc_case(rng: &mut Prng) -> SvcCase {
    let choices = SchedulerChoice::all_simulated();
    let shapes = [BatchShape::Array, BatchShape::DagChain, BatchShape::Gang];
    let task_time = rng.range_f64(0.5, 6.0);
    SvcCase {
        choice: choices[rng.choose_index(choices.len())],
        shape: shapes[rng.choose_index(shapes.len())],
        services: rng.range_u64(1, 7),
        n_batch: rng.range_u64(1, 80),
        task_time,
        horizon: task_time * rng.range_f64(1.0, 6.0),
        preemptible: rng.chance(0.5),
        seed: rng.next_u64(),
    }
}

fn build_svc_workload(case: &SvcCase) -> Workload {
    let mut b = WorkloadBuilder::constant(case.task_time)
        .tasks(case.n_batch)
        .services(case.services, 1)
        .seed(case.seed)
        .label("svc-prop");
    b = match case.shape {
        BatchShape::Array => b,
        BatchShape::DagChain => b.dag_chains(4),
        BatchShape::Gang => b.gangs(4),
    };
    if case.preemptible {
        b = b.preemptible(0.0);
    }
    b.build()
}

/// Per-slot execution intervals of a windowed run: from spans when the
/// preemption subsystem collected them, else from the trace (identical
/// for eviction-free runs). All tasks are 1-core in these cases, so the
/// intervals fully describe slot occupancy.
fn slot_intervals(r: &RunResult) -> Vec<(u32, f64, f64)> {
    match &r.spans {
        Some(spans) => spans.iter().map(|s| (s.slot, s.start, s.end)).collect(),
        None => r
            .trace
            .as_ref()
            .expect("traced run")
            .iter()
            .map(|t| (t.slot, t.start, t.end))
            .collect(),
    }
}

fn check_windowed_run(r: &RunResult, w: &Workload, horizon: f64) -> Result<(), String> {
    r.check_invariants()?;
    let trace = r.trace.as_ref().expect("trace collected");
    ensure(trace.len() <= w.len(), "more trace records than tasks")?;
    let mut ids: Vec<u32> = trace.iter().map(|t| t.task).collect();
    ids.sort_unstable();
    ids.dedup();
    ensure(ids.len() == trace.len(), "duplicate task ids in trace")?;
    for rec in trace {
        ensure(
            rec.end <= horizon + 1e-9,
            format!("record past horizon: {rec:?}"),
        )?;
        ensure(
            rec.start >= rec.submit - 1e-9 && rec.end >= rec.start - 1e-9,
            format!("non-causal record {rec:?}"),
        )?;
    }
    // Window-clipped span accounting: busy_core_seconds is exactly the
    // integral of the observed (1-core) execution intervals.
    let intervals = slot_intervals(r);
    let expected: f64 = intervals.iter().map(|&(_, s, e)| e - s).sum();
    ensure(
        (r.busy_core_seconds - expected).abs() < 1e-6,
        format!(
            "busy_core_seconds {} != span integral {expected}",
            r.busy_core_seconds
        ),
    )?;
    // No slot double-allocation: intervals on one slot never overlap.
    let mut by_slot = intervals;
    by_slot.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
    for pair in by_slot.windows(2) {
        let (s0, _, e0) = pair[0];
        let (s1, b1, _) = pair[1];
        ensure(
            s0 != s1 || b1 >= e0 - 1e-9,
            format!("slot {s0} double-booked: {pair:?}"),
        )?;
    }
    Ok(())
}

#[test]
fn prop_service_mixes_clip_spans_and_never_double_book_slots() {
    forall(
        PropConfig {
            cases: 60,
            seed: 0x5E41_1CE,
        },
        gen_svc_case,
        |case| {
            let w = build_svc_workload(case);
            let options = RunOptions {
                collect_trace: true,
                horizon: Some(case.horizon),
                ..Default::default()
            };
            w.validate_for(&options)?;
            let sched = make_scheduler(case.choice);
            let r = sched.run(&w, &cluster(), case.seed, &options);
            check_windowed_run(&r, &w, case.horizon)?;
            // Every service that started is clipped to the horizon or
            // was last seen at its eviction instant — it never "ends"
            // earlier on its own.
            let trace = r.trace.as_ref().expect("traced");
            for rec in trace.iter().filter(|t| t.task < case.services as u32) {
                ensure(
                    r.preemptions > 0 || (rec.end - case.horizon).abs() < 1e-9,
                    format!("service completed early: {rec:?}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn service_mix_with_preemption_keeps_window_accounting() {
    // A saturated cluster of preemptible services + staggered
    // high-priority short tasks under the Preemptive wrapper: evictions
    // must happen, and the windowed accounting must still integrate
    // exactly over the split spans with no slot double-booking.
    let cl = cluster(); // 16 slots
    let horizon = 30.0;
    let mut tasks: Vec<TaskSpec> = (0..16)
        .map(|i| {
            let mut t = TaskSpec::service(i, i, 1);
            t.preemptible = true;
            t
        })
        .collect();
    for k in 0..12u32 {
        let mut t = TaskSpec::array(16 + k, 16 + k, 2.0);
        t.priority = 10;
        t.submit_at = 1.0 + 0.5 * k as f64;
        tasks.push(t);
    }
    let w = Workload {
        tasks,
        label: "svc-pre".into(),
    };
    let options = RunOptions {
        collect_trace: true,
        horizon: Some(horizon),
        ..Default::default()
    };
    w.validate_for(&options).unwrap();
    for choice in [
        SchedulerChoice::IdealFifo,
        SchedulerChoice::Slurm,
        SchedulerChoice::Mesos,
    ] {
        let sched = make_preemptive(choice, 1, Order::Priority);
        let r = sched.run(&w, &cl, 3, &options);
        check_windowed_run(&r, &w, horizon).unwrap_or_else(|e| panic!("{}: {e}", sched.name()));
        if choice == SchedulerChoice::IdealFifo {
            assert!(r.preemptions > 0, "saturated ideal cluster must evict");
            // All 28 tasks ran inside the generous window.
            assert_eq!(r.trace.as_ref().unwrap().len(), 28);
        }
    }
}

#[test]
fn individual_submission_still_runs_through_kernel() {
    let options = RunOptions {
        individual_submission: true,
        collect_trace: true,
        ..Default::default()
    };
    let w = WorkloadBuilder::constant(2.0).tasks(48).label("ind").build();
    for choice in SchedulerChoice::all_simulated() {
        let sched = make_scheduler(choice);
        let r = sched.run(&w, &cluster(), 5, &options);
        r.check_invariants()
            .unwrap_or_else(|e| panic!("{}: {e}", sched.name()));
        assert_eq!(r.trace.as_ref().unwrap().len(), 48, "{}", sched.name());
    }
}
