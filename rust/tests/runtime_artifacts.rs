//! Integration: PJRT execution of the AOT artifacts from rust, checked
//! against the rust-native implementations. Requires `make artifacts`.

use sssched::runtime::{shapes, ArtifactSuite, PjrtFit};
use sssched::util::fit::fit_power_law;

fn suite() -> ArtifactSuite {
    ArtifactSuite::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .expect("artifacts missing — run `make artifacts`")
}

#[test]
fn powerlaw_fit_matches_rust_fit() {
    let mut s = suite();
    // Synthetic series at the paper's Table 10 parameters.
    let mk = |t_s: f64, alpha: f64| -> Vec<(f64, f64)> {
        [4.0, 8.0, 48.0, 240.0]
            .iter()
            .map(|&n: &f64| (n, t_s * n.powf(alpha)))
            .collect()
    };
    let series = vec![mk(2.2, 1.3), mk(2.8, 1.3), mk(3.4, 1.1), mk(33.0, 1.0)];
    let fits = s.powerlaw_fit(&series).unwrap();
    assert_eq!(fits.len(), 4);
    for (fit, pts) in fits.iter().zip(&series) {
        let ns: Vec<f64> = pts.iter().map(|p| p.0).collect();
        let dts: Vec<f64> = pts.iter().map(|p| p.1).collect();
        let rust_fit = fit_power_law(&ns, &dts);
        // f32 kernel vs f64 rust: agree to ~1e-3 relative.
        assert!(
            (fit.t_s - rust_fit.t_s).abs() / rust_fit.t_s < 2e-3,
            "t_s pjrt={} rust={}",
            fit.t_s,
            rust_fit.t_s
        );
        assert!((fit.alpha_s - rust_fit.alpha_s).abs() < 2e-3);
        assert!(fit.r2 > 0.999);
    }
}

#[test]
fn powerlaw_fit_skips_nonpositive_points() {
    let mut s = suite();
    // ΔT = 0 at small n (shot noise) must be masked out, matching the
    // rust fitter's behaviour.
    let series = vec![vec![
        (1.0, 0.0),
        (4.0, 2.2 * 4f64.powf(1.3)),
        (8.0, 2.2 * 8f64.powf(1.3)),
        (240.0, 2.2 * 240f64.powf(1.3)),
    ]];
    let fits = s.powerlaw_fit(&series).unwrap();
    assert!((fits[0].t_s - 2.2).abs() < 0.01, "t_s={}", fits[0].t_s);
    assert!((fits[0].alpha_s - 1.3).abs() < 0.01);
}

#[test]
fn powerlaw_fit_rejects_degenerate_series() {
    let mut s = suite();
    assert!(s.powerlaw_fit(&[vec![(4.0, 10.0)]]).is_err()); // 1 point
    assert!(s
        .powerlaw_fit(&[vec![(0.0, 0.0), (-1.0, -5.0)]])
        .is_err()); // no positive points
}

#[test]
fn utilization_curves_match_model() {
    let mut s = suite();
    let fits = [
        PjrtFit {
            t_s: 2.2,
            alpha_s: 1.3,
            r2: 1.0,
        },
        PjrtFit {
            t_s: 33.0,
            alpha_s: 1.0,
            r2: 1.0,
        },
    ];
    let t_grid: Vec<f64> = (0..shapes::UTIL_T)
        .map(|i| 0.5 * 1.1f64.powi(i as i32))
        .collect();
    let (approx, exact) = s.utilization_curves(&fits, &t_grid).unwrap();
    assert_eq!(approx.len(), 2);
    for (i, f) in fits.iter().enumerate() {
        for (j, &t) in t_grid.iter().enumerate() {
            let want_a = sssched::model::u_constant_approx(f.t_s, t);
            let n = 240.0 / t;
            let want_e = sssched::model::u_constant_exact(f.t_s, f.alpha_s, t, n);
            assert!(
                (approx[i][j] - want_a).abs() < 1e-4,
                "approx[{i}][{j}] {} vs {}",
                approx[i][j],
                want_a
            );
            assert!((exact[i][j] - want_e).abs() < 1e-4);
        }
    }
}

#[test]
fn analytics_payload_executes() {
    let mut s = suite();
    let x = vec![1.0f32; shapes::ANALYTICS_B * shapes::ANALYTICS_D];
    let w = vec![0.5f32; shapes::ANALYTICS_D * shapes::ANALYTICS_F];
    let (feats, checksum) = s.analytics(&x, &w).unwrap();
    assert_eq!(feats.len(), shapes::ANALYTICS_F);
    // relu(1·0.5·D) summed over B: each feature = B * D * 0.5.
    let expect = (shapes::ANALYTICS_B * shapes::ANALYTICS_D) as f32 * 0.5;
    for &f in &feats {
        assert!((f - expect).abs() < expect * 1e-5, "{f} vs {expect}");
    }
    let sum: f32 = feats.iter().sum();
    assert!((checksum - sum).abs() < sum.abs() * 1e-5);
}

#[test]
fn uvar_matches_rust_model() {
    let mut s = suite();
    // Mixed per-processor mean task times.
    let tp: Vec<f64> = (0..1408).map(|i| 1.0 + (i % 60) as f64).collect();
    let t_s = 2.2;
    let got = s.u_variable(&tp, t_s).unwrap();
    let want = sssched::model::u_variable(t_s, &tp);
    assert!(
        (got - want).abs() < 1e-4,
        "pjrt U_v={got} vs rust {want}"
    );
}

#[test]
fn uvar_uniform_reduces_to_constant_model() {
    let mut s = suite();
    let tp = vec![5.0; 100];
    let got = s.u_variable(&tp, 2.2).unwrap();
    let want = sssched::model::u_constant_approx(2.2, 5.0);
    assert!((got - want).abs() < 1e-5, "{got} vs {want}");
}

#[test]
fn uvar_validates_variable_task_time_simulation() {
    // Section 4's claim, end to end: simulate a variable-duration
    // workload, compute U_v from the per-processor mean task times via
    // the PJRT kernel, compare with the sim's measured utilization.
    use sssched::cluster::ClusterSpec;
    use sssched::config::SchedulerChoice;
    use sssched::sched::{make_scheduler, RunOptions};
    use sssched::workload::{TaskTimeDist, WorkloadBuilder};

    let cluster = ClusterSpec::homogeneous(4, 8, 64 * 1024, 2);
    let sched = make_scheduler(SchedulerChoice::Slurm);
    let w = WorkloadBuilder::with_dist(TaskTimeDist::Lognormal { mean: 8.0, cv: 0.4 })
        .tasks(32 * 24)
        .seed(9)
        .build();
    let r = sched.run(&w, &cluster, 9, &RunOptions::with_trace());
    // Per-processor mean task time from the trace.
    let trace = r.trace.as_ref().unwrap();
    let mut sums = vec![0.0f64; r.processors as usize];
    let mut counts = vec![0u32; r.processors as usize];
    for rec in trace {
        sums[rec.slot as usize] += rec.end - rec.start;
        counts[rec.slot as usize] += 1;
    }
    let means: Vec<f64> = sums
        .iter()
        .zip(&counts)
        .filter(|(_, &c)| c > 0)
        .map(|(&s, &c)| s / c as f64)
        .collect();
    // Effective t_s of this sim from a constant-time probe.
    let probe = WorkloadBuilder::constant(8.0).tasks(32 * 24).build();
    let pr = sched.run(&probe, &cluster, 9, &RunOptions::default());
    let t_s_eff = (1.0 / pr.utilization() - 1.0) * 8.0;
    let mut s = suite();
    let u_v = s.u_variable(&means, t_s_eff).unwrap();
    assert!(
        (u_v - r.utilization()).abs() < 0.10,
        "U_v model {u_v:.3} vs measured {:.3}",
        r.utilization()
    );
}
