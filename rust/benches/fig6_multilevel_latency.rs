//! Bench: regenerate Figure 6 — ΔT vs n with multilevel scheduling
//! (LLMapReduce) on Slurm / Grid Engine / Mesos, including the ΔT
//! reduction factors at the largest n.

use sssched::config::ExperimentConfig;
use sssched::harness::fig6;
use sssched::multilevel::MultilevelParams;
use std::time::Instant;

fn main() {
    let mut cfg = ExperimentConfig::default();
    if std::env::var("SSSCHED_QUICK").is_ok() {
        cfg.scale_down = 8;
        cfg.trials = 1;
    }
    let t0 = Instant::now();
    let rep = fig6(&cfg, &MultilevelParams::default());
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", rep.render_plots());
    println!("{}", rep.render_table().render());
    std::fs::create_dir_all("out").ok();
    if std::fs::write("out/fig6.csv", rep.render_table().to_csv()).is_ok() {
        println!("series written to out/fig6.csv");
    }
    println!("bench: {wall:.2}s wall");
    match rep.check_shape() {
        Ok(()) => println!(
            "shape vs paper: OK (multilevel ΔT bounded; ≥10x reduction at max n — paper: 30x/40x/100x)"
        ),
        Err(e) => {
            println!("shape vs paper: FAIL — {e}");
            std::process::exit(1);
        }
    }
}
