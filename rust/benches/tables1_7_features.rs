//! Bench: regenerate the paper's feature-comparison Tables 1–7 from the
//! feature database and verify the paper's headline observations hold.

use sssched::features::{all_features, feature_table, FeatureCategory, SchedulerInfo};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    for cat in FeatureCategory::all() {
        println!("{}", feature_table(cat).render());
    }
    // §3.4 summary observations, checked from the data:
    let rows = all_features();
    let hpc: Vec<usize> = SchedulerInfo::all()
        .iter()
        .enumerate()
        .filter(|(_, s)| s.family() == "HPC" && **s != SchedulerInfo::Pacora)
        .map(|(i, _)| i)
        .collect();
    let bd: Vec<usize> = SchedulerInfo::all()
        .iter()
        .enumerate()
        .filter(|(_, s)| s.family() == "Big Data")
        .map(|(i, _)| i)
        .collect();
    let common = [
        "Timesharing",
        "Resource heterogeneity",
        "Resource allocation policy",
        "Prioritization schema",
        "Job restarting",
    ];
    for name in common {
        let row = rows.iter().find(|r| r.name == name).unwrap();
        let all = hpc.iter().chain(&bd).all(|&i| row.values[i].supported());
        assert!(all, "`{name}` should be common across production schedulers");
        println!("common feature confirmed: {name}");
    }
    let hpc_only = ["Backfilling", "Checkpointing", "Data movement / file staging", "Network-aware scheduling"];
    for name in hpc_only {
        let row = rows.iter().find(|r| r.name == name).unwrap();
        let none_bd = bd.iter().all(|&i| !row.values[i].supported());
        assert!(none_bd, "`{name}` should be HPC-only");
        println!("HPC-only feature confirmed: {name}");
    }
    println!(
        "\nbench: rendered 7 tables × 8 schedulers in {:.3} ms; §3.4 observations hold",
        t0.elapsed().as_secs_f64() * 1e3
    );
}
