//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! A1. Job-array vs individual-job submission (paper §5.2: arrays
//!     "introduce much less scheduler latency").
//! A2. Scheduling-cycle interval sensitivity (Slurm-like).
//! A3. Allocator offer-interval sensitivity (Mesos-like).
//! A4. AM-startup sensitivity (YARN-like; am→1.5 s models an Apache
//!     Llama-style low-latency application master, §3.1.4).
//! A5. Centralized vs Sparrow-like distributed scheduling on the rapid
//!     set (§3.2.6 centralized-vs-distributed trade-off).
//! A6. FCFS vs EASY-backfill on a mixed parallel workload (§3.2.3).
//! A7. On-demand responsiveness: mean wait vs offered load under
//!     Poisson arrivals (§1 interactive vs batch discussion).

use sssched::cluster::ClusterSpec;
use sssched::config::SchedulerChoice;
use sssched::sched::batchq::{BatchJob, BatchQueueSim, QueuePolicy};
use sssched::sched::sparrow::{SparrowParams, SparrowSim};
use sssched::sched::{calibration, centralized::CentralizedSim, make_scheduler, mesos::MesosSim, yarn::YarnSim, RunOptions, Scheduler};
use sssched::util::prng::Prng;
use sssched::util::table::{fnum, Table};
use sssched::workload::{ArrivalProcess, WorkloadBuilder};

fn cluster() -> ClusterSpec {
    // 8 nodes × 32 = 256 cores: ablations isolate mechanisms, the
    // full-scale numbers live in the table9/fig benches.
    ClusterSpec::homogeneous(8, 32, 64 * 1024, 4)
}

fn main() {
    let c = cluster();
    let p = c.total_cores();

    // ---- A1: array vs individual submission.
    let mut t = Table::new(
        "A1: job-array vs individual submission (Slurm-like, n=8, t=30s)",
        &["mode", "T_total (s)", "ΔT (s)", "U"],
    );
    let sched = make_scheduler(SchedulerChoice::Slurm);
    let w = WorkloadBuilder::constant(30.0).tasks(8 * p).label("a1").build();
    for (mode, opts) in [
        ("array", RunOptions::default()),
        (
            "individual",
            RunOptions {
                individual_submission: true,
                ..Default::default()
            },
        ),
    ] {
        let r = sched.run(&w, &c, 1, &opts);
        t.row(&[
            mode.into(),
            fnum(r.t_total),
            fnum(r.delta_t()),
            format!("{:.3}", r.utilization()),
        ]);
    }
    println!("{}", t.render());

    // ---- A2: cycle-interval sensitivity.
    let mut t = Table::new(
        "A2: scheduling-cycle interval (Slurm-like, n=8, t=30s)",
        &["cycle (s)", "ΔT (s)", "U"],
    );
    for cycle in [0.25, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let mut params = calibration::slurm_params();
        params.cycle_interval = cycle;
        let sim = CentralizedSim::new(params);
        let r = sim.run(&w, &c, 2, &RunOptions::default());
        t.row(&[fnum(cycle), fnum(r.delta_t()), format!("{:.3}", r.utilization())]);
    }
    println!("{}", t.render());

    // ---- A3: offer-interval sensitivity.
    let mut t = Table::new(
        "A3: allocator offer interval (Mesos-like, n=8, t=30s)",
        &["offer interval (s)", "ΔT (s)", "U"],
    );
    for interval in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let mut params = calibration::mesos_params();
        params.offer_interval = interval;
        let sim = MesosSim::new(params);
        let r = sim.run(&w, &c, 3, &RunOptions::default());
        t.row(&[fnum(interval), fnum(r.delta_t()), format!("{:.3}", r.utilization())]);
    }
    println!("{}", t.render());

    // ---- A4: AM-startup sensitivity (Llama-style low-latency AM).
    let mut t = Table::new(
        "A4: ApplicationMaster startup (YARN-like, n=48, t=5s)",
        &["AM startup (s)", "T_total (s)", "U"],
    );
    let wf = WorkloadBuilder::constant(5.0).tasks(48 * p).label("a4").build();
    for am in [31.0, 15.0, 5.0, 1.5] {
        let mut params = calibration::yarn_params();
        params.am_startup_mean = am;
        let sim = YarnSim::new(params);
        let r = sim.run(&wf, &c, 4, &RunOptions::default());
        t.row(&[fnum(am), fnum(r.t_total), format!("{:.3}", r.utilization())]);
    }
    println!("{}", t.render());
    println!("(am=1.5 s ~ Apache Llama low-latency AM: recovers most of the lost utilization)\n");

    // ---- A5: centralized vs distributed on the rapid set.
    let mut t = Table::new(
        "A5: centralized vs Sparrow-like distributed (n=240, t=1s)",
        &["scheduler", "T_total (s)", "ΔT (s)", "U", "daemon busy (s)"],
    );
    let wr = WorkloadBuilder::constant(1.0).tasks(240 * p).label("a5").build();
    for sched in [
        make_scheduler(SchedulerChoice::Slurm),
        Box::new(SparrowSim::new(SparrowParams::default())) as Box<dyn Scheduler>,
    ] {
        let r = sched.run(&wr, &c, 5, &RunOptions::default());
        t.row(&[
            sched.name().into(),
            fnum(r.t_total),
            fnum(r.delta_t()),
            format!("{:.3}", r.utilization()),
            fnum(r.daemon_busy),
        ]);
    }
    println!("{}", t.render());

    // ---- A6: FCFS vs backfill on a mixed parallel workload.
    let mut rng = Prng::new(0xAB6);
    let jobs: Vec<BatchJob> = (0..300)
        .map(|id| BatchJob {
            id,
            user: id % 5,
            cores: [1, 1, 2, 4, 8, 16, 64][rng.choose_index(7)],
            duration: rng.range_f64(10.0, 600.0),
            priority: 0,
            submit_at: 0.0,
        })
        .collect();
    let mut t = Table::new(
        "A6: queue policy on a mixed parallel workload (300 jobs, 256 cores)",
        &["policy", "makespan (s)", "U", "mean wait (s)", "max wait (s)"],
    );
    for (name, policy) in [
        ("FCFS", QueuePolicy::Fcfs),
        ("FCFS+backfill", QueuePolicy::FcfsBackfill),
        ("Fairshare", QueuePolicy::Fairshare),
    ] {
        let r = BatchQueueSim::new(policy).run(&jobs, &c).unwrap();
        t.row(&[
            name.into(),
            fnum(r.makespan),
            format!("{:.3}", r.utilization),
            fnum(r.waits.mean()),
            fnum(r.waits.max()),
        ]);
    }
    println!("{}", t.render());

    // ---- A7: on-demand responsiveness under Poisson arrivals.
    let mut t = Table::new(
        "A7: mean wait vs offered load (Slurm-like, Poisson arrivals, t=30s)",
        &["offered load ρ", "arrival rate (t/s)", "mean wait (s)", "p~max wait (s)"],
    );
    for rho in [0.3, 0.6, 0.8, 0.9] {
        let rate = rho * p as f64 / 30.0;
        let mut wl = WorkloadBuilder::constant(30.0).tasks(8 * p).label("a7").build();
        ArrivalProcess::Poisson { rate }.apply(&mut wl, 7);
        let sched = make_scheduler(SchedulerChoice::Slurm);
        let r = sched.run(
            &wl,
            &c,
            7,
            &RunOptions {
                individual_submission: true, // on-demand jobs arrive one by one
                ..Default::default()
            },
        );
        t.row(&[
            format!("{rho:.1}"),
            fnum(rate),
            fnum(r.waits.mean()),
            fnum(r.waits.max()),
        ]);
    }
    println!("{}", t.render());
    println!("ablations complete");
}
