//! Perf bench: hot-path microbenchmarks feeding EXPERIMENTS.md §Perf.
//!
//! * event-queue throughput (push+pop)
//! * full scheduler-simulation events/s (the L3 hot path)
//! * realtime coordinator dispatch rate (channel round-trip)
//! * PJRT power-law fit latency (the L1/L2 hot path from rust)

use sssched::cluster::ClusterSpec;
use sssched::config::SchedulerChoice;
use sssched::exec::{RealtimeCoordinator, RealtimeParams, RtTask, RtWork};
use sssched::sched::{make_scheduler, RunOptions};
use sssched::sim::EventQueue;
use sssched::workload::WorkloadBuilder;
use std::time::Instant;

fn main() {
    // ---- 1. Raw event queue.
    let n = 2_000_000u64;
    let t0 = Instant::now();
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut acc = 0u64;
    for i in 0..n {
        // Jittered future times (respecting the causality guard).
        q.push(q.now() + (i % 100) as f64 * 0.01, i);
        if i % 4 == 3 {
            acc = acc.wrapping_add(q.pop().unwrap().1);
        }
    }
    while let Some((_, v)) = q.pop() {
        acc = acc.wrapping_add(v);
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "event queue: {:.2}M push+pop/s (checksum {acc})",
        2.0 * n as f64 / dt / 1e6
    );

    // ---- 2. Scheduler sims, events/s.
    let cluster = ClusterSpec::supercloud();
    for choice in [
        SchedulerChoice::Slurm,
        SchedulerChoice::Mesos,
        SchedulerChoice::Yarn,
        SchedulerChoice::IdealFifo,
    ] {
        let sched = make_scheduler(choice);
        let w = WorkloadBuilder::constant(5.0)
            .tasks(48 * cluster.total_cores())
            .label("bench")
            .build();
        let t0 = Instant::now();
        let r = sched.run(&w, &cluster, 1, &RunOptions::default());
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<12} sim: {:>7} tasks, {:>8} events in {:.3}s = {:.2}M events/s ({:.0}x realtime)",
            sched.name(),
            r.n_tasks,
            r.events,
            dt,
            r.events as f64 / dt / 1e6,
            r.t_total / dt,
        );
    }

    // ---- 3. Realtime dispatch rate (zero-work tasks).
    let coord = RealtimeCoordinator::new(RealtimeParams {
        workers: 2,
        dispatch_overhead: 0.0,
        artifacts_dir: None,
    });
    let tasks: Vec<RtTask> = (0..20_000)
        .map(|id| RtTask {
            id,
            nominal: 0.0,
            work: RtWork::Spin(0.0),
        })
        .collect();
    let t0 = Instant::now();
    let r = coord.run(&tasks).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "realtime coordinator: {:.0} dispatches/s ({} tasks in {:.3}s)",
        r.n_tasks as f64 / dt,
        r.n_tasks,
        dt
    );

    // ---- 4. PJRT fit latency.
    match sssched::runtime::ArtifactSuite::load("artifacts") {
        Ok(mut suite) => {
            let series: Vec<Vec<(f64, f64)>> = (0..4)
                .map(|s| {
                    (0..16)
                        .map(|k| {
                            let n = 2f64.powi(k % 8);
                            (n, (2.0 + s as f64) * n.powf(1.2))
                        })
                        .collect()
                })
                .collect();
            // Warmup + timed.
            let _ = suite.powerlaw_fit(&series).unwrap();
            let iters = 200;
            let t0 = Instant::now();
            for _ in 0..iters {
                let _ = suite.powerlaw_fit(&series).unwrap();
            }
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "pjrt powerlaw_fit: {:.3} ms/call (4 series x 16 pts, {iters} iters)",
                dt / iters as f64 * 1e3
            );
        }
        Err(_) => println!("pjrt fit: artifacts missing (run `make artifacts`)"),
    }
}
