//! Perf bench: hot-path microbenchmarks + the sweep-executor
//! throughput benchmark feeding README §Performance and the
//! `BENCH_perf.json` trajectory artifact.
//!
//! * event-queue throughput (push+pop)
//! * full scheduler-simulation events/s (the L3 hot path)
//! * preemption-heavy kernel loop: evictions/s + warm events/s, with a
//!   counting-allocator assert that warm-scratch preemption runs stay
//!   allocation-flat (per-run allocations are a small constant that
//!   does not scale with workload size — nothing allocates on the
//!   evict/requeue/resume hot path after warmup)
//! * fault-churn kernel loop: kills/s + warm events/s under a rolling
//!   node-outage plan, with the same flat-allocation assert on the
//!   retire/kill/requeue/restore path (`churn_mevents_per_s` in
//!   BENCH_perf.json)
//! * degraded-control-plane kernel loop: warm events/s with heartbeat
//!   detection, an active `MessagePlan` (latency + loss + duplication)
//!   and speculation all armed, with the same flat-allocation assert
//!   on the heartbeat/suspect/defer/backoff/speculate path
//!   (`degraded_mevents_per_s` in BENCH_perf.json)
//! * indexed-queue scale sweep: warm events/s per (scheduler, n) up to
//!   n = 100k — including the node-granular and sharded engine rows —
//!   the fitted log-log wall-time exponent, the eager-sort vs
//!   incremental ordered-queue speedup (asserted ≥ 5×, bit-identical),
//!   a flat-allocation assert at the largest n, and the engine rows'
//!   Mevents/s floor (`harness::SCALE_MEVENTS_FLOOR`)
//! * streaming-metrics memory gate: a warm untraced run's transient
//!   byte peak is O(active) — bounded, independent of n — while the
//!   exact traced oracle necessarily peaks at O(n) trace bytes
//! * realtime coordinator dispatch rate (channel round-trip)
//! * artifact-suite power-law fit latency (the L1/L2 hot path from rust)
//! * serial vs parallel fig4-style sweep: cells/s, events/s, wall-clock
//!   speedup, and a bit-identity check between `jobs=1` and `jobs=N`
//!
//! Usage: `cargo bench --bench perf_engine -- [--quick] [--jobs N]
//! [--bench-out FILE]` (default out: BENCH_perf.json in the working
//! dir; `--out` is accepted as a legacy alias).

use sssched::cluster::{ClusterSpec, FaultPlan, MessagePlan};
use sssched::config::{ExperimentConfig, SchedulerChoice};
use sssched::exec::{RealtimeCoordinator, RealtimeParams, RtTask, RtWork};
use sssched::harness::{
    run_sweeps, scale_array_workload, scale_cluster as scale_cluster_of, scale_preempt_workload,
    SchedulerSweep, SweepSpec, SCALE_MEVENTS_FLOOR, SCALE_SHARDS,
};
use sssched::sched::combinators::{make_preemptive, Order, OrderedSim};
use sssched::sched::{
    make_scheduler, NodeGranularSim, RunOptions, Scheduler, ShardedSim, SimScratch,
};
use sssched::sim::EventQueue;
use sssched::util::fit::fit_power_law;
use sssched::workload::{TaskSpec, Workload};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::time::Instant;

/// Allocation-counting wrapper around the system allocator, used to
/// assert the warm-scratch preemption path allocates nothing per event
/// and that the streaming-metrics path keeps transient memory O(active).
/// Counting is flag-gated so the timed benchmarks elsewhere in this
/// binary pay only a relaxed load per allocation, not a shared atomic
/// RMW that could skew the published sweep numbers; it is switched on
/// only around the flatness/peak measurements. Tracks the allocation
/// count (frees are irrelevant to the zero-alloc contract) plus net
/// live bytes and their high-water mark (frees matter there).
struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);
static ALLOC_CURRENT: AtomicI64 = AtomicI64::new(0);
static ALLOC_PEAK: AtomicI64 = AtomicI64::new(0);

/// Record `delta` net bytes (and, for allocating calls, one count).
fn track(delta: i64, count: bool) {
    if count {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
    }
    let cur = ALLOC_CURRENT.fetch_add(delta, Ordering::Relaxed) + delta;
    ALLOC_PEAK.fetch_max(cur, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            track(layout.size() as i64, true);
        }
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            track(layout.size() as i64, true);
        }
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            track(new_size as i64 - layout.size() as i64, true);
        }
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        if COUNTING.load(Ordering::Relaxed) {
            track(-(layout.size() as i64), false);
        }
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

/// Zero the net-bytes ledger so the next measurement window reads peak
/// *growth* relative to its start.
fn reset_byte_ledger() {
    ALLOC_CURRENT.store(0, Ordering::Relaxed);
    ALLOC_PEAK.store(0, Ordering::Relaxed);
}

fn peak_bytes() -> i64 {
    ALLOC_PEAK.load(Ordering::Relaxed).max(0)
}

/// Process-lifetime peak resident set (VmHWM) in KiB, when the
/// platform exposes it (Linux /proc; `None` elsewhere).
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("VmHWM:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

struct SweepStats {
    wall_s: f64,
    cells: u64,
    events: u64,
}

fn sweep_stats(sweeps: &[SchedulerSweep], wall_s: f64) -> SweepStats {
    let mut cells = 0u64;
    let mut events = 0u64;
    for s in sweeps {
        for p in &s.points {
            cells += p.trials.len() as u64;
            events += p.trials.iter().map(|r| r.events).sum::<u64>();
        }
    }
    SweepStats {
        wall_s,
        cells,
        events,
    }
}

/// Bitwise comparison of two sweep batches (the `jobs` invariance the
/// executor promises).
fn assert_bit_identical(a: &[SchedulerSweep], b: &[SchedulerSweep]) {
    assert_eq!(a.len(), b.len(), "sweep count differs");
    for (sa, sb) in a.iter().zip(b) {
        assert_eq!(sa.scheduler, sb.scheduler);
        assert_eq!(sa.skipped, sb.skipped, "{}: skipped differ", sa.scheduler);
        assert_eq!(sa.points.len(), sb.points.len());
        for (pa, pb) in sa.points.iter().zip(&sb.points) {
            assert_eq!(pa.n, pb.n);
            assert_eq!(pa.trials.len(), pb.trials.len());
            for (ra, rb) in pa.trials.iter().zip(&pb.trials) {
                assert_eq!(
                    ra.t_total.to_bits(),
                    rb.t_total.to_bits(),
                    "{} n={}: t_total {} vs {}",
                    sa.scheduler,
                    pa.n,
                    ra.t_total,
                    rb.t_total
                );
                assert_eq!(ra.events, rb.events, "{} n={}: events", sa.scheduler, pa.n);
                assert_eq!(ra.daemon_busy.to_bits(), rb.daemon_busy.to_bits());
                assert_eq!(ra.waits.count(), rb.waits.count());
                assert_eq!(ra.waits.mean().to_bits(), rb.waits.mean().to_bits());
            }
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let opt = |name: &str| -> Option<String> {
        argv.iter()
            .position(|a| a == name)
            .and_then(|i| argv.get(i + 1))
            .cloned()
    };
    let par_jobs: u32 = opt("--jobs").and_then(|v| v.parse().ok()).unwrap_or(4);
    let out_path = opt("--bench-out")
        .or_else(|| opt("--out"))
        .unwrap_or_else(|| "BENCH_perf.json".to_string());

    // ---- 1. Raw event queue.
    let n = if quick { 500_000u64 } else { 2_000_000u64 };
    let t0 = Instant::now();
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut acc = 0u64;
    for i in 0..n {
        // Jittered future times (respecting the causality guard).
        q.push(q.now() + (i % 100) as f64 * 0.01, i);
        if i % 4 == 3 {
            acc = acc.wrapping_add(q.pop().unwrap().1);
        }
    }
    while let Some((_, v)) = q.pop() {
        acc = acc.wrapping_add(v);
    }
    let queue_mops = 2.0 * n as f64 / t0.elapsed().as_secs_f64() / 1e6;
    println!("event queue: {queue_mops:.2}M push+pop/s (checksum {acc})");

    // ---- 2. Scheduler sims, events/s.
    let cluster = if quick {
        ClusterSpec::homogeneous(11, 32, 64 * 1024, 11)
    } else {
        ClusterSpec::supercloud()
    };
    let mut sim_rates: Vec<(String, f64)> = Vec::new();
    for choice in [
        SchedulerChoice::Slurm,
        SchedulerChoice::Mesos,
        SchedulerChoice::Yarn,
        SchedulerChoice::IdealFifo,
    ] {
        let sched = make_scheduler(choice);
        let w = sssched::workload::WorkloadBuilder::constant(5.0)
            .tasks(48 * cluster.total_cores())
            .label("bench")
            .build();
        let t0 = Instant::now();
        let r = sched.run(&w, &cluster, 1, &RunOptions::default());
        let dt = t0.elapsed().as_secs_f64();
        let rate = r.events as f64 / dt / 1e6;
        println!(
            "{:<12} sim: {:>7} tasks, {:>8} events in {:.3}s = {:.2}M events/s ({:.0}x realtime)",
            sched.name(),
            r.n_tasks,
            r.events,
            dt,
            rate,
            r.t_total / dt,
        );
        sim_rates.push((sched.name().to_string(), rate));
    }

    // ---- 2b. Kernel-loop events/s on the warm-scratch path.
    //
    // Since the unified-kernel refactor every backend runs its events
    // through `sim::Kernel` + `SchedPolicy` hooks; this isolates the
    // steady-state loop (repeated trials, reused scratch) so the
    // BENCH_perf.json trajectory tracks that the policy indirection
    // stays within noise (<5%) of the pre-refactor per-backend loops
    // (compare `kernel_warm_mevents_per_s` across commits).
    let kernel_warm_rate = {
        let sched = make_scheduler(SchedulerChoice::Slurm);
        let w = sssched::workload::WorkloadBuilder::constant(5.0)
            .tasks(24 * cluster.total_cores())
            .label("kernel-bench")
            .build();
        let mut scratch = SimScratch::new();
        // Warm-up run sizes every buffer.
        let warm = sched.run_with_scratch(&w, &cluster, 0, &RunOptions::default(), &mut scratch);
        let iters = if quick { 3u64 } else { 8 };
        let t0 = Instant::now();
        let mut events = 0u64;
        for i in 0..iters {
            let r =
                sched.run_with_scratch(&w, &cluster, i + 1, &RunOptions::default(), &mut scratch);
            events += r.events;
        }
        let dt = t0.elapsed().as_secs_f64();
        let rate = events as f64 / dt / 1e6;
        println!(
            "kernel loop (warm scratch): {events} events over {iters} trials in {dt:.3}s \
             = {rate:.2}M events/s (warm-up run: {} events)",
            warm.events
        );
        rate
    };

    // ---- 2c. Preemption-heavy kernel loop (warm scratch): evictions/s
    // plus an allocation-flatness assert — after warmup, a preemption
    // run's allocations are a small per-run constant (policy setup +
    // result labels), independent of workload size: nothing allocates
    // on the evict/requeue/resume hot path.
    let preempt_bench_workload = |waves: u64| -> Workload {
        let cores = cluster.total_cores();
        let mut tasks: Vec<TaskSpec> = Vec::new();
        for i in 0..waves * cores {
            let mut t = TaskSpec::array(i as u32, i as u32, 5.0);
            t.preemptible = true;
            tasks.push(t);
        }
        for k in 0..cores / 2 {
            let id = (waves * cores + k) as u32;
            let mut t = TaskSpec::array(id, id, 1.0);
            t.priority = 10;
            t.submit_at = 0.5 + (k % 32) as f64 * 2.0;
            tasks.push(t);
        }
        Workload {
            tasks,
            label: "preempt-bench".into(),
        }
    };
    let (preempt_rate, preempt_evictions_per_s, preempt_allocs_per_run) = {
        let sched = make_preemptive(SchedulerChoice::Slurm, 1, Order::Priority);
        let big = preempt_bench_workload(16);
        let small = preempt_bench_workload(4);
        let mut scratch = SimScratch::new();
        // Warm-up on the big workload sizes every buffer.
        sched.run_with_scratch(&big, &cluster, 0, &RunOptions::default(), &mut scratch);
        let iters = if quick { 2u64 } else { 5 };
        let t0 = Instant::now();
        let mut events = 0u64;
        let mut evictions = 0u64;
        for i in 0..iters {
            let r = sched.run_with_scratch(
                &big,
                &cluster,
                i + 1,
                &RunOptions::default(),
                &mut scratch,
            );
            events += r.events;
            evictions += r.preemptions;
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(evictions > 0, "preemption bench executed no evictions");
        COUNTING.store(true, Ordering::Relaxed);
        let before_small = allocs();
        sched.run_with_scratch(&small, &cluster, 97, &RunOptions::default(), &mut scratch);
        let small_allocs = allocs() - before_small;
        let before_big = allocs();
        sched.run_with_scratch(&big, &cluster, 98, &RunOptions::default(), &mut scratch);
        let big_allocs = allocs() - before_big;
        COUNTING.store(false, Ordering::Relaxed);
        assert!(
            small_allocs < 512 && big_allocs < 512,
            "warm preemption run allocates per event: small={small_allocs} big={big_allocs}"
        );
        assert!(
            big_allocs <= small_allocs + 64 && small_allocs <= big_allocs + 64,
            "warm preemption allocations scale with workload size: \
             small={small_allocs} big={big_allocs}"
        );
        let rate = events as f64 / dt / 1e6;
        let eps = evictions as f64 / dt;
        println!(
            "preempt loop (warm scratch): {events} events, {evictions} evictions over \
             {iters} trials in {dt:.3}s = {rate:.2}M events/s, {eps:.0} evictions/s; \
             allocs/run small={small_allocs} big={big_allocs} (flat)"
        );
        (rate, eps, big_allocs)
    };

    // ---- 2d. Fault-churn kernel loop (warm scratch): events/s and
    // kills/s under a rolling node-outage plan, with the same
    // flat-allocation contract as the preemption loop — after warmup
    // nothing on the retire / kill / requeue / restore hot path
    // allocates.
    let (churn_rate, churn_kills_per_s, churn_allocs_per_run) = {
        let sched = make_scheduler(SchedulerChoice::Slurm);
        let n_nodes = cluster.nodes.len() as u32;
        let mut plan = FaultPlan::none();
        for k in 0..n_nodes.min(8) {
            plan = plan
                .fail(4.0 + 4.0 * k as f64, k)
                .recover(6.0 + 4.0 * k as f64, k);
        }
        plan.validate().expect("bench fault plan");
        let opts = RunOptions {
            faults: plan,
            ..Default::default()
        };
        let churn_workload = |waves: u64| {
            sssched::workload::WorkloadBuilder::constant(5.0)
                .tasks(waves * cluster.total_cores())
                .label("churn-bench")
                .build()
        };
        let big = churn_workload(16);
        let small = churn_workload(4);
        let mut scratch = SimScratch::new();
        // Warm-up run sizes every buffer, fault machinery included.
        sched.run_with_scratch(&big, &cluster, 0, &opts, &mut scratch);
        let iters = if quick { 2u64 } else { 5 };
        let t0 = Instant::now();
        let mut events = 0u64;
        let mut kills = 0u64;
        for i in 0..iters {
            let r = sched.run_with_scratch(&big, &cluster, i + 1, &opts, &mut scratch);
            events += r.events;
            kills += r.kills;
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(kills > 0, "churn bench executed no kills");
        COUNTING.store(true, Ordering::Relaxed);
        let before_small = allocs();
        sched.run_with_scratch(&small, &cluster, 97, &opts, &mut scratch);
        let small_allocs = allocs() - before_small;
        let before_big = allocs();
        sched.run_with_scratch(&big, &cluster, 98, &opts, &mut scratch);
        let big_allocs = allocs() - before_big;
        COUNTING.store(false, Ordering::Relaxed);
        assert!(
            small_allocs < 512 && big_allocs < 512,
            "warm churn run allocates per event: small={small_allocs} big={big_allocs}"
        );
        assert!(
            big_allocs <= small_allocs + 64 && small_allocs <= big_allocs + 64,
            "warm churn allocations scale with workload size: \
             small={small_allocs} big={big_allocs}"
        );
        let rate = events as f64 / dt / 1e6;
        let kps = kills as f64 / dt;
        println!(
            "churn loop (warm scratch): {events} events, {kills} kills over {iters} trials \
             in {dt:.3}s = {rate:.2}M events/s, {kps:.0} kills/s; allocs/run \
             small={small_allocs} big={big_allocs} (flat)"
        );
        (rate, kps, big_allocs)
    };

    // ---- 2d-bis. Degraded-control-plane kernel loop (warm scratch):
    // events/s with heartbeat detection, an active message plan
    // (latency + loss + duplication) and speculation all armed at
    // once, plus the same flat-allocation contract — after warmup
    // nothing on the heartbeat/suspect/deferred-End/backoff/speculate
    // hot path allocates.
    let (degraded_rate, degraded_allocs_per_run) = {
        let sched = make_scheduler(SchedulerChoice::Slurm);
        let n_nodes = cluster.nodes.len() as u32;
        let mut plan = FaultPlan::none();
        for k in 0..n_nodes.min(8) {
            plan = plan
                .fail(4.0 + 4.0 * k as f64, k)
                .recover(8.0 + 4.0 * k as f64, k);
        }
        plan.validate().expect("bench fault plan");
        let messages = MessagePlan::seeded(0xBE9C)
            .with_latency(0.02, 0.02, 0.01)
            .with_loss(0.05, 0.05, 0.4, 3)
            .with_duplication(0.05);
        messages.validate().expect("bench message plan");
        let opts = RunOptions {
            faults: plan,
            ..Default::default()
        }
        .messages(messages)
        .detection(1.0, 0.5)
        .speculation(3.0);
        let degraded_workload = |waves: u64| {
            let mut w = sssched::workload::WorkloadBuilder::constant(5.0)
                .tasks(waves * cluster.total_cores())
                .label("degraded-bench")
                .build();
            // Sparse stragglers keep the speculation path live.
            for t in &mut w.tasks {
                if t.id % 100 == 50 {
                    t.duration = 25.0;
                }
            }
            w
        };
        let big = degraded_workload(16);
        let small = degraded_workload(4);
        let mut scratch = SimScratch::new();
        // Warm-up run sizes every buffer, degraded machinery included.
        sched.run_with_scratch(&big, &cluster, 0, &opts, &mut scratch);
        let iters = if quick { 2u64 } else { 5 };
        let t0 = Instant::now();
        let mut events = 0u64;
        let mut perturbed = 0u64;
        for i in 0..iters {
            let r = sched.run_with_scratch(&big, &cluster, i + 1, &opts, &mut scratch);
            events += r.events;
            perturbed += r.messages_lost
                + r.messages_duplicated
                + r.spec_launches
                + r.detection_latencies.len() as u64;
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(
            perturbed > 0,
            "degraded bench never perturbed the control plane"
        );
        COUNTING.store(true, Ordering::Relaxed);
        let before_small = allocs();
        sched.run_with_scratch(&small, &cluster, 97, &opts, &mut scratch);
        let small_allocs = allocs() - before_small;
        let before_big = allocs();
        sched.run_with_scratch(&big, &cluster, 98, &opts, &mut scratch);
        let big_allocs = allocs() - before_big;
        COUNTING.store(false, Ordering::Relaxed);
        assert!(
            small_allocs < 512 && big_allocs < 512,
            "warm degraded run allocates per event: small={small_allocs} big={big_allocs}"
        );
        assert!(
            big_allocs <= small_allocs + 64 && small_allocs <= big_allocs + 64,
            "warm degraded allocations scale with workload size: \
             small={small_allocs} big={big_allocs}"
        );
        let rate = events as f64 / dt / 1e6;
        println!(
            "degraded loop (warm scratch): {events} events, {perturbed} perturbations over \
             {iters} trials in {dt:.3}s = {rate:.2}M events/s; allocs/run \
             small={small_allocs} big={big_allocs} (flat)"
        );
        (rate, big_allocs)
    };

    // ---- 2e. Indexed-queue scale sweep (the `scale` experiment's
    // bench-side mirror): warm-scratch events/s per (scheduler, n), the
    // fitted log-log wall-time-vs-n exponent, the eager-sort vs
    // incremental ordered-queue speedup (asserted ≥ 5×, bit-identical),
    // and a flag-gated counting-allocator assert that warm runs at the
    // largest n stay flat-allocation.
    let scale_ns: Vec<u32> = if quick {
        vec![2_000, 8_000, 32_000]
    } else {
        vec![10_000, 50_000, 100_000]
    };
    let scale_procs: u32 = 1_000;
    // Shared with the `scale` experiment so the bench mirrors the exact
    // cluster shape the experiment measures.
    let scale_cluster = scale_cluster_of(scale_procs);
    let scale_rows: Vec<Box<dyn Scheduler>> = vec![
        make_scheduler(SchedulerChoice::Slurm),
        make_scheduler(SchedulerChoice::Sparrow),
        make_scheduler(SchedulerChoice::IdealFifo),
        Box::new(OrderedSim::new(
            make_scheduler(SchedulerChoice::IdealFifo),
            Order::Priority,
            "IdealFIFO+prio",
        )),
        make_preemptive(SchedulerChoice::IdealFifo, 1, Order::Priority),
        Box::new(NodeGranularSim::new(
            make_scheduler(SchedulerChoice::IdealFifo),
            "IdealFIFO+node",
        )),
        Box::new(ShardedSim::new(
            make_scheduler(SchedulerChoice::IdealFifo),
            SCALE_SHARDS,
            SCALE_SHARDS,
            "IdealFIFO+shard4",
        )),
    ];
    let mut scale_cells: Vec<(String, u32, f64, u64)> = Vec::new(); // (name, n, wall, events)
    let mut scale_exponents: Vec<(String, f64, f64)> = Vec::new(); // (name, alpha, r2)
    for sched in &scale_rows {
        let name = sched.name().to_string();
        let preemptive = name.ends_with("+preempt");
        let mut scratch = SimScratch::new();
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        for &n in &scale_ns {
            let w = if preemptive {
                scale_preempt_workload(n, scale_procs)
            } else {
                scale_array_workload(n)
            };
            // Warm-up sizes the buffers; the timed run is steady-state.
            sched.run_with_scratch(&w, &scale_cluster, 7, &RunOptions::default(), &mut scratch);
            let t0 = Instant::now();
            let r =
                sched.run_with_scratch(&w, &scale_cluster, 7, &RunOptions::default(), &mut scratch);
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            println!(
                "scale {:<20} n={:>6}: {:>8} events in {:.4}s = {:.2}M events/s",
                name,
                n,
                r.events,
                wall,
                r.events as f64 / wall / 1e6
            );
            xs.push(n as f64);
            ys.push(wall);
            scale_cells.push((name.clone(), n, wall, r.events));
        }
        let fit = fit_power_law(&xs, &ys);
        println!("scale {name:<20} wall-time exponent alpha={:.3} (R²={:.3})", fit.alpha_s, fit.r2);
        scale_exponents.push((name, fit.alpha_s, fit.r2));
    }

    // Engine-row throughput floor at the largest n (mirrors the
    // `scale` experiment's check_shape gate).
    let scale_max_n = *scale_ns.last().expect("non-empty scale_ns");
    for (name, n, wall, events) in &scale_cells {
        let floored =
            name == "IdealFIFO" || name == "IdealFIFO+node" || name == "IdealFIFO+shard4";
        if *n == scale_max_n && floored {
            let rate = *events as f64 / wall.max(1e-9) / 1e6;
            assert!(
                rate >= SCALE_MEVENTS_FLOOR,
                "{name} n={n}: {rate:.3} Mev/s under the {SCALE_MEVENTS_FLOOR} floor \
                 ({events} events in {wall:.3} s)"
            );
        }
    }

    // Eager-sort oracle vs incremental ordered queue: bit-identical
    // results, and the wall-clock speedup the de-quadratized queue buys.
    let speedup_n: u32 = if quick { 8_000 } else { 50_000 };
    let (ordered_speedup, ordered_eager_wall, ordered_incr_wall) = {
        let w = scale_array_workload(speedup_n);
        let incr = OrderedSim::new(
            make_scheduler(SchedulerChoice::IdealFifo),
            Order::Priority,
            "IdealFIFO+prio",
        );
        let eager = OrderedSim::new_eager(
            make_scheduler(SchedulerChoice::IdealFifo),
            Order::Priority,
            "IdealFIFO+prio",
        );
        let time_one = |s: &OrderedSim| {
            let mut scratch = SimScratch::new();
            s.run_with_scratch(&w, &scale_cluster, 3, &RunOptions::default(), &mut scratch);
            let t0 = Instant::now();
            let r = s.run_with_scratch(&w, &scale_cluster, 3, &RunOptions::default(), &mut scratch);
            (t0.elapsed().as_secs_f64().max(1e-9), r)
        };
        let (wi, ri) = time_one(&incr);
        let (we, re) = time_one(&eager);
        assert_eq!(
            ri.t_total.to_bits(),
            re.t_total.to_bits(),
            "incremental ordered queue diverged from the eager-sort oracle"
        );
        assert_eq!(ri.events, re.events, "ordered event counts diverged");
        let speedup = we / wi;
        println!(
            "ordered queue @ n={speedup_n}: eager sort {we:.3}s vs incremental {wi:.3}s \
             = {speedup:.1}x speedup (bit-identical: yes)"
        );
        assert!(
            speedup >= 5.0,
            "incremental ordered queue speedup {speedup:.2}x below the 5x floor at n={speedup_n}"
        );
        (speedup, we, wi)
    };

    // Flat-allocation assert at the largest n: a warm ordered run's
    // allocation count is a small per-run constant, independent of n.
    let (scale_allocs_small, scale_allocs_big) = {
        let small = scale_array_workload(scale_ns[0]);
        let big = scale_array_workload(*scale_ns.last().expect("non-empty scale_ns"));
        let sched = OrderedSim::new(
            make_scheduler(SchedulerChoice::IdealFifo),
            Order::Priority,
            "IdealFIFO+prio",
        );
        let mut scratch = SimScratch::new();
        // Warm on the big shape so every buffer reaches its max size.
        sched.run_with_scratch(&big, &scale_cluster, 11, &RunOptions::default(), &mut scratch);
        COUNTING.store(true, Ordering::Relaxed);
        let before_small = allocs();
        sched.run_with_scratch(&small, &scale_cluster, 12, &RunOptions::default(), &mut scratch);
        let small_allocs = allocs() - before_small;
        let before_big = allocs();
        sched.run_with_scratch(&big, &scale_cluster, 13, &RunOptions::default(), &mut scratch);
        let big_allocs = allocs() - before_big;
        COUNTING.store(false, Ordering::Relaxed);
        assert!(
            small_allocs < 512 && big_allocs < 512,
            "warm scale run allocates per event: small={small_allocs} big={big_allocs}"
        );
        assert!(
            big_allocs <= small_allocs + 64 && small_allocs <= big_allocs + 64,
            "warm scale allocations grow with n: small={small_allocs} big={big_allocs}"
        );
        println!(
            "scale flat-alloc: warm ordered runs allocate small={small_allocs} \
             big={big_allocs} (n={} vs n={})",
            scale_ns[0],
            scale_ns.last().expect("non-empty")
        );
        (small_allocs, big_allocs)
    };

    // ---- 2f. Streaming-metrics memory gate. With wait statistics
    // streamed (P² quantiles + bounded reservoir) instead of traced, a
    // warm untraced run's transient byte peak is O(active): a small
    // constant regardless of n. The exact traced oracle (kept behind
    // `collect_trace` as the differential reference) necessarily peaks
    // at O(n) trace bytes — the contrast is the contract.
    let (streaming_n, streaming_untraced_peak, streaming_traced_peak) = {
        let n = scale_max_n;
        let w = scale_array_workload(n);
        let sched = make_scheduler(SchedulerChoice::IdealFifo);
        let mut scratch = SimScratch::new();
        // Warm both shapes (the traced warm-up also sizes what it can;
        // the trace buffer itself leaves the scratch with each result).
        sched.run_with_scratch(&w, &scale_cluster, 21, &RunOptions::default(), &mut scratch);
        sched.run_with_scratch(&w, &scale_cluster, 22, &RunOptions::with_trace(), &mut scratch);
        let mut measure = |opts: &RunOptions, seed: u64| -> i64 {
            COUNTING.store(true, Ordering::Relaxed);
            reset_byte_ledger();
            let r = sched.run_with_scratch(&w, &scale_cluster, seed, opts, &mut scratch);
            let peak = peak_bytes();
            COUNTING.store(false, Ordering::Relaxed);
            drop(r);
            peak
        };
        let untraced = measure(&RunOptions::default(), 23);
        let traced = measure(&RunOptions::with_trace(), 24);
        assert!(
            untraced < 1_000_000,
            "warm untraced run peaked at {untraced} transient bytes for n={n}: \
             streaming metrics should keep per-run memory O(active)"
        );
        assert!(
            traced >= 16 * n as i64,
            "traced oracle peaked at only {traced} bytes for n={n} — the O(n) \
             contrast with the streaming path has collapsed"
        );
        println!(
            "streaming memory @ n={n}: warm untraced peak {untraced} B (O(active)) vs \
             traced oracle {traced} B (O(n))"
        );
        (n, untraced, traced)
    };

    // ---- 3. Realtime dispatch rate (zero-work tasks).
    let coord = RealtimeCoordinator::new(RealtimeParams {
        workers: 2,
        dispatch_overhead: 0.0,
        artifacts_dir: None,
    });
    let tasks: Vec<RtTask> = (0..if quick { 5_000 } else { 20_000 })
        .map(|id| RtTask {
            id,
            nominal: 0.0,
            work: RtWork::Spin(0.0),
        })
        .collect();
    let t0 = Instant::now();
    let r = coord.run(&tasks).unwrap();
    let dispatch_rate = r.n_tasks as f64 / t0.elapsed().as_secs_f64();
    println!(
        "realtime coordinator: {:.0} dispatches/s ({} tasks in {:.3}s)",
        dispatch_rate,
        r.n_tasks,
        t0.elapsed().as_secs_f64()
    );

    // ---- 4. Artifact-suite fit latency.
    let mut fit_ms_per_call = f64::NAN;
    if let Ok(mut suite) = sssched::runtime::ArtifactSuite::load("artifacts") {
        let series: Vec<Vec<(f64, f64)>> = (0..4)
            .map(|s| {
                (0..16)
                    .map(|k| {
                        let n = 2f64.powi(k % 8);
                        (n, (2.0 + s as f64) * n.powf(1.2))
                    })
                    .collect()
            })
            .collect();
        // Warmup + timed.
        let _ = suite.powerlaw_fit(&series).unwrap();
        let iters = 200;
        let t0 = Instant::now();
        for _ in 0..iters {
            let _ = suite.powerlaw_fit(&series).unwrap();
        }
        fit_ms_per_call = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;
        println!(
            "powerlaw_fit [{}]: {fit_ms_per_call:.3} ms/call (4 series x 16 pts, {iters} iters)",
            suite.platform()
        );
    }

    // ---- 4b. Fitted-model layer: rust-native power-law fit latency
    // (the hardened `fit_sweep` path the `model` experiment gates on)
    // and the auto-tuner's bundle-size derivation scan.
    let (model_fit_us_per_call, model_tune_us_per_call) = {
        use sssched::model::{derive_bundle_size, fit_sweep};
        use sssched::multilevel::MultilevelParams;
        use sssched::util::prng::Prng;
        // Synthetic pooled sweep the shape of the real one: 7 n values
        // × 3 trials, Slurm-like parameters, deterministic noise.
        let mut rng = Prng::new(0xBE_4C);
        let pts: Vec<(f64, f64)> = [4u32, 8, 16, 32, 48, 96, 240]
            .iter()
            .flat_map(|&n| {
                let mut draw = || {
                    (
                        n as f64,
                        2.2 * (n as f64).powf(1.3) * rng.lognormal_mean_cv(1.0, 0.05),
                    )
                };
                [draw(), draw(), draw()]
            })
            .collect();
        let params = MultilevelParams::default();
        let fit_iters = if quick { 2_000u32 } else { 10_000 };
        let f = fit_sweep("bench", &pts).unwrap();
        let t0 = Instant::now();
        let mut acc = 0.0f64;
        for _ in 0..fit_iters {
            acc += fit_sweep("bench", &pts).unwrap().t_s;
        }
        let fit_us = t0.elapsed().as_secs_f64() / fit_iters as f64 * 1e6;
        let tune_iters = if quick { 500u32 } else { 2_000 };
        let t0 = Instant::now();
        let mut m_acc = 0u64;
        for _ in 0..tune_iters {
            m_acc += derive_bundle_size(f.t_s, f.alpha_s, &params, 1.0, 960, 0.9)
                .bundles_per_proc as u64;
        }
        let tune_us = t0.elapsed().as_secs_f64() / tune_iters as f64 * 1e6;
        println!(
            "model fit: {fit_us:.2} us/call ({} pts, {fit_iters} iters, checksum {acc:.1}); \
             auto-tune: {tune_us:.2} us/call (n=960 scan, {tune_iters} iters, checksum {m_acc})",
            pts.len()
        );
        (fit_us, tune_us)
    };

    // ---- 5. Sweep executor: serial vs parallel fig4-style sweep.
    let mut cfg = ExperimentConfig::default();
    cfg.scale_down = 8; // 5 nodes × 32 = 160 cores, shape-preserving
    cfg.trials = if quick { 1 } else { 3 };
    let specs: Vec<SweepSpec> = SchedulerChoice::paper_four()
        .iter()
        .map(|&c| (c, None))
        .collect();

    cfg.jobs = 1;
    let t0 = Instant::now();
    let serial = run_sweeps(&specs, &cfg, &cfg.n_sweep.clone());
    let serial_stats = sweep_stats(&serial, t0.elapsed().as_secs_f64());

    cfg.jobs = par_jobs;
    let t0 = Instant::now();
    let parallel = run_sweeps(&specs, &cfg, &cfg.n_sweep.clone());
    let par_stats = sweep_stats(&parallel, t0.elapsed().as_secs_f64());

    assert_bit_identical(&serial, &parallel);
    let speedup = serial_stats.wall_s / par_stats.wall_s;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "sweep jobs=1:  {:>3} cells, {:>9} events in {:.3}s = {:.1} cells/s, {:.2}M events/s",
        serial_stats.cells,
        serial_stats.events,
        serial_stats.wall_s,
        serial_stats.cells as f64 / serial_stats.wall_s,
        serial_stats.events as f64 / serial_stats.wall_s / 1e6,
    );
    println!(
        "sweep jobs={par_jobs}:  {:>3} cells, {:>9} events in {:.3}s = {:.1} cells/s, {:.2}M events/s",
        par_stats.cells,
        par_stats.events,
        par_stats.wall_s,
        par_stats.cells as f64 / par_stats.wall_s,
        par_stats.events as f64 / par_stats.wall_s / 1e6,
    );
    println!(
        "sweep speedup: {speedup:.2}x with --jobs {par_jobs} on {cores} available cores; \
         outputs bit-identical: yes"
    );

    // ---- 6. pallas-lint: the static determinism pass over this
    // crate's own tree. Tracked so a rule or tree growth that makes the
    // lint step slow shows up in the perf trajectory like any other
    // regression, and so rule-hit counts (pre-suppression) are recorded
    // alongside the numbers they protect.
    let (lint_wall_ms, lint_report) = {
        let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let _warm = sssched::lint::lint_tree(&root).expect("lint walks the crate");
        let t0 = Instant::now();
        let report = sssched::lint::lint_tree(&root).expect("lint walks the crate");
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        assert!(
            report.is_clean(),
            "perf run on a tree that fails pallas-lint:\n{}",
            report.render()
        );
        println!(
            "pallas-lint: {} files clean in {wall_ms:.1} ms ({} suppression honoured)",
            report.files_scanned,
            report.suppressed
        );
        (wall_ms, report)
    };

    // ---- Machine-readable perf trajectory.
    let sims_json: Vec<String> = sim_rates
        .iter()
        .map(|(name, rate)| format!("    {{\"name\": \"{name}\", \"mevents_per_s\": {rate:.4}}}"))
        .collect();
    let scale_rows_json: Vec<String> = scale_cells
        .iter()
        .map(|(name, n, wall, events)| {
            format!(
                "      {{\"scheduler\": \"{name}\", \"n\": {n}, \"wall_s\": {wall:.5}, \
                 \"events\": {events}, \"mevents_per_s\": {:.4}}}",
                *events as f64 / wall / 1e6
            )
        })
        .collect();
    let scale_exp_json: Vec<String> = scale_exponents
        .iter()
        .map(|(name, alpha, r2)| {
            format!(
                "      {{\"scheduler\": \"{name}\", \"alpha\": {alpha:.4}, \"r2\": {r2:.4}}}"
            )
        })
        .collect();
    let json = format!(
        "{{\n\
         \x20 \"bench\": \"perf_engine\",\n\
         \x20 \"quick\": {quick},\n\
         \x20 \"available_cores\": {cores},\n\
         \x20 \"event_queue_mops\": {queue_mops:.4},\n\
         \x20 \"kernel_warm_mevents_per_s\": {kernel_warm_rate:.4},\n\
         \x20 \"preempt_warm_mevents_per_s\": {preempt_rate:.4},\n\
         \x20 \"preempt_evictions_per_s\": {preempt_evictions_per_s:.1},\n\
         \x20 \"preempt_warm_allocs_per_run\": {preempt_allocs_per_run},\n\
         \x20 \"churn_mevents_per_s\": {churn_rate:.4},\n\
         \x20 \"churn_kills_per_s\": {churn_kills_per_s:.1},\n\
         \x20 \"churn_warm_allocs_per_run\": {churn_allocs_per_run},\n\
         \x20 \"degraded_mevents_per_s\": {degraded_rate:.4},\n\
         \x20 \"degraded_warm_allocs_per_run\": {degraded_allocs_per_run},\n\
         \x20 \"sims\": [\n{sims}\n  ],\n\
         \x20 \"scale\": {{\n\
         \x20   \"procs\": {scale_procs},\n\
         \x20   \"scale_mevents_per_s\": [\n{scale_rows}\n    ],\n\
         \x20   \"exponents\": [\n{scale_exps}\n    ],\n\
         \x20   \"ordered_speedup_n\": {speedup_n},\n\
         \x20   \"ordered_eager_wall_s\": {oew:.5},\n\
         \x20   \"ordered_incremental_wall_s\": {oiw:.5},\n\
         \x20   \"ordered_speedup\": {osp:.3},\n\
         \x20   \"flat_allocs_small\": {sas},\n\
         \x20   \"flat_allocs_big\": {sab},\n\
         \x20   \"mevents_floor\": {floor},\n\
         \x20   \"streaming_n\": {stn},\n\
         \x20   \"streaming_untraced_peak_bytes\": {supb},\n\
         \x20   \"streaming_traced_peak_bytes\": {stpb},\n\
         \x20   \"bit_identical\": true\n\
         \x20 }},\n\
         \x20 \"lint_wall_ms\": {lint_wall_ms:.2},\n\
         \x20 \"lint_files\": {lint_files},\n\
         \x20 \"lint_suppressed\": {lint_suppressed},\n\
         \x20 \"lint_rule_hits\": {{{lint_hits}}},\n\
         \x20 \"peak_rss_kb\": {rss},\n\
         \x20 \"realtime_dispatch_per_s\": {dispatch_rate:.1},\n\
         \x20 \"powerlaw_fit_ms_per_call\": {fit_ms},\n\
         \x20 \"model_fit_us_per_call\": {model_fit_us:.3},\n\
         \x20 \"model_tune_us_per_call\": {model_tune_us:.3},\n\
         \x20 \"sweep\": {{\n\
         \x20   \"scale_down\": {scale_down},\n\
         \x20   \"trials\": {trials},\n\
         \x20   \"cells\": {cells},\n\
         \x20   \"events\": {events},\n\
         \x20   \"serial_wall_s\": {sw:.4},\n\
         \x20   \"parallel_jobs\": {pj},\n\
         \x20   \"parallel_wall_s\": {pw:.4},\n\
         \x20   \"serial_cells_per_s\": {scps:.2},\n\
         \x20   \"parallel_cells_per_s\": {pcps:.2},\n\
         \x20   \"serial_mevents_per_s\": {seps:.4},\n\
         \x20   \"parallel_mevents_per_s\": {peps:.4},\n\
         \x20   \"speedup\": {speedup:.3},\n\
         \x20   \"bit_identical\": true\n\
         \x20 }}\n\
         }}\n",
        sims = sims_json.join(",\n"),
        scale_rows = scale_rows_json.join(",\n"),
        scale_exps = scale_exp_json.join(",\n"),
        oew = ordered_eager_wall,
        oiw = ordered_incr_wall,
        osp = ordered_speedup,
        sas = scale_allocs_small,
        sab = scale_allocs_big,
        floor = SCALE_MEVENTS_FLOOR,
        stn = streaming_n,
        supb = streaming_untraced_peak,
        stpb = streaming_traced_peak,
        lint_files = lint_report.files_scanned,
        lint_suppressed = lint_report.suppressed,
        lint_hits = lint_report
            .rule_hits
            .iter()
            .map(|(n, c)| format!("\"{n}\": {c}"))
            .collect::<Vec<_>>()
            .join(", "),
        rss = peak_rss_kb()
            .map(|kb| kb.to_string())
            .unwrap_or_else(|| "null".to_string()),
        fit_ms = if fit_ms_per_call.is_finite() {
            format!("{fit_ms_per_call:.4}")
        } else {
            "null".to_string()
        },
        model_fit_us = model_fit_us_per_call,
        model_tune_us = model_tune_us_per_call,
        scale_down = cfg.scale_down,
        trials = cfg.trials,
        cells = serial_stats.cells,
        events = serial_stats.events,
        sw = serial_stats.wall_s,
        pj = par_jobs,
        pw = par_stats.wall_s,
        scps = serial_stats.cells as f64 / serial_stats.wall_s,
        pcps = par_stats.cells as f64 / par_stats.wall_s,
        seps = serial_stats.events as f64 / serial_stats.wall_s / 1e6,
        peps = par_stats.events as f64 / par_stats.wall_s / 1e6,
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
