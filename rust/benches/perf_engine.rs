//! Perf bench: hot-path microbenchmarks + the sweep-executor
//! throughput benchmark feeding README §Performance and the
//! `BENCH_perf.json` trajectory artifact.
//!
//! * event-queue throughput (push+pop)
//! * full scheduler-simulation events/s (the L3 hot path)
//! * preemption-heavy kernel loop: evictions/s + warm events/s, with a
//!   counting-allocator assert that warm-scratch preemption runs stay
//!   allocation-flat (per-run allocations are a small constant that
//!   does not scale with workload size — nothing allocates on the
//!   evict/requeue/resume hot path after warmup)
//! * realtime coordinator dispatch rate (channel round-trip)
//! * artifact-suite power-law fit latency (the L1/L2 hot path from rust)
//! * serial vs parallel fig4-style sweep: cells/s, events/s, wall-clock
//!   speedup, and a bit-identity check between `jobs=1` and `jobs=N`
//!
//! Usage: `cargo bench --bench perf_engine -- [--quick] [--jobs N]
//! [--out FILE]` (default out: BENCH_perf.json in the working dir).

use sssched::cluster::ClusterSpec;
use sssched::config::{ExperimentConfig, SchedulerChoice};
use sssched::exec::{RealtimeCoordinator, RealtimeParams, RtTask, RtWork};
use sssched::harness::{run_sweeps, SchedulerSweep, SweepSpec};
use sssched::sched::combinators::{make_preemptive, Order};
use sssched::sched::{make_scheduler, RunOptions, SimScratch};
use sssched::sim::EventQueue;
use sssched::workload::{TaskSpec, Workload};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

/// Allocation-counting wrapper around the system allocator, used to
/// assert the warm-scratch preemption path allocates nothing per
/// event. Counting is flag-gated so the timed benchmarks elsewhere in
/// this binary pay only a relaxed load per allocation, not a shared
/// atomic RMW that could skew the published sweep numbers; it is
/// switched on only around the preemption flatness measurement. Counts
/// allocations and reallocations (frees are irrelevant to the
/// zero-alloc contract).
struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

struct SweepStats {
    wall_s: f64,
    cells: u64,
    events: u64,
}

fn sweep_stats(sweeps: &[SchedulerSweep], wall_s: f64) -> SweepStats {
    let mut cells = 0u64;
    let mut events = 0u64;
    for s in sweeps {
        for p in &s.points {
            cells += p.trials.len() as u64;
            events += p.trials.iter().map(|r| r.events).sum::<u64>();
        }
    }
    SweepStats {
        wall_s,
        cells,
        events,
    }
}

/// Bitwise comparison of two sweep batches (the `jobs` invariance the
/// executor promises).
fn assert_bit_identical(a: &[SchedulerSweep], b: &[SchedulerSweep]) {
    assert_eq!(a.len(), b.len(), "sweep count differs");
    for (sa, sb) in a.iter().zip(b) {
        assert_eq!(sa.scheduler, sb.scheduler);
        assert_eq!(sa.skipped, sb.skipped, "{}: skipped differ", sa.scheduler);
        assert_eq!(sa.points.len(), sb.points.len());
        for (pa, pb) in sa.points.iter().zip(&sb.points) {
            assert_eq!(pa.n, pb.n);
            assert_eq!(pa.trials.len(), pb.trials.len());
            for (ra, rb) in pa.trials.iter().zip(&pb.trials) {
                assert_eq!(
                    ra.t_total.to_bits(),
                    rb.t_total.to_bits(),
                    "{} n={}: t_total {} vs {}",
                    sa.scheduler,
                    pa.n,
                    ra.t_total,
                    rb.t_total
                );
                assert_eq!(ra.events, rb.events, "{} n={}: events", sa.scheduler, pa.n);
                assert_eq!(ra.daemon_busy.to_bits(), rb.daemon_busy.to_bits());
                assert_eq!(ra.waits.count(), rb.waits.count());
                assert_eq!(ra.waits.mean().to_bits(), rb.waits.mean().to_bits());
            }
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let opt = |name: &str| -> Option<String> {
        argv.iter()
            .position(|a| a == name)
            .and_then(|i| argv.get(i + 1))
            .cloned()
    };
    let par_jobs: u32 = opt("--jobs").and_then(|v| v.parse().ok()).unwrap_or(4);
    let out_path = opt("--out").unwrap_or_else(|| "BENCH_perf.json".to_string());

    // ---- 1. Raw event queue.
    let n = if quick { 500_000u64 } else { 2_000_000u64 };
    let t0 = Instant::now();
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut acc = 0u64;
    for i in 0..n {
        // Jittered future times (respecting the causality guard).
        q.push(q.now() + (i % 100) as f64 * 0.01, i);
        if i % 4 == 3 {
            acc = acc.wrapping_add(q.pop().unwrap().1);
        }
    }
    while let Some((_, v)) = q.pop() {
        acc = acc.wrapping_add(v);
    }
    let queue_mops = 2.0 * n as f64 / t0.elapsed().as_secs_f64() / 1e6;
    println!("event queue: {queue_mops:.2}M push+pop/s (checksum {acc})");

    // ---- 2. Scheduler sims, events/s.
    let cluster = if quick {
        ClusterSpec::homogeneous(11, 32, 64 * 1024, 11)
    } else {
        ClusterSpec::supercloud()
    };
    let mut sim_rates: Vec<(String, f64)> = Vec::new();
    for choice in [
        SchedulerChoice::Slurm,
        SchedulerChoice::Mesos,
        SchedulerChoice::Yarn,
        SchedulerChoice::IdealFifo,
    ] {
        let sched = make_scheduler(choice);
        let w = sssched::workload::WorkloadBuilder::constant(5.0)
            .tasks(48 * cluster.total_cores())
            .label("bench")
            .build();
        let t0 = Instant::now();
        let r = sched.run(&w, &cluster, 1, &RunOptions::default());
        let dt = t0.elapsed().as_secs_f64();
        let rate = r.events as f64 / dt / 1e6;
        println!(
            "{:<12} sim: {:>7} tasks, {:>8} events in {:.3}s = {:.2}M events/s ({:.0}x realtime)",
            sched.name(),
            r.n_tasks,
            r.events,
            dt,
            rate,
            r.t_total / dt,
        );
        sim_rates.push((sched.name().to_string(), rate));
    }

    // ---- 2b. Kernel-loop events/s on the warm-scratch path.
    //
    // Since the unified-kernel refactor every backend runs its events
    // through `sim::Kernel` + `SchedPolicy` hooks; this isolates the
    // steady-state loop (repeated trials, reused scratch) so the
    // BENCH_perf.json trajectory tracks that the policy indirection
    // stays within noise (<5%) of the pre-refactor per-backend loops
    // (compare `kernel_warm_mevents_per_s` across commits).
    let kernel_warm_rate = {
        let sched = make_scheduler(SchedulerChoice::Slurm);
        let w = sssched::workload::WorkloadBuilder::constant(5.0)
            .tasks(24 * cluster.total_cores())
            .label("kernel-bench")
            .build();
        let mut scratch = SimScratch::new();
        // Warm-up run sizes every buffer.
        let warm = sched.run_with_scratch(&w, &cluster, 0, &RunOptions::default(), &mut scratch);
        let iters = if quick { 3u64 } else { 8 };
        let t0 = Instant::now();
        let mut events = 0u64;
        for i in 0..iters {
            let r =
                sched.run_with_scratch(&w, &cluster, i + 1, &RunOptions::default(), &mut scratch);
            events += r.events;
        }
        let dt = t0.elapsed().as_secs_f64();
        let rate = events as f64 / dt / 1e6;
        println!(
            "kernel loop (warm scratch): {events} events over {iters} trials in {dt:.3}s \
             = {rate:.2}M events/s (warm-up run: {} events)",
            warm.events
        );
        rate
    };

    // ---- 2c. Preemption-heavy kernel loop (warm scratch): evictions/s
    // plus an allocation-flatness assert — after warmup, a preemption
    // run's allocations are a small per-run constant (policy setup +
    // result labels), independent of workload size: nothing allocates
    // on the evict/requeue/resume hot path.
    let preempt_bench_workload = |waves: u64| -> Workload {
        let cores = cluster.total_cores();
        let mut tasks: Vec<TaskSpec> = Vec::new();
        for i in 0..waves * cores {
            let mut t = TaskSpec::array(i as u32, i as u32, 5.0);
            t.preemptible = true;
            tasks.push(t);
        }
        for k in 0..cores / 2 {
            let id = (waves * cores + k) as u32;
            let mut t = TaskSpec::array(id, id, 1.0);
            t.priority = 10;
            t.submit_at = 0.5 + (k % 32) as f64 * 2.0;
            tasks.push(t);
        }
        Workload {
            tasks,
            label: "preempt-bench".into(),
        }
    };
    let (preempt_rate, preempt_evictions_per_s, preempt_allocs_per_run) = {
        let sched = make_preemptive(SchedulerChoice::Slurm, 1, Order::Priority);
        let big = preempt_bench_workload(16);
        let small = preempt_bench_workload(4);
        let mut scratch = SimScratch::new();
        // Warm-up on the big workload sizes every buffer.
        sched.run_with_scratch(&big, &cluster, 0, &RunOptions::default(), &mut scratch);
        let iters = if quick { 2u64 } else { 5 };
        let t0 = Instant::now();
        let mut events = 0u64;
        let mut evictions = 0u64;
        for i in 0..iters {
            let r = sched.run_with_scratch(
                &big,
                &cluster,
                i + 1,
                &RunOptions::default(),
                &mut scratch,
            );
            events += r.events;
            evictions += r.preemptions;
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(evictions > 0, "preemption bench executed no evictions");
        COUNTING.store(true, Ordering::Relaxed);
        let before_small = allocs();
        sched.run_with_scratch(&small, &cluster, 97, &RunOptions::default(), &mut scratch);
        let small_allocs = allocs() - before_small;
        let before_big = allocs();
        sched.run_with_scratch(&big, &cluster, 98, &RunOptions::default(), &mut scratch);
        let big_allocs = allocs() - before_big;
        COUNTING.store(false, Ordering::Relaxed);
        assert!(
            small_allocs < 512 && big_allocs < 512,
            "warm preemption run allocates per event: small={small_allocs} big={big_allocs}"
        );
        assert!(
            big_allocs <= small_allocs + 64 && small_allocs <= big_allocs + 64,
            "warm preemption allocations scale with workload size: \
             small={small_allocs} big={big_allocs}"
        );
        let rate = events as f64 / dt / 1e6;
        let eps = evictions as f64 / dt;
        println!(
            "preempt loop (warm scratch): {events} events, {evictions} evictions over \
             {iters} trials in {dt:.3}s = {rate:.2}M events/s, {eps:.0} evictions/s; \
             allocs/run small={small_allocs} big={big_allocs} (flat)"
        );
        (rate, eps, big_allocs)
    };

    // ---- 3. Realtime dispatch rate (zero-work tasks).
    let coord = RealtimeCoordinator::new(RealtimeParams {
        workers: 2,
        dispatch_overhead: 0.0,
        artifacts_dir: None,
    });
    let tasks: Vec<RtTask> = (0..if quick { 5_000 } else { 20_000 })
        .map(|id| RtTask {
            id,
            nominal: 0.0,
            work: RtWork::Spin(0.0),
        })
        .collect();
    let t0 = Instant::now();
    let r = coord.run(&tasks).unwrap();
    let dispatch_rate = r.n_tasks as f64 / t0.elapsed().as_secs_f64();
    println!(
        "realtime coordinator: {:.0} dispatches/s ({} tasks in {:.3}s)",
        dispatch_rate,
        r.n_tasks,
        t0.elapsed().as_secs_f64()
    );

    // ---- 4. Artifact-suite fit latency.
    let mut fit_ms_per_call = f64::NAN;
    if let Ok(mut suite) = sssched::runtime::ArtifactSuite::load("artifacts") {
        let series: Vec<Vec<(f64, f64)>> = (0..4)
            .map(|s| {
                (0..16)
                    .map(|k| {
                        let n = 2f64.powi(k % 8);
                        (n, (2.0 + s as f64) * n.powf(1.2))
                    })
                    .collect()
            })
            .collect();
        // Warmup + timed.
        let _ = suite.powerlaw_fit(&series).unwrap();
        let iters = 200;
        let t0 = Instant::now();
        for _ in 0..iters {
            let _ = suite.powerlaw_fit(&series).unwrap();
        }
        fit_ms_per_call = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;
        println!(
            "powerlaw_fit [{}]: {fit_ms_per_call:.3} ms/call (4 series x 16 pts, {iters} iters)",
            suite.platform()
        );
    }

    // ---- 5. Sweep executor: serial vs parallel fig4-style sweep.
    let mut cfg = ExperimentConfig::default();
    cfg.scale_down = 8; // 5 nodes × 32 = 160 cores, shape-preserving
    cfg.trials = if quick { 1 } else { 3 };
    let specs: Vec<SweepSpec> = SchedulerChoice::paper_four()
        .iter()
        .map(|&c| (c, None))
        .collect();

    cfg.jobs = 1;
    let t0 = Instant::now();
    let serial = run_sweeps(&specs, &cfg, &cfg.n_sweep.clone());
    let serial_stats = sweep_stats(&serial, t0.elapsed().as_secs_f64());

    cfg.jobs = par_jobs;
    let t0 = Instant::now();
    let parallel = run_sweeps(&specs, &cfg, &cfg.n_sweep.clone());
    let par_stats = sweep_stats(&parallel, t0.elapsed().as_secs_f64());

    assert_bit_identical(&serial, &parallel);
    let speedup = serial_stats.wall_s / par_stats.wall_s;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "sweep jobs=1:  {:>3} cells, {:>9} events in {:.3}s = {:.1} cells/s, {:.2}M events/s",
        serial_stats.cells,
        serial_stats.events,
        serial_stats.wall_s,
        serial_stats.cells as f64 / serial_stats.wall_s,
        serial_stats.events as f64 / serial_stats.wall_s / 1e6,
    );
    println!(
        "sweep jobs={par_jobs}:  {:>3} cells, {:>9} events in {:.3}s = {:.1} cells/s, {:.2}M events/s",
        par_stats.cells,
        par_stats.events,
        par_stats.wall_s,
        par_stats.cells as f64 / par_stats.wall_s,
        par_stats.events as f64 / par_stats.wall_s / 1e6,
    );
    println!(
        "sweep speedup: {speedup:.2}x with --jobs {par_jobs} on {cores} available cores; \
         outputs bit-identical: yes"
    );

    // ---- Machine-readable perf trajectory.
    let sims_json: Vec<String> = sim_rates
        .iter()
        .map(|(name, rate)| format!("    {{\"name\": \"{name}\", \"mevents_per_s\": {rate:.4}}}"))
        .collect();
    let json = format!(
        "{{\n\
         \x20 \"bench\": \"perf_engine\",\n\
         \x20 \"quick\": {quick},\n\
         \x20 \"available_cores\": {cores},\n\
         \x20 \"event_queue_mops\": {queue_mops:.4},\n\
         \x20 \"kernel_warm_mevents_per_s\": {kernel_warm_rate:.4},\n\
         \x20 \"preempt_warm_mevents_per_s\": {preempt_rate:.4},\n\
         \x20 \"preempt_evictions_per_s\": {preempt_evictions_per_s:.1},\n\
         \x20 \"preempt_warm_allocs_per_run\": {preempt_allocs_per_run},\n\
         \x20 \"sims\": [\n{sims}\n  ],\n\
         \x20 \"realtime_dispatch_per_s\": {dispatch_rate:.1},\n\
         \x20 \"powerlaw_fit_ms_per_call\": {fit_ms},\n\
         \x20 \"sweep\": {{\n\
         \x20   \"scale_down\": {scale_down},\n\
         \x20   \"trials\": {trials},\n\
         \x20   \"cells\": {cells},\n\
         \x20   \"events\": {events},\n\
         \x20   \"serial_wall_s\": {sw:.4},\n\
         \x20   \"parallel_jobs\": {pj},\n\
         \x20   \"parallel_wall_s\": {pw:.4},\n\
         \x20   \"serial_cells_per_s\": {scps:.2},\n\
         \x20   \"parallel_cells_per_s\": {pcps:.2},\n\
         \x20   \"serial_mevents_per_s\": {seps:.4},\n\
         \x20   \"parallel_mevents_per_s\": {peps:.4},\n\
         \x20   \"speedup\": {speedup:.3},\n\
         \x20   \"bit_identical\": true\n\
         \x20 }}\n\
         }}\n",
        sims = sims_json.join(",\n"),
        fit_ms = if fit_ms_per_call.is_finite() {
            format!("{fit_ms_per_call:.4}")
        } else {
            "null".to_string()
        },
        scale_down = cfg.scale_down,
        trials = cfg.trials,
        cells = serial_stats.cells,
        events = serial_stats.events,
        sw = serial_stats.wall_s,
        pj = par_jobs,
        pw = par_stats.wall_s,
        scps = serial_stats.cells as f64 / serial_stats.wall_s,
        pcps = par_stats.cells as f64 / par_stats.wall_s,
        seps = serial_stats.events as f64 / serial_stats.wall_s / 1e6,
        peps = par_stats.events as f64 / par_stats.wall_s / 1e6,
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
