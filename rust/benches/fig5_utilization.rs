//! Bench: regenerate Figure 5 — scheduler utilization vs task time,
//! measured points plus the approximate (5a) and exact (5b) model
//! curves, the latter evaluated through the AOT `utilization` artifact.

use sssched::config::ExperimentConfig;
use sssched::harness::fig5;
use std::time::Instant;

fn main() {
    let mut cfg = ExperimentConfig::default();
    if std::env::var("SSSCHED_QUICK").is_ok() {
        cfg.scale_down = 8;
        cfg.trials = 1;
    }
    let t0 = Instant::now();
    let rep = fig5(&cfg, Some("artifacts"));
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", rep.render_plot());
    println!(
        "model curves via {}",
        if rep.used_pjrt { "PJRT artifact (Pallas kernel)" } else { "rust fallback" }
    );
    std::fs::create_dir_all("out").ok();
    if std::fs::write("out/fig5.csv", rep.to_csv()).is_ok() {
        println!("series written to out/fig5.csv");
    }
    println!("bench: {wall:.2}s wall");
    match rep.check_shape() {
        Ok(()) => println!(
            "shape vs paper: OK (U<15% at 1s tasks; U recovers by 60s; monotone)"
        ),
        Err(e) => {
            println!("shape vs paper: FAIL — {e}");
            std::process::exit(1);
        }
    }
}
