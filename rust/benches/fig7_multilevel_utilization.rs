//! Bench: regenerate Figure 7 — utilization for regular vs multilevel
//! scheduling on Slurm / Grid Engine / Mesos (the paper's ~90 % result).

use sssched::config::ExperimentConfig;
use sssched::harness::fig7;
use sssched::multilevel::MultilevelParams;
use std::time::Instant;

fn main() {
    let mut cfg = ExperimentConfig::default();
    if std::env::var("SSSCHED_QUICK").is_ok() {
        cfg.scale_down = 8;
        cfg.trials = 1;
    }
    let t0 = Instant::now();
    let rep = fig7(&cfg, &MultilevelParams::default());
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", rep.render_plots());
    println!("{}", rep.render_table().render());
    std::fs::create_dir_all("out").ok();
    if std::fs::write("out/fig7.csv", rep.render_table().to_csv()).is_ok() {
        println!("series written to out/fig7.csv");
    }
    println!("bench: {wall:.2}s wall");
    match rep.check_shape() {
        Ok(()) => println!("shape vs paper: OK (multilevel U ≥ 80% everywhere, ~90% typical)"),
        Err(e) => {
            println!("shape vs paper: FAIL — {e}");
            std::process::exit(1);
        }
    }
}
