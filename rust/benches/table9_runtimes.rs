//! Bench: regenerate Table 9 (runtimes of 4 task sets × 4 schedulers ×
//! 3 trials at full 1408-core scale) and compare with the paper.
//!
//! `SSSCHED_QUICK=1 cargo bench --bench table9_runtimes` scales down.

use sssched::config::ExperimentConfig;
use sssched::harness::table9;
use std::time::Instant;

fn main() {
    let mut cfg = ExperimentConfig::default();
    if std::env::var("SSSCHED_QUICK").is_ok() {
        cfg.scale_down = 8;
        cfg.trials = 1;
    }
    println!(
        "table9 bench: P={} trials={} (paper: 1408 cores, 3 trials)",
        cfg.processors(),
        cfg.trials
    );
    let t0 = Instant::now();
    let rep = table9(&cfg);
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", rep.render().render());
    let simulated: f64 = rep
        .sweeps
        .iter()
        .flat_map(|s| s.points.iter())
        .flat_map(|p| p.trials.iter())
        .map(|r| r.t_total)
        .sum();
    let events: u64 = rep
        .sweeps
        .iter()
        .flat_map(|s| s.points.iter())
        .flat_map(|p| p.trials.iter())
        .map(|r| r.events)
        .sum();
    println!(
        "bench: {wall:.2}s wall to simulate {simulated:.0}s of cluster time \
         ({events} events, {:.2}M events/s, speedup {:.0}x)",
        events as f64 / wall / 1e6,
        simulated / wall
    );
    match rep.check_shape(0.35) {
        Ok(()) => println!("shape vs paper: OK (all ratios within ±35%)"),
        Err(e) => {
            println!("shape vs paper: FAIL — {e}");
            std::process::exit(1);
        }
    }
}
