//! Bench: regenerate Figure 4 — ΔT vs tasks-per-processor on log-log
//! axes, measured trials + fitted model line, one panel per scheduler.

use sssched::config::ExperimentConfig;
use sssched::harness::fig4;
use std::time::Instant;

fn main() {
    let mut cfg = ExperimentConfig::default();
    if std::env::var("SSSCHED_QUICK").is_ok() {
        cfg.scale_down = 8;
        cfg.trials = 1;
    }
    let t0 = Instant::now();
    let rep = fig4(&cfg);
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", rep.render_plots());
    std::fs::create_dir_all("out").ok();
    if std::fs::write("out/fig4.csv", rep.to_csv()).is_ok() {
        println!("series written to out/fig4.csv");
    }
    println!("bench: {wall:.2}s wall");
    match rep.check_shape() {
        Ok(()) => println!("shape vs paper: OK (ΔT grows with n; power law fits)"),
        Err(e) => {
            println!("shape vs paper: FAIL — {e}");
            std::process::exit(1);
        }
    }
}
