//! Bench: regenerate Table 10 (fitted t_s, α_s per scheduler) over the
//! Figure 4 n-sweep, through both the rust and the PJRT/Pallas fit
//! paths, and check the paper's orderings.

use sssched::config::ExperimentConfig;
use sssched::harness::table10;
use std::time::Instant;

fn main() {
    let mut cfg = ExperimentConfig::default();
    if std::env::var("SSSCHED_QUICK").is_ok() {
        cfg.scale_down = 8;
        cfg.trials = 1;
    }
    println!(
        "table10 bench: P={} trials={} n_sweep={:?}",
        cfg.processors(),
        cfg.trials,
        cfg.n_sweep
    );
    let t0 = Instant::now();
    let rep = table10(&cfg, Some("artifacts"));
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", rep.render().render());
    println!("bench: {wall:.2}s wall");
    match rep.check_shape() {
        Ok(()) => println!("shape vs paper: OK (t_s and alpha orderings hold, fit paths agree)"),
        Err(e) => {
            println!("shape vs paper: FAIL — {e}");
            std::process::exit(1);
        }
    }
}
