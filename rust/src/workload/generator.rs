//! Workload generation: constant task times (the paper's benchmark) and
//! variable task-time distributions (used to exercise the U_v model of
//! Section 4), plus the structured dimensions the kernel executes —
//! multi-core tasks, DAG chains, gang-scheduled parallel jobs and
//! arrival processes.

use super::arrivals::ArrivalProcess;
use super::types::{JobKind, TaskSpec, Workload};
use crate::util::prng::Prng;

/// Distribution of task durations.
#[derive(Clone, Copy, Debug)]
pub enum TaskTimeDist {
    /// Every task takes exactly t seconds (Table 9 style).
    Constant(f64),
    /// Uniform in [lo, hi).
    Uniform(f64, f64),
    /// Exponential with the given mean.
    Exponential(f64),
    /// Lognormal with linear-space mean and coefficient of variation.
    Lognormal { mean: f64, cv: f64 },
}

impl TaskTimeDist {
    /// Draw one duration.
    pub fn sample(&self, rng: &mut Prng) -> f64 {
        match *self {
            TaskTimeDist::Constant(t) => t,
            TaskTimeDist::Uniform(lo, hi) => rng.range_f64(lo, hi),
            TaskTimeDist::Exponential(mean) => rng.exponential(mean),
            TaskTimeDist::Lognormal { mean, cv } => rng.lognormal_mean_cv(mean, cv),
        }
    }

    /// Expected value.
    pub fn mean(&self) -> f64 {
        match *self {
            TaskTimeDist::Constant(t) => t,
            TaskTimeDist::Uniform(lo, hi) => 0.5 * (lo + hi),
            TaskTimeDist::Exponential(mean) => mean,
            TaskTimeDist::Lognormal { mean, .. } => mean,
        }
    }
}

/// Builder for workloads: array-style by default, with optional
/// multi-core tasks, linear DAG chains, gang-scheduled parallel jobs
/// and arrival processes.
#[derive(Clone, Debug)]
pub struct WorkloadBuilder {
    dist: TaskTimeDist,
    n_tasks: u64,
    label: String,
    mem_mb: i64,
    seed: u64,
    n_jobs: u32,
    cores: u32,
    chain_len: u32,
    gang_size: u32,
    arrivals: Option<ArrivalProcess>,
    priority: i32,
    users: u32,
    preemptible: bool,
    checkpoint_cost: f64,
    svc_count: u64,
    svc_cores: u32,
}

impl WorkloadBuilder {
    /// Constant-duration tasks.
    pub fn constant(t: f64) -> Self {
        Self::with_dist(TaskTimeDist::Constant(t))
    }

    /// Tasks drawn from an arbitrary distribution.
    pub fn with_dist(dist: TaskTimeDist) -> Self {
        Self {
            dist,
            n_tasks: 0,
            label: String::new(),
            mem_mb: 2048,
            seed: 0,
            n_jobs: 1,
            cores: 1,
            chain_len: 1,
            gang_size: 1,
            arrivals: None,
            priority: 0,
            users: 1,
            preemptible: false,
            checkpoint_cost: 0.0,
            svc_count: 0,
            svc_cores: 1,
        }
    }

    /// Number of tasks N.
    pub fn tasks(mut self, n: u64) -> Self {
        self.n_tasks = n;
        self
    }

    /// Label for reports.
    pub fn label(mut self, l: impl Into<String>) -> Self {
        self.label = l.into();
        self
    }

    /// Per-task memory (MB).
    pub fn mem_mb(mut self, m: i64) -> Self {
        self.mem_mb = m;
        self
    }

    /// Seed for sampled durations (and arrival times).
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Split tasks round-robin across this many job arrays.
    pub fn jobs(mut self, n: u32) -> Self {
        self.n_jobs = n.max(1);
        self
    }

    /// Cores required by every task (slot-packing exercised for > 1).
    pub fn cores(mut self, c: u32) -> Self {
        self.cores = c.max(1);
        self
    }

    /// Chain consecutive groups of `len` tasks into linear DAGs: task i
    /// depends on task i-1 within its chain. `len <= 1` disables.
    pub fn dag_chains(mut self, len: u32) -> Self {
        self.chain_len = len.max(1);
        self
    }

    /// Group consecutive `size`-task blocks into gang-scheduled
    /// (`JobKind::Parallel`) jobs that start all-or-nothing. Overrides
    /// the [`WorkloadBuilder::jobs`] round-robin job assignment.
    /// `size <= 1` disables.
    pub fn gangs(mut self, size: u32) -> Self {
        self.gang_size = size.max(1);
        self
    }

    /// Stamp submission times from an arrival process instead of the
    /// all-at-once batch default.
    pub fn arrivals(mut self, process: ArrivalProcess) -> Self {
        self.arrivals = Some(process);
        self
    }

    /// Static priority for every task (combinator ordering).
    pub fn priority(mut self, p: i32) -> Self {
        self.priority = p;
        self
    }

    /// Spread tasks round-robin across `n` users (fairshare ordering).
    pub fn users(mut self, n: u32) -> Self {
        self.users = n.max(1);
        self
    }

    /// Mark every task evictable by preemption-capable policies, with
    /// the given checkpoint/restart overhead (seconds of slot drain per
    /// eviction).
    pub fn preemptible(mut self, checkpoint_cost: f64) -> Self {
        self.preemptible = true;
        self.checkpoint_cost = checkpoint_cost;
        self
    }

    /// Prepend `count` long-running service tasks of `cores` cores each
    /// (`JobKind::Service`, submitted at t = 0, each its own job). The
    /// batch tasks declared via [`WorkloadBuilder::tasks`] follow with
    /// shifted ids; chains/gangs/arrivals apply to the batch portion
    /// only. Workloads with services run only under
    /// `RunOptions::horizon` (see `Workload::validate_for`).
    pub fn services(mut self, count: u64, cores: u32) -> Self {
        self.svc_count = count;
        self.svc_cores = cores.max(1);
        self
    }

    /// Materialize.
    pub fn build(self) -> Workload {
        assert!(
            self.gang_size <= 1 || self.chain_len <= 1,
            "gangs + dag_chains: a dependency between gang members can never \
             be satisfied (the gang waits for all members, the member waits \
             for the gang)"
        );
        let mut rng = Prng::new(self.seed ^ 0x5EED_F00D);
        let svc = self.svc_count;
        let mut tasks = Vec::with_capacity((svc + self.n_tasks) as usize);
        for s in 0..svc {
            let mut t = TaskSpec::service(s as u32, s as u32, self.svc_cores);
            t.mem_mb = self.mem_mb;
            t.user = (s % self.users as u64) as u32;
            t.preemptible = self.preemptible;
            t.checkpoint_cost = self.checkpoint_cost;
            tasks.push(t);
        }
        for i in 0..self.n_tasks {
            // Batch-portion index `i`; dense global id follows the
            // services. Job ids are offset past the service jobs.
            let id = svc + i;
            let job = svc as u32
                + if self.gang_size > 1 {
                    (i / self.gang_size as u64) as u32
                } else {
                    (i % self.n_jobs as u64) as u32
                };
            let mut t = TaskSpec::array(id as u32, job, self.dist.sample(&mut rng));
            t.mem_mb = self.mem_mb;
            t.cores = self.cores;
            t.priority = self.priority;
            t.user = (i % self.users as u64) as u32;
            t.preemptible = self.preemptible;
            t.checkpoint_cost = self.checkpoint_cost;
            if self.gang_size > 1 {
                t.kind = JobKind::Parallel;
            }
            if self.chain_len > 1 && i % self.chain_len as u64 != 0 {
                t.deps = vec![id as u32 - 1];
            }
            tasks.push(t);
        }
        let mut workload = Workload {
            tasks,
            label: self.label,
        };
        if let Some(process) = self.arrivals {
            process.apply(&mut workload, self.seed);
        }
        workload
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};
    use crate::workload::ArrivalProcess;

    #[test]
    fn constant_workload() {
        let w = WorkloadBuilder::constant(5.0).tasks(10).label("x").build();
        assert_eq!(w.len(), 10);
        assert!(w.tasks.iter().all(|t| t.duration == 5.0));
        w.validate().unwrap();
    }

    #[test]
    fn seeded_reproducibility() {
        let a = WorkloadBuilder::with_dist(TaskTimeDist::Exponential(3.0))
            .tasks(100)
            .seed(7)
            .build();
        let b = WorkloadBuilder::with_dist(TaskTimeDist::Exponential(3.0))
            .tasks(100)
            .seed(7)
            .build();
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.duration, y.duration);
        }
    }

    #[test]
    fn job_split_round_robin() {
        let w = WorkloadBuilder::constant(1.0).tasks(10).jobs(3).build();
        assert_eq!(w.tasks[0].job, 0);
        assert_eq!(w.tasks[1].job, 1);
        assert_eq!(w.tasks[2].job, 2);
        assert_eq!(w.tasks[3].job, 0);
    }

    #[test]
    fn dist_means() {
        assert_eq!(TaskTimeDist::Constant(4.0).mean(), 4.0);
        assert_eq!(TaskTimeDist::Uniform(2.0, 6.0).mean(), 4.0);
    }

    #[test]
    fn dag_chains_link_consecutive_tasks() {
        let w = WorkloadBuilder::constant(1.0).tasks(7).dag_chains(3).build();
        w.validate().unwrap();
        // Chains: [0,1,2], [3,4,5], [6].
        assert!(w.tasks[0].deps.is_empty());
        assert_eq!(w.tasks[1].deps, vec![0]);
        assert_eq!(w.tasks[2].deps, vec![1]);
        assert!(w.tasks[3].deps.is_empty());
        assert_eq!(w.tasks[4].deps, vec![3]);
        assert!(w.tasks[6].deps.is_empty());
    }

    #[test]
    fn gangs_group_blocks_as_parallel_jobs() {
        let w = WorkloadBuilder::constant(1.0).tasks(8).gangs(4).build();
        w.validate().unwrap();
        assert!(w.tasks.iter().all(|t| t.kind == JobKind::Parallel));
        assert_eq!(w.tasks[0].job, 0);
        assert_eq!(w.tasks[3].job, 0);
        assert_eq!(w.tasks[4].job, 1);
        assert_eq!(w.tasks[7].job, 1);
    }

    #[test]
    fn cores_and_arrivals_stamp_tasks() {
        let w = WorkloadBuilder::constant(2.0)
            .tasks(100)
            .cores(4)
            .arrivals(ArrivalProcess::Poisson { rate: 10.0 })
            .seed(3)
            .build();
        w.validate().unwrap();
        assert!(w.tasks.iter().all(|t| t.cores == 4));
        assert!(w.tasks.last().unwrap().submit_at > 0.0);
        // Monotone non-decreasing submit times (task order = arrival order).
        assert!(w.tasks.windows(2).all(|p| p[1].submit_at >= p[0].submit_at));
        // Same seed reproduces arrivals.
        let v = WorkloadBuilder::constant(2.0)
            .tasks(100)
            .cores(4)
            .arrivals(ArrivalProcess::Poisson { rate: 10.0 })
            .seed(3)
            .build();
        for (a, b) in w.tasks.iter().zip(&v.tasks) {
            assert_eq!(a.submit_at.to_bits(), b.submit_at.to_bits());
        }
    }

    #[test]
    fn preempt_and_fairness_knobs_stamp_tasks() {
        let w = WorkloadBuilder::constant(1.0)
            .tasks(6)
            .users(3)
            .priority(4)
            .preemptible(0.25)
            .build();
        w.validate().unwrap();
        assert!(w.tasks.iter().all(|t| t.preemptible));
        assert!(w.tasks.iter().all(|t| t.checkpoint_cost == 0.25));
        assert!(w.tasks.iter().all(|t| t.priority == 4));
        let users: Vec<u32> = w.tasks.iter().map(|t| t.user).collect();
        assert_eq!(users, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn services_prepend_and_batch_shifts() {
        let w = WorkloadBuilder::constant(2.0)
            .tasks(6)
            .services(3, 2)
            .dag_chains(3)
            .arrivals(ArrivalProcess::Poisson { rate: 5.0 })
            .seed(11)
            .build();
        w.validate().unwrap();
        assert_eq!(w.len(), 9);
        for t in &w.tasks[..3] {
            assert_eq!(t.kind, JobKind::Service);
            assert_eq!(t.cores, 2);
            assert_eq!(t.submit_at, 0.0, "services are resident, not arriving");
        }
        // Batch chains link within the batch portion only: [3,4,5], [6,7,8].
        assert!(w.tasks[3].deps.is_empty());
        assert_eq!(w.tasks[4].deps, vec![3]);
        assert_eq!(w.tasks[5].deps, vec![4]);
        assert!(w.tasks[6].deps.is_empty());
        // Arrivals stamped on batch tasks only, in order.
        assert!(w.tasks[3].submit_at > 0.0);
        assert!(w.tasks[3..].windows(2).all(|p| p[1].submit_at >= p[0].submit_at));
        // Service-free builds are unchanged by the services machinery.
        let plain = WorkloadBuilder::constant(2.0).tasks(6).seed(11).build();
        let with0 = WorkloadBuilder::constant(2.0).tasks(6).services(0, 4).seed(11).build();
        for (a, b) in plain.tasks.iter().zip(&with0.tasks) {
            assert_eq!(a.duration.to_bits(), b.duration.to_bits());
            assert_eq!(a.job, b.job);
        }
    }

    #[test]
    fn prop_generated_workloads_valid_and_positive() {
        check(
            |rng| {
                let n = rng.range_u64(1, 500);
                let mean = rng.range_f64(0.5, 30.0);
                let cv = rng.range_f64(0.0, 1.5);
                (n, mean, cv)
            },
            |&(n, mean, cv)| {
                let w = WorkloadBuilder::with_dist(TaskTimeDist::Lognormal { mean, cv })
                    .tasks(n)
                    .seed(n)
                    .build();
                w.validate()?;
                ensure(
                    w.tasks.iter().all(|t| t.duration > 0.0),
                    "non-positive duration",
                )?;
                ensure(w.len() as u64 == n, "length mismatch")
            },
        );
    }
}
