//! Workload generation: constant task times (the paper's benchmark) and
//! variable task-time distributions (used to exercise the U_v model of
//! Section 4).

use super::types::{TaskSpec, Workload};
use crate::util::prng::Prng;

/// Distribution of task durations.
#[derive(Clone, Copy, Debug)]
pub enum TaskTimeDist {
    /// Every task takes exactly t seconds (Table 9 style).
    Constant(f64),
    /// Uniform in [lo, hi).
    Uniform(f64, f64),
    /// Exponential with the given mean.
    Exponential(f64),
    /// Lognormal with linear-space mean and coefficient of variation.
    Lognormal { mean: f64, cv: f64 },
}

impl TaskTimeDist {
    /// Draw one duration.
    pub fn sample(&self, rng: &mut Prng) -> f64 {
        match *self {
            TaskTimeDist::Constant(t) => t,
            TaskTimeDist::Uniform(lo, hi) => rng.range_f64(lo, hi),
            TaskTimeDist::Exponential(mean) => rng.exponential(mean),
            TaskTimeDist::Lognormal { mean, cv } => rng.lognormal_mean_cv(mean, cv),
        }
    }

    /// Expected value.
    pub fn mean(&self) -> f64 {
        match *self {
            TaskTimeDist::Constant(t) => t,
            TaskTimeDist::Uniform(lo, hi) => 0.5 * (lo + hi),
            TaskTimeDist::Exponential(mean) => mean,
            TaskTimeDist::Lognormal { mean, .. } => mean,
        }
    }
}

/// Builder for array-style workloads.
#[derive(Clone, Debug)]
pub struct WorkloadBuilder {
    dist: TaskTimeDist,
    n_tasks: u64,
    label: String,
    mem_mb: i64,
    seed: u64,
    n_jobs: u32,
}

impl WorkloadBuilder {
    /// Constant-duration tasks.
    pub fn constant(t: f64) -> Self {
        Self::with_dist(TaskTimeDist::Constant(t))
    }

    /// Tasks drawn from an arbitrary distribution.
    pub fn with_dist(dist: TaskTimeDist) -> Self {
        Self {
            dist,
            n_tasks: 0,
            label: String::new(),
            mem_mb: 2048,
            seed: 0,
            n_jobs: 1,
        }
    }

    /// Number of tasks N.
    pub fn tasks(mut self, n: u64) -> Self {
        self.n_tasks = n;
        self
    }

    /// Label for reports.
    pub fn label(mut self, l: impl Into<String>) -> Self {
        self.label = l.into();
        self
    }

    /// Per-task memory (MB).
    pub fn mem_mb(mut self, m: i64) -> Self {
        self.mem_mb = m;
        self
    }

    /// Seed for sampled durations.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Split tasks round-robin across this many job arrays.
    pub fn jobs(mut self, n: u32) -> Self {
        self.n_jobs = n.max(1);
        self
    }

    /// Materialize.
    pub fn build(self) -> Workload {
        let mut rng = Prng::new(self.seed ^ 0x5EED_F00D);
        let mut tasks = Vec::with_capacity(self.n_tasks as usize);
        for i in 0..self.n_tasks {
            let mut t = TaskSpec::array(
                i as u32,
                (i % self.n_jobs as u64) as u32,
                self.dist.sample(&mut rng),
            );
            t.mem_mb = self.mem_mb;
            tasks.push(t);
        }
        Workload {
            tasks,
            label: self.label,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    #[test]
    fn constant_workload() {
        let w = WorkloadBuilder::constant(5.0).tasks(10).label("x").build();
        assert_eq!(w.len(), 10);
        assert!(w.tasks.iter().all(|t| t.duration == 5.0));
        w.validate().unwrap();
    }

    #[test]
    fn seeded_reproducibility() {
        let a = WorkloadBuilder::with_dist(TaskTimeDist::Exponential(3.0))
            .tasks(100)
            .seed(7)
            .build();
        let b = WorkloadBuilder::with_dist(TaskTimeDist::Exponential(3.0))
            .tasks(100)
            .seed(7)
            .build();
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.duration, y.duration);
        }
    }

    #[test]
    fn job_split_round_robin() {
        let w = WorkloadBuilder::constant(1.0).tasks(10).jobs(3).build();
        assert_eq!(w.tasks[0].job, 0);
        assert_eq!(w.tasks[1].job, 1);
        assert_eq!(w.tasks[2].job, 2);
        assert_eq!(w.tasks[3].job, 0);
    }

    #[test]
    fn dist_means() {
        assert_eq!(TaskTimeDist::Constant(4.0).mean(), 4.0);
        assert_eq!(TaskTimeDist::Uniform(2.0, 6.0).mean(), 4.0);
    }

    #[test]
    fn prop_generated_workloads_valid_and_positive() {
        check(
            |rng| {
                let n = rng.range_u64(1, 500);
                let mean = rng.range_f64(0.5, 30.0);
                let cv = rng.range_f64(0.0, 1.5);
                (n, mean, cv)
            },
            |&(n, mean, cv)| {
                let w = WorkloadBuilder::with_dist(TaskTimeDist::Lognormal { mean, cv })
                    .tasks(n)
                    .seed(n)
                    .build();
                w.validate()?;
                ensure(
                    w.tasks.iter().all(|t| t.duration > 0.0),
                    "non-positive duration",
                )?;
                ensure(w.len() as u64 == n, "length mismatch")
            },
        );
    }
}
