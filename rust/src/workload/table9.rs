//! The paper's Table 9 benchmark parameter sets.
//!
//! Four constant-task-time sets sized so every set does the same total
//! work: T_job per processor = 240 s on P = 1408 cores (93.7 processor-
//! hours in total).

use super::generator::WorkloadBuilder;
use super::types::Workload;

/// Per-processor isolated job time shared by all Table 9 sets (seconds).
pub const TABLE9_JOB_TIME_PER_PROC: f64 = 240.0;

/// One column of Table 9.
#[derive(Clone, Copy, Debug)]
pub struct Table9Set {
    /// "Rapid" / "Fast" / "Medium" / "Long".
    pub name: &'static str,
    /// Task time t (seconds).
    pub task_time: f64,
    /// Tasks per processor n.
    pub tasks_per_proc: u32,
}

impl Table9Set {
    /// Total tasks N for a given processor count.
    pub fn total_tasks(&self, processors: u64) -> u64 {
        self.tasks_per_proc as u64 * processors
    }

    /// Materialize the workload for `processors` cores.
    pub fn workload(&self, processors: u64) -> Workload {
        WorkloadBuilder::constant(self.task_time)
            .label(self.name)
            .tasks(self.total_tasks(processors))
            .build()
    }
}

/// The four Table 9 parameter sets.
pub fn table9_sets() -> [Table9Set; 4] {
    [
        Table9Set {
            name: "rapid",
            task_time: 1.0,
            tasks_per_proc: 240,
        },
        Table9Set {
            name: "fast",
            task_time: 5.0,
            tasks_per_proc: 48,
        },
        Table9Set {
            name: "medium",
            task_time: 30.0,
            tasks_per_proc: 8,
        },
        Table9Set {
            name: "long",
            task_time: 60.0,
            tasks_per_proc: 4,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sets_match_paper_totals() {
        let p = 1408;
        let sets = table9_sets();
        let totals: Vec<u64> = sets.iter().map(|s| s.total_tasks(p)).collect();
        assert_eq!(totals, vec![337_920, 67_584, 11_264, 5_632]);
        for s in &sets {
            // Constant processor time across sets: t * n = 240 s.
            assert_eq!(s.task_time * s.tasks_per_proc as f64, TABLE9_JOB_TIME_PER_PROC);
        }
        // 93.7 processor-hours total.
        let hours = TABLE9_JOB_TIME_PER_PROC * p as f64 / 3600.0;
        assert!((hours - 93.9).abs() < 0.3, "hours={hours}");
    }

    #[test]
    fn workload_materialization() {
        let w = table9_sets()[3].workload(4);
        assert_eq!(w.len(), 16);
        assert_eq!(w.total_work(), 960.0);
        assert_eq!(w.label, "long");
        w.validate().unwrap();
    }
}
