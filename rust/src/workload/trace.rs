//! Per-task execution trace read/write (CSV). The harness writes traces
//! so experiments can be inspected/replotted offline; the end-to-end
//! example replays one.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// One per-task execution record.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Task id.
    pub task: u32,
    /// Node it ran on.
    pub node: u32,
    /// Slot it ran on.
    pub slot: u32,
    /// Submission time (virtual s).
    pub submit: f64,
    /// Execution start time.
    pub start: f64,
    /// Execution end time.
    pub end: f64,
}

impl TraceRecord {
    /// Scheduler-induced wait for this task.
    pub fn wait(&self) -> f64 {
        self.start - self.submit
    }
}

/// Write records as CSV.
pub fn write_trace(path: impl AsRef<Path>, records: &[TraceRecord]) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "task,node,slot,submit,start,end")?;
    for r in records {
        writeln!(
            w,
            "{},{},{},{:.6},{:.6},{:.6}",
            r.task, r.node, r.slot, r.submit, r.start, r.end
        )?;
    }
    w.flush()
}

/// Read records back.
pub fn read_trace(path: impl AsRef<Path>) -> std::io::Result<Vec<TraceRecord>> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut out = Vec::new();
    for (i, line) in reader.lines().enumerate() {
        let line = line?;
        if i == 0 || line.trim().is_empty() {
            continue; // header
        }
        let cells: Vec<&str> = line.split(',').collect();
        if cells.len() != 6 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad trace line {i}: {line}"),
            ));
        }
        let parse_f = |s: &str| {
            s.parse::<f64>().map_err(|e| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, format!("line {i}: {e}"))
            })
        };
        let parse_u = |s: &str| {
            s.parse::<u32>().map_err(|e| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, format!("line {i}: {e}"))
            })
        };
        out.push(TraceRecord {
            task: parse_u(cells[0])?,
            node: parse_u(cells[1])?,
            slot: parse_u(cells[2])?,
            submit: parse_f(cells[3])?,
            start: parse_f(cells[4])?,
            end: parse_f(cells[5])?,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let recs = vec![
            TraceRecord {
                task: 0,
                node: 1,
                slot: 33,
                submit: 0.0,
                start: 2.25,
                end: 3.25,
            },
            TraceRecord {
                task: 1,
                node: 0,
                slot: 2,
                submit: 0.0,
                start: 2.5,
                end: 7.5,
            },
        ];
        let path = std::env::temp_dir().join("sssched_trace_test.csv");
        write_trace(&path, &recs).unwrap();
        let back = read_trace(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].task, 0);
        assert!((back[0].wait() - 2.25).abs() < 1e-9);
        assert!((back[1].end - 7.5).abs() < 1e-9);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_malformed() {
        let path = std::env::temp_dir().join("sssched_trace_bad.csv");
        std::fs::write(&path, "task,node,slot,submit,start,end\n1,2,3\n").unwrap();
        assert!(read_trace(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
