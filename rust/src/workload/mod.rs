//! Workloads: tasks, jobs (arrays, dependencies), generators for the
//! paper's benchmark task sets (Table 9) and for variable-task-time
//! experiments, plus trace read/write.

mod arrivals;
mod generator;
mod table9;
mod trace;
mod types;

pub use arrivals::{offered_load, ArrivalProcess};
pub use generator::{TaskTimeDist, WorkloadBuilder};
pub use table9::{table9_sets, Table9Set, TABLE9_JOB_TIME_PER_PROC};
pub use trace::{read_trace, write_trace, TraceRecord};
pub use types::{JobId, JobKind, TaskId, TaskSpec, Workload};
