//! Core workload types.

/// Task identifier, dense and 0-based within a workload.
pub type TaskId = u32;
/// Job identifier.
pub type JobId = u32;

/// What flavour of job a task belongs to — mirrors the paper's Figure 2
/// characterization (single-process / job array / parallel / service).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// Independent single-process task (possibly part of a job array).
    Array,
    /// Synchronously parallel job: all tasks must start together.
    Parallel,
    /// Long-running service job.
    Service,
}

/// One schedulable task.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    /// Dense id.
    pub id: TaskId,
    /// Job (array) this task belongs to.
    pub job: JobId,
    /// Job flavour.
    pub kind: JobKind,
    /// Isolated execution time t (virtual seconds).
    pub duration: f64,
    /// Cores required (1 for the paper's benchmark tasks).
    pub cores: u32,
    /// Memory required (MB). The paper's Slurm config used
    /// DefMemPerCPU=2048.
    pub mem_mb: i64,
    /// Submission time (0 for the paper's batch-submitted arrays).
    pub submit_at: f64,
    /// Task ids that must complete before this task may start (DAG
    /// dependencies; empty for array tasks).
    pub deps: Vec<TaskId>,
    /// Static priority (higher = sooner) consulted by the
    /// priority/fairshare policy combinators; 0 for the paper's
    /// benchmark tasks, which are all equal.
    pub priority: i32,
    /// Owning user, for fairshare accounting (accumulated core-seconds
    /// per user order the queue).
    pub user: u32,
    /// Whether a preemption-capable policy may evict this task while it
    /// runs (Slurm `PreemptMode`-style opt-in; the kernel refuses to
    /// evict non-preemptible tasks).
    pub preemptible: bool,
    /// Checkpoint/restart overhead (virtual s): after an eviction the
    /// task's slots stay occupied this long (checkpoint drain) before
    /// they are released; the task itself loses no progress.
    pub checkpoint_cost: f64,
    /// How many times the task may be re-run after being killed by a
    /// node failure before it is counted as permanently `failed`
    /// (kills beyond this budget stop requeueing). Unlike preemption —
    /// which banks progress — a kill loses the run's work, so every
    /// retry re-pays the full duration. Services ignore this field
    /// (they restart elsewhere, unbounded) and must leave it 0.
    pub max_retries: u32,
}

impl TaskSpec {
    /// Simple 1-core array task.
    pub fn array(id: TaskId, job: JobId, duration: f64) -> Self {
        Self {
            id,
            job,
            kind: JobKind::Array,
            duration,
            cores: 1,
            mem_mb: 2048,
            submit_at: 0.0,
            deps: Vec::new(),
            priority: 0,
            user: 0,
            preemptible: false,
            checkpoint_cost: 0.0,
            max_retries: 3,
        }
    }

    /// Member of a synchronously-parallel (gang-scheduled) job.
    pub fn parallel(id: TaskId, job: JobId, duration: f64, cores: u32) -> Self {
        Self {
            kind: JobKind::Parallel,
            cores,
            ..Self::array(id, job, duration)
        }
    }

    /// Long-running service task: occupies `cores` slots from dispatch
    /// until the run's horizon (`RunOptions::horizon` — required; see
    /// [`Workload::validate_for`]). `duration` is meaningless for a
    /// service and is set to 0 so it cannot leak into work totals.
    pub fn service(id: TaskId, job: JobId, cores: u32) -> Self {
        Self {
            kind: JobKind::Service,
            cores,
            // Services restart elsewhere after a node failure instead
            // of consuming a retry budget.
            max_retries: 0,
            ..Self::array(id, job, 0.0)
        }
    }
}

/// A workload: a set of tasks plus metadata.
#[derive(Clone, Debug, Default)]
pub struct Workload {
    /// All tasks, indexed by `TaskId`.
    pub tasks: Vec<TaskSpec>,
    /// Human-readable label (e.g. "rapid", "fast").
    pub label: String,
}

impl Workload {
    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Total processor-seconds of *batch* work: Σ duration × cores over
    /// non-service tasks. For the paper's 1-core benchmark tasks this
    /// is the plain duration sum; multi-core tasks count every core
    /// they occupy. Service tasks are excluded — they have no finite
    /// work, and counting a placeholder `duration` for them would
    /// poison the T_job denominator of every derived utilization.
    pub fn total_work(&self) -> f64 {
        self.tasks
            .iter()
            .filter(|t| t.kind != JobKind::Service)
            .map(|t| t.duration * t.cores as f64)
            .sum()
    }

    /// True if the workload contains any `JobKind::Service` task.
    pub fn has_services(&self) -> bool {
        self.tasks.iter().any(|t| t.kind == JobKind::Service)
    }

    /// Isolated job execution time per processor, T_job = total work / P,
    /// assuming perfect balance (exact for the paper's constant-time sets).
    pub fn t_job_per_proc(&self, processors: u64) -> f64 {
        self.total_work() / processors as f64
    }

    /// Validate ids are dense, per-task resources sane, and
    /// dependencies acyclic (topological check).
    pub fn validate(&self) -> Result<(), String> {
        for (i, t) in self.tasks.iter().enumerate() {
            if t.id as usize != i {
                return Err(format!("task id {} at index {i} not dense", t.id));
            }
            if t.duration < 0.0 || !t.duration.is_finite() {
                return Err(format!("task {} has invalid duration {}", t.id, t.duration));
            }
            if t.cores == 0 {
                return Err(format!("task {} requires zero cores", t.id));
            }
            if t.mem_mb <= 0 {
                return Err(format!("task {} has non-positive mem_mb {}", t.id, t.mem_mb));
            }
            if !t.submit_at.is_finite() {
                return Err(format!(
                    "task {} has non-finite submit_at {}",
                    t.id, t.submit_at
                ));
            }
            if !(t.checkpoint_cost.is_finite() && t.checkpoint_cost >= 0.0) {
                return Err(format!(
                    "task {} has invalid checkpoint_cost {}",
                    t.id, t.checkpoint_cost
                ));
            }
            for &d in &t.deps {
                if d as usize >= self.tasks.len() {
                    return Err(format!("task {} depends on unknown task {d}", t.id));
                }
                if d == t.id {
                    return Err(format!("task {} depends on itself", t.id));
                }
                if self.tasks[d as usize].kind == JobKind::Service {
                    // A service never completes, so a dependent would
                    // never be admitted — a structural deadlock.
                    return Err(format!(
                        "task {} depends on service task {d}, which never completes",
                        t.id
                    ));
                }
            }
        }
        // Kahn's algorithm for cycle detection.
        let n = self.tasks.len();
        let mut indeg = vec![0usize; n];
        let mut out: Vec<Vec<usize>> = vec![Vec::new(); n];
        for t in &self.tasks {
            for &d in &t.deps {
                indeg[t.id as usize] += 1;
                out[d as usize].push(t.id as usize);
            }
        }
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(i) = ready.pop() {
            seen += 1;
            for &j in &out[i] {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    ready.push(j);
                }
            }
        }
        if seen != n {
            return Err("dependency cycle detected".into());
        }
        Ok(())
    }

    /// [`Workload::validate`] plus run-mode compatibility checks:
    /// `JobKind::Service` tasks never complete, so running them without
    /// [`crate::sched::RunOptions::horizon`] would (before this check)
    /// silently simulate them as batch tasks that "finish" after
    /// `duration` seconds — wrong in every metric. Harness runners call
    /// this before simulating; [`crate::sim::Kernel::run`] enforces the
    /// same rule with a hard panic as a last line of defence.
    pub fn validate_for(&self, options: &crate::sched::RunOptions) -> Result<(), String> {
        self.validate()?;
        match options.horizon {
            None => {
                if let Some(t) = self.tasks.iter().find(|t| t.kind == JobKind::Service) {
                    return Err(format!(
                        "task {} is a Service job but RunOptions.horizon is not set; \
                         services never complete and require a horizon-bounded run",
                        t.id
                    ));
                }
            }
            Some(h) => {
                if !(h.is_finite() && h > 0.0) {
                    return Err(format!("RunOptions.horizon must be finite and > 0, got {h}"));
                }
            }
        }
        options.faults.validate()?;
        if let Some(t) = self
            .tasks
            .iter()
            .find(|t| t.kind == JobKind::Service && t.max_retries != 0)
        {
            return Err(format!(
                "task {} is a Service job with max_retries {}; services restart \
                 elsewhere after a node failure, they do not consume a retry budget \
                 (leave max_retries at 0)",
                t.id, t.max_retries
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl(tasks: Vec<TaskSpec>) -> Workload {
        Workload {
            tasks,
            label: "t".into(),
        }
    }

    #[test]
    fn totals() {
        let w = wl(vec![
            TaskSpec::array(0, 0, 5.0),
            TaskSpec::array(1, 0, 5.0),
            TaskSpec::array(2, 0, 5.0),
            TaskSpec::array(3, 0, 5.0),
        ]);
        assert_eq!(w.total_work(), 20.0);
        assert_eq!(w.t_job_per_proc(2), 10.0);
        w.validate().unwrap();
    }

    #[test]
    fn detects_cycle() {
        let mut a = TaskSpec::array(0, 0, 1.0);
        let mut b = TaskSpec::array(1, 0, 1.0);
        a.deps = vec![1];
        b.deps = vec![0];
        assert!(wl(vec![a, b]).validate().unwrap_err().contains("cycle"));
    }

    #[test]
    fn detects_bad_ids() {
        let t = TaskSpec::array(5, 0, 1.0);
        assert!(wl(vec![t]).validate().is_err());
    }

    #[test]
    fn dag_ok() {
        let mut b = TaskSpec::array(1, 0, 1.0);
        b.deps = vec![0];
        let mut c = TaskSpec::array(2, 0, 1.0);
        c.deps = vec![0, 1];
        wl(vec![TaskSpec::array(0, 0, 1.0), b, c]).validate().unwrap();
    }

    #[test]
    fn rejects_zero_cores() {
        let mut t = TaskSpec::array(0, 0, 1.0);
        t.cores = 0;
        assert!(wl(vec![t]).validate().unwrap_err().contains("zero cores"));
    }

    #[test]
    fn rejects_non_positive_memory() {
        let mut t = TaskSpec::array(0, 0, 1.0);
        t.mem_mb = 0;
        assert!(wl(vec![t]).validate().unwrap_err().contains("mem_mb"));
        let mut t = TaskSpec::array(0, 0, 1.0);
        t.mem_mb = -5;
        assert!(wl(vec![t]).validate().is_err());
    }

    #[test]
    fn rejects_non_finite_submit_time() {
        let mut t = TaskSpec::array(0, 0, 1.0);
        t.submit_at = f64::NAN;
        assert!(wl(vec![t]).validate().unwrap_err().contains("submit_at"));
        let mut t = TaskSpec::array(0, 0, 1.0);
        t.submit_at = f64::INFINITY;
        assert!(wl(vec![t]).validate().is_err());
    }

    #[test]
    fn rejects_self_dependency() {
        let mut t = TaskSpec::array(0, 0, 1.0);
        t.deps = vec![0];
        assert!(wl(vec![t]).validate().unwrap_err().contains("itself"));
    }

    #[test]
    fn rejects_invalid_checkpoint_cost() {
        let mut t = TaskSpec::array(0, 0, 1.0);
        t.checkpoint_cost = f64::NAN;
        assert!(wl(vec![t])
            .validate()
            .unwrap_err()
            .contains("checkpoint_cost"));
        let mut t = TaskSpec::array(0, 0, 1.0);
        t.checkpoint_cost = -1.0;
        assert!(wl(vec![t]).validate().is_err());
    }

    #[test]
    fn preemption_fields_default_off() {
        let t = TaskSpec::array(0, 0, 1.0);
        assert!(!t.preemptible);
        assert_eq!(t.checkpoint_cost, 0.0);
        assert_eq!(t.priority, 0);
        assert_eq!(t.user, 0);
        assert_eq!(t.max_retries, 3);
    }

    #[test]
    fn parallel_helper_sets_kind() {
        let t = TaskSpec::parallel(3, 1, 2.0, 4);
        assert_eq!(t.kind, JobKind::Parallel);
        assert_eq!(t.cores, 4);
        assert_eq!(t.job, 1);
    }

    #[test]
    fn service_helper_sets_kind_and_zero_duration() {
        let t = TaskSpec::service(2, 7, 4);
        assert_eq!(t.kind, JobKind::Service);
        assert_eq!(t.cores, 4);
        assert_eq!(t.duration, 0.0);
    }

    #[test]
    fn total_work_excludes_services() {
        let w = wl(vec![
            TaskSpec::service(0, 0, 8),
            TaskSpec::array(1, 1, 5.0),
            TaskSpec::array(2, 1, 5.0),
        ]);
        assert_eq!(w.total_work(), 10.0);
        assert!(w.has_services());
    }

    #[test]
    fn rejects_dependency_on_a_service() {
        let svc = TaskSpec::service(0, 0, 1);
        let mut child = TaskSpec::array(1, 1, 1.0);
        child.deps = vec![0];
        let err = wl(vec![svc, child]).validate().unwrap_err();
        assert!(err.contains("service"), "{err}");
        // A service depending ON a batch task (setup-then-serve) is fine.
        let setup = TaskSpec::array(0, 0, 1.0);
        let mut svc = TaskSpec::service(1, 1, 1);
        svc.deps = vec![0];
        wl(vec![setup, svc]).validate().unwrap();
    }

    #[test]
    fn service_without_horizon_is_rejected() {
        use crate::sched::RunOptions;
        let w = wl(vec![TaskSpec::service(0, 0, 1), TaskSpec::array(1, 1, 1.0)]);
        let err = w.validate_for(&RunOptions::default()).unwrap_err();
        assert!(err.contains("horizon"), "{err}");
        w.validate_for(&RunOptions::with_horizon(100.0)).unwrap();
        // Bad horizons are rejected too.
        assert!(w.validate_for(&RunOptions::with_horizon(f64::NAN)).is_err());
        assert!(w.validate_for(&RunOptions::with_horizon(0.0)).is_err());
        // Batch-only workloads don't need a horizon.
        wl(vec![TaskSpec::array(0, 0, 1.0)])
            .validate_for(&RunOptions::default())
            .unwrap();
    }

    #[test]
    fn service_helper_has_no_retry_budget() {
        assert_eq!(TaskSpec::service(0, 0, 1).max_retries, 0);
    }

    #[test]
    fn validate_for_rejects_service_with_retry_budget() {
        use crate::sched::RunOptions;
        let mut svc = TaskSpec::service(0, 0, 1);
        svc.max_retries = 2;
        let err = wl(vec![svc])
            .validate_for(&RunOptions::with_horizon(100.0))
            .unwrap_err();
        assert!(err.contains("retry budget"), "{err}");
    }

    #[test]
    fn validate_for_rejects_malformed_fault_plans() {
        use crate::cluster::FaultPlan;
        use crate::sched::RunOptions;
        let w = wl(vec![TaskSpec::array(0, 0, 1.0)]);
        // Well-formed plan passes.
        w.validate_for(&RunOptions::with_faults(
            FaultPlan::none().fail(5.0, 0).recover(9.0, 0),
        ))
        .unwrap();
        // Event before t=0.
        let err = w
            .validate_for(&RunOptions::with_faults(FaultPlan::none().fail(-1.0, 0)))
            .unwrap_err();
        assert!(err.contains("t=0"), "{err}");
        // Non-finite time.
        assert!(w
            .validate_for(&RunOptions::with_faults(FaultPlan::none().fail(f64::NAN, 0)))
            .is_err());
        // Fail of an already-failed node.
        assert!(w
            .validate_for(&RunOptions::with_faults(
                FaultPlan::none().fail(1.0, 0).fail(2.0, 0)
            ))
            .is_err());
        // Recover of a healthy node.
        assert!(w
            .validate_for(&RunOptions::with_faults(FaultPlan::none().recover(1.0, 0)))
            .is_err());
    }
}
