//! Arrival processes: when tasks reach the scheduler.
//!
//! The Table 9 benchmark submits everything at t=0 (one job array); the
//! paper's §1/§5 discussion of *on-demand* vs *batch* scheduling is
//! about sustained arrival streams — big data jobs "are expected to
//! execute immediately; that is, they tend not to wait in batch
//! queues". These processes stamp `submit_at` to model that.

use super::types::{JobKind, Workload};
use crate::util::prng::Prng;

/// Arrival process for a workload.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalProcess {
    /// Everything at t = 0 (the Table 9 job-array benchmark).
    AllAtOnce,
    /// Poisson arrivals at `rate` tasks/second.
    Poisson {
        /// Mean arrival rate (tasks/s).
        rate: f64,
    },
    /// On/off bursts: `burst` tasks arrive together every `period` s.
    Bursty {
        /// Tasks per burst.
        burst: u32,
        /// Seconds between bursts.
        period: f64,
    },
}

impl ArrivalProcess {
    /// Stamp submit times onto a workload (in task order). Service
    /// tasks are left untouched: they model resident daemons that are
    /// up from t = 0, not part of the arriving stream.
    pub fn apply(&self, workload: &mut Workload, seed: u64) {
        let mut rng = Prng::new(seed ^ 0xA221_7A15);
        let arriving = workload
            .tasks
            .iter_mut()
            .filter(|t| t.kind != JobKind::Service);
        match *self {
            ArrivalProcess::AllAtOnce => {
                for t in arriving {
                    t.submit_at = 0.0;
                }
            }
            ArrivalProcess::Poisson { rate } => {
                assert!(rate > 0.0, "rate must be positive");
                let mut now = 0.0;
                for t in arriving {
                    now += rng.exponential(1.0 / rate);
                    t.submit_at = now;
                }
            }
            ArrivalProcess::Bursty { burst, period } => {
                assert!(burst > 0 && period > 0.0);
                for (i, t) in arriving.enumerate() {
                    t.submit_at = (i as u32 / burst) as f64 * period;
                }
            }
        }
    }
}

/// Offered load ρ = arrival rate × mean task time / processors.
pub fn offered_load(rate: f64, mean_task_time: f64, processors: u64) -> f64 {
    rate * mean_task_time / processors as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadBuilder;

    #[test]
    fn poisson_rate_approximates() {
        let mut w = WorkloadBuilder::constant(1.0).tasks(10_000).build();
        ArrivalProcess::Poisson { rate: 50.0 }.apply(&mut w, 1);
        let last = w.tasks.last().unwrap().submit_at;
        let rate = 10_000.0 / last;
        assert!((rate - 50.0).abs() < 2.5, "rate={rate}");
        // Monotone non-decreasing submit times.
        assert!(w.tasks.windows(2).all(|p| p[1].submit_at >= p[0].submit_at));
    }

    #[test]
    fn bursts_group_tasks() {
        let mut w = WorkloadBuilder::constant(1.0).tasks(10).build();
        ArrivalProcess::Bursty { burst: 4, period: 10.0 }.apply(&mut w, 0);
        assert_eq!(w.tasks[0].submit_at, 0.0);
        assert_eq!(w.tasks[3].submit_at, 0.0);
        assert_eq!(w.tasks[4].submit_at, 10.0);
        assert_eq!(w.tasks[9].submit_at, 20.0);
    }

    #[test]
    fn load_arithmetic() {
        assert!((offered_load(100.0, 5.0, 1000) - 0.5).abs() < 1e-12);
    }
}
