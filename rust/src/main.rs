//! sssched — CLI for the "Scalable System Scheduling for HPC and Big
//! Data" reproduction.
//!
//! Subcommands:
//!   features    render the paper's feature-comparison Tables 1–7
//!   experiment  run table9 | table10 | fig4 | fig5 | fig6 | fig7 |
//!               scenarios | preempt | service | churn | degraded |
//!               scale | model | all
//!   serve       realtime mini-cluster (leader + worker threads, PJRT payloads)
//!   validate    run every experiment's shape checks at reduced scale
//!
//! Common options: --config <toml>, --quick (scaled-down cluster),
//! --huge (adds a 10⁷-task point to the `scale` sweep), --churn (adds
//! the fault-plan refit phase to the `model` experiment), --trials N,
//! --jobs N (sweep worker threads; results are bit-identical for any
//! value), --out-dir <dir>, --artifacts <dir>, --csv.

use sssched::cli::Args;
use sssched::config::{validate_experiment, ExperimentConfig, EXPERIMENT_NAMES};
use sssched::exec::{RealtimeCoordinator, RealtimeParams, RtTask, RtWork};
use sssched::features::{feature_table, FeatureCategory};
use sssched::harness;
use sssched::multilevel::MultilevelParams;
use sssched::util::table::fnum;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_deref() {
        Some("features") => cmd_features(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("serve") => cmd_serve(&args),
        Some("validate") => cmd_validate(&args),
        Some(other) => {
            eprintln!("unknown command `{other}`");
            usage();
            2
        }
        None => {
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "usage: sssched <command> [options]\n\
         commands:\n\
         \x20 features   [--table 1..7] [--csv]\n\
         \x20 experiment <table9|table10|fig4|fig5|fig6|fig7|scenarios|preempt|service|churn|degraded|scale|model|all> \
         [--config f] [--quick] [--huge] [--churn] [--trials N] [--jobs N] [--out-dir d] [--artifacts d] [--csv]\n\
         \x20 serve      [--workers N] [--tasks N] [--task-ms MS] \
         [--payload sleep|spin|analytics] [--ts SECS] [--artifacts d]\n\
         \x20 validate   [--quick]"
    );
}

fn load_config(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.opt("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::default(),
    };
    if args.flag("quick") {
        cfg.scale_down = 8; // 5 nodes × 32 = 160 cores
        cfg.trials = 1;
        // Reduced-size `scale` sweep (the CI perf-smoke shape): still
        // large enough for a meaningful wall-time exponent fit.
        cfg.scale_ns = vec![2_000, 8_000, 32_000];
        cfg.scale_procs = vec![1_000];
    }
    if args.flag("huge") {
        // Appended by the scale runner, so it composes with --quick and
        // config-file sweeps alike.
        cfg.scale_huge = true;
    }
    if let Some(t) = args.opt("trials") {
        cfg.trials = t.parse().map_err(|_| "bad --trials")?;
    }
    if let Some(j) = args.opt("jobs") {
        cfg.jobs = j.parse().map_err(|_| "bad --jobs")?;
    }
    if let Some(d) = args.opt("out-dir") {
        cfg.out_dir = d.to_string();
    }
    cfg.validate()?;
    Ok(cfg)
}

fn artifacts_dir(args: &Args) -> String {
    args.opt("artifacts").unwrap_or("artifacts").to_string()
}

fn cmd_features(args: &Args) -> i32 {
    let categories: Vec<FeatureCategory> = match args.opt("table") {
        Some(n) => {
            let n: u32 = match n.parse() {
                Ok(v @ 1..=7) => v,
                _ => {
                    eprintln!("--table must be 1..7");
                    return 2;
                }
            };
            FeatureCategory::all()
                .into_iter()
                .filter(|c| c.table_number() == n)
                .collect()
        }
        None => FeatureCategory::all().to_vec(),
    };
    for c in categories {
        let t = feature_table(c);
        if args.flag("csv") {
            print!("{}", t.to_csv());
        } else {
            println!("{}", t.render());
        }
    }
    0
}

fn write_out(cfg: &ExperimentConfig, name: &str, content: &str) {
    let dir = std::path::Path::new(&cfg.out_dir);
    if std::fs::create_dir_all(dir).is_ok() {
        let path = dir.join(name);
        if std::fs::write(&path, content).is_ok() {
            println!("wrote {}", path.display());
        }
    }
}

fn cmd_experiment(args: &Args) -> i32 {
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let what = args.positionals.first().map(|s| s.as_str()).unwrap_or("all");
    let arts = artifacts_dir(args);
    let ml = MultilevelParams::default();
    let run = |name: &str| -> i32 {
        match name {
            "table9" => {
                let rep = harness::table9(&cfg);
                println!("{}", rep.render().render());
                write_out(&cfg, "table9.csv", &rep.render().to_csv());
            }
            "table10" => {
                let rep = harness::table10(&cfg, Some(&arts));
                println!("{}", rep.render().render());
                if let Err(e) = rep.check_shape() {
                    eprintln!("shape check FAILED: {e}");
                    return 1;
                }
                println!("shape check vs paper: OK");
                write_out(&cfg, "table10.csv", &rep.render().to_csv());
            }
            "fig4" => {
                let rep = harness::fig4(&cfg);
                println!("{}", rep.render_plots());
                write_out(&cfg, "fig4.csv", &rep.to_csv());
            }
            "fig5" => {
                let rep = harness::fig5(&cfg, Some(&arts));
                println!("{}", rep.render_plot());
                println!(
                    "(model curves computed via {})",
                    if rep.used_pjrt { "artifact suite" } else { "rust fallback" }
                );
                write_out(&cfg, "fig5.csv", &rep.to_csv());
            }
            "fig6" => {
                let rep = harness::fig6(&cfg, &ml);
                println!("{}", rep.render_plots());
                println!("{}", rep.render_table().render());
                write_out(&cfg, "fig6.csv", &rep.render_table().to_csv());
            }
            "fig7" => {
                let rep = harness::fig7(&cfg, &ml);
                println!("{}", rep.render_plots());
                println!("{}", rep.render_table().render());
                write_out(&cfg, "fig7.csv", &rep.render_table().to_csv());
            }
            "scenarios" => {
                let rep = harness::scenarios(&cfg);
                println!("{}", rep.render_table().render());
                if let Err(e) = rep.check_shape(cfg.trials) {
                    eprintln!("shape check FAILED: {e}");
                    return 1;
                }
                println!("shape checks: OK");
                write_out(&cfg, "scenarios.csv", &rep.to_csv());
            }
            "preempt" => {
                let rep = harness::preempt(&cfg);
                println!("{}", rep.render_table().render());
                if let Err(e) = rep.check_shape(cfg.trials) {
                    eprintln!("shape check FAILED: {e}");
                    return 1;
                }
                println!("shape checks: OK");
                write_out(&cfg, "preempt.csv", &rep.to_csv());
            }
            "service" => {
                let rep = harness::service(&cfg);
                println!("{}", rep.render_table().render());
                if let Err(e) = rep.check_shape(cfg.trials) {
                    eprintln!("shape check FAILED: {e}");
                    return 1;
                }
                println!("shape checks: OK");
                write_out(&cfg, "service.csv", &rep.to_csv());
            }
            "churn" => {
                let rep = harness::churn(&cfg);
                println!("{}", rep.render_table().render());
                if let Err(e) = rep.check_shape(cfg.trials) {
                    eprintln!("shape check FAILED: {e}");
                    return 1;
                }
                println!("shape checks (incl. fault-free coverage gate): OK");
                write_out(&cfg, "churn.csv", &rep.to_csv());
            }
            "degraded" => {
                let rep = harness::degraded(&cfg);
                println!("{}", rep.render_table().render());
                println!("{}", rep.render_fits().render());
                if let Err(e) = rep.check_shape(cfg.trials) {
                    eprintln!("shape check FAILED: {e}");
                    return 1;
                }
                println!(
                    "shape checks (incl. control-row purity + goodput \
                     monotonicity + detection-latency floor): OK"
                );
                write_out(&cfg, "degraded.csv", &rep.to_csv());
            }
            "scale" => {
                let rep = harness::scale(&cfg);
                println!("{}", rep.render_table().render());
                println!("{}", rep.render_fits().render());
                if let Err(e) = rep.check_shape(&cfg) {
                    eprintln!("shape check FAILED: {e}");
                    return 1;
                }
                println!("shape checks (incl. exponent gate + eager bit-identity): OK");
                write_out(&cfg, "scale.csv", &rep.to_csv());
            }
            "model" => {
                let rep = harness::model(&cfg, args.flag("churn"));
                println!("{}", rep.render_fits().render());
                println!("{}", rep.render_tune().render());
                if let Some(t) = rep.render_churn() {
                    println!("{}", t.render());
                }
                if let Err(e) = rep.check_shape(&cfg) {
                    eprintln!("shape check FAILED: {e}");
                    return 1;
                }
                println!("shape checks (incl. R2 gate + predicted-vs-simulated eps): OK");
                write_out(&cfg, "model.csv", &rep.to_csv());
            }
            other => {
                eprintln!("unknown experiment `{other}`");
                return 2;
            }
        }
        0
    };
    // Fail fast on typos before any experiment runs; `run`'s own
    // fallback arm stays as a defensive backstop.
    if let Err(e) = validate_experiment(what) {
        eprintln!("{e}");
        return 2;
    }
    if what == "all" {
        for name in EXPERIMENT_NAMES {
            let rc = run(name);
            if rc != 0 {
                return rc;
            }
        }
        0
    } else {
        run(what)
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let workers = args.opt_parse("workers", 4usize).unwrap_or(4);
    let n_tasks = args.opt_parse("tasks", 64u32).unwrap_or(64);
    let task_ms = args.opt_parse("task-ms", 20.0f64).unwrap_or(20.0);
    let ts = args.opt_parse("ts", 0.0f64).unwrap_or(0.0);
    let payload = args.opt("payload").unwrap_or("spin");
    let arts = artifacts_dir(args);

    let nominal = task_ms / 1000.0;
    let tasks: Vec<RtTask> = (0..n_tasks)
        .map(|id| RtTask {
            id,
            nominal,
            work: match payload {
                "sleep" => RtWork::Sleep(nominal),
                "analytics" => RtWork::Analytics {
                    // ~0.45 ms per batch on this CPU; scale count to the
                    // requested nominal duration.
                    batches: ((nominal / 0.00045).ceil() as u32).max(1),
                    seed: id as u64,
                },
                _ => RtWork::Spin(nominal),
            },
        })
        .collect();

    let coord = RealtimeCoordinator::new(RealtimeParams {
        workers,
        dispatch_overhead: ts,
        artifacts_dir: (payload == "analytics").then(|| arts),
    });
    match coord.run(&tasks) {
        Ok(r) => {
            println!(
                "{} tasks x {} ms on {} workers (payload={payload}, ts={ts}s)",
                n_tasks, task_ms, workers
            );
            println!(
                "T_total={} s  T_job={} s  U={:.3}  throughput={:.1} tasks/s",
                fnum(r.t_total),
                fnum(r.t_job),
                r.utilization(),
                r.n_tasks as f64 / r.t_total.max(1e-9),
            );
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

fn cmd_validate(args: &Args) -> i32 {
    let mut cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    if !args.flag("full") {
        cfg.scale_down = 8;
        cfg.trials = 1;
        // Tiny scale sweep: exercises the machinery (and the eager
        // bit-identity assert) without the multi-second timing cells;
        // the wall-time exponent gate needs larger n and stays with the
        // `experiment scale --quick` / CI perf-smoke path.
        cfg.scale_ns = vec![500, 2_000];
        cfg.scale_procs = vec![500];
    }
    let arts = artifacts_dir(args);
    let ml = MultilevelParams::default();
    let mut failures = 0;
    let mut check = |name: &str, result: Result<(), String>| match result {
        Ok(()) => println!("  ok  {name}"),
        Err(e) => {
            println!("FAIL  {name}: {e}");
            failures += 1;
        }
    };
    println!("validate (P={}, trials={}):", cfg.processors(), cfg.trials);
    check("table9 shapes", harness::table9(&cfg).check_shape(0.35));
    check("table10 shapes", harness::table10(&cfg, Some(&arts)).check_shape());
    check("fig4 shapes", harness::fig4(&cfg).check_shape());
    check("fig5 shapes", harness::fig5(&cfg, Some(&arts)).check_shape());
    check("fig6 shapes", harness::fig6(&cfg, &ml).check_shape());
    check("fig7 shapes", harness::fig7(&cfg, &ml).check_shape());
    check(
        "scenarios shapes",
        harness::scenarios(&cfg).check_shape(cfg.trials),
    );
    check(
        "preempt shapes",
        harness::preempt(&cfg).check_shape(cfg.trials),
    );
    check(
        "service shapes",
        harness::service(&cfg).check_shape(cfg.trials),
    );
    check("churn shapes", harness::churn(&cfg).check_shape(cfg.trials));
    check(
        "degraded shapes",
        harness::degraded(&cfg).check_shape(cfg.trials),
    );
    check("scale shapes", harness::scale(&cfg).check_shape(&cfg));
    check("model shapes", harness::model(&cfg, false).check_shape(&cfg));
    if failures == 0 {
        println!("all shape checks passed");
        0
    } else {
        1
    }
}
