//! Shared sweep machinery: run a scheduler over a set of
//! tasks-per-processor values at fixed per-processor work (the paper's
//! T_job = 240 s), several trials each.

use crate::cluster::ClusterSpec;
use crate::config::{ExperimentConfig, SchedulerChoice};
use crate::multilevel::{Multilevel, MultilevelParams};
use crate::sched::{make_scheduler_scaled, RunOptions, RunResult, Scheduler};
use crate::workload::{Workload, WorkloadBuilder, TABLE9_JOB_TIME_PER_PROC};

/// Runs projected past this virtual-seconds bound are skipped, like the
/// paper's abandoned YARN rapid trials.
pub const PROHIBITIVE_SECS: f64 = 3600.0;

/// All trials at one tasks-per-processor value.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Tasks per processor n.
    pub n: u32,
    /// Task time t = T_job / n.
    pub t: f64,
    /// One result per trial.
    pub trials: Vec<RunResult>,
}

impl SweepPoint {
    /// Mean T_total across trials.
    pub fn mean_t_total(&self) -> f64 {
        self.trials.iter().map(|r| r.t_total).sum::<f64>() / self.trials.len() as f64
    }

    /// Mean ΔT across trials.
    pub fn mean_delta_t(&self) -> f64 {
        self.trials.iter().map(|r| r.delta_t()).sum::<f64>() / self.trials.len() as f64
    }

    /// Mean utilization across trials.
    pub fn mean_utilization(&self) -> f64 {
        self.trials.iter().map(|r| r.utilization()).sum::<f64>()
            / self.trials.len() as f64
    }
}

/// A full sweep for one scheduler.
#[derive(Clone, Debug)]
pub struct SchedulerSweep {
    /// Scheduler display name.
    pub scheduler: String,
    /// Points actually run.
    pub points: Vec<SweepPoint>,
    /// n values skipped as prohibitive.
    pub skipped: Vec<u32>,
}

impl SchedulerSweep {
    /// Pooled (n, ΔT) observations across all trials (fit input).
    pub fn fit_points(&self) -> Vec<(f64, f64)> {
        self.points
            .iter()
            .flat_map(|p| p.trials.iter().map(|r| (p.n as f64, r.delta_t())))
            .collect()
    }
}

fn cluster_of(cfg: &ExperimentConfig) -> ClusterSpec {
    ClusterSpec::homogeneous(
        cfg.effective_nodes(),
        cfg.cores_per_node,
        cfg.mem_mb,
        (cfg.effective_nodes() / 2).max(1),
    )
}

fn workload_for(n: u32, processors: u64, label: &str) -> Workload {
    let t = TABLE9_JOB_TIME_PER_PROC / n as f64;
    WorkloadBuilder::constant(t)
        .tasks(n as u64 * processors)
        .label(label)
        .build()
}

/// Run `choice` over `n_values`, `cfg.trials` trials each. When
/// `multilevel` is given, the workload is routed through the
/// LLMapReduce-style aggregator first (Figures 6–7).
pub fn run_sweep(
    choice: SchedulerChoice,
    cfg: &ExperimentConfig,
    n_values: &[u32],
    multilevel: Option<&MultilevelParams>,
) -> SchedulerSweep {
    let cluster = cluster_of(cfg);
    let processors = cluster.total_cores();
    // Scaled daemon costs keep the experiment shape-invariant on
    // scaled-down clusters (see make_scheduler_scaled).
    let inner = make_scheduler_scaled(choice, cfg.scale_down);
    let mut points = Vec::new();
    let mut skipped = Vec::new();

    for &n in n_values {
        let t = TABLE9_JOB_TIME_PER_PROC / n as f64;
        let label = format!("n{n}");
        let workload = workload_for(n, processors, &label);
        let projected = match multilevel {
            Some(ml) => Multilevel::new(inner.as_ref(), ml.clone())
                .projected_runtime(&workload, &cluster),
            None => inner.projected_runtime(&workload, &cluster),
        };
        if projected > PROHIBITIVE_SECS {
            skipped.push(n);
            continue;
        }
        let mut trials = Vec::with_capacity(cfg.trials as usize);
        for trial in 0..cfg.trials {
            let seed = cfg
                .seed
                .wrapping_add(trial as u64)
                .wrapping_add((n as u64) << 20);
            let r = match multilevel {
                Some(ml) => Multilevel::new(inner.as_ref(), ml.clone()).run(
                    &workload,
                    &cluster,
                    seed,
                    &RunOptions::default(),
                ),
                None => inner.run(&workload, &cluster, seed, &RunOptions::default()),
            };
            r.check_invariants()
                .unwrap_or_else(|e| panic!("{} n={n}: {e}", inner.name()));
            trials.push(r);
        }
        points.push(SweepPoint { n, t, trials });
    }

    SchedulerSweep {
        scheduler: match multilevel {
            Some(_) => format!("{}+multilevel", inner.name()),
            None => inner.name().to_string(),
        },
        points,
        skipped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.scale_down = 11; // 4 nodes, 128 cores — fast in tests
        cfg.trials = 1;
        cfg
    }

    #[test]
    fn sweep_runs_all_points() {
        let s = run_sweep(SchedulerChoice::Slurm, &quick_cfg(), &[4, 8], None);
        assert_eq!(s.points.len(), 2);
        assert!(s.skipped.is_empty());
        assert_eq!(s.points[0].trials.len(), 1);
        assert!((s.points[0].t - 60.0).abs() < 1e-9);
    }

    #[test]
    fn yarn_rapid_is_skipped() {
        let s = run_sweep(SchedulerChoice::Yarn, &quick_cfg(), &[240], None);
        assert!(s.points.is_empty());
        assert_eq!(s.skipped, vec![240]);
    }

    #[test]
    fn multilevel_sweep_labels() {
        let ml = MultilevelParams::default();
        let s = run_sweep(SchedulerChoice::Mesos, &quick_cfg(), &[8], Some(&ml));
        assert!(s.scheduler.contains("multilevel"));
        assert_eq!(s.points.len(), 1);
    }

    #[test]
    fn fit_points_pool_trials() {
        let mut cfg = quick_cfg();
        cfg.trials = 2;
        let s = run_sweep(SchedulerChoice::Slurm, &cfg, &[4, 8], None);
        assert_eq!(s.fit_points().len(), 4);
    }
}
