//! Shared sweep machinery: run schedulers over a set of
//! tasks-per-processor values at fixed per-processor work (the paper's
//! T_job = 240 s), several trials each.
//!
//! Since this PR, sweeps execute on the deterministic parallel cell
//! executor ([`super::parallel::run_cells`]): every `(scheduler, n,
//! trial)` cell derives its seed exactly as the serial code did, so the
//! assembled results are bit-identical for any `--jobs` value.

use crate::cluster::ClusterSpec;
use crate::config::{ExperimentConfig, SchedulerChoice};
use crate::multilevel::{Multilevel, MultilevelParams};
use crate::sched::{make_scheduler_scaled, RunOptions, RunResult, Scheduler};
use crate::workload::{Workload, WorkloadBuilder, TABLE9_JOB_TIME_PER_PROC};

use super::parallel::run_cells;

/// Runs projected past this virtual-seconds bound are skipped, like the
/// paper's abandoned YARN rapid trials.
pub const PROHIBITIVE_SECS: f64 = 3600.0;

/// All trials at one tasks-per-processor value.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Tasks per processor n.
    pub n: u32,
    /// Task time t = T_job / n.
    pub t: f64,
    /// One result per trial.
    pub trials: Vec<RunResult>,
}

/// Mean of `f` over a set of trial results, guarding the empty case:
/// an all-skipped point or cell would otherwise divide by zero and
/// leak NaN into CSVs and power-law fits. Shared by [`SweepPoint`] and
/// the scenario/preempt/service cell types.
pub(crate) fn trial_mean(trials: &[RunResult], f: impl Fn(&RunResult) -> f64) -> f64 {
    if trials.is_empty() {
        return 0.0;
    }
    trials.iter().map(f).sum::<f64>() / trials.len() as f64
}

impl SweepPoint {
    /// Mean T_total across trials (0 when no trials ran).
    pub fn mean_t_total(&self) -> f64 {
        trial_mean(&self.trials, |r| r.t_total)
    }

    /// Mean ΔT across trials (0 when no trials ran).
    pub fn mean_delta_t(&self) -> f64 {
        trial_mean(&self.trials, |r| r.delta_t())
    }

    /// Mean utilization across trials (0 when no trials ran).
    pub fn mean_utilization(&self) -> f64 {
        trial_mean(&self.trials, |r| r.utilization())
    }
}

/// A full sweep for one scheduler.
#[derive(Clone, Debug)]
pub struct SchedulerSweep {
    /// Scheduler display name.
    pub scheduler: String,
    /// Points actually run.
    pub points: Vec<SweepPoint>,
    /// n values skipped as prohibitive.
    pub skipped: Vec<u32>,
}

impl SchedulerSweep {
    /// Pooled (n, ΔT) observations across all trials (fit input).
    pub fn fit_points(&self) -> Vec<(f64, f64)> {
        self.points
            .iter()
            .flat_map(|p| p.trials.iter().map(|r| (p.n as f64, r.delta_t())))
            .collect()
    }
}

pub(crate) fn cluster_of(cfg: &ExperimentConfig) -> ClusterSpec {
    ClusterSpec::homogeneous(
        cfg.effective_nodes(),
        cfg.cores_per_node,
        cfg.mem_mb,
        (cfg.effective_nodes() / 2).max(1),
    )
}

pub(crate) fn workload_for(n: u32, processors: u64, label: &str) -> Workload {
    let t = TABLE9_JOB_TIME_PER_PROC / n as f64;
    WorkloadBuilder::constant(t)
        .tasks(n as u64 * processors)
        .label(label)
        .build()
}

/// One sweep request: a scheduler choice, optionally routed through the
/// LLMapReduce-style aggregator (Figures 6–7).
pub type SweepSpec<'a> = (SchedulerChoice, Option<&'a MultilevelParams>);

/// One executable simulation cell of a sweep batch.
struct Cell<'a> {
    /// Index into the spec/sweep list.
    sweep: usize,
    /// Index into that sweep's points.
    point: usize,
    /// Tasks per processor (for diagnostics).
    n: u32,
    /// Derived seed — same formula as the seed repo's serial loop.
    seed: u64,
    /// Workload shared by every cell at this n.
    workload: &'a Workload,
}

/// Run every `(scheduler, n, trial)` cell of `specs` × `n_values` ×
/// `cfg.trials` on `cfg.effective_jobs()` worker threads and assemble
/// per-spec sweeps. Cell seeds and result ordering are independent of
/// the worker count, so outputs are bit-identical for any `jobs`.
pub fn run_sweeps(
    specs: &[SweepSpec],
    cfg: &ExperimentConfig,
    n_values: &[u32],
) -> Vec<SchedulerSweep> {
    let cluster = cluster_of(cfg);
    let processors = cluster.total_cores();
    // Scaled daemon costs keep the experiment shape-invariant on
    // scaled-down clusters (see make_scheduler_scaled).
    let schedulers: Vec<Box<dyn Scheduler>> = specs
        .iter()
        .map(|&(choice, _)| make_scheduler_scaled(choice, cfg.scale_down))
        .collect();

    // One workload per n, shared by every spec and trial at that n.
    let workloads: Vec<(u32, f64, Workload)> = n_values
        .iter()
        .map(|&n| {
            let t = TABLE9_JOB_TIME_PER_PROC / n as f64;
            let label = format!("n{n}");
            (n, t, workload_for(n, processors, &label))
        })
        .collect();

    // Skeleton sweeps + the flat cell list (cells ordered by sweep,
    // then point, then trial — reassembly below relies on this).
    let mut sweeps: Vec<SchedulerSweep> = Vec::with_capacity(specs.len());
    let mut cells: Vec<Cell> = Vec::new();
    for (si, &(_, ml)) in specs.iter().enumerate() {
        let inner = schedulers[si].as_ref();
        let mut points = Vec::new();
        let mut skipped = Vec::new();
        for &(n, t, ref workload) in &workloads {
            let projected = match ml {
                Some(params) => Multilevel::new(inner, params.clone())
                    .projected_runtime(workload, &cluster),
                None => inner.projected_runtime(workload, &cluster),
            };
            if projected > PROHIBITIVE_SECS {
                skipped.push(n);
                continue;
            }
            let point = points.len();
            for trial in 0..cfg.trials {
                let seed = cfg
                    .seed
                    .wrapping_add(trial as u64)
                    .wrapping_add((n as u64) << 20);
                cells.push(Cell {
                    sweep: si,
                    point,
                    n,
                    seed,
                    workload,
                });
            }
            points.push(SweepPoint {
                n,
                t,
                trials: Vec::with_capacity(cfg.trials as usize),
            });
        }
        sweeps.push(SchedulerSweep {
            scheduler: match ml {
                Some(_) => format!("{}+multilevel", inner.name()),
                None => inner.name().to_string(),
            },
            points,
            skipped,
        });
    }

    let results = run_cells(cfg.effective_jobs(), &cells, |cell, scratch| {
        let inner = schedulers[cell.sweep].as_ref();
        let options = RunOptions::default();
        let r = match specs[cell.sweep].1 {
            Some(params) => Multilevel::new(inner, params.clone()).run_with_scratch(
                cell.workload,
                &cluster,
                cell.seed,
                &options,
                scratch,
            ),
            None => inner.run_with_scratch(cell.workload, &cluster, cell.seed, &options, scratch),
        };
        r.check_invariants()
            .unwrap_or_else(|e| panic!("{} n={}: {e}", inner.name(), cell.n));
        r
    });

    for (cell, result) in cells.iter().zip(results) {
        sweeps[cell.sweep].points[cell.point].trials.push(result);
    }
    sweeps
}

/// Run `choice` over `n_values`, `cfg.trials` trials each. When
/// `multilevel` is given, the workload is routed through the
/// LLMapReduce-style aggregator first (Figures 6–7).
pub fn run_sweep(
    choice: SchedulerChoice,
    cfg: &ExperimentConfig,
    n_values: &[u32],
    multilevel: Option<&MultilevelParams>,
) -> SchedulerSweep {
    run_sweeps(&[(choice, multilevel)], cfg, n_values)
        .pop()
        .expect("one spec in, one sweep out")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.scale_down = 11; // 4 nodes, 128 cores — fast in tests
        cfg.trials = 1;
        cfg
    }

    #[test]
    fn sweep_runs_all_points() {
        let s = run_sweep(SchedulerChoice::Slurm, &quick_cfg(), &[4, 8], None);
        assert_eq!(s.points.len(), 2);
        assert!(s.skipped.is_empty());
        assert_eq!(s.points[0].trials.len(), 1);
        assert!((s.points[0].t - 60.0).abs() < 1e-9);
    }

    #[test]
    fn yarn_rapid_is_skipped() {
        let s = run_sweep(SchedulerChoice::Yarn, &quick_cfg(), &[240], None);
        assert!(s.points.is_empty());
        assert_eq!(s.skipped, vec![240]);
    }

    #[test]
    fn multilevel_sweep_labels() {
        let ml = MultilevelParams::default();
        let s = run_sweep(SchedulerChoice::Mesos, &quick_cfg(), &[8], Some(&ml));
        assert!(s.scheduler.contains("multilevel"));
        assert_eq!(s.points.len(), 1);
    }

    #[test]
    fn empty_point_means_are_zero_not_nan() {
        let p = SweepPoint {
            n: 4,
            t: 60.0,
            trials: Vec::new(),
        };
        assert_eq!(p.mean_t_total(), 0.0);
        assert_eq!(p.mean_delta_t(), 0.0);
        assert_eq!(p.mean_utilization(), 0.0);
        assert!(p.mean_t_total().is_finite());
    }

    #[test]
    fn fit_points_pool_trials() {
        let mut cfg = quick_cfg();
        cfg.trials = 2;
        let s = run_sweep(SchedulerChoice::Slurm, &cfg, &[4, 8], None);
        assert_eq!(s.fit_points().len(), 4);
    }

    #[test]
    fn batched_sweeps_match_individual_sweeps() {
        let cfg = quick_cfg();
        let ml = MultilevelParams::default();
        let batch = run_sweeps(
            &[
                (SchedulerChoice::Slurm, None),
                (SchedulerChoice::Mesos, Some(&ml)),
            ],
            &cfg,
            &[4, 8],
        );
        let solo_slurm = run_sweep(SchedulerChoice::Slurm, &cfg, &[4, 8], None);
        let solo_mesos = run_sweep(SchedulerChoice::Mesos, &cfg, &[4, 8], Some(&ml));
        for (a, b) in [(&batch[0], &solo_slurm), (&batch[1], &solo_mesos)] {
            assert_eq!(a.scheduler, b.scheduler);
            assert_eq!(a.points.len(), b.points.len());
            for (pa, pb) in a.points.iter().zip(&b.points) {
                for (ra, rb) in pa.trials.iter().zip(&pb.trials) {
                    assert_eq!(ra.t_total.to_bits(), rb.t_total.to_bits());
                    assert_eq!(ra.events, rb.events);
                }
            }
        }
    }
}
