//! Figure 4: ΔT vs tasks-per-processor (log–log), measured trials plus
//! the fitted power-law model line, one panel per scheduler.

use super::sweep::{run_sweeps, SchedulerSweep, SweepSpec};
use crate::config::ExperimentConfig;
use crate::util::fit::{fit_power_law, PowerLawFit};
use crate::util::plot::Plot;
use crate::util::table::Table;

/// One scheduler panel of Figure 4.
pub struct Fig4Panel {
    /// Scheduler display name.
    pub scheduler: String,
    /// The measured sweep.
    pub sweep: SchedulerSweep,
    /// Power-law fit over the pooled trials.
    pub fit: PowerLawFit,
}

/// All panels.
pub struct Fig4Report {
    /// Panel (a)–(d) in scheduler order.
    pub panels: Vec<Fig4Panel>,
}

/// Run Figure 4. All schedulers' cells execute in one parallel batch.
pub fn fig4(cfg: &ExperimentConfig) -> Fig4Report {
    let specs: Vec<SweepSpec> = cfg.schedulers.iter().map(|&c| (c, None)).collect();
    let panels = run_sweeps(&specs, cfg, &cfg.n_sweep)
        .into_iter()
        .map(|sweep| {
            let pts = sweep.fit_points();
            let ns: Vec<f64> = pts.iter().map(|p| p.0).collect();
            let dts: Vec<f64> = pts.iter().map(|p| p.1).collect();
            let fit = fit_power_law(&ns, &dts);
            Fig4Panel {
                scheduler: sweep.scheduler.clone(),
                sweep,
                fit,
            }
        })
        .collect();
    Fig4Report { panels }
}

impl Fig4Report {
    /// ASCII log-log plots, one per scheduler (measured ○ + model ·).
    pub fn render_plots(&self) -> String {
        let mut out = String::new();
        for (i, panel) in self.panels.iter().enumerate() {
            let mut plot = Plot::new(
                format!(
                    "Figure 4{}: {} — ΔT vs n (t_s={:.2}, α={:.2})",
                    (b'a' + i as u8) as char,
                    panel.scheduler,
                    panel.fit.t_s,
                    panel.fit.alpha_s
                ),
                "tasks per processor n",
                "ΔT (s)",
            )
            .loglog()
            .size(60, 16);
            plot.series("measured", 'o', panel.sweep.fit_points());
            // Model line sampled densely over the measured range.
            let (lo, hi) = panel
                .sweep
                .points
                .iter()
                .fold((f64::MAX, f64::MIN), |(lo, hi), p| {
                    (lo.min(p.n as f64), hi.max(p.n as f64))
                });
            let model: Vec<(f64, f64)> = (0..40)
                .map(|i| {
                    let n = lo * (hi / lo).powf(i as f64 / 39.0);
                    (n, panel.fit.delta_t(n))
                })
                .collect();
            plot.series("model t_s·n^α", '.', model);
            out.push_str(&plot.render());
            out.push('\n');
        }
        out
    }

    /// CSV series (scheduler, n, trial, delta_t).
    pub fn to_csv(&self) -> String {
        let mut t = Table::new("", &["scheduler", "n", "trial", "delta_t_s"]);
        for panel in &self.panels {
            for point in &panel.sweep.points {
                for (trial, run) in point.trials.iter().enumerate() {
                    t.row(&[
                        panel.scheduler.clone(),
                        point.n.to_string(),
                        trial.to_string(),
                        format!("{:.3}", run.delta_t()),
                    ]);
                }
            }
        }
        t.to_csv()
    }

    /// Shape checks: ΔT grows with n for every scheduler (beyond shot
    /// noise) and the model fit is tight (R² high) at high n.
    pub fn check_shape(&self) -> Result<(), String> {
        for panel in &self.panels {
            if panel.sweep.points.len() < 3 {
                continue;
            }
            let first = panel.sweep.points.first().unwrap();
            let last = panel.sweep.points.last().unwrap();
            if last.mean_delta_t() <= first.mean_delta_t() {
                return Err(format!(
                    "{}: ΔT not increasing over the sweep",
                    panel.scheduler
                ));
            }
            if panel.fit.r2 < 0.85 {
                return Err(format!(
                    "{}: power-law fit R²={:.3} too low",
                    panel.scheduler, panel.fit.r2
                ));
            }
        }
        Ok(())
    }
}
