//! Figure 6: ΔT vs tasks-per-processor with multilevel scheduling
//! (LLMapReduce) on Slurm, Grid Engine and Mesos — compared against the
//! regular (non-multilevel) runs to measure the ΔT reduction factors.

use super::sweep::{run_sweeps, SchedulerSweep, SweepSpec};
use crate::config::{ExperimentConfig, SchedulerChoice};
use crate::multilevel::MultilevelParams;
use crate::util::plot::Plot;
use crate::util::table::{fnum, Table};

/// One scheduler's regular-vs-multilevel comparison.
pub struct Fig6Panel {
    /// Scheduler display name (inner scheduler).
    pub scheduler: String,
    /// Regular submission sweep.
    pub regular: SchedulerSweep,
    /// Multilevel (aggregated) sweep.
    pub multilevel: SchedulerSweep,
}

impl Fig6Panel {
    /// ΔT reduction factor at the largest common n (the paper quotes
    /// 30×/40×/100× for Slurm/GE/Mesos).
    pub fn reduction_at_max_n(&self) -> Option<f64> {
        let reg = self.regular.points.last()?;
        let ml = self
            .multilevel
            .points
            .iter()
            .find(|p| p.n == reg.n)?;
        Some(reg.mean_delta_t() / ml.mean_delta_t().max(1e-9))
    }
}

/// Figure 6 data.
pub struct Fig6Report {
    /// Panels (a)–(c): Slurm, Grid Engine, Mesos.
    pub panels: Vec<Fig6Panel>,
}

/// The three schedulers the paper runs multilevel scheduling on.
pub fn fig6_schedulers() -> [SchedulerChoice; 3] {
    [
        SchedulerChoice::Slurm,
        SchedulerChoice::GridEngine,
        SchedulerChoice::Mesos,
    ]
}

/// Run Figure 6. All six sweeps (3 schedulers × regular/multilevel)
/// execute as one parallel cell batch.
pub fn fig6(cfg: &ExperimentConfig, ml_params: &MultilevelParams) -> Fig6Report {
    let mut specs: Vec<SweepSpec> = Vec::new();
    for &choice in fig6_schedulers().iter() {
        specs.push((choice, None));
        specs.push((choice, Some(ml_params)));
    }
    let mut sweeps = run_sweeps(&specs, cfg, &cfg.n_sweep).into_iter();
    let mut panels = Vec::with_capacity(3);
    while let (Some(regular), Some(multilevel)) = (sweeps.next(), sweeps.next()) {
        panels.push(Fig6Panel {
            scheduler: regular.scheduler.clone(),
            regular,
            multilevel,
        });
    }
    Fig6Report { panels }
}

impl Fig6Report {
    /// ASCII log-log plots: regular (o) vs multilevel (x) ΔT.
    pub fn render_plots(&self) -> String {
        let mut out = String::new();
        for (i, panel) in self.panels.iter().enumerate() {
            let mut plot = Plot::new(
                format!(
                    "Figure 6{}: {} — ΔT vs n, multilevel vs regular",
                    (b'a' + i as u8) as char,
                    panel.scheduler
                ),
                "tasks per processor n",
                "ΔT (s)",
            )
            .loglog()
            .size(60, 16);
            plot.series("regular", 'o', panel.regular.fit_points());
            plot.series("multilevel", 'x', panel.multilevel.fit_points());
            out.push_str(&plot.render());
            if let Some(red) = panel.reduction_at_max_n() {
                out.push_str(&format!("   ΔT reduction at max n: {red:.0}x\n"));
            }
            out.push('\n');
        }
        out
    }

    /// Summary table.
    pub fn render_table(&self) -> Table {
        let mut t = Table::new(
            "Figure 6 summary: multilevel ΔT (s) by n",
            &["scheduler", "n", "ΔT regular", "ΔT multilevel", "reduction"],
        );
        for panel in &self.panels {
            for reg in &panel.regular.points {
                if let Some(ml) = panel.multilevel.points.iter().find(|p| p.n == reg.n) {
                    let (dr, dm) = (reg.mean_delta_t(), ml.mean_delta_t());
                    t.row(&[
                        panel.scheduler.clone(),
                        reg.n.to_string(),
                        fnum(dr),
                        fnum(dm),
                        format!("{:.0}x", dr / dm.max(1e-9)),
                    ]);
                }
            }
        }
        t
    }

    /// Shape checks (paper §5.3): multilevel ΔT stays bounded (< 120 s)
    /// at every n, and the reduction at the largest n is ≥ 10×.
    pub fn check_shape(&self) -> Result<(), String> {
        for panel in &self.panels {
            for p in &panel.multilevel.points {
                let dt = p.mean_delta_t();
                if dt > 120.0 {
                    return Err(format!(
                        "{} multilevel ΔT({}) = {dt:.0}s exceeds 120 s",
                        panel.scheduler, p.n
                    ));
                }
            }
            match panel.reduction_at_max_n() {
                Some(red) if red >= 10.0 => {}
                Some(red) => {
                    return Err(format!(
                        "{}: ΔT reduction {red:.1}x at max n below 10x",
                        panel.scheduler
                    ));
                }
                None => return Err(format!("{}: no common max-n point", panel.scheduler)),
            }
        }
        Ok(())
    }
}
