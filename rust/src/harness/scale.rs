//! `scale` experiment: simulator wall-clock scaling at 10³–10⁶ tasks
//! (10⁷ behind `--huge`).
//!
//! The paper's experiments stop at thousands of tasks, but the regime
//! Byun et al. ("Node-Based Job Scheduling for Large Scale Simulations
//! of Short Running Jobs") identify as decisive is 10⁴–10⁵ short jobs —
//! where the *simulator itself* used to become the bottleneck: the
//! legacy `Ordered`/`Preemptive` combinators re-sorted the whole
//! pending queue per event, `take_task`/`try_dispatch` scanned it per
//! dispatch, and memory-constrained `SlotPool` allocations scanned and
//! memmoved the free stack, all quadratic. With those gone and the
//! kernel on SoA task state + streaming metrics, the sweep extends to
//! 10⁶ tasks as a matter of course.
//!
//! This runner measures the *wall time* of simulating n ∈
//! `cfg.scale_ns` tasks on P ∈ `cfg.scale_procs` cores for every
//! scheduler family plus the ordered/preemptive wrapper rows and two
//! engine-mode rows — `IdealFIFO+node` (whole-node allocation, arXiv
//! 2108.11359) and `IdealFIFO+shard4` (the kernel sharded across node
//! groups) — fits the log-log wall-time-vs-n exponent with
//! [`crate::util::fit`], and (in [`ScaleReport::check_shape`]) gates
//! the ordered/preemptive rows at exponent ≤ 1.25, holds the
//! engine-mode rows to the [`SCALE_MEVENTS_FLOOR`] throughput floor,
//! and asserts the incremental ordered queue is bit-identical to the
//! legacy eager-sort oracle.
//!
//! Methodology notes:
//!
//! * each cell runs twice through one warm scratch — the first run
//!   sizes every buffer, the second is timed — so the measurement sees
//!   the steady-state (zero-allocation) path;
//! * simulated outputs (events, t_total, preemptions) are bit-identical
//!   for every `--jobs` value as usual; wall times are measured per
//!   cell and are machine-dependent, so they are excluded from the
//!   determinism contract. For clean exponents run with `--jobs 1`
//!   (the CI perf-smoke step does);
//! * there is no prohibitive-skip pass: n here is a *total* task count
//!   (n/P stays ≤ 100 tasks per processor), so virtual makespans stay
//!   small even for the slow control planes.

use super::parallel::run_cells;
use crate::config::{ExperimentConfig, SchedulerChoice};
use crate::sched::combinators::{self, Order, OrderedSim};
use crate::sched::{make_scheduler, NodeGranularSim, RunOptions, Scheduler, ShardedSim};
use crate::util::fit::fit_power_law;
use crate::util::table::{fnum, Table};
use crate::workload::{TaskSpec, Workload};
use std::time::Instant;

/// Cores per node of the scale clusters (`scale_procs` entries must be
/// multiples of this; 25 divides the round 1k/10k core counts).
pub const SCALE_CORES_PER_NODE: u32 = 25;

/// Preemptible background tasks of the preemptive-row workload (the
/// victim pool; kept small so victim-selection passes stay cheap and
/// the measured scaling is the queue machinery, not the victim sort).
pub const SCALE_PREEMPT_BG: u32 = 64;

/// Smallest max-n for which the exponent gate is meaningful: below
/// this, cells run in microseconds and the fit is timer noise.
pub const SCALE_GATE_MIN_N: u32 = 8000;

/// Fitted log-log exponent ceiling for the ordered/preemptive rows.
pub const SCALE_ALPHA_CEILING: f64 = 1.25;

/// Shard count of the `IdealFIFO+shard4` row (must divide every
/// `scale_procs / SCALE_CORES_PER_NODE` node count).
pub const SCALE_SHARDS: usize = 4;

/// Throughput floor (million simulation events per wall second) for the
/// engine-mode rows at the largest n. Deliberately conservative — a
/// release-build kernel clears it by an order of magnitude; only a
/// quadratic regression or an accidental debug-path allocation storm
/// trips it.
pub const SCALE_MEVENTS_FLOOR: f64 = 0.5;

/// One measured (P, scheduler, n) cell.
pub struct ScaleCell {
    /// Cluster core count P.
    pub procs: u32,
    /// Scheduler display name.
    pub scheduler: String,
    /// Foreground task count n (the preemptive row adds P resident
    /// tasks on top; see [`scale_preempt_workload`]).
    pub n: u32,
    /// Wall seconds of the timed (second, warm-scratch) run.
    pub wall_s: f64,
    /// Simulation events processed by the timed run.
    pub events: u64,
    /// Simulated makespan (determinism-checked).
    pub t_total: f64,
    /// Evictions executed (preemptive row only).
    pub preemptions: u64,
}

impl ScaleCell {
    /// Millions of simulation events per wall second.
    pub fn mevents_per_s(&self) -> f64 {
        self.events as f64 / self.wall_s.max(1e-12) / 1e6
    }
}

/// Fitted wall-time power law of one (P, scheduler) row.
pub struct ScaleFit {
    /// Cluster core count P.
    pub procs: u32,
    /// Scheduler display name.
    pub scheduler: String,
    /// Log-log slope of wall seconds vs n.
    pub alpha: f64,
    /// R² of the fit.
    pub r2: f64,
    /// Whether this row is held to [`SCALE_ALPHA_CEILING`] (the
    /// ordered/preemptive paths the tentpole de-quadratized).
    pub gated: bool,
}

/// Full scale sweep.
pub struct ScaleReport {
    /// All cells, procs-major then scheduler then n.
    pub cells: Vec<ScaleCell>,
    /// One fit per (procs, scheduler).
    pub fits: Vec<ScaleFit>,
    /// The n sweep.
    pub ns: Vec<u32>,
    /// The P sweep.
    pub procs: Vec<u32>,
    /// Whether cells were timed serially (`jobs == 1`). Parallel runs
    /// time cells under CPU contention, so the exponent gate only
    /// applies to serial timings (the CI smoke passes `--jobs 1`).
    pub serial_timing: bool,
}

/// The shared array workload of the plain and ordered rows: n one-core
/// 1 s tasks, batch-submitted, with mixed priorities/users so the
/// ordering machinery has real work (plain backends ignore both).
pub fn scale_array_workload(n: u32) -> Workload {
    let tasks = (0..n)
        .map(|i| {
            let mut t = TaskSpec::array(i, i, 1.0);
            t.priority = (i % 8) as i32;
            t.user = i % 4;
            t
        })
        .collect();
    Workload {
        tasks,
        label: format!("scale-n{n}"),
    }
}

/// The preemptive-row workload: the cluster is saturated at t = 0 by
/// [`SCALE_PREEMPT_BG`] preemptible background tasks plus
/// non-preemptible fillers, and n high-priority 1 s foreground tasks
/// arrive on a deterministic uniform schedule at half the background
/// pool's service rate — so early arrivals must evict their way in and
/// the rest stream through the recovered slots. Total tasks: n + P.
pub fn scale_preempt_workload(n: u32, procs: u32) -> Workload {
    let bg = SCALE_PREEMPT_BG.min(procs / 4).max(1);
    let fill = procs - bg;
    let rate = 0.5 * bg as f64; // foreground arrivals per virtual second
    let long = n as f64 / rate + 5.0;
    let mut tasks: Vec<TaskSpec> = Vec::with_capacity((procs + n) as usize);
    let mut id = 0u32;
    for _ in 0..bg {
        let mut t = TaskSpec::array(id, id, long);
        t.preemptible = true;
        t.checkpoint_cost = 0.05;
        tasks.push(t);
        id += 1;
    }
    for _ in 0..fill {
        tasks.push(TaskSpec::array(id, id, long));
        id += 1;
    }
    for k in 0..n {
        let mut t = TaskSpec::array(id, id, 1.0);
        t.priority = 10;
        t.submit_at = 0.05 + k as f64 / rate;
        tasks.push(t);
        id += 1;
    }
    Workload {
        tasks,
        label: format!("scale-pre-n{n}"),
    }
}

/// Whether a scheduler row uses the preemptive workload.
fn is_preemptive_row(name: &str) -> bool {
    name.ends_with("+preempt")
}

/// Whether a row's fitted exponent is gated (the ordered/preemptive
/// combinator paths).
fn is_gated_row(name: &str) -> bool {
    name.contains("+prio")
}

/// Whether a row is held to the [`SCALE_MEVENTS_FLOOR`] throughput
/// floor at the largest n (the raw engine and its two fast modes —
/// rows whose cost per event is pure kernel machinery).
fn is_floor_row(name: &str) -> bool {
    name == "IdealFIFO" || name == "IdealFIFO+node" || name == "IdealFIFO+shard4"
}

/// The scale scheduler set: every simulated family at calibrated
/// (unscaled) costs, plus the ordered and preemptive wrapper rows over
/// the zero-overhead reference (isolating the queue machinery), plus
/// the node-granular and sharded engine modes over the same reference
/// (isolating the allocation and parallelism machinery).
fn scale_schedulers() -> Vec<Box<dyn Scheduler>> {
    let mut v: Vec<Box<dyn Scheduler>> = SchedulerChoice::all_simulated()
        .iter()
        .map(|&c| make_scheduler(c))
        .collect();
    v.push(Box::new(OrderedSim::new(
        make_scheduler(SchedulerChoice::IdealFifo),
        Order::Priority,
        "IdealFIFO+prio",
    )));
    v.push(combinators::make_preemptive(
        SchedulerChoice::IdealFifo,
        1,
        Order::Priority,
    ));
    v.push(Box::new(NodeGranularSim::new(
        make_scheduler(SchedulerChoice::IdealFifo),
        "IdealFIFO+node",
    )));
    v.push(Box::new(ShardedSim::new(
        make_scheduler(SchedulerChoice::IdealFifo),
        SCALE_SHARDS,
        SCALE_SHARDS,
        "IdealFIFO+shard4",
    )));
    v
}

/// The homogeneous cluster the scale experiment (and `perf_engine`'s
/// bench-side mirror) runs on: `procs / SCALE_CORES_PER_NODE` nodes of
/// `SCALE_CORES_PER_NODE` cores and 64 GB each.
pub fn scale_cluster(procs: u32) -> crate::cluster::ClusterSpec {
    assert!(
        procs >= SCALE_CORES_PER_NODE && procs % SCALE_CORES_PER_NODE == 0,
        "scale_procs entries must be positive multiples of {SCALE_CORES_PER_NODE}, got {procs}"
    );
    crate::cluster::ClusterSpec::homogeneous(
        procs / SCALE_CORES_PER_NODE,
        SCALE_CORES_PER_NODE,
        64 * 1024,
        8,
    )
}

/// The n sweep a config asks for: `scale_ns`, extended with the
/// 10⁷-task point when `--huge` (`scale_huge`) is set.
pub fn scale_effective_ns(cfg: &ExperimentConfig) -> Vec<u32> {
    let mut ns = cfg.scale_ns.clone();
    if cfg.scale_huge && !ns.contains(&10_000_000) {
        ns.push(10_000_000);
    }
    ns
}

/// Run the scale sweep.
pub fn scale(cfg: &ExperimentConfig) -> ScaleReport {
    let schedulers = scale_schedulers();
    let scale_ns = scale_effective_ns(cfg);
    // One array + one preempt workload per (P, n); preempt workloads
    // depend on P through the filler count.
    let array_workloads: Vec<(u32, Workload)> = scale_ns
        .iter()
        .map(|&n| (n, scale_array_workload(n)))
        .collect();
    let preempt_workloads: Vec<(u32, u32, Workload)> = cfg
        .scale_procs
        .iter()
        .flat_map(|&p| {
            scale_ns
                .iter()
                .map(move |&n| (p, n, scale_preempt_workload(n, p)))
        })
        .collect();

    struct Cell<'a> {
        sched: usize,
        procs: u32,
        n: u32,
        seed: u64,
        workload: &'a Workload,
        cluster: crate::cluster::ClusterSpec,
    }
    let mut cells: Vec<Cell> = Vec::new();
    for &procs in &cfg.scale_procs {
        let cluster = scale_cluster(procs);
        for (ki, sched) in schedulers.iter().enumerate() {
            let preemptive = is_preemptive_row(sched.name());
            for (ni, &n) in scale_ns.iter().enumerate() {
                let workload = if preemptive {
                    &preempt_workloads
                        .iter()
                        .find(|&&(p, wn, _)| p == procs && wn == n)
                        .expect("preempt workload built for every (P, n)")
                        .2
                } else {
                    &array_workloads[ni].1
                };
                cells.push(Cell {
                    sched: ki,
                    procs,
                    n,
                    seed: cfg
                        .seed
                        .wrapping_add((ki as u64) << 16)
                        .wrapping_add((n as u64) << 24)
                        .wrapping_add(procs as u64),
                    workload,
                    cluster: cluster.clone(),
                });
            }
        }
    }

    let results = run_cells(cfg.effective_jobs(), &cells, |cell, scratch| {
        let sched = schedulers[cell.sched].as_ref();
        let options = RunOptions::default();
        // Warm-up run sizes every scratch buffer for this shape…
        sched.run_with_scratch(cell.workload, &cell.cluster, cell.seed, &options, scratch);
        // …so the timed run measures the steady-state hot path.
        let t0 = Instant::now();
        let r = sched.run_with_scratch(cell.workload, &cell.cluster, cell.seed, &options, scratch);
        let wall = t0.elapsed().as_secs_f64();
        r.check_invariants()
            .unwrap_or_else(|e| panic!("{} scale n={}: {e}", sched.name(), cell.n));
        (wall, r)
    });

    let cells: Vec<ScaleCell> = cells
        .iter()
        .zip(results)
        .map(|(cell, (wall_s, r))| ScaleCell {
            procs: cell.procs,
            scheduler: schedulers[cell.sched].name().to_string(),
            n: cell.n,
            wall_s,
            events: r.events,
            t_total: r.t_total,
            preemptions: r.preemptions,
        })
        .collect();

    // Per-(P, scheduler) log-log fits.
    let mut fits: Vec<ScaleFit> = Vec::new();
    for &procs in &cfg.scale_procs {
        for sched in &schedulers {
            let name = sched.name();
            let (mut xs, mut ys) = (Vec::new(), Vec::new());
            for c in cells
                .iter()
                .filter(|c| c.procs == procs && c.scheduler == name)
            {
                xs.push(c.n as f64);
                // Clamp away an (unlikely) zero timer reading so the
                // log-log fit always has usable points.
                ys.push(c.wall_s.max(1e-9));
            }
            if xs.len() < 2 {
                continue;
            }
            let fit = fit_power_law(&xs, &ys);
            fits.push(ScaleFit {
                procs,
                scheduler: name.to_string(),
                alpha: fit.alpha_s,
                r2: fit.r2,
                gated: is_gated_row(name),
            });
        }
    }

    ScaleReport {
        cells,
        fits,
        ns: scale_ns,
        procs: cfg.scale_procs.clone(),
        serial_timing: cfg.effective_jobs() == 1,
    }
}

impl ScaleReport {
    /// Rendered summary: per-cell throughput plus per-row exponents.
    pub fn render_table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Scale — simulator wall time vs n (n up to {}, P up to {})",
                self.ns.iter().max().copied().unwrap_or(0),
                self.procs.iter().max().copied().unwrap_or(0),
            ),
            &[
                "P",
                "scheduler",
                "n",
                "events",
                "wall (s)",
                "Mev/s",
                "evictions",
            ],
        );
        for c in &self.cells {
            t.row(&[
                c.procs.to_string(),
                c.scheduler.clone(),
                c.n.to_string(),
                c.events.to_string(),
                format!("{:.4}", c.wall_s),
                format!("{:.2}", c.mevents_per_s()),
                c.preemptions.to_string(),
            ]);
        }
        t
    }

    /// Rendered exponent table.
    pub fn render_fits(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Scale — fitted log-log exponent of wall time vs n \
                 (gated rows must stay ≤ {SCALE_ALPHA_CEILING})"
            ),
            &["P", "scheduler", "alpha", "R²", "gated"],
        );
        for f in &self.fits {
            t.row(&[
                f.procs.to_string(),
                f.scheduler.clone(),
                format!("{:.3}", f.alpha),
                format!("{:.3}", f.r2),
                if f.gated { "yes".into() } else { "-".into() },
            ]);
        }
        t
    }

    /// CSV series (wall times are machine-dependent; the simulated
    /// columns are `--jobs`-deterministic).
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(
            "",
            &[
                "procs",
                "scheduler",
                "n",
                "events",
                "t_total_s",
                "wall_s",
                "mevents_per_s",
                "preemptions",
            ],
        );
        for c in &self.cells {
            t.row(&[
                c.procs.to_string(),
                c.scheduler.clone(),
                c.n.to_string(),
                c.events.to_string(),
                fnum(c.t_total),
                format!("{:.5}", c.wall_s),
                format!("{:.3}", c.mevents_per_s()),
                c.preemptions.to_string(),
            ]);
        }
        t.to_csv()
    }

    /// Structural + performance gates:
    ///
    /// * every (P, scheduler, n) cell ran, with sane event counts;
    /// * every preemptive cell actually evicted;
    /// * the incremental ordered queue is **bit-identical** to the
    ///   legacy eager-sort oracle (ordered and preemptive rows at the
    ///   smallest sweep point);
    /// * the fitted exponent of every gated (ordered/preemptive) row
    ///   stays ≤ [`SCALE_ALPHA_CEILING`] — applied only to serially
    ///   timed runs (`--jobs 1`; parallel cells time each other's CPU
    ///   contention) that are large enough for the timer to out-vote
    ///   noise (max n ≥ [`SCALE_GATE_MIN_N`]). The CI smoke step runs
    ///   with `--jobs 1` so the gate is always live there;
    /// * under the same serial-timing conditions, the engine-mode rows
    ///   (`IdealFIFO`, `IdealFIFO+node`, `IdealFIFO+shard4`) clear
    ///   [`SCALE_MEVENTS_FLOOR`] at the largest n.
    pub fn check_shape(&self, cfg: &ExperimentConfig) -> Result<(), String> {
        let expected = self.procs.len() * scale_schedulers().len() * self.ns.len();
        if self.cells.len() != expected {
            return Err(format!(
                "{} of {expected} scale cells ran",
                self.cells.len()
            ));
        }
        for c in &self.cells {
            if c.events < c.n as u64 {
                return Err(format!(
                    "{} P={} n={}: only {} events for {} tasks",
                    c.scheduler, c.procs, c.n, c.events, c.n
                ));
            }
            if !(c.t_total.is_finite() && c.t_total > 0.0) {
                return Err(format!(
                    "{} P={} n={}: bad makespan {}",
                    c.scheduler, c.procs, c.n, c.t_total
                ));
            }
            if is_preemptive_row(&c.scheduler) && c.preemptions == 0 {
                return Err(format!(
                    "{} P={} n={}: preemptive row executed no evictions",
                    c.scheduler, c.procs, c.n
                ));
            }
        }
        self.check_eager_bit_identity(cfg)?;
        let max_n = self.ns.iter().max().copied().unwrap_or(0);
        if self.serial_timing && max_n >= SCALE_GATE_MIN_N {
            for f in self.fits.iter().filter(|f| f.gated) {
                if f.alpha.is_nan() || f.alpha > SCALE_ALPHA_CEILING {
                    return Err(format!(
                        "{} P={}: fitted exponent {:.3} exceeds the \
                         {SCALE_ALPHA_CEILING} ceiling (quadratic regression?)",
                        f.scheduler, f.procs, f.alpha
                    ));
                }
            }
            for c in self
                .cells
                .iter()
                .filter(|c| c.n == max_n && is_floor_row(&c.scheduler))
            {
                if c.mevents_per_s() < SCALE_MEVENTS_FLOOR {
                    return Err(format!(
                        "{} P={} n={}: {:.3} Mev/s under the {SCALE_MEVENTS_FLOOR} \
                         floor ({} events in {:.3} s)",
                        c.scheduler,
                        c.procs,
                        c.n,
                        c.mevents_per_s(),
                        c.events,
                        c.wall_s
                    ));
                }
            }
        }
        Ok(())
    }

    /// The bit-identity assert of the CI smoke step: run the smallest
    /// (P, n) ordered and preemptive cells through both the incremental
    /// index and the legacy eager-sort oracle; any divergence in
    /// makespan bits, event counts or eviction counts trips it.
    fn check_eager_bit_identity(&self, cfg: &ExperimentConfig) -> Result<(), String> {
        let (Some(&n), Some(&procs)) =
            (cfg.scale_ns.iter().min(), cfg.scale_procs.iter().min())
        else {
            return Err("empty scale sweep".into());
        };
        let cluster = scale_cluster(procs);
        let seed = cfg.seed ^ 0x5CA1E;
        let pairs: [(Box<dyn Scheduler>, Box<dyn Scheduler>, Workload); 2] = [
            (
                Box::new(OrderedSim::new(
                    make_scheduler(SchedulerChoice::IdealFifo),
                    Order::Priority,
                    "IdealFIFO+prio",
                )),
                Box::new(OrderedSim::new_eager(
                    make_scheduler(SchedulerChoice::IdealFifo),
                    Order::Priority,
                    "IdealFIFO+prio",
                )),
                scale_array_workload(n),
            ),
            (
                combinators::make_preemptive(SchedulerChoice::IdealFifo, 1, Order::Priority),
                Box::new(combinators::PreemptiveSim::new_eager(
                    make_scheduler(SchedulerChoice::IdealFifo),
                    Order::Priority,
                    "IdealFIFO+prio+preempt",
                )),
                scale_preempt_workload(n, procs),
            ),
        ];
        for (incremental, eager, workload) in &pairs {
            let a = incremental.run(workload, &cluster, seed, &RunOptions::default());
            let b = eager.run(workload, &cluster, seed, &RunOptions::default());
            if a.t_total.to_bits() != b.t_total.to_bits()
                || a.events != b.events
                || a.preemptions != b.preemptions
            {
                return Err(format!(
                    "bit-identity tripped for {}: incremental (t={}, ev={}, pre={}) \
                     vs eager oracle (t={}, ev={}, pre={})",
                    incremental.name(),
                    a.t_total,
                    a.events,
                    a.preemptions,
                    b.t_total,
                    b.events,
                    b.preemptions,
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.scale_ns = vec![200, 800];
        cfg.scale_procs = vec![100];
        cfg.jobs = 1;
        cfg
    }

    #[test]
    fn scale_runs_and_passes_shape_checks() {
        let cfg = tiny_cfg();
        let rep = scale(&cfg);
        rep.check_shape(&cfg).unwrap();
        // 10 scheduler rows × 2 n values × 1 P value.
        assert_eq!(rep.cells.len(), 20);
        assert_eq!(rep.fits.len(), 10);
        assert_eq!(rep.fits.iter().filter(|f| f.gated).count(), 2);
        assert!(!rep.to_csv().is_empty());
    }

    #[test]
    fn scale_simulated_outputs_deterministic_across_jobs() {
        let mut a_cfg = tiny_cfg();
        a_cfg.jobs = 1;
        let mut b_cfg = tiny_cfg();
        b_cfg.jobs = 4;
        let a = scale(&a_cfg);
        let b = scale(&b_cfg);
        assert_eq!(a.cells.len(), b.cells.len());
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.scheduler, cb.scheduler);
            assert_eq!(ca.n, cb.n);
            assert_eq!(
                ca.t_total.to_bits(),
                cb.t_total.to_bits(),
                "{} n={}",
                ca.scheduler,
                ca.n
            );
            assert_eq!(ca.events, cb.events);
            assert_eq!(ca.preemptions, cb.preemptions);
        }
    }

    #[test]
    fn huge_flag_appends_the_ten_million_point() {
        let mut cfg = tiny_cfg();
        assert_eq!(scale_effective_ns(&cfg), vec![200, 800]);
        cfg.scale_huge = true;
        assert_eq!(scale_effective_ns(&cfg), vec![200, 800, 10_000_000]);
        // Idempotent when the point is already in the sweep.
        cfg.scale_ns.push(10_000_000);
        assert_eq!(scale_effective_ns(&cfg), vec![200, 800, 10_000_000]);
    }

    #[test]
    fn engine_mode_rows_agree_with_the_reference() {
        // Constant 1-core tasks under zero-overhead FIFO finish in
        // ceil(n/P) waves however the slots are carved up: the plain,
        // node-granular and sharded rows must report the same makespan.
        let cfg = tiny_cfg();
        let rep = scale(&cfg);
        for &n in &cfg.scale_ns {
            let t = |name: &str| {
                rep.cells
                    .iter()
                    .find(|c| c.scheduler == name && c.n == n)
                    .unwrap_or_else(|| panic!("missing {name} n={n}"))
                    .t_total
            };
            let reference = t("IdealFIFO");
            assert_eq!(t("IdealFIFO+node").to_bits(), reference.to_bits());
            assert_eq!(t("IdealFIFO+shard4").to_bits(), reference.to_bits());
        }
    }

    #[test]
    fn preempt_workload_shape() {
        let w = scale_preempt_workload(500, 100);
        assert_eq!(w.tasks.len(), 600);
        let preemptible = w.tasks.iter().filter(|t| t.preemptible).count();
        assert_eq!(preemptible, 25); // min(64, P/4)
        assert!(w.tasks.iter().filter(|t| t.priority == 10).count() == 500);
        w.validate().unwrap();
    }
}
