//! Experiment harness: one runner per table/figure of the paper's
//! evaluation, producing both rendered reports and CSV series.
//!
//! | runner     | reproduces |
//! |------------|-----------|
//! | `features` | Tables 1–7 (via `crate::features`) |
//! | `table9`   | Table 9 — runtimes of 4 task sets × 4 schedulers × trials |
//! | `table10`  | Table 10 — fitted (t_s, α_s) per scheduler |
//! | `fig4`     | Figure 4 — ΔT vs n (log-log), measured + model |
//! | `fig5`     | Figure 5 — utilization vs task time, approx/exact models |
//! | `fig6`     | Figure 6 — ΔT vs n with multilevel scheduling |
//! | `fig7`     | Figure 7 — utilization, regular vs multilevel |
//! | `scenarios`| workload-space sweep: array / multicore / DAG / gang / arrivals × all schedulers |
//! | `preempt`  | preemption sweep: checkpoint cost × ordering × all schedulers, fairness vs ΔT |
//! | `service`  | service-footprint sweep: resident services × Poisson short tasks × all schedulers, windowed utilization |
//! | `churn`    | fault-injection sweep: seeded node failure/repair churn × retry budget × all schedulers, goodput + lost work + completion coverage |
//! | `degraded` | degraded-control-plane sweep: heartbeat detect timeout × message loss/latency severity × speculation × all schedulers, goodput + duplicate work + detection latency percentiles + effective (t_s, α_s) inflation |
//! | `scale`    | simulator wall-time scaling at 10³–10⁶ tasks (10⁷ with `--huge`): n × P × all schedulers + ordered/preemptive + node-granular/sharded engine rows, fitted log-log exponent + Mev/s floor |
//! | `model`    | closed loop on (t_s, α_s): fit per-backend sweeps vs paper Table 10, invert the analytic model to auto-tune the multilevel bundle size, report predicted vs simulated U; `--churn` refits under a seeded fault plan |

//! All experiment runners route their `(scheduler, n, trial)`
//! cells through the deterministic parallel executor in [`parallel`];
//! `--jobs` (or `ExperimentConfig::jobs`) picks the worker count and
//! results are bit-identical for every choice of it.

mod fig4;
mod fig5;
mod fig6;
mod fig7;
mod model;
mod parallel;
mod scale;
mod scenarios;
mod sweep;
mod table10;
mod table9;

pub use fig4::{fig4, Fig4Report};
pub use fig5::{fig5, fig5_from, Fig5Report};
pub use fig6::{fig6, Fig6Report};
pub use fig7::{fig7, Fig7Report};
pub use model::{
    model, ModelChurnRow, ModelFitRow, ModelReport, ModelTuneRow, MODEL_CHURN_MTBF_SECS,
    MODEL_CHURN_MTTR_SECS, MODEL_PRED_EPS, MODEL_R2_GATE, MODEL_SIM_UTIL_FLOOR,
    MODEL_TUNE_TASKS_PER_PROC, MODEL_TUNE_TASK_SECS,
};
pub use parallel::{default_jobs, run_cells};
pub use scale::{
    scale, scale_array_workload, scale_cluster, scale_effective_ns, scale_preempt_workload,
    ScaleCell, ScaleFit, ScaleReport, SCALE_ALPHA_CEILING, SCALE_CORES_PER_NODE,
    SCALE_GATE_MIN_N, SCALE_MEVENTS_FLOOR, SCALE_PREEMPT_BG, SCALE_SHARDS,
};
pub use scenarios::{
    churn, degraded, preempt, scenarios, service, ChurnCell, ChurnReport, DegradedCell,
    DegradedFitRow, DegradedReport, PreemptCell, PreemptReport, ScenarioCell, ScenariosReport,
    ServiceCell, ServiceReport, CHURN_ARRIVAL_SPAN, CHURN_RETRY_BUDGETS, DEGRADED_BACKLOG,
    DEGRADED_FIT_NS, DEGRADED_MONO_EPS, DEGRADED_STRAGGLER_EVERY, DEGRADED_STRAGGLER_FACTOR,
    GANG_SIZE,
};
pub use sweep::{run_sweep, run_sweeps, SchedulerSweep, SweepPoint, SweepSpec, PROHIBITIVE_SECS};
pub use table10::{table10, Table10Report};
pub use table9::{table9, Table9Report};
