//! Figure 5: scheduler utilization as a function of task time —
//! measured points plus (a) the approximate model U⁻¹ ≈ 1 + t_s/t and
//! (b) the exact model U⁻¹ = 1 + t_s n^α/(t n). Model curves are
//! evaluated through the AOT `utilization` artifact when available,
//! falling back to the rust implementation.

use super::sweep::SchedulerSweep;
use super::table10::{table10, Table10Report};
use crate::config::ExperimentConfig;
use crate::model::{u_constant_approx, u_constant_exact};
use crate::util::plot::Plot;
use crate::util::table::Table;
use crate::workload::TABLE9_JOB_TIME_PER_PROC;

/// One scheduler's measured + modeled utilization curve.
pub struct Fig5Series {
    /// Scheduler display name.
    pub scheduler: String,
    /// Measured (t, U) points.
    pub measured: Vec<(f64, f64)>,
    /// Approximate model curve (t, U).
    pub model_approx: Vec<(f64, f64)>,
    /// Exact model curve (t, U).
    pub model_exact: Vec<(f64, f64)>,
}

/// Figure 5 data.
pub struct Fig5Report {
    /// One series per scheduler.
    pub series: Vec<Fig5Series>,
    /// Whether the model curves came from the PJRT artifact.
    pub used_pjrt: bool,
}

/// Run Figure 5 (reuses the Table 10 sweep + fits).
pub fn fig5(cfg: &ExperimentConfig, artifacts_dir: Option<&str>) -> Fig5Report {
    let t10 = table10(cfg, artifacts_dir);
    fig5_from(&t10, artifacts_dir)
}

/// Build Figure 5 from an existing Table 10 report.
pub fn fig5_from(t10: &Table10Report, artifacts_dir: Option<&str>) -> Fig5Report {
    let t_grid: Vec<f64> = (0..crate::runtime::shapes::UTIL_T)
        .map(|i| 0.5 * (120.0f64 / 0.5).powf(i as f64 / (crate::runtime::shapes::UTIL_T - 1) as f64))
        .collect();

    // Try the PJRT path for the model curves (≤8 series per call).
    let mut used_pjrt = false;
    let pjrt_curves = artifacts_dir.and_then(|dir| {
        let mut suite = crate::runtime::ArtifactSuite::load(dir).ok()?;
        let fits: Vec<crate::runtime::PjrtFit> = t10
            .fits
            .iter()
            .map(|f| crate::runtime::PjrtFit {
                t_s: f.rust_fit.t_s,
                alpha_s: f.rust_fit.alpha_s,
                r2: f.rust_fit.r2,
            })
            .collect();
        if fits.len() > crate::runtime::shapes::FIT_S {
            return None;
        }
        suite.utilization_curves(&fits, &t_grid).ok()
    });
    if pjrt_curves.is_some() {
        used_pjrt = true;
    }

    let series = t10
        .fits
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let measured = measured_points(&f.sweep);
            let (approx, exact) = match &pjrt_curves {
                Some((a, e)) => (
                    t_grid.iter().copied().zip(a[i].iter().copied()).collect(),
                    t_grid.iter().copied().zip(e[i].iter().copied()).collect(),
                ),
                None => {
                    let a: Vec<(f64, f64)> = t_grid
                        .iter()
                        .map(|&t| (t, u_constant_approx(f.rust_fit.t_s, t)))
                        .collect();
                    let e: Vec<(f64, f64)> = t_grid
                        .iter()
                        .map(|&t| {
                            let n = TABLE9_JOB_TIME_PER_PROC / t;
                            (t, u_constant_exact(f.rust_fit.t_s, f.rust_fit.alpha_s, t, n))
                        })
                        .collect();
                    (a, e)
                }
            };
            Fig5Series {
                scheduler: f.scheduler.clone(),
                measured,
                model_approx: approx,
                model_exact: exact,
            }
        })
        .collect();
    Fig5Report { series, used_pjrt }
}

fn measured_points(sweep: &SchedulerSweep) -> Vec<(f64, f64)> {
    sweep
        .points
        .iter()
        .map(|p| (p.t, p.mean_utilization()))
        .collect()
}

impl Fig5Report {
    /// ASCII plot: measured points (per-scheduler glyphs) + exact model.
    pub fn render_plot(&self) -> String {
        let glyphs = ['S', 'G', 'M', 'Y', '5', '6', '7', '8'];
        let mut plot = Plot::new(
            "Figure 5: utilization vs task time (points=measured, .=exact model)",
            "task time t (s)",
            "utilization U",
        )
        .size(70, 20);
        for (i, s) in self.series.iter().enumerate() {
            plot.series(
                s.scheduler.clone(),
                glyphs[i % glyphs.len()],
                s.measured.clone(),
            );
            plot.series(format!("{} model", s.scheduler), '.', s.model_exact.clone());
        }
        plot.render()
    }

    /// CSV of measured + model curves.
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(
            "",
            &["scheduler", "kind", "t_s_task", "utilization"],
        );
        for s in &self.series {
            for &(x, u) in &s.measured {
                t.row(&[s.scheduler.clone(), "measured".into(), format!("{x:.4}"), format!("{u:.4}")]);
            }
            for &(x, u) in &s.model_approx {
                t.row(&[s.scheduler.clone(), "model_approx".into(), format!("{x:.4}"), format!("{u:.4}")]);
            }
            for &(x, u) in &s.model_exact {
                t.row(&[s.scheduler.clone(), "model_exact".into(), format!("{x:.4}"), format!("{u:.4}")]);
            }
        }
        t.to_csv()
    }

    /// Shape checks (paper §5.2): U < 15 % at t = 1 s for every measured
    /// scheduler; U > 70 % at t = 60 s except YARN; measured utilization
    /// is (weakly) increasing in t.
    pub fn check_shape(&self) -> Result<(), String> {
        for s in &self.series {
            if let Some(&(_, u1)) = s.measured.iter().find(|(t, _)| (*t - 1.0).abs() < 0.01) {
                if u1 > 0.15 {
                    return Err(format!("{}: U(1s)={u1:.2} should be <0.15", s.scheduler));
                }
            }
            if let Some(&(_, u60)) = s.measured.iter().find(|(t, _)| (*t - 60.0).abs() < 0.01) {
                let floor = if s.scheduler.contains("YARN") { 0.5 } else { 0.7 };
                if u60 < floor {
                    return Err(format!(
                        "{}: U(60s)={u60:.2} should be >{floor}",
                        s.scheduler
                    ));
                }
            }
            let mut sorted = s.measured.clone();
            sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in sorted.windows(2) {
                if w[1].1 < w[0].1 * 0.8 {
                    return Err(format!(
                        "{}: utilization strongly non-monotone at t={}",
                        s.scheduler, w[1].0
                    ));
                }
            }
        }
        Ok(())
    }
}
