//! Table 10: the fitted latency-model parameters (t_s, α_s) per
//! scheduler, from a sweep over tasks-per-processor (the paper fits
//! over the Figure 4 points).
//!
//! The fit runs through BOTH paths — the rust-native OLS and the
//! AOT-compiled Pallas kernel via PJRT — and reports both, asserting
//! they agree.

use super::sweep::{run_sweeps, SchedulerSweep, SweepSpec};
use crate::config::ExperimentConfig;
use crate::sched::calibration::paper_table10;
use crate::util::fit::{fit_power_law, PowerLawFit};
use crate::util::table::{fnum, Table};

/// One scheduler's fit, both paths.
pub struct SchedulerFit {
    /// Scheduler display name.
    pub scheduler: String,
    /// Rust-native log-log OLS.
    pub rust_fit: PowerLawFit,
    /// PJRT/Pallas fit (None when artifacts are unavailable).
    pub pjrt_fit: Option<crate::runtime::PjrtFit>,
    /// Underlying sweep.
    pub sweep: SchedulerSweep,
}

/// Table 10 results.
pub struct Table10Report {
    /// One entry per scheduler.
    pub fits: Vec<SchedulerFit>,
}

/// Run the sweep and fit. `artifacts_dir` enables the artifact-suite
/// fit path. All schedulers' cells execute in one parallel batch.
pub fn table10(cfg: &ExperimentConfig, artifacts_dir: Option<&str>) -> Table10Report {
    let mut suite = artifacts_dir.and_then(|d| crate::runtime::ArtifactSuite::load(d).ok());
    let specs: Vec<SweepSpec> = cfg.schedulers.iter().map(|&c| (c, None)).collect();
    let fits = run_sweeps(&specs, cfg, &cfg.n_sweep)
        .into_iter()
        .map(|sweep| {
            let pts = sweep.fit_points();
            let ns: Vec<f64> = pts.iter().map(|p| p.0).collect();
            let dts: Vec<f64> = pts.iter().map(|p| p.1).collect();
            let rust_fit = fit_power_law(&ns, &dts);
            let pjrt_fit = suite.as_mut().and_then(|s| {
                // The artifact takes ≤32 points per series; subsample
                // trials evenly if the sweep is larger.
                let capped: Vec<(f64, f64)> = if pts.len() > crate::runtime::shapes::FIT_K {
                    let stride = pts.len().div_ceil(crate::runtime::shapes::FIT_K);
                    pts.iter().step_by(stride).copied().collect()
                } else {
                    pts.clone()
                };
                s.powerlaw_fit(&[capped]).ok().map(|v| v[0])
            });
            SchedulerFit {
                scheduler: sweep.scheduler.clone(),
                rust_fit,
                pjrt_fit,
                sweep,
            }
        })
        .collect();
    Table10Report { fits }
}

impl Table10Report {
    /// Render with the paper's reference values.
    pub fn render(&self) -> Table {
        let mut t = Table::new(
            "Table 10: measured model-fit parameters",
            &[
                "scheduler", "t_s (rust)", "t_s (pjrt)", "t_s (paper)",
                "alpha (rust)", "alpha (pjrt)", "alpha (paper)", "R2",
            ],
        );
        for f in &self.fits {
            let paper = paper_table10()
                .into_iter()
                .find(|p| p.scheduler == f.scheduler);
            t.row(&[
                f.scheduler.clone(),
                fnum(f.rust_fit.t_s),
                f.pjrt_fit.map(|p| fnum(p.t_s)).unwrap_or_else(|| "-".into()),
                paper.as_ref().map(|p| fnum(p.t_s)).unwrap_or_else(|| "-".into()),
                format!("{:.2}", f.rust_fit.alpha_s),
                f.pjrt_fit
                    .map(|p| format!("{:.2}", p.alpha_s))
                    .unwrap_or_else(|| "-".into()),
                paper
                    .as_ref()
                    .map(|p| format!("{:.2}", p.alpha_s))
                    .unwrap_or_else(|| "-".into()),
                format!("{:.3}", f.rust_fit.r2),
            ]);
        }
        t
    }

    /// Shape assertions: orderings of Table 10 hold — t_s(Slurm) <
    /// t_s(GE) < t_s(Mesos) ≪ t_s(YARN); α(Slurm), α(GE) > α(Mesos) >
    /// α(YARN) ≈ 1; and the two fit paths agree.
    pub fn check_shape(&self) -> Result<(), String> {
        let get = |name: &str| -> Result<&SchedulerFit, String> {
            self.fits
                .iter()
                .find(|f| f.scheduler == name)
                .ok_or_else(|| format!("missing fit for {name}"))
        };
        let slurm = get("Slurm")?;
        let ge = get("GridEngine")?;
        let mesos = get("Mesos")?;
        let yarn = get("Hadoop YARN")?;
        let ts = |f: &SchedulerFit| f.rust_fit.t_s;
        let al = |f: &SchedulerFit| f.rust_fit.alpha_s;
        if !(ts(slurm) < ts(ge) && ts(ge) < ts(yarn) && ts(mesos) < ts(yarn)) {
            return Err(format!(
                "t_s ordering violated: slurm={} ge={} mesos={} yarn={}",
                ts(slurm), ts(ge), ts(mesos), ts(yarn)
            ));
        }
        if ts(yarn) < 5.0 * ts(mesos) {
            return Err("YARN t_s should dwarf the others".into());
        }
        if !(al(slurm) > al(mesos) && al(ge) > al(mesos) && al(mesos) > al(yarn) - 0.05) {
            return Err(format!(
                "alpha ordering violated: slurm={:.2} ge={:.2} mesos={:.2} yarn={:.2}",
                al(slurm), al(ge), al(mesos), al(yarn)
            ));
        }
        if (al(yarn) - 1.0).abs() > 0.15 {
            return Err(format!("YARN alpha {:.2} should be ~1.0", al(yarn)));
        }
        for f in &self.fits {
            if let Some(p) = f.pjrt_fit {
                if (p.t_s - f.rust_fit.t_s).abs() / f.rust_fit.t_s > 0.05
                    || (p.alpha_s - f.rust_fit.alpha_s).abs() > 0.05
                {
                    return Err(format!(
                        "{}: pjrt fit ({}, {:.2}) diverges from rust fit ({}, {:.2})",
                        f.scheduler, p.t_s, p.alpha_s, f.rust_fit.t_s, f.rust_fit.alpha_s
                    ));
                }
            }
        }
        Ok(())
    }
}
