//! Figure 7: utilization as a function of task count for regular vs
//! multilevel scheduling (Grid Engine, Slurm, Mesos) — the paper's
//! headline result: multilevel scheduling brings 1–5 s task utilization
//! to ~90 %, on par with 30–60 s tasks.

use super::fig6::{fig6, Fig6Report};
use crate::config::ExperimentConfig;
use crate::multilevel::MultilevelParams;
use crate::util::plot::Plot;
use crate::util::table::Table;

/// Figure 7 data (derived from the Figure 6 runs).
pub struct Fig7Report {
    /// Underlying regular/multilevel sweeps.
    pub fig6: Fig6Report,
}

/// Run Figure 7.
pub fn fig7(cfg: &ExperimentConfig, ml_params: &MultilevelParams) -> Fig7Report {
    Fig7Report {
        fig6: fig6(cfg, ml_params),
    }
}

impl Fig7Report {
    /// ASCII plots of U vs task time, regular (o) vs multilevel (x).
    pub fn render_plots(&self) -> String {
        let mut out = String::new();
        for (i, panel) in self.fig6.panels.iter().enumerate() {
            let mut plot = Plot::new(
                format!(
                    "Figure 7{}: {} — utilization, regular vs multilevel",
                    (b'a' + i as u8) as char,
                    panel.scheduler
                ),
                "task time t (s)",
                "utilization U",
            )
            .size(60, 14);
            let reg: Vec<(f64, f64)> = panel
                .regular
                .points
                .iter()
                .map(|p| (p.t, p.mean_utilization()))
                .collect();
            let ml: Vec<(f64, f64)> = panel
                .multilevel
                .points
                .iter()
                .map(|p| (p.t, p.mean_utilization()))
                .collect();
            plot.series("regular", 'o', reg);
            plot.series("multilevel", 'x', ml);
            out.push_str(&plot.render());
            out.push('\n');
        }
        out
    }

    /// Summary table of utilizations.
    pub fn render_table(&self) -> Table {
        let mut t = Table::new(
            "Figure 7 summary: utilization by task time",
            &["scheduler", "t (s)", "n", "U regular", "U multilevel"],
        );
        for panel in &self.fig6.panels {
            for reg in &panel.regular.points {
                if let Some(ml) = panel.multilevel.points.iter().find(|p| p.n == reg.n) {
                    t.row(&[
                        panel.scheduler.clone(),
                        format!("{:.2}", reg.t),
                        reg.n.to_string(),
                        format!("{:.3}", reg.mean_utilization()),
                        format!("{:.3}", ml.mean_utilization()),
                    ]);
                }
            }
        }
        t
    }

    /// Shape checks: multilevel utilization ≥ 80 % at every task time
    /// for all three schedulers (paper: "around 90 %"), and multilevel
    /// at the shortest tasks beats regular by ≥ 5×.
    pub fn check_shape(&self) -> Result<(), String> {
        for panel in &self.fig6.panels {
            for p in &panel.multilevel.points {
                let u = p.mean_utilization();
                if u < 0.80 {
                    return Err(format!(
                        "{} multilevel U(n={}) = {u:.2} below 0.80",
                        panel.scheduler, p.n
                    ));
                }
            }
            let (reg_max, ml_max) = match (
                panel.regular.points.last(),
                panel.multilevel.points.last(),
            ) {
                (Some(r), Some(m)) if r.n == m.n => {
                    (r.mean_utilization(), m.mean_utilization())
                }
                _ => continue,
            };
            if ml_max < reg_max * 5.0 {
                return Err(format!(
                    "{}: multilevel U {ml_max:.2} should be ≥5x regular {reg_max:.2} at max n",
                    panel.scheduler
                ));
            }
        }
        Ok(())
    }
}
