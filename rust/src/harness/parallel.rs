//! Deterministic parallel execution of independent simulation cells.
//!
//! The paper's headline numbers come from sweeps of hundreds of
//! independent cells — one `(scheduler, n, trial)` simulation each —
//! which the seed ran serially on one thread. Each cell derives its RNG
//! stream purely from its seed and touches no shared mutable state, so
//! the sweep is embarrassingly parallel *and* can stay bit-identical
//! across thread counts: cell i's result is `work(&items[i])` no matter
//! which worker claims it or in which order cells finish.
//!
//! Implementation notes:
//!
//! * `std::thread::scope` only — the offline crate set has no rayon;
//! * chunked atomic work claiming: a worker grabs `chunk` consecutive
//!   cells per fetch-add, amortizing contention while leaving the tail
//!   fine-grained enough to balance heterogeneous cell costs (an
//!   n = 240 rapid cell costs ~60× an n = 4 cell);
//! * each worker owns one warm [`SimScratch`], so the parallel sweep is
//!   also the zero-allocation sweep.

use crate::sim::SimScratch;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count to use when the user doesn't pin one: every available
/// core.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `work` over every item on up to `jobs` worker threads, each
/// owning a warm [`SimScratch`]. Returns results in item order,
/// independent of thread count and scheduling.
///
/// `work` must be a pure function of the item (typically a sweep cell
/// carrying its own seed): it may use the scratch freely but must not
/// depend on execution order, or determinism across `jobs` values is
/// lost.
pub fn run_cells<T, R, F>(jobs: usize, items: &[T], work: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T, &mut SimScratch) -> R + Sync,
{
    let jobs = jobs.max(1).min(items.len().max(1));
    if jobs == 1 || items.len() <= 1 {
        // Serial fast path: same scratch reuse, no thread machinery.
        let mut scratch = SimScratch::new();
        return items.iter().map(|item| work(item, &mut scratch)).collect();
    }

    // Chunk size: ~8 claims per worker keeps the atomic cold while the
    // final chunks still spread the expensive cells.
    let chunk = (items.len() / (jobs * 8)).max(1);
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(items.len());
    slots.resize_with(items.len(), || None);

    std::thread::scope(|scope| {
        let next = &next;
        let work = &work;
        let handles: Vec<_> = (0..jobs)
            .map(|_| {
                scope.spawn(move || {
                    let mut scratch = SimScratch::new();
                    let mut produced: Vec<(usize, R)> = Vec::new();
                    loop {
                        let start = next.fetch_add(chunk, Ordering::Relaxed);
                        if start >= items.len() {
                            break;
                        }
                        let end = (start + chunk).min(items.len());
                        for (i, item) in items.iter().enumerate().take(end).skip(start) {
                            produced.push((i, work(item, &mut scratch)));
                        }
                    }
                    produced
                })
            })
            .collect();
        for handle in handles {
            for (i, r) in handle.join().expect("sweep worker panicked") {
                slots[i] = Some(r);
            }
        }
    });

    slots
        .into_iter()
        .map(|r| r.expect("every cell claimed exactly once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_item_order() {
        let items: Vec<u64> = (0..200).collect();
        for jobs in [1, 2, 4, 7] {
            let out = run_cells(jobs, &items, |&x, _| x * 3);
            assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn handles_empty_and_tiny_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(run_cells(8, &empty, |&x, _| x).is_empty());
        assert_eq!(run_cells(8, &[42u32], |&x, _| x + 1), vec![43]);
    }

    #[test]
    fn scratch_is_usable_per_worker() {
        // Each worker's scratch must behave like a fresh one per cell.
        use crate::cluster::ClusterSpec;
        let cluster = ClusterSpec::tiny();
        let items: Vec<u32> = (0..32).collect();
        let out = run_cells(4, &items, |&i, scratch| {
            scratch.begin(&cluster, i as usize, true);
            scratch.pending.push_back(i);
            (scratch.pending.len(), scratch.trace_idx.len())
        });
        for (i, &(pend, tr)) in out.iter().enumerate() {
            assert_eq!(pend, 1);
            assert_eq!(tr, i);
        }
    }

    #[test]
    fn default_jobs_is_positive() {
        assert!(default_jobs() >= 1);
    }
}
