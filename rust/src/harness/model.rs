//! The `model` experiment — close the paper's loop on (t_s, α_s).
//!
//! Three phases, all on the deterministic cell executor:
//!
//! 1. **Fit**: per-backend launch-latency sweeps (the same `run_sweeps`
//!    cells Table 10 uses, over `cfg.model_ns`) are pooled and fitted
//!    to ΔT = t_s · n^α_s through the hardened `try_fit` path, then
//!    compared against the paper's reported Table 10 values.
//! 2. **Tune**: for each backend the fitted parameters are fed to
//!    [`crate::model::derive_bundle_size`], which inverts the analytic
//!    utilization model to find the smallest multilevel bundle size
//!    whose *predicted* short-task utilization meets
//!    `cfg.model_target_util`; `Multilevel` then runs at exactly that
//!    derived size and the report shows predicted vs simulated side by
//!    side.
//! 3. **Churn** (`--churn`): the same sweeps re-run under a seeded
//!    [`FaultPlan`] and are refitted, reporting the effective
//!    (t_s, α_s) shift — the scheduler a fault-ridden cluster
//!    *behaves like*, fed back into the same model.

use crate::cluster::FaultPlan;
use crate::config::{ExperimentConfig, SchedulerChoice};
use crate::model::{derive_bundle_size, fit_sweep, BundleChoice, FittedModel};
use crate::multilevel::{Multilevel, MultilevelParams};
use crate::sched::calibration::{paper_table10, PaperFit};
use crate::sched::{make_scheduler_scaled, RunOptions, RunResult, Scheduler};
use crate::util::table::{fnum, Table};
use crate::workload::WorkloadBuilder;

use super::parallel::run_cells;
use super::sweep::{cluster_of, run_sweeps, trial_mean, workload_for, SweepSpec, PROHIBITIVE_SECS};

/// Minimum R² for a gated (paper-scheduler) fit row.
pub const MODEL_R2_GATE: f64 = 0.90;
/// Floor on the auto-tuned bundle's *simulated* utilization for the
/// four paper schedulers.
pub const MODEL_SIM_UTIL_FLOOR: f64 = 0.85;
/// Maximum |predicted − simulated| divergence on gated tune rows.
pub const MODEL_PRED_EPS: f64 = 0.10;
/// Tasks per processor in the tune phase. Deliberately larger than the
/// sweep workloads: at the sweep's T_job = 240 s even a single 240-task
/// bundle per processor cannot amortize YARN's per-job startup to 85 %,
/// so the headline "model-derived size reaches the target" claim needs
/// a job long enough that the target is reachable at all.
pub const MODEL_TUNE_TASKS_PER_PROC: u32 = 960;
/// Task time in the tune phase (seconds) — the paper's "short task"
/// regime where raw backends sit under 10 % utilization.
pub const MODEL_TUNE_TASK_SECS: f64 = 1.0;
/// Mean time between failures per node in the churn refit (seconds).
pub const MODEL_CHURN_MTBF_SECS: f64 = 480.0;
/// Mean time to repair per node in the churn refit (seconds).
pub const MODEL_CHURN_MTTR_SECS: f64 = 24.0;
/// Retry budget for churn-refit tasks: generous, so the refit measures
/// the latency shift of retried work rather than failure truncation.
const MODEL_CHURN_RETRIES: u32 = 8;

/// One backend's fitted parameters next to the paper's measurement.
#[derive(Clone, Debug)]
pub struct ModelFitRow {
    /// Which backend.
    pub choice: SchedulerChoice,
    /// Display name.
    pub scheduler: String,
    /// The hardened fit — `Err` carries scheduler + n-range context.
    pub fit: Result<FittedModel, String>,
    /// Paper Table 10 values, for the four schedulers it reports.
    pub paper: Option<PaperFit>,
    /// n values skipped as prohibitive in the sweep.
    pub skipped: Vec<u32>,
}

/// One backend's auto-tuned aggregation run.
#[derive(Clone, Debug)]
pub struct ModelTuneRow {
    /// Which backend.
    pub choice: SchedulerChoice,
    /// Display name of the wrapped scheduler.
    pub scheduler: String,
    /// The derived bundle size and its predicted utilization.
    pub bundle: BundleChoice,
    /// Simulation trials of `Multilevel` at the derived size.
    pub trials: Vec<RunResult>,
}

impl ModelTuneRow {
    /// Mean simulated utilization across trials.
    pub fn mean_utilization(&self) -> f64 {
        trial_mean(&self.trials, |r| r.utilization())
    }
}

/// One backend's refit under churn, next to its fault-free baseline.
#[derive(Clone, Debug)]
pub struct ModelChurnRow {
    /// Display name.
    pub scheduler: String,
    /// Refit of the same sweep under the seeded fault plan.
    pub fit: Result<FittedModel, String>,
    /// The fault-free fit this row shifts from (when it succeeded).
    pub base: Option<FittedModel>,
}

impl ModelChurnRow {
    /// Multiplicative t_s shift (churn / base), when both fits exist
    /// and the baseline has measurable overhead.
    pub fn t_s_shift(&self) -> Option<f64> {
        match (&self.fit, &self.base) {
            (Ok(c), Some(b)) if b.t_s > 0.0 => Some(c.t_s / b.t_s),
            _ => None,
        }
    }

    /// Additive α_s shift (churn − base), when both fits exist.
    pub fn alpha_shift(&self) -> Option<f64> {
        match (&self.fit, &self.base) {
            (Ok(c), Some(b)) => Some(c.alpha_s - b.alpha_s),
            _ => None,
        }
    }
}

/// Full report of the `model` experiment.
#[derive(Clone, Debug)]
pub struct ModelReport {
    /// Phase 1: per-backend fits vs paper.
    pub fits: Vec<ModelFitRow>,
    /// Phase 2: auto-tuned aggregation, one row per successful fit.
    pub tune: Vec<ModelTuneRow>,
    /// Phase 3 (`--churn` only): refits under the seeded fault plan.
    pub churn: Option<Vec<ModelChurnRow>>,
    /// The target utilization the tuner inverted for.
    pub target: f64,
}

/// Tune-cell: run `Multilevel` at row `row`'s derived size, one trial.
struct TuneCell {
    row: usize,
    seed: u64,
}

/// Churn-cell: one `(sweep, point, trial)` of the refit sweep.
struct ChurnCell {
    sweep: usize,
    point: usize,
    n: u32,
    seed: u64,
    workload: usize,
    plan: usize,
}

/// Run the `model` experiment: fit, tune, and optionally refit under
/// churn. Deterministic for any `cfg.jobs`.
pub fn model(cfg: &ExperimentConfig, churn: bool) -> ModelReport {
    let choices = SchedulerChoice::all_simulated();

    // ---- Phase 1: fit (t_s, α_s) from the shared sweep cells. ----
    let specs: Vec<SweepSpec> = choices.iter().map(|&c| (c, None)).collect();
    let sweeps = run_sweeps(&specs, cfg, &cfg.model_ns);
    let fits: Vec<ModelFitRow> = choices
        .iter()
        .zip(&sweeps)
        .map(|(&choice, sweep)| ModelFitRow {
            choice,
            scheduler: sweep.scheduler.clone(),
            fit: fit_sweep(&sweep.scheduler, &sweep.fit_points()),
            paper: paper_table10()
                .into_iter()
                .find(|p| p.scheduler == sweep.scheduler),
            skipped: sweep.skipped.clone(),
        })
        .collect();

    // ---- Phase 2: invert the model, run Multilevel at the answer. ----
    let cluster = cluster_of(cfg);
    let processors = cluster.total_cores();
    let params = MultilevelParams::default();
    let tune_workload = WorkloadBuilder::constant(MODEL_TUNE_TASK_SECS)
        .tasks(MODEL_TUNE_TASKS_PER_PROC as u64 * processors)
        .label("model-tune")
        .build();
    let tuned: Vec<(SchedulerChoice, String, BundleChoice)> = fits
        .iter()
        .filter_map(|row| {
            let f = row.fit.as_ref().ok()?;
            Some((
                row.choice,
                row.scheduler.clone(),
                derive_bundle_size(
                    f.t_s,
                    f.alpha_s,
                    &params,
                    MODEL_TUNE_TASK_SECS,
                    MODEL_TUNE_TASKS_PER_PROC,
                    cfg.model_target_util,
                ),
            ))
        })
        .collect();
    let tune_schedulers: Vec<Box<dyn Scheduler>> = tuned
        .iter()
        .map(|&(choice, _, _)| make_scheduler_scaled(choice, cfg.scale_down))
        .collect();
    let mut tune_cells: Vec<TuneCell> = Vec::new();
    for row in 0..tuned.len() {
        for trial in 0..cfg.trials {
            // A seed stream of its own, disjoint from the sweep cells'.
            let seed = (cfg.seed ^ 0x0DE1_7A6E)
                .wrapping_add(trial as u64)
                .wrapping_add((row as u64) << 24);
            tune_cells.push(TuneCell { row, seed });
        }
    }
    let tune_results = run_cells(cfg.effective_jobs(), &tune_cells, |cell, scratch| {
        let (_, _, bundle) = &tuned[cell.row];
        let ml = Multilevel::with_bundles_per_proc(
            tune_schedulers[cell.row].as_ref(),
            params.clone(),
            bundle.bundles_per_proc as u64,
        );
        let r = ml.run_with_scratch(
            &tune_workload,
            &cluster,
            cell.seed,
            &RunOptions::default(),
            scratch,
        );
        r.check_invariants()
            .unwrap_or_else(|e| panic!("model tune {}: {e}", tuned[cell.row].1));
        r
    });
    let mut tune: Vec<ModelTuneRow> = tuned
        .into_iter()
        .map(|(choice, scheduler, bundle)| ModelTuneRow {
            choice,
            scheduler,
            bundle,
            trials: Vec::with_capacity(cfg.trials as usize),
        })
        .collect();
    for (cell, result) in tune_cells.iter().zip(tune_results) {
        tune[cell.row].trials.push(result);
    }

    // ---- Phase 3: refit the same sweeps under seeded churn. ----
    let churn = churn.then(|| churn_refit(cfg, &fits));

    ModelReport {
        fits,
        tune,
        churn,
        target: cfg.model_target_util,
    }
}

/// Re-run the fit sweeps under a seeded [`FaultPlan`] and refit. The
/// plan at each `(n, trial)` is shared by every backend, so the shift
/// comparison across schedulers sees identical node weather.
fn churn_refit(cfg: &ExperimentConfig, fits: &[ModelFitRow]) -> Vec<ModelChurnRow> {
    let choices = SchedulerChoice::all_simulated();
    let cluster = cluster_of(cfg);
    let processors = cluster.total_cores();
    let schedulers: Vec<Box<dyn Scheduler>> = choices
        .iter()
        .map(|&c| make_scheduler_scaled(c, cfg.scale_down))
        .collect();

    let workloads: Vec<(u32, crate::workload::Workload)> = cfg
        .model_ns
        .iter()
        .map(|&n| {
            let mut w = workload_for(n, processors, &format!("n{n}+churn"));
            for task in &mut w.tasks {
                task.max_retries = MODEL_CHURN_RETRIES;
            }
            (n, w)
        })
        .collect();
    // One plan per (n, trial), shared across backends.
    let plans: Vec<FaultPlan> = cfg
        .model_ns
        .iter()
        .flat_map(|&n| {
            (0..cfg.trials).map(move |trial| {
                FaultPlan::seeded(
                    (cfg.seed ^ 0xC11A_0F0E)
                        .wrapping_add(trial as u64)
                        .wrapping_add((n as u64) << 24),
                    cfg.effective_nodes(),
                    MODEL_CHURN_MTBF_SECS,
                    MODEL_CHURN_MTTR_SECS,
                    PROHIBITIVE_SECS,
                )
            })
        })
        .collect();

    // Skeleton sweeps + flat cells, mirroring `run_sweeps` (which
    // hard-codes fault-free options and so cannot run this phase).
    let mut pooled: Vec<Vec<Vec<(f64, f64)>>> = Vec::new(); // [sweep][point] -> obs
    let mut cells: Vec<ChurnCell> = Vec::new();
    for (si, inner) in schedulers.iter().enumerate() {
        let mut points = Vec::new();
        for (wi, &(n, ref workload)) in workloads.iter().enumerate() {
            // Same prohibitive-cost skip as the fault-free sweep: the
            // fault-free projection decides, so both phases fit over
            // the same n values.
            if inner.projected_runtime(workload, &cluster) > PROHIBITIVE_SECS {
                continue;
            }
            let point = points.len();
            for trial in 0..cfg.trials {
                let ni = cfg.model_ns.iter().position(|&x| x == n).unwrap();
                cells.push(ChurnCell {
                    sweep: si,
                    point,
                    n,
                    seed: cfg
                        .seed
                        .wrapping_add(trial as u64)
                        .wrapping_add((n as u64) << 20),
                    workload: wi,
                    plan: ni * cfg.trials as usize + trial as usize,
                });
            }
            points.push(Vec::with_capacity(cfg.trials as usize));
        }
        pooled.push(points);
    }

    let results = run_cells(cfg.effective_jobs(), &cells, |cell, scratch| {
        let inner = schedulers[cell.sweep].as_ref();
        let options = RunOptions::with_faults(plans[cell.plan].clone());
        let r = inner.run_with_scratch(
            &workloads[cell.workload].1,
            &cluster,
            cell.seed,
            &options,
            scratch,
        );
        r.check_invariants()
            .unwrap_or_else(|e| panic!("model churn {} n={}: {e}", inner.name(), cell.n));
        r
    });
    for (cell, result) in cells.iter().zip(results) {
        pooled[cell.sweep][cell.point].push((cell.n as f64, result.delta_t()));
    }

    schedulers
        .iter()
        .zip(pooled)
        .map(|(inner, points)| {
            let name = inner.name().to_string();
            let obs: Vec<(f64, f64)> = points.into_iter().flatten().collect();
            let base = fits
                .iter()
                .find(|f| f.scheduler == name)
                .and_then(|f| f.fit.as_ref().ok())
                .cloned();
            ModelChurnRow {
                fit: fit_sweep(&format!("{name}+churn"), &obs),
                scheduler: name,
                base,
            }
        })
        .collect()
}

/// Format an `f64` for the CSV: fixed precision keeps the bytes stable
/// and diffable across platforms and `--jobs` values.
fn csv_num(x: f64) -> String {
    format!("{x:.6}")
}

/// Flatten an error note into a single CSV-safe field.
fn csv_note(s: &str) -> String {
    s.replace([',', '\n'], ";")
}

impl ModelReport {
    /// Phase-1 table: fitted parameters vs the paper's Table 10.
    pub fn render_fits(&self) -> Table {
        let mut t = Table::new(
            "Model: fitted DT = t_s * n^alpha_s per backend vs paper Table 10",
            &[
                "scheduler",
                "t_s",
                "alpha_s",
                "R2",
                "points",
                "n range",
                "t_s paper",
                "alpha paper",
                "note",
            ],
        );
        for row in &self.fits {
            let (paper_ts, paper_a) = match &row.paper {
                Some(p) => (fnum(p.t_s), fnum(p.alpha_s)),
                None => ("-".into(), "-".into()),
            };
            match &row.fit {
                Ok(f) => {
                    t.row(&[
                        row.scheduler.clone(),
                        fnum(f.t_s),
                        fnum(f.alpha_s),
                        fnum(f.r2),
                        f.points.to_string(),
                        format!("{}..{}", f.n_lo, f.n_hi),
                        paper_ts,
                        paper_a,
                        if f.zero_overhead {
                            "zero-overhead".into()
                        } else {
                            String::new()
                        },
                    ]);
                }
                Err(e) => {
                    t.row(&[
                        row.scheduler.clone(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "0".into(),
                        "-".into(),
                        paper_ts,
                        paper_a,
                        format!("FIT FAILED: {e}"),
                    ]);
                }
            }
        }
        t
    }

    /// Phase-2 table: the derived bundle size, predicted vs simulated.
    pub fn render_tune(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Model: auto-tuned aggregation for {} s tasks, n = {}/proc (target U >= {})",
                fnum(MODEL_TUNE_TASK_SECS),
                MODEL_TUNE_TASKS_PER_PROC,
                fnum(self.target)
            ),
            &[
                "scheduler",
                "bundle size",
                "bundles/proc",
                "U predicted",
                "U simulated",
                "|diff|",
                "note",
            ],
        );
        for row in &self.tune {
            let sim = row.mean_utilization();
            t.row(&[
                row.scheduler.clone(),
                row.bundle.bundle_size.to_string(),
                row.bundle.bundles_per_proc.to_string(),
                fnum(row.bundle.predicted_u),
                fnum(sim),
                fnum((sim - row.bundle.predicted_u).abs()),
                if row.bundle.capped {
                    "capped (target unreachable)".into()
                } else {
                    String::new()
                },
            ]);
        }
        t
    }

    /// Phase-3 table, when the churn refit ran.
    pub fn render_churn(&self) -> Option<Table> {
        let churn = self.churn.as_ref()?;
        let mut t = Table::new(
            format!(
                "Model: (t_s, alpha_s) refit under churn (MTBF {} s, MTTR {} s per node)",
                fnum(MODEL_CHURN_MTBF_SECS),
                fnum(MODEL_CHURN_MTTR_SECS)
            ),
            &[
                "scheduler",
                "t_s churn",
                "alpha churn",
                "R2",
                "t_s shift x",
                "alpha shift",
                "note",
            ],
        );
        for row in churn {
            match &row.fit {
                Ok(f) => {
                    t.row(&[
                        row.scheduler.clone(),
                        fnum(f.t_s),
                        fnum(f.alpha_s),
                        fnum(f.r2),
                        row.t_s_shift().map(fnum).unwrap_or_else(|| "-".into()),
                        row.alpha_shift().map(fnum).unwrap_or_else(|| "-".into()),
                        if f.zero_overhead {
                            "zero-overhead".into()
                        } else {
                            String::new()
                        },
                    ]);
                }
                Err(e) => {
                    t.row(&[
                        row.scheduler.clone(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        format!("REFIT FAILED: {e}"),
                    ]);
                }
            }
        }
        Some(t)
    }

    /// The experiment's CSV: one row per fit / tune / churn entry,
    /// distinguished by the `kind` column. Fully deterministic — no
    /// wall-clock content — so it is byte-identical for any `--jobs`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "kind,scheduler,t_s,alpha_s,r2,zero_overhead,points,t_s_paper,alpha_paper,\
             bundle_size,bundles_per_proc,predicted_u,simulated_u,capped,t_s_shift,\
             alpha_shift,note\n",
        );
        let blank = |n: usize| vec![String::new(); n];
        let mut push = |fields: Vec<String>| {
            out.push_str(&fields.join(","));
            out.push('\n');
        };
        for row in &self.fits {
            let (pt, pa) = match &row.paper {
                Some(p) => (csv_num(p.t_s), csv_num(p.alpha_s)),
                None => (String::new(), String::new()),
            };
            let mut fields = vec!["fit".to_string(), row.scheduler.clone()];
            match &row.fit {
                Ok(f) => {
                    fields.extend([
                        csv_num(f.t_s),
                        csv_num(f.alpha_s),
                        csv_num(f.r2),
                        f.zero_overhead.to_string(),
                        f.points.to_string(),
                        pt,
                        pa,
                    ]);
                    fields.extend(blank(7));
                    fields.push(String::new());
                }
                Err(e) => {
                    fields.extend(blank(5));
                    fields.extend([pt, pa]);
                    fields.extend(blank(7));
                    fields.push(csv_note(e));
                }
            }
            push(fields);
        }
        for row in &self.tune {
            let mut fields = vec!["tune".to_string(), row.scheduler.clone()];
            fields.extend(blank(7));
            fields.extend([
                row.bundle.bundle_size.to_string(),
                row.bundle.bundles_per_proc.to_string(),
                csv_num(row.bundle.predicted_u),
                csv_num(row.mean_utilization()),
                row.bundle.capped.to_string(),
            ]);
            fields.extend(blank(2));
            fields.push(String::new());
            push(fields);
        }
        for row in self.churn.iter().flatten() {
            let mut fields = vec!["churn".to_string(), row.scheduler.clone()];
            match &row.fit {
                Ok(f) => {
                    fields.extend([
                        csv_num(f.t_s),
                        csv_num(f.alpha_s),
                        csv_num(f.r2),
                        f.zero_overhead.to_string(),
                        f.points.to_string(),
                    ]);
                    fields.extend(blank(7));
                    fields.extend([
                        row.t_s_shift().map(csv_num).unwrap_or_default(),
                        row.alpha_shift().map(csv_num).unwrap_or_default(),
                    ]);
                    fields.push(String::new());
                }
                Err(e) => {
                    fields.extend(blank(14));
                    fields.push(csv_note(e));
                }
            }
            push(fields);
        }
        out
    }

    /// Structural gates, enforced by CI's model smoke step:
    ///
    /// * every backend's fit succeeded, with finite parameters;
    /// * gated rows (the four paper schedulers) have measurable
    ///   overhead and R² ≥ [`MODEL_R2_GATE`];
    /// * every tune row ran all trials at a sane derived size, and on
    ///   gated rows the simulated utilization is ≥
    ///   [`MODEL_SIM_UTIL_FLOOR`] *and* within [`MODEL_PRED_EPS`] of
    ///   the model's prediction — the closed-loop claim itself;
    /// * when the churn refit ran, it succeeded for every backend whose
    ///   fault-free fit had measurable overhead.
    pub fn check_shape(&self, cfg: &ExperimentConfig) -> Result<(), String> {
        let gated = SchedulerChoice::paper_four();
        if self.fits.len() != SchedulerChoice::all_simulated().len() {
            return Err(format!("expected 6 fit rows, got {}", self.fits.len()));
        }
        for row in &self.fits {
            let f = row.fit.as_ref().map_err(|e| format!("fit failed: {e}"))?;
            if !(f.t_s.is_finite() && f.alpha_s.is_finite() && f.t_s >= 0.0) {
                return Err(format!(
                    "{}: non-finite or negative fit (t_s={}, alpha_s={})",
                    row.scheduler, f.t_s, f.alpha_s
                ));
            }
            if gated.contains(&row.choice) {
                if f.zero_overhead {
                    return Err(format!(
                        "{}: paper scheduler fitted as zero-overhead — sweep measured no DT",
                        row.scheduler
                    ));
                }
                if f.r2 < MODEL_R2_GATE {
                    return Err(format!(
                        "{}: R2 {} below gate {MODEL_R2_GATE} over n in [{}, {}]",
                        row.scheduler,
                        fnum(f.r2),
                        f.n_lo,
                        f.n_hi
                    ));
                }
            }
        }
        if self.tune.len() != self.fits.len() {
            return Err(format!(
                "expected {} tune rows, got {}",
                self.fits.len(),
                self.tune.len()
            ));
        }
        for row in &self.tune {
            let b = &row.bundle;
            if b.bundles_per_proc < 1
                || b.bundles_per_proc > MODEL_TUNE_TASKS_PER_PROC
                || b.bundle_size < 1
                || !(b.predicted_u > 0.0 && b.predicted_u <= 1.0)
            {
                return Err(format!(
                    "{}: insane bundle choice (m={}, k={}, predicted U={})",
                    row.scheduler, b.bundles_per_proc, b.bundle_size, b.predicted_u
                ));
            }
            if row.trials.len() != cfg.trials as usize {
                return Err(format!(
                    "{}: ran {} tune trials, expected {}",
                    row.scheduler,
                    row.trials.len(),
                    cfg.trials
                ));
            }
            let sim = row.mean_utilization();
            if !sim.is_finite() || sim <= 0.0 || sim > 1.0 + 1e-9 {
                return Err(format!("{}: insane simulated U {sim}", row.scheduler));
            }
            if gated.contains(&row.choice) {
                if b.capped {
                    return Err(format!(
                        "{}: target U {} unreachable even at one bundle per processor",
                        row.scheduler, self.target
                    ));
                }
                if sim < MODEL_SIM_UTIL_FLOOR {
                    return Err(format!(
                        "{}: simulated U {} below floor {MODEL_SIM_UTIL_FLOOR} at derived \
                         bundle size {}",
                        row.scheduler,
                        fnum(sim),
                        b.bundle_size
                    ));
                }
                if (sim - b.predicted_u).abs() > MODEL_PRED_EPS {
                    return Err(format!(
                        "{}: simulated U {} diverges from predicted {} by more than \
                         {MODEL_PRED_EPS}",
                        row.scheduler,
                        fnum(sim),
                        fnum(b.predicted_u)
                    ));
                }
            }
        }
        if let Some(churn) = &self.churn {
            for row in churn {
                let measurable_base = row.base.as_ref().is_some_and(|b| !b.zero_overhead);
                match &row.fit {
                    Err(e) if measurable_base => {
                        return Err(format!("churn refit failed: {e}"));
                    }
                    Ok(f) if measurable_base && !(f.t_s.is_finite() && f.alpha_s.is_finite()) => {
                        return Err(format!(
                            "{}: non-finite churn refit (t_s={}, alpha_s={})",
                            row.scheduler, f.t_s, f.alpha_s
                        ));
                    }
                    _ => {}
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.scale_down = 11; // 4 nodes, 128 cores — fast in tests
        cfg.trials = 1;
        cfg.model_ns = vec![4, 8, 48];
        cfg
    }

    #[test]
    fn model_report_structure() {
        let rep = model(&tiny_cfg(), false);
        assert_eq!(rep.fits.len(), 6);
        assert_eq!(rep.tune.len(), 6, "every fit Ok => every backend tuned");
        assert!(rep.churn.is_none());
        for row in &rep.fits {
            let f = row.fit.as_ref().unwrap();
            assert!(f.t_s.is_finite() && f.alpha_s.is_finite());
        }
        for row in &rep.tune {
            assert!(row.bundle.bundles_per_proc >= 1);
            assert!(row.bundle.bundle_size as u32 <= MODEL_TUNE_TASKS_PER_PROC);
            assert_eq!(row.trials.len(), 1);
            let sim = row.mean_utilization();
            assert!(sim > 0.0 && sim <= 1.0 + 1e-9, "{}: U={sim}", row.scheduler);
        }
        // The paper's four get comparison columns; the extras don't.
        assert_eq!(rep.fits.iter().filter(|r| r.paper.is_some()).count(), 4);
    }

    #[test]
    fn churn_refit_shifts_params_for_paper_backends() {
        let rep = model(&tiny_cfg(), true);
        let churn = rep.churn.as_ref().unwrap();
        assert_eq!(churn.len(), 6);
        for row in churn {
            if row.base.as_ref().is_some_and(|b| !b.zero_overhead) {
                let f = row.fit.as_ref().unwrap_or_else(|e| panic!("{e}"));
                assert!(f.t_s.is_finite() && f.alpha_s.is_finite());
            }
        }
        // Churn can only add effective overhead in aggregate: at least
        // one measurable backend must show a t_s or alpha_s increase.
        assert!(
            churn.iter().any(|r| {
                r.t_s_shift().is_some_and(|s| s > 1.0)
                    || r.alpha_shift().is_some_and(|d| d > 0.0)
            }),
            "no backend shifted under churn"
        );
    }

    #[test]
    fn csv_is_deterministic_and_kind_tagged() {
        let rep = model(&tiny_cfg(), false);
        let csv = rep.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines[0].starts_with("kind,scheduler,"));
        let cols = lines[0].split(',').count();
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), cols, "ragged row: {line}");
        }
        assert_eq!(csv.matches("\nfit,").count() + 1, 7); // header + 6 (first row offset)
        assert_eq!(csv.matches("\ntune,").count(), 6);
        assert_eq!(rep.to_csv(), csv, "recomputation stable");
    }
}
