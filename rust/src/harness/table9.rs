//! Table 9: runtimes of the four constant-task-time sets on the four
//! schedulers, three trials each.

use super::sweep::{run_sweeps, SchedulerSweep, SweepSpec};
use crate::config::ExperimentConfig;
use crate::sched::calibration::paper_table9_runtimes;
use crate::util::table::{fnum, Table};
use crate::workload::table9_sets;

/// Table 9 results for all schedulers.
pub struct Table9Report {
    /// One sweep per scheduler over the Table 9 n values.
    pub sweeps: Vec<SchedulerSweep>,
    /// Trials per point.
    pub trials: u32,
}

/// Run Table 9. All schedulers' cells execute in one parallel batch.
pub fn table9(cfg: &ExperimentConfig) -> Table9Report {
    let ns: Vec<u32> = table9_sets().iter().map(|s| s.tasks_per_proc).collect();
    let specs: Vec<SweepSpec> = cfg.schedulers.iter().map(|&c| (c, None)).collect();
    Table9Report {
        sweeps: run_sweeps(&specs, cfg, &ns),
        trials: cfg.trials,
    }
}

impl Table9Report {
    /// Render in the paper's layout (one row per scheduler × set, with
    /// all trial runtimes and the paper's means for comparison).
    pub fn render(&self) -> Table {
        let mut t = Table::new(
            "Table 9: runtimes by task set (simulated, s)",
            &["scheduler", "set", "t (s)", "n", "trial runtimes", "mean", "paper mean", "ratio"],
        );
        let sets = table9_sets();
        let paper = paper_table9_runtimes();
        for sweep in &self.sweeps {
            for set in &sets {
                let paper_mean = paper
                    .iter()
                    .find(|(name, _)| *name == sweep.scheduler)
                    .and_then(|(_, runtimes)| {
                        let idx = sets
                            .iter()
                            .position(|s| s.name == set.name)
                            .unwrap();
                        runtimes[idx]
                    });
                match sweep.points.iter().find(|p| p.n == set.tasks_per_proc) {
                    Some(point) => {
                        let runtimes: Vec<String> =
                            point.trials.iter().map(|r| fnum(r.t_total)).collect();
                        let mean = point.mean_t_total();
                        t.row(&[
                            sweep.scheduler.clone(),
                            set.name.into(),
                            fnum(set.task_time),
                            set.tasks_per_proc.to_string(),
                            runtimes.join(", "),
                            fnum(mean),
                            paper_mean.map(fnum).unwrap_or_else(|| "-".into()),
                            paper_mean
                                .map(|p| format!("{:.2}", mean / p))
                                .unwrap_or_else(|| "-".into()),
                        ]);
                    }
                    None if sweep.skipped.contains(&set.tasks_per_proc) => {
                        t.row(&[
                            sweep.scheduler.clone(),
                            set.name.into(),
                            fnum(set.task_time),
                            set.tasks_per_proc.to_string(),
                            "abandoned (prohibitive)".into(),
                            "-".into(),
                            "-".into(),
                            "-".into(),
                        ]);
                    }
                    None => {}
                }
            }
        }
        t
    }

    /// Shape assertions against the paper (used by tests/benches):
    /// ratios to the paper means stay within `tol` where both exist;
    /// YARN rapid is skipped.
    pub fn check_shape(&self, tol: f64) -> Result<(), String> {
        let sets = table9_sets();
        let paper = paper_table9_runtimes();
        for sweep in &self.sweeps {
            let Some((_, paper_runtimes)) =
                paper.iter().find(|(name, _)| *name == sweep.scheduler)
            else {
                continue;
            };
            for (idx, set) in sets.iter().enumerate() {
                match (
                    sweep.points.iter().find(|p| p.n == set.tasks_per_proc),
                    paper_runtimes[idx],
                ) {
                    (Some(point), Some(paper_mean)) => {
                        let ratio = point.mean_t_total() / paper_mean;
                        if !((1.0 - tol)..=(1.0 + tol)).contains(&ratio) {
                            return Err(format!(
                                "{} {}: sim/paper ratio {ratio:.2} outside ±{tol}",
                                sweep.scheduler, set.name
                            ));
                        }
                    }
                    (None, None) => {} // both abandoned: correct
                    (None, Some(_)) => {
                        return Err(format!(
                            "{} {}: simulated run skipped but paper ran it",
                            sweep.scheduler, set.name
                        ));
                    }
                    (Some(_), None) => {
                        return Err(format!(
                            "{} {}: paper abandoned this but the sim ran it",
                            sweep.scheduler, set.name
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}
