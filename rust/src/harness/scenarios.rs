//! `scenarios` experiment: ΔT and utilization across the full workload
//! space — job arrays, DAG chains, gang-scheduled parallel jobs,
//! multi-core tasks and arrival processes — on every simulated
//! scheduler family.
//!
//! The paper's Table 9 benchmark exercises exactly one point of the
//! workload space of its Figure 2 (independent 1-core array tasks, all
//! submitted at t = 0). The unified kernel executes the remaining
//! dimensions for every backend at once, so this runner sweeps the
//! cross product {array, multicore, dag-chain, gang, poisson, burst} ×
//! {Slurm, GridEngine, Mesos, YARN, Sparrow, IdealFIFO}, with the same
//! per-processor work (T_job = 240 s) as the Table 9 sets.
//!
//! Cells run on the deterministic parallel executor, so results are
//! bit-identical for every `--jobs` value.

use super::parallel::run_cells;
use super::sweep::PROHIBITIVE_SECS;
use crate::config::{ExperimentConfig, SchedulerChoice};
use crate::sched::{make_scheduler_scaled, RunOptions, RunResult, Scheduler};
use crate::util::table::{fnum, Table};
use crate::workload::{ArrivalProcess, Workload, WorkloadBuilder, TABLE9_JOB_TIME_PER_PROC};

/// Gang width used by the gang scenario (also the DAG chain depth).
pub const GANG_SIZE: u32 = 8;

/// One (scenario, scheduler) cell of the sweep.
pub struct ScenarioCell {
    /// Scenario name ("array", "dag-chain", ...).
    pub scenario: &'static str,
    /// Scheduler display name.
    pub scheduler: String,
    /// One result per trial (empty iff skipped as prohibitive).
    pub trials: Vec<RunResult>,
}

impl ScenarioCell {
    /// Mean ΔT across trials.
    pub fn mean_delta_t(&self) -> f64 {
        self.trials.iter().map(|r| r.delta_t()).sum::<f64>() / self.trials.len().max(1) as f64
    }

    /// Mean utilization across trials.
    pub fn mean_utilization(&self) -> f64 {
        self.trials.iter().map(|r| r.utilization()).sum::<f64>()
            / self.trials.len().max(1) as f64
    }

    /// Mean of the per-trial mean scheduler-induced waits.
    pub fn mean_wait(&self) -> f64 {
        self.trials.iter().map(|r| r.waits.mean()).sum::<f64>()
            / self.trials.len().max(1) as f64
    }
}

/// Full scenarios sweep.
pub struct ScenariosReport {
    /// All (scenario, scheduler) cells, scenario-major.
    pub cells: Vec<ScenarioCell>,
    /// Cells skipped as prohibitive (the YARN-rapid treatment).
    pub skipped: Vec<(&'static str, String)>,
    /// Tasks per processor n.
    pub n: u32,
    /// Constant task time t = T_job / n.
    pub t: f64,
}

fn scenario_workloads(cfg: &ExperimentConfig, processors: u64) -> Vec<(&'static str, Workload)> {
    let n = cfg.scenario_n.max(1);
    let t = TABLE9_JOB_TIME_PER_PROC / n as f64;
    let total = n as u64 * processors;
    let rate = cfg.arrival_rho * processors as f64 / t;
    let base = |label: &str| WorkloadBuilder::constant(t).seed(cfg.seed).label(label);
    let out = vec![
        ("array", base("array").tasks(total).build()),
        (
            "multicore",
            base("multicore").tasks((total / 2).max(1)).cores(2).build(),
        ),
        (
            "dag-chain",
            base("dag-chain").tasks(total).dag_chains(GANG_SIZE).build(),
        ),
        ("gang", base("gang").tasks(total).gangs(GANG_SIZE).build()),
        (
            "poisson",
            base("poisson")
                .tasks(total)
                .arrivals(ArrivalProcess::Poisson { rate })
                .build(),
        ),
        (
            "burst",
            base("burst")
                .tasks(total)
                .arrivals(ArrivalProcess::Bursty {
                    burst: processors.max(1) as u32,
                    period: t,
                })
                .build(),
        ),
    ];
    for (name, w) in &out {
        w.validate()
            .unwrap_or_else(|e| panic!("scenario {name} workload invalid: {e}"));
    }
    out
}

/// Run the scenarios sweep: every scenario × every simulated scheduler
/// family × `cfg.trials`, in one deterministic parallel batch.
pub fn scenarios(cfg: &ExperimentConfig) -> ScenariosReport {
    let cluster = crate::cluster::ClusterSpec::homogeneous(
        cfg.effective_nodes(),
        cfg.cores_per_node,
        cfg.mem_mb,
        (cfg.effective_nodes() / 2).max(1),
    );
    let processors = cluster.total_cores();
    let workloads = scenario_workloads(cfg, processors);
    let choices = SchedulerChoice::all_simulated();
    let schedulers: Vec<Box<dyn Scheduler>> = choices
        .iter()
        .map(|&c| make_scheduler_scaled(c, cfg.scale_down))
        .collect();

    // Flat cell list: (scenario idx, scheduler idx, trial) with seeds
    // derived independently of execution order.
    struct Cell<'a> {
        sched: usize,
        /// Index into the assembled report cells (set at creation so
        /// reassembly is a direct index, not a name lookup).
        slot: usize,
        workload: &'a Workload,
        seed: u64,
    }
    let mut cells: Vec<Cell> = Vec::new();
    let mut out: Vec<ScenarioCell> = Vec::new();
    let mut skipped: Vec<(&'static str, String)> = Vec::new();
    for (si, &(name, ref workload)) in workloads.iter().enumerate() {
        for (ki, sched) in schedulers.iter().enumerate() {
            if sched.projected_runtime(workload, &cluster) > PROHIBITIVE_SECS {
                skipped.push((name, sched.name().to_string()));
                continue;
            }
            for trial in 0..cfg.trials {
                cells.push(Cell {
                    sched: ki,
                    slot: out.len(),
                    workload,
                    seed: cfg
                        .seed
                        .wrapping_add(trial as u64)
                        .wrapping_add((si as u64) << 24)
                        .wrapping_add((ki as u64) << 16),
                });
            }
            out.push(ScenarioCell {
                scenario: name,
                scheduler: sched.name().to_string(),
                trials: Vec::with_capacity(cfg.trials as usize),
            });
        }
    }

    let results = run_cells(cfg.effective_jobs(), &cells, |cell, scratch| {
        let sched = schedulers[cell.sched].as_ref();
        let r = sched.run_with_scratch(
            cell.workload,
            &cluster,
            cell.seed,
            &RunOptions::default(),
            scratch,
        );
        r.check_invariants().unwrap_or_else(|e| {
            panic!(
                "{} on {}: {e}",
                sched.name(),
                cell.workload.label
            )
        });
        r
    });
    for (cell, result) in cells.iter().zip(results) {
        out[cell.slot].trials.push(result);
    }

    ScenariosReport {
        cells: out,
        skipped,
        n: cfg.scenario_n.max(1),
        t: TABLE9_JOB_TIME_PER_PROC / cfg.scenario_n.max(1) as f64,
    }
}

impl ScenariosReport {
    /// Rendered summary table.
    pub fn render_table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Scenarios — ΔT and utilization across workload shapes \
                 (n={}, t={} s)",
                self.n,
                fnum(self.t)
            ),
            &["scenario", "scheduler", "ΔT (s)", "U", "mean wait (s)"],
        );
        for c in &self.cells {
            t.row(&[
                c.scenario.to_string(),
                c.scheduler.clone(),
                fnum(c.mean_delta_t()),
                format!("{:.3}", c.mean_utilization()),
                fnum(c.mean_wait()),
            ]);
        }
        for (scenario, sched) in &self.skipped {
            t.row(&[
                scenario.to_string(),
                sched.clone(),
                "(skipped)".into(),
                "-".into(),
                "-".into(),
            ]);
        }
        t
    }

    /// CSV series (scenario, scheduler, trial, delta_t, utilization).
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(
            "",
            &["scenario", "scheduler", "trial", "delta_t_s", "utilization"],
        );
        for c in &self.cells {
            for (trial, r) in c.trials.iter().enumerate() {
                t.row(&[
                    c.scenario.to_string(),
                    c.scheduler.clone(),
                    trial.to_string(),
                    format!("{:.3}", r.delta_t()),
                    format!("{:.4}", r.utilization()),
                ]);
            }
        }
        t.to_csv()
    }

    fn cell(&self, scenario: &str, scheduler: &str) -> Option<&ScenarioCell> {
        self.cells
            .iter()
            .find(|c| c.scenario == scenario && c.scheduler == scheduler)
    }

    /// Structural shape checks (loose bounds — mechanisms, not
    /// calibration): the zero-overhead reference behaves exactly, DAG
    /// chains serialize, gangs pack, and every non-skipped cell ran all
    /// its trials.
    pub fn check_shape(&self, trials: u32) -> Result<(), String> {
        for c in &self.cells {
            if c.trials.len() != trials as usize {
                return Err(format!(
                    "{} × {}: {} of {trials} trials ran",
                    c.scenario,
                    c.scheduler,
                    c.trials.len()
                ));
            }
        }
        let ideal_array = self
            .cell("array", "IdealFIFO")
            .ok_or("missing ideal array cell")?;
        if ideal_array.mean_delta_t().abs() > 1e-6 {
            return Err(format!(
                "ideal array ΔT={} should be ~0",
                ideal_array.mean_delta_t()
            ));
        }
        let ideal_chain = self
            .cell("dag-chain", "IdealFIFO")
            .ok_or("missing ideal dag-chain cell")?;
        let chain_floor = GANG_SIZE as f64 * self.t * 0.999;
        for r in &ideal_chain.trials {
            if r.t_total < chain_floor {
                return Err(format!(
                    "dag-chain t_total {} below serial floor {chain_floor}",
                    r.t_total
                ));
            }
        }
        let ideal_gang = self
            .cell("gang", "IdealFIFO")
            .ok_or("missing ideal gang cell")?;
        if ideal_gang.mean_utilization() < 0.99 {
            return Err(format!(
                "ideal gang utilization {} should pack perfectly",
                ideal_gang.mean_utilization()
            ));
        }
        // Real control planes cost something on every scenario.
        for c in &self.cells {
            if c.scheduler != "IdealFIFO" && c.mean_delta_t() < 0.0 {
                return Err(format!(
                    "{} × {}: negative ΔT {}",
                    c.scenario,
                    c.scheduler,
                    c.mean_delta_t()
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.scale_down = 11; // 4 nodes × 32 = 128 cores
        cfg.trials = 1;
        cfg.scenario_n = 4;
        cfg
    }

    #[test]
    fn scenarios_run_and_pass_shape_checks() {
        let cfg = quick_cfg();
        let rep = scenarios(&cfg);
        rep.check_shape(cfg.trials).unwrap();
        // 6 scenarios × 6 schedulers, minus any prohibitive skips.
        assert_eq!(rep.cells.len() + rep.skipped.len(), 36);
        assert!(!rep.to_csv().is_empty());
    }

    #[test]
    fn scenarios_deterministic_across_jobs() {
        let mut a_cfg = quick_cfg();
        a_cfg.jobs = 1;
        let mut b_cfg = quick_cfg();
        b_cfg.jobs = 4;
        let a = scenarios(&a_cfg);
        let b = scenarios(&b_cfg);
        assert_eq!(a.cells.len(), b.cells.len());
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.scenario, cb.scenario);
            assert_eq!(ca.scheduler, cb.scheduler);
            for (ra, rb) in ca.trials.iter().zip(&cb.trials) {
                assert_eq!(
                    ra.t_total.to_bits(),
                    rb.t_total.to_bits(),
                    "{} × {}",
                    ca.scenario,
                    ca.scheduler
                );
                assert_eq!(ra.events, rb.events);
            }
        }
    }
}
