//! `scenarios` experiment: ΔT and utilization across the full workload
//! space — job arrays, DAG chains, gang-scheduled parallel jobs,
//! multi-core tasks and arrival processes — on every simulated
//! scheduler family.
//!
//! The paper's Table 9 benchmark exercises exactly one point of the
//! workload space of its Figure 2 (independent 1-core array tasks, all
//! submitted at t = 0). The unified kernel executes the remaining
//! dimensions for every backend at once, so this runner sweeps the
//! cross product {array, multicore, dag-chain, gang, poisson, burst} ×
//! {Slurm, GridEngine, Mesos, YARN, Sparrow, IdealFIFO}, with the same
//! per-processor work (T_job = 240 s) as the Table 9 sets.
//!
//! Cells run on the deterministic parallel executor, so results are
//! bit-identical for every `--jobs` value.
//!
//! The `preempt` experiment family lives here too: a priority-mixed
//! workload (preemptible low-priority background saturating the
//! cluster + high-priority Poisson foreground arrivals) swept over
//! checkpoint-cost fractions × ordering disciplines × every scheduler
//! family, each run under the [`combinators::Preemptive`] wrapper. It
//! measures fairness-vs-ΔT (per-priority-class queueing delays) and
//! preemption-overhead-vs-utilization.

use super::parallel::run_cells;
use super::sweep::{trial_mean, PROHIBITIVE_SECS};
use crate::cluster::{FaultPlan, MessagePlan};
use crate::config::{ExperimentConfig, SchedulerChoice};
use crate::model::{fit_sweep, FittedModel};
use crate::sched::combinators::{self, Order};
use crate::sched::{make_scheduler_scaled, RunOptions, RunResult, Scheduler};
use crate::util::prng::Prng;
use crate::util::table::{fnum, Table};
use crate::workload::{
    ArrivalProcess, TaskSpec, Workload, WorkloadBuilder, TABLE9_JOB_TIME_PER_PROC,
};

/// Gang width used by the gang scenario (also the DAG chain depth).
pub const GANG_SIZE: u32 = 8;

/// One (scenario, scheduler) cell of the sweep.
pub struct ScenarioCell {
    /// Scenario name ("array", "dag-chain", ...).
    pub scenario: &'static str,
    /// Scheduler display name.
    pub scheduler: String,
    /// One result per trial (empty iff skipped as prohibitive).
    pub trials: Vec<RunResult>,
}

impl ScenarioCell {
    /// Mean ΔT across trials.
    pub fn mean_delta_t(&self) -> f64 {
        trial_mean(&self.trials, |r| r.delta_t())
    }

    /// Mean utilization across trials.
    pub fn mean_utilization(&self) -> f64 {
        trial_mean(&self.trials, |r| r.utilization())
    }

    /// Mean of the per-trial mean scheduler-induced waits.
    pub fn mean_wait(&self) -> f64 {
        trial_mean(&self.trials, |r| r.waits.mean())
    }
}

/// Full scenarios sweep.
pub struct ScenariosReport {
    /// All (scenario, scheduler) cells, scenario-major.
    pub cells: Vec<ScenarioCell>,
    /// Cells skipped as prohibitive (the YARN-rapid treatment).
    pub skipped: Vec<(&'static str, String)>,
    /// Tasks per processor n.
    pub n: u32,
    /// Constant task time t = T_job / n.
    pub t: f64,
}

fn scenario_workloads(cfg: &ExperimentConfig, processors: u64) -> Vec<(&'static str, Workload)> {
    let n = cfg.scenario_n.max(1);
    let t = TABLE9_JOB_TIME_PER_PROC / n as f64;
    let total = n as u64 * processors;
    let rate = cfg.arrival_rho * processors as f64 / t;
    let base = |label: &str| WorkloadBuilder::constant(t).seed(cfg.seed).label(label);
    let out = vec![
        ("array", base("array").tasks(total).build()),
        (
            "multicore",
            base("multicore").tasks((total / 2).max(1)).cores(2).build(),
        ),
        (
            "dag-chain",
            base("dag-chain").tasks(total).dag_chains(GANG_SIZE).build(),
        ),
        ("gang", base("gang").tasks(total).gangs(GANG_SIZE).build()),
        (
            "poisson",
            base("poisson")
                .tasks(total)
                .arrivals(ArrivalProcess::Poisson { rate })
                .build(),
        ),
        (
            "burst",
            base("burst")
                .tasks(total)
                .arrivals(ArrivalProcess::Bursty {
                    burst: processors.max(1) as u32,
                    period: t,
                })
                .build(),
        ),
    ];
    for (name, w) in &out {
        w.validate()
            .unwrap_or_else(|e| panic!("scenario {name} workload invalid: {e}"));
    }
    out
}

/// Run the scenarios sweep: every scenario × every simulated scheduler
/// family × `cfg.trials`, in one deterministic parallel batch.
pub fn scenarios(cfg: &ExperimentConfig) -> ScenariosReport {
    let cluster = crate::cluster::ClusterSpec::homogeneous(
        cfg.effective_nodes(),
        cfg.cores_per_node,
        cfg.mem_mb,
        (cfg.effective_nodes() / 2).max(1),
    );
    let processors = cluster.total_cores();
    let workloads = scenario_workloads(cfg, processors);
    let choices = SchedulerChoice::all_simulated();
    let schedulers: Vec<Box<dyn Scheduler>> = choices
        .iter()
        .map(|&c| make_scheduler_scaled(c, cfg.scale_down))
        .collect();

    // Flat cell list: (scenario idx, scheduler idx, trial) with seeds
    // derived independently of execution order.
    struct Cell<'a> {
        sched: usize,
        /// Index into the assembled report cells (set at creation so
        /// reassembly is a direct index, not a name lookup).
        slot: usize,
        workload: &'a Workload,
        seed: u64,
    }
    let mut cells: Vec<Cell> = Vec::new();
    let mut out: Vec<ScenarioCell> = Vec::new();
    let mut skipped: Vec<(&'static str, String)> = Vec::new();
    for (si, &(name, ref workload)) in workloads.iter().enumerate() {
        for (ki, sched) in schedulers.iter().enumerate() {
            if sched.projected_runtime(workload, &cluster) > PROHIBITIVE_SECS {
                skipped.push((name, sched.name().to_string()));
                continue;
            }
            for trial in 0..cfg.trials {
                cells.push(Cell {
                    sched: ki,
                    slot: out.len(),
                    workload,
                    seed: cfg
                        .seed
                        .wrapping_add(trial as u64)
                        .wrapping_add((si as u64) << 24)
                        .wrapping_add((ki as u64) << 16),
                });
            }
            out.push(ScenarioCell {
                scenario: name,
                scheduler: sched.name().to_string(),
                trials: Vec::with_capacity(cfg.trials as usize),
            });
        }
    }

    let results = run_cells(cfg.effective_jobs(), &cells, |cell, scratch| {
        let sched = schedulers[cell.sched].as_ref();
        let r = sched.run_with_scratch(
            cell.workload,
            &cluster,
            cell.seed,
            &RunOptions::default(),
            scratch,
        );
        r.check_invariants().unwrap_or_else(|e| {
            panic!(
                "{} on {}: {e}",
                sched.name(),
                cell.workload.label
            )
        });
        r
    });
    for (cell, result) in cells.iter().zip(results) {
        out[cell.slot].trials.push(result);
    }

    ScenariosReport {
        cells: out,
        skipped,
        n: cfg.scenario_n.max(1),
        t: TABLE9_JOB_TIME_PER_PROC / cfg.scenario_n.max(1) as f64,
    }
}

impl ScenariosReport {
    /// Rendered summary table.
    pub fn render_table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Scenarios — ΔT and utilization across workload shapes \
                 (n={}, t={} s)",
                self.n,
                fnum(self.t)
            ),
            &["scenario", "scheduler", "ΔT (s)", "U", "mean wait (s)"],
        );
        for c in &self.cells {
            t.row(&[
                c.scenario.to_string(),
                c.scheduler.clone(),
                fnum(c.mean_delta_t()),
                format!("{:.3}", c.mean_utilization()),
                fnum(c.mean_wait()),
            ]);
        }
        for (scenario, sched) in &self.skipped {
            t.row(&[
                scenario.to_string(),
                sched.clone(),
                "(skipped)".into(),
                "-".into(),
                "-".into(),
            ]);
        }
        t
    }

    /// CSV series (scenario, scheduler, trial, delta_t, utilization).
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(
            "",
            &["scenario", "scheduler", "trial", "delta_t_s", "utilization"],
        );
        for c in &self.cells {
            for (trial, r) in c.trials.iter().enumerate() {
                t.row(&[
                    c.scenario.to_string(),
                    c.scheduler.clone(),
                    trial.to_string(),
                    format!("{:.3}", r.delta_t()),
                    format!("{:.4}", r.utilization()),
                ]);
            }
        }
        t.to_csv()
    }

    fn cell(&self, scenario: &str, scheduler: &str) -> Option<&ScenarioCell> {
        self.cells
            .iter()
            .find(|c| c.scenario == scenario && c.scheduler == scheduler)
    }

    /// Structural shape checks (loose bounds — mechanisms, not
    /// calibration): the zero-overhead reference behaves exactly, DAG
    /// chains serialize, gangs pack, and every non-skipped cell ran all
    /// its trials.
    pub fn check_shape(&self, trials: u32) -> Result<(), String> {
        for c in &self.cells {
            if c.trials.len() != trials as usize {
                return Err(format!(
                    "{} × {}: {} of {trials} trials ran",
                    c.scenario,
                    c.scheduler,
                    c.trials.len()
                ));
            }
        }
        let ideal_array = self
            .cell("array", "IdealFIFO")
            .ok_or("missing ideal array cell")?;
        if ideal_array.mean_delta_t().abs() > 1e-6 {
            return Err(format!(
                "ideal array ΔT={} should be ~0",
                ideal_array.mean_delta_t()
            ));
        }
        let ideal_chain = self
            .cell("dag-chain", "IdealFIFO")
            .ok_or("missing ideal dag-chain cell")?;
        let chain_floor = GANG_SIZE as f64 * self.t * 0.999;
        for r in &ideal_chain.trials {
            if r.t_total < chain_floor {
                return Err(format!(
                    "dag-chain t_total {} below serial floor {chain_floor}",
                    r.t_total
                ));
            }
        }
        let ideal_gang = self
            .cell("gang", "IdealFIFO")
            .ok_or("missing ideal gang cell")?;
        if ideal_gang.mean_utilization() < 0.99 {
            return Err(format!(
                "ideal gang utilization {} should pack perfectly",
                ideal_gang.mean_utilization()
            ));
        }
        // Real control planes cost something on every scenario.
        for c in &self.cells {
            if c.scheduler != "IdealFIFO" && c.mean_delta_t() < 0.0 {
                return Err(format!(
                    "{} × {}: negative ΔT {}",
                    c.scenario,
                    c.scheduler,
                    c.mean_delta_t()
                ));
            }
        }
        Ok(())
    }
}

// ---- the `preempt` experiment family --------------------------------------

/// One (checkpoint-cost, ordering, scheduler) cell of the preempt sweep.
pub struct PreemptCell {
    /// Checkpoint cost as a fraction of the task time t.
    pub cost_frac: f64,
    /// Ordering discipline under the preemption wrapper.
    pub order: Order,
    /// Scheduler display name (e.g. "Slurm+prio+preempt").
    pub scheduler: String,
    /// One traced result per trial.
    pub trials: Vec<RunResult>,
}

impl PreemptCell {
    /// Mean utilization across trials.
    pub fn mean_utilization(&self) -> f64 {
        trial_mean(&self.trials, |r| r.utilization())
    }

    /// Mean ΔT across trials.
    pub fn mean_delta_t(&self) -> f64 {
        trial_mean(&self.trials, |r| r.delta_t())
    }

    /// Mean evictions per trial.
    pub fn mean_preemptions(&self) -> f64 {
        trial_mean(&self.trials, |r| r.preemptions as f64)
    }
}

/// Per-class queueing-delay sums and counts of one trial's trace:
/// `(hi_sum, hi_count, lo_sum, lo_count)`.
///
/// Delay is (end − submit) − duration — the task's whole non-execution
/// latency — NOT the wait before its first dispatch. A preempted
/// background task often starts at t ≈ 0 and then loses time to
/// evictions, requeues and checkpoint drains; first-dispatch wait would
/// record that as zero and systematically understate the penalty the
/// low-priority class pays, which is the very axis this experiment
/// measures.
fn class_delay_sums(
    r: &RunResult,
    hi_from: u32,
    bg_dur: f64,
    fg_dur: f64,
) -> (f64, u64, f64, u64) {
    let (mut hi_sum, mut hi_n, mut lo_sum, mut lo_n) = (0.0, 0u64, 0.0, 0u64);
    let trace = r.trace.as_ref().expect("preempt cells collect traces");
    for rec in trace {
        if rec.task >= hi_from {
            hi_sum += rec.end - rec.submit - fg_dur;
            hi_n += 1;
        } else {
            lo_sum += rec.end - rec.submit - bg_dur;
            lo_n += 1;
        }
    }
    (hi_sum, hi_n, lo_sum, lo_n)
}

/// Full preempt sweep report.
pub struct PreemptReport {
    /// All cells, cost-major then ordering then scheduler.
    pub cells: Vec<PreemptCell>,
    /// Cells skipped as prohibitive.
    pub skipped: Vec<(f64, String)>,
    /// First foreground (high-priority) task id — tasks `>= hi_from`
    /// are the arriving foreground class.
    pub hi_from: u32,
    /// Tasks per processor n.
    pub n: u32,
    /// Base task time t (background tasks run 2t, foreground t/2).
    pub t: f64,
}

impl PreemptReport {
    /// Mean queueing delay of the (hi, lo) priority classes of one
    /// cell, across its trials (see [`class_delay_sums`]).
    pub fn mean_delay_by_class(&self, cell: &PreemptCell) -> (f64, f64) {
        let (mut hi_sum, mut hi_n, mut lo_sum, mut lo_n) = (0.0, 0u64, 0.0, 0u64);
        for r in &cell.trials {
            let (hs, hn, ls, ln) =
                class_delay_sums(r, self.hi_from, 2.0 * self.t, 0.5 * self.t);
            hi_sum += hs;
            hi_n += hn;
            lo_sum += ls;
            lo_n += ln;
        }
        (hi_sum / hi_n.max(1) as f64, lo_sum / lo_n.max(1) as f64)
    }
}

/// Shared shape parameters of the preempt workload, derived once so
/// the workload builder and the report's class split (`hi_from`)
/// cannot drift apart.
#[derive(Clone, Copy)]
struct PreemptShape {
    /// Base task time t (bg tasks run 2t, fg t/2).
    t: f64,
    /// Total task count.
    total: u64,
    /// Background (preemptible) task count; foreground ids start here.
    bg: u64,
}

fn preempt_shape(cfg: &ExperimentConfig, processors: u64) -> PreemptShape {
    let n = cfg.scenario_n.max(1) as u64;
    let t = TABLE9_JOB_TIME_PER_PROC / n as f64;
    let total = (n * processors).max(4);
    let hi = ((total as f64 * cfg.preempt_hi_frac).round() as u64).clamp(1, total - 1);
    PreemptShape {
        t,
        total,
        bg: total - hi,
    }
}

/// Priority-mixed preemption workload: `1 − hi_frac` of the tasks are
/// preemptible low-priority 2t background tasks submitted at t = 0
/// (saturating the cluster), the rest high-priority t/2 foreground
/// tasks arriving Poisson over roughly the first half of the
/// background span. Deterministic in (cfg.seed, cost_frac).
fn preempt_workload(cfg: &ExperimentConfig, processors: u64, cost_frac: f64) -> Workload {
    let PreemptShape { t, total, bg } = preempt_shape(cfg, processors);
    let hi = total - bg;
    let mut tasks: Vec<TaskSpec> = Vec::with_capacity(total as usize);
    for i in 0..bg {
        let mut task = TaskSpec::array(i as u32, i as u32, 2.0 * t);
        task.preemptible = true;
        task.checkpoint_cost = cost_frac * t;
        task.user = (i % 2) as u32;
        tasks.push(task);
    }
    let bg_span = (bg as f64 / processors as f64) * 2.0 * t;
    let rate = hi as f64 / (0.5 * bg_span).max(t);
    let mut rng = Prng::new(cfg.seed ^ 0x9EEE_47);
    let mut now = 0.0;
    for k in 0..hi {
        let id = (bg + k) as u32;
        let mut task = TaskSpec::array(id, id, 0.5 * t);
        task.priority = 10;
        task.user = 2 + (k % 2) as u32;
        now += rng.exponential(1.0 / rate);
        task.submit_at = now;
        tasks.push(task);
    }
    let w = Workload {
        tasks,
        label: "preempt".into(),
    };
    w.validate()
        .unwrap_or_else(|e| panic!("preempt workload invalid: {e}"));
    w
}

/// Run the preempt sweep: checkpoint-cost fractions × {priority,
/// fairshare} ordering × every scheduler family, all under the
/// preemption wrapper, in one deterministic parallel batch.
pub fn preempt(cfg: &ExperimentConfig) -> PreemptReport {
    let cluster = crate::cluster::ClusterSpec::homogeneous(
        cfg.effective_nodes(),
        cfg.cores_per_node,
        cfg.mem_mb,
        (cfg.effective_nodes() / 2).max(1),
    );
    let processors = cluster.total_cores();
    let choices = SchedulerChoice::all_simulated();
    let orders = [Order::Priority, Order::Fairshare];

    struct Cell<'a> {
        sched: usize,
        slot: usize,
        workload: &'a Workload,
        seed: u64,
    }
    // One workload per cost fraction (shared across schedulers/orders).
    let workloads: Vec<(f64, Workload)> = cfg
        .preempt_cost_fracs
        .iter()
        .map(|&f| (f, preempt_workload(cfg, processors, f)))
        .collect();
    let schedulers: Vec<(Order, Box<dyn Scheduler>)> = orders
        .iter()
        .flat_map(|&o| {
            choices
                .iter()
                .map(move |&c| (o, combinators::make_preemptive(c, cfg.scale_down, o)))
        })
        .collect();

    let mut cells: Vec<Cell> = Vec::new();
    let mut out: Vec<PreemptCell> = Vec::new();
    let mut skipped: Vec<(f64, String)> = Vec::new();
    for (wi, &(cost_frac, ref workload)) in workloads.iter().enumerate() {
        for (ki, (order, sched)) in schedulers.iter().enumerate() {
            if sched.projected_runtime(workload, &cluster) > PROHIBITIVE_SECS {
                skipped.push((cost_frac, sched.name().to_string()));
                continue;
            }
            for trial in 0..cfg.trials {
                cells.push(Cell {
                    sched: ki,
                    slot: out.len(),
                    workload,
                    seed: cfg
                        .seed
                        .wrapping_add(trial as u64)
                        .wrapping_add((wi as u64) << 32)
                        .wrapping_add((ki as u64) << 16),
                });
            }
            out.push(PreemptCell {
                cost_frac,
                order: *order,
                scheduler: sched.name().to_string(),
                trials: Vec::with_capacity(cfg.trials as usize),
            });
        }
    }

    let results = run_cells(cfg.effective_jobs(), &cells, |cell, scratch| {
        let sched = schedulers[cell.sched].1.as_ref();
        let r = sched.run_with_scratch(
            cell.workload,
            &cluster,
            cell.seed,
            &RunOptions::with_trace(),
            scratch,
        );
        r.check_invariants()
            .unwrap_or_else(|e| panic!("{} on preempt: {e}", sched.name()));
        r
    });
    for (cell, result) in cells.iter().zip(results) {
        out[cell.slot].trials.push(result);
    }

    let shape = preempt_shape(cfg, processors);
    PreemptReport {
        cells: out,
        skipped,
        hi_from: shape.bg as u32,
        n: cfg.scenario_n.max(1),
        t: shape.t,
    }
}

impl PreemptReport {
    /// Rendered summary table: fairness (per-class waits) vs ΔT, and
    /// preemption overhead vs utilization.
    pub fn render_table(&self) -> Table {
        let mut table = Table::new(
            format!(
                "Preemption — fairness vs ΔT and overhead vs utilization \
                 (n={}, t={} s; bg 2t preemptible, fg t/2 at priority 10)",
                self.n,
                fnum(self.t)
            ),
            &[
                "cost/t",
                "order",
                "scheduler",
                "ΔT (s)",
                "U",
                "evictions",
                "hi delay (s)",
                "lo delay (s)",
            ],
        );
        for c in &self.cells {
            let (hi, lo) = self.mean_delay_by_class(c);
            table.row(&[
                format!("{:.2}", c.cost_frac),
                c.order.label().to_string(),
                c.scheduler.clone(),
                fnum(c.mean_delta_t()),
                format!("{:.3}", c.mean_utilization()),
                format!("{:.1}", c.mean_preemptions()),
                fnum(hi),
                fnum(lo),
            ]);
        }
        for (cost, sched) in &self.skipped {
            table.row(&[
                format!("{cost:.2}"),
                "-".into(),
                sched.clone(),
                "(skipped)".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
        }
        table
    }

    /// CSV series.
    pub fn to_csv(&self) -> String {
        let mut table = Table::new(
            "",
            &[
                "cost_frac",
                "order",
                "scheduler",
                "trial",
                "delta_t_s",
                "utilization",
                "preemptions",
                "hi_delay_s",
                "lo_delay_s",
            ],
        );
        for c in &self.cells {
            for (trial, r) in c.trials.iter().enumerate() {
                // Per-trial class delays, matching the per-trial
                // columns beside them.
                let (hs, hn, ls, ln) =
                    class_delay_sums(r, self.hi_from, 2.0 * self.t, 0.5 * self.t);
                table.row(&[
                    format!("{:.3}", c.cost_frac),
                    c.order.label().to_string(),
                    c.scheduler.clone(),
                    trial.to_string(),
                    format!("{:.3}", r.delta_t()),
                    format!("{:.4}", r.utilization()),
                    r.preemptions.to_string(),
                    format!("{:.3}", hs / hn.max(1) as f64),
                    format!("{:.3}", ls / ln.max(1) as f64),
                ]);
            }
        }
        table.to_csv()
    }

    /// Structural shape checks: every cell ran all trials; the
    /// reference (IdealFIFO + priority + preemption, cheapest
    /// checkpoint) actually evicts; preemption favours the
    /// high-priority class there; and no run lost work (per-task span
    /// sums stay within duration).
    pub fn check_shape(&self, trials: u32) -> Result<(), String> {
        for c in &self.cells {
            if c.trials.len() != trials as usize {
                return Err(format!(
                    "cost {} × {}: {} of {trials} trials ran",
                    c.cost_frac,
                    c.scheduler,
                    c.trials.len()
                ));
            }
        }
        let min_cost = self
            .cells
            .iter()
            .map(|c| c.cost_frac)
            .fold(f64::INFINITY, f64::min);
        let ideal = self
            .cells
            .iter()
            .find(|c| {
                c.cost_frac == min_cost
                    && c.order == Order::Priority
                    && c.scheduler.starts_with("IdealFIFO")
            })
            .ok_or("missing ideal preempt cell")?;
        if ideal.mean_preemptions() <= 0.0 {
            return Err("ideal preempt cell executed no evictions".into());
        }
        let (hi, lo) = self.mean_delay_by_class(ideal);
        if hi >= lo {
            return Err(format!(
                "preemption should favour the high-priority class: hi={hi} lo={lo}"
            ));
        }
        for c in &self.cells {
            for r in &c.trials {
                let spans = r
                    .spans
                    .as_ref()
                    .ok_or("preempt trial missing span accounting")?;
                let mut executed = vec![0.0f64; r.n_tasks as usize];
                for s in spans {
                    executed[s.task as usize] += s.seconds();
                }
                for (task, &ex) in executed.iter().enumerate() {
                    let dur = if (task as u32) < self.hi_from {
                        2.0 * self.t
                    } else {
                        0.5 * self.t
                    };
                    if ex > dur + 1e-6 {
                        return Err(format!(
                            "{}: task {task} executed {ex} > duration {dur}",
                            c.scheduler
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

// ---- the `service` experiment family --------------------------------------

/// One (service-footprint fraction, scheduler) cell of the service
/// sweep.
pub struct ServiceCell {
    /// Fraction of the cluster's cores pinned by service tasks.
    pub frac: f64,
    /// Service task count in this cell's workload (first `svc_count`
    /// task ids are the service class).
    pub svc_count: u32,
    /// Scheduler display name.
    pub scheduler: String,
    /// One traced, horizon-bounded result per trial.
    pub trials: Vec<RunResult>,
}

/// Per-class dispatch-wait sums of one windowed trial's trace:
/// `(svc_sum, svc_n, batch_sum, batch_n)`. Batch tasks the window
/// closed on before they started are absent from the trace and
/// excluded from the wait mean (the started count beside it exposes
/// them).
fn service_class_waits(r: &RunResult, svc_count: u32) -> (f64, u64, f64, u64) {
    let trace = r.trace.as_ref().expect("service cells collect traces");
    let (mut ss, mut sn, mut bs, mut bn) = (0.0, 0u64, 0.0, 0u64);
    for rec in trace {
        if rec.task < svc_count {
            ss += rec.start - rec.submit;
            sn += 1;
        } else {
            bs += rec.start - rec.submit;
            bn += 1;
        }
    }
    (ss, sn, bs, bn)
}

impl ServiceCell {
    /// Mean windowed utilization across trials.
    pub fn mean_utilization(&self) -> f64 {
        trial_mean(&self.trials, |r| r.utilization())
    }

    /// Mean dispatch wait of the (service, batch) classes across
    /// trials, plus the mean fraction of batch tasks that started
    /// inside the window.
    pub fn class_waits(&self) -> (f64, f64, f64) {
        let (mut ss, mut sn, mut bs, mut bn, mut started) = (0.0, 0u64, 0.0, 0u64, 0.0);
        for r in &self.trials {
            let (s, n, b, m) = service_class_waits(r, self.svc_count);
            ss += s;
            sn += n;
            bs += b;
            bn += m;
            let total = r.n_tasks - self.svc_count as u64;
            started += if total > 0 { m as f64 / total as f64 } else { 1.0 };
        }
        (
            ss / sn.max(1) as f64,
            bs / bn.max(1) as f64,
            started / self.trials.len().max(1) as f64,
        )
    }
}

/// Full service sweep report.
pub struct ServiceReport {
    /// All cells, fraction-major then scheduler.
    pub cells: Vec<ServiceCell>,
    /// Tasks per processor n of the short-batch stream.
    pub n: u32,
    /// Short-batch task time t = T_job / n.
    pub t: f64,
    /// Observation window (virtual s).
    pub horizon: f64,
}

/// Mixed service + short-batch workload for one footprint fraction:
/// `round(frac · P)` one-core services resident from t = 0, plus a
/// Poisson stream of t-second batch tasks offered at `arrival_rho` of
/// the residual (non-service) capacity, sized to span the whole
/// window. Deterministic in `cfg.seed` and `frac`.
fn service_workload(cfg: &ExperimentConfig, processors: u64, frac: f64) -> (Workload, u32) {
    let n = cfg.scenario_n.max(1) as u64;
    let t = TABLE9_JOB_TIME_PER_PROC / n as f64;
    let h = cfg.service_horizon;
    let svc = ((processors as f64 * frac).round() as u64).min(processors.saturating_sub(1));
    let residual = (processors - svc).max(1);
    let rate = cfg.arrival_rho * residual as f64 / t;
    let n_batch = ((rate * h).ceil() as u64).max(1);
    let w = WorkloadBuilder::constant(t)
        .tasks(n_batch)
        .services(svc, 1)
        .arrivals(ArrivalProcess::Poisson { rate })
        .seed(cfg.seed)
        .label("service")
        .build();
    w.validate_for(&RunOptions::with_horizon(h))
        .unwrap_or_else(|e| panic!("service workload invalid: {e}"));
    (w, svc as u32)
}

/// Run the service sweep: every service-footprint fraction × every
/// simulated scheduler family × `cfg.trials`, horizon-bounded, in one
/// deterministic parallel batch. No prohibitive-skip pass is needed:
/// the horizon bounds every run's virtual time (and hence its event
/// count) regardless of the scheduler's per-task overhead.
pub fn service(cfg: &ExperimentConfig) -> ServiceReport {
    let cluster = crate::cluster::ClusterSpec::homogeneous(
        cfg.effective_nodes(),
        cfg.cores_per_node,
        cfg.mem_mb,
        (cfg.effective_nodes() / 2).max(1),
    );
    let processors = cluster.total_cores();
    let choices = SchedulerChoice::all_simulated();
    let schedulers: Vec<Box<dyn Scheduler>> = choices
        .iter()
        .map(|&c| make_scheduler_scaled(c, cfg.scale_down))
        .collect();
    let workloads: Vec<(f64, u32, Workload)> = cfg
        .service_fracs
        .iter()
        .map(|&f| {
            let (w, svc) = service_workload(cfg, processors, f);
            (f, svc, w)
        })
        .collect();

    struct Cell<'a> {
        sched: usize,
        slot: usize,
        workload: &'a Workload,
        seed: u64,
    }
    let mut cells: Vec<Cell> = Vec::new();
    let mut out: Vec<ServiceCell> = Vec::new();
    for (wi, &(frac, svc, ref workload)) in workloads.iter().enumerate() {
        for (ki, sched) in schedulers.iter().enumerate() {
            for trial in 0..cfg.trials {
                cells.push(Cell {
                    sched: ki,
                    slot: out.len(),
                    workload,
                    seed: cfg
                        .seed
                        .wrapping_add(trial as u64)
                        .wrapping_add((wi as u64) << 40)
                        .wrapping_add((ki as u64) << 16),
                });
            }
            out.push(ServiceCell {
                frac,
                svc_count: svc,
                scheduler: sched.name().to_string(),
                trials: Vec::with_capacity(cfg.trials as usize),
            });
        }
    }

    let options = RunOptions {
        collect_trace: true,
        horizon: Some(cfg.service_horizon),
        ..Default::default()
    };
    let results = run_cells(cfg.effective_jobs(), &cells, |cell, scratch| {
        let sched = schedulers[cell.sched].as_ref();
        let r = sched.run_with_scratch(cell.workload, &cluster, cell.seed, &options, scratch);
        r.check_invariants()
            .unwrap_or_else(|e| panic!("{} on service: {e}", sched.name()));
        r
    });
    for (cell, result) in cells.iter().zip(results) {
        out[cell.slot].trials.push(result);
    }

    ServiceReport {
        cells: out,
        n: cfg.scenario_n.max(1),
        t: TABLE9_JOB_TIME_PER_PROC / cfg.scenario_n.max(1) as f64,
        horizon: cfg.service_horizon,
    }
}

impl ServiceReport {
    /// Rendered summary table: windowed utilization plus per-class
    /// dispatch waits and batch coverage.
    pub fn render_table(&self) -> Table {
        let mut table = Table::new(
            format!(
                "Service jobs — windowed utilization and per-class wait \
                 (horizon={} s, batch t={} s at n={})",
                fnum(self.horizon),
                fnum(self.t),
                self.n
            ),
            &[
                "svc frac",
                "scheduler",
                "U(window)",
                "svc wait (s)",
                "batch wait (s)",
                "batch started",
            ],
        );
        for c in &self.cells {
            let (sw, bw, started) = c.class_waits();
            table.row(&[
                format!("{:.2}", c.frac),
                c.scheduler.clone(),
                format!("{:.3}", c.mean_utilization()),
                fnum(sw),
                fnum(bw),
                format!("{:.2}", started),
            ]);
        }
        table
    }

    /// CSV series.
    pub fn to_csv(&self) -> String {
        let mut table = Table::new(
            "",
            &[
                "service_frac",
                "scheduler",
                "trial",
                "utilization",
                "busy_core_s",
                "svc_wait_s",
                "batch_wait_s",
                "batch_started",
                "batch_total",
            ],
        );
        for c in &self.cells {
            for (trial, r) in c.trials.iter().enumerate() {
                let (ss, sn, bs, bn) = service_class_waits(r, c.svc_count);
                table.row(&[
                    format!("{:.3}", c.frac),
                    c.scheduler.clone(),
                    trial.to_string(),
                    format!("{:.4}", r.utilization()),
                    format!("{:.3}", r.busy_core_seconds),
                    format!("{:.3}", ss / sn.max(1) as f64),
                    format!("{:.3}", bs / bn.max(1) as f64),
                    bn.to_string(),
                    (r.n_tasks - c.svc_count as u64).to_string(),
                ]);
            }
        }
        table.to_csv()
    }

    /// Structural shape checks: every cell ran all its trials as
    /// horizon-bounded runs; the zero-overhead reference pins its
    /// services for the whole window (windowed utilization at least the
    /// service footprint) and starts them instantly; and every cell
    /// dispatched some of the batch stream.
    pub fn check_shape(&self, trials: u32) -> Result<(), String> {
        for c in &self.cells {
            if c.trials.len() != trials as usize {
                return Err(format!(
                    "frac {} × {}: {} of {trials} trials ran",
                    c.frac,
                    c.scheduler,
                    c.trials.len()
                ));
            }
            for r in &c.trials {
                if r.horizon != Some(self.horizon) {
                    return Err(format!(
                        "{}: result horizon {:?} != {}",
                        c.scheduler, r.horizon, self.horizon
                    ));
                }
                if (r.t_total - self.horizon).abs() > 1e-9 {
                    return Err(format!(
                        "{}: windowed t_total {} != horizon {}",
                        c.scheduler, r.t_total, self.horizon
                    ));
                }
            }
            let (_, _, started) = c.class_waits();
            if started <= 0.0 {
                return Err(format!(
                    "frac {} × {}: no batch task started inside the window",
                    c.frac, c.scheduler
                ));
            }
        }
        for c in self.cells.iter().filter(|c| c.scheduler == "IdealFIFO") {
            let floor = c.svc_count as f64
                / c.trials
                    .first()
                    .map(|r| r.processors as f64)
                    .unwrap_or(f64::INFINITY);
            if c.mean_utilization() + 1e-9 < floor {
                return Err(format!(
                    "ideal frac {}: windowed U {} below service floor {floor}",
                    c.frac,
                    c.mean_utilization()
                ));
            }
            let (sw, _, _) = c.class_waits();
            if sw > 1e-9 {
                return Err(format!(
                    "ideal frac {}: services should start instantly, waited {sw}",
                    c.frac
                ));
            }
        }
        Ok(())
    }
}

// ---- the `churn` experiment family ----------------------------------------

/// Retry budgets swept by the churn experiment: fail-fast (a single
/// kill exhausts the task) vs the default budget of batch tasks.
pub const CHURN_RETRY_BUDGETS: [u32; 2] = [0, 3];

/// Fraction of the observation window the Poisson arrival stream
/// spans. Keeping arrivals inside the first ~45% leaves every task
/// enough residual window to complete (and to absorb a few retries),
/// so a fault-free run reaches 100% completion coverage and any
/// shortfall in a churn cell is attributable to the injected faults.
pub const CHURN_ARRIVAL_SPAN: f64 = 0.45;

/// One (MTBF row, retry budget, scheduler) cell of the churn sweep.
pub struct ChurnCell {
    /// Mean time between failures as a fraction of the horizon;
    /// `None` is the fault-free control row (MTBF = ∞), the gentlest
    /// point of the sweep and the CI-gated baseline.
    pub mtbf_frac: Option<f64>,
    /// Per-task retry budget of this cell's workload variant.
    pub retry_budget: u32,
    /// Scheduler display name.
    pub scheduler: String,
    /// One traced, horizon-bounded, fault-injected result per trial.
    pub trials: Vec<RunResult>,
}

/// Per-task dispatch counts of one trial folded into a retry
/// histogram: `hist[k]` = tasks observed with `k` retries (`k + 1`
/// productive dispatches; kernel-aborted launches never started and
/// do not count). Tasks the window closed on before any dispatch sit
/// in `hist[0]`. Fault-free runs carry no span accounting, so the
/// trace (one record per started task) stands in.
fn churn_retry_hist(r: &RunResult) -> Vec<u64> {
    let mut dispatches = vec![0u32; r.n_tasks as usize];
    if let Some(spans) = &r.spans {
        for s in spans {
            dispatches[s.task as usize] += 1;
        }
    } else if let Some(trace) = &r.trace {
        for rec in trace {
            dispatches[rec.task as usize] += 1;
        }
    }
    let mut hist: Vec<u64> = Vec::new();
    for &d in &dispatches {
        let k = d.saturating_sub(1) as usize;
        if hist.len() <= k {
            hist.resize(k + 1, 0);
        }
        hist[k] += 1;
    }
    hist
}

/// Compact "0:812 1:14 2:1" rendering of a retry histogram.
fn hist_string(hist: &[u64]) -> String {
    hist.iter()
        .enumerate()
        .filter(|&(k, &n)| n > 0 || k == 0)
        .map(|(k, n)| format!("{k}:{n}"))
        .collect::<Vec<_>>()
        .join(" ")
}

impl ChurnCell {
    /// Mean windowed utilization across trials.
    pub fn mean_utilization(&self) -> f64 {
        trial_mean(&self.trials, |r| r.utilization())
    }

    /// Mean goodput utilization (productive work not later lost to a
    /// kill) across trials.
    pub fn mean_goodput(&self) -> f64 {
        trial_mean(&self.trials, |r| r.goodput_utilization())
    }

    /// Mean executed-then-lost core-seconds across trials.
    pub fn mean_wasted(&self) -> f64 {
        trial_mean(&self.trials, |r| r.wasted_core_seconds)
    }

    /// Total kills across trials.
    pub fn kills(&self) -> u64 {
        self.trials.iter().map(|r| r.kills).sum()
    }

    /// Total retry-budget exhaustions across trials.
    pub fn failed(&self) -> u64 {
        self.trials.iter().map(|r| r.failed).sum()
    }

    /// Mean completion coverage (`completed / n_tasks`) across trials.
    pub fn coverage(&self) -> f64 {
        trial_mean(&self.trials, |r| {
            r.completed as f64 / r.n_tasks.max(1) as f64
        })
    }

    /// Retry histogram pooled over trials ([`churn_retry_hist`]).
    pub fn retry_hist(&self) -> Vec<u64> {
        let mut hist: Vec<u64> = Vec::new();
        for r in &self.trials {
            for (k, n) in churn_retry_hist(r).into_iter().enumerate() {
                if hist.len() <= k {
                    hist.resize(k + 1, 0);
                }
                hist[k] += n;
            }
        }
        hist
    }
}

/// Full churn sweep report.
pub struct ChurnReport {
    /// All cells: the control row first, then MTBF-major × budget,
    /// scheduler-minor.
    pub cells: Vec<ChurnCell>,
    /// Tasks per processor n of the batch stream.
    pub n: u32,
    /// Batch task time t = T_job / n.
    pub t: f64,
    /// Observation window (virtual s).
    pub horizon: f64,
    /// Swept MTBF fractions (of the horizon).
    pub mtbf_fracs: Vec<f64>,
    /// MTTR as a fraction of the horizon.
    pub mttr_frac: f64,
}

/// Run the churn sweep: {fault-free control} ∪ {MTBF fraction × retry
/// budget} × every simulated scheduler family × `cfg.trials`,
/// horizon-bounded, in one deterministic parallel batch. Every cell
/// of an MTBF row faces the identical seeded failure schedule (plans
/// are keyed by `(MTBF, trial)`, not by scheduler or budget), so the
/// goodput/coverage comparison across schedulers is like-for-like.
/// The horizon bounds every run's virtual time, so no
/// prohibitive-skip pass is needed.
pub fn churn(cfg: &ExperimentConfig) -> ChurnReport {
    let cluster = crate::cluster::ClusterSpec::homogeneous(
        cfg.effective_nodes(),
        cfg.cores_per_node,
        cfg.mem_mb,
        (cfg.effective_nodes() / 2).max(1),
    );
    let processors = cluster.total_cores();
    let h = cfg.service_horizon;
    let choices = SchedulerChoice::all_simulated();
    let schedulers: Vec<Box<dyn Scheduler>> = choices
        .iter()
        .map(|&c| make_scheduler_scaled(c, cfg.scale_down))
        .collect();

    // Pure-batch Poisson stream confined to the first CHURN_ARRIVAL_SPAN
    // of the window; one workload variant per retry budget.
    let n_scn = cfg.scenario_n.max(1);
    let t = TABLE9_JOB_TIME_PER_PROC / n_scn as f64;
    let rate = cfg.arrival_rho * processors as f64 / t;
    let n_batch = ((rate * CHURN_ARRIVAL_SPAN * h).ceil() as u64).max(1);
    let workloads: Vec<Workload> = CHURN_RETRY_BUDGETS
        .iter()
        .map(|&budget| {
            let mut w = WorkloadBuilder::constant(t)
                .tasks(n_batch)
                .arrivals(ArrivalProcess::Poisson { rate })
                .seed(cfg.seed)
                .label("churn")
                .build();
            for task in &mut w.tasks {
                task.max_retries = budget;
            }
            w.validate_for(&RunOptions::with_horizon(h))
                .unwrap_or_else(|e| panic!("churn workload invalid: {e}"));
            w
        })
        .collect();

    // plans[0] is the fault-free control; seeded plans follow,
    // MTBF-major then trial.
    let mut plans: Vec<FaultPlan> = vec![FaultPlan::none()];
    for (mi, &frac) in cfg.churn_mtbf_fracs.iter().enumerate() {
        for trial in 0..cfg.trials {
            let plan = FaultPlan::seeded(
                cfg.seed
                    .wrapping_add((mi as u64) << 32)
                    .wrapping_add(trial as u64),
                cfg.effective_nodes(),
                frac * h,
                cfg.churn_mttr_frac * h,
                h,
            );
            plan.validate()
                .unwrap_or_else(|e| panic!("seeded churn plan invalid: {e}"));
            plans.push(plan);
        }
    }

    // Row layout: control first (run once, at the largest budget — with
    // no kills the budget is never consulted), then MTBF × budget.
    struct Row {
        mtbf_frac: Option<f64>,
        mi: Option<usize>,
        budget_idx: usize,
    }
    let mut rows: Vec<Row> = vec![Row {
        mtbf_frac: None,
        mi: None,
        budget_idx: CHURN_RETRY_BUDGETS.len() - 1,
    }];
    for (mi, &frac) in cfg.churn_mtbf_fracs.iter().enumerate() {
        for budget_idx in 0..CHURN_RETRY_BUDGETS.len() {
            rows.push(Row {
                mtbf_frac: Some(frac),
                mi: Some(mi),
                budget_idx,
            });
        }
    }

    struct Cell<'a> {
        sched: usize,
        slot: usize,
        workload: &'a Workload,
        plan: usize,
        seed: u64,
    }
    let mut cells: Vec<Cell> = Vec::new();
    let mut out: Vec<ChurnCell> = Vec::new();
    for (ri, row) in rows.iter().enumerate() {
        for (ki, sched) in schedulers.iter().enumerate() {
            for trial in 0..cfg.trials {
                cells.push(Cell {
                    sched: ki,
                    slot: out.len(),
                    workload: &workloads[row.budget_idx],
                    plan: row
                        .mi
                        .map_or(0, |mi| 1 + mi * cfg.trials as usize + trial as usize),
                    seed: cfg
                        .seed
                        .wrapping_add(trial as u64)
                        .wrapping_add((ri as u64) << 40)
                        .wrapping_add((ki as u64) << 16),
                });
            }
            out.push(ChurnCell {
                mtbf_frac: row.mtbf_frac,
                retry_budget: CHURN_RETRY_BUDGETS[row.budget_idx],
                scheduler: sched.name().to_string(),
                trials: Vec::with_capacity(cfg.trials as usize),
            });
        }
    }

    let results = run_cells(cfg.effective_jobs(), &cells, |cell, scratch| {
        let options = RunOptions {
            collect_trace: true,
            horizon: Some(h),
            faults: plans[cell.plan].clone(),
            ..Default::default()
        };
        let sched = schedulers[cell.sched].as_ref();
        let r = sched.run_with_scratch(cell.workload, &cluster, cell.seed, &options, scratch);
        r.check_invariants()
            .unwrap_or_else(|e| panic!("{} on churn: {e}", sched.name()));
        r
    });
    for (cell, result) in cells.iter().zip(results) {
        out[cell.slot].trials.push(result);
    }

    ChurnReport {
        cells: out,
        n: n_scn,
        t,
        horizon: h,
        mtbf_fracs: cfg.churn_mtbf_fracs.clone(),
        mttr_frac: cfg.churn_mttr_frac,
    }
}

impl ChurnReport {
    /// Rendered summary table: goodput vs raw windowed utilization,
    /// lost-work and retry accounting, completion coverage.
    pub fn render_table(&self) -> Table {
        let mut table = Table::new(
            format!(
                "Node churn — goodput and retry accounting (horizon={} s, \
                 MTTR={}·h, batch t={} s at n={})",
                fnum(self.horizon),
                self.mttr_frac,
                fnum(self.t),
                self.n
            ),
            &[
                "MTBF/h",
                "budget",
                "scheduler",
                "U(goodput)",
                "U(window)",
                "wasted core-s",
                "kills",
                "failed",
                "coverage",
                "retries",
            ],
        );
        for c in &self.cells {
            table.row(&[
                c.mtbf_frac
                    .map_or("none".to_string(), |f| format!("{f:.2}")),
                c.retry_budget.to_string(),
                c.scheduler.clone(),
                format!("{:.3}", c.mean_goodput()),
                format!("{:.3}", c.mean_utilization()),
                fnum(c.mean_wasted()),
                c.kills().to_string(),
                c.failed().to_string(),
                format!("{:.3}", c.coverage()),
                hist_string(&c.retry_hist()),
            ]);
        }
        table
    }

    /// CSV series, one row per trial.
    pub fn to_csv(&self) -> String {
        let mut table = Table::new(
            "",
            &[
                "mtbf_frac",
                "retry_budget",
                "scheduler",
                "trial",
                "utilization",
                "goodput_utilization",
                "wasted_core_s",
                "kills",
                "failed",
                "completed",
                "n_tasks",
                "retry_hist",
            ],
        );
        for c in &self.cells {
            for (trial, r) in c.trials.iter().enumerate() {
                table.row(&[
                    c.mtbf_frac
                        .map_or("inf".to_string(), |f| format!("{f:.3}")),
                    c.retry_budget.to_string(),
                    c.scheduler.clone(),
                    trial.to_string(),
                    format!("{:.6}", r.utilization()),
                    format!("{:.6}", r.goodput_utilization()),
                    format!("{:.3}", r.wasted_core_seconds),
                    r.kills.to_string(),
                    r.failed.to_string(),
                    r.completed.to_string(),
                    r.n_tasks.to_string(),
                    hist_string(&churn_retry_hist(r)),
                ]);
            }
        }
        table.to_csv()
    }

    /// Structural shape checks, including the CI-gated coverage
    /// baseline: every cell ran all its trials as horizon-bounded
    /// windows; the fault-free control row kills nothing, loses
    /// nothing, fails nothing — and the zero-overhead reference
    /// completes *every* task there (100% coverage; the smoke gate);
    /// goodput never exceeds raw utilization; observed retries never
    /// exceed the cell's budget; with a zero budget every kill is a
    /// failure; and the harshest MTBF row actually kills something.
    pub fn check_shape(&self, trials: u32) -> Result<(), String> {
        for c in &self.cells {
            let label = format!(
                "mtbf {:?} budget {} × {}",
                c.mtbf_frac, c.retry_budget, c.scheduler
            );
            if c.trials.len() != trials as usize {
                return Err(format!(
                    "{label}: {} of {trials} trials ran",
                    c.trials.len()
                ));
            }
            for r in &c.trials {
                if r.horizon != Some(self.horizon) {
                    return Err(format!(
                        "{label}: result horizon {:?} != {}",
                        r.horizon, self.horizon
                    ));
                }
                if (r.t_total - self.horizon).abs() > 1e-9 {
                    return Err(format!(
                        "{label}: windowed t_total {} != horizon {}",
                        r.t_total, self.horizon
                    ));
                }
                if r.goodput_utilization() > r.utilization() + 1e-9 {
                    return Err(format!(
                        "{label}: goodput {} exceeds utilization {}",
                        r.goodput_utilization(),
                        r.utilization()
                    ));
                }
                let hist = churn_retry_hist(r);
                if hist.len() as u32 > c.retry_budget + 1 {
                    return Err(format!(
                        "{label}: observed {} retries, budget {}",
                        hist.len() - 1,
                        c.retry_budget
                    ));
                }
                if c.retry_budget == 0 && c.mtbf_frac.is_some() && r.failed != r.kills {
                    return Err(format!(
                        "{label}: zero-budget row failed {} != kills {}",
                        r.failed, r.kills
                    ));
                }
            }
            if c.mtbf_frac.is_none() {
                if c.kills() != 0 || c.failed() != 0 || c.mean_wasted() != 0.0 {
                    return Err(format!(
                        "control × {}: fault-free row reports kills={} \
                         failed={} wasted={}",
                        c.scheduler,
                        c.kills(),
                        c.failed(),
                        c.mean_wasted()
                    ));
                }
                if c.coverage() <= 0.0 {
                    return Err(format!(
                        "control × {}: no task completed",
                        c.scheduler
                    ));
                }
                if c.scheduler == "IdealFIFO" && (c.coverage() - 1.0).abs() > 1e-12 {
                    return Err(format!(
                        "control × IdealFIFO: completion coverage {} < 100% — \
                         the workload no longer fits its window fault-free",
                        c.coverage()
                    ));
                }
            }
        }
        if let Some(harshest) = self
            .mtbf_fracs
            .iter()
            .copied()
            .min_by(|a, b| a.total_cmp(b))
        {
            let kills: u64 = self
                .cells
                .iter()
                .filter(|c| c.mtbf_frac == Some(harshest))
                .map(|c| c.kills())
                .sum();
            if kills == 0 {
                return Err(format!(
                    "harshest MTBF row ({harshest}·h) killed nothing — the \
                     fault machinery was not exercised"
                ));
            }
        }
        Ok(())
    }
}

// ---- the `degraded` experiment family -------------------------------------

/// Backlog factor over the window's total core capacity: every cell
/// submits `DEGRADED_BACKLOG · h · P / t` tasks at t = 0, so the queue
/// never drains and every core-second a degraded control plane idles
/// (launch latency, loss backoff) is a core-second of goodput lost at
/// the window close — the signal the monotonicity gate rides on.
pub const DEGRADED_BACKLOG: f64 = 1.25;

/// Every this-many-th task is a straggler; the rest run the constant
/// batch time t.
pub const DEGRADED_STRAGGLER_EVERY: u64 = 100;

/// Straggler duration multiple. Must exceed the speculation threshold
/// (`speculate_factor ×` the streaming class mean ≈ `factor ×
/// ~1.04 t`) so duplicates actually launch, and stay short enough
/// that early stragglers complete inside the window — their losing
/// duplicates are where `spec_kills` comes from.
pub const DEGRADED_STRAGGLER_FACTOR: f64 = 5.0;

/// MTBF of the shared per-trial fault plan, as a fraction of the
/// horizon (each node fails about once per window).
pub const DEGRADED_MTBF_FRAC: f64 = 1.0;

/// MTTR as a fraction of the horizon — long against every swept
/// `detect_timeout`, so real failures are detected, not false alarms.
pub const DEGRADED_MTTR_FRAC: f64 = 0.25;

/// Backoff base / cap (virtual s) and retry cap for lost launch RPCs.
pub const DEGRADED_BACKOFF_BASE: f64 = 0.25;
/// See [`DEGRADED_BACKOFF_BASE`].
pub const DEGRADED_BACKOFF_CAP: f64 = 2.0;
/// See [`DEGRADED_BACKOFF_BASE`].
pub const DEGRADED_MAX_RETRIES: u32 = 4;

/// Slack on the goodput-monotone-in-severity gate: kill timing shifts
/// between severity levels (same fault plan, different dispatch
/// instants) add noise of a few tenths of a percent to the pooled
/// means; the latency-idle signal between adjacent default levels is
/// an order of magnitude larger.
pub const DEGRADED_MONO_EPS: f64 = 2e-3;

/// Tasks-per-processor values of the refit phase (subset of the model
/// experiment's sweep: enough spread to fit ΔT = t_s · n^α, cheap
/// enough to ride inside the experiment).
pub const DEGRADED_FIT_NS: [u32; 4] = [4, 16, 48, 240];

/// Build one severity level's message plan. Zero loss and latency
/// yield an empty (bypassed) plan, so level 0 isolates pure
/// detection + speculation effects.
fn degraded_message_plan(seed: u64, loss: f64, latency: f64) -> MessagePlan {
    let mut m = MessagePlan::seeded(seed);
    if latency > 0.0 {
        m = m.with_latency(latency, latency, 0.5 * latency);
    }
    if loss > 0.0 {
        m = m
            .with_loss(
                loss,
                DEGRADED_BACKOFF_BASE,
                DEGRADED_BACKOFF_CAP,
                DEGRADED_MAX_RETRIES,
            )
            .with_duplication(0.5 * loss);
    }
    m.validate()
        .unwrap_or_else(|e| panic!("degraded message plan invalid: {e}"));
    m
}

/// One (detect-timeout, severity level, speculation, scheduler) cell.
pub struct DegradedCell {
    /// Failure-detection timeout; `None` is the undegraded control row
    /// (oracular detection, perfect messages, no speculation).
    pub detect_timeout: Option<f64>,
    /// Launch/completion-loss probability of this cell's level.
    pub loss_prob: f64,
    /// Mean control-message latency (virtual s) of this cell's level.
    pub latency_mean: f64,
    /// Whether speculative re-execution was armed.
    pub speculate: bool,
    /// Scheduler display name.
    pub scheduler: String,
    /// One traced, horizon-bounded result per trial.
    pub trials: Vec<RunResult>,
}

impl DegradedCell {
    /// Mean windowed utilization across trials.
    pub fn mean_utilization(&self) -> f64 {
        trial_mean(&self.trials, |r| r.utilization())
    }

    /// Mean goodput utilization across trials.
    pub fn mean_goodput(&self) -> f64 {
        trial_mean(&self.trials, |r| r.goodput_utilization())
    }

    /// Mean executed-then-lost core-seconds across trials.
    pub fn mean_wasted(&self) -> f64 {
        trial_mean(&self.trials, |r| r.wasted_core_seconds)
    }

    /// Mean core-seconds lost to undetected (doomed) work.
    pub fn mean_undetected(&self) -> f64 {
        trial_mean(&self.trials, |r| r.undetected_lost_core_seconds)
    }

    /// Total kills across trials.
    pub fn kills(&self) -> u64 {
        self.trials.iter().map(|r| r.kills).sum()
    }

    /// Total lost control messages across trials.
    pub fn messages_lost(&self) -> u64 {
        self.trials.iter().map(|r| r.messages_lost).sum()
    }

    /// Total duplicated completions across trials.
    pub fn messages_duplicated(&self) -> u64 {
        self.trials.iter().map(|r| r.messages_duplicated).sum()
    }

    /// Total speculative duplicate launches across trials.
    pub fn spec_launches(&self) -> u64 {
        self.trials.iter().map(|r| r.spec_launches).sum()
    }

    /// Total speculation losers killed across trials.
    pub fn spec_kills(&self) -> u64 {
        self.trials.iter().map(|r| r.spec_kills).sum()
    }

    /// All detection latencies across trials, sorted ascending.
    pub fn detections(&self) -> Vec<f64> {
        let mut d: Vec<f64> = self
            .trials
            .iter()
            .flat_map(|r| r.detection_latencies.iter().copied())
            .collect();
        d.sort_by(f64::total_cmp);
        d
    }
}

/// Linear-interpolated quantile of an ascending-sorted slice (NaN when
/// empty — rendered literally, which keeps the CSV deterministic).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
}

/// One backend's (t_s, α_s) refit under the harshest control-plane
/// degradation, next to its clean baseline — the "effective scheduler
/// the degraded control plane behaves like".
pub struct DegradedFitRow {
    /// Scheduler display name.
    pub scheduler: String,
    /// Clean fit over [`DEGRADED_FIT_NS`].
    pub base: Result<FittedModel, String>,
    /// Refit of the same sweep under the harshest message plan +
    /// detection + speculation (no fault plan: pure control-plane
    /// inflation).
    pub degraded: Result<FittedModel, String>,
    /// n values skipped as prohibitive (both variants alike).
    pub skipped: Vec<u32>,
}

impl DegradedFitRow {
    /// Largest swept n that actually ran (anchor for the inflation
    /// gate), when any did.
    pub fn n_hi(&self) -> Option<u32> {
        DEGRADED_FIT_NS
            .iter()
            .rev()
            .copied()
            .find(|n| !self.skipped.contains(n))
    }
}

/// Full degraded-control-plane sweep report.
pub struct DegradedReport {
    /// Control row first, then timeout-major × severity × speculation,
    /// scheduler-minor.
    pub cells: Vec<DegradedCell>,
    /// Per-backend (t_s, α_s) inflation refits.
    pub fits: Vec<DegradedFitRow>,
    /// Tasks per processor n of the batch stream.
    pub n: u32,
    /// Batch task time t = T_job / n.
    pub t: f64,
    /// Observation window (virtual s).
    pub horizon: f64,
    /// Swept detection timeouts (virtual s).
    pub detect_timeouts: Vec<f64>,
    /// Severity levels as (loss probability, latency mean) pairs, in
    /// non-decreasing severity order.
    pub levels: Vec<(f64, f64)>,
    /// Speculation threshold factor of the spec-armed rows.
    pub speculate_factor: f64,
}

/// Run the degraded-control-plane sweep: {undegraded control} ∪
/// {detect timeout × severity level × speculation on/off} × every
/// simulated scheduler family × `cfg.trials`, horizon-bounded, on a
/// saturated backlog (so control-plane idle shows up as goodput lost
/// at the window close), plus the per-backend (t_s, α_s) refit phase.
/// Every cell of a trial faces the identical seeded failure schedule
/// and every cell of a (level, trial) the identical message plan, so
/// comparisons across schedulers and timeouts are like-for-like.
pub fn degraded(cfg: &ExperimentConfig) -> DegradedReport {
    let cluster = super::sweep::cluster_of(cfg);
    let processors = cluster.total_cores();
    let h = cfg.service_horizon;
    let choices = SchedulerChoice::all_simulated();
    let schedulers: Vec<Box<dyn Scheduler>> = choices
        .iter()
        .map(|&c| make_scheduler_scaled(c, cfg.scale_down))
        .collect();
    assert_eq!(
        cfg.degraded_loss_probs.len(),
        cfg.degraded_latency_means.len(),
        "severity level vectors must zip (validated by the config)"
    );
    let levels: Vec<(f64, f64)> = cfg
        .degraded_loss_probs
        .iter()
        .copied()
        .zip(cfg.degraded_latency_means.iter().copied())
        .collect();

    // Saturated backlog: every task submitted at t = 0, with a sparse
    // straggler population for the speculation dimension to bite on.
    let n_scn = cfg.scenario_n.max(1);
    let t = TABLE9_JOB_TIME_PER_PROC / n_scn as f64;
    let n_tasks = ((DEGRADED_BACKLOG * h * processors as f64 / t).ceil() as u64).max(1);
    let mut workload = WorkloadBuilder::constant(t)
        .tasks(n_tasks)
        .seed(cfg.seed)
        .label("degraded")
        .build();
    for (i, task) in workload.tasks.iter_mut().enumerate() {
        if i as u64 % DEGRADED_STRAGGLER_EVERY == 0 {
            task.duration = DEGRADED_STRAGGLER_FACTOR * t;
        }
    }
    workload
        .validate_for(&RunOptions::with_horizon(h))
        .unwrap_or_else(|e| panic!("degraded workload invalid: {e}"));

    // One fault plan per trial, shared by every non-control cell of
    // that trial.
    let plans: Vec<FaultPlan> = (0..cfg.trials)
        .map(|trial| {
            let plan = FaultPlan::seeded(
                cfg.seed
                    .wrapping_add(0xDE6A_0000)
                    .wrapping_add(trial as u64),
                cfg.effective_nodes(),
                DEGRADED_MTBF_FRAC * h,
                DEGRADED_MTTR_FRAC * h,
                h,
            );
            plan.validate()
                .unwrap_or_else(|e| panic!("seeded degraded plan invalid: {e}"));
            plan
        })
        .collect();

    // One message plan per (severity level, trial), shared across
    // schedulers, timeouts and the speculation toggle.
    let msg_plans: Vec<MessagePlan> = levels
        .iter()
        .enumerate()
        .flat_map(|(li, &(loss, latency))| {
            (0..cfg.trials).map(move |trial| {
                degraded_message_plan(
                    cfg.seed
                        .wrapping_add(0x4D50_0000)
                        .wrapping_add((li as u64) << 20)
                        .wrapping_add(trial as u64),
                    loss,
                    latency,
                )
            })
        })
        .collect();

    struct Row {
        timeout: Option<f64>,
        li: usize,
        spec: bool,
    }
    let mut rows: Vec<Row> = vec![Row {
        timeout: None,
        li: 0,
        spec: false,
    }];
    for &timeout in &cfg.degraded_detect_timeouts {
        for li in 0..levels.len() {
            for spec in [false, true] {
                rows.push(Row {
                    timeout: Some(timeout),
                    li,
                    spec,
                });
            }
        }
    }

    struct Cell {
        row: usize,
        sched: usize,
        slot: usize,
        trial: usize,
        seed: u64,
    }
    let mut cells: Vec<Cell> = Vec::new();
    let mut out: Vec<DegradedCell> = Vec::new();
    for (ri, row) in rows.iter().enumerate() {
        for (ki, sched) in schedulers.iter().enumerate() {
            for trial in 0..cfg.trials {
                cells.push(Cell {
                    row: ri,
                    sched: ki,
                    slot: out.len(),
                    trial: trial as usize,
                    seed: cfg
                        .seed
                        .wrapping_add(trial as u64)
                        .wrapping_add((ri as u64) << 40)
                        .wrapping_add((ki as u64) << 16),
                });
            }
            let (loss, latency) = levels[row.li];
            out.push(DegradedCell {
                detect_timeout: row.timeout,
                loss_prob: if row.timeout.is_some() { loss } else { 0.0 },
                latency_mean: if row.timeout.is_some() { latency } else { 0.0 },
                speculate: row.spec,
                scheduler: sched.name().to_string(),
                trials: Vec::with_capacity(cfg.trials as usize),
            });
        }
    }

    let results = run_cells(cfg.effective_jobs(), &cells, |cell, scratch| {
        let row = &rows[cell.row];
        let mut options = RunOptions {
            collect_trace: true,
            horizon: Some(h),
            ..Default::default()
        };
        if let Some(timeout) = row.timeout {
            options.faults = plans[cell.trial].clone();
            options.messages =
                msg_plans[row.li * cfg.trials as usize + cell.trial].clone();
            options = options.detection(timeout, 0.5 * timeout).speculation(
                if row.spec {
                    cfg.degraded_speculate_factor
                } else {
                    0.0
                },
            );
        }
        let sched = schedulers[cell.sched].as_ref();
        let r = sched.run_with_scratch(&workload, &cluster, cell.seed, &options, scratch);
        r.check_invariants()
            .unwrap_or_else(|e| panic!("{} on degraded: {e}", sched.name()));
        r
    });
    for (cell, result) in cells.iter().zip(results) {
        out[cell.slot].trials.push(result);
    }

    let fits = degraded_fits(cfg, &cluster, &schedulers, &levels);

    DegradedReport {
        cells: out,
        fits,
        n: n_scn,
        t,
        horizon: h,
        detect_timeouts: cfg.degraded_detect_timeouts.clone(),
        levels,
        speculate_factor: cfg.degraded_speculate_factor,
    }
}

/// The refit phase: per-backend clean vs degraded launch-latency
/// sweeps over [`DEGRADED_FIT_NS`] (run-to-completion, no fault plan),
/// pooled and fitted to ΔT = t_s · n^α — the effective (t_s, α_s)
/// inflation a lossy, delayed control plane imposes.
fn degraded_fits(
    cfg: &ExperimentConfig,
    cluster: &crate::cluster::ClusterSpec,
    schedulers: &[Box<dyn Scheduler>],
    levels: &[(f64, f64)],
) -> Vec<DegradedFitRow> {
    let processors = cluster.total_cores();
    let workloads: Vec<(u32, Workload)> = DEGRADED_FIT_NS
        .iter()
        .map(|&n| (n, super::sweep::workload_for(n, processors, "degraded-fit")))
        .collect();
    let &(loss, latency) = levels.last().expect("levels validated non-empty");
    let timeout = cfg
        .degraded_detect_timeouts
        .iter()
        .copied()
        .fold(f64::NEG_INFINITY, f64::max);

    struct FitCell {
        sched: usize,
        wi: usize,
        degraded: bool,
        seed: u64,
        msg_seed: u64,
    }
    let mut cells: Vec<FitCell> = Vec::new();
    let mut skipped: Vec<Vec<u32>> = vec![Vec::new(); schedulers.len()];
    for (ki, sched) in schedulers.iter().enumerate() {
        for (wi, (n, w)) in workloads.iter().enumerate() {
            if sched.projected_runtime(w, cluster) > PROHIBITIVE_SECS {
                skipped[ki].push(*n);
                continue;
            }
            for degraded in [false, true] {
                for trial in 0..cfg.trials {
                    cells.push(FitCell {
                        sched: ki,
                        wi,
                        degraded,
                        seed: cfg
                            .seed
                            .wrapping_add(trial as u64)
                            .wrapping_add((wi as u64) << 40)
                            .wrapping_add((ki as u64) << 16)
                            .wrapping_add(u64::from(degraded) << 8),
                        // The plan is keyed by (n, trial) only, so every
                        // scheduler faces the identical message stream.
                        msg_seed: cfg
                            .seed
                            .wrapping_add(0xF17D_0000)
                            .wrapping_add((wi as u64) << 20)
                            .wrapping_add(trial as u64),
                    });
                }
            }
        }
    }

    let results = run_cells(cfg.effective_jobs(), &cells, |cell, scratch| {
        let (_, ref w) = workloads[cell.wi];
        let options = if cell.degraded {
            RunOptions::with_messages(degraded_message_plan(cell.msg_seed, loss, latency))
                .detection(timeout, 0.5 * timeout)
                .speculation(cfg.degraded_speculate_factor)
        } else {
            RunOptions::default()
        };
        let sched = schedulers[cell.sched].as_ref();
        let r = sched.run_with_scratch(w, cluster, cell.seed, &options, scratch);
        r.check_invariants()
            .unwrap_or_else(|e| panic!("{} on degraded-fit: {e}", sched.name()));
        r
    });

    let mut base_pts: Vec<Vec<(f64, f64)>> = vec![Vec::new(); schedulers.len()];
    let mut deg_pts: Vec<Vec<(f64, f64)>> = vec![Vec::new(); schedulers.len()];
    for (cell, r) in cells.iter().zip(&results) {
        let (n, _) = workloads[cell.wi];
        let pts = if cell.degraded {
            &mut deg_pts[cell.sched]
        } else {
            &mut base_pts[cell.sched]
        };
        pts.push((n as f64, r.delta_t()));
    }
    schedulers
        .iter()
        .enumerate()
        .map(|(ki, s)| DegradedFitRow {
            scheduler: s.name().to_string(),
            base: fit_sweep(s.name(), &base_pts[ki]),
            degraded: fit_sweep(&format!("{}+degraded", s.name()), &deg_pts[ki]),
            skipped: skipped[ki].clone(),
        })
        .collect()
}

impl DegradedReport {
    /// Rendered summary table of the sweep cells.
    pub fn render_table(&self) -> Table {
        let mut table = Table::new(
            format!(
                "Degraded control plane — goodput under imperfect detection, \
                 lossy/delayed messages and speculation (horizon={} s, batch \
                 t={} s at n={}, backlog ×{})",
                fnum(self.horizon),
                fnum(self.t),
                self.n,
                DEGRADED_BACKLOG
            ),
            &[
                "detect",
                "loss",
                "latency",
                "spec",
                "scheduler",
                "U(goodput)",
                "U(window)",
                "wasted core-s",
                "undetected",
                "kills",
                "msgs lost",
                "msgs dup",
                "spec L/K",
                "detect p50/p99",
            ],
        );
        for c in &self.cells {
            let d = c.detections();
            table.row(&[
                c.detect_timeout
                    .map_or("none".to_string(), |t| format!("{t:.1}")),
                format!("{:.2}", c.loss_prob),
                format!("{:.2}", c.latency_mean),
                if c.speculate { "on" } else { "off" }.to_string(),
                c.scheduler.clone(),
                format!("{:.3}", c.mean_goodput()),
                format!("{:.3}", c.mean_utilization()),
                fnum(c.mean_wasted()),
                fnum(c.mean_undetected()),
                c.kills().to_string(),
                c.messages_lost().to_string(),
                c.messages_duplicated().to_string(),
                format!("{}/{}", c.spec_launches(), c.spec_kills()),
                format!(
                    "{:.2}/{:.2}",
                    percentile(&d, 0.50),
                    percentile(&d, 0.99)
                ),
            ]);
        }
        table
    }

    /// Rendered (t_s, α_s) inflation table of the refit phase.
    pub fn render_fits(&self) -> Table {
        let mut table = Table::new(
            format!(
                "Effective (t_s, α_s) under the harshest message plan \
                 (loss={:.2}, latency={:.2} s; no faults)",
                self.levels.last().map_or(0.0, |l| l.0),
                self.levels.last().map_or(0.0, |l| l.1)
            ),
            &[
                "scheduler",
                "t_s",
                "α_s",
                "R²",
                "t_s (degraded)",
                "α_s (degraded)",
                "R² (degraded)",
                "ΔT shift @n_hi",
                "skipped n",
            ],
        );
        for f in &self.fits {
            let n_hi = f.n_hi().map_or(0.0, f64::from);
            let shift = match (&f.base, &f.degraded) {
                (Ok(b), Ok(d)) if n_hi > 0.0 => {
                    format!("{:+.1}", d.delta_t(n_hi) - b.delta_t(n_hi))
                }
                _ => "—".to_string(),
            };
            let fmt = |fit: &Result<FittedModel, String>, pick: fn(&FittedModel) -> f64| {
                fit.as_ref()
                    .map_or("—".to_string(), |m| format!("{:.3}", pick(m)))
            };
            table.row(&[
                f.scheduler.clone(),
                fmt(&f.base, |m| m.t_s),
                fmt(&f.base, |m| m.alpha_s),
                fmt(&f.base, |m| m.r2),
                fmt(&f.degraded, |m| m.t_s),
                fmt(&f.degraded, |m| m.alpha_s),
                fmt(&f.degraded, |m| m.r2),
                shift,
                format!("{:?}", f.skipped),
            ]);
        }
        table
    }

    /// CSV series, one row per sweep trial.
    pub fn to_csv(&self) -> String {
        let mut table = Table::new(
            "",
            &[
                "detect_timeout",
                "loss_prob",
                "latency_mean",
                "speculate",
                "scheduler",
                "trial",
                "utilization",
                "goodput_utilization",
                "wasted_core_s",
                "undetected_lost_core_s",
                "kills",
                "failed",
                "completed",
                "n_tasks",
                "messages_lost",
                "messages_duplicated",
                "spec_launches",
                "spec_kills",
                "detections",
                "detect_p50",
                "detect_p99",
            ],
        );
        for c in &self.cells {
            for (trial, r) in c.trials.iter().enumerate() {
                let mut d = r.detection_latencies.clone();
                d.sort_by(f64::total_cmp);
                table.row(&[
                    c.detect_timeout
                        .map_or("none".to_string(), |t| format!("{t:.3}")),
                    format!("{:.3}", c.loss_prob),
                    format!("{:.3}", c.latency_mean),
                    u8::from(c.speculate).to_string(),
                    c.scheduler.clone(),
                    trial.to_string(),
                    format!("{:.6}", r.utilization()),
                    format!("{:.6}", r.goodput_utilization()),
                    format!("{:.3}", r.wasted_core_seconds),
                    format!("{:.3}", r.undetected_lost_core_seconds),
                    r.kills.to_string(),
                    r.failed.to_string(),
                    r.completed.to_string(),
                    r.n_tasks.to_string(),
                    r.messages_lost.to_string(),
                    r.messages_duplicated.to_string(),
                    r.spec_launches.to_string(),
                    r.spec_kills.to_string(),
                    d.len().to_string(),
                    format!("{:.4}", percentile(&d, 0.50)),
                    format!("{:.4}", percentile(&d, 0.99)),
                ]);
            }
        }
        table.to_csv()
    }

    /// Mean goodput pooled over every non-control cell of one severity
    /// level (all timeouts, speculation toggles, schedulers, trials).
    fn level_goodput(&self, li: usize) -> f64 {
        let (loss, latency) = self.levels[li];
        let mut sum = 0.0;
        let mut count = 0u64;
        for c in &self.cells {
            if c.detect_timeout.is_some() && c.loss_prob == loss && c.latency_mean == latency {
                for r in &c.trials {
                    sum += r.goodput_utilization();
                    count += 1;
                }
            }
        }
        if count == 0 {
            f64::NAN
        } else {
            sum / count as f64
        }
    }

    /// Structural shape checks, CI-gated:
    ///
    /// - every cell ran all its trials as windows of the configured
    ///   horizon, with goodput ≤ raw utilization;
    /// - the control row is *pure*: zero kills, failures, wasted or
    ///   duplicate work, lost/duplicated messages and detections — the
    ///   degraded-off bypass must cost nothing — and the zero-overhead
    ///   reference saturates its window;
    /// - every recorded detection latency ≥ the cell's configured
    ///   timeout (detection can never be faster than the timeout);
    /// - speculation-off cells launch no duplicates, loss-free levels
    ///   lose/duplicate no messages;
    /// - pooled goodput is monotone non-increasing in severity level;
    /// - the machinery was exercised: the harshest level lost and
    ///   duplicated messages, failures were detected, doomed work was
    ///   charged, and spec-armed rows launched (and killed) duplicates;
    /// - the refit phase fitted every backend, and the degraded fit's
    ///   ΔT at the anchor n is never below the clean fit's.
    pub fn check_shape(&self, trials: u32) -> Result<(), String> {
        for c in &self.cells {
            let label = format!(
                "detect {:?} loss {} latency {} spec {} × {}",
                c.detect_timeout, c.loss_prob, c.latency_mean, c.speculate, c.scheduler
            );
            if c.trials.len() != trials as usize {
                return Err(format!(
                    "{label}: {} of {trials} trials ran",
                    c.trials.len()
                ));
            }
            for r in &c.trials {
                if r.horizon != Some(self.horizon) {
                    return Err(format!(
                        "{label}: result horizon {:?} != {}",
                        r.horizon, self.horizon
                    ));
                }
                if (r.t_total - self.horizon).abs() > 1e-9 {
                    return Err(format!(
                        "{label}: windowed t_total {} != horizon {}",
                        r.t_total, self.horizon
                    ));
                }
                if r.goodput_utilization() > r.utilization() + 1e-9 {
                    return Err(format!(
                        "{label}: goodput {} exceeds utilization {}",
                        r.goodput_utilization(),
                        r.utilization()
                    ));
                }
                match c.detect_timeout {
                    None => {
                        if r.kills != 0
                            || r.failed != 0
                            || r.wasted_core_seconds != 0.0
                            || r.spec_launches != 0
                            || r.spec_kills != 0
                            || r.messages_lost != 0
                            || r.messages_duplicated != 0
                            || !r.detection_latencies.is_empty()
                            || r.undetected_lost_core_seconds != 0.0
                        {
                            return Err(format!(
                                "{label}: control row is not pure — kills={} \
                                 failed={} wasted={} spec={}/{} msgs={}/{} \
                                 detections={} undetected={}",
                                r.kills,
                                r.failed,
                                r.wasted_core_seconds,
                                r.spec_launches,
                                r.spec_kills,
                                r.messages_lost,
                                r.messages_duplicated,
                                r.detection_latencies.len(),
                                r.undetected_lost_core_seconds
                            ));
                        }
                    }
                    Some(timeout) => {
                        for &d in &r.detection_latencies {
                            if d + 1e-9 < timeout {
                                return Err(format!(
                                    "{label}: detection latency {d} beats the \
                                     configured timeout {timeout}"
                                ));
                            }
                        }
                        if !c.speculate && r.spec_launches != 0 {
                            return Err(format!(
                                "{label}: speculation-off cell launched {} duplicates",
                                r.spec_launches
                            ));
                        }
                        if c.loss_prob == 0.0
                            && (r.messages_lost != 0 || r.messages_duplicated != 0)
                        {
                            return Err(format!(
                                "{label}: loss-free level lost {} / duplicated {} messages",
                                r.messages_lost, r.messages_duplicated
                            ));
                        }
                    }
                }
            }
            // On a 1.25× backlog the zero-overhead reference never
            // idles a slot fault-free, so the control row pins the
            // saturation the monotonicity gate rides on.
            if c.detect_timeout.is_none()
                && c.scheduler == "IdealFIFO"
                && c.mean_utilization() < 0.999
            {
                return Err(format!(
                    "control × IdealFIFO: windowed utilization {} < 0.999 — \
                     the backlog no longer saturates the window",
                    c.mean_utilization()
                ));
            }
        }

        // Goodput monotone non-increasing in severity.
        let pooled: Vec<f64> = (0..self.levels.len())
            .map(|li| self.level_goodput(li))
            .collect();
        for (li, w) in pooled.windows(2).enumerate() {
            if !(w[0].is_finite() && w[1].is_finite()) {
                return Err(format!("level goodput NaN: {pooled:?}"));
            }
            if w[1] > w[0] + DEGRADED_MONO_EPS {
                return Err(format!(
                    "goodput not monotone in severity: level {} = {} > level {} = {}",
                    li + 1,
                    w[1],
                    li,
                    w[0]
                ));
            }
        }

        // The machinery must actually have been exercised.
        let harsh = self.levels.last().copied().unwrap_or((0.0, 0.0));
        if harsh.0 > 0.0 {
            let (lost, dup): (u64, u64) = self
                .cells
                .iter()
                .filter(|c| {
                    c.detect_timeout.is_some()
                        && c.loss_prob == harsh.0
                        && c.latency_mean == harsh.1
                })
                .fold((0, 0), |(l, d), c| {
                    (l + c.messages_lost(), d + c.messages_duplicated())
                });
            if lost == 0 || dup == 0 {
                return Err(format!(
                    "harshest level ({}, {}) lost {lost} / duplicated {dup} \
                     messages — the message machinery was not exercised",
                    harsh.0, harsh.1
                ));
            }
        }
        let detections: usize = self
            .cells
            .iter()
            .filter(|c| c.detect_timeout.is_some())
            .map(|c| c.detections().len())
            .sum();
        if detections == 0 {
            return Err("no failure was ever detected — the heartbeat \
                        machinery was not exercised"
                .to_string());
        }
        let undetected: f64 = self
            .cells
            .iter()
            .filter(|c| c.detect_timeout.is_some())
            .map(|c| c.mean_undetected())
            .sum();
        if undetected <= 0.0 {
            return Err("no doomed (undetected) work was ever charged".to_string());
        }
        let (spec_l, spec_k): (u64, u64) = self
            .cells
            .iter()
            .filter(|c| c.speculate)
            .fold((0, 0), |(l, k), c| (l + c.spec_launches(), k + c.spec_kills()));
        if spec_l == 0 || spec_k == 0 {
            return Err(format!(
                "spec-armed rows launched {spec_l} / killed {spec_k} duplicates \
                 — the speculation machinery was not exercised"
            ));
        }

        // Refit gate: every backend fitted, and degradation never
        // *reduces* the fitted overhead at the anchor point.
        for f in &self.fits {
            let base = f
                .base
                .as_ref()
                .map_err(|e| format!("{}: clean fit failed: {e}", f.scheduler))?;
            let deg = f
                .degraded
                .as_ref()
                .map_err(|e| format!("{}: degraded fit failed: {e}", f.scheduler))?;
            let Some(n_hi) = f.n_hi() else {
                return Err(format!("{}: every fit n was skipped", f.scheduler));
            };
            let n_hi = f64::from(n_hi);
            if deg.delta_t(n_hi) + 1e-6 < base.delta_t(n_hi) {
                return Err(format!(
                    "{}: degraded ΔT({n_hi}) = {} below clean ΔT = {} — \
                     control-plane degradation cannot speed a scheduler up",
                    f.scheduler,
                    deg.delta_t(n_hi),
                    base.delta_t(n_hi)
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.scale_down = 11; // 4 nodes × 32 = 128 cores
        cfg.trials = 1;
        cfg.scenario_n = 4;
        cfg
    }

    #[test]
    fn scenarios_run_and_pass_shape_checks() {
        let cfg = quick_cfg();
        let rep = scenarios(&cfg);
        rep.check_shape(cfg.trials).unwrap();
        // 6 scenarios × 6 schedulers, minus any prohibitive skips.
        assert_eq!(rep.cells.len() + rep.skipped.len(), 36);
        assert!(!rep.to_csv().is_empty());
    }

    #[test]
    fn preempt_runs_and_passes_shape_checks() {
        let cfg = quick_cfg();
        let rep = preempt(&cfg);
        rep.check_shape(cfg.trials).unwrap();
        // 2 cost fracs × 2 orders × 6 schedulers, minus skips.
        assert_eq!(rep.cells.len() + rep.skipped.len(), 24);
        assert!(!rep.to_csv().is_empty());
    }

    #[test]
    fn service_runs_and_passes_shape_checks() {
        let mut cfg = quick_cfg();
        cfg.service_horizon = 120.0; // smaller window keeps the test fast
        let rep = service(&cfg);
        rep.check_shape(cfg.trials).unwrap();
        // 2 service fractions × 6 schedulers, nothing skipped (the
        // horizon bounds every run).
        assert_eq!(rep.cells.len(), 12);
        assert!(!rep.to_csv().is_empty());
        // Higher service footprint -> higher windowed utilization floor
        // on the zero-overhead reference.
        let ideal: Vec<&ServiceCell> = rep
            .cells
            .iter()
            .filter(|c| c.scheduler == "IdealFIFO")
            .collect();
        assert_eq!(ideal.len(), 2);
        assert!(ideal[1].frac > ideal[0].frac);
        assert!(
            ideal[1].mean_utilization() > ideal[0].mean_utilization() - 1e-9,
            "U({}) = {} should not drop below U({}) = {}",
            ideal[1].frac,
            ideal[1].mean_utilization(),
            ideal[0].frac,
            ideal[0].mean_utilization()
        );
    }

    #[test]
    fn service_deterministic_across_jobs() {
        let mut a_cfg = quick_cfg();
        a_cfg.service_horizon = 120.0;
        a_cfg.jobs = 1;
        let mut b_cfg = a_cfg.clone();
        b_cfg.jobs = 4;
        let a = service(&a_cfg);
        let b = service(&b_cfg);
        assert_eq!(a.cells.len(), b.cells.len());
        assert_eq!(a.to_csv(), b.to_csv(), "service CSVs must not depend on --jobs");
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.scheduler, cb.scheduler);
            for (ra, rb) in ca.trials.iter().zip(&cb.trials) {
                assert_eq!(
                    ra.busy_core_seconds.to_bits(),
                    rb.busy_core_seconds.to_bits(),
                    "{} frac {}",
                    ca.scheduler,
                    ca.frac
                );
                assert_eq!(ra.events, rb.events);
            }
        }
    }

    #[test]
    fn churn_runs_and_passes_shape_checks() {
        let cfg = quick_cfg();
        let rep = churn(&cfg);
        rep.check_shape(cfg.trials).unwrap();
        // Control row + 3 MTBF fracs × 2 budgets, × 6 schedulers;
        // nothing skipped (the horizon bounds every run).
        assert_eq!(
            rep.cells.len(),
            (1 + rep.mtbf_fracs.len() * CHURN_RETRY_BUDGETS.len()) * 6
        );
        assert!(!rep.to_csv().is_empty());
        // The harshest row exercises the fault machinery on every
        // scheduler family combined.
        let harshest = rep
            .mtbf_fracs
            .iter()
            .copied()
            .min_by(|a, b| a.total_cmp(b))
            .unwrap();
        let harsh_kills: u64 = rep
            .cells
            .iter()
            .filter(|c| c.mtbf_frac == Some(harshest))
            .map(|c| c.kills())
            .sum();
        assert!(harsh_kills > 0, "harshest row killed nothing");
    }

    #[test]
    fn churn_deterministic_across_jobs() {
        let mut a_cfg = quick_cfg();
        a_cfg.jobs = 1;
        let mut b_cfg = a_cfg.clone();
        b_cfg.jobs = 4;
        let a = churn(&a_cfg);
        let b = churn(&b_cfg);
        assert_eq!(a.cells.len(), b.cells.len());
        assert_eq!(a.to_csv(), b.to_csv(), "churn CSVs must not depend on --jobs");
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.scheduler, cb.scheduler);
            for (ra, rb) in ca.trials.iter().zip(&cb.trials) {
                assert_eq!(
                    ra.busy_core_seconds.to_bits(),
                    rb.busy_core_seconds.to_bits(),
                    "{} mtbf {:?}",
                    ca.scheduler,
                    ca.mtbf_frac
                );
                assert_eq!(
                    ra.wasted_core_seconds.to_bits(),
                    rb.wasted_core_seconds.to_bits()
                );
                assert_eq!(ra.events, rb.events);
                assert_eq!(ra.kills, rb.kills);
                assert_eq!(ra.failed, rb.failed);
            }
        }
    }

    #[test]
    fn degraded_runs_and_passes_shape_checks() {
        let cfg = quick_cfg();
        let rep = degraded(&cfg);
        rep.check_shape(cfg.trials).unwrap();
        // Control row + 2 timeouts × 3 levels × {spec off, on}, × 6
        // schedulers; the horizon bounds every sweep run.
        assert_eq!(
            rep.cells.len(),
            (1 + rep.detect_timeouts.len() * rep.levels.len() * 2) * 6
        );
        assert_eq!(rep.fits.len(), 6);
        assert!(!rep.to_csv().is_empty());
    }

    #[test]
    fn degraded_deterministic_across_jobs() {
        let mut a_cfg = quick_cfg();
        a_cfg.jobs = 1;
        let mut b_cfg = a_cfg.clone();
        b_cfg.jobs = 4;
        let a = degraded(&a_cfg);
        let b = degraded(&b_cfg);
        assert_eq!(a.cells.len(), b.cells.len());
        assert_eq!(
            a.to_csv(),
            b.to_csv(),
            "degraded CSVs must not depend on --jobs"
        );
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.scheduler, cb.scheduler);
            for (ra, rb) in ca.trials.iter().zip(&cb.trials) {
                assert_eq!(
                    ra.busy_core_seconds.to_bits(),
                    rb.busy_core_seconds.to_bits(),
                    "{} detect {:?}",
                    ca.scheduler,
                    ca.detect_timeout
                );
                assert_eq!(
                    ra.wasted_core_seconds.to_bits(),
                    rb.wasted_core_seconds.to_bits()
                );
                assert_eq!(ra.events, rb.events);
                assert_eq!(ra.messages_lost, rb.messages_lost);
                assert_eq!(ra.messages_duplicated, rb.messages_duplicated);
                assert_eq!(ra.spec_launches, rb.spec_launches);
                assert_eq!(ra.detection_latencies, rb.detection_latencies);
            }
        }
        for (fa, fb) in a.fits.iter().zip(&b.fits) {
            assert_eq!(fa.scheduler, fb.scheduler);
            match (&fa.degraded, &fb.degraded) {
                (Ok(x), Ok(y)) => {
                    assert_eq!(x.t_s.to_bits(), y.t_s.to_bits(), "{}", fa.scheduler);
                    assert_eq!(x.alpha_s.to_bits(), y.alpha_s.to_bits());
                }
                (x, y) => assert_eq!(x.is_err(), y.is_err()),
            }
        }
    }

    #[test]
    fn preempt_deterministic_across_jobs() {
        let mut a_cfg = quick_cfg();
        a_cfg.jobs = 1;
        let mut b_cfg = quick_cfg();
        b_cfg.jobs = 4;
        let a = preempt(&a_cfg);
        let b = preempt(&b_cfg);
        assert_eq!(a.cells.len(), b.cells.len());
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.scheduler, cb.scheduler);
            assert_eq!(ca.cost_frac, cb.cost_frac);
            for (ra, rb) in ca.trials.iter().zip(&cb.trials) {
                assert_eq!(
                    ra.t_total.to_bits(),
                    rb.t_total.to_bits(),
                    "{} cost {}",
                    ca.scheduler,
                    ca.cost_frac
                );
                assert_eq!(ra.events, rb.events);
                assert_eq!(ra.preemptions, rb.preemptions);
            }
        }
    }

    #[test]
    fn scenarios_deterministic_across_jobs() {
        let mut a_cfg = quick_cfg();
        a_cfg.jobs = 1;
        let mut b_cfg = quick_cfg();
        b_cfg.jobs = 4;
        let a = scenarios(&a_cfg);
        let b = scenarios(&b_cfg);
        assert_eq!(a.cells.len(), b.cells.len());
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.scenario, cb.scenario);
            assert_eq!(ca.scheduler, cb.scheduler);
            for (ra, rb) in ca.trials.iter().zip(&cb.trials) {
                assert_eq!(
                    ra.t_total.to_bits(),
                    rb.t_total.to_bits(),
                    "{} × {}",
                    ca.scenario,
                    ca.scheduler
                );
                assert_eq!(ra.events, rb.events);
            }
        }
    }
}
