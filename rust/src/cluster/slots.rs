//! Core-slot pool: the scheduler-facing view of cluster capacity.
//!
//! Tracks free/busy core slots and per-node memory, and enforces the key
//! invariant the property tests lean on: a slot is never double-allocated
//! and memory is never oversubscribed.

use super::nodes::{ClusterSpec, NodeId, NodeState};

/// Identifies a core slot (dense, 0-based across the cluster).
pub type SlotId = u32;

/// Allocation bookkeeping over a cluster's core slots.
#[derive(Clone, Debug)]
pub struct SlotPool {
    /// slot -> node
    node_of: Vec<NodeId>,
    /// free-slot stack (LIFO keeps placement cache-friendly and matches
    /// the "pack onto recently freed resources" behaviour of cons_res)
    free: Vec<SlotId>,
    /// busy flags, by slot
    busy: Vec<bool>,
    /// per-node free memory (MB)
    mem_free: Vec<i64>,
    /// per-node total memory (MB)
    mem_total: Vec<i64>,
    busy_count: usize,
}

impl SlotPool {
    /// Build a pool over all Up nodes of the spec.
    pub fn new(spec: &ClusterSpec) -> Self {
        let mut pool = Self::empty();
        pool.reinit(spec);
        pool
    }

    /// A zero-capacity pool — the resting state of a
    /// [`crate::sim::SimScratch`] before its first run.
    pub fn empty() -> Self {
        Self {
            node_of: Vec::new(),
            free: Vec::new(),
            busy: Vec::new(),
            mem_free: Vec::new(),
            mem_total: Vec::new(),
            busy_count: 0,
        }
    }

    /// Rebuild the pool over `spec` in place, reusing every backing
    /// allocation (the free-list stack, busy flags and memory tables).
    /// The result is bit-identical to [`SlotPool::new`] — same slot ids,
    /// same free-stack pop order — so simulations that reuse a pool
    /// across trials stay deterministic.
    pub fn reinit(&mut self, spec: &ClusterSpec) {
        self.node_of.clear();
        self.free.clear();
        self.busy.clear();
        self.mem_free.clear();
        self.mem_total.clear();
        self.busy_count = 0;
        for node in &spec.nodes {
            if node.state != NodeState::Up {
                continue;
            }
            for _ in 0..node.cores {
                let id = self.node_of.len() as SlotId;
                self.node_of.push(node.id);
                self.free.push(id);
            }
        }
        // Pop order: slot 0 first (free is a stack).
        self.free.reverse();
        self.busy.resize(self.node_of.len(), false);
        self.mem_total
            .extend(spec.nodes.iter().map(|n| n.mem_mb as i64));
        self.mem_free.extend_from_slice(&self.mem_total);
    }

    /// Total slot count.
    pub fn capacity(&self) -> usize {
        self.node_of.len()
    }

    /// Currently free slot count.
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Currently busy slot count.
    pub fn busy_count(&self) -> usize {
        self.busy_count
    }

    /// Node that hosts a slot.
    pub fn node_of(&self, slot: SlotId) -> NodeId {
        self.node_of[slot as usize]
    }

    /// Allocate one slot requiring `mem_mb` on its node. Returns `None`
    /// if no slot satisfies the request.
    pub fn alloc(&mut self, mem_mb: i64) -> Option<SlotId> {
        // Fast path: top of stack has enough memory (homogeneous common
        // case). Otherwise scan the free stack for a fitting node.
        let pos = self
            .free
            .iter()
            .rposition(|&s| self.mem_free[self.node_of[s as usize] as usize] >= mem_mb)?;
        let slot = self.free.remove(pos);
        let node = self.node_of[slot as usize] as usize;
        self.mem_free[node] -= mem_mb;
        debug_assert!(self.mem_free[node] >= 0);
        debug_assert!(!self.busy[slot as usize], "double allocation of slot {slot}");
        self.busy[slot as usize] = true;
        self.busy_count += 1;
        Some(slot)
    }

    /// Release a slot and its memory.
    pub fn release(&mut self, slot: SlotId, mem_mb: i64) {
        let idx = slot as usize;
        assert!(self.busy[idx], "release of free slot {slot}");
        self.busy[idx] = false;
        self.busy_count -= 1;
        let node = self.node_of[idx] as usize;
        self.mem_free[node] += mem_mb;
        assert!(
            self.mem_free[node] <= self.mem_total[node],
            "memory over-release on node {node}"
        );
        self.free.push(slot);
    }

    /// Invariant check used by property tests: busy+free counts conserve
    /// capacity and no slot is both busy and free.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.free.len() + self.busy_count != self.capacity() {
            return Err(format!(
                "slot conservation violated: free={} busy={} cap={}",
                self.free.len(),
                self.busy_count,
                self.capacity()
            ));
        }
        for &s in &self.free {
            if self.busy[s as usize] {
                return Err(format!("slot {s} both busy and free"));
            }
        }
        for (node, (&f, &t)) in self.mem_free.iter().zip(&self.mem_total).enumerate() {
            if f < 0 || f > t {
                return Err(format!("node {node} memory out of range: {f}/{t}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    fn spec() -> ClusterSpec {
        ClusterSpec::homogeneous(4, 4, 1000, 2)
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut p = SlotPool::new(&spec());
        assert_eq!(p.capacity(), 16);
        let s = p.alloc(100).unwrap();
        assert_eq!(p.busy_count(), 1);
        p.release(s, 100);
        assert_eq!(p.busy_count(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = SlotPool::new(&spec());
        let mut slots = Vec::new();
        while let Some(s) = p.alloc(0) {
            slots.push(s);
        }
        assert_eq!(slots.len(), 16);
        assert!(p.alloc(0).is_none());
        // All distinct
        let mut sorted = slots.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 16);
    }

    #[test]
    fn memory_limits_respected() {
        let mut p = SlotPool::new(&spec());
        // Each node has 1000 MB and 4 cores: only 2 × 500 MB tasks fit per node.
        let mut got = 0;
        while p.alloc(500).is_some() {
            got += 1;
        }
        assert_eq!(got, 8); // 2 per node × 4 nodes
        p.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "release of free slot")]
    fn double_release_panics() {
        let mut p = SlotPool::new(&spec());
        let s = p.alloc(0).unwrap();
        p.release(s, 0);
        p.release(s, 0);
    }

    #[test]
    fn reinit_matches_fresh_pool() {
        let spec_a = ClusterSpec::homogeneous(4, 4, 1000, 2);
        let spec_b = ClusterSpec::homogeneous(2, 8, 500, 2);
        let mut reused = SlotPool::new(&spec_a);
        // Dirty the pool, then rebuild over a different cluster.
        reused.alloc(100).unwrap();
        reused.alloc(100).unwrap();
        reused.reinit(&spec_b);
        let fresh = SlotPool::new(&spec_b);
        assert_eq!(reused.capacity(), fresh.capacity());
        assert_eq!(reused.free_count(), fresh.free_count());
        assert_eq!(reused.busy_count(), 0);
        reused.check_invariants().unwrap();
        // Identical allocation order after reinit.
        let mut a = reused;
        let mut b = fresh;
        for _ in 0..b.capacity() {
            assert_eq!(a.alloc(100), b.alloc(100));
        }
    }

    #[test]
    fn down_nodes_excluded() {
        let mut sp = spec();
        sp.set_state(0, NodeState::Down);
        let p = SlotPool::new(&sp);
        assert_eq!(p.capacity(), 12);
        assert!((0..p.capacity() as u32).all(|s| p.node_of(s) != 0));
    }

    #[test]
    fn prop_random_alloc_release_conserves() {
        check(
            |rng| {
                // random sequence of alloc/release ops
                let ops: Vec<bool> = (0..200).map(|_| rng.chance(0.6)).collect();
                ops
            },
            |ops| {
                let mut p = SlotPool::new(&spec());
                let mut held: Vec<SlotId> = Vec::new();
                for &is_alloc in ops {
                    if is_alloc {
                        if let Some(s) = p.alloc(100) {
                            held.push(s);
                        }
                    } else if let Some(s) = held.pop() {
                        p.release(s, 100);
                    }
                    p.check_invariants()?;
                    ensure(
                        p.busy_count() == held.len(),
                        format!("busy {} != held {}", p.busy_count(), held.len()),
                    )?;
                }
                Ok(())
            },
        );
    }
}
