//! Core-slot pool: the scheduler-facing view of cluster capacity.
//!
//! Tracks free/busy core slots and per-node memory, and enforces the key
//! invariant the property tests lean on: a slot is never double-allocated
//! and memory is never oversubscribed.
//!
//! # The indexed free structure
//!
//! The original pool kept one global free-slot stack and served a
//! memory-constrained allocation with an O(P) `rposition` scan plus an
//! O(P) `Vec::remove` memmove — quadratic over a run once memory
//! pressure makes the top of the stack unusable. The pool is now
//! indexed, while reproducing the legacy pop choice **bit-identically**:
//!
//! * every freed slot gets a globally unique, monotonically increasing
//!   **free sequence number**; the legacy "rposition over a LIFO stack"
//!   choice is exactly *the fitting free slot with the highest seq*;
//! * a **lazy global LIFO** (`free_lifo`) of `(slot, seq)` entries
//!   serves the common case in O(1): the top live entry is the max-seq
//!   free slot overall, so whenever its node has enough memory (always,
//!   for `mem_mb == 0` or an unconstrained cluster) it is the answer.
//!   Entries invalidated by a slow-path allocation are left in place and
//!   skipped when they surface — each entry is pushed and popped at most
//!   once, so maintenance stays amortized O(1);
//! * **per-node LIFO free lists** (`node_free`) hold each node's free
//!   slots in seq order (top = that node's max seq), so the slow path
//!   only has to choose among *nodes*;
//! * a **tournament (segment) tree over nodes** answers the slow-path
//!   query "which node with `mem_free >= m` holds the highest-seq free
//!   slot?" by storing, per range, the max available memory among
//!   non-empty nodes and the max top-of-list seq. The tree is maintained
//!   *lazily*: fast-path allocations and releases only mark the touched
//!   node dirty (O(1)); dirty leaves are flushed right before a
//!   slow-path query, so workloads that never hit memory pressure never
//!   pay for the tree at all.
//!
//! Equivalence argument (pinned by `tests/pool_equivalence.rs` against a
//! verbatim copy of the legacy implementation): within a node the top of
//! the free list has that node's max seq, so the global max-seq fitting
//! slot is always some node's list top; the fast path returns it when
//! the overall max-seq slot fits, and the tree query returns it
//! otherwise. Releases push a fresh max seq exactly like the legacy
//! stack push.

use super::nodes::{ClusterSpec, NodeId, NodeState};

/// Identifies a core slot (dense, 0-based across the cluster).
pub type SlotId = u32;

/// Allocation bookkeeping over a cluster's core slots.
#[derive(Clone, Debug)]
pub struct SlotPool {
    /// slot -> node
    node_of: Vec<NodeId>,
    /// busy flags, by slot
    busy: Vec<bool>,
    /// per-node free memory (MB)
    mem_free: Vec<i64>,
    /// per-node total memory (MB)
    mem_total: Vec<i64>,
    busy_count: usize,
    /// Lazy global LIFO of `(slot, seq)`; an entry is live iff the slot
    /// is free and `slot_seq` still matches. LIFO keeps placement
    /// cache-friendly and matches cons_res's "pack onto recently freed
    /// resources" behaviour, exactly as the legacy stack did.
    free_lifo: Vec<(SlotId, u64)>,
    /// Current free-sequence number per slot (stale while busy).
    slot_seq: Vec<u64>,
    /// Monotone counter behind `slot_seq`.
    next_seq: u64,
    /// Live free-slot count (the lazy stack may hold dead entries).
    free_n: usize,
    /// Per-node free lists, bottom-to-top in seq order.
    node_free: Vec<Vec<SlotId>>,
    /// First leaf index of the tournament tree (tree is 1-based,
    /// `leaf_base + node` is node's leaf).
    leaf_base: usize,
    /// Per-range max `mem_free` among nodes with a non-empty free list
    /// (`i64::MIN` when the range has none) — the eligibility prune.
    tree_avail: Vec<i64>,
    /// Per-range max top-of-list seq among non-empty nodes (0 if none).
    tree_seq: Vec<u64>,
    /// Nodes whose leaf is out of date (flushed before tree queries).
    dirty: Vec<u32>,
    dirty_flag: Vec<bool>,
    /// Per-node placement flag: false once the node is retired mid-run
    /// (failed or draining). Retired nodes never serve allocations.
    placeable: Vec<bool>,
    /// Per-node parked slots: free slots of a retired node, held out of
    /// the free structure (in their former seq order) until the node is
    /// restored. Parked slots carry `slot_seq == 0`, which no lazy-stack
    /// entry can match (live entries always have seq >= 1).
    parked: Vec<Vec<SlotId>>,
    /// Total parked slot count across nodes.
    parked_n: usize,
    /// Node-granular mode (arXiv 2108.11359): allocations drain one
    /// *open* node's cores until it runs dry, consulting the tournament
    /// tree only on node rollover — one tree query per node instead of
    /// per slot, and no lazy-stack maintenance at all. Changes placement
    /// (whole-node packing, not most-recently-freed), so it is off by
    /// default and selected per run.
    node_granular: bool,
    /// Currently open node in node-granular mode (`u32::MAX` = none).
    open_node: u32,
}

impl SlotPool {
    /// Build a pool over all Up nodes of the spec.
    pub fn new(spec: &ClusterSpec) -> Self {
        let mut pool = Self::empty();
        pool.reinit(spec);
        pool
    }

    /// A zero-capacity pool — the resting state of a
    /// [`crate::sim::SimScratch`] before its first run.
    pub fn empty() -> Self {
        Self {
            node_of: Vec::new(),
            busy: Vec::new(),
            mem_free: Vec::new(),
            mem_total: Vec::new(),
            busy_count: 0,
            free_lifo: Vec::new(),
            slot_seq: Vec::new(),
            next_seq: 0,
            free_n: 0,
            node_free: Vec::new(),
            leaf_base: 0,
            tree_avail: Vec::new(),
            tree_seq: Vec::new(),
            dirty: Vec::new(),
            dirty_flag: Vec::new(),
            placeable: Vec::new(),
            parked: Vec::new(),
            parked_n: 0,
            node_granular: false,
            open_node: u32::MAX,
        }
    }

    /// Rebuild the pool over `spec` in place, reusing every backing
    /// allocation (the lazy stack, per-node lists, busy flags, memory
    /// tables and the tree). The result is bit-identical to
    /// [`SlotPool::new`] — same slot ids, same pop order — so
    /// simulations that reuse a pool across trials stay deterministic.
    pub fn reinit(&mut self, spec: &ClusterSpec) {
        self.node_of.clear();
        self.busy.clear();
        self.mem_free.clear();
        self.mem_total.clear();
        self.busy_count = 0;
        self.free_lifo.clear();
        self.slot_seq.clear();
        self.next_seq = 0;
        self.dirty.clear();
        self.node_granular = false;
        self.open_node = u32::MAX;
        let n_nodes = spec.nodes.len();
        // Keep (never shrink) the outer per-node vec so inner list
        // capacity survives trials; only the first `n_nodes` entries are
        // ever indexed.
        if self.node_free.len() < n_nodes {
            self.node_free.resize_with(n_nodes, Vec::new);
        }
        for list in &mut self.node_free {
            list.clear();
        }
        if self.parked.len() < n_nodes {
            self.parked.resize_with(n_nodes, Vec::new);
        }
        for list in &mut self.parked {
            list.clear();
        }
        self.parked_n = 0;
        self.placeable.clear();
        self.placeable.resize(n_nodes, true);
        for node in &spec.nodes {
            if node.state != NodeState::Up {
                continue;
            }
            for _ in 0..node.cores {
                self.node_of.push(node.id);
            }
        }
        let cap = self.node_of.len();
        self.busy.resize(cap, false);
        self.slot_seq.resize(cap, 0);
        self.mem_total
            .extend(spec.nodes.iter().map(|n| n.mem_mb as i64));
        self.mem_free.extend_from_slice(&self.mem_total);
        // Legacy pop order: slot 0 first. Descending-id pushes give slot
        // 0 the highest seq (top of the LIFO) and leave each node's list
        // topped by its lowest slot id.
        for id in (0..cap as SlotId).rev() {
            self.next_seq += 1;
            self.slot_seq[id as usize] = self.next_seq;
            self.free_lifo.push((id, self.next_seq));
            self.node_free[self.node_of[id as usize] as usize].push(id);
        }
        self.free_n = cap;
        // Tree: full rebuild from the leaves.
        let m = n_nodes.next_power_of_two().max(1);
        self.leaf_base = m;
        self.tree_avail.clear();
        self.tree_avail.resize(2 * m, i64::MIN);
        self.tree_seq.clear();
        self.tree_seq.resize(2 * m, 0);
        for n in 0..n_nodes {
            if let Some(&top) = self.node_free[n].last() {
                self.tree_avail[m + n] = self.mem_free[n];
                self.tree_seq[m + n] = self.slot_seq[top as usize];
            }
        }
        for t in (1..m).rev() {
            self.tree_avail[t] = self.tree_avail[2 * t].max(self.tree_avail[2 * t + 1]);
            self.tree_seq[t] = self.tree_seq[2 * t].max(self.tree_seq[2 * t + 1]);
        }
        self.dirty_flag.clear();
        self.dirty_flag.resize(n_nodes, false);
    }

    /// Total slot count.
    pub fn capacity(&self) -> usize {
        self.node_of.len()
    }

    /// Currently free slot count.
    pub fn free_count(&self) -> usize {
        self.free_n
    }

    /// Currently busy slot count.
    pub fn busy_count(&self) -> usize {
        self.busy_count
    }

    /// Node that hosts a slot.
    pub fn node_of(&self, slot: SlotId) -> NodeId {
        self.node_of[slot as usize]
    }

    /// Switch the pool into (or out of) node-granular allocation mode.
    /// Must be called on a quiescent pool (no busy slots): the mode
    /// changes the pop order and stops maintaining the lazy stack, so
    /// flipping mid-run would break the per-slot mode's equivalence
    /// argument. [`SlotPool::reinit`] always resets to per-slot mode.
    pub fn set_node_granular(&mut self, on: bool) {
        assert!(
            self.busy_count == 0,
            "set_node_granular on a pool with {} busy slots",
            self.busy_count
        );
        self.node_granular = on;
        self.open_node = u32::MAX;
    }

    /// Whether node-granular allocation mode is active.
    pub fn node_granular(&self) -> bool {
        self.node_granular
    }

    #[inline]
    fn mark_dirty(&mut self, node: usize) {
        if !self.dirty_flag[node] {
            self.dirty_flag[node] = true;
            self.dirty.push(node as u32);
        }
    }

    /// Bring dirty leaves (and their ancestor ranges) up to date.
    /// Amortized against the fast-path operations that marked them.
    fn flush_dirty(&mut self) {
        while let Some(node) = self.dirty.pop() {
            let n = node as usize;
            self.dirty_flag[n] = false;
            let mut t = self.leaf_base + n;
            let (avail, seq) = match self.node_free[n].last() {
                Some(&top) => (self.mem_free[n], self.slot_seq[top as usize]),
                None => (i64::MIN, 0),
            };
            if self.tree_avail[t] == avail && self.tree_seq[t] == seq {
                continue;
            }
            self.tree_avail[t] = avail;
            self.tree_seq[t] = seq;
            t /= 2;
            while t >= 1 {
                let (l, r) = (2 * t, 2 * t + 1);
                let na = self.tree_avail[l].max(self.tree_avail[r]);
                let ns = self.tree_seq[l].max(self.tree_seq[r]);
                if self.tree_avail[t] == na && self.tree_seq[t] == ns {
                    break; // ancestors already consistent
                }
                self.tree_avail[t] = na;
                self.tree_seq[t] = ns;
                t /= 2;
            }
        }
    }

    /// Max-seq node whose free memory covers `mem`, over tree range `t`.
    /// Descends into the higher-seq child first and prunes ranges with
    /// no eligible node (`tree_avail < mem`); a hit that equals its
    /// range's overall max seq is globally optimal, which short-circuits
    /// the sibling visit on the common (memory-rich) path.
    fn query_best(&self, t: usize, mem: i64) -> Option<(u64, usize)> {
        if self.tree_avail[t] < mem {
            return None;
        }
        if t >= self.leaf_base {
            // An eligible leaf: non-empty (avail > MIN) and fitting.
            return Some((self.tree_seq[t], t - self.leaf_base));
        }
        let (l, r) = (2 * t, 2 * t + 1);
        let (first, second) = if self.tree_seq[l] >= self.tree_seq[r] {
            (l, r)
        } else {
            (r, l)
        };
        match self.query_best(first, mem) {
            Some(hit) if hit.0 == self.tree_seq[first] => Some(hit),
            best => {
                let other = self.query_best(second, mem);
                match (best, other) {
                    (Some(a), Some(b)) => Some(if a.0 >= b.0 { a } else { b }),
                    (a, b) => a.or(b),
                }
            }
        }
    }

    /// Shared allocation epilogue once a slot has been chosen and popped
    /// from its node list.
    fn take(&mut self, slot: SlotId, node: usize, mem_mb: i64) -> SlotId {
        self.mem_free[node] -= mem_mb;
        debug_assert!(self.mem_free[node] >= 0);
        debug_assert!(!self.busy[slot as usize], "double allocation of slot {slot}");
        self.busy[slot as usize] = true;
        self.busy_count += 1;
        self.free_n -= 1;
        self.mark_dirty(node);
        slot
    }

    /// Allocate one slot requiring `mem_mb` on its node. Returns `None`
    /// if no slot satisfies the request. The chosen slot is exactly the
    /// one the legacy stack scan returned: the most recently freed slot
    /// whose node has enough memory.
    pub fn alloc(&mut self, mem_mb: i64) -> Option<SlotId> {
        if self.free_n == 0 {
            return None;
        }
        if self.node_granular {
            return self.alloc_node_granular(mem_mb);
        }
        // Skim dead entries (slot re-allocated via the slow path, or
        // re-freed under a newer seq). Each entry dies at most once.
        while let Some(&(s, q)) = self.free_lifo.last() {
            if self.busy[s as usize] || self.slot_seq[s as usize] != q {
                self.free_lifo.pop();
            } else {
                break;
            }
        }
        if let Some(&(top, _)) = self.free_lifo.last() {
            let node = self.node_of[top as usize] as usize;
            if self.mem_free[node] >= mem_mb {
                // Fast path: the overall most recently freed slot fits
                // (always, for mem_mb == 0 on a homogeneous cluster) —
                // a plain O(1) stack pop, tree untouched.
                self.free_lifo.pop();
                let popped = self.node_free[node].pop();
                debug_assert_eq!(popped, Some(top), "node free-list desynced");
                return Some(self.take(top, node, mem_mb));
            }
        }
        // Slow path (memory pressure): ask the tree for the node whose
        // top free slot is the max-seq fitting choice.
        self.flush_dirty();
        let (_, node) = self.query_best(1, mem_mb)?;
        let slot = self.node_free[node]
            .pop()
            .expect("tree eligibility implies a non-empty node list");
        Some(self.take(slot, node, mem_mb))
    }

    /// Node-granular allocation: hand out cores from the open node
    /// until it has no fitting free slot, then roll over to the node
    /// the tournament tree ranks best. A retired open node has an empty
    /// free list, so it rolls over naturally.
    fn alloc_node_granular(&mut self, mem_mb: i64) -> Option<SlotId> {
        if self.open_node != u32::MAX {
            let n = self.open_node as usize;
            if self.mem_free[n] >= mem_mb {
                if let Some(top) = self.node_free[n].pop() {
                    return Some(self.take(top, n, mem_mb));
                }
            }
        }
        // Node rollover (or first allocation): one tree query opens the
        // next node.
        self.flush_dirty();
        let (_, node) = self.query_best(1, mem_mb)?;
        self.open_node = node as u32;
        let slot = self.node_free[node]
            .pop()
            .expect("tree eligibility implies a non-empty node list");
        Some(self.take(slot, node, mem_mb))
    }

    /// Whether `node` currently accepts placement (not retired by a
    /// mid-run failure or drain).
    pub fn node_placeable(&self, node: NodeId) -> bool {
        self.placeable[node as usize]
    }

    /// Retire a node mid-run (failure or drain): its free slots move to
    /// the parked list — lazily invalidated in the free-LIFO by zeroing
    /// their seq, pruned from the tournament tree via the normal dirty
    /// path — and no future allocation lands there. Busy slots stay
    /// busy; when they release they park instead of re-entering the
    /// free structure. Idempotent (a drain followed by a failure of the
    /// same node retires once).
    pub fn retire_node(&mut self, node: NodeId) {
        let n = node as usize;
        assert!(
            n < self.placeable.len(),
            "retire_node: node {node} out of range ({} nodes)",
            self.placeable.len()
        );
        if !self.placeable[n] {
            return;
        }
        self.placeable[n] = false;
        let mut list = std::mem::take(&mut self.node_free[n]);
        for &s in &list {
            // Kill any live lazy-stack entry: live entries carry the
            // slot's current seq (>= 1), so zeroing can never match.
            self.slot_seq[s as usize] = 0;
        }
        self.free_n -= list.len();
        self.parked_n += list.len();
        self.parked[n].append(&mut list);
        self.node_free[n] = list; // empty, capacity retained
        self.mark_dirty(n);
    }

    /// Restore a retired node: parked slots re-enter the free structure
    /// in their parked order, each under a fresh (maximal) seq — the
    /// same indexed paths a release uses, so recovered capacity is
    /// immediately placeable.
    pub fn restore_node(&mut self, node: NodeId) {
        let n = node as usize;
        assert!(
            n < self.placeable.len(),
            "restore_node: node {node} out of range ({} nodes)",
            self.placeable.len()
        );
        if self.placeable[n] {
            return;
        }
        self.placeable[n] = true;
        let mut parked = std::mem::take(&mut self.parked[n]);
        for &s in &parked {
            let idx = s as usize;
            debug_assert!(!self.busy[idx], "parked slot {s} is busy");
            self.next_seq += 1;
            self.slot_seq[idx] = self.next_seq;
            self.free_lifo.push((s, self.next_seq));
            self.node_free[n].push(s);
        }
        self.free_n += parked.len();
        self.parked_n -= parked.len();
        parked.clear();
        self.parked[n] = parked; // empty, capacity retained
        self.mark_dirty(n);
    }

    /// Release a slot and its memory. The slot takes a fresh (maximal)
    /// free sequence number — the legacy push-to-top-of-stack. If the
    /// slot's node was retired mid-run, the slot parks instead of
    /// re-entering the free structure.
    pub fn release(&mut self, slot: SlotId, mem_mb: i64) {
        let idx = slot as usize;
        assert!(self.busy[idx], "release of free slot {slot}");
        self.busy[idx] = false;
        self.busy_count -= 1;
        let node = self.node_of[idx] as usize;
        self.mem_free[node] += mem_mb;
        assert!(
            self.mem_free[node] <= self.mem_total[node],
            "memory over-release on node {node}"
        );
        if !self.placeable[node] {
            // Zero the seq so a stale lazy-stack entry from an earlier
            // slow-path alloc of this slot can't resurrect as live.
            self.slot_seq[idx] = 0;
            self.parked[node].push(slot);
            self.parked_n += 1;
            return;
        }
        self.next_seq += 1;
        self.slot_seq[idx] = self.next_seq;
        if !self.node_granular {
            // Node-granular mode never consults the lazy stack; pushing
            // here would only accumulate dead entries (O(completions)
            // growth over a long run) with nothing skimming them.
            self.free_lifo.push((slot, self.next_seq));
        }
        self.node_free[node].push(slot);
        self.free_n += 1;
        self.mark_dirty(node);
    }

    /// Invariant check used by property tests: busy+free counts conserve
    /// capacity, no slot is both busy and free, per-node lists are
    /// seq-ordered and consistent with the lazy stack.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.free_n + self.busy_count + self.parked_n != self.capacity() {
            return Err(format!(
                "slot conservation violated: free={} busy={} parked={} cap={}",
                self.free_n,
                self.busy_count,
                self.parked_n,
                self.capacity()
            ));
        }
        let mut parked_seen = 0usize;
        for (node, list) in self.parked.iter().enumerate() {
            if !list.is_empty() && self.placeable.get(node).copied().unwrap_or(false) {
                return Err(format!("placeable node {node} holds parked slots"));
            }
            for &s in list {
                if self.busy[s as usize] {
                    return Err(format!("slot {s} both busy and parked"));
                }
                if self.node_of[s as usize] as usize != node {
                    return Err(format!("slot {s} parked under wrong node {node}"));
                }
                if self.slot_seq[s as usize] != 0 {
                    return Err(format!("parked slot {s} carries a live seq"));
                }
                parked_seen += 1;
            }
        }
        if parked_seen != self.parked_n {
            return Err(format!(
                "parked lists hold {parked_seen} slots but parked count is {}",
                self.parked_n
            ));
        }
        for (node, placeable) in self.placeable.iter().enumerate() {
            if !placeable && !self.node_free[node].is_empty() {
                return Err(format!("retired node {node} still lists free slots"));
            }
        }
        let mut listed = 0usize;
        for (node, list) in self.node_free.iter().enumerate() {
            let mut last_seq = 0u64;
            for &s in list {
                if self.busy[s as usize] {
                    return Err(format!("slot {s} both busy and free"));
                }
                if self.node_of[s as usize] as usize != node {
                    return Err(format!("slot {s} listed under wrong node {node}"));
                }
                let seq = self.slot_seq[s as usize];
                if seq <= last_seq {
                    return Err(format!("node {node} free list out of seq order"));
                }
                last_seq = seq;
                listed += 1;
            }
        }
        if listed != self.free_n {
            return Err(format!(
                "node lists hold {listed} slots but free count is {}",
                self.free_n
            ));
        }
        let live = self
            .free_lifo
            .iter()
            .filter(|&&(s, q)| !self.busy[s as usize] && self.slot_seq[s as usize] == q)
            .count();
        if self.node_granular {
            // Node mode stops maintaining the stack: reinit-seeded
            // entries die off as slots cycle and nothing replaces them.
            if live > self.free_n {
                return Err(format!(
                    "lazy stack holds {live} live entries but free count is {} (node mode)",
                    self.free_n
                ));
            }
        } else if live != self.free_n {
            return Err(format!(
                "lazy stack holds {live} live entries but free count is {}",
                self.free_n
            ));
        }
        for (node, (&f, &t)) in self.mem_free.iter().zip(&self.mem_total).enumerate() {
            if f < 0 || f > t {
                return Err(format!("node {node} memory out of range: {f}/{t}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    fn spec() -> ClusterSpec {
        ClusterSpec::homogeneous(4, 4, 1000, 2)
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut p = SlotPool::new(&spec());
        assert_eq!(p.capacity(), 16);
        let s = p.alloc(100).unwrap();
        assert_eq!(p.busy_count(), 1);
        p.release(s, 100);
        assert_eq!(p.busy_count(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = SlotPool::new(&spec());
        let mut slots = Vec::new();
        while let Some(s) = p.alloc(0) {
            slots.push(s);
        }
        assert_eq!(slots.len(), 16);
        assert!(p.alloc(0).is_none());
        // All distinct, popped in ascending-id (legacy stack) order.
        assert_eq!(slots, (0..16).collect::<Vec<SlotId>>());
    }

    #[test]
    fn memory_limits_respected() {
        let mut p = SlotPool::new(&spec());
        // Each node has 1000 MB and 4 cores: only 2 × 500 MB tasks fit per node.
        let mut got = 0;
        while p.alloc(500).is_some() {
            got += 1;
        }
        assert_eq!(got, 8); // 2 per node × 4 nodes
        p.check_invariants().unwrap();
    }

    #[test]
    fn mem_pressure_pops_most_recent_fitting_slot() {
        // 2 nodes × 2 cores, 1000 MB each. Drain node 0's memory, then a
        // constrained alloc must take node 1's most recently freed slot
        // even though node 0's slots top the stack order.
        let sp = ClusterSpec::homogeneous(2, 2, 1000, 2);
        let mut p = SlotPool::new(&sp);
        let a = p.alloc(900).unwrap(); // slot 0 (node 0)
        assert_eq!(a, 0);
        let b = p.alloc(900).unwrap(); // node 0 full -> slot 2 (node 1)
        assert_eq!(b, 2);
        // Free both; stack top is now slot 2 (freed last).
        p.release(a, 900);
        p.release(b, 900);
        // A big request fits either node; the legacy choice is the most
        // recently freed slot: slot 2.
        assert_eq!(p.alloc(900), Some(2));
        // Node 1 is now exhausted for big requests; next goes to node 0
        // via the slow path, picking its most recent free slot (0).
        assert_eq!(p.alloc(900), Some(0));
        // Nothing fits any more at 900 MB, but 0-MB allocs still drain
        // the remaining slots in stack order.
        assert_eq!(p.alloc(900), None);
        assert_eq!(p.alloc(0), Some(1));
        assert_eq!(p.alloc(0), Some(3));
        p.check_invariants().unwrap();
    }

    #[test]
    fn node_granular_drains_whole_nodes() {
        let sp = ClusterSpec::homogeneous(3, 4, 1000, 2);
        let mut p = SlotPool::new(&sp);
        p.set_node_granular(true);
        assert!(p.node_granular());
        let mut nodes = Vec::new();
        while let Some(s) = p.alloc(100) {
            nodes.push(p.node_of(s));
            p.check_invariants().unwrap();
        }
        assert_eq!(nodes.len(), 12);
        // Cores come out node-by-node: once the cursor leaves a node it
        // never interleaves back (3 contiguous groups of 4).
        let mut opened: Vec<NodeId> = Vec::new();
        for &n in &nodes {
            if opened.last() != Some(&n) {
                assert!(!opened.contains(&n), "node {n} reopened mid-drain");
                opened.push(n);
            }
        }
        assert_eq!(opened.len(), 3);
    }

    #[test]
    fn node_granular_respects_memory_on_rollover() {
        // 1000 MB per node, 4 cores: only two 450 MB tasks fit per node,
        // so the cursor must roll over with cores still free.
        let sp = ClusterSpec::homogeneous(3, 4, 1000, 2);
        let mut p = SlotPool::new(&sp);
        p.set_node_granular(true);
        let mut per_node = [0u32; 3];
        while let Some(s) = p.alloc(450) {
            per_node[p.node_of(s) as usize] += 1;
            p.check_invariants().unwrap();
        }
        assert_eq!(per_node, [2, 2, 2]);
    }

    #[test]
    fn node_granular_mode_keeps_the_lazy_stack_bounded() {
        let sp = ClusterSpec::homogeneous(2, 2, 1000, 2);
        let mut p = SlotPool::new(&sp);
        p.set_node_granular(true);
        for _ in 0..1000 {
            let s = p.alloc(100).unwrap();
            p.release(s, 100);
            p.check_invariants().unwrap();
        }
        // Releases skip the lazy stack in node mode: it never grows
        // past the reinit seeding (per-slot mode would hold ~1000 dead
        // entries here).
        assert!(p.free_lifo.len() <= p.capacity());
    }

    #[test]
    fn node_granular_rolls_over_a_retired_open_node() {
        let sp = ClusterSpec::homogeneous(2, 2, 1000, 2);
        let mut p = SlotPool::new(&sp);
        p.set_node_granular(true);
        let a = p.alloc(0).unwrap();
        let open = p.node_of(a);
        p.retire_node(open);
        p.check_invariants().unwrap();
        // The open node's free list was parked: the cursor rolls to the
        // surviving node instead of resurrecting retired capacity.
        let b = p.alloc(0).unwrap();
        assert_ne!(p.node_of(b), open);
        p.release(a, 0); // parks on the retired node
        p.check_invariants().unwrap();
        p.restore_node(open);
        p.check_invariants().unwrap();
    }

    #[test]
    fn reinit_resets_node_granular_mode() {
        let mut p = SlotPool::new(&spec());
        p.set_node_granular(true);
        p.reinit(&spec());
        assert!(!p.node_granular());
        // Back in per-slot mode the legacy pop order returns.
        let fresh = SlotPool::new(&spec());
        let mut a = p;
        let mut b = fresh;
        for _ in 0..b.capacity() {
            assert_eq!(a.alloc(100), b.alloc(100));
        }
    }

    #[test]
    #[should_panic(expected = "set_node_granular on a pool with")]
    fn node_granular_flip_requires_quiescent_pool() {
        let mut p = SlotPool::new(&spec());
        p.alloc(0).unwrap();
        p.set_node_granular(true);
    }

    #[test]
    #[should_panic(expected = "release of free slot")]
    fn double_release_panics() {
        let mut p = SlotPool::new(&spec());
        let s = p.alloc(0).unwrap();
        p.release(s, 0);
        p.release(s, 0);
    }

    #[test]
    fn reinit_matches_fresh_pool() {
        let spec_a = ClusterSpec::homogeneous(4, 4, 1000, 2);
        let spec_b = ClusterSpec::homogeneous(2, 8, 500, 2);
        let mut reused = SlotPool::new(&spec_a);
        // Dirty the pool, then rebuild over a different cluster.
        reused.alloc(100).unwrap();
        reused.alloc(100).unwrap();
        reused.reinit(&spec_b);
        let fresh = SlotPool::new(&spec_b);
        assert_eq!(reused.capacity(), fresh.capacity());
        assert_eq!(reused.free_count(), fresh.free_count());
        assert_eq!(reused.busy_count(), 0);
        reused.check_invariants().unwrap();
        // Identical allocation order after reinit.
        let mut a = reused;
        let mut b = fresh;
        for _ in 0..b.capacity() {
            assert_eq!(a.alloc(100), b.alloc(100));
        }
    }

    #[test]
    fn down_nodes_excluded() {
        let mut sp = spec();
        sp.set_state(0, NodeState::Down);
        let p = SlotPool::new(&sp);
        assert_eq!(p.capacity(), 12);
        assert!((0..p.capacity() as u32).all(|s| p.node_of(s) != 0));
    }

    #[test]
    fn down_node_never_chosen_by_the_tree() {
        // The down node keeps memory-table entries but owns no slots;
        // constrained allocs must never select it.
        let mut sp = ClusterSpec::homogeneous(3, 2, 1000, 3);
        sp.set_state(1, NodeState::Down);
        let mut p = SlotPool::new(&sp);
        let mut got = Vec::new();
        while let Some(s) = p.alloc(400) {
            got.push(p.node_of(s));
        }
        assert_eq!(got.len(), 4); // 2 slots × 2 up nodes
        assert!(got.iter().all(|&n| n != 1));
        p.check_invariants().unwrap();
    }

    #[test]
    fn retire_restore_roundtrip_matches_fresh_order() {
        let mut p = SlotPool::new(&spec());
        // Retire node 0 with all slots free, then restore: the pool
        // must still allocate node 0's slots (under fresh seqs).
        p.retire_node(0);
        p.check_invariants().unwrap();
        assert!(!p.node_placeable(0));
        assert_eq!(p.free_count(), 12);
        let mut nodes = Vec::new();
        let mut held = Vec::new();
        while let Some(s) = p.alloc(0) {
            nodes.push(p.node_of(s));
            held.push(s);
        }
        assert_eq!(nodes.len(), 12);
        assert!(nodes.iter().all(|&n| n != 0), "retired node served an alloc");
        p.restore_node(0);
        p.check_invariants().unwrap();
        assert!(p.node_placeable(0));
        assert_eq!(p.free_count(), 4);
        // Restored slots re-enter in parked order: node 0's list was
        // topped by slot 0 (lowest id), so the last restored push — the
        // new stack top — is slot 0.
        assert_eq!(p.alloc(0), Some(0));
        for s in held {
            p.release(s, 0);
        }
        p.check_invariants().unwrap();
    }

    #[test]
    fn release_onto_retired_node_parks_until_restore() {
        let sp = ClusterSpec::homogeneous(2, 2, 1000, 2);
        let mut p = SlotPool::new(&sp);
        let a = p.alloc(100).unwrap(); // slot 0, node 0
        assert_eq!(p.node_of(a), 0);
        p.retire_node(0);
        p.check_invariants().unwrap();
        // Busy slot survives the retire; its release parks it.
        p.release(a, 100);
        p.check_invariants().unwrap();
        assert_eq!(p.busy_count(), 0);
        assert_eq!(p.free_count(), 2); // node 1 only
        // Parked slots are unreachable until restore.
        let mut got = Vec::new();
        while let Some(s) = p.alloc(0) {
            got.push(p.node_of(s));
        }
        assert!(got.iter().all(|&n| n == 1));
        p.restore_node(0);
        p.check_invariants().unwrap();
        assert_eq!(p.free_count(), 2); // node 0's two parked slots return
        assert_eq!(p.alloc(500).map(|s| p.node_of(s)), Some(0));
    }

    #[test]
    fn retire_is_idempotent_and_tree_skips_retired_nodes() {
        // Force the slow path (memory pressure) after a retire: the
        // tree must never select the retired node even though its
        // memory table still shows free MB.
        let sp = ClusterSpec::homogeneous(3, 2, 1000, 3);
        let mut p = SlotPool::new(&sp);
        // Saturate node memory elsewhere so a 900 MB request must use
        // the tree.
        let a = p.alloc(900).unwrap(); // node 0
        p.retire_node(p.node_of(a)); // drain then...
        p.retire_node(p.node_of(a)); // ...fail: second retire is a no-op
        p.check_invariants().unwrap();
        let mut nodes = Vec::new();
        while let Some(s) = p.alloc(900) {
            nodes.push(p.node_of(s));
        }
        assert_eq!(nodes.len(), 2, "one 900 MB slot per surviving node");
        assert!(nodes.iter().all(|&n| n != p.node_of(a)));
        p.check_invariants().unwrap();
    }

    #[test]
    fn stale_lifo_entry_cannot_resurrect_a_parked_slot() {
        // Slow-path alloc leaves a stale (slot, old-seq) entry in the
        // lazy stack. Parking the slot on release must not let that
        // entry come back live.
        let sp = ClusterSpec::homogeneous(2, 2, 1000, 2);
        let mut p = SlotPool::new(&sp);
        let a = p.alloc(900).unwrap(); // slot 0 (node 0), fast path
        let b = p.alloc(900).unwrap(); // node 0 out of memory -> the
                                       // tree picks slot 2 (node 1),
                                       // leaving its stale stack entry
        assert_eq!((a, b), (0, 2));
        p.retire_node(1);
        p.release(b, 900); // parks slot 2 on retired node 1
        p.check_invariants().unwrap();
        // Slot 2's stale stack entry must not serve this drain.
        let mut got = Vec::new();
        while let Some(s) = p.alloc(0) {
            got.push(s);
        }
        assert_eq!(got, vec![1], "only node 0's remaining slot is placeable");
        p.check_invariants().unwrap();
    }

    #[test]
    fn prop_random_retire_restore_conserves() {
        // Random interleaving of alloc/release/retire/restore across a
        // small cluster keeps every pool invariant.
        check(
            |rng| {
                let ops: Vec<(u8, u8, u8)> = (0..300)
                    .map(|_| {
                        (
                            rng.below(8) as u8,
                            rng.below(4) as u8,
                            rng.below(16) as u8,
                        )
                    })
                    .collect();
                ops
            },
            |ops| {
                let mut p = SlotPool::new(&spec());
                let mut held: Vec<(SlotId, i64)> = Vec::new();
                let mut up = [true; 4];
                for &(op, node, pick) in ops {
                    let n = (node % 4) as NodeId;
                    match op {
                        0..=3 => {
                            let m = [0i64, 100, 450, 900][(pick % 4) as usize];
                            if let Some(s) = p.alloc(m) {
                                ensure(
                                    up[p.node_of(s) as usize],
                                    format!("alloc landed on retired node {}", p.node_of(s)),
                                )?;
                                held.push((s, m));
                            }
                        }
                        4..=5 => {
                            if !held.is_empty() {
                                let i = pick as usize % held.len();
                                let (s, m) = held.swap_remove(i);
                                p.release(s, m);
                            }
                        }
                        6 => {
                            p.retire_node(n);
                            up[n as usize] = false;
                        }
                        _ => {
                            p.restore_node(n);
                            up[n as usize] = true;
                        }
                    }
                    p.check_invariants()?;
                    ensure(
                        p.busy_count() == held.len(),
                        format!("busy {} != held {}", p.busy_count(), held.len()),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_random_alloc_release_conserves() {
        check(
            |rng| {
                // random sequence of alloc/release ops
                let ops: Vec<bool> = (0..200).map(|_| rng.chance(0.6)).collect();
                ops
            },
            |ops| {
                let mut p = SlotPool::new(&spec());
                let mut held: Vec<SlotId> = Vec::new();
                for &is_alloc in ops {
                    if is_alloc {
                        if let Some(s) = p.alloc(100) {
                            held.push(s);
                        }
                    } else if let Some(s) = held.pop() {
                        p.release(s, 100);
                    }
                    p.check_invariants()?;
                    ensure(
                        p.busy_count() == held.len(),
                        format!("busy {} != held {}", p.busy_count(), held.len()),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_random_mem_pressure_conserves() {
        // Heavier differential-style property: random mixed-size allocs
        // with random-order releases keep every invariant while the lazy
        // stack accumulates and skims dead entries.
        check(
            |rng| {
                let ops: Vec<(bool, u8, u8)> = (0..300)
                    .map(|_| {
                        (
                            rng.chance(0.55),
                            rng.below(4) as u8,
                            rng.below(8) as u8,
                        )
                    })
                    .collect();
                ops
            },
            |ops| {
                let mems = [0i64, 100, 450, 900];
                let mut p = SlotPool::new(&spec());
                let mut held: Vec<(SlotId, i64)> = Vec::new();
                for &(is_alloc, mem_i, pick) in ops {
                    if is_alloc {
                        let m = mems[mem_i as usize % mems.len()];
                        if let Some(s) = p.alloc(m) {
                            held.push((s, m));
                        }
                    } else if !held.is_empty() {
                        let i = pick as usize % held.len();
                        let (s, m) = held.swap_remove(i);
                        p.release(s, m);
                    }
                    p.check_invariants()?;
                    ensure(
                        p.busy_count() == held.len(),
                        format!("busy {} != held {}", p.busy_count(), held.len()),
                    )?;
                }
                Ok(())
            },
        );
    }
}
