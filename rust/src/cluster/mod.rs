//! Cluster model: nodes, core slots, memory accounting, racks and RPC
//! latencies. This is the synthetic stand-in for the paper's 44-node /
//! 1408-core MIT SuperCloud testbed (one scheduler node + 44 compute
//! nodes on 10 GigE).

mod nodes;
mod slots;

pub use nodes::{ClusterSpec, FaultEvent, FaultKind, FaultPlan, MessagePlan, Node, NodeId, NodeState};
pub use slots::{SlotId, SlotPool};
