//! Node-level cluster description and state, plus the deterministic
//! fault plans (`FaultPlan`) that drive mid-run node
//! failure/drain/recovery in the kernel.

use crate::util::prng::Prng;

/// Identifies a compute node.
pub type NodeId = u32;

/// Administrative / health state of a node, mirroring the states the
/// production schedulers track (Slurm: IDLE/ALLOC/DRAIN/DOWN, etc.).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    /// Accepting work.
    Up,
    /// Finishing current work, accepting nothing new.
    Draining,
    /// Out of service.
    Down,
}

/// Static description of one compute node.
#[derive(Clone, Debug)]
pub struct Node {
    /// Node id (dense, 0-based).
    pub id: NodeId,
    /// Core count (= job slots for single-core tasks).
    pub cores: u32,
    /// RAM in MB.
    pub mem_mb: u64,
    /// Rack index, for network-aware placement experiments.
    pub rack: u32,
    /// Health state.
    pub state: NodeState,
}

/// Whole-cluster specification.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Compute nodes (excludes the scheduler node, which is modeled as
    /// the scheduler's service stations).
    pub nodes: Vec<Node>,
    /// One-way control-plane RPC latency scheduler <-> node (seconds).
    pub rpc_latency: f64,
    /// Node-daemon task launch overhead mean (fork/exec, cgroup setup).
    pub launch_overhead: f64,
    /// Node-daemon task teardown overhead mean (reap, accounting).
    pub teardown_overhead: f64,
}

impl ClusterSpec {
    /// Homogeneous cluster: `n_nodes` nodes × `cores` cores, `nodes_per_rack`
    /// nodes per rack.
    pub fn homogeneous(n_nodes: u32, cores: u32, mem_mb: u64, nodes_per_rack: u32) -> Self {
        let nodes = (0..n_nodes)
            .map(|id| Node {
                id,
                cores,
                mem_mb,
                rack: id / nodes_per_rack.max(1),
                state: NodeState::Up,
            })
            .collect();
        Self {
            nodes,
            rpc_latency: 0.000_2, // 10 GigE round-trip /2, switch hop
            launch_overhead: 0.010,
            teardown_overhead: 0.005,
        }
    }

    /// The paper's testbed: 44 compute nodes × 32 cores = 1408 cores,
    /// one rack per 22 nodes, 10 GigE.
    pub fn supercloud() -> Self {
        Self::homogeneous(44, 32, 64 * 1024, 22)
    }

    /// A laptop-scale cluster for fast tests.
    pub fn tiny() -> Self {
        Self::homogeneous(2, 4, 8 * 1024, 2)
    }

    /// Total core slots across Up nodes.
    pub fn total_cores(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| n.state == NodeState::Up)
            .map(|n| n.cores as u64)
            .sum()
    }

    /// Number of nodes.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Mark a node down (failure injection in tests).
    ///
    /// Panics on an out-of-range `id` with a message naming the node,
    /// so a fault plan referencing a nonexistent node fails loudly
    /// instead of no-op'ing.
    pub fn set_state(&mut self, id: NodeId, state: NodeState) {
        assert!(
            (id as usize) < self.nodes.len(),
            "ClusterSpec::set_state: node {id} out of range (cluster has {} nodes)",
            self.nodes.len()
        );
        self.nodes[id as usize].state = state;
    }
}

/// Node-lifecycle transition kind of one [`FaultEvent`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The node dies: its free slots retire immediately, every task
    /// running there is killed (non-checkpointed work is lost), and
    /// killed tasks requeue through their retry budget.
    Fail,
    /// The node drains: no new placement, but running work finishes;
    /// slots park as they free instead of returning to the pool.
    Drain,
    /// The node returns to service with its full slot complement.
    Recover,
}

impl FaultKind {
    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Fail => "fail",
            FaultKind::Drain => "drain",
            FaultKind::Recover => "recover",
        }
    }
}

/// One timed node-lifecycle event of a [`FaultPlan`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Virtual time (seconds) at which the event fires.
    pub at: f64,
    /// Target node.
    pub node: NodeId,
    /// Lifecycle transition.
    pub kind: FaultKind,
}

/// Deterministic node-lifecycle schedule injected into a kernel run
/// via `RunOptions::faults`. Events fire in `(at, insertion order)`
/// order — the event queue's tie-break — so a plan is replayed
/// bit-identically on every run. An empty plan (the default) leaves
/// every simulation path untouched.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Events, fired in `(at, insertion order)` order.
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: no node ever changes state.
    pub fn none() -> Self {
        Self::default()
    }

    /// True iff the plan schedules no events (the fault machinery is
    /// bypassed entirely and runs are bit-identical to pre-fault-plan
    /// builds).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Append a failure of `node` at `at` (builder-style).
    pub fn fail(mut self, at: f64, node: NodeId) -> Self {
        self.events.push(FaultEvent {
            at,
            node,
            kind: FaultKind::Fail,
        });
        self
    }

    /// Append a drain of `node` at `at` (builder-style).
    pub fn drain(mut self, at: f64, node: NodeId) -> Self {
        self.events.push(FaultEvent {
            at,
            node,
            kind: FaultKind::Drain,
        });
        self
    }

    /// Append a recovery of `node` at `at` (builder-style).
    pub fn recover(mut self, at: f64, node: NodeId) -> Self {
        self.events.push(FaultEvent {
            at,
            node,
            kind: FaultKind::Recover,
        });
        self
    }

    /// Seeded MTBF/MTTR plan: each node independently draws
    /// exponential times-to-failure (mean `mtbf`) and times-to-repair
    /// (mean `mttr`) from its own forked PRNG stream, alternating
    /// fail/recover until `horizon`. Deterministic in `seed` and
    /// independent of node iteration order (per-node streams).
    pub fn seeded(seed: u64, n_nodes: u32, mtbf: f64, mttr: f64, horizon: f64) -> Self {
        assert!(mtbf.is_finite() && mtbf > 0.0, "mtbf must be finite and > 0");
        assert!(mttr.is_finite() && mttr > 0.0, "mttr must be finite and > 0");
        let root = Prng::new(seed ^ 0xFA17_71A5);
        let mut events: Vec<FaultEvent> = Vec::new();
        for node in 0..n_nodes {
            let mut rng = root.fork(node as u64);
            let mut t = rng.exponential(mtbf);
            while t < horizon {
                events.push(FaultEvent {
                    at: t,
                    node,
                    kind: FaultKind::Fail,
                });
                let back = t + rng.exponential(mttr);
                if back >= horizon {
                    break;
                }
                events.push(FaultEvent {
                    at: back,
                    node,
                    kind: FaultKind::Recover,
                });
                t = back + rng.exponential(mtbf);
            }
        }
        // Stable sort: ties keep per-node generation order, which is
        // already lifecycle-consistent per node.
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        Self { events }
    }

    /// Validate the plan: every event time finite and `>= 0`, and the
    /// per-node lifecycle consistent when replayed in firing order —
    /// no fail of an already-failed node, no drain of a non-up node,
    /// no recovery of a healthy node. (Node-id range is checked
    /// against the cluster at run time: `ClusterSpec::set_state`
    /// panics loudly on out-of-range ids.)
    pub fn validate(&self) -> Result<(), String> {
        for (i, e) in self.events.iter().enumerate() {
            if !e.at.is_finite() {
                return Err(format!(
                    "fault event {i}: non-finite time {} for node {}",
                    e.at, e.node
                ));
            }
            if e.at < 0.0 {
                return Err(format!(
                    "fault event {i}: time {} is before t=0 (node {})",
                    e.at, e.node
                ));
            }
        }
        // Replay in firing order: time-sorted, insertion order on ties
        // (Vec::sort_by is stable).
        let mut order: Vec<usize> = (0..self.events.len()).collect();
        order.sort_by(|&a, &b| self.events[a].at.total_cmp(&self.events[b].at));
        // BTreeMap, not HashMap: the map is only probed via entry(), so
        // iteration order can't leak today — but the determinism linter
        // bans hash containers in cluster/ outright, and the ordered map
        // keeps any future "report all inconsistent nodes" iteration
        // deterministic by construction.
        let mut state: std::collections::BTreeMap<NodeId, NodeState> =
            std::collections::BTreeMap::new();
        for &i in &order {
            let e = &self.events[i];
            let s = state.entry(e.node).or_insert(NodeState::Up);
            match e.kind {
                FaultKind::Fail => {
                    if *s == NodeState::Down {
                        return Err(format!(
                            "fault event {i}: node {} fails at t={} but is already down",
                            e.node, e.at
                        ));
                    }
                    *s = NodeState::Down;
                }
                FaultKind::Drain => {
                    if *s != NodeState::Up {
                        return Err(format!(
                            "fault event {i}: node {} drains at t={} but is not up",
                            e.node, e.at
                        ));
                    }
                    *s = NodeState::Draining;
                }
                FaultKind::Recover => {
                    if *s == NodeState::Up {
                        return Err(format!(
                            "fault event {i}: node {} recovers at t={} but is already up",
                            e.node, e.at
                        ));
                    }
                    *s = NodeState::Up;
                }
            }
        }
        Ok(())
    }
}

/// Seeded control-plane perturbation injected into a kernel run via
/// `RunOptions::messages`. Unlike [`FaultPlan`] (a pre-drawn event
/// schedule), a `MessagePlan` is a parameter set: the kernel forks a
/// dedicated PRNG stream from `seed` at run start and draws every
/// per-message latency/loss/duplication outcome in event-loop order,
/// so results are bit-identical for any `--jobs` worker count. The
/// empty plan (the default) is a zero-cost bypass: no stream is
/// forked, no draw happens, and runs are bit-identical to
/// pre-message-plan builds.
#[derive(Clone, Debug, PartialEq)]
pub struct MessagePlan {
    /// Seed for the plan's forked PRNG stream.
    pub seed: u64,
    /// Mean of the exponential extra delay added to launch RPCs
    /// (`Start`/`Resume` deliveries), seconds. 0 = no delay.
    pub launch_latency_mean: f64,
    /// Mean of the exponential extra delay added to completion
    /// notifications (`End` deliveries — the slot is held busy until
    /// the scheduler processes the notification), seconds. 0 = none.
    pub completion_latency_mean: f64,
    /// Mean of the exponential extra delay added to staged launches
    /// (Sparrow probe deliveries), seconds. 0 = no delay.
    pub probe_latency_mean: f64,
    /// Probability a launch RPC is lost in flight. Lost launches are
    /// retried with capped exponential backoff.
    pub loss_prob: f64,
    /// Probability a completion notification is delivered twice. The
    /// duplicate must be idempotent (dispatch-epoch check).
    pub dup_prob: f64,
    /// First retry delay after a lost launch, seconds.
    pub backoff_base: f64,
    /// Upper bound on any single backoff delay, seconds.
    pub backoff_cap: f64,
    /// Maximum consecutive losses of one launch; the attempt after the
    /// cap is force-delivered so every dispatch makes progress.
    pub max_retries: u32,
}

impl Default for MessagePlan {
    fn default() -> Self {
        Self {
            seed: 0,
            launch_latency_mean: 0.0,
            completion_latency_mean: 0.0,
            probe_latency_mean: 0.0,
            loss_prob: 0.0,
            dup_prob: 0.0,
            backoff_base: 0.05,
            backoff_cap: 1.0,
            max_retries: 4,
        }
    }
}

impl MessagePlan {
    /// Seed-XOR constant for the plan's PRNG stream, distinct from
    /// every other stream constant in the tree (`FaultPlan` uses
    /// 0xFA17_71A5, Sparrow 0x5BA2_2063, ...).
    pub const STREAM: u64 = 0x4D50_1A6C;

    /// The empty plan: every control message is instant, lossless, and
    /// delivered exactly once.
    pub fn none() -> Self {
        Self::default()
    }

    /// New plan with the given PRNG seed and no perturbation yet.
    pub fn seeded(seed: u64) -> Self {
        Self {
            seed,
            ..Self::default()
        }
    }

    /// True iff the plan perturbs nothing (the message machinery is
    /// bypassed entirely).
    pub fn is_empty(&self) -> bool {
        self.launch_latency_mean == 0.0
            && self.completion_latency_mean == 0.0
            && self.probe_latency_mean == 0.0
            && self.loss_prob == 0.0
            && self.dup_prob == 0.0
    }

    /// Set per-class latency means (builder-style).
    pub fn with_latency(mut self, launch: f64, completion: f64, probe: f64) -> Self {
        self.launch_latency_mean = launch;
        self.completion_latency_mean = completion;
        self.probe_latency_mean = probe;
        self
    }

    /// Set the launch-loss probability and backoff schedule
    /// (builder-style).
    pub fn with_loss(mut self, p: f64, base: f64, cap: f64, max_retries: u32) -> Self {
        self.loss_prob = p;
        self.backoff_base = base;
        self.backoff_cap = cap;
        self.max_retries = max_retries;
        self
    }

    /// Set the completion-duplication probability (builder-style).
    pub fn with_duplication(mut self, p: f64) -> Self {
        self.dup_prob = p;
        self
    }

    /// Backoff delay before retry number `attempt` (1-based): base
    /// doubled per retry, capped at `backoff_cap`.
    pub fn backoff_delay(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(52);
        (self.backoff_base * (1u64 << exp) as f64).min(self.backoff_cap)
    }

    /// Validate the plan: probabilities in [0, 1), latency means
    /// finite and >= 0, and a usable backoff schedule whenever loss is
    /// enabled.
    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in [("loss_prob", self.loss_prob), ("dup_prob", self.dup_prob)] {
            if !p.is_finite() || !(0.0..1.0).contains(&p) {
                return Err(format!("message plan: {name} {p} outside [0, 1)"));
            }
        }
        for (name, m) in [
            ("launch_latency_mean", self.launch_latency_mean),
            ("completion_latency_mean", self.completion_latency_mean),
            ("probe_latency_mean", self.probe_latency_mean),
        ] {
            if !m.is_finite() || m < 0.0 {
                return Err(format!(
                    "message plan: {name} {m} must be finite and >= 0"
                ));
            }
        }
        if self.loss_prob > 0.0 {
            if !self.backoff_base.is_finite() || self.backoff_base <= 0.0 {
                return Err(format!(
                    "message plan: loss enabled but backoff_base {} is not > 0",
                    self.backoff_base
                ));
            }
            if !self.backoff_cap.is_finite() || self.backoff_cap < self.backoff_base {
                return Err(format!(
                    "message plan: backoff_cap {} must be finite and >= backoff_base {}",
                    self.backoff_cap, self.backoff_base
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn supercloud_is_1408_cores() {
        let c = ClusterSpec::supercloud();
        assert_eq!(c.n_nodes(), 44);
        assert_eq!(c.total_cores(), 1408);
        assert_eq!(c.nodes[0].rack, 0);
        assert_eq!(c.nodes[43].rack, 1);
    }

    #[test]
    fn down_nodes_drop_from_capacity() {
        let mut c = ClusterSpec::homogeneous(4, 8, 1024, 2);
        assert_eq!(c.total_cores(), 32);
        c.set_state(1, NodeState::Down);
        assert_eq!(c.total_cores(), 24);
        c.set_state(2, NodeState::Draining);
        assert_eq!(c.total_cores(), 16);
    }

    #[test]
    fn heterogeneous_by_hand() {
        let mut c = ClusterSpec::homogeneous(2, 4, 1024, 2);
        c.nodes[1].cores = 16;
        assert_eq!(c.total_cores(), 20);
    }

    #[test]
    #[should_panic(expected = "node 4 out of range")]
    fn set_state_panics_on_out_of_range_node() {
        let mut c = ClusterSpec::homogeneous(4, 8, 1024, 2);
        c.set_state(4, NodeState::Down);
    }

    #[test]
    fn fault_plan_builder_and_validation() {
        let plan = FaultPlan::none().fail(2.0, 0).recover(6.0, 0).drain(3.0, 1);
        assert!(!plan.is_empty());
        plan.validate().unwrap();
        assert_eq!(plan.events.len(), 3);
        assert_eq!(plan.events[0].kind.label(), "fail");
    }

    #[test]
    fn fault_plan_rejects_negative_and_non_finite_times() {
        let neg = FaultPlan::none().fail(-1.0, 0);
        assert!(neg.validate().unwrap_err().contains("before t=0"));
        let nan = FaultPlan::none().drain(f64::NAN, 0);
        assert!(nan.validate().unwrap_err().contains("non-finite"));
        let inf = FaultPlan::none().recover(f64::INFINITY, 0);
        assert!(inf.validate().unwrap_err().contains("non-finite"));
    }

    #[test]
    fn fault_plan_rejects_lifecycle_inconsistencies() {
        let double_fail = FaultPlan::none().fail(1.0, 0).fail(2.0, 0);
        assert!(double_fail.validate().unwrap_err().contains("already down"));
        let healthy_recover = FaultPlan::none().recover(1.0, 0);
        assert!(healthy_recover
            .validate()
            .unwrap_err()
            .contains("already up"));
        let drain_down = FaultPlan::none().fail(1.0, 0).drain(2.0, 0);
        assert!(drain_down.validate().unwrap_err().contains("not up"));
        // Draining -> Fail and Down -> Recover -> Fail are legal.
        FaultPlan::none()
            .drain(1.0, 0)
            .fail(2.0, 0)
            .recover(3.0, 0)
            .fail(4.0, 0)
            .validate()
            .unwrap();
    }

    #[test]
    fn fault_plan_validation_replays_in_time_order() {
        // Insertion order is recover-then-fail, but the fail fires
        // first in time, so the plan is consistent.
        FaultPlan::none()
            .recover(5.0, 0)
            .fail(1.0, 0)
            .validate()
            .unwrap();
    }

    #[test]
    fn seeded_fault_plan_is_deterministic_and_valid() {
        let a = FaultPlan::seeded(7, 4, 50.0, 10.0, 240.0);
        let b = FaultPlan::seeded(7, 4, 50.0, 10.0, 240.0);
        assert_eq!(a, b);
        a.validate().unwrap();
        assert!(!a.is_empty(), "240 s at MTBF 50 s should draw failures");
        for w in a.events.windows(2) {
            assert!(w[0].at <= w[1].at, "events sorted by time");
        }
        for e in &a.events {
            assert!(e.at >= 0.0 && e.at < 240.0);
            assert!(e.node < 4);
        }
        // Different seeds draw different schedules.
        let c = FaultPlan::seeded(8, 4, 50.0, 10.0, 240.0);
        assert_ne!(a, c);
    }

    #[test]
    fn fault_plan_validation_error_is_order_deterministic() {
        // Two independent inconsistencies on different nodes: node 0
        // double-fails at t=2, node 1 recovers while healthy at t=5.
        // Replay is in firing order, so the earliest inconsistency must
        // win every time — regardless of builder call order and of any
        // map the replay keeps per-node state in (the reason the state
        // map is a BTreeMap, not a HashMap).
        let plan = FaultPlan::none().recover(5.0, 1).fail(1.0, 0).fail(2.0, 0);
        for _ in 0..8 {
            let err = plan.validate().unwrap_err();
            assert!(
                err.contains("node 0") && err.contains("already down"),
                "expected the t=2 double-fail on node 0 to fire first, got: {err}"
            );
        }
    }

    #[test]
    fn message_plan_default_is_empty_and_valid() {
        let plan = MessagePlan::none();
        assert!(plan.is_empty());
        plan.validate().unwrap();
        assert_eq!(plan, MessagePlan::default());
        // Any perturbation knob flips is_empty.
        assert!(!MessagePlan::none().with_latency(0.01, 0.0, 0.0).is_empty());
        assert!(!MessagePlan::none().with_latency(0.0, 0.01, 0.0).is_empty());
        assert!(!MessagePlan::none().with_latency(0.0, 0.0, 0.01).is_empty());
        assert!(!MessagePlan::none().with_loss(0.1, 0.05, 1.0, 4).is_empty());
        assert!(!MessagePlan::none().with_duplication(0.1).is_empty());
        // The seed alone does not: a seeded-but-quiet plan still
        // bypasses the machinery.
        assert!(MessagePlan::seeded(42).is_empty());
    }

    #[test]
    fn message_plan_backoff_doubles_and_caps() {
        let plan = MessagePlan::none().with_loss(0.5, 0.05, 0.3, 8);
        assert_eq!(plan.backoff_delay(1), 0.05);
        assert_eq!(plan.backoff_delay(2), 0.10);
        assert_eq!(plan.backoff_delay(3), 0.20);
        assert_eq!(plan.backoff_delay(4), 0.30, "capped at backoff_cap");
        assert_eq!(plan.backoff_delay(40), 0.30, "stays capped, no overflow");
    }

    #[test]
    fn message_plan_validation_rejects_bad_knobs() {
        let p = MessagePlan::none().with_loss(1.0, 0.05, 1.0, 4);
        assert!(p.validate().unwrap_err().contains("loss_prob"));
        let p = MessagePlan::none().with_duplication(-0.1);
        assert!(p.validate().unwrap_err().contains("dup_prob"));
        let p = MessagePlan::none().with_latency(f64::NAN, 0.0, 0.0);
        assert!(p.validate().unwrap_err().contains("launch_latency_mean"));
        let p = MessagePlan::none().with_latency(0.0, -1.0, 0.0);
        assert!(p
            .validate()
            .unwrap_err()
            .contains("completion_latency_mean"));
        let p = MessagePlan::none().with_loss(0.1, 0.0, 1.0, 4);
        assert!(p.validate().unwrap_err().contains("backoff_base"));
        let p = MessagePlan::none().with_loss(0.1, 0.5, 0.1, 4);
        assert!(p.validate().unwrap_err().contains("backoff_cap"));
    }
}
