//! Node-level cluster description and state.

/// Identifies a compute node.
pub type NodeId = u32;

/// Administrative / health state of a node, mirroring the states the
/// production schedulers track (Slurm: IDLE/ALLOC/DRAIN/DOWN, etc.).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    /// Accepting work.
    Up,
    /// Finishing current work, accepting nothing new.
    Draining,
    /// Out of service.
    Down,
}

/// Static description of one compute node.
#[derive(Clone, Debug)]
pub struct Node {
    /// Node id (dense, 0-based).
    pub id: NodeId,
    /// Core count (= job slots for single-core tasks).
    pub cores: u32,
    /// RAM in MB.
    pub mem_mb: u64,
    /// Rack index, for network-aware placement experiments.
    pub rack: u32,
    /// Health state.
    pub state: NodeState,
}

/// Whole-cluster specification.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Compute nodes (excludes the scheduler node, which is modeled as
    /// the scheduler's service stations).
    pub nodes: Vec<Node>,
    /// One-way control-plane RPC latency scheduler <-> node (seconds).
    pub rpc_latency: f64,
    /// Node-daemon task launch overhead mean (fork/exec, cgroup setup).
    pub launch_overhead: f64,
    /// Node-daemon task teardown overhead mean (reap, accounting).
    pub teardown_overhead: f64,
}

impl ClusterSpec {
    /// Homogeneous cluster: `n_nodes` nodes × `cores` cores, `nodes_per_rack`
    /// nodes per rack.
    pub fn homogeneous(n_nodes: u32, cores: u32, mem_mb: u64, nodes_per_rack: u32) -> Self {
        let nodes = (0..n_nodes)
            .map(|id| Node {
                id,
                cores,
                mem_mb,
                rack: id / nodes_per_rack.max(1),
                state: NodeState::Up,
            })
            .collect();
        Self {
            nodes,
            rpc_latency: 0.000_2, // 10 GigE round-trip /2, switch hop
            launch_overhead: 0.010,
            teardown_overhead: 0.005,
        }
    }

    /// The paper's testbed: 44 compute nodes × 32 cores = 1408 cores,
    /// one rack per 22 nodes, 10 GigE.
    pub fn supercloud() -> Self {
        Self::homogeneous(44, 32, 64 * 1024, 22)
    }

    /// A laptop-scale cluster for fast tests.
    pub fn tiny() -> Self {
        Self::homogeneous(2, 4, 8 * 1024, 2)
    }

    /// Total core slots across Up nodes.
    pub fn total_cores(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| n.state == NodeState::Up)
            .map(|n| n.cores as u64)
            .sum()
    }

    /// Number of nodes.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Mark a node down (failure injection in tests).
    pub fn set_state(&mut self, id: NodeId, state: NodeState) {
        self.nodes[id as usize].state = state;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn supercloud_is_1408_cores() {
        let c = ClusterSpec::supercloud();
        assert_eq!(c.n_nodes(), 44);
        assert_eq!(c.total_cores(), 1408);
        assert_eq!(c.nodes[0].rack, 0);
        assert_eq!(c.nodes[43].rack, 1);
    }

    #[test]
    fn down_nodes_drop_from_capacity() {
        let mut c = ClusterSpec::homogeneous(4, 8, 1024, 2);
        assert_eq!(c.total_cores(), 32);
        c.set_state(1, NodeState::Down);
        assert_eq!(c.total_cores(), 24);
        c.set_state(2, NodeState::Draining);
        assert_eq!(c.total_cores(), 16);
    }

    #[test]
    fn heterogeneous_by_hand() {
        let mut c = ClusterSpec::homogeneous(2, 4, 1024, 2);
        c.nodes[1].cores = 16;
        assert_eq!(c.total_cores(), 20);
    }
}
