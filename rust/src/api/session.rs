//! DRMAA-style session over a scheduler backend.
//!
//! Usage mirrors DRMAA 1.0's control flow: build [`JobTemplate`]s,
//! `submit`/`submit_array`, then `run()` the session (the synchronous
//! equivalent of `drmaa_synchronize(ALL)`) and query [`JobInfo`]s.

use crate::cluster::ClusterSpec;
use crate::sched::{RunOptions, Scheduler};
use crate::workload::{TaskSpec, Workload};

/// Description of a job to submit (DRMAA job template).
#[derive(Clone, Debug)]
pub struct JobTemplate {
    /// Human-readable name.
    pub name: String,
    /// Task runtime (virtual s).
    pub duration: f64,
    /// Memory per task (MB).
    pub mem_mb: i64,
    /// Submission time offset.
    pub submit_at: f64,
}

impl Default for JobTemplate {
    fn default() -> Self {
        Self {
            name: "job".into(),
            duration: 1.0,
            mem_mb: 2048,
            submit_at: 0.0,
        }
    }
}

/// Job state after the session runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Queued, session not yet run.
    Pending,
    /// Ran to completion.
    Done,
}

/// Per-job accounting (DRMAA `drmaa_wait` result analog).
#[derive(Clone, Debug)]
pub struct JobInfo {
    /// Job id (dense, as returned by submit).
    pub id: u32,
    /// Status.
    pub status: JobStatus,
    /// First task start (s).
    pub start: f64,
    /// Last task end (s).
    pub end: f64,
    /// Mean queue wait across the job's tasks.
    pub mean_wait: f64,
    /// Number of tasks in the job (1 unless an array).
    pub tasks: u32,
}

/// A DRMAA-like session bound to a scheduler and cluster.
pub struct Session<'a> {
    scheduler: &'a dyn Scheduler,
    cluster: &'a ClusterSpec,
    seed: u64,
    tasks: Vec<TaskSpec>,
    /// job id -> (first task id, task count)
    jobs: Vec<(u32, u32)>,
    infos: Option<Vec<JobInfo>>,
}

impl<'a> Session<'a> {
    /// Open a session.
    pub fn new(scheduler: &'a dyn Scheduler, cluster: &'a ClusterSpec, seed: u64) -> Self {
        Self {
            scheduler,
            cluster,
            seed,
            tasks: Vec::new(),
            jobs: Vec::new(),
            infos: None,
        }
    }

    /// Submit one job; returns its job id.
    pub fn submit(&mut self, template: &JobTemplate) -> u32 {
        self.submit_array(template, 1)
    }

    /// Submit a job array of `count` tasks; returns the job id.
    pub fn submit_array(&mut self, template: &JobTemplate, count: u32) -> u32 {
        assert!(count > 0, "empty job array");
        assert!(self.infos.is_none(), "session already ran");
        let job_id = self.jobs.len() as u32;
        let first = self.tasks.len() as u32;
        for _ in 0..count {
            let mut t = TaskSpec::array(self.tasks.len() as u32, job_id, template.duration);
            t.mem_mb = template.mem_mb;
            t.submit_at = template.submit_at;
            self.tasks.push(t);
        }
        self.jobs.push((first, count));
        job_id
    }

    /// Number of submitted jobs.
    pub fn job_count(&self) -> usize {
        self.jobs.len()
    }

    /// Run everything to completion (synchronous `drmaa_synchronize`).
    /// Returns the underlying [`crate::sched::RunResult`].
    pub fn run(&mut self) -> crate::sched::RunResult {
        let workload = Workload {
            tasks: self.tasks.clone(),
            label: "api-session".into(),
        };
        workload.validate().expect("invalid session workload");
        let result =
            self.scheduler
                .run(&workload, self.cluster, self.seed, &RunOptions::with_trace());
        let trace = result.trace.as_ref().expect("trace requested");
        let mut infos = Vec::with_capacity(self.jobs.len());
        for (job_id, &(first, count)) in self.jobs.iter().enumerate() {
            let mut start = f64::INFINITY;
            let mut end = 0.0f64;
            let mut wait_sum = 0.0;
            for rec in trace
                .iter()
                .filter(|r| r.task >= first && r.task < first + count)
            {
                start = start.min(rec.start);
                end = end.max(rec.end);
                wait_sum += rec.wait();
            }
            infos.push(JobInfo {
                id: job_id as u32,
                status: JobStatus::Done,
                start,
                end,
                mean_wait: wait_sum / count as f64,
                tasks: count,
            });
        }
        self.infos = Some(infos);
        result
    }

    /// Status of a job (Pending until `run`, then Done).
    pub fn job_status(&self, job_id: u32) -> JobStatus {
        match &self.infos {
            Some(_) => JobStatus::Done,
            None => {
                assert!((job_id as usize) < self.jobs.len(), "unknown job {job_id}");
                JobStatus::Pending
            }
        }
    }

    /// Accounting info for a job after `run`.
    pub fn wait(&self, job_id: u32) -> Option<&JobInfo> {
        self.infos.as_ref()?.get(job_id as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerChoice;
    use crate::sched::make_scheduler;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 8, 32 * 1024, 2)
    }

    #[test]
    fn submit_run_wait_lifecycle() {
        let sched = make_scheduler(SchedulerChoice::Slurm);
        let cluster = cluster();
        let mut session = Session::new(sched.as_ref(), &cluster, 1);
        let a = session.submit(&JobTemplate {
            duration: 2.0,
            ..Default::default()
        });
        let b = session.submit_array(
            &JobTemplate {
                duration: 1.0,
                ..Default::default()
            },
            32,
        );
        assert_eq!(session.job_status(a), JobStatus::Pending);
        let result = session.run();
        result.check_invariants().unwrap();
        assert_eq!(session.job_status(a), JobStatus::Done);
        let ia = session.wait(a).unwrap();
        let ib = session.wait(b).unwrap();
        assert_eq!(ia.tasks, 1);
        assert_eq!(ib.tasks, 32);
        assert!(ia.end > ia.start);
        assert!(ib.mean_wait >= 0.0);
        assert!(session.wait(99).is_none());
    }

    #[test]
    fn works_across_backends() {
        let cluster = cluster();
        for choice in [
            SchedulerChoice::Mesos,
            SchedulerChoice::Yarn,
            SchedulerChoice::IdealFifo,
        ] {
            let sched = make_scheduler(choice);
            let mut session = Session::new(sched.as_ref(), &cluster, 2);
            let j = session.submit_array(&JobTemplate::default(), 8);
            let r = session.run();
            r.check_invariants().unwrap();
            assert_eq!(session.wait(j).unwrap().tasks, 8);
        }
    }

    #[test]
    #[should_panic(expected = "already ran")]
    fn no_submission_after_run() {
        let sched = make_scheduler(SchedulerChoice::IdealFifo);
        let cluster = cluster();
        let mut session = Session::new(sched.as_ref(), &cluster, 3);
        session.submit(&JobTemplate::default());
        session.run();
        session.submit(&JobTemplate::default());
    }
}
