//! DRMAA-like in-language job API.
//!
//! The paper (§3.1, §3.4) credits the big-data schedulers' adoption to
//! their "easy-to-use APIs with which applications are developed" and
//! notes DRMAA (Distributed Resource Management Application API) was
//! the batch world's equivalent. This module is that layer for sssched:
//! a session object with `submit` / `submit_array` / `wait` /
//! `job_status` over any [`crate::sched::Scheduler`] backend, so applications
//! script experiments without touching the simulator guts.

mod session;

pub use session::{JobInfo, JobStatus, JobTemplate, Session};
