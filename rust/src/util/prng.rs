//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` seeds `Prng` (xoshiro256**), which provides uniform,
//! exponential, normal and lognormal variates. Determinism matters: every
//! simulated trial is reproducible from `(experiment seed, trial index)`.

/// SplitMix64 — tiny, full-period seeder (Steele et al., 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main generator (Blackman & Vigna, 2018).
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second normal variate from the Box–Muller pair.
    cached_normal: Option<f64>,
}

impl Prng {
    /// Seed via SplitMix64, per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            cached_normal: None,
        }
    }

    /// Derive an independent stream (e.g. per trial / per node).
    pub fn fork(&mut self, stream: u64) -> Prng {
        Prng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection-free-enough method; bias is
        // negligible for n ≪ 2^64 and determinism is what we care about.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0,1], avoids ln(0)
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (cached pair).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let (u1, u2) = (1.0 - self.f64(), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Lognormal variate with *linear-space* mean `mean` and coefficient of
    /// variation `cv` (σ/μ). Used for service-time jitter: mean-preserving,
    /// strictly positive.
    pub fn lognormal_mean_cv(&mut self, mean: f64, cv: f64) -> f64 {
        if cv <= 0.0 || mean <= 0.0 {
            return mean;
        }
        let sigma2 = (1.0 + cv * cv).ln();
        let mu = mean.ln() - 0.5 * sigma2;
        (mu + sigma2.sqrt() * self.normal()).exp()
    }

    /// Sample from a precomputed lognormal (hot-path variant of
    /// [`Prng::lognormal_mean_cv`]).
    pub fn lognormal(&mut self, gen: &LognormalGen) -> f64 {
        if gen.sigma == 0.0 {
            return gen.mean;
        }
        (gen.mu + gen.sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element index.
    pub fn choose_index(&mut self, len: usize) -> usize {
        assert!(len > 0, "choose_index on empty range");
        self.below(len as u64) as usize
    }
}

/// Precomputed lognormal distribution: mean-preserving with a given
/// coefficient of variation. The per-sample cost drops from four
/// transcendentals (ln, ln, sqrt, exp) to one exp — this matters in the
/// simulators' jitter path, which draws one sample per task event.
#[derive(Clone, Copy, Debug)]
pub struct LognormalGen {
    mean: f64,
    mu: f64,
    sigma: f64,
}

impl LognormalGen {
    /// From linear-space mean and coefficient of variation.
    pub fn new(mean: f64, cv: f64) -> Self {
        if cv <= 0.0 || mean <= 0.0 {
            return Self {
                mean,
                mu: 0.0,
                sigma: 0.0,
            };
        }
        let sigma2 = (1.0 + cv * cv).ln();
        Self {
            mean,
            mu: mean.ln() - 0.5 * sigma2,
            sigma: sigma2.sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precomputed_lognormal_matches_direct() {
        // Same seed ⇒ identical samples from both paths.
        let mut a = Prng::new(3);
        let mut b = Prng::new(3);
        let gen = LognormalGen::new(2.5, 0.3);
        for _ in 0..1000 {
            let x = a.lognormal_mean_cv(2.5, 0.3);
            let y = b.lognormal(&gen);
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn precomputed_lognormal_zero_cv() {
        let mut p = Prng::new(1);
        let gen = LognormalGen::new(4.0, 0.0);
        assert_eq!(p.lognormal(&gen), 4.0);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut p = Prng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| p.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut p = Prng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| p.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_mean_preserving_and_positive() {
        let mut p = Prng::new(19);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| p.lognormal_mean_cv(2.5, 0.3)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn lognormal_zero_cv_is_constant() {
        let mut p = Prng::new(23);
        assert_eq!(p.lognormal_mean_cv(4.0, 0.0), 4.0);
    }

    #[test]
    fn below_bounds() {
        let mut p = Prng::new(29);
        for _ in 0..10_000 {
            assert!(p.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(31);
        let mut xs: Vec<u32> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Prng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }
}
