//! Running statistics (Welford) and small helpers used throughout the
//! harness for summarizing trial measurements.

/// Online mean/variance/min/max accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Build from a slice.
    pub fn of(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.add(x);
        }
        s
    }

    /// Add an observation.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 for empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Sample standard deviation (n-1).
    pub fn stddev_sample(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Minimum (+inf for empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum (-inf for empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge two summaries (parallel Welford).
    pub fn merge(&self, other: &Summary) -> Summary {
        if self.n == 0 {
            return other.clone();
        }
        if other.n == 0 {
            return self.clone();
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        Summary {
            n,
            mean,
            m2,
            min: self.min.min(other.min),
            max: self.max.max(other.max),
        }
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Percentile via linear interpolation on a *sorted* slice; q in `[0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean of strictly-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Capacity of the bounded wait-time reservoir a run carries in its
/// [`crate::sched::RunResult`]. At or below this many observations the
/// reservoir holds *every* value, so reservoir-derived percentiles are
/// exact — the property the streaming-vs-exact oracle tests exploit.
pub const WAIT_SAMPLE_CAP: usize = 512;

/// Streaming quantile estimator (the P² algorithm of Jain & Chlamtác,
/// CACM 1985): five markers track `{min, p/2, p, (1+p)/2, max}` in O(1)
/// memory, adjusting heights by a piecewise-parabolic rule as
/// observations stream in. Below 5 observations it stores the values
/// and answers with an exact order statistic (the bootstrap edge case).
///
/// Estimates are always within `[min, max]` of the observed data and
/// exact for constant streams; accuracy on wild distributions is
/// bounded by the marker spacing, which is why results also carry a
/// bounded [`Reservoir`] sample as a cross-check.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    p: f64,
    count: u64,
    /// Marker heights (during bootstrap: the first ≤5 raw values).
    q: [f64; 5],
    /// Marker positions, 1-based (integral, kept as f64 for the rule).
    pos: [f64; 5],
    /// Desired marker positions.
    npos: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
}

impl P2Quantile {
    /// Estimator for quantile `p` ∈ (0, 1).
    pub fn new(p: f64) -> Self {
        assert!(p > 0.0 && p < 1.0, "P² quantile must be in (0,1), got {p}");
        Self {
            p,
            count: 0,
            q: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            npos: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
        }
    }

    /// The target quantile.
    pub fn quantile(&self) -> f64 {
        self.p
    }

    /// Observations absorbed.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Reset to the empty state (same target quantile) — used by the
    /// warm-scratch path so a reused estimator is bit-identical to a
    /// fresh one.
    pub fn reset(&mut self) {
        *self = Self::new(self.p);
    }

    /// Absorb one observation.
    pub fn add(&mut self, x: f64) {
        if self.count < 5 {
            self.q[self.count as usize] = x;
            self.count += 1;
            if self.count == 5 {
                self.q.sort_by(|a, b| a.total_cmp(b));
            }
            return;
        }
        // Locate the cell and update the extreme markers exactly.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            while k < 3 && x >= self.q[k + 1] {
                k += 1;
            }
            k
        };
        for i in (k + 1)..5 {
            self.pos[i] += 1.0;
        }
        for i in 0..5 {
            self.npos[i] += self.dn[i];
        }
        // Adjust the three interior markers toward their desired
        // positions, parabolic when the result stays ordered.
        for i in 1..4 {
            let d = self.npos[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let d = d.signum();
                let qp = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    self.linear(i, d)
                };
                self.pos[i] += d;
            }
        }
        self.count += 1;
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.pos);
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate: NaN when empty, an exact order statistic
    /// during the <5-observation bootstrap, the middle marker after.
    pub fn estimate(&self) -> f64 {
        match self.count {
            0 => f64::NAN,
            c if c < 5 => {
                let mut head = self.q;
                let head = &mut head[..c as usize];
                head.sort_by(|a, b| a.total_cmp(b));
                percentile_sorted(head, self.p)
            }
            _ => self.q[2],
        }
    }
}

/// Bounded uniform sample of a stream (Vitter's Algorithm R) with a
/// deterministic splitmix64 replacement sequence, so equal streams give
/// bit-identical samples regardless of wall clock or worker count. At
/// or below capacity the sample *is* the stream (exact percentiles);
/// past it each prefix item stays with probability `cap / seen`.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    state: u64,
    buf: Vec<f64>,
}

impl Reservoir {
    /// Reservoir holding at most `cap` values (`cap` ≥ 1).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "reservoir capacity must be >= 1");
        Self {
            cap,
            seen: 0,
            // Fixed seed: sampling is part of the deterministic result
            // contract, not a per-run stochastic input.
            state: 0x9E37_79B9_7F4A_7C15,
            buf: Vec::new(),
        }
    }

    fn next_u64(&mut self) -> u64 {
        // splitmix64 step.
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Observations seen (≥ `sample().len()`).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Reset to empty (same capacity, same deterministic sequence) —
    /// keeps the buffer's allocation for the warm-scratch path.
    pub fn reset(&mut self) {
        self.seen = 0;
        self.state = 0x9E37_79B9_7F4A_7C15;
        self.buf.clear();
    }

    /// Absorb one observation.
    pub fn add(&mut self, x: f64) {
        self.seen += 1;
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            let j = self.next_u64() % self.seen;
            if (j as usize) < self.cap {
                self.buf[j as usize] = x;
            }
        }
    }

    /// The current sample (unsorted, insertion/replacement order).
    pub fn sample(&self) -> &[f64] {
        &self.buf
    }

    /// Sorted copy of the sample for percentile queries.
    pub fn sorted_sample(&self) -> Vec<f64> {
        let mut v = self.buf.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        v
    }
}

/// Deterministically condense a sample to at most `cap` values while
/// preserving its empirical quantile curve: sort, then keep `cap`
/// evenly-spaced order statistics (always including min and max). Used
/// when merging per-shard wait samples whose union exceeds the bound.
pub fn condense_sample(xs: &mut Vec<f64>, cap: usize) {
    assert!(cap >= 2, "condense_sample needs cap >= 2");
    xs.sort_by(|a, b| a.total_cmp(b));
    if xs.len() <= cap {
        return;
    }
    let n = xs.len();
    let picked: Vec<f64> = (0..cap).map(|i| xs[(i * (n - 1)) / (cap - 1)]).collect();
    *xs = picked;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 1.25).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn summary_merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let full = Summary::of(&xs);
        let merged = Summary::of(&xs[..37]).merge(&Summary::of(&xs[37..]));
        assert!((full.mean() - merged.mean()).abs() < 1e-9);
        assert!((full.variance() - merged.variance()).abs() < 1e-9);
        assert_eq!(full.count(), merged.count());
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.5), 3.0);
        assert!((percentile_sorted(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    /// Deterministic value stream with a known exact quantile oracle.
    fn exact_q(xs: &[f64], q: f64) -> f64 {
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        percentile_sorted(&s, q)
    }

    fn feed(p: f64, xs: &[f64]) -> P2Quantile {
        let mut e = P2Quantile::new(p);
        for &x in xs {
            e.add(x);
        }
        e
    }

    #[test]
    fn p2_constant_stream_is_exact() {
        for &q in &[0.5, 0.95, 0.99] {
            let e = feed(q, &[7.25; 1000]);
            assert_eq!(e.estimate(), 7.25, "q={q}");
        }
    }

    #[test]
    fn p2_bootstrap_below_five_is_exact_order_statistic() {
        let mut e = P2Quantile::new(0.5);
        assert!(e.estimate().is_nan(), "empty estimator must answer NaN");
        e.add(5.0);
        assert_eq!(e.estimate(), 5.0);
        e.add(1.0);
        assert!((e.estimate() - 3.0).abs() < 1e-12); // median of {1,5}
        e.add(9.0);
        assert_eq!(e.estimate(), 5.0); // median of {1,5,9}
        e.add(3.0);
        assert!((e.estimate() - 4.0).abs() < 1e-12); // median of {1,3,5,9}
    }

    #[test]
    fn p2_uniform_ramp_converges() {
        // 0..10 ramp, deterministic shuffle by stride walk.
        let n = 2001usize;
        let xs: Vec<f64> = (0..n).map(|i| (i * 977 % n) as f64 / 200.0).collect();
        for &q in &[0.5, 0.95, 0.99] {
            let e = feed(q, &xs);
            let exact = exact_q(&xs, q);
            assert!(
                (e.estimate() - exact).abs() < 0.2,
                "q={q}: p2 {} vs exact {exact}",
                e.estimate()
            );
        }
    }

    #[test]
    fn p2_bimodal_stays_in_range_and_picks_the_right_mode() {
        // 80% mass at ~1, 20% at ~100: p50 must sit in the low mode,
        // p95 in the high mode.
        let xs: Vec<f64> = (0..5000)
            .map(|i| {
                if i % 5 == 4 {
                    100.0 + (i % 7) as f64
                } else {
                    1.0 + (i % 3) as f64 * 0.01
                }
            })
            .collect();
        let p50 = feed(0.5, &xs).estimate();
        let p95 = feed(0.95, &xs).estimate();
        // p50's neighbor markers (q25, q75) both sit in the low mode, so
        // the estimate is pinned there; p95 interpolates across the mode
        // gap, so the principled bound is "far above the low mode and
        // inside the observed range", not mode membership.
        assert!((1.0..=2.0).contains(&p50), "bimodal p50 {p50}");
        assert!((10.0..=107.0).contains(&p95), "bimodal p95 {p95}");
        assert!(p50 < p95);
    }

    #[test]
    fn p2_heavy_tail_median_close_and_extremes_bounded() {
        // Pareto-ish tail: x = (1 - u)^(-2), u a deterministic ramp.
        let n = 4001usize;
        let xs: Vec<f64> = (1..=n)
            .map(|i| {
                let u = (i * 1663 % n) as f64 / (n as f64 + 1.0);
                (1.0 - u).powi(-2)
            })
            .collect();
        let (lo, hi) = (
            xs.iter().cloned().fold(f64::INFINITY, f64::min),
            xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        );
        for &q in &[0.5, 0.95, 0.99] {
            let est = feed(q, &xs).estimate();
            assert!(est >= lo && est <= hi, "q={q} estimate {est} out of range");
        }
        let exact50 = exact_q(&xs, 0.5);
        let p50 = feed(0.5, &xs).estimate();
        assert!(
            (p50 - exact50).abs() / exact50 < 0.25,
            "heavy-tail p50 {p50} vs exact {exact50}"
        );
    }

    #[test]
    fn p2_reset_matches_fresh() {
        let xs: Vec<f64> = (0..500).map(|i| ((i * 31) % 97) as f64).collect();
        let fresh = feed(0.95, &xs);
        let mut reused = feed(0.95, &[3.0, 1.0, 4.0]);
        reused.reset();
        for &x in &xs {
            reused.add(x);
        }
        assert_eq!(fresh.estimate().to_bits(), reused.estimate().to_bits());
    }

    #[test]
    fn reservoir_exact_below_capacity() {
        let mut r = Reservoir::new(64);
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        for &x in &xs {
            r.add(x);
        }
        assert_eq!(r.seen(), 50);
        assert_eq!(r.sample(), &xs[..]);
        let sorted = r.sorted_sample();
        assert_eq!(percentile_sorted(&sorted, 0.5), exact_q(&xs, 0.5));
    }

    #[test]
    fn reservoir_bounded_deterministic_and_representative() {
        let xs: Vec<f64> = (0..10_000).map(|i| (i * 379 % 10_000) as f64).collect();
        let mut a = Reservoir::new(256);
        let mut b = Reservoir::new(256);
        for &x in &xs {
            a.add(x);
            b.add(x);
        }
        assert_eq!(a.sample().len(), 256);
        assert_eq!(a.sample(), b.sample(), "equal streams → identical samples");
        // A 256-point uniform sample's median sits near the true one.
        let est = percentile_sorted(&a.sorted_sample(), 0.5);
        let exact = exact_q(&xs, 0.5);
        assert!(
            (est - exact).abs() < 1500.0,
            "reservoir median {est} vs exact {exact}"
        );
        // Reset replays the identical sequence.
        a.reset();
        assert_eq!(a.seen(), 0);
        for &x in &xs {
            a.add(x);
        }
        assert_eq!(a.sample(), b.sample());
    }

    #[test]
    fn condense_preserves_extremes_and_quantiles() {
        let mut xs: Vec<f64> = (0..1000).map(|i| (i * 613 % 1000) as f64).collect();
        let full = xs.clone();
        condense_sample(&mut xs, 101);
        assert_eq!(xs.len(), 101);
        assert_eq!(xs[0], 0.0);
        assert_eq!(*xs.last().unwrap(), 999.0);
        let med = percentile_sorted(&xs, 0.5);
        assert!((med - exact_q(&full, 0.5)).abs() < 20.0);
        // Below cap: sorted but untouched in content.
        let mut small = vec![3.0, 1.0, 2.0];
        condense_sample(&mut small, 10);
        assert_eq!(small, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn sorts_are_total_even_for_nan_and_signed_zero() {
        // Regression for the PR 1 `partial_cmp().unwrap()` bug class:
        // the stats sorts must neither panic on NaN nor let -0.0/+0.0
        // order depend on input order. total_cmp pins -0.0 < +0.0 and
        // sorts NaN after +inf instead of panicking.
        let mut xs = vec![f64::NAN, 0.0, f64::INFINITY, -0.0, f64::NEG_INFINITY, 1.0];
        condense_sample(&mut xs, 6);
        assert_eq!(xs[0], f64::NEG_INFINITY);
        assert!(xs[1].is_sign_negative() && xs[1] == 0.0, "-0.0 sorts first");
        assert!(xs[2].is_sign_positive() && xs[2] == 0.0);
        assert_eq!(xs[3], 1.0);
        assert_eq!(xs[4], f64::INFINITY);
        assert!(xs[5].is_nan(), "NaN sorts last, no panic");

        // Same stream in reverse condenses to the identical bytes —
        // the order-independence the differential suites rely on.
        let mut fwd = vec![-0.0, 0.0, 2.5, -1.0];
        let mut rev = fwd.clone();
        rev.reverse();
        condense_sample(&mut fwd, 4);
        condense_sample(&mut rev, 4);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&fwd), bits(&rev));
    }
}
