//! Running statistics (Welford) and small helpers used throughout the
//! harness for summarizing trial measurements.

/// Online mean/variance/min/max accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Build from a slice.
    pub fn of(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.add(x);
        }
        s
    }

    /// Add an observation.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 for empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Sample standard deviation (n-1).
    pub fn stddev_sample(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Minimum (+inf for empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum (-inf for empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge two summaries (parallel Welford).
    pub fn merge(&self, other: &Summary) -> Summary {
        if self.n == 0 {
            return other.clone();
        }
        if other.n == 0 {
            return self.clone();
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        Summary {
            n,
            mean,
            m2,
            min: self.min.min(other.min),
            max: self.max.max(other.max),
        }
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Percentile via linear interpolation on a *sorted* slice; q in `[0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean of strictly-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 1.25).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn summary_merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let full = Summary::of(&xs);
        let merged = Summary::of(&xs[..37]).merge(&Summary::of(&xs[37..]));
        assert!((full.mean() - merged.mean()).abs() < 1e-9);
        assert!((full.variance() - merged.variance()).abs() < 1e-9);
        assert_eq!(full.count(), merged.count());
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.5), 3.0);
        assert!((percentile_sorted(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }
}
