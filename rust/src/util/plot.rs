//! ASCII scatter/line plots, log–log capable — used to render Figures 4–7
//! in the terminal the way the paper renders them on log-log axes.

/// One named series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    /// Legend label.
    pub label: String,
    /// Glyph used for this series' points.
    pub glyph: char,
    /// Data points.
    pub points: Vec<(f64, f64)>,
}

/// An ASCII plot canvas.
#[derive(Clone, Debug)]
pub struct Plot {
    title: String,
    x_label: String,
    y_label: String,
    log_x: bool,
    log_y: bool,
    width: usize,
    height: usize,
    series: Vec<Series>,
}

impl Plot {
    /// New plot with axis labels.
    pub fn new(title: impl Into<String>, x_label: impl Into<String>, y_label: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            log_x: false,
            log_y: false,
            width: 64,
            height: 20,
            series: Vec::new(),
        }
    }

    /// Use log-log axes (points with non-positive coords are dropped).
    pub fn loglog(mut self) -> Self {
        self.log_x = true;
        self.log_y = true;
        self
    }

    /// Canvas size in characters.
    pub fn size(mut self, width: usize, height: usize) -> Self {
        self.width = width.max(16);
        self.height = height.max(8);
        self
    }

    /// Add a series.
    pub fn series(&mut self, label: impl Into<String>, glyph: char, points: Vec<(f64, f64)>) -> &mut Self {
        self.series.push(Series {
            label: label.into(),
            glyph,
            points,
        });
        self
    }

    fn tx(&self, x: f64) -> Option<f64> {
        if self.log_x {
            (x > 0.0).then(|| x.ln())
        } else {
            Some(x)
        }
    }

    fn ty(&self, y: f64) -> Option<f64> {
        if self.log_y {
            (y > 0.0).then(|| y.ln())
        } else {
            Some(y)
        }
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut pts: Vec<(f64, f64, char)> = Vec::new();
        for s in &self.series {
            for &(x, y) in &s.points {
                if let (Some(tx), Some(ty)) = (self.tx(x), self.ty(y)) {
                    pts.push((tx, ty, s.glyph));
                }
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("-- {} --\n", self.title));
        }
        if pts.is_empty() {
            out.push_str("(no plottable points)\n");
            return out;
        }
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y, _) in &pts {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for &(x, y, g) in &pts {
            let cx = ((x - x0) / (x1 - x0) * (self.width - 1) as f64).round() as usize;
            let cy = ((y - y0) / (y1 - y0) * (self.height - 1) as f64).round() as usize;
            let row = self.height - 1 - cy;
            // Later series overwrite earlier ones; '*' marks collisions of
            // different glyphs.
            let cell = &mut grid[row][cx];
            *cell = if *cell == ' ' || *cell == g { g } else { '*' };
        }
        let inv = |v: f64| if self.log_y { v.exp() } else { v };
        let invx = |v: f64| if self.log_x { v.exp() } else { v };
        out.push_str(&format!("{} (top={:.3})\n", self.y_label, inv(y1)));
        for row in &grid {
            out.push('|');
            out.extend(row.iter());
            out.push('\n');
        }
        out.push('+');
        out.push_str(&"-".repeat(self.width));
        out.push('\n');
        out.push_str(&format!(
            " {}: {:.3} .. {:.3}   (y bottom={:.3})\n",
            self.x_label,
            invx(x0),
            invx(x1),
            inv(y0)
        ));
        for s in &self.series {
            out.push_str(&format!("   {} {}\n", s.glyph, s.label));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points() {
        let mut p = Plot::new("t", "n", "dt").size(32, 10);
        p.series("s", 'o', vec![(1.0, 1.0), (2.0, 4.0), (3.0, 9.0)]);
        let r = p.render();
        assert!(r.contains("-- t --"));
        assert!(r.matches('o').count() >= 3);
    }

    #[test]
    fn loglog_drops_nonpositive() {
        let mut p = Plot::new("t", "n", "dt").loglog().size(32, 10);
        p.series("s", '#', vec![(0.0, 1.0), (-1.0, 2.0), (10.0, 100.0), (100.0, 1000.0)]);
        let r = p.render();
        // 2 plotted points + 1 legend glyph; the non-positive points drop.
        assert!(r.matches('#').count() == 3, "{r}");
    }

    #[test]
    fn empty_plot_ok() {
        let p = Plot::new("t", "x", "y");
        assert!(p.render().contains("no plottable points"));
    }
}
