//! Least-squares fitting, including the paper's headline fit:
//! ΔT = t_s · n^α_s, fitted as a line in log–log space
//! (log ΔT = log t_s + α_s · log n). Table 10 of the paper reports
//! exactly these two parameters per scheduler.
//!
//! Two entry points per fit: a `try_*` form returning [`FitError`] for
//! callers that must survive pathological data (the `model` experiment
//! gates a sweep row on its fit, so a degenerate row has to fail with a
//! diagnostic rather than abort the process), and the original
//! panicking form for call sites where bad input is a programming
//! error.

use std::fmt;

/// Why a least-squares fit could not be computed from the given points.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitError {
    /// Fewer than two usable points: `usable` counts the points that
    /// survived filtering (the power-law path drops non-positive n or
    /// ΔT), out of `total` supplied.
    TooFewPoints { usable: usize, total: usize },
    /// All x values coincide, so the slope is unidentifiable.
    DegenerateX,
}

impl fmt::Display for FitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FitError::TooFewPoints { usable, total } => write!(
                f,
                "need at least 2 usable points, got {usable} of {total} supplied"
            ),
            FitError::DegenerateX => write!(f, "degenerate x values (all x coincide)"),
        }
    }
}

/// Result of a simple linear regression y = a + b·x.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Line {
    /// Intercept a.
    pub intercept: f64,
    /// Slope b.
    pub slope: f64,
    /// Coefficient of determination.
    pub r2: f64,
}

/// Ordinary least squares on (x, y) pairs. Errors on fewer than 2
/// points or coincident x values instead of panicking.
pub fn try_linear_regression(xs: &[f64], ys: &[f64]) -> Result<Line, FitError> {
    assert_eq!(xs.len(), ys.len(), "x/y length mismatch");
    if xs.len() < 2 {
        return Err(FitError::TooFewPoints {
            usable: xs.len(),
            total: xs.len(),
        });
    }
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() <= 1e-300 {
        return Err(FitError::DegenerateX);
    }
    let slope = (n * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / n;
    // R^2
    let my = sy / n;
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (intercept + slope * x);
            e * e
        })
        .sum();
    let r2 = if ss_tot <= 0.0 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    };
    Ok(Line {
        intercept,
        slope,
        r2,
    })
}

/// Ordinary least squares on (x, y) pairs. Panics if fewer than 2
/// points or the x values are degenerate; use [`try_linear_regression`]
/// where bad input is survivable.
pub fn linear_regression(xs: &[f64], ys: &[f64]) -> Line {
    try_linear_regression(xs, ys).unwrap_or_else(|e| panic!("linear regression: {e}"))
}

/// Fitted power law ΔT = t_s · n^α_s.
#[derive(Clone, Copy, Debug)]
pub struct PowerLawFit {
    /// Marginal scheduler latency t_s (seconds). "Smaller is better".
    pub t_s: f64,
    /// Nonlinear exponent α_s. "Smaller is better".
    pub alpha_s: f64,
    /// R² of the log–log fit.
    pub r2: f64,
}

impl PowerLawFit {
    /// Evaluate the model ΔT(n).
    pub fn delta_t(&self, n: f64) -> f64 {
        self.t_s * n.powf(self.alpha_s)
    }
}

/// Fit ΔT = t_s n^α_s by OLS in log–log space. Points with non-positive
/// n or ΔT are skipped (they carry no information for a power law and
/// occur only as shot noise at tiny n). Errors if fewer than 2 usable
/// points remain or all usable n coincide.
pub fn try_fit_power_law(ns: &[f64], delta_ts: &[f64]) -> Result<PowerLawFit, FitError> {
    assert_eq!(ns.len(), delta_ts.len());
    let (mut xs, mut ys) = (Vec::new(), Vec::new());
    for (&n, &dt) in ns.iter().zip(delta_ts) {
        if n > 0.0 && dt > 0.0 {
            xs.push(n.ln());
            ys.push(dt.ln());
        }
    }
    let line = try_linear_regression(&xs, &ys).map_err(|e| match e {
        // Report the filter's view of the data, not the filtered slice's.
        FitError::TooFewPoints { usable, .. } => FitError::TooFewPoints {
            usable,
            total: ns.len(),
        },
        other => other,
    })?;
    Ok(PowerLawFit {
        t_s: line.intercept.exp(),
        alpha_s: line.slope,
        r2: line.r2,
    })
}

/// Fit ΔT = t_s n^α_s by OLS in log–log space, panicking on degenerate
/// input; use [`try_fit_power_law`] where bad input is survivable.
pub fn fit_power_law(ns: &[f64], delta_ts: &[f64]) -> PowerLawFit {
    try_fit_power_law(ns, delta_ts).unwrap_or_else(|e| panic!("power-law fit: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let l = linear_regression(&xs, &ys);
        assert!((l.intercept - 1.0).abs() < 1e-12);
        assert!((l.slope - 2.0).abs() < 1e-12);
        assert!((l.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_line_r2_below_one() {
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        let ys = [0.1, 0.9, 2.2, 2.8, 4.1];
        let l = linear_regression(&xs, &ys);
        assert!((l.slope - 1.0).abs() < 0.1);
        assert!(l.r2 > 0.97 && l.r2 < 1.0);
    }

    #[test]
    fn power_law_exact_recovery() {
        // The paper's Slurm fit: t_s = 2.2, alpha_s = 1.3.
        let ns: [f64; 4] = [4.0, 8.0, 48.0, 240.0];
        let dts: Vec<f64> = ns.iter().map(|n| 2.2 * n.powf(1.3)).collect();
        let fit = fit_power_law(&ns, &dts);
        assert!((fit.t_s - 2.2).abs() < 1e-9, "t_s={}", fit.t_s);
        assert!((fit.alpha_s - 1.3).abs() < 1e-9, "alpha={}", fit.alpha_s);
        assert!((fit.r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn power_law_skips_nonpositive_points() {
        let ns: [f64; 5] = [1.0, 4.0, 8.0, 48.0, 240.0];
        let mut dts: Vec<f64> = ns.iter().map(|n| 33.0 * n.powf(1.0)).collect();
        dts[0] = 0.0; // shot-noise zero at n=1 must be ignored
        let fit = fit_power_law(&ns, &dts);
        assert!((fit.t_s - 33.0).abs() < 1e-9);
        assert!((fit.alpha_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn model_eval_roundtrip() {
        let fit = PowerLawFit {
            t_s: 3.4,
            alpha_s: 1.1,
            r2: 1.0,
        };
        assert!((fit.delta_t(240.0) - 3.4 * 240f64.powf(1.1)).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn regression_needs_two_points() {
        linear_regression(&[1.0], &[1.0]);
    }

    #[test]
    fn try_regression_too_few_points_is_an_error() {
        let err = try_linear_regression(&[1.0], &[1.0]).unwrap_err();
        assert_eq!(err, FitError::TooFewPoints { usable: 1, total: 1 });
        assert!(err.to_string().contains("at least 2"));
    }

    #[test]
    fn try_regression_degenerate_x_is_an_error() {
        // Three points, all at the same x: slope unidentifiable.
        let err = try_linear_regression(&[2.0, 2.0, 2.0], &[1.0, 2.0, 3.0]).unwrap_err();
        assert_eq!(err, FitError::DegenerateX);
        assert!(err.to_string().contains("degenerate"));
    }

    #[test]
    fn try_power_law_reports_usable_vs_total() {
        // Five points supplied, but only one survives the positivity
        // filter — the error must say so.
        let err = try_fit_power_law(&[1.0, 2.0, 4.0, 8.0, 16.0], &[0.0, 0.0, 0.0, 0.0, 3.0])
            .unwrap_err();
        assert_eq!(err, FitError::TooFewPoints { usable: 1, total: 5 });
    }

    #[test]
    fn try_power_law_single_n_is_degenerate() {
        // Repeated trials at one n: positive ΔT everywhere, but the
        // exponent is unidentifiable from a single n.
        let err = try_fit_power_law(&[8.0, 8.0, 8.0], &[3.0, 3.1, 2.9]).unwrap_err();
        assert_eq!(err, FitError::DegenerateX);
    }

    #[test]
    #[should_panic]
    fn regression_panics_on_degenerate_x() {
        linear_regression(&[5.0, 5.0], &[1.0, 2.0]);
    }
}
