//! Miniature property-based testing harness.
//!
//! The offline crate set has no `proptest`/`quickcheck`, so this module
//! supplies the small subset we need: seeded generators + a `forall`
//! runner that reports the failing case count and seed. Shrinking is
//! deliberately omitted — cases are reported with their seed so they can
//! be replayed deterministically.

use super::prng::Prng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of random cases to run.
    pub cases: u32,
    /// Base seed; case i uses seed `seed + i`.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` on `cases` generated inputs; panics with the seed of the
/// first failing case. `gen` receives a fresh deterministic PRNG per case.
pub fn forall<T: std::fmt::Debug>(
    config: PropConfig,
    mut gen: impl FnMut(&mut Prng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..config.cases {
        let seed = config.seed.wrapping_add(case as u64);
        let mut rng = Prng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (replay seed {seed:#x}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Like `forall` but with default config.
pub fn check<T: std::fmt::Debug>(
    gen: impl FnMut(&mut Prng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    forall(PropConfig::default(), gen, prop)
}

/// Assert helper: build a `Result` from a condition.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(
            |rng| rng.range_u64(0, 100),
            |&x| ensure(x <= 100, "bounded"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(
            |rng| rng.range_u64(0, 100),
            |&x| ensure(x > 100, "impossible"),
        );
    }

    #[test]
    fn deterministic_generation() {
        let mut seen = Vec::new();
        forall(
            PropConfig { cases: 5, seed: 9 },
            |rng| rng.next_u64(),
            |&x| {
                seen.push(x);
                Ok(())
            },
        );
        let mut seen2 = Vec::new();
        forall(
            PropConfig { cases: 5, seed: 9 },
            |rng| rng.next_u64(),
            |&x| {
                seen2.push(x);
                Ok(())
            },
        );
        assert_eq!(seen, seen2);
    }
}
