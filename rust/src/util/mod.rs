//! Small self-contained utilities. The offline crate set has no `rand`,
//! `serde`, `clap`, `criterion` or `proptest`, so the pieces we need from
//! them are implemented here: a deterministic PRNG + distributions,
//! running statistics and least-squares fitting, ASCII table/plot
//! rendering, and a miniature property-testing harness.

pub mod fit;
pub mod plot;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;

pub use fit::{fit_power_law, linear_regression, PowerLawFit};
pub use prng::{Prng, SplitMix64};
pub use stats::Summary;
