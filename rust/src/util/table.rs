//! ASCII table rendering for the harness reports (the paper's tables are
//! regenerated as text tables; CSV export for downstream plotting).

/// A simple column-aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; must match the header arity.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: append a row of &str.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as aligned ASCII.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", c, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|c| esc(c))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with sensible significant digits for reports.
pub fn fnum(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    let ax = x.abs();
    if ax == 0.0 {
        "0".into()
    } else if ax >= 1000.0 {
        format!("{x:.0}")
    } else if ax >= 10.0 {
        format!("{x:.1}")
    } else if ax >= 0.01 {
        format!("{x:.3}")
    } else {
        format!("{x:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row_str(&["xx", "y"]);
        t.row_str(&["1", "22222"]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("a   bbbb"));
        assert!(r.lines().count() == 5);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row_str(&["x,y", "he said \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(2774.3), "2774");
        assert_eq!(fnum(33.0), "33.0");
        assert_eq!(fnum(2.2), "2.200");
        assert_eq!(fnum(0.001), "1.00e-3");
    }
}
