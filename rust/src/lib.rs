//! sssched — reproduction of "Scalable System Scheduling for HPC and
//! Big Data" (Reuther et al., JPDC 2017, DOI 10.1016/j.jpdc.2017.06.009).
//!
//! Job schedulers are the "operating systems" of big-data and HPC
//! clusters; the paper measures their job-launch latency, models it as
//! ΔT = t_s·n^α_s, and shows multilevel scheduling recovers the
//! utilization that seconds-scale tasks lose. This crate rebuilds the
//! entire study:
//!
//! * [`sim`], [`cluster`], [`workload`] — the discrete-event testbed
//!   standing in for the paper's 1408-core SuperCloud;
//! * [`sched`] — mechanistic models of Slurm, Grid Engine, Mesos and
//!   Hadoop YARN (plus a Sparrow-like distributed scheduler, batch-queue
//!   policies with EASY backfill, and an ideal-FIFO reference);
//! * [`multilevel`] — LLMapReduce-style aggregation (paper §5.3);
//! * [`model`] — the Section 4 latency/utilization equations + fitting;
//! * [`runtime`] — the model-kernel suite (power-law fit, U_v
//!   reduction, analytics payload); native backend offline, with the
//!   AOT/PJRT path gated out until the crate set carries `xla`;
//! * [`exec`] — a realtime leader/worker mini-cluster running real
//!   kernel payloads (examples/end_to_end.rs);
//! * [`harness`], [`features`] — regenerate every table and figure;
//! * [`api`] — a DRMAA-like session API for scripting experiments;
//! * [`config`], [`cli`], [`util`] — config files, CLI, and the PRNG /
//!   stats / property-testing substrate (the offline crate set has no
//!   rand/serde/clap/proptest, so they live here).
//!
//! Python (`python/compile/`) runs only at build time (`make
//! artifacts`); the rust binary is self-contained afterwards.
pub mod api;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod exec;
pub mod features;
pub mod harness;
pub mod lint;
pub mod model;
pub mod multilevel;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;
pub mod workload;
