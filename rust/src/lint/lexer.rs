//! A minimal hand-rolled Rust lexer for `pallas-lint`.
//!
//! This is not a full Rust grammar — it is exactly enough lexing to
//! make token-level rules sound: comments (line, nested block), string
//! literals (cooked, raw with any `#` count, byte, raw-byte), char
//! literals vs lifetimes, raw identifiers, and numeric literals are
//! all recognized so that e.g. `partial_cmp` inside a string or a
//! comment never reaches the rule engine as an identifier.
//!
//! The lexer is lossy on purpose: punctuation is emitted one char at a
//! time (`::` is two `Punct(':')` tokens) and numeric payloads are
//! discarded. Rules match identifier sequences, which survive intact.

/// One source token, with comments and whitespace stripped.
#[derive(Clone, Debug, PartialEq)]
pub struct Tok {
    /// 1-based line the token starts on.
    pub line: u32,
    /// Kind and payload.
    pub kind: TokKind,
}

/// Token kinds. Only identifiers and string contents carry payloads —
/// the rules never need anything else.
#[derive(Clone, Debug, PartialEq)]
pub enum TokKind {
    /// Identifier or keyword (raw identifiers lose their `r#` prefix).
    Ident(String),
    /// String-literal content (quotes stripped, escapes left raw).
    Str(String),
    /// Char or byte-char literal; the payload is irrelevant to rules.
    CharLit,
    /// A lifetime such as `'a` or `'static`.
    Lifetime,
    /// Numeric literal; the payload is irrelevant to rules.
    Num,
    /// A single punctuation character.
    Punct(char),
}

/// A `//` line comment (doc comments included), kept separately from
/// the token stream so the allow-directive parser can see them.
#[derive(Clone, Debug, PartialEq)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: u32,
    /// True when code tokens precede the comment on the same line
    /// (a trailing comment annotates its own line, not the next one).
    pub trailing: bool,
    /// Text after the `//`, untrimmed.
    pub text: String,
}

/// Result of lexing one file.
#[derive(Clone, Debug, Default)]
pub struct Lexed {
    /// Code tokens in source order.
    pub tokens: Vec<Tok>,
    /// Line comments in source order.
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_char(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Consume a cooked (escaped) string body starting just after the
/// opening quote. Returns `(content, index_after_closing_quote,
/// newlines_consumed)`. Escapes are kept verbatim in the content.
fn cooked_string(cs: &[char], mut j: usize) -> (String, usize, u32) {
    let mut content = String::new();
    let mut nl = 0u32;
    while j < cs.len() {
        match cs[j] {
            '\\' => {
                content.push('\\');
                if let Some(&e) = cs.get(j + 1) {
                    if e == '\n' {
                        nl += 1;
                    }
                    content.push(e);
                    j += 2;
                } else {
                    j += 1;
                }
            }
            '"' => return (content, j + 1, nl),
            '\n' => {
                nl += 1;
                content.push('\n');
                j += 1;
            }
            ch => {
                content.push(ch);
                j += 1;
            }
        }
    }
    (content, j, nl)
}

/// Lex `src` into tokens and line comments.
pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;
    // Whether a code token has been emitted on the current line; used
    // to classify comments as trailing.
    let mut line_has_code = false;

    macro_rules! emit {
        ($kind:expr) => {{
            out.tokens.push(Tok { line, kind: $kind });
            line_has_code = true;
        }};
    }

    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            line_has_code = false;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }

        // Line comment (also catches /// and //! doc comments).
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            let start = i + 2;
            let mut j = start;
            while j < cs.len() && cs[j] != '\n' {
                j += 1;
            }
            out.comments.push(Comment {
                line,
                trailing: line_has_code,
                text: cs[start..j].iter().collect(),
            });
            i = j; // the newline is handled on the next iteration
            continue;
        }

        // Block comment, with nesting (Rust block comments nest).
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let mut depth = 1u32;
            let mut j = i + 2;
            while j < cs.len() && depth > 0 {
                if cs[j] == '\n' {
                    line += 1;
                    line_has_code = false;
                    j += 1;
                } else if cs[j] == '/' && cs.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if cs[j] == '*' && cs.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }

        // Byte string b"..." (cooked).
        if c == 'b' && cs.get(i + 1) == Some(&'"') {
            let (content, j, nl) = cooked_string(&cs, i + 2);
            emit!(TokKind::Str(content));
            line += nl;
            i = j;
            continue;
        }

        // Byte char b'x'.
        if c == 'b' && cs.get(i + 1) == Some(&'\'') {
            let mut j = i + 2;
            if cs.get(j) == Some(&'\\') {
                j += 2; // skip the escaped char
            }
            while j < cs.len() && cs[j] != '\'' {
                j += 1;
            }
            emit!(TokKind::CharLit);
            i = (j + 1).min(cs.len());
            continue;
        }

        // Raw strings r"…" / r#"…"# / br#"…"# and raw identifiers r#x.
        let raw_start = (c == 'r' && matches!(cs.get(i + 1), Some('"') | Some('#')))
            || (c == 'b'
                && cs.get(i + 1) == Some(&'r')
                && matches!(cs.get(i + 2), Some('"') | Some('#')));
        if raw_start {
            let hash_start = if c == 'b' { i + 2 } else { i + 1 };
            let mut hashes = 0usize;
            let mut j = hash_start;
            while cs.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if cs.get(j) == Some(&'"') {
                // Raw string: body runs until `"` followed by `hashes` #s.
                j += 1;
                let body_start = j;
                let mut nl = 0u32;
                while j < cs.len() {
                    if cs[j] == '"' {
                        let mut k = 0usize;
                        while k < hashes && cs.get(j + 1 + k) == Some(&'#') {
                            k += 1;
                        }
                        if k == hashes {
                            break;
                        }
                    } else if cs[j] == '\n' {
                        nl += 1;
                    }
                    j += 1;
                }
                let content: String = cs[body_start..j.min(cs.len())].iter().collect();
                emit!(TokKind::Str(content));
                line += nl;
                i = (j + 1 + hashes).min(cs.len());
                continue;
            }
            if c == 'r' && hashes == 1 && cs.get(j).map(|&ch| is_ident_start(ch)) == Some(true) {
                // Raw identifier r#ident — emit without the prefix.
                let mut k = j;
                while k < cs.len() && is_ident_char(cs[k]) {
                    k += 1;
                }
                emit!(TokKind::Ident(cs[j..k].iter().collect()));
                i = k;
                continue;
            }
            // Fall through: a bare `r` / `b` ident followed by puncts.
        }

        // Char literal vs lifetime.
        if c == '\'' {
            match cs.get(i + 1).copied() {
                Some('\\') => {
                    // Escaped char literal: escapes never contain a quote.
                    let mut j = i + 2;
                    while j < cs.len() && cs[j] != '\'' {
                        j += 1;
                    }
                    emit!(TokKind::CharLit);
                    i = (j + 1).min(cs.len());
                }
                Some(ch) if is_ident_start(ch) => {
                    if cs.get(i + 2) == Some(&'\'') {
                        // 'a' — a one-char literal.
                        emit!(TokKind::CharLit);
                        i += 3;
                    } else {
                        // 'ident not followed by a quote — a lifetime.
                        let mut j = i + 1;
                        while j < cs.len() && is_ident_char(cs[j]) {
                            j += 1;
                        }
                        emit!(TokKind::Lifetime);
                        i = j;
                    }
                }
                Some(_) => {
                    // Non-identifier char literal such as '+' or '\n'.
                    let mut j = i + 1;
                    while j < cs.len() && cs[j] != '\'' {
                        j += 1;
                    }
                    emit!(TokKind::CharLit);
                    i = (j + 1).min(cs.len());
                }
                None => {
                    i += 1;
                }
            }
            continue;
        }

        // Cooked string.
        if c == '"' {
            let (content, j, nl) = cooked_string(&cs, i + 1);
            emit!(TokKind::Str(content));
            line += nl;
            i = j;
            continue;
        }

        // Number: digits, alphanumeric suffixes/exponents and `.` only
        // when the dot is followed by a digit (so `1.0f64.to_bits()`
        // does not swallow the method name).
        if c.is_ascii_digit() {
            let mut j = i;
            while j < cs.len() {
                let ch = cs[j];
                if ch.is_ascii_alphanumeric() || ch == '_' {
                    j += 1;
                } else if ch == '.' && cs.get(j + 1).map(|d| d.is_ascii_digit()) == Some(true) {
                    j += 1;
                } else {
                    break;
                }
            }
            emit!(TokKind::Num);
            i = j;
            continue;
        }

        // Identifier / keyword.
        if is_ident_start(c) {
            let mut j = i;
            while j < cs.len() && is_ident_char(cs[j]) {
                j += 1;
            }
            emit!(TokKind::Ident(cs[i..j].iter().collect()));
            i = j;
            continue;
        }

        // Everything else: single-char punctuation.
        emit!(TokKind::Punct(c));
        i += 1;
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn strings_hide_identifiers() {
        let src = r##"let x = "partial_cmp HashMap"; let y = r#"Instant::now"#;"##;
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "x", "let", "y"]);
        let strs: Vec<String> = lex(src)
            .tokens
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::Str(s) => Some(s),
                _ => None,
            })
            .collect();
        assert_eq!(strs, vec!["partial_cmp HashMap", "Instant::now"]);
    }

    #[test]
    fn raw_string_hash_counts_respected() {
        // The inner "# must not terminate a ##-delimited raw string.
        let src = "let a = r##\"has \"# inside\"##; let b = 1;";
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "a", "let", "b"]);
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* outer /* inner partial_cmp */ still out */ b";
        assert_eq!(idents(src), vec!["a", "b"]);
    }

    #[test]
    fn line_comments_captured_with_trailing_flag() {
        let src = "// leading\nlet x = 1; // trailing\n";
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 2);
        assert!(!lx.comments[0].trailing);
        assert_eq!(lx.comments[0].line, 1);
        assert!(lx.comments[1].trailing);
        assert_eq!(lx.comments[1].line, 2);
        assert_eq!(lx.comments[1].text.trim(), "trailing");
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let src = "let c = 'x'; fn f<'a>(s: &'a str, t: &'static str) -> char { '\\n' }";
        let lx = lex(src);
        let chars = lx
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::CharLit)
            .count();
        let lifetimes = lx
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .count();
        assert_eq!(chars, 2, "'x' and '\\n'");
        assert_eq!(lifetimes, 3, "'a twice and 'static");
    }

    #[test]
    fn numbers_do_not_swallow_method_calls() {
        let src = "let b = 1.0f64.to_bits(); let r = 0..5;";
        let ids = idents(src);
        assert!(ids.contains(&"to_bits".to_string()));
    }

    #[test]
    fn line_numbers_survive_multiline_strings() {
        let src = "let a = \"one\ntwo\";\nlet b = 1;";
        let lx = lex(src);
        let b_line = lx
            .tokens
            .iter()
            .find(|t| t.kind == TokKind::Ident("b".into()))
            .map(|t| t.line);
        assert_eq!(b_line, Some(3));
    }

    #[test]
    fn byte_literals() {
        let src = "let s = b\"HashSet\"; let c = b'x';";
        assert_eq!(idents(src), vec!["let", "s", "let", "c"]);
    }
}
