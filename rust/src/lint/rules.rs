//! The determinism rules `pallas-lint` enforces.
//!
//! Per-file token rules live in [`token_rules`]; helpers for the
//! cross-file rules (golden snapshots, experiment wiring) extract the
//! facts each file contributes and leave the joining to `lint_tree`.
//!
//! Scopes are path-based on the `rust/`-relative forward-slash path
//! (`src/sim/engine.rs`), so the same engine runs against fixture
//! sources with synthetic paths in tests.

use super::lexer::{Lexed, Tok, TokKind};
use super::Diagnostic;

/// `HashMap`/`HashSet` inside a deterministic module.
pub const RULE_HASH_ITERATION: &str = "hash-iteration";
/// `partial_cmp` call (NaN-incomparable float ordering).
pub const RULE_FLOAT_ORD: &str = "float-ord";
/// `Instant`/`SystemTime` outside the realtime executor / timing harness.
pub const RULE_WALL_CLOCK: &str = "wall-clock";
/// OS entropy (`RandomState`, `thread_rng`, …) anywhere in `src/`.
pub const RULE_OS_ENTROPY: &str = "os-entropy";
/// `thread::{spawn,scope,Builder}` outside the deterministic-merge modules.
pub const RULE_THREAD_SPAWN: &str = "thread-spawn";
/// A `SchedPolicy` impl that does not state its fault behaviour.
pub const RULE_FAULT_HOOKS: &str = "fault-hooks";
/// An experiment name missing from CLI dispatch, `validate`, or README.
pub const RULE_EXPERIMENT_WIRING: &str = "experiment-wiring";
/// A golden snapshot referenced by tests but absent (or orphaned) on disk.
pub const RULE_GOLDEN_EXISTS: &str = "golden-exists";

/// Meta: an allow that suppressed nothing.
pub const RULE_STALE_ALLOW: &str = "stale-allow";
/// Meta: an allow without a reason clause.
pub const RULE_ALLOW_MISSING_REASON: &str = "allow-missing-reason";
/// Meta: an allow naming no known rule, or an unparseable directive.
pub const RULE_UNKNOWN_RULE: &str = "unknown-rule";

/// Static description of one suppressible rule, for `--json` consumers
/// and the README table.
pub struct RuleInfo {
    /// Rule name as used in diagnostics and `pallas: allow(...)`.
    pub name: &'static str,
    /// Path scope the rule applies to.
    pub scope: &'static str,
    /// Why the pattern breaks the bit-identity contract.
    pub rationale: &'static str,
}

/// All suppressible rules. The meta rules ([`RULE_STALE_ALLOW`],
/// [`RULE_ALLOW_MISSING_REASON`], [`RULE_UNKNOWN_RULE`]) are deliberately
/// not in this table: an allow cannot suppress the allow machinery.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        name: RULE_HASH_ITERATION,
        scope: "src/{sim,sched,cluster,multilevel,workload}/",
        rationale: "HashMap/HashSet iteration order is seeded per process; \
                    simulated outcomes must not depend on it — use BTreeMap/BTreeSet \
                    or a sorted drain",
    },
    RuleInfo {
        name: RULE_FLOAT_ORD,
        scope: "src/ (definitions named partial_cmp are exempt)",
        rationale: "partial_cmp returns None for NaN, so sorts panic or silently \
                    misorder — use total_cmp (the PR 1 MultiServer::serve bug class)",
    },
    RuleInfo {
        name: RULE_WALL_CLOCK,
        scope: "src/ except exec/realtime.rs and harness/scale.rs",
        rationale: "simulated paths must be pure in virtual time; wall-clock reads \
                    make runs non-replayable",
    },
    RuleInfo {
        name: RULE_OS_ENTROPY,
        scope: "src/ except exec/realtime.rs and harness/scale.rs",
        rationale: "all randomness must flow from the experiment seed so any run \
                    can be replayed bit-for-bit",
    },
    RuleInfo {
        name: RULE_THREAD_SPAWN,
        scope: "src/ except harness/parallel.rs, sched/sharded.rs, exec/",
        rationale: "ad-hoc threading reduces in nondeterministic order and breaks \
                    --jobs bit-identity; use the deterministic-merge helpers",
    },
    RuleInfo {
        name: RULE_FAULT_HOOKS,
        scope: "src/ SchedPolicy impls outside #[cfg(test)] modules",
        rationale: "every policy must state its on_node_{fail,suspected,drain,recover} \
                    behaviour, if only as a documented no-op, so churn and \
                    detection semantics are a decision rather than an accident",
    },
    RuleInfo {
        name: RULE_EXPERIMENT_WIRING,
        scope: "config::EXPERIMENT_NAMES vs src/main.rs and README EXPERIMENTS",
        rationale: "an experiment that parses but is missing a CLI arm, a validate \
                    shape-check, or a README row is dead weight or a typo",
    },
    RuleInfo {
        name: RULE_GOLDEN_EXISTS,
        scope: "tests/*.rs references into tests/golden/",
        rationale: "a renamed or typo'd snapshot reference silently un-pins the \
                    behaviour the golden was guarding",
    },
];

/// True when `rule` may appear in a `pallas: allow(...)` directive.
pub fn is_allowable(rule: &str) -> bool {
    RULES.iter().any(|r| r.name == rule)
}

/// Hooks every non-test `SchedPolicy` impl must define.
/// `on_node_suspected` joined the list with the degraded control
/// plane: under heartbeat detection it replaces `on_node_fail` as the
/// instant a failure becomes visible, so a policy that handles one but
/// not the other silently strands requeued work in detection runs.
const REQUIRED_HOOKS: &[&str] = &[
    "on_node_fail",
    "on_node_suspected",
    "on_node_drain",
    "on_node_recover",
];

fn deterministic_scope(rel: &str) -> bool {
    const DIRS: &[&str] = &[
        "src/sim/",
        "src/sched/",
        "src/cluster/",
        "src/multilevel/",
        "src/workload/",
    ];
    DIRS.iter().any(|d| rel.starts_with(d))
}

fn clock_exempt(rel: &str) -> bool {
    // The realtime executor is *about* wall time; the scale harness
    // measures wall-time-vs-n exponents. Everything else is simulated.
    rel == "src/exec/realtime.rs" || rel == "src/harness/scale.rs"
}

fn thread_exempt(rel: &str) -> bool {
    // parallel.rs and sharded.rs own the deterministic merges; the
    // exec backends run real work on real threads by design.
    rel == "src/harness/parallel.rs"
        || rel == "src/sched/sharded.rs"
        || rel.starts_with("src/exec/")
}

fn ident(t: &Tok) -> Option<&str> {
    match &t.kind {
        TokKind::Ident(s) => Some(s.as_str()),
        _ => None,
    }
}

fn is_punct(t: Option<&Tok>, c: char) -> bool {
    matches!(t.map(|t| &t.kind), Some(TokKind::Punct(p)) if *p == c)
}

/// Run every per-file token rule against one lexed file. `rel` is the
/// `rust/`-relative path with forward slashes; files outside `src/`
/// produce no token diagnostics (tests are checked by the cross-file
/// rules only).
pub fn token_rules(rel: &str, lexed: &Lexed) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if !rel.starts_with("src/") {
        return out;
    }
    let toks = &lexed.tokens;
    for (idx, t) in toks.iter().enumerate() {
        let name = match ident(t) {
            Some(s) => s,
            None => continue,
        };
        match name {
            "HashMap" | "HashSet" if deterministic_scope(rel) => {
                out.push(Diagnostic::new(
                    rel,
                    t.line,
                    RULE_HASH_ITERATION,
                    format!(
                        "`{name}` in a deterministic module: iteration order is seeded \
                         per process — use `BTreeMap`/`BTreeSet` or a sorted drain"
                    ),
                ));
            }
            "partial_cmp" => {
                let is_definition = idx > 0 && ident(&toks[idx - 1]) == Some("fn");
                if !is_definition {
                    out.push(Diagnostic::new(
                        rel,
                        t.line,
                        RULE_FLOAT_ORD,
                        "`partial_cmp` float ordering: NaN is incomparable, so sorts \
                         panic or misorder — use `total_cmp`"
                            .to_string(),
                    ));
                }
            }
            "Instant" | "SystemTime" if !clock_exempt(rel) => {
                out.push(Diagnostic::new(
                    rel,
                    t.line,
                    RULE_WALL_CLOCK,
                    format!(
                        "`{name}` outside the realtime executor / timing harness: \
                         simulated paths must be pure in virtual time"
                    ),
                ));
            }
            "RandomState" | "from_entropy" | "getrandom" | "thread_rng" | "OsRng"
                if !clock_exempt(rel) =>
            {
                out.push(Diagnostic::new(
                    rel,
                    t.line,
                    RULE_OS_ENTROPY,
                    format!(
                        "`{name}` draws OS entropy: seeds must come from the \
                         experiment config so runs replay bit-for-bit"
                    ),
                ));
            }
            "thread" if !thread_exempt(rel) => {
                let method = if is_punct(toks.get(idx + 1), ':') && is_punct(toks.get(idx + 2), ':')
                {
                    toks.get(idx + 3).and_then(ident)
                } else {
                    None
                };
                if let Some(m @ ("spawn" | "scope" | "Builder")) = method {
                    out.push(Diagnostic::new(
                        rel,
                        t.line,
                        RULE_THREAD_SPAWN,
                        format!(
                            "`thread::{m}` outside harness/parallel.rs and \
                             sched/sharded.rs: ad-hoc threading breaks --jobs \
                             bit-identity — use the deterministic-merge helpers"
                        ),
                    ));
                }
            }
            _ => {}
        }
    }
    out.extend(fault_hook_rule(rel, lexed));
    out
}

/// Index of the `}` matching the `{` at `open`, or `toks.len()` if the
/// file is truncated.
fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open;
    while j < toks.len() {
        match toks[j].kind {
            TokKind::Punct('{') => depth += 1,
            TokKind::Punct('}') => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    toks.len()
}

/// Token-index ranges of `mod tests { .. }` / `#[cfg(test)] mod x { .. }`
/// blocks — policy impls inside them are harness scaffolding, not
/// production policies.
fn test_mod_ranges(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if ident(&toks[i]) == Some("mod") {
            let named = matches!(&toks[i + 1].kind, TokKind::Ident(_));
            if named && matches!(toks[i + 2].kind, TokKind::Punct('{')) {
                let test_named = ident(&toks[i + 1]) == Some("tests");
                let cfg_test = i >= 7 && is_cfg_test(&toks[i - 7..i]);
                if test_named || cfg_test {
                    let end = match_brace(toks, i + 2);
                    out.push((i, end));
                    i = end;
                    continue;
                }
            }
        }
        i += 1;
    }
    out
}

fn is_cfg_test(w: &[Tok]) -> bool {
    w.len() == 7
        && matches!(w[0].kind, TokKind::Punct('#'))
        && matches!(w[1].kind, TokKind::Punct('['))
        && ident(&w[2]) == Some("cfg")
        && matches!(w[3].kind, TokKind::Punct('('))
        && ident(&w[4]) == Some("test")
        && matches!(w[5].kind, TokKind::Punct(')'))
        && matches!(w[6].kind, TokKind::Punct(']'))
}

/// Enforce that every `impl .. SchedPolicy for ..` outside test modules
/// defines all of [`REQUIRED_HOOKS`].
fn fault_hook_rule(rel: &str, lexed: &Lexed) -> Vec<Diagnostic> {
    let toks = &lexed.tokens;
    let skip = test_mod_ranges(toks);
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if ident(&toks[i]) != Some("impl") || skip.iter().any(|&(a, b)| i >= a && i <= b) {
            i += 1;
            continue;
        }
        let impl_line = toks[i].line;
        let mut j = i + 1;
        // Skip `<..>` generic params; a `>` preceded by `-` is the arrow
        // of an `Fn() -> T` bound, not a closer.
        if matches!(toks.get(j).map(|t| &t.kind), Some(TokKind::Punct('<'))) {
            let mut depth = 0i32;
            while j < toks.len() {
                match toks[j].kind {
                    TokKind::Punct('<') => depth += 1,
                    TokKind::Punct('>') => {
                        let arrow = matches!(toks[j - 1].kind, TokKind::Punct('-'));
                        if !arrow {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
        }
        let header_start = j;
        while j < toks.len() && !matches!(toks[j].kind, TokKind::Punct('{') | TokKind::Punct(';')) {
            j += 1;
        }
        let header = &toks[header_start..j.min(toks.len())];
        let is_policy_impl = header
            .windows(2)
            .any(|w| ident(&w[0]) == Some("SchedPolicy") && ident(&w[1]) == Some("for"));
        if !is_policy_impl || j >= toks.len() || !matches!(toks[j].kind, TokKind::Punct('{')) {
            i += 1;
            continue;
        }
        let end = match_brace(toks, j);
        let mut fns: Vec<&str> = Vec::new();
        let mut depth = 0i32;
        for k in j..=end.min(toks.len() - 1) {
            match &toks[k].kind {
                TokKind::Punct('{') => depth += 1,
                TokKind::Punct('}') => depth -= 1,
                TokKind::Ident(s) if s == "fn" && depth == 1 => {
                    if let Some(name) = toks.get(k + 1).and_then(ident) {
                        fns.push(name);
                    }
                }
                _ => {}
            }
        }
        let missing: Vec<&str> = REQUIRED_HOOKS
            .iter()
            .copied()
            .filter(|h| !fns.contains(h))
            .collect();
        if !missing.is_empty() {
            out.push(Diagnostic::new(
                rel,
                impl_line,
                RULE_FAULT_HOOKS,
                format!(
                    "`SchedPolicy` impl is missing fault hooks: {} — every policy \
                     must state its fail/suspected/drain/recover behaviour (an \
                     explicit no-op with a comment counts)",
                    missing.join(", ")
                ),
            ));
        }
        i = end + 1;
    }
    out
}

/// Golden-snapshot filenames a test file references via the repo's
/// `.join("golden").join("<name>")` convention, with the line of each.
pub fn golden_refs(lexed: &Lexed) -> Vec<(String, u32)> {
    let t = &lexed.tokens;
    let mut out = Vec::new();
    for i in 0..t.len().saturating_sub(7) {
        let shape = ident(&t[i]) == Some("join")
            && matches!(t[i + 1].kind, TokKind::Punct('('))
            && matches!(&t[i + 2].kind, TokKind::Str(s) if s == "golden")
            && matches!(t[i + 3].kind, TokKind::Punct(')'))
            && matches!(t[i + 4].kind, TokKind::Punct('.'))
            && ident(&t[i + 5]) == Some("join")
            && matches!(t[i + 6].kind, TokKind::Punct('('));
        if shape {
            if let TokKind::Str(f) = &t[i + 7].kind {
                out.push((f.clone(), t[i + 7].line));
            }
        }
    }
    out
}

/// True when the file defines the repo's self-seeding snapshot helper
/// (`fn assert_snapshot`): such tests create a missing golden on first
/// run, so absence on disk is the documented bootstrap state, not a bug.
pub fn defines_assert_snapshot(lexed: &Lexed) -> bool {
    lexed
        .tokens
        .windows(2)
        .any(|w| ident(&w[0]) == Some("fn") && ident(&w[1]) == Some("assert_snapshot"))
}

/// Extract the string entries of `EXPERIMENT_NAMES` from the lexed
/// `config/schema.rs`, plus the line the registry starts on.
pub fn experiment_names(lexed: &Lexed) -> Option<(Vec<String>, u32)> {
    let t = &lexed.tokens;
    let at = t.iter().position(|tok| ident(tok) == Some("EXPERIMENT_NAMES"))?;
    let line = t[at].line;
    // Skip past the `=` so the `[` of the `&[&str]` type annotation is
    // not mistaken for the initializer list.
    let eq = t[at..]
        .iter()
        .position(|tok| matches!(tok.kind, TokKind::Punct('=')))?
        + at;
    let open = t[eq..].iter().position(|tok| matches!(tok.kind, TokKind::Punct('[')))? + eq;
    let mut names = Vec::new();
    for tok in &t[open + 1..] {
        match &tok.kind {
            TokKind::Punct(']') => break,
            TokKind::Str(s) => names.push(s.clone()),
            _ => {}
        }
    }
    Some((names, line))
}

/// All string literals in a file (used to check `main.rs` for CLI arms
/// and validate coverage).
pub fn string_literals(lexed: &Lexed) -> Vec<&str> {
    lexed
        .tokens
        .iter()
        .filter_map(|t| match &t.kind {
            TokKind::Str(s) => Some(s.as_str()),
            _ => None,
        })
        .collect()
}
