//! `pallas-lint` — a zero-dependency static-analysis pass that enforces
//! the repo's bit-identity determinism contract.
//!
//! Every result this simulator reports rests on one promise: runs are
//! bit-identical across `--jobs` worker counts, warm-vs-fresh scratch,
//! and shard counts. The differential tests catch violations *after*
//! they are written; this linter rejects the hazard patterns at review
//! time — nondeterministic hash iteration, NaN-unsafe float ordering,
//! wall-clock reads in simulated paths, OS entropy, ad-hoc threading —
//! plus cross-file structural drift (unwired experiments, missing
//! fault hooks, dangling golden snapshots).
//!
//! Suppressions are spelled `// pallas: allow(rule-name) — <reason>`
//! on (or directly above) the offending line. The reason is mandatory,
//! and an allow that no longer suppresses anything is itself an error,
//! so annotations cannot rot.
//!
//! Entry points: [`lint_tree`] walks a crate root (`src/**` plus
//! top-level `tests/*.rs`); [`lint_source`] lints one in-memory file
//! under a caller-chosen relative path (this is what the fixture tests
//! use). The `pallas-lint` binary and `tests/lint_clean.rs` both call
//! [`lint_tree`].

pub mod lexer;
pub mod rules;

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

pub use lexer::{lex, Comment, Lexed, Tok, TokKind};
pub use rules::{is_allowable, RuleInfo, RULES};

/// One finding, pointing at a `file:line`.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    /// Path relative to the crate root (forward slashes).
    pub file: String,
    /// 1-based line.
    pub line: u32,
    /// Rule name (one of the [`rules`] constants).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub msg: String,
}

impl Diagnostic {
    /// Build a diagnostic for `file:line` under `rule`.
    pub fn new(file: &str, line: u32, rule: &'static str, msg: String) -> Self {
        Diagnostic {
            file: file.to_string(),
            line,
            rule,
            msg,
        }
    }
}

/// Result of linting a single source string via [`lint_source`].
#[derive(Debug)]
pub struct FileReport {
    /// Post-suppression diagnostics, including allow-machinery errors.
    pub diagnostics: Vec<Diagnostic>,
    /// Pre-suppression match counts per suppressible rule.
    pub rule_hits: Vec<(&'static str, usize)>,
    /// Diagnostics silenced by well-formed allows.
    pub suppressed: usize,
}

/// Result of linting a whole crate via [`lint_tree`].
#[derive(Debug)]
pub struct LintReport {
    /// Post-suppression diagnostics, sorted by `(file, line, rule)`.
    pub diagnostics: Vec<Diagnostic>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Diagnostics silenced by well-formed allows.
    pub suppressed: usize,
    /// Pre-suppression match counts per suppressible rule, in
    /// [`RULES`] order (zeros included, so the shape is stable).
    pub rule_hits: Vec<(&'static str, usize)>,
}

impl LintReport {
    /// True when the tree carries no diagnostics at all.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Human-readable rendering: one `file:line: [rule] msg` per
    /// diagnostic plus a summary line.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for d in &self.diagnostics {
            s.push_str(&format!("{}:{}: [{}] {}\n", d.file, d.line, d.rule, d.msg));
        }
        if self.is_clean() {
            s.push_str(&format!(
                "pallas-lint: clean — {} files scanned, {} suppression(s) honoured\n",
                self.files_scanned,
                self.suppressed
            ));
        } else {
            s.push_str(&format!(
                "pallas-lint: {} diagnostic(s) across {} files ({} suppressed)\n",
                self.diagnostics.len(),
                self.files_scanned,
                self.suppressed
            ));
        }
        s
    }

    /// Machine-readable rendering. `wall_ms` is the caller-measured
    /// lint wall time, when available.
    pub fn to_json(&self, wall_ms: Option<f64>) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"clean\": {},\n", self.is_clean()));
        s.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        s.push_str(&format!("  \"suppressed\": {},\n", self.suppressed));
        if let Some(ms) = wall_ms {
            s.push_str(&format!("  \"lint_wall_ms\": {ms:.2},\n"));
        }
        let hits: Vec<String> = self
            .rule_hits
            .iter()
            .map(|(name, n)| format!("\"{name}\": {n}"))
            .collect();
        s.push_str(&format!("  \"rule_hits\": {{{}}},\n", hits.join(", ")));
        let diags: Vec<String> = self
            .diagnostics
            .iter()
            .map(|d| {
                format!(
                    "    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
                    json_escape(&d.file),
                    d.line,
                    d.rule,
                    json_escape(&d.msg)
                )
            })
            .collect();
        if diags.is_empty() {
            s.push_str("  \"diagnostics\": []\n");
        } else {
            s.push_str(&format!("  \"diagnostics\": [\n{}\n  ]\n", diags.join(",\n")));
        }
        s.push('}');
        s
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// A parsed, well-formed `pallas: allow(rule) — reason` directive.
struct Allow {
    rule: String,
    /// Line whose diagnostics this allow suppresses.
    target_line: u32,
    /// Line the comment itself sits on (anchor for stale reports).
    comment_line: u32,
    used: bool,
}

/// Parse every `pallas:` directive in a file's comments. Malformed
/// directives (unknown rule, missing reason, unparseable) become meta
/// diagnostics and do not suppress anything.
fn parse_allows(rel: &str, lexed: &Lexed) -> (Vec<Allow>, Vec<Diagnostic>) {
    let mut allows = Vec::new();
    let mut meta = Vec::new();
    for c in &lexed.comments {
        // Doc comments reach us with a leading `/` or `!` still attached.
        let text = c.text.trim_start_matches(['/', '!']).trim();
        let rest = match text.strip_prefix("pallas:") {
            Some(r) => r.trim(),
            None => continue,
        };
        let parsed = rest
            .strip_prefix("allow")
            .map(str::trim_start)
            .and_then(|r| r.strip_prefix('('))
            .and_then(|r| r.split_once(')'));
        let (rule_raw, tail) = match parsed {
            Some(p) => p,
            None => {
                meta.push(Diagnostic::new(
                    rel,
                    c.line,
                    rules::RULE_UNKNOWN_RULE,
                    format!(
                        "unrecognized pallas directive `{rest}` — the grammar is \
                         `pallas: allow(<rule>) — <reason>`"
                    ),
                ));
                continue;
            }
        };
        let rule = rule_raw.trim().to_string();
        if !rules::is_allowable(&rule) {
            let known: Vec<&str> = RULES.iter().map(|r| r.name).collect();
            meta.push(Diagnostic::new(
                rel,
                c.line,
                rules::RULE_UNKNOWN_RULE,
                format!(
                    "`allow({rule})` names no suppressible rule (known: {})",
                    known.join(", ")
                ),
            ));
            continue;
        }
        let reason = tail.trim_start().trim_start_matches(['—', '–', '-', ':']).trim();
        if reason.is_empty() {
            meta.push(Diagnostic::new(
                rel,
                c.line,
                rules::RULE_ALLOW_MISSING_REASON,
                format!(
                    "`allow({rule})` carries no reason — write \
                     `pallas: allow({rule}) — <why this is safe here>`"
                ),
            ));
            continue;
        }
        // A trailing comment annotates its own line; a leading comment
        // annotates the next line that has code on it.
        let target_line = if c.trailing {
            c.line
        } else {
            lexed
                .tokens
                .iter()
                .find(|t| t.line > c.line)
                .map(|t| t.line)
                .unwrap_or(c.line)
        };
        allows.push(Allow {
            rule,
            target_line,
            comment_line: c.line,
            used: false,
        });
    }
    (allows, meta)
}

/// Apply allows to raw diagnostics; unused allows become stale-allow
/// errors. Returns the surviving diagnostics (raw + meta) and the
/// suppression count.
fn apply_allows(
    rel: &str,
    raw: Vec<Diagnostic>,
    mut allows: Vec<Allow>,
    meta: Vec<Diagnostic>,
) -> (Vec<Diagnostic>, usize) {
    let mut kept = Vec::new();
    let mut suppressed = 0usize;
    for d in raw {
        let hit = allows
            .iter_mut()
            .find(|a| a.rule == d.rule && a.target_line == d.line);
        match hit {
            Some(a) => {
                a.used = true;
                suppressed += 1;
            }
            None => kept.push(d),
        }
    }
    for a in &allows {
        if !a.used {
            kept.push(Diagnostic::new(
                rel,
                a.comment_line,
                rules::RULE_STALE_ALLOW,
                format!(
                    "stale `pallas: allow({})` — nothing on line {} trips that rule \
                     any more; remove the annotation",
                    a.rule, a.target_line
                ),
            ));
        }
    }
    kept.extend(meta);
    (kept, suppressed)
}

fn zero_hits() -> Vec<(&'static str, usize)> {
    RULES.iter().map(|r| (r.name, 0usize)).collect()
}

fn count_hits(counts: &mut [(&'static str, usize)], diags: &[Diagnostic]) {
    for d in diags {
        if let Some(e) = counts.iter_mut().find(|(n, _)| *n == d.rule) {
            e.1 += 1;
        }
    }
}

/// Lint one in-memory source file as if it lived at `rel` (a
/// `rust/`-relative forward-slash path such as `src/sim/engine.rs`).
/// Cross-file rules (golden snapshots, experiment wiring) need a real
/// tree and only run under [`lint_tree`].
pub fn lint_source(rel: &str, source: &str) -> FileReport {
    let lexed = lexer::lex(source);
    let raw = rules::token_rules(rel, &lexed);
    let (allows, meta) = parse_allows(rel, &lexed);
    let mut rule_hits = zero_hits();
    count_hits(&mut rule_hits, &raw);
    let (mut diagnostics, suppressed) = apply_allows(rel, raw, allows, meta);
    diagnostics.sort();
    FileReport {
        diagnostics,
        rule_hits,
        suppressed,
    }
}

struct FileCtx {
    rel: String,
    lexed: Lexed,
    raw: Vec<Diagnostic>,
    allows: Vec<Allow>,
    meta: Vec<Diagnostic>,
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    // Sorted walk, so diagnostics and timings are order-stable.
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_of(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .to_string_lossy()
        .replace('\\', "/")
}

/// The `golden-exists` rule: every snapshot a test references must be
/// on disk (unless the test self-seeds via `fn assert_snapshot`, the
/// repo's bootstrap convention), and every file under `tests/golden/`
/// must be referenced by some test (orphans are renames or typos).
fn golden_rule(root: &Path, ctxs: &mut [FileCtx], extra: &mut Vec<Diagnostic>) {
    let mut referenced: BTreeSet<String> = BTreeSet::new();
    for ctx in ctxs.iter_mut() {
        if !ctx.rel.starts_with("tests/") {
            continue;
        }
        let refs = rules::golden_refs(&ctx.lexed);
        if refs.is_empty() {
            continue;
        }
        let self_seeding = rules::defines_assert_snapshot(&ctx.lexed);
        for (fname, line) in refs {
            referenced.insert(fname.clone());
            let on_disk = root.join("tests/golden").join(&fname).is_file();
            if !on_disk && !self_seeding {
                ctx.raw.push(Diagnostic::new(
                    &ctx.rel,
                    line,
                    rules::RULE_GOLDEN_EXISTS,
                    format!(
                        "referenced snapshot tests/golden/{fname} is missing and this \
                         test has no self-seeding `assert_snapshot` helper"
                    ),
                ));
            }
        }
    }
    let gdir = root.join("tests/golden");
    if gdir.is_dir() {
        let mut names: Vec<String> = match fs::read_dir(&gdir) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter(|e| e.path().is_file())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .collect(),
            Err(_) => Vec::new(),
        };
        names.sort();
        for n in names {
            if !referenced.contains(&n) {
                extra.push(Diagnostic::new(
                    &format!("tests/golden/{n}"),
                    1,
                    rules::RULE_GOLDEN_EXISTS,
                    "snapshot is not referenced by any test — stale file or typo'd \
                     reference"
                        .to_string(),
                ));
            }
        }
    }
}

/// The `experiment-wiring` rule: every name in the
/// `config::EXPERIMENT_NAMES` registry must have a CLI dispatch arm and
/// a `validate` shape-check in `src/main.rs`, and a row in the README
/// `## EXPERIMENTS` table. Skipped silently when the tree has no
/// `src/config/schema.rs` + `src/main.rs` pair (synthetic test roots).
fn wiring_rule(root: &Path, ctxs: &[FileCtx], extra: &mut Vec<Diagnostic>) {
    let schema = ctxs.iter().find(|c| c.rel == "src/config/schema.rs");
    let main = ctxs.iter().find(|c| c.rel == "src/main.rs");
    let (schema, main) = match (schema, main) {
        (Some(s), Some(m)) => (s, m),
        _ => return,
    };
    let (names, _reg_line) = match rules::experiment_names(&schema.lexed) {
        Some(v) => v,
        None => {
            extra.push(Diagnostic::new(
                "src/config/schema.rs",
                1,
                rules::RULE_EXPERIMENT_WIRING,
                "no EXPERIMENT_NAMES registry found — the wiring rule cross-checks \
                 CLI, validate, and README against it"
                    .to_string(),
            ));
            return;
        }
    };
    let lits = rules::string_literals(&main.lexed);
    let readme = root
        .parent()
        .map(|p| p.join("README.md"))
        .and_then(|p| fs::read_to_string(p).ok());
    let section = readme.as_deref().and_then(experiments_section);
    for name in &names {
        if !lits.contains(&name.as_str()) {
            extra.push(Diagnostic::new(
                "src/main.rs",
                1,
                rules::RULE_EXPERIMENT_WIRING,
                format!("experiment `{name}` has no CLI dispatch arm in main.rs"),
            ));
        }
        let shapes = format!("{name} shapes");
        if !lits.iter().any(|l| l.contains(shapes.as_str())) {
            extra.push(Diagnostic::new(
                "src/main.rs",
                1,
                rules::RULE_EXPERIMENT_WIRING,
                format!(
                    "experiment `{name}` is not covered by `validate` (no \
                     \"{name} shapes\" check in main.rs)"
                ),
            ));
        }
        if let Some((sec_line, sec)) = &section {
            if !sec.contains(&format!("`{name}`")) {
                extra.push(Diagnostic::new(
                    "README.md",
                    *sec_line,
                    rules::RULE_EXPERIMENT_WIRING,
                    format!("experiment `{name}` has no row in the README EXPERIMENTS table"),
                ));
            }
        }
    }
    if section.is_none() {
        extra.push(Diagnostic::new(
            "README.md",
            1,
            rules::RULE_EXPERIMENT_WIRING,
            "README has no `## EXPERIMENTS` section to cross-check experiment names \
             against"
                .to_string(),
        ));
    }
}

/// Body of the README `## EXPERIMENTS` section (up to the next `## `
/// heading) and the 1-based line of its heading.
fn experiments_section(readme: &str) -> Option<(u32, String)> {
    let mut body = String::new();
    let mut in_sec = false;
    let mut sec_line = 0u32;
    for (i, l) in readme.lines().enumerate() {
        if l.starts_with("## ") {
            if in_sec {
                break;
            }
            if l.contains("EXPERIMENTS") {
                in_sec = true;
                sec_line = i as u32 + 1;
            }
            continue;
        }
        if in_sec {
            body.push_str(l);
            body.push('\n');
        }
    }
    if in_sec {
        Some((sec_line, body))
    } else {
        None
    }
}

/// Lint a crate rooted at `root` (the directory holding `src/`): all of
/// `src/**/*.rs` recursively plus top-level `tests/*.rs`, then the
/// cross-file rules. Returns `Err` only for I/O-level failures (missing
/// `src/`, unreadable file) — findings are diagnostics, not errors.
pub fn lint_tree(root: &Path) -> Result<LintReport, String> {
    let src_dir = root.join("src");
    if !src_dir.is_dir() {
        return Err(format!("no src/ directory under {}", root.display()));
    }
    let mut files = Vec::new();
    walk_rs(&src_dir, &mut files)?;
    let tests_dir = root.join("tests");
    if tests_dir.is_dir() {
        let rd = fs::read_dir(&tests_dir).map_err(|e| format!("read tests/: {e}"))?;
        let mut tests: Vec<PathBuf> = rd
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.is_file() && p.extension().and_then(|e| e.to_str()) == Some("rs"))
            .collect();
        tests.sort();
        files.extend(tests);
    }

    let mut ctxs: Vec<FileCtx> = Vec::with_capacity(files.len());
    for p in &files {
        let rel = rel_of(root, p);
        let source = fs::read_to_string(p).map_err(|e| format!("read {}: {e}", p.display()))?;
        let lexed = lexer::lex(&source);
        let raw = rules::token_rules(&rel, &lexed);
        let (allows, meta) = parse_allows(&rel, &lexed);
        ctxs.push(FileCtx {
            rel,
            lexed,
            raw,
            allows,
            meta,
        });
    }

    let mut extra: Vec<Diagnostic> = Vec::new();
    golden_rule(root, &mut ctxs, &mut extra);
    wiring_rule(root, &ctxs, &mut extra);

    let mut rule_hits = zero_hits();
    let mut diagnostics = Vec::new();
    let mut suppressed = 0usize;
    for ctx in ctxs {
        count_hits(&mut rule_hits, &ctx.raw);
        let (d, s) = apply_allows(&ctx.rel, ctx.raw, ctx.allows, ctx.meta);
        diagnostics.extend(d);
        suppressed += s;
    }
    count_hits(&mut rule_hits, &extra);
    diagnostics.extend(extra);
    diagnostics.sort();

    Ok(LintReport {
        diagnostics,
        files_scanned: files.len(),
        suppressed,
        rule_hits,
    })
}
