//! Scheduler simulators.
//!
//! One mechanistic model per scheduler family measured in the paper:
//!
//! * [`centralized`] — single central daemon with periodic scheduling
//!   cycles; instantiated as **Slurm-like** and **Grid-Engine-like**
//!   (traditional/new HPC families).
//! * [`mesos`] — two-level scheduling: allocator publishes resource
//!   offers on an offer cycle; a framework accepts them and launches
//!   tasks through per-task executors (open-source big data family).
//! * [`yarn`] — ResourceManager + per-job ApplicationMaster: every job
//!   array element pays an AM container launch before its task
//!   container runs (the paper: "Hadoop YARN has greater overhead for
//!   each job, including launching an application master process for
//!   each job").
//! * [`sparrow`] — decentralized power-of-two-choices placement
//!   (research family).
//! * [`batchq`] — batch-queue policies (FCFS / priority / fairshare /
//!   EASY backfill) over rigid parallel jobs.
//! * [`ideal`] — zero-overhead FIFO used as a correctness reference
//!   (T_total == ceil(N/P)·t exactly, U == 1).
//! * [`sharded`] — wrappers, not backends: [`ShardedSim`] decomposes a
//!   run across disjoint node groups (parallelism *within* one giant
//!   run) and [`NodeGranularSim`] switches the slot pool to whole-node
//!   allocation (arXiv 2108.11359).
//!
//! Since the kernel refactor every backend is a
//! [`crate::sim::SchedPolicy`]: the event loop, slot packing, gang
//! dispatch, dependency gating and result assembly live once in
//! [`crate::sim::Kernel`]; each file here contributes only mechanism
//! parameters and policy pricing. A new policy is a ~100-line file, not
//! a ~300-line fork of the loop.
//!
//! The power law ΔT = t_s·n^α_s is *not* hard-coded anywhere: it
//! emerges from daemon queueing, cycle waits and per-task overheads.
//! Parameter presets calibrated against the paper's Table 9/10 live in
//! [`calibration`].

pub mod batchq;
pub mod calibration;
pub mod centralized;
pub mod combinators;
pub mod ideal;
pub mod mesos;
mod result;
pub mod sharded;
pub mod sparrow;
pub mod yarn;

pub use result::{ExecSpan, RunOptions, RunResult};
pub use sharded::{NodeGranularSim, ShardedSim};

use crate::cluster::ClusterSpec;
use crate::config::SchedulerChoice;
use crate::sim::SchedPolicy;
pub use crate::sim::SimScratch;
use crate::workload::Workload;

/// A scheduler simulator: runs a workload on a cluster in virtual time.
pub trait Scheduler: Send + Sync {
    /// Display name ("Slurm", "Mesos", ...).
    fn name(&self) -> &'static str;

    /// Construct this backend's [`SchedPolicy`] for one trial, if the
    /// backend is kernel-policy-driven. The policy combinators
    /// ([`combinators::Ordered`], [`combinators::Preemptive`]) wrap the
    /// returned object and drive it through [`crate::sim::Kernel`]
    /// themselves. `None` for wrapper schedulers that are not a single
    /// kernel policy (e.g. multilevel aggregation).
    fn make_policy<'a>(&'a self, _seed: u64) -> Option<Box<dyn SchedPolicy + 'a>> {
        None
    }

    /// Simulate one trial with a fresh [`SimScratch`] (allocating).
    /// `seed` controls all stochastic jitter; equal seeds give
    /// bit-identical results.
    fn run(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
    ) -> RunResult {
        self.run_with_scratch(workload, cluster, seed, options, &mut SimScratch::new())
    }

    /// Simulate one trial reusing `scratch`'s warm buffers (the
    /// zero-allocation path for sweeps). The result is bit-identical to
    /// [`Scheduler::run`] regardless of what the scratch previously
    /// executed.
    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult;

    /// Rough lower-bound estimate of the simulated makespan (virtual
    /// seconds), used by the harness to skip prohibitive runs the way
    /// the paper abandoned the YARN rapid-task trials.
    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        let p = cluster.total_cores() as f64;
        workload.total_work() / p
    }
}

/// Construct a simulator whose central-daemon costs are scaled ×`k`,
/// preserving experiment *shape* on a cluster scaled down ÷`k`: the
/// dimensionless saturation ratio P·(per-task daemon time)/t — which
/// controls where the Figure 4 knee falls — is invariant under
/// (P/k, cost·k). Used by `--quick` runs and CI tests.
pub fn make_scheduler_scaled(choice: SchedulerChoice, k: u32) -> Box<dyn Scheduler> {
    let k = k.max(1) as f64;
    match choice {
        SchedulerChoice::Slurm | SchedulerChoice::GridEngine => {
            let mut p = if choice == SchedulerChoice::Slurm {
                calibration::slurm_params()
            } else {
                calibration::gridengine_params()
            };
            p.sched_cost_per_task *= k;
            p.complete_cost_per_task *= k;
            p.scan_cost_per_pending *= k;
            p.submit_cost_per_task *= k;
            Box::new(centralized::CentralizedSim::new(p))
        }
        SchedulerChoice::Mesos => {
            let mut p = calibration::mesos_params();
            p.offer_batch_cost *= k;
            p.launch_cost_per_task *= k;
            p.complete_cost_per_task *= k;
            Box::new(mesos::MesosSim::new(p))
        }
        SchedulerChoice::Yarn => {
            let mut p = calibration::yarn_params();
            p.rm_cost_per_app *= k;
            p.complete_cost_per_app *= k;
            Box::new(yarn::YarnSim::new(p))
        }
        SchedulerChoice::Sparrow => {
            // No central daemon to saturate; scale the per-task
            // overheads so ΔT per task keeps its proportion.
            let d = sparrow::SparrowParams::default();
            Box::new(sparrow::SparrowSim::new(sparrow::SparrowParams {
                probe_rtt: d.probe_rtt * k,
                launch_overhead: d.launch_overhead * k,
                ..d
            }))
        }
        SchedulerChoice::IdealFifo => Box::new(ideal::IdealFifo),
    }
}

/// Construct the calibrated simulator for a scheduler choice.
pub fn make_scheduler(choice: SchedulerChoice) -> Box<dyn Scheduler> {
    match choice {
        SchedulerChoice::Slurm => Box::new(centralized::CentralizedSim::new(
            calibration::slurm_params(),
        )),
        SchedulerChoice::GridEngine => Box::new(centralized::CentralizedSim::new(
            calibration::gridengine_params(),
        )),
        SchedulerChoice::Mesos => Box::new(mesos::MesosSim::new(calibration::mesos_params())),
        SchedulerChoice::Yarn => Box::new(yarn::YarnSim::new(calibration::yarn_params())),
        SchedulerChoice::Sparrow => Box::new(sparrow::SparrowSim::new(
            sparrow::SparrowParams::default(),
        )),
        SchedulerChoice::IdealFifo => Box::new(ideal::IdealFifo),
    }
}
