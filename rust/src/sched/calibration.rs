//! Calibrated mechanism parameters for the four measured schedulers.
//!
//! The *mechanisms* live in `centralized.rs` / `mesos.rs` / `yarn.rs`;
//! the constants below are chosen so the simulated Table 9 runtimes and
//! the resulting Table 10 fits land near the paper's measurements:
//!
//! | Scheduler   | paper t_s | paper α_s | Table 9 runtimes (rapid/fast/medium/long, s) |
//! |-------------|-----------|-----------|----------------------------------------------|
//! | Slurm       | 2.2       | 1.3       | ~2784 / ~610 / ~271 / ~284                   |
//! | Grid Engine | 2.8       | 1.3       | ~3071 / ~626 / ~278 / ~277                   |
//! | Mesos       | 3.4       | 1.1       | ~1794 / ~366 / ~280 / ~306                   |
//! | Hadoop YARN | 33        | 1.0       | (abandoned) / ~1840 / ~487 / ~378            |
//!
//! Key anchors derived from the paper's own data:
//! * central-daemon steady throughput = N / T_total on the rapid set:
//!   Slurm ≈ 121/s (→ 8.2 ms/task), GE ≈ 110/s (→ 9.1 ms/task),
//!   Mesos ≈ 188/s (→ 5.3 ms/task);
//! * YARN per-application AM startup ≈ 31 s (fast: 48·(5+~33) ≈ 1824 s);
//! * trial scatter ≈ 0.5 % (Table 9 triples) → jitter CVs of a few %.

use super::centralized::CentralizedParams;
use super::mesos::MesosParams;
use super::yarn::YarnParams;

/// Slurm 15.08-like parameters (sched/builtin, select/cons_res,
/// proctrack/cgroup — the paper's §5.1 configuration).
pub fn slurm_params() -> CentralizedParams {
    CentralizedParams {
        name: "Slurm",
        cycle_interval: 1.0,
        submit_cost_base: 0.5,
        submit_cost_per_task: 20e-6,
        submit_cost_job: 0.05,
        sched_cost_per_task: 4.0e-3,
        complete_cost_per_task: 4.2e-3,
        scan_cost_per_pending: 2.0e-6,
        scan_cap: 10_000,
        launch_mean: 0.10,
        launch_cv: 0.30,
        teardown_mean: 0.10,
        rpc: 2.0e-4,
        jitter_cv: 0.05,
    }
}

/// Son of Grid Engine 8.1.8-like parameters (high-throughput config:
/// reduced scheduling interval, flat fair-share off).
pub fn gridengine_params() -> CentralizedParams {
    CentralizedParams {
        name: "GridEngine",
        cycle_interval: 2.0,
        submit_cost_base: 0.8,
        submit_cost_per_task: 25e-6,
        submit_cost_job: 0.06,
        sched_cost_per_task: 4.4e-3,
        complete_cost_per_task: 4.6e-3,
        scan_cost_per_pending: 3.0e-6,
        scan_cap: 10_000,
        launch_mean: 0.15,
        launch_cv: 0.30,
        teardown_mean: 0.15,
        rpc: 2.0e-4,
        jitter_cv: 0.05,
    }
}

/// Mesos 0.25-like parameters (single master, one framework, command
/// executor per task, 1 s allocation interval).
pub fn mesos_params() -> MesosParams {
    MesosParams {
        name: "Mesos",
        offer_interval: 1.0,
        offer_batch_cost: 2.0e-3,
        launch_cost_per_task: 2.8e-3,
        complete_cost_per_task: 2.5e-3,
        framework_latency: 0.05,
        executor_startup_mean: 1.5,
        executor_startup_cv: 0.25,
        agent_teardown: 0.10,
        rpc: 2.0e-4,
        jitter_cv: 0.05,
    }
}

/// Hadoop YARN 2.7.1-like parameters (one RM, NM heartbeats, one
/// application — and hence one ApplicationMaster — per array element).
pub fn yarn_params() -> YarnParams {
    YarnParams {
        name: "Hadoop YARN",
        rm_cost_per_app: 5e-3,
        complete_cost_per_app: 5e-3,
        nm_heartbeat: 1.0,
        am_startup_mean: 31.0,
        am_startup_cv: 0.03,
        container_launch: 0.8,
        teardown: 0.5,
        rpc: 2.0e-4,
        jitter_cv: 0.05,
    }
}

/// The paper's Table 10 reference values, used by calibration tests and
/// the comparison reports.
pub struct PaperFit {
    /// Scheduler display name.
    pub scheduler: &'static str,
    /// Marginal latency t_s (s).
    pub t_s: f64,
    /// Nonlinear exponent α_s.
    pub alpha_s: f64,
}

/// Table 10 as published.
pub fn paper_table10() -> [PaperFit; 4] {
    [
        PaperFit {
            scheduler: "Slurm",
            t_s: 2.2,
            alpha_s: 1.3,
        },
        PaperFit {
            scheduler: "GridEngine",
            t_s: 2.8,
            alpha_s: 1.3,
        },
        PaperFit {
            scheduler: "Mesos",
            t_s: 3.4,
            alpha_s: 1.1,
        },
        PaperFit {
            scheduler: "Hadoop YARN",
            t_s: 33.0,
            alpha_s: 1.0,
        },
    ]
}

/// The paper's Table 9 mean runtimes (s) for comparison reports.
/// `None` marks the abandoned YARN rapid trials.
pub fn paper_table9_runtimes() -> [(&'static str, [Option<f64>; 4]); 4] {
    [
        ("Slurm", [Some(2783.7), Some(610.3), Some(271.0), Some(283.7)]),
        ("GridEngine", [Some(3070.7), Some(626.3), Some(278.0), Some(276.7)]),
        ("Mesos", [Some(1793.7), Some(365.7), Some(280.3), Some(305.7)]),
        ("Hadoop YARN", [None, Some(1840.3), Some(487.0), Some(378.0)]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn daemon_throughput_anchors() {
        // N / T_total on the rapid set must match the per-task daemon cost.
        let slurm = slurm_params();
        let per_task = slurm.sched_cost_per_task + slurm.complete_cost_per_task;
        let implied_runtime = 337_920.0 * per_task;
        assert!(
            (implied_runtime - 2771.0).abs() < 100.0,
            "slurm rapid implied {implied_runtime}"
        );
        let mesos = mesos_params();
        let per_task = mesos.launch_cost_per_task + mesos.complete_cost_per_task;
        assert!((337_920.0 * per_task - 1791.0).abs() < 100.0);
    }

    #[test]
    fn yarn_fast_anchor() {
        let y = yarn_params();
        // 48 tasks/slot × (5 s + AM + container + ~heartbeat/2 + teardown)
        let per_slot = 48.0 * (5.0 + y.am_startup_mean + y.container_launch + 0.5 + y.teardown);
        assert!(
            (per_slot - 1840.0).abs() < 200.0,
            "yarn fast implied {per_slot}"
        );
    }

    #[test]
    fn paper_tables_well_formed() {
        assert_eq!(paper_table10().len(), 4);
        assert_eq!(paper_table9_runtimes().len(), 4);
        assert!(paper_table9_runtimes()[3].1[0].is_none()); // YARN rapid abandoned
    }
}
