//! Sparrow-like distributed scheduler (research family, §3.1.5).
//!
//! Sparrow (Ousterhout et al., SOSP 2013) removes the central daemon:
//! stateless schedulers probe d random workers per task ("batch
//! sampling" / power-of-two-choices) and enqueue the task at the
//! least-loaded probed worker; workers run their local FIFO queues.
//!
//! In the paper's taxonomy this trades placement quality for latency:
//! there is no serial daemon to saturate, so ΔT(n) stays near-linear
//! with a tiny marginal cost — the `ablations` bench contrasts it with
//! the centralized Table 10 schedulers ("distributed scheduler
//! architecture would allow for greater resilience but could cost the
//! scheduler in performance", §3.2.6).
//!
//! As a [`SchedPolicy`] Sparrow does its own capacity bookkeeping:
//! tasks are *placed* into per-slot backlogs (`busy_until`) the moment
//! they become ready, not allocated kernel slots, so
//! [`SchedPolicy::on_complete`] returns `None` and the kernel emits no
//! `SlotFree` events. Multi-core tasks claim several distinct backlog
//! slots; gangs place all members with a common synchronized start.

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::ClusterSpec;
use crate::sim::{Kernel, KernelCtx, SchedPolicy, SimEv, SimScratch, Time};
use crate::util::prng::Prng;
use crate::workload::{JobKind, TaskId, Workload};

/// Sparrow-model parameters.
#[derive(Clone, Debug)]
pub struct SparrowParams {
    /// Display name.
    pub name: &'static str,
    /// Probes per task (d; Sparrow's default power-of-two = 2).
    pub probes: usize,
    /// Probe round-trip latency added before a task starts (s).
    pub probe_rtt: f64,
    /// Worker-side dequeue/launch overhead per task (s).
    pub launch_overhead: f64,
    /// CV of lognormal jitter.
    pub jitter_cv: f64,
}

impl Default for SparrowParams {
    fn default() -> Self {
        Self {
            name: "Sparrow",
            probes: 2,
            probe_rtt: 0.002,
            launch_overhead: 0.005,
            jitter_cv: 0.10,
        }
    }
}

/// Sparrow-like simulator.
pub struct SparrowSim {
    params: SparrowParams,
}

impl SparrowSim {
    /// New simulator.
    pub fn new(params: SparrowParams) -> Self {
        Self { params }
    }
}

struct SparrowPolicy<'p> {
    p: &'p SparrowParams,
    rng: Prng,
}

impl SparrowPolicy<'_> {
    /// Probe d random slots, preferring the least-backlogged; slots in
    /// `taken` (already claimed by this task/gang) are skipped by a
    /// deterministic linear advance so concurrent claims stay distinct.
    /// Workers pinned by a service for the whole window carry an
    /// infinite backlog; when every probe lands on one, fall back to a
    /// deterministic full scan so a batch task is not starved by probe
    /// luck while finite workers exist (no-op for service-free runs,
    /// where every backlog is finite).
    fn probe(&mut self, busy: &[f64], taken: &[usize]) -> usize {
        let slots = busy.len();
        let mut best = self.rng.choose_index(slots);
        for _ in 1..self.p.probes.max(1) {
            let probe = self.rng.choose_index(slots);
            if busy[probe] < busy[best] {
                best = probe;
            }
        }
        while taken.contains(&best) {
            best = (best + 1) % slots;
        }
        if !busy[best].is_finite() {
            if let Some((i, _)) = busy
                .iter()
                .enumerate()
                .filter(|&(i, b)| b.is_finite() && !taken.contains(&i))
                .min_by(|a, b| a.1.total_cmp(b.1))
            {
                best = i;
            }
        }
        best
    }

    /// Place every ready pending task. Gangs wait until all members
    /// are ready, then place with a synchronized start.
    fn place_ready(&mut self, ctx: &mut KernelCtx, now: Time) {
        let slots = ctx.capacity();
        assert!(slots > 0, "empty cluster");
        if ctx.busy_until().len() < slots {
            ctx.busy_until().resize(slots, 0.0);
        }
        for tid in ctx.pending_snapshot() {
            let task = &ctx.workload().tasks[tid as usize];
            if task.kind == JobKind::Parallel {
                if !ctx.gang_all_ready(task.job) {
                    continue; // placed when the last member arrives
                }
                let members = ctx.pending_members(task.job);
                let gang_cores: usize = members
                    .iter()
                    .map(|&m| ctx.workload().tasks[m as usize].cores.max(1) as usize)
                    .sum();
                assert!(
                    gang_cores <= slots,
                    "gang {} needs {gang_cores} cores; cluster has {slots}",
                    task.job
                );
                // Probe per member, then synchronize the start.
                let mut taken: Vec<usize> = Vec::new();
                let mut placements: Vec<(TaskId, usize, usize)> = Vec::new();
                let mut start_all = 0.0f64;
                for &m in &members {
                    let spec = &ctx.workload().tasks[m as usize];
                    let first = taken.len();
                    let mut worst_busy = 0.0f64;
                    for _ in 0..spec.cores.max(1) {
                        let s = self.probe(ctx.busy_until(), &taken);
                        worst_busy = worst_busy.max(ctx.busy_until()[s]);
                        taken.push(s);
                    }
                    let overhead = self.p.probe_rtt
                        + self
                            .rng
                            .lognormal_mean_cv(self.p.launch_overhead, self.p.jitter_cv);
                    let raw = worst_busy.max(spec.submit_at).max(now) + overhead;
                    start_all = start_all.max(raw);
                    placements.push((m, first, taken.len() - first));
                }
                if !start_all.is_finite() {
                    // Every probed worker is pinned by a service for the
                    // whole window: the gang cannot assemble; leave it
                    // pending for a later pass.
                    continue;
                }
                for (m, first, count) in placements {
                    let dur = ctx.workload().tasks[m as usize].duration;
                    for &s in &taken[first..first + count] {
                        ctx.busy_until()[s] = start_all + dur;
                    }
                    ctx.take_task(m);
                    let slot = taken[first] as u32;
                    ctx.push(start_all, SimEv::Start { task: m, slot });
                }
            } else {
                assert!(
                    task.cores.max(1) as usize <= slots,
                    "task {} needs {} cores; cluster has {slots}",
                    task.id,
                    task.cores
                );
                // Batch sampling: probe d random slots per core.
                let mut taken: Vec<usize> = Vec::new();
                let mut worst_busy = 0.0f64;
                for _ in 0..task.cores.max(1) {
                    let s = self.probe(ctx.busy_until(), &taken);
                    worst_busy = worst_busy.max(ctx.busy_until()[s]);
                    taken.push(s);
                }
                let overhead = self.p.probe_rtt
                    + self
                        .rng
                        .lognormal_mean_cv(self.p.launch_overhead, self.p.jitter_cv);
                let start = worst_busy.max(task.submit_at).max(now) + overhead;
                if !start.is_finite() {
                    // Every worker is pinned by a service for the whole
                    // window: leave the task pending for a later pass.
                    continue;
                }
                if !ctx.take_task(tid) {
                    continue; // already placed as part of a gang
                }
                // A service holds its workers until the horizon: an
                // infinite backlog keeps later probes away from them.
                let end = if task.kind == JobKind::Service {
                    f64::INFINITY
                } else {
                    start + task.duration
                };
                for &s in &taken {
                    ctx.busy_until()[s] = end;
                }
                ctx.push(start, SimEv::Start { task: tid, slot: taken[0] as u32 });
            }
        }
    }
}

impl SchedPolicy for SparrowPolicy<'_> {
    fn label(&self) -> String {
        self.p.name.to_string()
    }

    fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
        self.place_ready(ctx, 0.0);
    }

    fn on_arrive(&mut self, ctx: &mut KernelCtx, now: Time, _task: TaskId) {
        self.place_ready(ctx, now);
    }

    fn on_deps_ready(&mut self, ctx: &mut KernelCtx, now: Time) {
        self.place_ready(ctx, now);
    }

    fn on_complete(
        &mut self,
        _ctx: &mut KernelCtx,
        _now: Time,
        _task: TaskId,
        _slot: u32,
    ) -> Option<Time> {
        None // backlog bookkeeping happened at placement time
    }
}

impl Scheduler for SparrowSim {
    fn name(&self) -> &'static str {
        self.params.name
    }

    fn make_policy<'a>(&'a self, seed: u64) -> Option<Box<dyn SchedPolicy + 'a>> {
        // Note: Sparrow places tasks into worker backlogs instead of
        // kernel slots, so it never yields evictable victims — wrapping
        // it in the preemption combinators is safe but inert.
        Some(Box::new(SparrowPolicy {
            p: &self.params,
            rng: Prng::new(seed ^ 0x5BA2_2063),
        }))
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let mut policy = self.make_policy(seed).expect("sparrow is kernel-driven");
        Kernel::run(policy.as_mut(), workload, cluster, options, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadBuilder;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(4, 8, 64 * 1024, 2)
    }

    #[test]
    fn completes_and_valid() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(1.0).tasks(320).label("s").build();
        let r = sim.run(&w, &cluster(), 3, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.trace.as_ref().unwrap().len(), 320);
    }

    #[test]
    fn two_choices_beats_one_choice() {
        // Classic power-of-two-choices: load imbalance (and hence
        // makespan) drops sharply from d=1 to d=2.
        let w = WorkloadBuilder::constant(1.0).tasks(3200).build();
        let one = SparrowSim::new(SparrowParams {
            probes: 1,
            ..Default::default()
        })
        .run(&w, &cluster(), 5, &RunOptions::default());
        let two = SparrowSim::new(SparrowParams {
            probes: 2,
            ..Default::default()
        })
        .run(&w, &cluster(), 5, &RunOptions::default());
        // With 100 tasks/slot, d=1 tail ≈ mean + sqrt(mean·ln S) while
        // d=2 is within a few tasks of the mean.
        assert!(
            two.t_total < one.t_total * 0.92,
            "d=2 {} vs d=1 {}",
            two.t_total,
            one.t_total
        );
    }

    #[test]
    fn no_central_bottleneck_at_high_task_rates() {
        // Sparrow ΔT stays tiny where centralized schedulers saturate:
        // 240 tasks/slot of 1 s.
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(1.0)
            .tasks(240 * 32)
            .label("rapid")
            .build();
        let r = sim.run(&w, &cluster(), 9, &RunOptions::default());
        // Overheads ~7 ms/task ⇒ ΔT ≈ a few seconds, U > 0.85.
        assert!(
            r.utilization() > 0.85,
            "sparrow rapid U={:.3}",
            r.utilization()
        );
    }

    #[test]
    fn deterministic() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(2.0).tasks(100).build();
        let a = sim.run(&w, &cluster(), 7, &RunOptions::default());
        let b = sim.run(&w, &cluster(), 7, &RunOptions::default());
        assert_eq!(a.t_total, b.t_total);
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w1 = WorkloadBuilder::constant(2.0).tasks(100).build();
        let w2 = WorkloadBuilder::constant(1.0).tasks(40).build();
        let mut scratch = SimScratch::new();
        sim.run_with_scratch(&w1, &cluster(), 3, &RunOptions::with_trace(), &mut scratch);
        for (w, seed) in [(&w1, 7u64), (&w2, 8)] {
            let warm =
                sim.run_with_scratch(w, &cluster(), seed, &RunOptions::with_trace(), &mut scratch);
            let fresh = sim.run(w, &cluster(), seed, &RunOptions::with_trace());
            assert_eq!(warm.t_total.to_bits(), fresh.t_total.to_bits());
            assert_eq!(warm.trace.as_ref().unwrap(), fresh.trace.as_ref().unwrap());
        }
    }

    #[test]
    fn gang_members_start_together() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(2.0)
            .tasks(32)
            .gangs(8)
            .label("g")
            .build();
        let r = sim.run(&w, &cluster(), 11, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        for job in 0..4u32 {
            let starts: Vec<f64> = trace
                .iter()
                .filter(|t| w.tasks[t.task as usize].job == job)
                .map(|t| t.start)
                .collect();
            assert_eq!(starts.len(), 8);
            for &s in &starts {
                assert!((s - starts[0]).abs() < 1e-12, "gang {job} skew");
            }
        }
    }

    #[test]
    fn services_pin_workers_and_batch_flows_around_them() {
        use crate::workload::{TaskSpec, Workload};
        // 32 worker slots, 16 pinned by services for the whole window;
        // the 32 batch tasks must all land on the finite-backlog half
        // (the probe fallback guarantees it) and complete well before
        // the 20 s horizon.
        let mut tasks: Vec<TaskSpec> = (0..16).map(|i| TaskSpec::service(i, i, 1)).collect();
        for i in 16..48 {
            tasks.push(TaskSpec::array(i, i, 1.0));
        }
        let w = Workload {
            tasks,
            label: "svc".into(),
        };
        let sim = SparrowSim::new(SparrowParams::default());
        let options = RunOptions {
            collect_trace: true,
            horizon: Some(20.0),
            ..Default::default()
        };
        let r = sim.run(&w, &cluster(), 7, &options);
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        assert_eq!(trace.len(), 48, "every task started inside the window");
        for rec in trace.iter().filter(|t| t.task < 16) {
            assert!((rec.end - 20.0).abs() < 1e-9, "service clipped to horizon");
        }
        for rec in trace.iter().filter(|t| t.task >= 16) {
            assert!(rec.end < 5.0, "batch task delayed: {rec:?}");
        }
        // Services alone pin half the window's core-time.
        assert!(r.utilization() > 0.5, "U={}", r.utilization());
    }

    #[test]
    fn dag_children_start_after_parents() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(1.0)
            .tasks(64)
            .dag_chains(8)
            .build();
        let r = sim.run(&w, &cluster(), 13, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        let mut start = vec![0.0f64; 64];
        let mut end = vec![0.0f64; 64];
        for rec in trace {
            start[rec.task as usize] = rec.start;
            end[rec.task as usize] = rec.end;
        }
        for t in &w.tasks {
            for &d in &t.deps {
                assert!(start[t.id as usize] >= end[d as usize] - 1e-9);
            }
        }
    }
}
