//! Sparrow-like distributed scheduler (research family, §3.1.5).
//!
//! Sparrow (Ousterhout et al., SOSP 2013) removes the central daemon:
//! stateless schedulers probe d random workers per task ("batch
//! sampling" / power-of-two-choices) and enqueue the task at the
//! least-loaded probed worker; workers run their local FIFO queues.
//!
//! In the paper's taxonomy this trades placement quality for latency:
//! there is no serial daemon to saturate, so ΔT(n) stays near-linear
//! with a tiny marginal cost — the `ablations` bench contrasts it with
//! the centralized Table 10 schedulers ("distributed scheduler
//! architecture would allow for greater resilience but could cost the
//! scheduler in performance", §3.2.6).

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::ClusterSpec;
use crate::sim::SimScratch;
use crate::util::prng::Prng;
use crate::util::stats::Summary;
use crate::workload::{TraceRecord, Workload};

/// Sparrow-model parameters.
#[derive(Clone, Debug)]
pub struct SparrowParams {
    /// Display name.
    pub name: &'static str,
    /// Probes per task (d; Sparrow's default power-of-two = 2).
    pub probes: usize,
    /// Probe round-trip latency added before a task starts (s).
    pub probe_rtt: f64,
    /// Worker-side dequeue/launch overhead per task (s).
    pub launch_overhead: f64,
    /// CV of lognormal jitter.
    pub jitter_cv: f64,
}

impl Default for SparrowParams {
    fn default() -> Self {
        Self {
            name: "Sparrow",
            probes: 2,
            probe_rtt: 0.002,
            launch_overhead: 0.005,
            jitter_cv: 0.10,
        }
    }
}

/// Sparrow-like simulator.
pub struct SparrowSim {
    params: SparrowParams,
}

impl SparrowSim {
    /// New simulator.
    pub fn new(params: SparrowParams) -> Self {
        Self { params }
    }
}

impl Scheduler for SparrowSim {
    fn name(&self) -> &'static str {
        self.params.name
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let p = &self.params;
        let mut rng = Prng::new(seed ^ 0x5BA2_2063);
        scratch.begin(cluster, workload.len(), options.collect_trace);
        let SimScratch {
            pool,
            busy_until,
            trace,
            ..
        } = scratch;
        let slots = pool.capacity();
        assert!(slots > 0, "empty cluster");

        // Per-slot local queues: we only need the backlog (busy-until)
        // per slot — tasks placed by least-backlog-of-d-probes run FIFO.
        busy_until.resize(slots, 0.0f64);
        let mut waits = Summary::new();
        let mut makespan = 0.0f64;

        for task in &workload.tasks {
            // Batch sampling: probe d distinct random slots.
            let mut best = rng.choose_index(slots);
            for _ in 1..p.probes.max(1) {
                let probe = rng.choose_index(slots);
                if busy_until[probe] < busy_until[best] {
                    best = probe;
                }
            }
            let overhead = p.probe_rtt
                + rng.lognormal_mean_cv(p.launch_overhead, p.jitter_cv);
            let start = busy_until[best].max(task.submit_at) + overhead;
            let end = start + task.duration;
            busy_until[best] = end;
            makespan = makespan.max(end);
            waits.add(start - task.submit_at);
            if options.collect_trace {
                trace.push(TraceRecord {
                    task: task.id,
                    node: pool.node_of(best as u32),
                    slot: best as u32,
                    submit: task.submit_at,
                    start,
                    end,
                });
            }
        }

        let processors = cluster.total_cores();
        RunResult {
            scheduler: p.name.to_string(),
            workload: workload.label.clone(),
            n_tasks: workload.len() as u64,
            processors,
            t_total: makespan,
            t_job: workload.t_job_per_proc(processors),
            events: workload.len() as u64,
            daemon_busy: 0.0, // no central daemon — the point
            waits,
            trace: options.collect_trace.then(|| std::mem::take(trace)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadBuilder;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(4, 8, 64 * 1024, 2)
    }

    #[test]
    fn completes_and_valid() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(1.0).tasks(320).label("s").build();
        let r = sim.run(&w, &cluster(), 3, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.trace.as_ref().unwrap().len(), 320);
    }

    #[test]
    fn two_choices_beats_one_choice() {
        // Classic power-of-two-choices: load imbalance (and hence
        // makespan) drops sharply from d=1 to d=2.
        let w = WorkloadBuilder::constant(1.0).tasks(3200).build();
        let one = SparrowSim::new(SparrowParams {
            probes: 1,
            ..Default::default()
        })
        .run(&w, &cluster(), 5, &RunOptions::default());
        let two = SparrowSim::new(SparrowParams {
            probes: 2,
            ..Default::default()
        })
        .run(&w, &cluster(), 5, &RunOptions::default());
        // With 100 tasks/slot, d=1 tail ≈ mean + sqrt(mean·ln S) while
        // d=2 is within a few tasks of the mean.
        assert!(
            two.t_total < one.t_total * 0.92,
            "d=2 {} vs d=1 {}",
            two.t_total,
            one.t_total
        );
    }

    #[test]
    fn no_central_bottleneck_at_high_task_rates() {
        // Sparrow ΔT stays tiny where centralized schedulers saturate:
        // 240 tasks/slot of 1 s.
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(1.0)
            .tasks(240 * 32)
            .label("rapid")
            .build();
        let r = sim.run(&w, &cluster(), 9, &RunOptions::default());
        // Overheads ~7 ms/task ⇒ ΔT ≈ a few seconds, U > 0.85.
        assert!(
            r.utilization() > 0.85,
            "sparrow rapid U={:.3}",
            r.utilization()
        );
    }

    #[test]
    fn deterministic() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(2.0).tasks(100).build();
        let a = sim.run(&w, &cluster(), 7, &RunOptions::default());
        let b = sim.run(&w, &cluster(), 7, &RunOptions::default());
        assert_eq!(a.t_total, b.t_total);
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w1 = WorkloadBuilder::constant(2.0).tasks(100).build();
        let w2 = WorkloadBuilder::constant(1.0).tasks(40).build();
        let mut scratch = SimScratch::new();
        sim.run_with_scratch(&w1, &cluster(), 3, &RunOptions::with_trace(), &mut scratch);
        for (w, seed) in [(&w1, 7u64), (&w2, 8)] {
            let warm =
                sim.run_with_scratch(w, &cluster(), seed, &RunOptions::with_trace(), &mut scratch);
            let fresh = sim.run(w, &cluster(), seed, &RunOptions::with_trace());
            assert_eq!(warm.t_total.to_bits(), fresh.t_total.to_bits());
            assert_eq!(warm.trace.as_ref().unwrap(), fresh.trace.as_ref().unwrap());
        }
    }
}
