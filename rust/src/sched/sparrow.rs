//! Sparrow-like distributed scheduler (research family, §3.1.5).
//!
//! Sparrow (Ousterhout et al., SOSP 2013) removes the central daemon:
//! stateless schedulers probe d random workers per task ("batch
//! sampling" / power-of-two-choices) and enqueue the task at the
//! least-loaded probed worker; workers run their local FIFO queues.
//!
//! In the paper's taxonomy this trades placement quality for latency:
//! there is no serial daemon to saturate, so ΔT(n) stays near-linear
//! with a tiny marginal cost — the `ablations` bench contrasts it with
//! the centralized Table 10 schedulers ("distributed scheduler
//! architecture would allow for greater resilience but could cost the
//! scheduler in performance", §3.2.6).
//!
//! As a [`SchedPolicy`] Sparrow does its own capacity bookkeeping:
//! tasks are *placed* into per-slot backlogs (`busy_until`) the moment
//! they become ready, not allocated kernel slots, so
//! [`SchedPolicy::on_complete`] returns `None` and the kernel emits no
//! `SlotFree` events. Multi-core tasks claim several distinct backlog
//! slots; gangs place all members with a common synchronized start.
//!
//! **Faults.** Because the backlogs live policy-side, Sparrow reacts
//! to node faults itself: a failed or drained node's worker backlogs
//! are masked to infinity so probes skip them (the same mechanism that
//! steers probes away from service-pinned workers), and recovery
//! restores the saved backlog — zeroed for failures, whose running
//! work was killed; kept for drains, whose running work finishes.
//! Tasks the kernel killed or aborted re-enter the pending queue and
//! are re-probed on the next placement pass. One approximation: the
//! kernel tracks only a task's *primary* worker, so a multi-core task
//! whose extra backlog slots sit on a failed node keeps running —
//! acceptable for a scheduler whose backlogs are estimates to begin
//! with.

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::{ClusterSpec, NodeId};
use crate::sim::{Kernel, KernelCtx, SchedPolicy, SimEv, SimScratch, Time};
use crate::util::prng::Prng;
use crate::workload::{JobKind, TaskId, Workload};

/// Sparrow-model parameters.
#[derive(Clone, Debug)]
pub struct SparrowParams {
    /// Display name.
    pub name: &'static str,
    /// Probes per task (d; Sparrow's default power-of-two = 2).
    pub probes: usize,
    /// Probe round-trip latency added before a task starts (s).
    pub probe_rtt: f64,
    /// Worker-side dequeue/launch overhead per task (s).
    pub launch_overhead: f64,
    /// CV of lognormal jitter.
    pub jitter_cv: f64,
}

impl Default for SparrowParams {
    fn default() -> Self {
        Self {
            name: "Sparrow",
            probes: 2,
            probe_rtt: 0.002,
            launch_overhead: 0.005,
            jitter_cv: 0.10,
        }
    }
}

/// Sparrow-like simulator.
pub struct SparrowSim {
    params: SparrowParams,
}

impl SparrowSim {
    /// New simulator.
    pub fn new(params: SparrowParams) -> Self {
        Self { params }
    }
}

struct SparrowPolicy<'p> {
    p: &'p SparrowParams,
    rng: Prng,
    /// Whether each worker slot's node is currently down (failed or
    /// drained); lazily sized on the first fault event.
    down: Vec<bool>,
    /// Backlog saved while a slot's node is down, restored on
    /// recovery: drains keep the running work's backlog, failures zero
    /// it (the work was killed).
    saved_backlog: Vec<f64>,
}

impl SparrowPolicy<'_> {
    /// Mask a down node's worker backlogs to infinity so probes skip
    /// them, saving the pre-fault backlog for recovery.
    fn mark_node_down(&mut self, ctx: &mut KernelCtx, node: NodeId, keep_backlog: bool) {
        let slots = ctx.capacity();
        if ctx.busy_until().len() < slots {
            ctx.busy_until().resize(slots, 0.0);
        }
        if self.down.len() < slots {
            self.down.resize(slots, false);
            self.saved_backlog.resize(slots, 0.0);
        }
        for s in 0..slots {
            if ctx.node_of_slot(s as u32) != node {
                continue;
            }
            if !self.down[s] {
                self.down[s] = true;
                self.saved_backlog[s] = if keep_backlog { ctx.busy_until()[s] } else { 0.0 };
            } else if !keep_backlog {
                // A drained node failing outright loses its backlog too.
                self.saved_backlog[s] = 0.0;
            }
            ctx.busy_until()[s] = f64::INFINITY;
        }
    }
    /// Probe d random slots, preferring the least-backlogged; slots in
    /// `taken` (already claimed by this task/gang) are skipped by a
    /// deterministic linear advance so concurrent claims stay distinct.
    /// Workers pinned by a service for the whole window carry an
    /// infinite backlog; when every probe lands on one, fall back to a
    /// deterministic full scan so a batch task is not starved by probe
    /// luck while finite workers exist (no-op for service-free runs,
    /// where every backlog is finite).
    fn probe(&mut self, busy: &[f64], taken: &[usize]) -> usize {
        let slots = busy.len();
        let mut best = self.rng.choose_index(slots);
        for _ in 1..self.p.probes.max(1) {
            let probe = self.rng.choose_index(slots);
            if busy[probe] < busy[best] {
                best = probe;
            }
        }
        while taken.contains(&best) {
            best = (best + 1) % slots;
        }
        if !busy[best].is_finite() {
            if let Some((i, _)) = busy
                .iter()
                .enumerate()
                .filter(|&(i, b)| b.is_finite() && !taken.contains(&i))
                .min_by(|a, b| a.1.total_cmp(b.1))
            {
                best = i;
            }
        }
        best
    }

    /// Place every ready pending task. Gangs wait until all members
    /// are ready, then place with a synchronized start.
    fn place_ready(&mut self, ctx: &mut KernelCtx, now: Time) {
        let slots = ctx.capacity();
        assert!(slots > 0, "empty cluster");
        if ctx.busy_until().len() < slots {
            ctx.busy_until().resize(slots, 0.0);
        }
        for tid in ctx.pending_snapshot() {
            let task = &ctx.workload().tasks[tid as usize];
            if task.kind == JobKind::Parallel {
                if !ctx.gang_all_ready(task.job) {
                    continue; // placed when the last member arrives
                }
                let members = ctx.pending_members(task.job);
                let gang_cores: usize = members
                    .iter()
                    .map(|&m| ctx.workload().tasks[m as usize].cores.max(1) as usize)
                    .sum();
                assert!(
                    gang_cores <= slots,
                    "gang {} needs {gang_cores} cores; cluster has {slots}",
                    task.job
                );
                // Probe per member, then synchronize the start.
                let mut taken: Vec<usize> = Vec::new();
                let mut placements: Vec<(TaskId, usize, usize)> = Vec::new();
                let mut start_all = 0.0f64;
                for &m in &members {
                    let spec = &ctx.workload().tasks[m as usize];
                    let first = taken.len();
                    let mut worst_busy = 0.0f64;
                    for _ in 0..spec.cores.max(1) {
                        let s = self.probe(ctx.busy_until(), &taken);
                        worst_busy = worst_busy.max(ctx.busy_until()[s]);
                        taken.push(s);
                    }
                    let overhead = self.p.probe_rtt
                        + self
                            .rng
                            .lognormal_mean_cv(self.p.launch_overhead, self.p.jitter_cv);
                    let raw = worst_busy.max(spec.submit_at).max(now) + overhead;
                    start_all = start_all.max(raw);
                    placements.push((m, first, taken.len() - first));
                }
                if !start_all.is_finite() {
                    // Every probed worker is pinned by a service for the
                    // whole window: the gang cannot assemble; leave it
                    // pending for a later pass.
                    continue;
                }
                for (m, first, count) in placements {
                    let dur = ctx.workload().tasks[m as usize].duration;
                    for &s in &taken[first..first + count] {
                        ctx.busy_until()[s] = start_all + dur;
                    }
                    ctx.take_task(m);
                    let slot = taken[first] as u32;
                    ctx.push(start_all, SimEv::Start { task: m, slot });
                }
            } else {
                assert!(
                    task.cores.max(1) as usize <= slots,
                    "task {} needs {} cores; cluster has {slots}",
                    task.id,
                    task.cores
                );
                // Batch sampling: probe d random slots per core.
                let mut taken: Vec<usize> = Vec::new();
                let mut worst_busy = 0.0f64;
                for _ in 0..task.cores.max(1) {
                    let s = self.probe(ctx.busy_until(), &taken);
                    worst_busy = worst_busy.max(ctx.busy_until()[s]);
                    taken.push(s);
                }
                let overhead = self.p.probe_rtt
                    + self
                        .rng
                        .lognormal_mean_cv(self.p.launch_overhead, self.p.jitter_cv);
                let start = worst_busy.max(task.submit_at).max(now) + overhead;
                if !start.is_finite() {
                    // Every worker is pinned by a service for the whole
                    // window: leave the task pending for a later pass.
                    continue;
                }
                if !ctx.take_task(tid) {
                    continue; // already placed as part of a gang
                }
                // A service holds its workers until the horizon: an
                // infinite backlog keeps later probes away from them.
                let end = if task.kind == JobKind::Service {
                    f64::INFINITY
                } else {
                    start + task.duration
                };
                for &s in &taken {
                    ctx.busy_until()[s] = end;
                }
                ctx.push(start, SimEv::Start { task: tid, slot: taken[0] as u32 });
            }
        }
    }
}

impl SchedPolicy for SparrowPolicy<'_> {
    fn label(&self) -> String {
        self.p.name.to_string()
    }

    fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
        self.place_ready(ctx, 0.0);
    }

    fn on_arrive(&mut self, ctx: &mut KernelCtx, now: Time, _task: TaskId) {
        self.place_ready(ctx, now);
    }

    fn on_deps_ready(&mut self, ctx: &mut KernelCtx, now: Time) {
        self.place_ready(ctx, now);
    }

    fn on_complete(
        &mut self,
        _ctx: &mut KernelCtx,
        _now: Time,
        _task: TaskId,
        _slot: u32,
    ) -> Option<Time> {
        None // backlog bookkeeping happened at placement time
    }

    fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
        // Sparrow holds no kernel slots, so this only fires when the
        // kernel aborts a launch in flight toward a dead node; the
        // aborted task is back in the pending queue — re-probe it.
        self.place_ready(ctx, now);
    }

    fn on_node_fail(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        self.mark_node_down(ctx, node, false);
        // The kernel already killed and requeued the node's tasks;
        // re-probe them against the surviving (finite) backlogs.
        self.place_ready(ctx, now);
    }

    fn on_node_suspected(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        // Identical to on_node_fail: detection is when the probes stop
        // getting answers, so the workers' backlogs mask out now.
        self.mark_node_down(ctx, node, false);
        self.place_ready(ctx, now);
    }

    fn on_node_drain(&mut self, ctx: &mut KernelCtx, _now: Time, node: NodeId) {
        // Running work finishes in place; only future probes move away.
        self.mark_node_down(ctx, node, true);
    }

    fn on_node_recover(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        let slots = ctx.capacity();
        for s in 0..slots.min(self.down.len()) {
            if ctx.node_of_slot(s as u32) != node || !self.down[s] {
                continue;
            }
            self.down[s] = false;
            ctx.busy_until()[s] = self.saved_backlog[s];
        }
        // Fresh capacity may unblock tasks every probe pass skipped.
        self.place_ready(ctx, now);
    }
}

impl Scheduler for SparrowSim {
    fn name(&self) -> &'static str {
        self.params.name
    }

    fn make_policy<'a>(&'a self, seed: u64) -> Option<Box<dyn SchedPolicy + 'a>> {
        // Note: Sparrow places tasks into worker backlogs instead of
        // kernel slots, so it never yields evictable victims — wrapping
        // it in the preemption combinators is safe but inert.
        Some(Box::new(SparrowPolicy {
            p: &self.params,
            rng: Prng::new(seed ^ 0x5BA2_2063),
            down: Vec::new(),
            saved_backlog: Vec::new(),
        }))
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let mut policy = self.make_policy(seed).expect("sparrow is kernel-driven");
        Kernel::run(policy.as_mut(), workload, cluster, options, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadBuilder;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(4, 8, 64 * 1024, 2)
    }

    #[test]
    fn completes_and_valid() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(1.0).tasks(320).label("s").build();
        let r = sim.run(&w, &cluster(), 3, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.trace.as_ref().unwrap().len(), 320);
    }

    #[test]
    fn two_choices_beats_one_choice() {
        // Classic power-of-two-choices: load imbalance (and hence
        // makespan) drops sharply from d=1 to d=2.
        let w = WorkloadBuilder::constant(1.0).tasks(3200).build();
        let one = SparrowSim::new(SparrowParams {
            probes: 1,
            ..Default::default()
        })
        .run(&w, &cluster(), 5, &RunOptions::default());
        let two = SparrowSim::new(SparrowParams {
            probes: 2,
            ..Default::default()
        })
        .run(&w, &cluster(), 5, &RunOptions::default());
        // With 100 tasks/slot, d=1 tail ≈ mean + sqrt(mean·ln S) while
        // d=2 is within a few tasks of the mean.
        assert!(
            two.t_total < one.t_total * 0.92,
            "d=2 {} vs d=1 {}",
            two.t_total,
            one.t_total
        );
    }

    #[test]
    fn no_central_bottleneck_at_high_task_rates() {
        // Sparrow ΔT stays tiny where centralized schedulers saturate:
        // 240 tasks/slot of 1 s.
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(1.0)
            .tasks(240 * 32)
            .label("rapid")
            .build();
        let r = sim.run(&w, &cluster(), 9, &RunOptions::default());
        // Overheads ~7 ms/task ⇒ ΔT ≈ a few seconds, U > 0.85.
        assert!(
            r.utilization() > 0.85,
            "sparrow rapid U={:.3}",
            r.utilization()
        );
    }

    #[test]
    fn deterministic() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(2.0).tasks(100).build();
        let a = sim.run(&w, &cluster(), 7, &RunOptions::default());
        let b = sim.run(&w, &cluster(), 7, &RunOptions::default());
        assert_eq!(a.t_total, b.t_total);
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w1 = WorkloadBuilder::constant(2.0).tasks(100).build();
        let w2 = WorkloadBuilder::constant(1.0).tasks(40).build();
        let mut scratch = SimScratch::new();
        sim.run_with_scratch(&w1, &cluster(), 3, &RunOptions::with_trace(), &mut scratch);
        for (w, seed) in [(&w1, 7u64), (&w2, 8)] {
            let warm =
                sim.run_with_scratch(w, &cluster(), seed, &RunOptions::with_trace(), &mut scratch);
            let fresh = sim.run(w, &cluster(), seed, &RunOptions::with_trace());
            assert_eq!(warm.t_total.to_bits(), fresh.t_total.to_bits());
            assert_eq!(warm.trace.as_ref().unwrap(), fresh.trace.as_ref().unwrap());
        }
    }

    #[test]
    fn gang_members_start_together() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(2.0)
            .tasks(32)
            .gangs(8)
            .label("g")
            .build();
        let r = sim.run(&w, &cluster(), 11, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        for job in 0..4u32 {
            let starts: Vec<f64> = trace
                .iter()
                .filter(|t| w.tasks[t.task as usize].job == job)
                .map(|t| t.start)
                .collect();
            assert_eq!(starts.len(), 8);
            for &s in &starts {
                assert!((s - starts[0]).abs() < 1e-12, "gang {job} skew");
            }
        }
    }

    #[test]
    fn services_pin_workers_and_batch_flows_around_them() {
        use crate::workload::{TaskSpec, Workload};
        // 32 worker slots, 16 pinned by services for the whole window;
        // the 32 batch tasks must all land on the finite-backlog half
        // (the probe fallback guarantees it) and complete well before
        // the 20 s horizon.
        let mut tasks: Vec<TaskSpec> = (0..16).map(|i| TaskSpec::service(i, i, 1)).collect();
        for i in 16..48 {
            tasks.push(TaskSpec::array(i, i, 1.0));
        }
        let w = Workload {
            tasks,
            label: "svc".into(),
        };
        let sim = SparrowSim::new(SparrowParams::default());
        let options = RunOptions {
            collect_trace: true,
            horizon: Some(20.0),
            ..Default::default()
        };
        let r = sim.run(&w, &cluster(), 7, &options);
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        assert_eq!(trace.len(), 48, "every task started inside the window");
        for rec in trace.iter().filter(|t| t.task < 16) {
            assert!((rec.end - 20.0).abs() < 1e-9, "service clipped to horizon");
        }
        for rec in trace.iter().filter(|t| t.task >= 16) {
            assert!(rec.end < 5.0, "batch task delayed: {rec:?}");
        }
        // Services alone pin half the window's core-time.
        assert!(r.utilization() > 0.5, "U={}", r.utilization());
    }

    #[test]
    fn node_failure_reprobes_killed_tasks_onto_survivors() {
        use crate::cluster::FaultPlan;
        // 4 nodes x 8 slots; node 0 (slots 0..8) dies at t=1 and never
        // comes back. Tasks killed there lose their work and re-probe
        // onto the 24 surviving workers inside the retry budget.
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(2.0).tasks(64).label("f").build();
        let mut options = RunOptions::with_trace();
        options.faults = FaultPlan::none().fail(1.0, 0);
        let r = sim.run(&w, &cluster(), 17, &options);
        r.check_invariants().unwrap();
        assert!(r.kills > 0, "slots 0..8 held tasks at t=1");
        assert_eq!(r.failed, 0, "default retry budget absorbs one kill");
        assert!(r.wasted_core_seconds > 0.0);
        assert_eq!(r.trace.as_ref().unwrap().len(), 64);
        // No execution span may touch the dead node after the failure.
        for s in r.spans.as_ref().unwrap() {
            if s.slot < 8 {
                assert!(s.end <= 1.0 + 1e-9, "span on dead node: {s:?}");
            }
        }
    }

    #[test]
    fn services_restart_after_failure_without_consuming_a_budget() {
        use crate::cluster::FaultPlan;
        use crate::workload::{TaskSpec, Workload};
        // 2 nodes x 4 slots, 6 services pinned to distinct workers:
        // by pigeonhole node 1 (slots 4..8) holds 2-4 of them. It dies
        // at t=5 and recovers at t=8; the killed services restart (on
        // node 0's spare slots at ~5, the rest on the recovered node at
        // ~8) and every one runs to the horizon — no retry budget.
        let cluster = ClusterSpec::homogeneous(2, 4, 64 * 1024, 2);
        let tasks: Vec<TaskSpec> = (0..6).map(|i| TaskSpec::service(i, i, 1)).collect();
        let w = Workload {
            tasks,
            label: "svc-fail".into(),
        };
        let sim = SparrowSim::new(SparrowParams::default());
        let options = RunOptions {
            collect_trace: true,
            horizon: Some(20.0),
            faults: FaultPlan::none().fail(5.0, 1).recover(8.0, 1),
            ..Default::default()
        };
        let r = sim.run(&w, &cluster, 23, &options);
        r.check_invariants().unwrap();
        assert!((2..=4).contains(&r.kills), "pigeonhole: {} kills", r.kills);
        assert_eq!(r.failed, 0, "services never fail permanently");
        assert!(r.wasted_core_seconds > 0.0, "killed work is lost");
        let spans = r.spans.as_ref().unwrap();
        // Nothing runs on node 1 inside the failure gap [5, 8).
        for s in spans {
            if s.slot >= 4 {
                assert!(
                    s.end <= 5.0 + 1e-9 || s.start >= 8.0,
                    "span overlaps the outage: {s:?}"
                );
            }
        }
        // Every kill produced a restart span that holds to the horizon.
        let restarted = spans
            .iter()
            .filter(|s| s.start >= 5.0 && (s.end - 20.0).abs() < 1e-9)
            .count() as u64;
        assert_eq!(restarted, r.kills, "every kill restarted somewhere");
    }

    #[test]
    fn drain_then_recover_restores_backlogs() {
        use crate::cluster::FaultPlan;
        // Drain node 1 at t=0.5, recover at t=3: running work finishes
        // in place (no kills), and post-recovery placements may use the
        // node again.
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(1.0).tasks(320).label("d").build();
        let mut options = RunOptions::with_trace();
        options.faults = FaultPlan::none().drain(0.5, 1).recover(3.0, 1);
        let r = sim.run(&w, &cluster(), 29, &options);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 0, "drain spares running work");
        assert_eq!(r.failed, 0);
        assert_eq!(r.wasted_core_seconds, 0.0);
        assert_eq!(r.trace.as_ref().unwrap().len(), 320);
        // 320 one-second tasks on 32 slots: the run outlives the
        // recovery and the node picks work back up.
        let reused = r
            .spans
            .as_ref()
            .unwrap()
            .iter()
            .any(|s| (8..16).contains(&s.slot) && s.start >= 3.0);
        assert!(reused, "recovered node never reused");
    }

    #[test]
    fn dag_children_start_after_parents() {
        let sim = SparrowSim::new(SparrowParams::default());
        let w = WorkloadBuilder::constant(1.0)
            .tasks(64)
            .dag_chains(8)
            .build();
        let r = sim.run(&w, &cluster(), 13, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        let mut start = vec![0.0f64; 64];
        let mut end = vec![0.0f64; 64];
        for rec in trace {
            start[rec.task as usize] = rec.start;
            end[rec.task as usize] = rec.end;
        }
        for t in &w.tasks {
            for &d in &t.deps {
                assert!(start[t.id as usize] >= end[d as usize] - 1e-9);
            }
        }
    }
}
