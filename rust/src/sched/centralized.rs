//! Centralized cycle-based scheduler simulator — the mechanism shared by
//! the Slurm-like and Grid-Engine-like models.
//!
//! Structure (mirrors slurmctld / sge_qmaster):
//!
//! * one central daemon = a serial [`ServiceStation`]; every scheduling
//!   decision and every completion notification transits it;
//! * a periodic scheduling cycle scans the pending queue (cost grows
//!   with queue depth, capped like Slurm's `default_queue_depth`) and
//!   dispatches tasks onto free core slots;
//! * dispatched tasks pay an RPC hop plus a node-daemon launch overhead
//!   before execution starts; completions pay daemon processing plus a
//!   node-side teardown before the slot is reusable.
//!
//! ΔT(n) emerges: at short task times the daemon saturates
//! (throughput = 1/(sched+complete cost) tasks/s) giving the steep
//! right side of Figure 4; at long task times per-task cycle waits and
//! stagger dominate, giving the shallow left side — together the
//! measured α_s ≈ 1.3 of Table 10.

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::ClusterSpec;
use crate::sim::{ServiceStation, SimEv, SimScratch};
use crate::util::prng::{LognormalGen, Prng};
use crate::util::stats::Summary;
use crate::workload::{TraceRecord, Workload};

/// Tunable mechanism parameters for a centralized scheduler.
#[derive(Clone, Debug)]
pub struct CentralizedParams {
    /// Display name.
    pub name: &'static str,
    /// Scheduling cycle period (s). Slurm sched/builtin ~1 s; SoGE
    /// scheduler interval ~2 s in high-throughput config.
    pub cycle_interval: f64,
    /// Daemon cost to accept a job-array submission: base + per-task.
    pub submit_cost_base: f64,
    /// Per-task component of submission parsing.
    pub submit_cost_per_task: f64,
    /// Daemon cost to accept ONE job submitted individually (RPC +
    /// full job-record accounting) — the paper's "individual jobs"
    /// submission mode pays this per task.
    pub submit_cost_job: f64,
    /// Daemon serial cost per dispatch decision (allocation + launch RPC
    /// issue).
    pub sched_cost_per_task: f64,
    /// Daemon serial cost per completion record.
    pub complete_cost_per_task: f64,
    /// Pending-queue scan cost per queued element per cycle.
    pub scan_cost_per_pending: f64,
    /// Scan depth cap (Slurm default_queue_depth analog).
    pub scan_cap: usize,
    /// Node-daemon launch overhead mean (s).
    pub launch_mean: f64,
    /// Coefficient of variation of launch overhead.
    pub launch_cv: f64,
    /// Node-side teardown before the slot is reusable (s).
    pub teardown_mean: f64,
    /// One-way control RPC latency (s).
    pub rpc: f64,
    /// CV of lognormal jitter applied to daemon service times.
    pub jitter_cv: f64,
}

/// Centralized scheduler simulator (Slurm-like / GE-like by params).
pub struct CentralizedSim {
    params: CentralizedParams,
}

impl CentralizedSim {
    /// New simulator with the given mechanism parameters.
    pub fn new(params: CentralizedParams) -> Self {
        Self { params }
    }

    /// Access the parameters (used by calibration tests).
    pub fn params(&self) -> &CentralizedParams {
        &self.params
    }
}

impl Scheduler for CentralizedSim {
    fn name(&self) -> &'static str {
        self.params.name
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let p = &self.params;
        let mut rng = Prng::new(seed ^ 0xCE47_4A11);
        // Precomputed jitter distributions (hot path: one sample per event).
        let g_sched = LognormalGen::new(p.sched_cost_per_task, p.jitter_cv);
        let g_complete = LognormalGen::new(p.complete_cost_per_task, p.jitter_cv);
        let g_launch = LognormalGen::new(p.launch_mean, p.launch_cv);
        let g_teardown = LognormalGen::new(p.teardown_mean, p.launch_cv);
        let g_submit = LognormalGen::new(p.submit_cost_job, p.jitter_cv);
        let n = workload.len();
        scratch.begin(cluster, n, options.collect_trace);
        let SimScratch {
            queue: q,
            pending,
            pool,
            slot_mem,
            trace,
            trace_idx,
            ..
        } = scratch;
        let mut daemon = ServiceStation::new();

        // Pending queue. Array mode: everything submitted at t<=0 in one
        // sbatch/qsub call; later arrivals (and individual mode) come in
        // through Arrive events that each pay a submission cost.
        if options.individual_submission {
            for t in &workload.tasks {
                q.push(t.submit_at.max(0.0), SimEv::Arrive { task: t.id });
            }
        } else {
            for t in &workload.tasks {
                if t.submit_at <= 0.0 {
                    pending.push_back(t.id);
                } else {
                    q.push(t.submit_at, SimEv::Arrive { task: t.id });
                }
            }
            if !pending.is_empty() {
                daemon.serve(
                    0.0,
                    p.submit_cost_base + p.submit_cost_per_task * pending.len() as f64,
                );
            }
        }
        q.push(daemon.free_at().max(0.0), SimEv::Tick);

        let mut makespan: f64 = 0.0;
        let mut completed: usize = 0;
        let mut waits = Summary::new();

        while let Some((now, ev)) = q.pop() {
            match ev {
                SimEv::Arrive { task } => {
                    daemon.serve(now, rng.lognormal(&g_submit));
                    pending.push_back(task);
                }
                SimEv::Tick => {
                    // Queue-management scan, capped.
                    let scan = p.scan_cost_per_pending * pending.len().min(p.scan_cap) as f64;
                    if scan > 0.0 {
                        daemon.serve(now, jit(&mut rng, scan, p.jitter_cv));
                    }
                    // Dispatch onto every free slot.
                    while !pending.is_empty() {
                        let task_id = *pending.front().unwrap();
                        let task = &workload.tasks[task_id as usize];
                        let Some(slot) = pool.alloc(task.mem_mb) else {
                            break;
                        };
                        pending.pop_front();
                        slot_mem[slot as usize] = task.mem_mb;
                        let fin = daemon.serve(now, rng.lognormal(&g_sched));
                        let launch = rng.lognormal(&g_launch);
                        q.push(fin + p.rpc + launch, SimEv::Start { task: task_id, slot });
                    }
                    if completed < n {
                        q.push(now + p.cycle_interval, SimEv::Tick);
                    }
                }
                SimEv::Start { task, slot } => {
                    let spec = &workload.tasks[task as usize];
                    waits.add(now - spec.submit_at);
                    if options.collect_trace {
                        trace_idx[task as usize] = trace.len() as u32;
                        trace.push(TraceRecord {
                            task,
                            node: pool.node_of(slot),
                            slot,
                            submit: spec.submit_at,
                            start: now,
                            end: 0.0, // patched on End
                        });
                    }
                    q.push(now + spec.duration, SimEv::End { task, slot });
                }
                SimEv::End { task, slot } => {
                    completed += 1;
                    makespan = makespan.max(now);
                    if options.collect_trace {
                        trace[trace_idx[task as usize] as usize].end = now;
                    }
                    let fin = daemon.serve(now, rng.lognormal(&g_complete));
                    let teardown = rng.lognormal(&g_teardown);
                    q.push(fin + teardown, SimEv::SlotFree { slot });
                }
                SimEv::SlotFree { slot } => {
                    pool.release(slot, slot_mem[slot as usize]);
                }
                SimEv::Stage { .. } => unreachable!("centralized sim emits no Stage events"),
            }
        }

        debug_assert_eq!(completed, n, "all tasks must complete");
        let processors = cluster.total_cores();
        let events = q.popped();
        RunResult {
            scheduler: p.name.to_string(),
            workload: workload.label.clone(),
            n_tasks: n as u64,
            processors,
            t_total: makespan,
            t_job: workload.t_job_per_proc(processors),
            events,
            daemon_busy: daemon.busy(),
            waits,
            trace: options.collect_trace.then(|| std::mem::take(trace)),
        }
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        // Max of the work bound and the central-daemon throughput bound.
        let p = cluster.total_cores() as f64;
        let per_task =
            self.params.sched_cost_per_task + self.params.complete_cost_per_task;
        (workload.total_work() / p).max(workload.len() as f64 * per_task)
    }
}

fn jit(rng: &mut Prng, mean: f64, cv: f64) -> f64 {
    rng.lognormal_mean_cv(mean, cv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::calibration;
    use crate::workload::WorkloadBuilder;

    fn quick_cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 8, 32 * 1024, 2)
    }

    #[test]
    fn completes_all_tasks_and_is_causal() {
        let sim = CentralizedSim::new(calibration::slurm_params());
        let w = WorkloadBuilder::constant(2.0).tasks(64).label("t").build();
        let r = sim.run(&w, &quick_cluster(), 1, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.n_tasks, 64);
        let trace = r.trace.as_ref().unwrap();
        assert!(trace.iter().all(|t| t.end > t.start));
    }

    #[test]
    fn deterministic_given_seed() {
        let sim = CentralizedSim::new(calibration::slurm_params());
        let w = WorkloadBuilder::constant(1.0).tasks(100).build();
        let a = sim.run(&w, &quick_cluster(), 7, &RunOptions::default());
        let b = sim.run(&w, &quick_cluster(), 7, &RunOptions::default());
        assert_eq!(a.t_total, b.t_total);
        let c = sim.run(&w, &quick_cluster(), 8, &RunOptions::default());
        assert_ne!(a.t_total, c.t_total);
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let sim = CentralizedSim::new(calibration::slurm_params());
        let cluster = quick_cluster();
        let w1 = WorkloadBuilder::constant(1.0).tasks(100).build();
        let w2 = WorkloadBuilder::constant(3.0).tasks(40).build();
        let mut scratch = SimScratch::new();
        // Warm the scratch on an unrelated run, then re-run both
        // workloads: results must match fresh-scratch runs exactly.
        sim.run_with_scratch(&w2, &cluster, 9, &RunOptions::with_trace(), &mut scratch);
        for (w, seed) in [(&w1, 7u64), (&w2, 8)] {
            let warm =
                sim.run_with_scratch(w, &cluster, seed, &RunOptions::with_trace(), &mut scratch);
            let fresh = sim.run(w, &cluster, seed, &RunOptions::with_trace());
            assert_eq!(warm.t_total.to_bits(), fresh.t_total.to_bits());
            assert_eq!(warm.events, fresh.events);
            assert_eq!(warm.trace.as_ref().unwrap(), fresh.trace.as_ref().unwrap());
        }
    }

    #[test]
    fn longer_tasks_improve_utilization() {
        let sim = CentralizedSim::new(calibration::slurm_params());
        let cluster = quick_cluster();
        let short = WorkloadBuilder::constant(1.0).tasks(16 * 60).build();
        let long = WorkloadBuilder::constant(60.0).tasks(16).build();
        let u_short = sim
            .run(&short, &cluster, 1, &RunOptions::default())
            .utilization();
        let u_long = sim
            .run(&long, &cluster, 1, &RunOptions::default())
            .utilization();
        assert!(
            u_long > u_short,
            "u_long={u_long} should beat u_short={u_short}"
        );
    }

    #[test]
    fn daemon_busy_scales_with_tasks() {
        let sim = CentralizedSim::new(calibration::slurm_params());
        let cluster = quick_cluster();
        let small = WorkloadBuilder::constant(1.0).tasks(32).build();
        let big = WorkloadBuilder::constant(1.0).tasks(320).build();
        let a = sim.run(&small, &cluster, 1, &RunOptions::default());
        let b = sim.run(&big, &cluster, 1, &RunOptions::default());
        // Per-task daemon work scales ~10x; the fixed submission cost
        // damps the ratio.
        assert!(b.daemon_busy > a.daemon_busy * 3.0);
    }
}
