//! Centralized cycle-based scheduler policy — the mechanism shared by
//! the Slurm-like and Grid-Engine-like models.
//!
//! Structure (mirrors slurmctld / sge_qmaster):
//!
//! * one central daemon = a serial [`ServiceStation`]; every scheduling
//!   decision and every completion notification transits it;
//! * a periodic scheduling cycle scans the pending queue (cost grows
//!   with queue depth, capped like Slurm's `default_queue_depth`) and
//!   dispatches tasks onto free core slots;
//! * dispatched tasks pay an RPC hop plus a node-daemon launch overhead
//!   before execution starts; completions pay daemon processing plus a
//!   node-side teardown before the slot is reusable.
//!
//! ΔT(n) emerges: at short task times the daemon saturates
//! (throughput = 1/(sched+complete cost) tasks/s) giving the steep
//! right side of Figure 4; at long task times per-task cycle waits and
//! stagger dominate, giving the shallow left side — together the
//! measured α_s ≈ 1.3 of Table 10.
//!
//! The event loop itself lives in [`crate::sim::Kernel`]; this file is
//! only the policy: submission/scan/dispatch/completion pricing.

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::{ClusterSpec, NodeId};
use crate::sim::{Kernel, KernelCtx, Launch, SchedPolicy, ServiceStation, SimEv, SimScratch, Time};
use crate::util::prng::{LognormalGen, Prng};
use crate::workload::{TaskId, Workload};

/// Tunable mechanism parameters for a centralized scheduler.
#[derive(Clone, Debug)]
pub struct CentralizedParams {
    /// Display name.
    pub name: &'static str,
    /// Scheduling cycle period (s). Slurm sched/builtin ~1 s; SoGE
    /// scheduler interval ~2 s in high-throughput config.
    pub cycle_interval: f64,
    /// Daemon cost to accept a job-array submission: base + per-task.
    pub submit_cost_base: f64,
    /// Per-task component of submission parsing.
    pub submit_cost_per_task: f64,
    /// Daemon cost to accept ONE job submitted individually (RPC +
    /// full job-record accounting) — the paper's "individual jobs"
    /// submission mode pays this per task.
    pub submit_cost_job: f64,
    /// Daemon serial cost per dispatch decision (allocation + launch RPC
    /// issue).
    pub sched_cost_per_task: f64,
    /// Daemon serial cost per completion record.
    pub complete_cost_per_task: f64,
    /// Pending-queue scan cost per queued element per cycle.
    pub scan_cost_per_pending: f64,
    /// Scan depth cap (Slurm default_queue_depth analog).
    pub scan_cap: usize,
    /// Node-daemon launch overhead mean (s).
    pub launch_mean: f64,
    /// Coefficient of variation of launch overhead.
    pub launch_cv: f64,
    /// Node-side teardown before the slot is reusable (s).
    pub teardown_mean: f64,
    /// One-way control RPC latency (s).
    pub rpc: f64,
    /// CV of lognormal jitter applied to daemon service times.
    pub jitter_cv: f64,
}

/// Centralized scheduler simulator (Slurm-like / GE-like by params).
pub struct CentralizedSim {
    params: CentralizedParams,
}

impl CentralizedSim {
    /// New simulator with the given mechanism parameters.
    pub fn new(params: CentralizedParams) -> Self {
        Self { params }
    }

    /// Access the parameters (used by calibration tests).
    pub fn params(&self) -> &CentralizedParams {
        &self.params
    }
}

/// Per-run policy state: the daemon station plus precomputed jitter
/// distributions (hot path: one sample per event).
struct CentralizedPolicy<'p> {
    p: &'p CentralizedParams,
    rng: Prng,
    g_sched: LognormalGen,
    g_complete: LognormalGen,
    g_launch: LognormalGen,
    g_teardown: LognormalGen,
    g_submit: LognormalGen,
    daemon: ServiceStation,
}

impl SchedPolicy for CentralizedPolicy<'_> {
    fn label(&self) -> String {
        self.p.name.to_string()
    }

    fn on_submit(&mut self, ctx: &mut KernelCtx, batch: usize) {
        // Array mode: everything at t<=0 arrived in one sbatch/qsub
        // call whose parsing cost scales with the array length.
        if batch > 0 {
            self.daemon.serve(
                0.0,
                self.p.submit_cost_base + self.p.submit_cost_per_task * batch as f64,
            );
        }
        ctx.push(self.daemon.free_at().max(0.0), SimEv::Tick);
    }

    fn on_arrive(&mut self, _ctx: &mut KernelCtx, now: Time, _task: TaskId) {
        self.daemon.serve(now, self.rng.lognormal(&self.g_submit));
    }

    fn tick_interval(&self) -> Option<Time> {
        Some(self.p.cycle_interval)
    }

    fn on_tick(&mut self, ctx: &mut KernelCtx, now: Time) {
        // Queue-management scan, capped.
        let scan = self.p.scan_cost_per_pending * ctx.pending_len().min(self.p.scan_cap) as f64;
        if scan > 0.0 {
            let cost = self.rng.lognormal_mean_cv(scan, self.p.jitter_cv);
            self.daemon.serve(now, cost);
        }
        // Dispatch onto every free slot.
        let (daemon, rng) = (&mut self.daemon, &mut self.rng);
        let (g_sched, g_launch, rpc) = (&self.g_sched, &self.g_launch, self.p.rpc);
        ctx.drain_fifo(&mut |_, _| {
            let fin = daemon.serve(now, rng.lognormal(g_sched));
            let launch = rng.lognormal(g_launch);
            Launch::start(fin + rpc + launch)
        });
    }

    fn on_complete(
        &mut self,
        _ctx: &mut KernelCtx,
        now: Time,
        _task: TaskId,
        _slot: u32,
    ) -> Option<Time> {
        let fin = self.daemon.serve(now, self.rng.lognormal(&self.g_complete));
        let teardown = self.rng.lognormal(&self.g_teardown);
        Some(fin + teardown)
    }

    // Node faults are deliberate no-ops here: the daemon's periodic
    // queue-management cycle (`on_tick`) already re-scans the pending
    // queue, so a killed task requeued by the kernel is re-admitted on
    // the next cycle exactly like a fresh arrival — which is how
    // slurmctld/sge_qmaster treat a requeued job — and a recovered
    // node's slots simply show up free to the next dispatch scan.
    fn on_node_fail(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    fn on_node_suspected(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {
        // Same reasoning as on_node_fail: the next queue-management
        // cycle re-admits whatever the (late) detection requeued.
    }

    fn on_node_drain(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    fn on_node_recover(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    fn daemon_busy(&self) -> f64 {
        self.daemon.busy()
    }
}

impl Scheduler for CentralizedSim {
    fn name(&self) -> &'static str {
        self.params.name
    }

    fn make_policy<'a>(&'a self, seed: u64) -> Option<Box<dyn SchedPolicy + 'a>> {
        let p = &self.params;
        Some(Box::new(CentralizedPolicy {
            p,
            rng: Prng::new(seed ^ 0xCE47_4A11),
            g_sched: LognormalGen::new(p.sched_cost_per_task, p.jitter_cv),
            g_complete: LognormalGen::new(p.complete_cost_per_task, p.jitter_cv),
            g_launch: LognormalGen::new(p.launch_mean, p.launch_cv),
            g_teardown: LognormalGen::new(p.teardown_mean, p.launch_cv),
            g_submit: LognormalGen::new(p.submit_cost_job, p.jitter_cv),
            daemon: ServiceStation::new(),
        }))
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let mut policy = self.make_policy(seed).expect("centralized is kernel-driven");
        Kernel::run(policy.as_mut(), workload, cluster, options, scratch)
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        // Max of the work bound and the central-daemon throughput bound.
        let p = cluster.total_cores() as f64;
        let per_task = self.params.sched_cost_per_task + self.params.complete_cost_per_task;
        (workload.total_work() / p).max(workload.len() as f64 * per_task)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::calibration;
    use crate::workload::WorkloadBuilder;

    fn quick_cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 8, 32 * 1024, 2)
    }

    #[test]
    fn completes_all_tasks_and_is_causal() {
        let sim = CentralizedSim::new(calibration::slurm_params());
        let w = WorkloadBuilder::constant(2.0).tasks(64).label("t").build();
        let r = sim.run(&w, &quick_cluster(), 1, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.n_tasks, 64);
        let trace = r.trace.as_ref().unwrap();
        assert!(trace.iter().all(|t| t.end > t.start));
    }

    #[test]
    fn deterministic_given_seed() {
        let sim = CentralizedSim::new(calibration::slurm_params());
        let w = WorkloadBuilder::constant(1.0).tasks(100).build();
        let a = sim.run(&w, &quick_cluster(), 7, &RunOptions::default());
        let b = sim.run(&w, &quick_cluster(), 7, &RunOptions::default());
        assert_eq!(a.t_total, b.t_total);
        let c = sim.run(&w, &quick_cluster(), 8, &RunOptions::default());
        assert_ne!(a.t_total, c.t_total);
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let sim = CentralizedSim::new(calibration::slurm_params());
        let cluster = quick_cluster();
        let w1 = WorkloadBuilder::constant(1.0).tasks(100).build();
        let w2 = WorkloadBuilder::constant(3.0).tasks(40).build();
        let mut scratch = SimScratch::new();
        // Warm the scratch on an unrelated run, then re-run both
        // workloads: results must match fresh-scratch runs exactly.
        sim.run_with_scratch(&w2, &cluster, 9, &RunOptions::with_trace(), &mut scratch);
        for (w, seed) in [(&w1, 7u64), (&w2, 8)] {
            let warm =
                sim.run_with_scratch(w, &cluster, seed, &RunOptions::with_trace(), &mut scratch);
            let fresh = sim.run(w, &cluster, seed, &RunOptions::with_trace());
            assert_eq!(warm.t_total.to_bits(), fresh.t_total.to_bits());
            assert_eq!(warm.events, fresh.events);
            assert_eq!(warm.trace.as_ref().unwrap(), fresh.trace.as_ref().unwrap());
        }
    }

    #[test]
    fn longer_tasks_improve_utilization() {
        let sim = CentralizedSim::new(calibration::slurm_params());
        let cluster = quick_cluster();
        let short = WorkloadBuilder::constant(1.0).tasks(16 * 60).build();
        let long = WorkloadBuilder::constant(60.0).tasks(16).build();
        let u_short = sim
            .run(&short, &cluster, 1, &RunOptions::default())
            .utilization();
        let u_long = sim
            .run(&long, &cluster, 1, &RunOptions::default())
            .utilization();
        assert!(
            u_long > u_short,
            "u_long={u_long} should beat u_short={u_short}"
        );
    }

    #[test]
    fn daemon_busy_scales_with_tasks() {
        let sim = CentralizedSim::new(calibration::slurm_params());
        let cluster = quick_cluster();
        let small = WorkloadBuilder::constant(1.0).tasks(32).build();
        let big = WorkloadBuilder::constant(1.0).tasks(320).build();
        let a = sim.run(&small, &cluster, 1, &RunOptions::default());
        let b = sim.run(&big, &cluster, 1, &RunOptions::default());
        // Per-task daemon work scales ~10x; the fixed submission cost
        // damps the ratio.
        assert!(b.daemon_busy > a.daemon_busy * 3.0);
    }

    #[test]
    fn dag_dependencies_respected_under_cycles() {
        // A chain through the centralized control plane: children must
        // not start before their parent's completion has been processed.
        let sim = CentralizedSim::new(calibration::slurm_params());
        let w = WorkloadBuilder::constant(2.0)
            .tasks(24)
            .dag_chains(4)
            .label("dag")
            .build();
        let r = sim.run(&w, &quick_cluster(), 3, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        let mut start = vec![0.0f64; 24];
        let mut end = vec![0.0f64; 24];
        for rec in trace {
            start[rec.task as usize] = rec.start;
            end[rec.task as usize] = rec.end;
        }
        for t in &w.tasks {
            for &d in &t.deps {
                assert!(
                    start[t.id as usize] >= end[d as usize] - 1e-9,
                    "task {} started before dep {d} finished",
                    t.id
                );
            }
        }
    }
}
