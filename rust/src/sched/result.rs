//! Simulation run results and the utilization arithmetic of the paper's
//! Section 4/5: U = T_job / T_total.

use crate::util::stats::Summary;
use crate::workload::TraceRecord;

/// Options controlling what a run records.
#[derive(Clone, Debug, Default)]
pub struct RunOptions {
    /// Collect a full per-task trace (memory ∝ N).
    pub collect_trace: bool,
    /// Submit every task as its own job (paying the per-job submission
    /// cost serially) instead of as one job array — the paper notes
    /// arrays "introduce much less scheduler latency".
    pub individual_submission: bool,
}

impl RunOptions {
    /// Trace-collecting options.
    pub fn with_trace() -> Self {
        Self {
            collect_trace: true,
            ..Default::default()
        }
    }
}

/// One contiguous productive execution span of a task on a slot.
///
/// Without preemption every task runs exactly one span; a preempted
/// task's work is split across several (one per dispatch), and the sum
/// of its span lengths equals its duration — the "no lost work"
/// contract `tests/preemption_properties.rs` pins. Checkpoint drain
/// time after an eviction is slot *occupancy*, not productive work, and
/// is deliberately excluded.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecSpan {
    /// Task id.
    pub task: u32,
    /// Primary slot the span executed on.
    pub slot: u32,
    /// Span start (virtual s).
    pub start: f64,
    /// Span end: completion or eviction instant (virtual s).
    pub end: f64,
}

impl ExecSpan {
    /// Span length in seconds.
    pub fn seconds(&self) -> f64 {
        self.end - self.start
    }
}

/// Outcome of one simulated (or realtime) trial.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Scheduler display name.
    pub scheduler: String,
    /// Workload label.
    pub workload: String,
    /// Task count N.
    pub n_tasks: u64,
    /// Processor (core slot) count P.
    pub processors: u64,
    /// Measured makespan T_total (virtual s): submission of the array to
    /// the end of its last task.
    pub t_total: f64,
    /// Isolated per-processor job time T_job = Σt / P.
    pub t_job: f64,
    /// Events processed by the simulator (work metric; 0 for realtime).
    pub events: u64,
    /// Seconds the central daemon / master spent busy.
    pub daemon_busy: f64,
    /// Summary of per-task scheduler-induced wait times.
    pub waits: Summary,
    /// Evictions executed by the kernel's preemption subsystem (0 for
    /// workloads without preemptible tasks).
    pub preemptions: u64,
    /// Optional full trace.
    pub trace: Option<Vec<TraceRecord>>,
    /// Productive execution spans, split at evictions. Collected only
    /// for traced runs of preemption-enabled workloads; `None`
    /// otherwise, so non-preempt results are unchanged.
    pub spans: Option<Vec<ExecSpan>>,
}

impl RunResult {
    /// Non-execution latency ΔT = T_total − T_job (the paper's measured
    /// quantity, Figure 4/6 y-axis).
    pub fn delta_t(&self) -> f64 {
        self.t_total - self.t_job
    }

    /// Utilization U = T_job / T_total (Figure 5/7 y-axis).
    pub fn utilization(&self) -> f64 {
        if self.t_total <= 0.0 {
            return 0.0;
        }
        self.t_job / self.t_total
    }

    /// Tasks per processor n = N / P.
    pub fn tasks_per_proc(&self) -> f64 {
        self.n_tasks as f64 / self.processors as f64
    }

    /// Sanity invariants every run must satisfy (used by tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        if !(self.t_total.is_finite() && self.t_total >= 0.0) {
            return Err(format!("bad t_total {}", self.t_total));
        }
        if self.t_total + 1e-9 < self.t_job {
            return Err(format!(
                "t_total {} < t_job {} — faster than physically possible",
                self.t_total, self.t_job
            ));
        }
        let u = self.utilization();
        if !(0.0..=1.0 + 1e-9).contains(&u) {
            return Err(format!("utilization {u} out of range"));
        }
        if let Some(trace) = &self.trace {
            if trace.len() as u64 != self.n_tasks {
                return Err(format!(
                    "trace has {} records for {} tasks",
                    trace.len(),
                    self.n_tasks
                ));
            }
            for r in trace {
                if r.start + 1e-9 < r.submit || r.end + 1e-9 < r.start {
                    return Err(format!("non-causal record {r:?}"));
                }
                if r.end > self.t_total + 1e-6 {
                    return Err(format!(
                        "task {} ends at {} after t_total {}",
                        r.task, r.end, self.t_total
                    ));
                }
            }
        }
        if let Some(spans) = &self.spans {
            for s in spans {
                if s.end + 1e-9 < s.start || s.end > self.t_total + 1e-6 {
                    return Err(format!("non-causal span {s:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(t_total: f64, t_job: f64) -> RunResult {
        RunResult {
            scheduler: "x".into(),
            workload: "w".into(),
            n_tasks: 10,
            processors: 2,
            t_total,
            t_job,
            events: 0,
            daemon_busy: 0.0,
            waits: Summary::new(),
            preemptions: 0,
            trace: None,
            spans: None,
        }
    }

    #[test]
    fn derived_quantities() {
        let r = result(300.0, 240.0);
        assert!((r.delta_t() - 60.0).abs() < 1e-12);
        assert!((r.utilization() - 0.8).abs() < 1e-12);
        assert!((r.tasks_per_proc() - 5.0).abs() < 1e-12);
        r.check_invariants().unwrap();
    }

    #[test]
    fn invariant_catches_impossible_runs() {
        assert!(result(100.0, 240.0).check_invariants().is_err());
        assert!(result(f64::NAN, 1.0).check_invariants().is_err());
    }
}
