//! Simulation run results and the utilization arithmetic of the paper's
//! Section 4/5: U = T_job / T_total.

use crate::cluster::{FaultPlan, MessagePlan};
use crate::util::stats::{Summary, WAIT_SAMPLE_CAP};
use crate::workload::TraceRecord;

/// Options controlling what a run records.
#[derive(Clone, Debug, Default)]
pub struct RunOptions {
    /// Collect a full per-task trace (memory ∝ N).
    pub collect_trace: bool,
    /// Submit every task as its own job (paying the per-job submission
    /// cost serially) instead of as one job array — the paper notes
    /// arrays "introduce much less scheduler latency".
    pub individual_submission: bool,
    /// Horizon-bounded run: the event loop executes only events at
    /// `t <= horizon`, [`crate::workload::JobKind::Service`] tasks
    /// occupy their slots from dispatch until the horizon, and the
    /// result carries windowed accounting ([`RunResult::horizon`],
    /// [`RunResult::busy_core_seconds`]). `None` (the default) is the
    /// classic run-to-completion mode; service tasks are rejected there
    /// because they never complete.
    pub horizon: Option<f64>,
    /// Deterministic node-lifecycle schedule injected into the run
    /// (mid-run failures, drains, recoveries). Empty (the default)
    /// bypasses the fault machinery entirely — runs are bit-identical
    /// to pre-fault-plan builds. Validated by
    /// [`crate::workload::Workload::validate_for`].
    pub faults: FaultPlan,
    /// Node-granular allocation (arXiv 2108.11359): the slot pool hands
    /// out cores from one open node at a time and consults the
    /// tournament tree only on node rollover, trading packing quality
    /// for allocation throughput on massive short-task streams. Changes
    /// placement, so results are *not* bit-identical to the default
    /// per-slot mode.
    pub node_granular: bool,
    /// Seeded control-plane perturbation: per-message latency draws,
    /// launch loss with capped exponential backoff, completion
    /// duplication. Empty (the default) bypasses the message machinery
    /// entirely — runs are bit-identical to pre-message-plan builds.
    pub messages: MessagePlan,
    /// Failure-detection timeout (seconds). 0 (the default) keeps the
    /// oracular instant-detection path: a `NodeFail` retires capacity
    /// and kills its tasks at the fail instant. When > 0, a failed
    /// node is only `Suspected` after this long without a heartbeat;
    /// doomed launches still target it in the window (work lost on
    /// detection) and a recovery inside the window is a free false
    /// alarm.
    pub detect_timeout: f64,
    /// Heartbeat emission period (seconds); 0 disables the explicit
    /// heartbeat events (detection then runs purely on the fail-timer).
    /// Only meaningful with `detect_timeout > 0`.
    pub heartbeat_period: f64,
    /// Speculative re-execution threshold: a task running longer than
    /// `speculate_factor ×` its class's streaming runtime estimate gets
    /// a duplicate launch; first completion wins and the loser's work
    /// counts as wasted. 0 (the default) disables speculation.
    pub speculate_factor: f64,
}

impl RunOptions {
    /// Trace-collecting options.
    pub fn with_trace() -> Self {
        Self {
            collect_trace: true,
            ..Default::default()
        }
    }

    /// Horizon-bounded (windowed) options — the only mode in which
    /// `JobKind::Service` tasks are valid.
    pub fn with_horizon(horizon: f64) -> Self {
        Self {
            horizon: Some(horizon),
            ..Default::default()
        }
    }

    /// Fault-injecting options.
    pub fn with_faults(faults: FaultPlan) -> Self {
        Self {
            faults,
            ..Default::default()
        }
    }

    /// Message-perturbing options.
    pub fn with_messages(messages: MessagePlan) -> Self {
        Self {
            messages,
            ..Default::default()
        }
    }

    /// Set the message plan (builder-style).
    pub fn messages(mut self, messages: MessagePlan) -> Self {
        self.messages = messages;
        self
    }

    /// Set heartbeat-based failure detection (builder-style).
    pub fn detection(mut self, detect_timeout: f64, heartbeat_period: f64) -> Self {
        self.detect_timeout = detect_timeout;
        self.heartbeat_period = heartbeat_period;
        self
    }

    /// Set the speculative re-execution factor (builder-style).
    pub fn speculation(mut self, speculate_factor: f64) -> Self {
        self.speculate_factor = speculate_factor;
        self
    }

    /// True iff any degraded-control-plane mechanism is active. False
    /// (the default) is the zero-cost bypass: no heartbeat/suspicion
    /// events, no message RNG stream, no speculation deadlines, and
    /// runs bit-identical to pre-degraded builds.
    pub fn degraded_active(&self) -> bool {
        !self.messages.is_empty() || self.detect_timeout > 0.0 || self.speculate_factor > 0.0
    }
}

/// One contiguous productive execution span of a task on a slot.
///
/// Without preemption every task runs exactly one span; a preempted
/// task's work is split across several (one per dispatch), and the sum
/// of its span lengths equals its duration — the "no lost work"
/// contract `tests/preemption_properties.rs` pins. Checkpoint drain
/// time after an eviction is slot *occupancy*, not productive work, and
/// is deliberately excluded.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecSpan {
    /// Task id.
    pub task: u32,
    /// Primary slot the span executed on.
    pub slot: u32,
    /// Span start (virtual s).
    pub start: f64,
    /// Span end: completion or eviction instant (virtual s).
    pub end: f64,
}

impl ExecSpan {
    /// Span length in seconds.
    pub fn seconds(&self) -> f64 {
        self.end - self.start
    }
}

/// Outcome of one simulated (or realtime) trial.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Scheduler display name.
    pub scheduler: String,
    /// Workload label.
    pub workload: String,
    /// Task count N.
    pub n_tasks: u64,
    /// Processor (core slot) count P.
    pub processors: u64,
    /// Measured makespan T_total (virtual s): submission of the array to
    /// the end of its last task.
    pub t_total: f64,
    /// Isolated per-processor job time T_job = Σt / P.
    pub t_job: f64,
    /// Events processed by the simulator (work metric; 0 for realtime).
    pub events: u64,
    /// Seconds the central daemon / master spent busy.
    pub daemon_busy: f64,
    /// Summary of per-task scheduler-induced wait times.
    pub waits: Summary,
    /// Streaming P² estimate of the median wait (NaN when no task ever
    /// started). Exact below 5 observations; within the P² marker error
    /// above — `wait_sample` carries the exactly-reconstructable tail
    /// for small runs.
    pub wait_p50: f64,
    /// Streaming P² estimate of the 95th-percentile wait (NaN when
    /// empty).
    pub wait_p95: f64,
    /// Streaming P² estimate of the 99th-percentile wait (NaN when
    /// empty).
    pub wait_p99: f64,
    /// Sorted bounded reservoir of wait observations (Algorithm R, cap
    /// [`WAIT_SAMPLE_CAP`], deterministic seed). Below the cap this IS
    /// the full sorted wait list, so small-n runs expose exact
    /// quantiles; above it, a uniform sample that shard merges condense.
    pub wait_sample: Vec<f64>,
    /// Evictions executed by the kernel's preemption subsystem (0 for
    /// workloads without preemptible tasks).
    pub preemptions: u64,
    /// Task kills executed by the fault subsystem: each node failure
    /// kills every task running there, losing its non-checkpointed work
    /// (unlike an eviction, which banks progress). 0 without a fault
    /// plan.
    pub kills: u64,
    /// Tasks that exhausted their retry budget (or were cascade-failed
    /// by a failed dependency) and never completed. 0 without a fault
    /// plan.
    pub failed: u64,
    /// Tasks that ran to completion. A horizonless run completes every
    /// non-failed task (`completed + failed == n_tasks`); a
    /// horizon-bounded run counts only tasks finished inside the window
    /// (services never are). The `churn` experiment's completion
    /// coverage is `completed / n_tasks`.
    pub completed: u64,
    /// Core-seconds of executed-then-lost work: the integral of killed
    /// runs' spans weighted by core count. Goodput subtracts this from
    /// the busy integral. 0 without a fault plan.
    pub wasted_core_seconds: f64,
    /// Observation window of a horizon-bounded run ([`RunOptions::horizon`]);
    /// `None` for classic run-to-completion trials. When set, `t_total`
    /// equals the window length.
    pub horizon: Option<f64>,
    /// Productive core-seconds executed inside the window: the integral
    /// of [`ExecSpan`]s (clipped to the horizon) weighted by each task's
    /// core count. Always 0 for horizonless runs, whose utilization
    /// derives from `t_job / t_total` instead.
    pub busy_core_seconds: f64,
    /// Per-failure detection latency (detection instant − fail
    /// instant), one entry per *detected* real failure, in detection
    /// order. Empty with `detect_timeout = 0` (oracular detection) —
    /// and for false alarms, which are never detected.
    pub detection_latencies: Vec<f64>,
    /// Core-seconds of killed work accrued *after* the true fail
    /// instant — the part of `wasted_core_seconds` an oracular detector
    /// would not have lost (launches doomed onto an undetected-dead
    /// node, plus the undetected tail of runs already there). Always a
    /// subset of `wasted_core_seconds`; 0 with instant detection.
    pub undetected_lost_core_seconds: f64,
    /// Launch RPCs lost by the `MessagePlan` (each is retried with
    /// capped exponential backoff). 0 without a plan.
    pub messages_lost: u64,
    /// Completion notifications the `MessagePlan` delivered twice; the
    /// dispatch-epoch check drops every duplicate, so accounting stays
    /// exactly-once. 0 without a plan.
    pub messages_duplicated: u64,
    /// Speculative duplicate launches issued. 0 with speculation off.
    pub spec_launches: u64,
    /// Speculation losers killed (primary or duplicate — whichever
    /// finished second); each loser's work is in `wasted_core_seconds`.
    pub spec_kills: u64,
    /// Retry histogram of fault kills: `retry_hist[k]` counts tasks
    /// killed exactly `k` times, so `Σ k · retry_hist[k] == kills`.
    /// Empty without a fault plan.
    pub retry_hist: Vec<u64>,
    /// Optional full trace.
    pub trace: Option<Vec<TraceRecord>>,
    /// Productive execution spans, split at evictions. Collected only
    /// for traced runs of preemption-enabled workloads; `None`
    /// otherwise, so non-preempt results are unchanged.
    pub spans: Option<Vec<ExecSpan>>,
}

impl RunResult {
    /// Non-execution latency ΔT = T_total − T_job (the paper's measured
    /// quantity, Figure 4/6 y-axis).
    pub fn delta_t(&self) -> f64 {
        self.t_total - self.t_job
    }

    /// Utilization. Horizon-bounded runs use the windowed definition
    /// `busy_core_seconds / (P · horizon)` — the fraction of the
    /// cluster's core-time inside the window spent on productive work —
    /// because service tasks have no meaningful completion time.
    /// Horizonless runs keep the paper's U = T_job / T_total
    /// (Figure 5/7 y-axis).
    pub fn utilization(&self) -> f64 {
        if let Some(h) = self.horizon {
            if h <= 0.0 || self.processors == 0 {
                return 0.0;
            }
            return self.busy_core_seconds / (h * self.processors as f64);
        }
        if self.t_total <= 0.0 {
            return 0.0;
        }
        self.t_job / self.t_total
    }

    /// Tasks per processor n = N / P.
    pub fn tasks_per_proc(&self) -> f64 {
        self.n_tasks as f64 / self.processors as f64
    }

    /// Goodput utilization of a windowed run: productive core-seconds
    /// that were *not* later lost to a node failure, over `P · h` —
    /// `(busy − wasted) / (P · h)`. Equals [`Self::utilization`] when
    /// nothing was killed; horizonless runs fall back to it.
    pub fn goodput_utilization(&self) -> f64 {
        if let Some(h) = self.horizon {
            if h <= 0.0 || self.processors == 0 {
                return 0.0;
            }
            return (self.busy_core_seconds - self.wasted_core_seconds).max(0.0)
                / (h * self.processors as f64);
        }
        self.utilization()
    }

    /// Sanity invariants every run must satisfy (used by tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        if !(self.t_total.is_finite() && self.t_total >= 0.0) {
            return Err(format!("bad t_total {}", self.t_total));
        }
        if self.horizon.is_none() && self.t_total + 1e-9 < self.t_job {
            // A horizon-bounded run legitimately observes less than the
            // workload's isolated job time — the window simply closed.
            return Err(format!(
                "t_total {} < t_job {} — faster than physically possible",
                self.t_total, self.t_job
            ));
        }
        let u = self.utilization();
        if !(0.0..=1.0 + 1e-9).contains(&u) {
            return Err(format!("utilization {u} out of range"));
        }
        if !(self.daemon_busy.is_finite() && self.daemon_busy >= 0.0) {
            return Err(format!("bad daemon_busy {}", self.daemon_busy));
        }
        if !(self.wasted_core_seconds.is_finite() && self.wasted_core_seconds >= 0.0) {
            return Err(format!(
                "bad wasted_core_seconds {}",
                self.wasted_core_seconds
            ));
        }
        if !self.retry_hist.is_empty() {
            let hist_kills: u64 = self
                .retry_hist
                .iter()
                .enumerate()
                .map(|(k, &c)| k as u64 * c)
                .sum();
            if hist_kills != self.kills {
                return Err(format!(
                    "retry histogram sums to {hist_kills} kills but the run recorded {}",
                    self.kills
                ));
            }
        }
        for (i, d) in self.detection_latencies.iter().enumerate() {
            if !(d.is_finite() && *d >= 0.0) {
                return Err(format!("detection latency {i} is {d}"));
            }
        }
        if !(self.undetected_lost_core_seconds.is_finite()
            && self.undetected_lost_core_seconds >= 0.0
            && self.undetected_lost_core_seconds <= self.wasted_core_seconds + 1e-6)
        {
            return Err(format!(
                "undetected_lost_core_seconds {} outside [0, wasted = {}]",
                self.undetected_lost_core_seconds, self.wasted_core_seconds
            ));
        }
        if self.spec_kills > self.spec_launches {
            return Err(format!(
                "{} speculation losers killed but only {} duplicates launched",
                self.spec_kills, self.spec_launches
            ));
        }
        if self.failed > self.n_tasks {
            return Err(format!(
                "{} failed tasks out of {}",
                self.failed, self.n_tasks
            ));
        }
        if self.completed + self.failed > self.n_tasks {
            return Err(format!(
                "{} completed + {} failed exceeds {} tasks",
                self.completed, self.failed, self.n_tasks
            ));
        }
        if self.horizon.is_none() && self.completed + self.failed != self.n_tasks {
            return Err(format!(
                "horizonless run completed {} + failed {} != {} tasks",
                self.completed, self.failed, self.n_tasks
            ));
        }
        if self.waits.count() > self.n_tasks {
            return Err(format!(
                "{} wait observations for {} tasks",
                self.waits.count(),
                self.n_tasks
            ));
        }
        if self.waits.count() > 0 && (self.waits.min() < -1e-9 || !self.waits.mean().is_finite()) {
            return Err(format!(
                "negative or non-finite waits: min {} mean {}",
                self.waits.min(),
                self.waits.mean()
            ));
        }
        if self.waits.count() > 0 {
            let lo = self.waits.min() - 1e-9;
            let hi = self.waits.max() + 1e-9;
            for (name, q) in [
                ("wait_p50", self.wait_p50),
                ("wait_p95", self.wait_p95),
                ("wait_p99", self.wait_p99),
            ] {
                if !q.is_finite() || q < lo || q > hi {
                    return Err(format!(
                        "{name} {q} outside observed wait range [{}, {}]",
                        self.waits.min(),
                        self.waits.max()
                    ));
                }
            }
            if self.wait_p50 > self.wait_p95 + 1e-9 || self.wait_p95 > self.wait_p99 + 1e-9 {
                return Err(format!(
                    "non-monotone wait quantiles p50 {} p95 {} p99 {}",
                    self.wait_p50, self.wait_p95, self.wait_p99
                ));
            }
        }
        let cap = (self.waits.count() as usize).min(WAIT_SAMPLE_CAP);
        if self.wait_sample.len() > cap {
            return Err(format!(
                "wait_sample holds {} entries for {} observations (cap {})",
                self.wait_sample.len(),
                self.waits.count(),
                WAIT_SAMPLE_CAP
            ));
        }
        match self.horizon {
            Some(h) => {
                if !(h.is_finite() && h > 0.0) {
                    return Err(format!("bad horizon {h}"));
                }
                let cap = h * self.processors as f64;
                if !(self.busy_core_seconds >= 0.0 && self.busy_core_seconds <= cap * (1.0 + 1e-9))
                {
                    return Err(format!(
                        "busy_core_seconds {} outside [0, P·h = {cap}]",
                        self.busy_core_seconds
                    ));
                }
                // Wasted work is a subset of executed work: every killed
                // span accrued busy core-seconds before it was lost.
                if self.wasted_core_seconds > self.busy_core_seconds * (1.0 + 1e-9) + 1e-6 {
                    return Err(format!(
                        "wasted_core_seconds {} exceeds busy_core_seconds {}",
                        self.wasted_core_seconds, self.busy_core_seconds
                    ));
                }
            }
            None => {
                if self.busy_core_seconds != 0.0 {
                    return Err(format!(
                        "horizonless run carries busy_core_seconds {}",
                        self.busy_core_seconds
                    ));
                }
                // Preemption/kill accounting: a traced run records one
                // span per dispatch, so spans = completions (= N −
                // failed; every non-failed task finishes in a
                // horizonless run) + evictions + kills + speculation
                // losers (each loser's run closes its own span).
                if let (Some(spans), Some(_)) = (&self.spans, &self.trace) {
                    let expect = self.n_tasks - self.failed
                        + self.preemptions
                        + self.kills
                        + self.spec_kills;
                    if spans.len() as u64 != expect {
                        return Err(format!(
                            "{} spans for {} tasks − {} failed + {} preemptions + {} kills + {} spec_kills",
                            spans.len(),
                            self.n_tasks,
                            self.failed,
                            self.preemptions,
                            self.kills,
                            self.spec_kills
                        ));
                    }
                }
            }
        }
        if let Some(trace) = &self.trace {
            // A window can close before every task starts, and a failed
            // task may never have started (dep-cascade); a
            // run-to-completion trial must start (and record) every
            // other task. Either way a task never has more than one
            // record.
            if trace.len() as u64 > self.n_tasks
                || (self.horizon.is_none()
                    && (trace.len() as u64) < self.n_tasks - self.failed)
            {
                return Err(format!(
                    "trace has {} records for {} tasks (horizon {:?})",
                    trace.len(),
                    self.n_tasks,
                    self.horizon
                ));
            }
            for r in trace {
                if r.start + 1e-9 < r.submit || r.end + 1e-9 < r.start {
                    return Err(format!("non-causal record {r:?}"));
                }
                if r.end > self.t_total + 1e-6 {
                    return Err(format!(
                        "task {} ends at {} after t_total {}",
                        r.task, r.end, self.t_total
                    ));
                }
            }
        }
        if let Some(spans) = &self.spans {
            for s in spans {
                if s.end + 1e-9 < s.start || s.end > self.t_total + 1e-6 {
                    return Err(format!("non-causal span {s:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(t_total: f64, t_job: f64) -> RunResult {
        RunResult {
            scheduler: "x".into(),
            workload: "w".into(),
            n_tasks: 10,
            processors: 2,
            t_total,
            t_job,
            events: 0,
            daemon_busy: 0.0,
            waits: Summary::new(),
            wait_p50: f64::NAN,
            wait_p95: f64::NAN,
            wait_p99: f64::NAN,
            wait_sample: Vec::new(),
            preemptions: 0,
            kills: 0,
            failed: 0,
            completed: 10,
            wasted_core_seconds: 0.0,
            horizon: None,
            busy_core_seconds: 0.0,
            detection_latencies: Vec::new(),
            undetected_lost_core_seconds: 0.0,
            messages_lost: 0,
            messages_duplicated: 0,
            spec_launches: 0,
            spec_kills: 0,
            retry_hist: Vec::new(),
            trace: None,
            spans: None,
        }
    }

    #[test]
    fn derived_quantities() {
        let r = result(300.0, 240.0);
        assert!((r.delta_t() - 60.0).abs() < 1e-12);
        assert!((r.utilization() - 0.8).abs() < 1e-12);
        assert!((r.tasks_per_proc() - 5.0).abs() < 1e-12);
        r.check_invariants().unwrap();
    }

    #[test]
    fn invariant_catches_impossible_runs() {
        assert!(result(100.0, 240.0).check_invariants().is_err());
        assert!(result(f64::NAN, 1.0).check_invariants().is_err());
    }

    #[test]
    fn windowed_utilization_uses_busy_core_seconds() {
        // 2 processors, 10 s window, 15 busy core-seconds -> U = 0.75.
        let mut r = result(10.0, 240.0); // t_job > window is fine with a horizon
        r.horizon = Some(10.0);
        r.busy_core_seconds = 15.0;
        assert!((r.utilization() - 0.75).abs() < 1e-12);
        r.check_invariants().unwrap();
        // Busy time above P·h is an accounting bug.
        r.busy_core_seconds = 25.0;
        assert!(r.check_invariants().is_err());
    }

    #[test]
    fn invariant_catches_bad_accounting() {
        let mut r = result(300.0, 240.0);
        r.daemon_busy = -1.0;
        assert!(r.check_invariants().unwrap_err().contains("daemon_busy"));
        let mut r = result(300.0, 240.0);
        r.daemon_busy = f64::NAN;
        assert!(r.check_invariants().is_err());
        // More wait observations than tasks.
        let mut r = result(300.0, 240.0);
        r.waits = Summary::of(&[1.0; 11]);
        assert!(r.check_invariants().unwrap_err().contains("wait"));
        // Negative waits.
        let mut r = result(300.0, 240.0);
        r.waits = Summary::of(&[-2.0]);
        assert!(r.check_invariants().is_err());
        // Horizonless runs must not carry windowed busy time.
        let mut r = result(300.0, 240.0);
        r.busy_core_seconds = 1.0;
        assert!(r.check_invariants().is_err());
    }

    #[test]
    fn invariant_checks_streaming_wait_quantiles() {
        let mut r = result(300.0, 240.0);
        r.waits = Summary::of(&[1.0, 2.0, 3.0]);
        r.wait_p50 = 2.0;
        r.wait_p95 = 2.9;
        r.wait_p99 = 3.0;
        r.wait_sample = vec![1.0, 2.0, 3.0];
        r.check_invariants().unwrap();
        // A quantile outside the observed wait range.
        r.wait_p99 = 4.0;
        assert!(r.check_invariants().unwrap_err().contains("wait_p99"));
        // Non-monotone quantiles.
        r.wait_p99 = 3.0;
        r.wait_p50 = 3.0;
        r.wait_p95 = 1.5;
        assert!(r.check_invariants().unwrap_err().contains("non-monotone"));
        // More sample entries than observations.
        r.wait_p50 = 2.0;
        r.wait_p95 = 2.9;
        r.wait_sample = vec![1.0, 2.0, 2.0, 3.0];
        assert!(r.check_invariants().unwrap_err().contains("wait_sample"));
    }

    #[test]
    fn invariant_checks_span_count_against_preemptions() {
        let mut r = result(300.0, 240.0);
        r.n_tasks = 2;
        r.preemptions = 1;
        r.trace = Some(vec![
            TraceRecord {
                task: 0,
                node: 0,
                slot: 0,
                submit: 0.0,
                start: 0.0,
                end: 5.0,
            },
            TraceRecord {
                task: 1,
                node: 0,
                slot: 1,
                submit: 0.0,
                start: 0.0,
                end: 3.0,
            },
        ]);
        // 2 tasks + 1 eviction must yield 3 spans; 2 is a lost span.
        let spans = |n: usize| {
            Some(
                (0..n)
                    .map(|i| ExecSpan {
                        task: i as u32,
                        slot: 0,
                        start: 0.0,
                        end: 1.0,
                    })
                    .collect::<Vec<_>>(),
            )
        };
        r.spans = spans(3);
        r.check_invariants().unwrap();
        r.spans = spans(2);
        assert!(r.check_invariants().unwrap_err().contains("spans"));
        // A kill also splits a span off; a failed task contributes its
        // killed spans but no completion span.
        r.preemptions = 0;
        r.kills = 2;
        r.failed = 1;
        r.spans = spans(3); // (2 − 1 completions) + 2 kills = 3
        r.check_invariants().unwrap();
        r.spans = spans(4);
        assert!(r.check_invariants().unwrap_err().contains("kills"));
    }

    #[test]
    fn invariant_catches_bad_fault_accounting() {
        let mut r = result(300.0, 240.0);
        r.wasted_core_seconds = -1.0;
        assert!(r.check_invariants().unwrap_err().contains("wasted"));
        let mut r = result(300.0, 240.0);
        r.wasted_core_seconds = f64::NAN;
        assert!(r.check_invariants().is_err());
        let mut r = result(300.0, 240.0);
        r.failed = 11; // > n_tasks
        assert!(r.check_invariants().unwrap_err().contains("failed"));
    }

    #[test]
    fn invariant_catches_wasted_exceeding_busy_on_windowed_runs() {
        // Regression: wasted work is carved out of executed work, so a
        // windowed run reporting more wasted than busy core-seconds is
        // an accounting bug that used to slip through check_invariants.
        let mut r = result(10.0, 240.0);
        r.horizon = Some(10.0);
        r.busy_core_seconds = 6.0;
        r.wasted_core_seconds = 6.0;
        r.kills = 1;
        r.check_invariants().unwrap();
        r.wasted_core_seconds = 6.5;
        let err = r.check_invariants().unwrap_err();
        assert!(err.contains("exceeds busy_core_seconds"), "got: {err}");
    }

    #[test]
    fn invariant_checks_retry_histogram_sums_to_kills() {
        // Regression: the retry histogram must account for every kill —
        // Σ k · hist[k] == kills.
        let mut r = result(300.0, 240.0);
        r.kills = 5;
        r.retry_hist = vec![7, 3, 1]; // 3 tasks killed once + 1 twice = 5
        r.check_invariants().unwrap();
        r.retry_hist = vec![7, 3, 0]; // sums to 3, not 5
        let err = r.check_invariants().unwrap_err();
        assert!(err.contains("retry histogram"), "got: {err}");
        // An empty histogram (no fault plan) is always consistent.
        r.retry_hist = Vec::new();
        r.kills = 0;
        r.check_invariants().unwrap();
    }

    #[test]
    fn invariant_checks_degraded_accounting() {
        // Undetected loss is a subset of wasted work.
        let mut r = result(300.0, 240.0);
        r.wasted_core_seconds = 2.0;
        r.undetected_lost_core_seconds = 3.0;
        assert!(r
            .check_invariants()
            .unwrap_err()
            .contains("undetected_lost_core_seconds"));
        r.undetected_lost_core_seconds = 1.5;
        r.check_invariants().unwrap();
        // Detection latencies must be finite and non-negative.
        r.detection_latencies = vec![0.5, -0.1];
        assert!(r
            .check_invariants()
            .unwrap_err()
            .contains("detection latency"));
        r.detection_latencies = vec![0.5, 0.5];
        r.check_invariants().unwrap();
        // More speculation losers than duplicates launched.
        r.spec_kills = 2;
        r.spec_launches = 1;
        assert!(r.check_invariants().unwrap_err().contains("speculation"));
    }

    #[test]
    fn goodput_subtracts_wasted_work_in_windowed_runs() {
        // 2 processors, 10 s window: 15 busy core-seconds of which 5
        // were later lost to kills -> U = 0.75, goodput = 0.5.
        let mut r = result(10.0, 240.0);
        r.horizon = Some(10.0);
        r.busy_core_seconds = 15.0;
        r.wasted_core_seconds = 5.0;
        r.kills = 1;
        assert!((r.utilization() - 0.75).abs() < 1e-12);
        assert!((r.goodput_utilization() - 0.5).abs() < 1e-12);
        r.check_invariants().unwrap();
        // Horizonless: goodput falls back to the paper's definition.
        let r = result(300.0, 240.0);
        assert_eq!(r.goodput_utilization(), r.utilization());
    }
}
