//! Policy combinators: reusable queue-ordering and preemption layers
//! that compose with any [`SchedPolicy`].
//!
//! batchq owned the only priority / fairshare / EASY-backfill
//! implementation in the tree, fused into its private drain loop, so
//! none of the Table 9 control-plane models could be run with (say)
//! Slurm-like costs *plus* fairshare ordering *plus* priority
//! preemption — the configuration real Slurm/SGE/YARN deployments use
//! to recover short-job responsiveness (Reuther et al. 2016, "Scheduler
//! Technologies in Support of High Performance Data Analysis"). This
//! module extracts that machinery into three composable pieces:
//!
//! * [`sort_queue`] + [`FairTracker`] — the canonical ordering
//!   comparators ([`Order`]), shared verbatim by batchq's drain and the
//!   generic wrapper (the unit tests pin bit-identity against an inline
//!   copy of batchq's pre-refactor drain);
//! * [`OrderedDrain`] — batchq's full policy-ordered dispatch pass
//!   (strict head-of-line blocking or EASY backfill with
//!   [`shadow_time`] reservations), reusable by any policy that drains
//!   through [`KernelCtx::try_dispatch`];
//! * [`Ordered`] / [`Preemptive`] — [`SchedPolicy`] wrappers. `Ordered`
//!   drives the kernel's incremental ordered ready-queue
//!   ([`crate::sim::OrderIndex`]) so the inner FIFO drain follows the
//!   discipline at O(log n) per queue operation (the original
//!   implementation re-sorted the whole pending queue before every
//!   dispatch opportunity — the quadratic hot path the `scale`
//!   experiment measures), while still pricing every launch with the
//!   inner policy's own cost model. `Preemptive` adds priority
//!   preemption on top: when the best-priority queued task cannot
//!   start, it nominates lower-priority preemptible running tasks as
//!   victims through [`SchedPolicy::on_preempt_candidates`], and the
//!   kernel executes the evictions.
//!
//! `Preemptive` should wrap an `Ordered` policy (see
//! [`make_preemptive`]): with a plain FIFO inner drain an evicted
//! victim re-queues behind the trigger task, which terminates but
//! thrashes; priority ordering gives preemption its intent.

use crate::cluster::{ClusterSpec, NodeId, SlotId};
use crate::sched::{RunOptions, RunResult, Scheduler};
use crate::sim::{Kernel, KernelCtx, LaunchFn, OrderMode, SchedPolicy, SimScratch, Time};
use crate::workload::{JobKind, TaskId, TaskSpec, Workload};
use std::collections::{BTreeMap, VecDeque};

/// Queue-ordering discipline applied ahead of a dispatch pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Order {
    /// Arrival order (no re-ordering).
    Fifo,
    /// Static priority (higher first), stable within a level.
    Priority,
    /// Fair share: users with less accumulated usage go first.
    Fairshare,
}

impl Order {
    /// Short label used in scheduler display names.
    pub fn label(&self) -> &'static str {
        match self {
            Order::Fifo => "fifo",
            Order::Priority => "prio",
            Order::Fairshare => "fair",
        }
    }
}

/// Accumulated core-seconds per user, the fairshare ordering key.
#[derive(Clone, Debug, Default)]
pub struct FairTracker {
    usage: BTreeMap<u32, f64>,
}

impl FairTracker {
    /// Empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `core_seconds` of usage to `user`.
    pub fn charge(&mut self, user: u32, core_seconds: f64) {
        *self.usage.entry(user).or_default() += core_seconds;
    }

    /// Accumulated usage of `user` (0 if never charged).
    pub fn usage(&self, user: u32) -> f64 {
        self.usage.get(&user).copied().unwrap_or(0.0)
    }
}

/// Sort `queue` (task ids into `tasks`) by `order`: (priority desc) or
/// (usage asc) with task id as the final tie-break — the comparators
/// batchq's pre-combinator drain used. The tie-break makes the order
/// total, so `sort_unstable_by` (allocation-free) produces the exact
/// permutation the historical stable sort did; the regression test
/// against the inline legacy drain pins this.
pub fn sort_queue(order: Order, tasks: &[TaskSpec], usage: &FairTracker, queue: &mut [TaskId]) {
    match order {
        Order::Fifo => {}
        Order::Priority => queue.sort_unstable_by(|&a, &b| {
            tasks[b as usize]
                .priority
                .cmp(&tasks[a as usize].priority)
                .then(a.cmp(&b))
        }),
        Order::Fairshare => queue.sort_unstable_by(|&a, &b| {
            let ua = usage.usage(tasks[a as usize].user);
            let ub = usage.usage(tasks[b as usize].user);
            ua.total_cmp(&ub).then(a.cmp(&b))
        }),
    }
}

/// Earliest time `need` cores are simultaneously free given the
/// currently `running` set `(end_time, cores, task)`, and the spare
/// cores left at that time (the EASY-backfill window test).
pub fn shadow_time(mut free: u32, need: u32, running: &[(f64, u32, u32)]) -> (f64, u32) {
    let mut ends: Vec<(f64, u32)> = running.iter().map(|&(e, c, _)| (e, c)).collect();
    ends.sort_by(|a, b| a.0.total_cmp(&b.0));
    for &(end, cores) in &ends {
        if free >= need {
            break;
        }
        free += cores;
        if free >= need {
            return (end, free - need);
        }
    }
    if free >= need {
        (0.0, free - need)
    } else {
        (f64::INFINITY, 0)
    }
}

/// One policy-ordered dispatch pass over the kernel's pending queue:
/// order the snapshot, dispatch greedily with head-of-line blocking,
/// and (optionally) EASY-backfill smaller tasks past a blocked head if
/// they cannot delay its [`shadow_time`] reservation. This is batchq's
/// historical drain, verbatim, parameterized over the launch pricing —
/// the `running`/`usage` state lives with the caller so tick-driven
/// and event-driven policies can both reuse it.
///
/// Deliberately **not** converted to the incremental
/// [`crate::sim::OrderIndex`] (unlike [`Ordered`]/[`Preemptive`]): this
/// drain charges fairshare usage *at dispatch, mid-pass*, while the
/// legacy (bit-pinned) semantics order the whole pass by the snapshot
/// taken at pass *start* — a live index would re-rank later candidates
/// within the same pass and change results; and the EASY-backfill
/// branch inherently enumerates every queued candidate per pass anyway,
/// so a per-pass sort is not the asymptotic bottleneck. What this PR
/// does fix here is the other quadratic half: each
/// [`KernelCtx::try_dispatch`] call is now an O(1) membership check
/// instead of a full queue scan.
#[derive(Clone, Copy, Debug)]
pub struct OrderedDrain {
    /// Ordering applied to the pending snapshot.
    pub order: Order,
    /// EASY backfill past a blocked head (FCFS reservation semantics).
    pub backfill: bool,
}

impl OrderedDrain {
    /// Run one pass at `now`. `running` is the caller's live set of
    /// `(end_time, cores, task)` entries (pruned on completion);
    /// `usage` the caller's fairshare account, charged at dispatch.
    pub fn drain(
        &self,
        ctx: &mut KernelCtx,
        now: Time,
        usage: &mut FairTracker,
        running: &mut Vec<(f64, u32, u32)>,
        launch: &mut LaunchFn,
    ) {
        let mut queue = ctx.pending_snapshot();
        sort_queue(self.order, &ctx.workload().tasks, usage, &mut queue);
        // A dispatched service never completes, so its cores never free:
        // an infinite release time keeps shadow_time honest (a head that
        // needs service-pinned cores has no finite reservation, and
        // backfill past it is then unconditionally harmless). Its
        // fairshare charge is its duration (0) — usage is accrued per
        // completed work, which a service never banks.
        let frees_at = |spec: &TaskSpec, now: Time| {
            if spec.kind == JobKind::Service {
                f64::INFINITY
            } else {
                now + spec.duration
            }
        };
        let mut blocked_head: Option<TaskId> = None;
        for idx in queue {
            let spec = &ctx.workload().tasks[idx as usize];
            if blocked_head.is_none() {
                if ctx.try_dispatch(idx, launch) {
                    running.push((frees_at(spec, now), spec.cores, idx));
                    usage.charge(spec.user, spec.cores as f64 * spec.duration);
                } else {
                    // Head-of-line blocked.
                    blocked_head = Some(idx);
                    if !self.backfill {
                        break; // strict policies stop here
                    }
                }
            } else {
                // EASY backfill: shadow time = earliest instant the
                // head task could start given current running tasks.
                let head = &ctx.workload().tasks[blocked_head.expect("head set") as usize];
                let free = ctx.free_slots() as u32;
                let (shadow, spare) = shadow_time(free, head.cores, running);
                let fits_now = spec.cores <= free;
                // frees_at, not raw duration: a service candidate holds
                // its cores forever, so it may only jump the head when
                // it fits in the spare cores (or the head itself can
                // never start).
                let no_delay = frees_at(spec, now) <= shadow + 1e-9 || spec.cores <= spare;
                if fits_now && no_delay && ctx.try_dispatch(idx, launch) {
                    running.push((frees_at(spec, now), spec.cores, idx));
                    usage.charge(spec.user, spec.cores as f64 * spec.duration);
                }
            }
        }
    }
}

/// [`SchedPolicy`] wrapper imposing a queue-ordering discipline on any
/// inner policy. Historically this re-sorted the kernel's entire
/// pending queue in place before *every* dispatch hook — O(n log n)
/// per event, the dominant quadratic term of ordered runs at scale. It
/// now activates the kernel's **incremental** ordered ready-queue
/// ([`crate::sim::OrderIndex`]): insertions are O(log n), the inner
/// FIFO drain walks the index in `order`, and fairshare usage charges
/// are O(1) because usage ranks whole users (no per-task re-keying, no
/// rebuilds). Dispatch decisions are bit-identical to the eager sort —
/// [`Ordered::new_eager`] keeps the legacy full-sort path alive as the
/// differential oracle and perf baseline, and
/// `tests/pool_equivalence.rs` pins the two against each other.
///
/// Fairshare ordering is the wrapper-specific refinement over batchq's
/// pure fairshare: usage ties break by priority before id (Slurm
/// multifactor-style). Usage is charged at completion (`on_complete` is
/// the only dispatch-independent signal a wrapper observes without
/// breaking the inner policy's pricing), so a freshly evicted victim
/// ties with the high-priority task that triggered its eviction — a
/// plain id tie-break would hand the freed slot straight back to the
/// victim and make preemption pure churn.
pub struct Ordered<P> {
    order: Order,
    inner: P,
    /// Oracle mode: rebuild the index with a full legacy-style sort
    /// before every dispatch hook instead of trusting the incremental
    /// maintenance. Same results, legacy O(n log n)-per-event cost.
    eager: bool,
}

impl<P: SchedPolicy> Ordered<P> {
    /// Wrap `inner` with `order` (incremental index maintenance).
    pub fn new(order: Order, inner: P) -> Self {
        Self::with_eager(order, inner, false)
    }

    /// Wrap `inner` with `order` in eager-sort oracle mode: the ordered
    /// index is rebuilt by a full sort before every dispatch
    /// opportunity, reproducing the legacy per-event `sort_queue` cost.
    /// Results are bit-identical to [`Ordered::new`]; the differential
    /// suite asserts it and the `scale`/`perf_engine` speedup numbers
    /// are measured against this baseline.
    pub fn new_eager(order: Order, inner: P) -> Self {
        Self::with_eager(order, inner, true)
    }

    /// Shared constructor behind [`Ordered::new`]/[`Ordered::new_eager`]
    /// and the `OrderedSim`/`PreemptiveSim` adapters.
    fn with_eager(order: Order, inner: P, eager: bool) -> Self {
        Self {
            order,
            inner,
            eager,
        }
    }

    fn mode(&self) -> Option<OrderMode> {
        match self.order {
            Order::Fifo => None,
            Order::Priority => Some(OrderMode::Priority),
            Order::Fairshare => Some(OrderMode::Fairshare),
        }
    }

    fn refresh(&mut self, ctx: &mut KernelCtx) {
        if self.eager && self.mode().is_some() {
            ctx.order_rebuild_eager();
        }
    }
}

impl<P: SchedPolicy> SchedPolicy for Ordered<P> {
    fn label(&self) -> String {
        format!("{}+{}", self.inner.label(), self.order.label())
    }

    fn on_submit(&mut self, ctx: &mut KernelCtx, batch: usize) {
        if let Some(mode) = self.mode() {
            ctx.enable_order(mode);
        }
        self.inner.on_submit(ctx, batch);
    }

    fn on_arrive(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId) {
        self.refresh(ctx);
        self.inner.on_arrive(ctx, now, task);
    }

    fn on_tick(&mut self, ctx: &mut KernelCtx, now: Time) {
        self.refresh(ctx);
        self.inner.on_tick(ctx, now);
    }

    fn tick_interval(&self) -> Option<Time> {
        self.inner.tick_interval()
    }

    fn on_stage(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: SlotId) {
        self.inner.on_stage(ctx, now, task, slot);
    }

    fn on_complete(
        &mut self,
        ctx: &mut KernelCtx,
        now: Time,
        task: TaskId,
        slot: SlotId,
    ) -> Option<Time> {
        if self.order == Order::Fairshare {
            let spec = &ctx.workload().tasks[task as usize];
            ctx.order_charge(spec.user, spec.cores as f64 * spec.duration);
        }
        self.inner.on_complete(ctx, now, task, slot)
    }

    fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
        self.refresh(ctx);
        self.inner.on_slot_free(ctx, now);
    }

    fn on_deps_ready(&mut self, ctx: &mut KernelCtx, now: Time) {
        self.refresh(ctx);
        self.inner.on_deps_ready(ctx, now);
    }

    fn on_preempt_candidates(&mut self, ctx: &mut KernelCtx, now: Time, out: &mut Vec<TaskId>) {
        self.inner.on_preempt_candidates(ctx, now, out);
    }

    fn on_resume(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: SlotId) {
        self.inner.on_resume(ctx, now, task, slot);
    }

    fn on_node_fail(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        // Killed tasks re-entered the overlay through the normal
        // requeue path with their original priority/usage; refresh the
        // eager oracle before the inner policy reacts.
        self.refresh(ctx);
        self.inner.on_node_fail(ctx, now, node);
    }

    fn on_node_suspected(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        // Same requeue shape as an instant-detection failure.
        self.refresh(ctx);
        self.inner.on_node_suspected(ctx, now, node);
    }

    fn on_message_lost(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: SlotId) {
        self.inner.on_message_lost(ctx, now, task, slot);
    }

    fn on_node_drain(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        self.inner.on_node_drain(ctx, now, node);
    }

    fn on_node_recover(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        self.refresh(ctx);
        self.inner.on_node_recover(ctx, now, node);
    }

    fn daemon_busy(&self) -> f64 {
        self.inner.daemon_busy()
    }
}

/// [`SchedPolicy`] wrapper adding priority preemption: when the
/// best-priority queued task cannot start on the free slots, running
/// preemptible tasks of strictly lower priority are nominated as
/// victims — lowest priority first, most recently started first (least
/// work lost), gang-aware (a nominated member frees its whole gang's
/// cores). In-flight evictions are tracked so a pass between the
/// eviction decision and the checkpointed slot release does not
/// over-evict.
pub struct Preemptive<P> {
    inner: P,
    /// (slots-free-at, cores) for evictions already requested, kept in
    /// ascending free-at order so expiry is amortized-O(1) front pops —
    /// the legacy `Vec::retain` swept the whole set on every pass.
    inflight: VecDeque<(Time, usize)>,
    /// Running core sum over `inflight` (legacy re-summed per pass).
    inflight_cores: usize,
    /// Evictions accepted during the current pass, merged into
    /// `inflight` only once the pass is known to satisfy the target
    /// (replaces the legacy truncate-rollback).
    added: Vec<(Time, usize)>,
    /// Victim-scan scratch.
    cands: Vec<TaskId>,
    /// Gangs already nominated this pass.
    picked_jobs: Vec<u32>,
    resumes: u64,
}

impl<P: SchedPolicy> Preemptive<P> {
    /// Wrap `inner` with priority preemption.
    pub fn new(inner: P) -> Self {
        Self {
            inner,
            inflight: VecDeque::new(),
            inflight_cores: 0,
            added: Vec::new(),
            cands: Vec::new(),
            picked_jobs: Vec::new(),
            resumes: 0,
        }
    }

    /// Resumes observed (restart count ≤ eviction count; exposed for
    /// tests and benches).
    pub fn resumes(&self) -> u64 {
        self.resumes
    }
}

impl<P: SchedPolicy> SchedPolicy for Preemptive<P> {
    fn label(&self) -> String {
        format!("{}+preempt", self.inner.label())
    }

    fn on_submit(&mut self, ctx: &mut KernelCtx, batch: usize) {
        self.inner.on_submit(ctx, batch);
    }

    fn on_arrive(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId) {
        self.inner.on_arrive(ctx, now, task);
    }

    fn on_tick(&mut self, ctx: &mut KernelCtx, now: Time) {
        self.inner.on_tick(ctx, now);
    }

    fn tick_interval(&self) -> Option<Time> {
        self.inner.tick_interval()
    }

    fn on_stage(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: SlotId) {
        self.inner.on_stage(ctx, now, task, slot);
    }

    fn on_complete(
        &mut self,
        ctx: &mut KernelCtx,
        now: Time,
        task: TaskId,
        slot: SlotId,
    ) -> Option<Time> {
        self.inner.on_complete(ctx, now, task, slot)
    }

    fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
        // Defer the inner dispatch opportunity until every same-instant
        // release has landed: a gang-sized eviction frees its slots as
        // several SlotFree events at one instant, and draining
        // mid-instant would let lower-priority tasks backfill the
        // partial hole before the gang can claim it (the same
        // complete-instant gating batchq's EASY backfill uses). The
        // final event of the instant always triggers the drain — every
        // same-instant completion re-emits a SlotFree behind itself.
        if !ctx.has_more_events_at(now) {
            self.inner.on_slot_free(ctx, now);
        }
    }

    fn on_deps_ready(&mut self, ctx: &mut KernelCtx, now: Time) {
        self.inner.on_deps_ready(ctx, now);
    }

    fn on_resume(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: SlotId) {
        self.resumes += 1;
        self.inner.on_resume(ctx, now, task, slot);
    }

    fn on_node_fail(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        // A failure is not an eviction: the killed tasks' slots parked
        // instantly (no checkpoint drain), so there is no in-flight
        // capacity to track here — the next preemption pass simply sees
        // the smaller free pool.
        self.inner.on_node_fail(ctx, now, node);
    }

    fn on_node_suspected(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        // Like on_node_fail: detection parks slots instantly, nothing
        // in-flight to track.
        self.inner.on_node_suspected(ctx, now, node);
    }

    fn on_message_lost(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: SlotId) {
        self.inner.on_message_lost(ctx, now, task, slot);
    }

    fn on_node_drain(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        self.inner.on_node_drain(ctx, now, node);
    }

    fn on_node_recover(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        self.inner.on_node_recover(ctx, now, node);
    }

    fn on_preempt_candidates(&mut self, ctx: &mut KernelCtx, now: Time, out: &mut Vec<TaskId>) {
        self.inner.on_preempt_candidates(ctx, now, out);
        // Expire checkpoint drains whose slots have been released: the
        // deque is time-ordered, so this is amortized O(1) front pops
        // (each entry is pushed and popped once) instead of the legacy
        // O(inflight) retain sweep per pass.
        while let Some(&(t, c)) = self.inflight.front() {
            if t > now {
                break;
            }
            self.inflight.pop_front();
            self.inflight_cores -= c;
        }
        let tasks = &ctx.workload().tasks;
        // Best-priority queued task, tie-broken by dispatch-order
        // position exactly as the legacy scan over the eagerly-sorted
        // queue did (O(log n) under a priority overlay).
        let Some(head) = ctx.best_priority_pending() else {
            return;
        };
        let head_spec = &tasks[head as usize];
        let need = if head_spec.kind == JobKind::Parallel {
            // Gang dispatch is all-or-nothing: the demand is every
            // pending member's cores, not just the nominating head's.
            // A gang that has not fully assembled cannot start no
            // matter what gets evicted, so don't waste work on it yet.
            if !ctx.gang_all_ready(head_spec.job) {
                return;
            }
            ctx.pending_ids()
                .filter(|&t| {
                    let s = &tasks[t as usize];
                    s.job == head_spec.job && s.kind == JobKind::Parallel
                })
                .map(|t| tasks[t as usize].cores as usize)
                .sum()
        } else {
            head_spec.cores as usize
        };
        let mut avail = ctx.free_slots() + self.inflight_cores;
        if avail >= need {
            return; // it can (or soon will) start without evictions
        }
        self.cands.clear();
        ctx.preemptible_running(&mut self.cands);
        self.cands
            .retain(|&v| tasks[v as usize].priority < head_spec.priority);
        let span_key = |t: TaskId| ctx.span_start_of(t);
        self.cands.sort_unstable_by(|&a, &b| {
            tasks[a as usize]
                .priority
                .cmp(&tasks[b as usize].priority)
                .then(span_key(b).total_cmp(&span_key(a)))
                .then(a.cmp(&b))
        });
        self.picked_jobs.clear();
        self.added.clear();
        let selected_start = out.len();
        for &v in &self.cands {
            if avail >= need {
                break;
            }
            // Only account victims the kernel would actually accept: a
            // refused request (mid-launch gang member, protected
            // sibling) would otherwise leave phantom in-flight capacity
            // that suppresses legitimate evictions until it expires.
            if !ctx.evictable(v) {
                continue;
            }
            let spec = &tasks[v as usize];
            let freed = if spec.kind == JobKind::Parallel {
                if self.picked_jobs.contains(&spec.job) {
                    continue;
                }
                self.picked_jobs.push(spec.job);
                ctx.running_gang_cores(spec.job)
            } else {
                spec.cores as usize
            };
            if freed == 0 {
                continue;
            }
            out.push(v);
            self.added.push((now + spec.checkpoint_cost, freed));
            avail += freed;
        }
        if avail < need {
            // The target cannot be satisfied even after evicting every
            // eligible victim: evicting would only waste work. Nothing
            // was merged into `inflight` yet, so rollback is free.
            out.truncate(selected_start);
            return;
        }
        // Merge the accepted evictions, preserving time order.
        // Checkpoint costs are uniform in practice, so the insertion
        // point is at (or within a few entries of) the back.
        for &(t, c) in &self.added {
            let mut pos = self.inflight.len();
            while pos > 0 && self.inflight[pos - 1].0 > t {
                pos -= 1;
            }
            self.inflight.insert(pos, (t, c));
            self.inflight_cores += c;
        }
    }

    fn daemon_busy(&self) -> f64 {
        self.inner.daemon_busy()
    }
}

/// Forwarding impl so boxed policies compose with the wrappers.
impl<P: SchedPolicy + ?Sized> SchedPolicy for Box<P> {
    fn label(&self) -> String {
        (**self).label()
    }
    fn on_submit(&mut self, ctx: &mut KernelCtx, batch: usize) {
        (**self).on_submit(ctx, batch)
    }
    fn on_arrive(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId) {
        (**self).on_arrive(ctx, now, task)
    }
    fn on_tick(&mut self, ctx: &mut KernelCtx, now: Time) {
        (**self).on_tick(ctx, now)
    }
    fn tick_interval(&self) -> Option<Time> {
        (**self).tick_interval()
    }
    fn on_stage(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: SlotId) {
        (**self).on_stage(ctx, now, task, slot)
    }
    fn on_complete(
        &mut self,
        ctx: &mut KernelCtx,
        now: Time,
        task: TaskId,
        slot: SlotId,
    ) -> Option<Time> {
        (**self).on_complete(ctx, now, task, slot)
    }
    fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
        (**self).on_slot_free(ctx, now)
    }
    fn on_deps_ready(&mut self, ctx: &mut KernelCtx, now: Time) {
        (**self).on_deps_ready(ctx, now)
    }
    fn on_preempt_candidates(&mut self, ctx: &mut KernelCtx, now: Time, out: &mut Vec<TaskId>) {
        (**self).on_preempt_candidates(ctx, now, out)
    }
    fn on_resume(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: SlotId) {
        (**self).on_resume(ctx, now, task, slot)
    }
    fn on_node_fail(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        (**self).on_node_fail(ctx, now, node)
    }
    fn on_node_suspected(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        (**self).on_node_suspected(ctx, now, node)
    }
    fn on_message_lost(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: SlotId) {
        (**self).on_message_lost(ctx, now, task, slot)
    }
    fn on_node_drain(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        (**self).on_node_drain(ctx, now, node)
    }
    fn on_node_recover(&mut self, ctx: &mut KernelCtx, now: Time, node: NodeId) {
        (**self).on_node_recover(ctx, now, node)
    }
    fn daemon_busy(&self) -> f64 {
        (**self).daemon_busy()
    }
}

/// A [`Scheduler`] adapter running an inner backend's policy under
/// [`Ordered`] + [`Preemptive`]. The inner backend must be
/// kernel-policy-driven ([`Scheduler::make_policy`] returns `Some`);
/// wrapping anything else panics loudly rather than silently running
/// the bare backend under a "+preempt" label.
pub struct PreemptiveSim {
    inner: Box<dyn Scheduler>,
    order: Order,
    name: &'static str,
    eager: bool,
}

impl PreemptiveSim {
    /// Wrap `inner`; `name` is the (static) display name, e.g.
    /// `"Slurm+prio+preempt"`.
    pub fn new(inner: Box<dyn Scheduler>, order: Order, name: &'static str) -> Self {
        Self {
            inner,
            order,
            name,
            eager: false,
        }
    }

    /// Same wrapper with the inner [`Ordered`] in eager-sort oracle
    /// mode (bit-identical results, legacy per-event sort cost).
    pub fn new_eager(inner: Box<dyn Scheduler>, order: Order, name: &'static str) -> Self {
        Self {
            inner,
            order,
            name,
            eager: true,
        }
    }
}

impl Scheduler for PreemptiveSim {
    fn name(&self) -> &'static str {
        self.name
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let inner_policy = self.inner.make_policy(seed).unwrap_or_else(|| {
            panic!(
                "{} is not kernel-policy-driven; it cannot run as {}",
                self.inner.name(),
                self.name
            )
        });
        let mut policy =
            Preemptive::new(Ordered::with_eager(self.order, inner_policy, self.eager));
        let mut r = Kernel::run(&mut policy, workload, cluster, options, scratch);
        r.scheduler = self.name.to_string();
        r
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        self.inner.projected_runtime(workload, cluster)
    }
}

/// A [`Scheduler`] adapter running an inner backend's policy under
/// [`Ordered`] alone (no preemption), e.g. `"IdealFIFO+prio"` — the
/// ordered-policy rows of the `scale` experiment. `eager` selects the
/// legacy full-sort oracle mode (see [`Ordered::new_eager`]).
pub struct OrderedSim {
    inner: Box<dyn Scheduler>,
    order: Order,
    name: &'static str,
    eager: bool,
}

impl OrderedSim {
    /// Wrap `inner` with incremental `order` maintenance; `name` is the
    /// display name, e.g. `"IdealFIFO+prio"`.
    pub fn new(inner: Box<dyn Scheduler>, order: Order, name: &'static str) -> Self {
        Self {
            inner,
            order,
            name,
            eager: false,
        }
    }

    /// Same wrapper in eager-sort oracle mode — the perf baseline and
    /// differential oracle (bit-identical results, legacy cost).
    pub fn new_eager(inner: Box<dyn Scheduler>, order: Order, name: &'static str) -> Self {
        Self {
            inner,
            order,
            name,
            eager: true,
        }
    }
}

impl Scheduler for OrderedSim {
    fn name(&self) -> &'static str {
        self.name
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let inner_policy = self.inner.make_policy(seed).unwrap_or_else(|| {
            panic!(
                "{} is not kernel-policy-driven; it cannot run as {}",
                self.inner.name(),
                self.name
            )
        });
        let mut policy = Ordered::with_eager(self.order, inner_policy, self.eager);
        let mut r = Kernel::run(&mut policy, workload, cluster, options, scratch);
        r.scheduler = self.name.to_string();
        r
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        self.inner.projected_runtime(workload, cluster)
    }
}

/// The preemption-capable flavour of [`crate::sched::make_scheduler_scaled`]:
/// the same cost-scaled backend, run under priority-or-fairshare
/// ordering plus priority preemption.
pub fn make_preemptive(
    choice: crate::config::SchedulerChoice,
    scale_down: u32,
    order: Order,
) -> Box<dyn Scheduler> {
    use crate::config::SchedulerChoice as C;
    let name = match (choice, order) {
        (C::Slurm, Order::Priority) => "Slurm+prio+preempt",
        (C::Slurm, Order::Fairshare) => "Slurm+fair+preempt",
        (C::Slurm, Order::Fifo) => "Slurm+fifo+preempt",
        (C::GridEngine, Order::Priority) => "GridEngine+prio+preempt",
        (C::GridEngine, Order::Fairshare) => "GridEngine+fair+preempt",
        (C::GridEngine, Order::Fifo) => "GridEngine+fifo+preempt",
        (C::Mesos, Order::Priority) => "Mesos+prio+preempt",
        (C::Mesos, Order::Fairshare) => "Mesos+fair+preempt",
        (C::Mesos, Order::Fifo) => "Mesos+fifo+preempt",
        (C::Yarn, Order::Priority) => "YARN+prio+preempt",
        (C::Yarn, Order::Fairshare) => "YARN+fair+preempt",
        (C::Yarn, Order::Fifo) => "YARN+fifo+preempt",
        (C::Sparrow, Order::Priority) => "Sparrow+prio+preempt",
        (C::Sparrow, Order::Fairshare) => "Sparrow+fair+preempt",
        (C::Sparrow, Order::Fifo) => "Sparrow+fifo+preempt",
        (C::IdealFifo, Order::Priority) => "IdealFIFO+prio+preempt",
        (C::IdealFifo, Order::Fairshare) => "IdealFIFO+fair+preempt",
        (C::IdealFifo, Order::Fifo) => "IdealFIFO+fifo+preempt",
    };
    Box::new(PreemptiveSim::new(
        crate::sched::make_scheduler_scaled(choice, scale_down),
        order,
        name,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::SchedulerChoice;
    use crate::sched::batchq::{BatchJob, BatchQueueSim, QueuePolicy};
    use crate::sched::{make_scheduler, RunOptions};
    use crate::sim::Launch;
    use crate::util::prng::Prng;
    use crate::workload::TraceRecord;

    // ---- regression harness: the extracted OrderedDrain is
    // bit-identical to batchq's historical in-module drain ----

    /// Verbatim copy of batchq's pre-combinator policy (ordering,
    /// usage charging and EASY backfill fused into the drain), kept as
    /// the reference the extraction is pinned against. Any drift in
    /// `OrderedDrain` / `sort_queue` / `shadow_time` breaks the
    /// bit-compare below.
    struct LegacyBatchPolicy<'a> {
        policy: QueuePolicy,
        jobs: &'a [BatchJob],
        usage: BTreeMap<u32, f64>,
        running: Vec<(f64, u32, u32)>,
    }

    impl LegacyBatchPolicy<'_> {
        fn order(&self, queue: &mut [TaskId]) {
            match self.policy {
                QueuePolicy::Fcfs | QueuePolicy::FcfsBackfill => {}
                QueuePolicy::Priority => {
                    queue.sort_by(|&a, &b| {
                        self.jobs[b as usize]
                            .priority
                            .cmp(&self.jobs[a as usize].priority)
                            .then(a.cmp(&b))
                    });
                }
                QueuePolicy::Fairshare => {
                    queue.sort_by(|&a, &b| {
                        let ua = self
                            .usage
                            .get(&self.jobs[a as usize].user)
                            .copied()
                            .unwrap_or(0.0);
                        let ub = self
                            .usage
                            .get(&self.jobs[b as usize].user)
                            .copied()
                            .unwrap_or(0.0);
                        ua.total_cmp(&ub).then(a.cmp(&b))
                    });
                }
            }
        }

        fn started(&mut self, idx: TaskId, now: Time) {
            let j = &self.jobs[idx as usize];
            self.running.push((now + j.duration, j.cores, idx));
            *self.usage.entry(j.user).or_default() += j.cores as f64 * j.duration;
        }

        fn drain(&mut self, ctx: &mut KernelCtx, now: Time) {
            let mut queue = ctx.pending_snapshot();
            self.order(&mut queue);
            let mut blocked_head: Option<TaskId> = None;
            for idx in queue {
                if blocked_head.is_none() {
                    if ctx.try_dispatch(idx, &mut |_, _| Launch::start(now)) {
                        self.started(idx, now);
                    } else {
                        blocked_head = Some(idx);
                        if self.policy != QueuePolicy::FcfsBackfill {
                            break;
                        }
                    }
                } else {
                    let j = &self.jobs[idx as usize];
                    let head = &self.jobs[blocked_head.expect("head set") as usize];
                    let free = ctx.free_slots() as u32;
                    let (shadow, spare) = shadow_time(free, head.cores, &self.running);
                    let fits_now = j.cores <= free;
                    let no_delay =
                        now + j.duration <= shadow + 1e-9 || j.cores <= spare;
                    if fits_now
                        && no_delay
                        && ctx.try_dispatch(idx, &mut |_, _| Launch::start(now))
                    {
                        self.started(idx, now);
                    }
                }
            }
        }
    }

    impl SchedPolicy for LegacyBatchPolicy<'_> {
        fn label(&self) -> String {
            "BatchQueue".into()
        }
        fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
            self.drain(ctx, 0.0);
        }
        fn on_arrive(&mut self, ctx: &mut KernelCtx, now: Time, _task: TaskId) {
            if !ctx.has_more_events_at(now) {
                self.drain(ctx, now);
            }
        }
        fn on_complete(
            &mut self,
            _ctx: &mut KernelCtx,
            now: Time,
            task: TaskId,
            _slot: SlotId,
        ) -> Option<Time> {
            self.running.retain(|&(_, _, t)| t != task);
            Some(now)
        }
        fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
            if !ctx.has_more_events_at(now) {
                self.drain(ctx, now);
            }
        }
    }

    fn cluster(cores: u32) -> ClusterSpec {
        ClusterSpec::homogeneous(1, cores, 1 << 20, 1)
    }

    fn random_jobs(rng: &mut Prng, n: u64, max_cores: u32) -> Vec<BatchJob> {
        (0..n)
            .map(|id| BatchJob {
                id: id as u32,
                user: rng.below(4) as u32,
                cores: 1 + rng.below(max_cores as u64) as u32,
                duration: rng.range_f64(0.5, 20.0),
                priority: rng.below(5) as i32,
                submit_at: if rng.chance(0.5) {
                    0.0
                } else {
                    rng.range_f64(0.0, 30.0)
                },
            })
            .collect()
    }

    /// Run the legacy reference policy through the kernel on the same
    /// task mapping `BatchQueueSim` uses, returning (makespan, trace).
    fn run_legacy(
        policy: QueuePolicy,
        jobs: &[BatchJob],
        cluster: &ClusterSpec,
    ) -> (f64, Vec<TraceRecord>) {
        let tasks: Vec<TaskSpec> = jobs
            .iter()
            .enumerate()
            .map(|(i, j)| {
                let mut t = TaskSpec::array(i as u32, i as u32, j.duration);
                t.cores = j.cores;
                t.mem_mb = 1;
                t.submit_at = j.submit_at;
                t.priority = j.priority;
                t.user = j.user;
                t
            })
            .collect();
        let workload = Workload {
            tasks,
            label: "batchq".into(),
        };
        let mut legacy = LegacyBatchPolicy {
            policy,
            jobs,
            usage: BTreeMap::new(),
            running: Vec::new(),
        };
        let r = Kernel::run(
            &mut legacy,
            &workload,
            cluster,
            &RunOptions::with_trace(),
            &mut SimScratch::new(),
        );
        (r.t_total, r.trace.expect("traced"))
    }

    #[test]
    fn ordered_drain_bit_identical_to_legacy_batchq() {
        let cl = cluster(8);
        for policy in [
            QueuePolicy::Fcfs,
            QueuePolicy::FcfsBackfill,
            QueuePolicy::Priority,
            QueuePolicy::Fairshare,
        ] {
            for seed in 0..6u64 {
                let mut rng = Prng::new(seed ^ 0xBA7C);
                let jobs = random_jobs(&mut rng, 48, 8);
                let new = BatchQueueSim::new(policy).run(&jobs, &cl).unwrap();
                let (legacy_makespan, legacy_trace) = run_legacy(policy, &jobs, &cl);
                assert_eq!(
                    new.makespan.to_bits(),
                    legacy_makespan.to_bits(),
                    "{policy:?} seed {seed}: makespan drifted from legacy drain"
                );
                for rec in &legacy_trace {
                    let o = &new.outcomes[rec.task as usize];
                    assert_eq!(o.start.to_bits(), rec.start.to_bits(), "{policy:?} {seed}");
                    assert_eq!(o.end.to_bits(), rec.end.to_bits(), "{policy:?} {seed}");
                }
            }
        }
    }

    // ---- service-aware drain units ----

    /// Minimal zero-overhead policy driving [`OrderedDrain`] with EASY
    /// backfill, for service-in-queue semantics.
    struct DrainPolicy {
        drain: OrderedDrain,
        usage: FairTracker,
        running: Vec<(f64, u32, u32)>,
    }

    impl DrainPolicy {
        fn pass(&mut self, ctx: &mut KernelCtx, now: Time) {
            self.drain.drain(
                ctx,
                now,
                &mut self.usage,
                &mut self.running,
                &mut |_, _| Launch::start(now),
            );
        }
    }

    impl SchedPolicy for DrainPolicy {
        fn label(&self) -> String {
            "Drain".into()
        }
        fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
            self.pass(ctx, 0.0);
        }
        fn on_complete(
            &mut self,
            _ctx: &mut KernelCtx,
            now: Time,
            task: TaskId,
            _slot: SlotId,
        ) -> Option<Time> {
            self.running.retain(|&(_, _, t)| t != task);
            Some(now)
        }
        fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
            if !ctx.has_more_events_at(now) {
                self.pass(ctx, now);
            }
        }
    }

    #[test]
    fn backfill_treats_service_pinned_cores_as_never_freeing() {
        // 4 slots: 3 services pin 3 of them for the whole window. The
        // 2-core head task can never start inside the window (no finite
        // reservation exists), so the 1-core tasks behind it must
        // backfill onto the single free slot instead of being starved
        // by a shadow time computed from the services' 0 "durations".
        let mut tasks: Vec<TaskSpec> = (0..3).map(|i| TaskSpec::service(i, i, 1)).collect();
        let mut head = TaskSpec::array(3, 3, 5.0);
        head.cores = 2;
        tasks.push(head);
        tasks.push(TaskSpec::array(4, 4, 1.0));
        tasks.push(TaskSpec::array(5, 5, 1.0));
        let w = Workload {
            tasks,
            label: "svc-drain".into(),
        };
        let cl = ClusterSpec::homogeneous(1, 4, 32 * 1024, 1);
        let mut policy = DrainPolicy {
            drain: OrderedDrain {
                order: Order::Fifo,
                backfill: true,
            },
            usage: FairTracker::new(),
            running: Vec::new(),
        };
        let options = RunOptions {
            collect_trace: true,
            horizon: Some(10.0),
            ..Default::default()
        };
        let r = Kernel::run(&mut policy, &w, &cl, &options, &mut SimScratch::new());
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        // Services + the two 1-core tasks ran; the 2-core head could not.
        assert_eq!(trace.len(), 5, "{trace:?}");
        assert!(trace.iter().all(|t| t.task != 3), "head cannot start");
        let t4 = trace.iter().find(|t| t.task == 4).unwrap();
        let t5 = trace.iter().find(|t| t.task == 5).unwrap();
        assert!((t4.start - 0.0).abs() < 1e-9, "first backfill at t=0");
        assert!((t5.start - 1.0).abs() < 1e-9, "second backfill at t=1");
        // 3 services × 10 s + 2 × 1 s on 4×10 core-seconds.
        assert!((r.busy_core_seconds - 32.0).abs() < 1e-9);
    }

    // ---- ordering / fair-share combinator units ----

    #[test]
    fn sort_queue_priority_then_id() {
        let mut tasks: Vec<TaskSpec> =
            (0..4).map(|i| TaskSpec::array(i, i, 1.0)).collect();
        tasks[1].priority = 5;
        tasks[3].priority = 5;
        let usage = FairTracker::new();
        let mut q = vec![0u32, 1, 2, 3];
        sort_queue(Order::Priority, &tasks, &usage, &mut q);
        assert_eq!(q, vec![1, 3, 0, 2]);
    }

    #[test]
    fn sort_queue_fairshare_prefers_light_users() {
        let mut tasks: Vec<TaskSpec> =
            (0..3).map(|i| TaskSpec::array(i, i, 1.0)).collect();
        tasks[0].user = 0;
        tasks[1].user = 1;
        tasks[2].user = 0;
        let mut usage = FairTracker::new();
        usage.charge(0, 100.0);
        let mut q = vec![0u32, 1, 2];
        sort_queue(Order::Fairshare, &tasks, &usage, &mut q);
        assert_eq!(q, vec![1, 0, 2]);
        // The id tie-break makes the order total: any input permutation
        // sorts to the same queue.
        let mut q2 = vec![2u32, 0, 1];
        sort_queue(Order::Fairshare, &tasks, &usage, &mut q2);
        assert_eq!(q2, vec![1, 0, 2]);
    }

    #[test]
    fn ordered_wrapper_imposes_priority_on_ideal() {
        // 2 slots, 4 × 1 s tasks, tasks 2,3 high priority: they must
        // form the first wave under Ordered(Priority) even though FIFO
        // order says otherwise.
        let cl = ClusterSpec::homogeneous(1, 2, 32 * 1024, 1);
        let mut tasks: Vec<TaskSpec> =
            (0..4).map(|i| TaskSpec::array(i, i, 1.0)).collect();
        tasks[2].priority = 9;
        tasks[3].priority = 9;
        let w = Workload {
            tasks,
            label: "prio".into(),
        };
        let ideal = make_scheduler(SchedulerChoice::IdealFifo);
        let inner = ideal.make_policy(0).expect("ideal is kernel-driven");
        let mut policy = Ordered::new(Order::Priority, inner);
        let r = Kernel::run(
            &mut policy,
            &w,
            &cl,
            &RunOptions::with_trace(),
            &mut SimScratch::new(),
        );
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        let start = |t: u32| trace.iter().find(|x| x.task == t).unwrap().start;
        assert!(start(2) < 0.5 && start(3) < 0.5, "high prio first");
        assert!(start(0) > 0.5 && start(1) > 0.5, "low prio second wave");
        assert_eq!(r.scheduler, "IdealFIFO+prio");
    }

    #[test]
    fn preemptive_sim_evicts_for_high_priority_arrivals() {
        // Slot-saturating preemptible background + one high-priority
        // arrival: the wrapped ideal backend must evict exactly enough
        // cores, lose no work, and finish the foreground task first.
        let cl = ClusterSpec::homogeneous(1, 2, 32 * 1024, 1);
        let mut tasks: Vec<TaskSpec> = (0..2)
            .map(|i| {
                let mut t = TaskSpec::array(i, i, 10.0);
                t.preemptible = true;
                t
            })
            .collect();
        let mut fg = TaskSpec::array(2, 2, 1.0);
        fg.submit_at = 2.0;
        fg.priority = 10;
        tasks.push(fg);
        let w = Workload {
            tasks,
            label: "pre".into(),
        };
        let sched = make_preemptive(SchedulerChoice::IdealFifo, 1, Order::Priority);
        let r = sched.run(&w, &cl, 3, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.preemptions, 1, "exactly one core's worth evicted");
        let spans = r.spans.as_ref().unwrap();
        for task in 0..2u32 {
            let work: f64 = spans
                .iter()
                .filter(|s| s.task == task)
                .map(|s| s.seconds())
                .sum();
            assert!((work - 10.0).abs() < 1e-9, "task {task} lost work: {work}");
        }
        let fg_span = spans.iter().find(|s| s.task == 2).unwrap();
        assert!((fg_span.start - 2.0).abs() < 1e-9, "{fg_span:?}");
        assert_eq!(r.scheduler, "IdealFIFO+prio+preempt");
        // Makespan: 20 core-seconds of bg + 1 of fg on 2 cores ≈ 10.5;
        // the eviction serializes half a second of bg tail -> 11.
        assert!((r.t_total - 11.0).abs() < 1e-9, "t_total={}", r.t_total);
    }

    #[test]
    fn preemptive_evicts_whole_demand_for_high_priority_gang() {
        // 4 slots saturated by 4 preemptible 1-core background tasks; a
        // priority-10 gang of 4 arrives at t=2. The victim sizing must
        // cover the WHOLE gang's demand (4 cores), not just one
        // member's — the gang starts at t=2 and the background resumes.
        let cl = ClusterSpec::homogeneous(1, 4, 32 * 1024, 1);
        let mut tasks: Vec<TaskSpec> = (0..4)
            .map(|i| {
                let mut t = TaskSpec::array(i, i, 10.0);
                t.preemptible = true;
                t
            })
            .collect();
        for m in 0..4u32 {
            let mut t = TaskSpec::array(4 + m, 9, 1.0);
            t.kind = crate::workload::JobKind::Parallel;
            t.priority = 10;
            t.submit_at = 2.0;
            tasks.push(t);
        }
        let w = Workload {
            tasks,
            label: "gang-pre".into(),
        };
        let sched = make_preemptive(SchedulerChoice::IdealFifo, 1, Order::Priority);
        let r = sched.run(&w, &cl, 5, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.preemptions, 4, "all four background tasks evicted");
        let trace = r.trace.as_ref().unwrap();
        for m in 4..8u32 {
            let rec = trace.iter().find(|t| t.task == m).unwrap();
            assert!(
                (rec.start - 2.0).abs() < 1e-9,
                "gang member {m} should start at 2, started {}",
                rec.start
            );
        }
        let spans = r.spans.as_ref().unwrap();
        for task in 0..4u32 {
            let work: f64 = spans
                .iter()
                .filter(|s| s.task == task)
                .map(|s| s.seconds())
                .sum();
            assert!((work - 10.0).abs() < 1e-9, "bg {task} lost work: {work}");
        }
        // Gang [2,3] + background 10 s split around it -> makespan 11.
        assert!((r.t_total - 11.0).abs() < 1e-9, "t_total={}", r.t_total);
    }

    #[test]
    fn preemptive_without_eligible_victims_is_inert() {
        let cl = ClusterSpec::homogeneous(1, 2, 32 * 1024, 1);
        // Preemptible flag set on the foreground task only (activates
        // the subsystem); the background is protected.
        let mut tasks: Vec<TaskSpec> = (0..2)
            .map(|i| TaskSpec::array(i, i, 10.0))
            .collect();
        let mut fg = TaskSpec::array(2, 2, 1.0);
        fg.submit_at = 2.0;
        fg.priority = 10;
        fg.preemptible = true;
        tasks.push(fg);
        let w = Workload {
            tasks,
            label: "inert".into(),
        };
        let sched = make_preemptive(SchedulerChoice::IdealFifo, 1, Order::Priority);
        let r = sched.run(&w, &cl, 3, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.preemptions, 0);
        let trace = r.trace.as_ref().unwrap();
        let fg_rec = trace.iter().find(|t| t.task == 2).unwrap();
        assert!((fg_rec.start - 10.0).abs() < 1e-9, "fg waits out the bg");
    }
}
