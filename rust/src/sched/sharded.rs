//! Sharded kernel driver and the node-granular allocation wrapper —
//! the two fast modes of the million-task data plane.
//!
//! # Sharding
//!
//! The Sparrow and ideal paths are embarrassingly independent: no
//! central daemon couples one task's placement to another's, so a run
//! over N tasks on P cores decomposes into G runs over disjoint node
//! groups and disjoint job subsets. [`ShardedSim`] performs that
//! decomposition — nodes into G contiguous groups, jobs by `job % G`,
//! task ids re-packed densely per shard — runs each shard through the
//! ordinary [`Kernel`](crate::sim::Kernel) loop (in parallel up to a
//! worker cap), and merges the shard results:
//!
//! * `t_total` = max over shards (the last shard to finish ends the
//!   run); sums for `events`, `daemon_busy`, completion and fault
//!   counters, and windowed busy time;
//! * `waits` via parallel Welford merge in shard order;
//! * wait quantiles re-estimated from the concatenated (then condensed)
//!   per-shard reservoir samples;
//! * traces/spans remapped back to global task/node/slot ids.
//!
//! The merge is deterministic in the worker count: each shard's result
//! is a pure function of its seed, and merging happens in shard-index
//! order. Shard 0 runs under the caller's seed unchanged, so a
//! single-shard `ShardedSim` reproduces the plain run bit-for-bit
//! (modulo the scheduler label and sample-derived quantiles), which
//! `tests/streaming_metrics.rs` pins.
//!
//! Policies with *global* state are not shardable: a centralized
//! daemon's queue couples shards, and Sparrow's single probe RNG
//! stream means a sharded Sparrow run is a different (equally valid,
//! still deterministic) draw than the global one. The ideal FIFO on a
//! constant-duration 1-core workload is exactly invariant: with G
//! dividing the node count, task `i = q·P + r` starts at wave `q` both
//! ways, so `t_total` matches bitwise.
//!
//! # Node granularity
//!
//! [`NodeGranularSim`] flips `RunOptions::node_granular`, switching the
//! slot pool into the whole-node allocation mode of arXiv 2108.11359
//! (open-node cursor, one tournament-tree query per node rollover, no
//! lazy-stack maintenance). Placement changes, so results are a
//! different valid schedule — the `scale` experiment measures what the
//! mode buys at n = 10^6.

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::{ClusterSpec, FaultEvent, FaultPlan, Node, NodeState};
use crate::sim::SimScratch;
use crate::util::stats::{condense_sample, percentile_sorted, Summary, WAIT_SAMPLE_CAP};
use crate::workload::{TaskSpec, Workload};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Per-shard seed derivation: shard 0 keeps the caller's seed (the
/// single-shard identity the tests pin); later shards step by the
/// golden-ratio increment so streams never collide.
fn shard_seed(seed: u64, shard: usize) -> u64 {
    seed.wrapping_add((shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Which of the `g` contiguous node groups owns global node id `node`.
///
/// Mirrors the decomposition in [`ShardedSim::run_with_scratch`]: the
/// first `n_nodes % g` groups take `n_nodes / g + 1` nodes each, the
/// rest take `n_nodes / g`. Callers guarantee `1 <= g <= n_nodes`.
fn shard_of_node(node: u32, n_nodes: usize, g: usize) -> usize {
    let base = n_nodes / g;
    let extra = n_nodes % g;
    let i = node as usize;
    let big = extra * (base + 1);
    if i < big {
        i / (base + 1)
    } else {
        extra + (i - big) / base
    }
}

/// A [`Scheduler`] adapter running an inner backend's run in
/// node-granular slot-pool mode (see [`RunOptions::node_granular`]).
pub struct NodeGranularSim {
    inner: Box<dyn Scheduler>,
    name: &'static str,
}

impl NodeGranularSim {
    /// Wrap `inner`; `name` is the display name, e.g.
    /// `"IdealFIFO+node"`.
    pub fn new(inner: Box<dyn Scheduler>, name: &'static str) -> Self {
        Self { inner, name }
    }
}

impl Scheduler for NodeGranularSim {
    fn name(&self) -> &'static str {
        self.name
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let mut opts = options.clone();
        opts.node_granular = true;
        let mut r = self
            .inner
            .run_with_scratch(workload, cluster, seed, &opts, scratch);
        r.scheduler = self.name.to_string();
        r
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        self.inner.projected_runtime(workload, cluster)
    }
}

/// A [`Scheduler`] adapter that shards a run across disjoint node
/// groups (see the module docs for the decomposition and merge rules).
pub struct ShardedSim {
    inner: Box<dyn Scheduler>,
    shards: usize,
    /// Worker-thread cap for running shards concurrently (1 = serial;
    /// results are identical either way).
    jobs: usize,
    name: &'static str,
    /// Warm per-worker scratches reused across runs, so repeated runs
    /// hit the kernel's zero-allocation steady state. The warm-buffer
    /// contract makes results independent of scratch history.
    scratch_pool: Mutex<Vec<SimScratch>>,
}

impl ShardedSim {
    /// Wrap `inner` into `shards` node groups run on up to `jobs`
    /// threads; `name` is the display name, e.g. `"IdealFIFO+shard4"`.
    pub fn new(inner: Box<dyn Scheduler>, shards: usize, jobs: usize, name: &'static str) -> Self {
        assert!(shards >= 1, "ShardedSim needs at least one shard");
        Self {
            inner,
            shards,
            jobs: jobs.max(1),
            name,
            scratch_pool: Mutex::new(Vec::new()),
        }
    }

    /// Check whether `(workload, options)` can be sharded over a
    /// cluster of `n_nodes` nodes split into `shards` groups.
    ///
    /// Two restrictions fall out of the decomposition (jobs route to
    /// shards by `job % G`, and each shard renumbers its node group
    /// from zero):
    ///
    /// * **fault plans** address *global* node ids. Events are routed
    ///   to the shard owning each node (and remapped to its local id),
    ///   so plans whose node set stays inside one node group replay
    ///   exactly like the unsharded run. Plans that *cross* shard
    ///   groups are rejected: their node lifecycles would be split
    ///   across kernels that see disjoint slices of the load, a
    ///   silently different experiment than the unsharded replay;
    /// * **task dependencies** may cross shard boundaries, where the
    ///   parent's completion is never observed and the child would wait
    ///   forever.
    ///
    /// The run path calls this and panics with the returned message;
    /// callers that want to degrade gracefully (pick an unsharded
    /// engine instead) should call it first.
    pub fn validate_shardable(
        workload: &Workload,
        options: &RunOptions,
        n_nodes: usize,
        shards: usize,
    ) -> Result<(), String> {
        if !options.faults.is_empty() {
            let g = shards.max(1).min(n_nodes.max(1));
            let mut group: Option<usize> = None;
            for e in &options.faults.events {
                if (e.node as usize) >= n_nodes {
                    return Err(format!(
                        "fault plan addresses node {} but the cluster has only {} nodes",
                        e.node, n_nodes
                    ));
                }
                let s = shard_of_node(e.node, n_nodes, g);
                if let Some(prev) = group {
                    if prev != s {
                        return Err(
                            "sharded runs do not support fault plans that cross shard \
                             groups: FaultPlan events address global node ids and are \
                             routed to the shard owning each node, so a plan spanning \
                             several node groups would split its lifecycle across \
                             kernels that each see only a slice of the load — a \
                             silently different experiment; confine the plan's node \
                             set to one node group or run it on an unsharded engine"
                                .into(),
                        );
                    }
                } else {
                    group = Some(s);
                }
            }
        }
        if let Some(t) = workload.tasks.iter().find(|t| !t.deps.is_empty()) {
            return Err(format!(
                "sharded runs require a dependency-free workload: task {} depends on \
                 {:?}, and jobs are routed to shards by `job % shards`, so a dependency \
                 crossing shards would deadlock (the parent's completion is never seen \
                 by the child's shard); run DAG workloads on an unsharded engine",
                t.id, t.deps
            ));
        }
        Ok(())
    }
}

impl Scheduler for ShardedSim {
    fn name(&self) -> &'static str {
        self.name
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        _scratch: &mut SimScratch,
    ) -> RunResult {
        // Shards run on the internal per-worker scratch pool (the
        // warm-buffer contract makes results independent of scratch
        // history), so the caller's scratch is deliberately unused.
        if let Err(e) = Self::validate_shardable(workload, options, cluster.n_nodes(), self.shards)
        {
            panic!("{}: {e}", self.name);
        }
        let g = self.shards.min(cluster.n_nodes().max(1));

        // Nodes into G contiguous groups (remainder spread over the
        // first groups), re-id'd densely per shard. Slot offsets count
        // Up-node cores only — the slot-id space the pool exposes.
        let n_nodes = cluster.n_nodes();
        let base = n_nodes / g;
        let extra = n_nodes % g;
        let mut clusters: Vec<ClusterSpec> = Vec::with_capacity(g);
        let mut node_off: Vec<u32> = Vec::with_capacity(g);
        let mut slot_off: Vec<u32> = Vec::with_capacity(g);
        let mut node_cursor = 0usize;
        let mut slot_cursor = 0u32;
        for s in 0..g {
            let take = base + usize::from(s < extra);
            node_off.push(node_cursor as u32);
            slot_off.push(slot_cursor);
            let nodes: Vec<Node> = cluster.nodes[node_cursor..node_cursor + take]
                .iter()
                .enumerate()
                .map(|(j, n)| Node {
                    id: j as u32,
                    ..n.clone()
                })
                .collect();
            slot_cursor += nodes
                .iter()
                .filter(|n| n.state == NodeState::Up)
                .map(|n| n.cores)
                .sum::<u32>();
            node_cursor += take;
            clusters.push(ClusterSpec {
                nodes,
                rpc_latency: cluster.rpc_latency,
                launch_overhead: cluster.launch_overhead,
                teardown_overhead: cluster.teardown_overhead,
            });
        }

        // Jobs to shards by `job % G`; task ids re-packed densely per
        // shard in global id order, with the inverse map kept for trace
        // remapping.
        let mut workloads: Vec<Workload> = (0..g)
            .map(|_| Workload {
                tasks: Vec::new(),
                label: workload.label.clone(),
            })
            .collect();
        let mut global_ids: Vec<Vec<u32>> = vec![Vec::new(); g];
        for t in &workload.tasks {
            let s = (t.job as usize) % g;
            let local = TaskSpec {
                id: global_ids[s].len() as u32,
                ..t.clone()
            };
            global_ids[s].push(t.id);
            workloads[s].tasks.push(local);
        }

        // Route fault events to the shard owning each node, remapped
        // to that shard's local node ids. Validation confined the
        // plan's node set to one group, so exactly one shard receives
        // a non-empty plan; the empty plans stay a zero-cost bypass in
        // the other kernels.
        let shard_options: Option<Vec<RunOptions>> = (!options.faults.is_empty()).then(|| {
            let mut plans: Vec<FaultPlan> = vec![FaultPlan::none(); g];
            for e in &options.faults.events {
                let s = shard_of_node(e.node, n_nodes, g);
                plans[s].events.push(FaultEvent {
                    node: e.node - node_off[s],
                    ..*e
                });
            }
            plans
                .into_iter()
                .map(|p| {
                    let mut o = options.clone();
                    o.faults = p;
                    o
                })
                .collect()
        });

        // Run every shard (worker pool claims shard indices; each
        // shard's result depends only on its own seed, so the outcome
        // is independent of `jobs`).
        let results: Vec<Mutex<Option<RunResult>>> = (0..g).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let workers = self.jobs.min(g);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    let mut scratch = self
                        .scratch_pool
                        .lock()
                        .expect("scratch pool lock")
                        .pop()
                        .unwrap_or_else(SimScratch::new);
                    loop {
                        let s = next.fetch_add(1, Ordering::Relaxed);
                        if s >= g {
                            break;
                        }
                        let opts: &RunOptions = match &shard_options {
                            Some(per_shard) => &per_shard[s],
                            None => options,
                        };
                        let r = self.inner.run_with_scratch(
                            &workloads[s],
                            &clusters[s],
                            shard_seed(seed, s),
                            opts,
                            &mut scratch,
                        );
                        *results[s].lock().expect("shard result lock") = Some(r);
                    }
                    self.scratch_pool
                        .lock()
                        .expect("scratch pool lock")
                        .push(scratch);
                });
            }
        });
        let shard_results: Vec<RunResult> = results
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .expect("shard result lock")
                    .expect("every shard ran")
            })
            .collect();

        // Merge in shard-index order (deterministic).
        let processors = cluster.total_cores();
        let mut merged = RunResult {
            scheduler: self.name.to_string(),
            workload: workload.label.clone(),
            n_tasks: workload.len() as u64,
            processors,
            t_total: 0.0,
            t_job: workload.t_job_per_proc(processors),
            events: 0,
            daemon_busy: 0.0,
            waits: Summary::new(),
            wait_p50: f64::NAN,
            wait_p95: f64::NAN,
            wait_p99: f64::NAN,
            wait_sample: Vec::new(),
            preemptions: 0,
            kills: 0,
            failed: 0,
            completed: 0,
            wasted_core_seconds: 0.0,
            horizon: options.horizon,
            busy_core_seconds: 0.0,
            detection_latencies: Vec::new(),
            undetected_lost_core_seconds: 0.0,
            messages_lost: 0,
            messages_duplicated: 0,
            spec_launches: 0,
            spec_kills: 0,
            retry_hist: Vec::new(),
            trace: options.collect_trace.then(Vec::new),
            spans: None,
        };
        let mut sample: Vec<f64> = Vec::new();
        let mut spans = Vec::new();
        let all_spans = shard_results.iter().all(|r| r.spans.is_some());
        for (s, r) in shard_results.iter().enumerate() {
            merged.t_total = merged.t_total.max(r.t_total);
            merged.events += r.events;
            merged.daemon_busy += r.daemon_busy;
            merged.waits = merged.waits.merge(&r.waits);
            sample.extend_from_slice(&r.wait_sample);
            merged.preemptions += r.preemptions;
            merged.kills += r.kills;
            merged.failed += r.failed;
            merged.completed += r.completed;
            merged.wasted_core_seconds += r.wasted_core_seconds;
            merged.busy_core_seconds += r.busy_core_seconds;
            merged
                .detection_latencies
                .extend_from_slice(&r.detection_latencies);
            merged.undetected_lost_core_seconds += r.undetected_lost_core_seconds;
            merged.messages_lost += r.messages_lost;
            merged.messages_duplicated += r.messages_duplicated;
            merged.spec_launches += r.spec_launches;
            merged.spec_kills += r.spec_kills;
            if merged.retry_hist.len() < r.retry_hist.len() {
                merged.retry_hist.resize(r.retry_hist.len(), 0);
            }
            for (k, c) in r.retry_hist.iter().enumerate() {
                merged.retry_hist[k] += c;
            }
            if let (Some(out), Some(tr)) = (merged.trace.as_mut(), r.trace.as_ref()) {
                for rec in tr {
                    let mut rec = rec.clone();
                    rec.task = global_ids[s][rec.task as usize];
                    rec.node += node_off[s];
                    rec.slot += slot_off[s];
                    out.push(rec);
                }
            }
            if all_spans {
                for sp in r.spans.as_ref().expect("checked above") {
                    let mut sp = *sp;
                    sp.task = global_ids[s][sp.task as usize];
                    sp.slot += slot_off[s];
                    spans.push(sp);
                }
            }
        }
        if let Some(tr) = merged.trace.as_mut() {
            tr.sort_by_key(|r| r.task);
        }
        if options.collect_trace && all_spans {
            spans.sort_by(|a, b| a.task.cmp(&b.task).then(a.start.total_cmp(&b.start)));
            merged.spans = Some(spans);
        }
        condense_sample(&mut sample, WAIT_SAMPLE_CAP);
        if !sample.is_empty() {
            merged.wait_p50 = percentile_sorted(&sample, 0.50);
            merged.wait_p95 = percentile_sorted(&sample, 0.95);
            merged.wait_p99 = percentile_sorted(&sample, 0.99);
        }
        merged.wait_sample = sample;
        merged
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        self.inner.projected_runtime(workload, cluster)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ideal::IdealFifo;
    use crate::workload::WorkloadBuilder;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(4, 4, 8 * 1024, 2)
    }

    #[test]
    fn shard_zero_keeps_the_caller_seed() {
        assert_eq!(shard_seed(1234, 0), 1234);
        assert_ne!(shard_seed(1234, 1), 1234);
    }

    #[test]
    fn single_shard_matches_plain_run() {
        let w = WorkloadBuilder::constant(3.0).tasks(64).label("s1").build();
        let plain = IdealFifo.run(&w, &cluster(), 7, &RunOptions::with_trace());
        let sharded = ShardedSim::new(Box::new(IdealFifo), 1, 1, "IdealFIFO+shard1");
        let r = sharded.run(&w, &cluster(), 7, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.t_total.to_bits(), plain.t_total.to_bits());
        assert_eq!(r.events, plain.events);
        assert_eq!(r.completed, plain.completed);
        assert_eq!(r.waits.count(), plain.waits.count());
        assert_eq!(r.waits.mean().to_bits(), plain.waits.mean().to_bits());
        let mut pt = plain.trace.clone().unwrap();
        pt.sort_by_key(|rec| rec.task);
        assert_eq!(r.trace.as_ref().unwrap(), &pt);
    }

    #[test]
    fn fault_plans_confined_to_one_node_group_are_accepted() {
        use crate::cluster::FaultPlan;
        let w = WorkloadBuilder::constant(1.0).tasks(16).jobs(16).build();
        // 4 nodes, 2 shards -> groups {0,1} and {2,3}. A plan touching
        // nodes 0 and 1 stays inside group 0; adding node 2 crosses.
        let same = RunOptions::with_faults(FaultPlan::none().fail(2.0, 0).recover(4.0, 1));
        ShardedSim::validate_shardable(&w, &same, 4, 2).unwrap();
        let crossing =
            RunOptions::with_faults(FaultPlan::none().fail(2.0, 0).fail(3.0, 2));
        let e = ShardedSim::validate_shardable(&w, &crossing, 4, 2).unwrap_err();
        assert!(e.contains("fault plans"), "{e}");
        assert!(e.contains("global"), "{e}");
        // Out-of-range nodes are a validated error, not a late panic.
        let oob = RunOptions::with_faults(FaultPlan::none().fail(2.0, 9));
        let e = ShardedSim::validate_shardable(&w, &oob, 4, 2).unwrap_err();
        assert!(e.contains("only 4 nodes"), "{e}");
        // The fault-free, dependency-free case passes.
        ShardedSim::validate_shardable(&w, &RunOptions::default(), 4, 2).unwrap();
    }

    #[test]
    fn dag_workloads_are_rejected_with_a_diagnostic() {
        let w = WorkloadBuilder::constant(1.0).tasks(12).dag_chains(4).build();
        let e = ShardedSim::validate_shardable(&w, &RunOptions::default(), 4, 2).unwrap_err();
        assert!(e.contains("dependency-free"), "{e}");
        assert!(e.contains("deadlock"), "{e}");
    }

    #[test]
    #[should_panic(expected = "sharded runs do not support fault plans")]
    fn run_panics_on_a_group_crossing_fault_plan() {
        use crate::cluster::FaultPlan;
        let w = WorkloadBuilder::constant(1.0).tasks(16).jobs(16).build();
        // Nodes 0 and 3 live in different groups under 2 shards.
        let options = RunOptions::with_faults(FaultPlan::none().fail(2.0, 0).fail(2.0, 3));
        let sim = ShardedSim::new(Box::new(IdealFifo), 2, 1, "I+shard2");
        sim.run(&w, &cluster(), 0, &options);
    }

    #[test]
    fn fault_events_route_to_the_owning_shard_and_match_the_plain_run() {
        use crate::cluster::FaultPlan;
        // 4 nodes × 4 cores; 16 one-core 4 s tasks fill the cluster at
        // t=0. Node 1 dies at t=1: its 4 tasks lose 1 s each and rerun
        // on slots freed at t=4, ending at t=8 — identically whether
        // the run is whole or split into 2 node groups (node 1 is
        // local node 1 of shard 0 after remapping).
        let w = WorkloadBuilder::constant(4.0).tasks(16).jobs(16).build();
        let options = RunOptions::with_faults(FaultPlan::none().fail(1.0, 1));
        let plain = IdealFifo.run(&w, &cluster(), 0, &options);
        let sim = ShardedSim::new(Box::new(IdealFifo), 2, 2, "I+shard2");
        let r = sim.run(&w, &cluster(), 0, &options);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, plain.kills);
        assert_eq!(r.kills, 4);
        assert_eq!(r.completed, 16);
        assert_eq!(r.failed, 0);
        assert!((r.wasted_core_seconds - plain.wasted_core_seconds).abs() < 1e-9);
        assert!((r.wasted_core_seconds - 4.0).abs() < 1e-9);
        assert!((r.t_total - plain.t_total).abs() < 1e-9, "t={}", r.t_total);
        // Per-shard retry histograms merge element-wise. Only shard 0
        // ran a (non-empty) fault plan, so the histogram covers its 8
        // tasks: 4 untouched, 4 killed exactly once.
        assert_eq!(r.retry_hist, vec![4, 4]);
    }

    #[test]
    fn sharded_ideal_constant_workload_is_wave_exact() {
        // 64 one-core 3 s tasks on 16 cores: 4 waves of 12 s whether
        // the cluster runs whole or as 2 or 4 node groups. One job per
        // task so `job % G` spreads the load evenly.
        let w = WorkloadBuilder::constant(3.0)
            .tasks(64)
            .jobs(64)
            .label("w")
            .build();
        let plain = IdealFifo.run(&w, &cluster(), 0, &RunOptions::default());
        for g in [2usize, 4] {
            let name: &'static str = if g == 2 { "I+shard2" } else { "I+shard4" };
            let sim = ShardedSim::new(Box::new(IdealFifo), g, 2, name);
            let r = sim.run(&w, &cluster(), 0, &RunOptions::default());
            r.check_invariants().unwrap();
            assert_eq!(r.t_total.to_bits(), plain.t_total.to_bits(), "G={g}");
            assert_eq!(r.completed, plain.completed);
            assert_eq!(r.processors, plain.processors);
        }
    }

    #[test]
    fn sharded_results_are_independent_of_worker_count() {
        let w = WorkloadBuilder::constant(2.0)
            .tasks(120)
            .jobs(12)
            .label("j")
            .build();
        let runs: Vec<RunResult> = [1usize, 2, 8]
            .iter()
            .map(|&jobs| {
                ShardedSim::new(Box::new(IdealFifo), 4, jobs, "I+shard4").run(
                    &w,
                    &cluster(),
                    42,
                    &RunOptions::with_trace(),
                )
            })
            .collect();
        for r in &runs[1..] {
            assert_eq!(r.t_total.to_bits(), runs[0].t_total.to_bits());
            assert_eq!(r.events, runs[0].events);
            assert_eq!(r.waits.mean().to_bits(), runs[0].waits.mean().to_bits());
            assert_eq!(r.trace, runs[0].trace);
            assert_eq!(r.wait_sample, runs[0].wait_sample);
        }
    }

    #[test]
    fn trace_remap_restores_global_ids_and_disjoint_slots() {
        let w = WorkloadBuilder::constant(1.0)
            .tasks(32)
            .jobs(32)
            .label("t")
            .build();
        let sim = ShardedSim::new(Box::new(IdealFifo), 4, 2, "I+shard4");
        let r = sim.run(&w, &cluster(), 3, &RunOptions::with_trace());
        let trace = r.trace.as_ref().unwrap();
        assert_eq!(trace.len(), 32);
        for (i, rec) in trace.iter().enumerate() {
            assert_eq!(rec.task, i as u32);
            assert!(rec.slot < 16);
            assert_eq!(rec.node, rec.slot / 4, "homogeneous slot->node map");
        }
        // Every shard (node group) actually ran work.
        let mut nodes: Vec<u32> = trace.iter().map(|rec| rec.node).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes, (0..4).collect::<Vec<u32>>());
    }

    #[test]
    fn node_granular_wrapper_relabels_and_completes() {
        let w = WorkloadBuilder::constant(2.0).tasks(48).label("ng").build();
        let sim = NodeGranularSim::new(Box::new(IdealFifo), "IdealFIFO+node");
        let r = sim.run(&w, &cluster(), 0, &RunOptions::default());
        r.check_invariants().unwrap();
        assert_eq!(r.scheduler, "IdealFIFO+node");
        assert_eq!(r.completed, 48);
        // Constant 1-core work: whole-node packing changes placement,
        // not the wave count.
        let plain = IdealFifo.run(&w, &cluster(), 0, &RunOptions::default());
        assert_eq!(r.t_total.to_bits(), plain.t_total.to_bits());
    }
}
