//! Hadoop-YARN-like scheduler simulator.
//!
//! Mechanism (mirrors ResourceManager + NodeManagers, Hadoop 2.7):
//!
//! * every job-array element is its own YARN application (YARN has no
//!   native job arrays), so each pays: RM submission/scheduling (serial
//!   at the RM), container allocation granted on a NodeManager
//!   **heartbeat** boundary, then an **ApplicationMaster** container
//!   launch — JVM spin-up, localization, registration — before the
//!   actual task container can run;
//! * the AM startup is the paper's explanation for YARN's poor numbers
//!   ("greater overhead for each job, including launching an
//!   application master process for each job", citing White 2015);
//! * completions pay RM bookkeeping before the containers are reusable.
//!
//! Per-task cost is dominated by the *uniform* AM startup ⇒ fitted
//! α_s ≈ 1.0 with a huge t_s ≈ 33 s (Table 10), and rapid-task runs
//! become prohibitive (the paper abandoned them; the harness skips them
//! via [`Scheduler::projected_runtime`]).

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::ClusterSpec;
use crate::sim::{ServiceStation, SimEv, SimScratch};
use crate::util::prng::{LognormalGen, Prng};
use crate::util::stats::Summary;
use crate::workload::{TraceRecord, Workload};

/// Mechanism parameters for the YARN-like model.
#[derive(Clone, Debug)]
pub struct YarnParams {
    /// Display name.
    pub name: &'static str,
    /// RM serial cost per application submission + scheduling decision.
    pub rm_cost_per_app: f64,
    /// RM serial cost per completion.
    pub complete_cost_per_app: f64,
    /// NodeManager heartbeat interval (container grants land on
    /// heartbeat boundaries).
    pub nm_heartbeat: f64,
    /// ApplicationMaster container startup mean (s): JVM + localization
    /// + AM-RM registration.
    pub am_startup_mean: f64,
    /// CV of AM startup.
    pub am_startup_cv: f64,
    /// Task container launch overhead once the AM is up (s).
    pub container_launch: f64,
    /// Node-side cleanup before the slot is reusable (s).
    pub teardown: f64,
    /// One-way RPC latency (s).
    pub rpc: f64,
    /// CV of lognormal jitter on RM service times.
    pub jitter_cv: f64,
}

/// YARN-like simulator.
pub struct YarnSim {
    params: YarnParams,
}

impl YarnSim {
    /// New simulator.
    pub fn new(params: YarnParams) -> Self {
        Self { params }
    }

    /// Access parameters.
    pub fn params(&self) -> &YarnParams {
        &self.params
    }
}

impl Scheduler for YarnSim {
    fn name(&self) -> &'static str {
        self.params.name
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let p = &self.params;
        let mut rng = Prng::new(seed ^ 0x7A42_4EAD);
        // Precomputed jitter distributions (hot path).
        let g_rm = LognormalGen::new(p.rm_cost_per_app, p.jitter_cv);
        let g_complete = LognormalGen::new(p.complete_cost_per_app, p.jitter_cv);
        let g_am = LognormalGen::new(p.am_startup_mean, p.am_startup_cv);
        let n = workload.len();
        scratch.begin(cluster, n, options.collect_trace);
        let SimScratch {
            queue: q,
            pending,
            pool,
            slot_mem,
            trace,
            trace_idx,
            ..
        } = scratch;
        let mut rm = ServiceStation::new();

        for t in &workload.tasks {
            if t.submit_at <= 0.0 && !options.individual_submission {
                pending.push_back(t.id);
            } else {
                q.push(t.submit_at.max(0.0), SimEv::Arrive { task: t.id });
            }
        }
        let mut makespan: f64 = 0.0;
        let mut completed = 0usize;
        let mut waits = Summary::new();

        q.push(p.nm_heartbeat, SimEv::Tick);

        while let Some((now, ev)) = q.pop() {
            match ev {
                SimEv::Arrive { task } => {
                    rm.serve(now, rng.lognormal(&g_rm));
                    pending.push_back(task);
                }
                SimEv::Tick => {
                    // Heartbeating NMs report free containers; RM grants
                    // AM containers for queued applications.
                    while !pending.is_empty() {
                        let task_id = *pending.front().unwrap();
                        let task = &workload.tasks[task_id as usize];
                        let Some(slot) = pool.alloc(task.mem_mb) else {
                            break;
                        };
                        pending.pop_front();
                        slot_mem[slot as usize] = task.mem_mb;
                        let fin = rm.serve(now, rng.lognormal(&g_rm));
                        let am = rng.lognormal(&g_am);
                        q.push(fin + p.rpc + am, SimEv::Stage { task: task_id, slot });
                    }
                    if completed < n {
                        q.push(now + p.nm_heartbeat, SimEv::Tick);
                    }
                }
                SimEv::Stage { task, slot } => {
                    // AM is up; it asks for its task container, launched
                    // on the same node.
                    q.push(now + p.container_launch, SimEv::Start { task, slot });
                }
                SimEv::Start { task, slot } => {
                    let spec = &workload.tasks[task as usize];
                    waits.add(now - spec.submit_at);
                    if options.collect_trace {
                        trace_idx[task as usize] = trace.len() as u32;
                        trace.push(TraceRecord {
                            task,
                            node: pool.node_of(slot),
                            slot,
                            submit: spec.submit_at,
                            start: now,
                            end: 0.0,
                        });
                    }
                    q.push(now + spec.duration, SimEv::End { task, slot });
                }
                SimEv::End { task, slot } => {
                    completed += 1;
                    makespan = makespan.max(now);
                    if options.collect_trace {
                        trace[trace_idx[task as usize] as usize].end = now;
                    }
                    let fin = rm.serve(now, rng.lognormal(&g_complete));
                    q.push(fin + p.teardown, SimEv::SlotFree { slot });
                }
                SimEv::SlotFree { slot } => {
                    pool.release(slot, slot_mem[slot as usize]);
                }
            }
        }

        debug_assert_eq!(completed, n);
        let processors = cluster.total_cores();
        let events = q.popped();
        RunResult {
            scheduler: p.name.to_string(),
            workload: workload.label.clone(),
            n_tasks: n as u64,
            processors,
            t_total: makespan,
            t_job: workload.t_job_per_proc(processors),
            events,
            daemon_busy: rm.busy(),
            waits,
            trace: options.collect_trace.then(|| std::mem::take(trace)),
        }
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        // Each task's slot is additionally occupied for ~the AM startup.
        let p = cluster.total_cores() as f64;
        let n_per_proc = workload.len() as f64 / p;
        workload.total_work() / p
            + n_per_proc * (self.params.am_startup_mean + self.params.container_launch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::calibration;
    use crate::workload::WorkloadBuilder;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 8, 32 * 1024, 2)
    }

    #[test]
    fn completes_and_valid() {
        let sim = YarnSim::new(calibration::yarn_params());
        let w = WorkloadBuilder::constant(5.0).tasks(32).label("y").build();
        let r = sim.run(&w, &cluster(), 2, &RunOptions::with_trace());
        r.check_invariants().unwrap();
    }

    #[test]
    fn am_overhead_dominates_short_tasks() {
        let sim = YarnSim::new(calibration::yarn_params());
        // 2 tasks per slot, 5 s each: ΔT ≈ 2 × am_startup ≫ t_job.
        let w = WorkloadBuilder::constant(5.0).tasks(32).build();
        let r = sim.run(&w, &cluster(), 4, &RunOptions::default());
        let per_task_overhead = r.delta_t() / 2.0;
        let am = calibration::yarn_params().am_startup_mean;
        assert!(
            (per_task_overhead - am).abs() < am * 0.5,
            "per-task overhead {per_task_overhead} should be near AM startup {am}"
        );
        assert!(r.utilization() < 0.3, "u={}", r.utilization());
    }

    #[test]
    fn projected_runtime_flags_prohibitive() {
        let sim = YarnSim::new(calibration::yarn_params());
        let w = WorkloadBuilder::constant(1.0).tasks(16 * 240).build();
        let projected = sim.projected_runtime(&w, &cluster());
        // 240 tasks/proc × (1 s + ~33 s AM) ≈ 2+ hours.
        assert!(projected > 3600.0, "projected={projected}");
    }
}
