//! Hadoop-YARN-like scheduler policy.
//!
//! Mechanism (mirrors ResourceManager + NodeManagers, Hadoop 2.7):
//!
//! * every job-array element is its own YARN application (YARN has no
//!   native job arrays), so each pays: RM submission/scheduling (serial
//!   at the RM), container allocation granted on a NodeManager
//!   **heartbeat** boundary, then an **ApplicationMaster** container
//!   launch — JVM spin-up, localization, registration — before the
//!   actual task container can run;
//! * the AM startup is the paper's explanation for YARN's poor numbers
//!   ("greater overhead for each job, including launching an
//!   application master process for each job", citing White 2015);
//! * completions pay RM bookkeeping before the containers are reusable.
//!
//! Per-task cost is dominated by the *uniform* AM startup ⇒ fitted
//! α_s ≈ 1.0 with a huge t_s ≈ 33 s (Table 10), and rapid-task runs
//! become prohibitive (the paper abandoned them; the harness skips them
//! via [`Scheduler::projected_runtime`]).
//!
//! The event loop lives in [`crate::sim::Kernel`]; this is the only
//! policy that uses the kernel's `Stage` hook (AM ready → container
//! launch).

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::{ClusterSpec, NodeId};
use crate::sim::{Kernel, KernelCtx, Launch, SchedPolicy, ServiceStation, SimEv, SimScratch, Time};
use crate::util::prng::{LognormalGen, Prng};
use crate::workload::{TaskId, Workload};

/// Mechanism parameters for the YARN-like model.
#[derive(Clone, Debug)]
pub struct YarnParams {
    /// Display name.
    pub name: &'static str,
    /// RM serial cost per application submission + scheduling decision.
    pub rm_cost_per_app: f64,
    /// RM serial cost per completion.
    pub complete_cost_per_app: f64,
    /// NodeManager heartbeat interval (container grants land on
    /// heartbeat boundaries).
    pub nm_heartbeat: f64,
    /// ApplicationMaster container startup mean (s): JVM + localization
    /// + AM-RM registration.
    pub am_startup_mean: f64,
    /// CV of AM startup.
    pub am_startup_cv: f64,
    /// Task container launch overhead once the AM is up (s).
    pub container_launch: f64,
    /// Node-side cleanup before the slot is reusable (s).
    pub teardown: f64,
    /// One-way RPC latency (s).
    pub rpc: f64,
    /// CV of lognormal jitter on RM service times.
    pub jitter_cv: f64,
}

/// YARN-like simulator.
pub struct YarnSim {
    params: YarnParams,
}

impl YarnSim {
    /// New simulator.
    pub fn new(params: YarnParams) -> Self {
        Self { params }
    }

    /// Access parameters.
    pub fn params(&self) -> &YarnParams {
        &self.params
    }
}

/// Per-run policy state: the ResourceManager station + jitter gens.
struct YarnPolicy<'p> {
    p: &'p YarnParams,
    rng: Prng,
    g_rm: LognormalGen,
    g_complete: LognormalGen,
    g_am: LognormalGen,
    rm: ServiceStation,
}

impl SchedPolicy for YarnPolicy<'_> {
    fn label(&self) -> String {
        self.p.name.to_string()
    }

    fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
        ctx.push(self.p.nm_heartbeat, SimEv::Tick);
    }

    fn on_arrive(&mut self, _ctx: &mut KernelCtx, now: Time, _task: TaskId) {
        self.rm.serve(now, self.rng.lognormal(&self.g_rm));
    }

    fn tick_interval(&self) -> Option<Time> {
        Some(self.p.nm_heartbeat)
    }

    fn on_tick(&mut self, ctx: &mut KernelCtx, now: Time) {
        // Heartbeating NMs report free containers; RM grants AM
        // containers for queued applications.
        let (rm, rng) = (&mut self.rm, &mut self.rng);
        let (g_rm, g_am, rpc) = (&self.g_rm, &self.g_am, self.p.rpc);
        ctx.drain_fifo(&mut |_, _| {
            let fin = rm.serve(now, rng.lognormal(g_rm));
            let am = rng.lognormal(g_am);
            Launch::staged(fin + rpc + am)
        });
    }

    fn on_stage(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: u32) {
        // AM is up; it asks for its task container, launched on the
        // same node.
        ctx.push(now + self.p.container_launch, SimEv::Start { task, slot });
    }

    fn on_complete(
        &mut self,
        _ctx: &mut KernelCtx,
        now: Time,
        _task: TaskId,
        _slot: u32,
    ) -> Option<Time> {
        let fin = self.rm.serve(now, self.rng.lognormal(&self.g_complete));
        Some(fin + self.p.teardown)
    }

    // Node faults are deliberate no-ops: a failed NM stops
    // heartbeating (its containers leave the pool via the kernel) and
    // the killed applications the kernel requeued are re-admitted at
    // the next NM heartbeat like fresh submissions; an AM whose
    // container launch was in flight toward the dead node is aborted
    // by the kernel and re-granted the same way. Recovery is the NM
    // heartbeating again with free containers.
    fn on_node_fail(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    fn on_node_suspected(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {
        // Same as on_node_fail: a suspected NM is one whose heartbeats
        // stopped; re-admission rides the next heartbeat cycle.
    }

    fn on_node_drain(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    fn on_node_recover(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    fn daemon_busy(&self) -> f64 {
        self.rm.busy()
    }
}

impl Scheduler for YarnSim {
    fn name(&self) -> &'static str {
        self.params.name
    }

    fn make_policy<'a>(&'a self, seed: u64) -> Option<Box<dyn SchedPolicy + 'a>> {
        let p = &self.params;
        Some(Box::new(YarnPolicy {
            p,
            rng: Prng::new(seed ^ 0x7A42_4EAD),
            g_rm: LognormalGen::new(p.rm_cost_per_app, p.jitter_cv),
            g_complete: LognormalGen::new(p.complete_cost_per_app, p.jitter_cv),
            g_am: LognormalGen::new(p.am_startup_mean, p.am_startup_cv),
            rm: ServiceStation::new(),
        }))
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let mut policy = self.make_policy(seed).expect("yarn is kernel-driven");
        Kernel::run(policy.as_mut(), workload, cluster, options, scratch)
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        // Each task's slot is additionally occupied for ~the AM startup.
        let p = cluster.total_cores() as f64;
        let n_per_proc = workload.len() as f64 / p;
        workload.total_work() / p
            + n_per_proc * (self.params.am_startup_mean + self.params.container_launch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::calibration;
    use crate::workload::WorkloadBuilder;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 8, 32 * 1024, 2)
    }

    #[test]
    fn completes_and_valid() {
        let sim = YarnSim::new(calibration::yarn_params());
        let w = WorkloadBuilder::constant(5.0).tasks(32).label("y").build();
        let r = sim.run(&w, &cluster(), 2, &RunOptions::with_trace());
        r.check_invariants().unwrap();
    }

    #[test]
    fn am_overhead_dominates_short_tasks() {
        let sim = YarnSim::new(calibration::yarn_params());
        // 2 tasks per slot, 5 s each: ΔT ≈ 2 × am_startup ≫ t_job.
        let w = WorkloadBuilder::constant(5.0).tasks(32).build();
        let r = sim.run(&w, &cluster(), 4, &RunOptions::default());
        let per_task_overhead = r.delta_t() / 2.0;
        let am = calibration::yarn_params().am_startup_mean;
        assert!(
            (per_task_overhead - am).abs() < am * 0.5,
            "per-task overhead {per_task_overhead} should be near AM startup {am}"
        );
        assert!(r.utilization() < 0.3, "u={}", r.utilization());
    }

    #[test]
    fn projected_runtime_flags_prohibitive() {
        let sim = YarnSim::new(calibration::yarn_params());
        let w = WorkloadBuilder::constant(1.0).tasks(16 * 240).build();
        let projected = sim.projected_runtime(&w, &cluster());
        // 240 tasks/proc × (1 s + ~33 s AM) ≈ 2+ hours.
        assert!(projected > 3600.0, "projected={projected}");
    }

    #[test]
    fn multicore_tasks_hold_all_their_containers() {
        let sim = YarnSim::new(calibration::yarn_params());
        let w = WorkloadBuilder::constant(20.0)
            .tasks(8)
            .cores(4)
            .label("mc")
            .build();
        // 8 tasks × 4 cores on 16 slots: two waves; each wave pays one
        // AM startup, so T_total ≈ 2 × (hb + AM + launch + 20 s).
        let r = sim.run(&w, &cluster(), 5, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert!(
            r.t_total > 2.0 * 20.0 + 31.0,
            "multi-core waves must serialize: t_total={}",
            r.t_total
        );
    }
}
