//! Idealized zero-overhead FIFO scheduler — the correctness reference.
//!
//! Dispatch, launch and completion are free; T_total for N constant
//! t-second tasks on P slots is exactly `ceil(N/P) · t` and utilization
//! is 1 when N divides P. Property tests compare the real simulators
//! against this floor.
//!
//! As a [`SchedPolicy`] this is the minimal event-driven policy: no
//! ticks, no daemon — dispatch happens at submission, on every slot
//! release, and whenever dependencies unblock.

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::{ClusterSpec, NodeId};
use crate::sim::{Kernel, KernelCtx, Launch, SchedPolicy, SimScratch, Time};
use crate::workload::{TaskId, Workload};

/// The ideal zero-overhead scheduler.
pub struct IdealFifo;

/// Zero-overhead policy: every dispatch is free and instantaneous.
struct IdealPolicy;

impl SchedPolicy for IdealPolicy {
    fn label(&self) -> String {
        "IdealFIFO".into()
    }

    fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
        // Fill every slot at t=0; refills happen on slot release.
        ctx.drain_fifo(&mut |_, _| Launch::start(0.0));
    }

    fn on_arrive(&mut self, ctx: &mut KernelCtx, now: Time, _task: TaskId) {
        ctx.drain_fifo(&mut |_, _| Launch::start(now));
    }

    fn on_complete(
        &mut self,
        _ctx: &mut KernelCtx,
        now: Time,
        _task: TaskId,
        _slot: u32,
    ) -> Option<Time> {
        Some(now) // slots are reusable instantly
    }

    fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
        ctx.drain_fifo(&mut |_, _| Launch::start(now));
    }

    fn on_node_fail(&mut self, ctx: &mut KernelCtx, now: Time, _node: NodeId) {
        // The kernel killed and requeued the node's tasks before this
        // hook; re-place them on whatever healthy capacity is free.
        // An event-driven policy has no tick to fall back on — without
        // this, requeued work would wait for an unrelated completion
        // (or strand outright on an otherwise-idle cluster).
        ctx.drain_fifo(&mut |_, _| Launch::start(now));
    }

    fn on_node_suspected(&mut self, ctx: &mut KernelCtx, now: Time, _node: NodeId) {
        // Detection is the instant the failure becomes visible: react
        // exactly as on_node_fail would have.
        ctx.drain_fifo(&mut |_, _| Launch::start(now));
    }

    fn on_node_drain(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {
        // Deliberate no-op: a drain only parks the node's *free* slots
        // (the pool refuses new placement kernel-side) and kills
        // nothing, so an event-driven policy has no requeued work to
        // re-place — the next completion or recovery drives dispatch.
    }

    fn on_node_recover(&mut self, ctx: &mut KernelCtx, now: Time, _node: NodeId) {
        // Restored slots re-enter the free pool without SlotFree
        // events; give pending work the dispatch pass a release would
        // have triggered.
        ctx.drain_fifo(&mut |_, _| Launch::start(now));
    }
}

impl Scheduler for IdealFifo {
    fn name(&self) -> &'static str {
        "IdealFIFO"
    }

    fn make_policy<'a>(&'a self, _seed: u64) -> Option<Box<dyn SchedPolicy + 'a>> {
        Some(Box::new(IdealPolicy))
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        _seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        Kernel::run(&mut IdealPolicy, workload, cluster, options, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadBuilder;

    #[test]
    fn exact_makespan_and_full_utilization() {
        let cluster = ClusterSpec::homogeneous(2, 8, 32 * 1024, 2);
        // N = 64 tasks of 3 s on 16 slots -> 4 waves -> exactly 12 s.
        let w = WorkloadBuilder::constant(3.0).tasks(64).label("i").build();
        let r = IdealFifo.run(&w, &cluster, 0, &RunOptions::default());
        assert!((r.t_total - 12.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert!((r.utilization() - 1.0).abs() < 1e-9);
        assert!((r.delta_t()).abs() < 1e-9);
    }

    #[test]
    fn ragged_last_wave() {
        let cluster = ClusterSpec::homogeneous(1, 4, 32 * 1024, 1);
        // 6 tasks of 2 s on 4 slots -> waves of 4 then 2 -> 4 s.
        let w = WorkloadBuilder::constant(2.0).tasks(6).build();
        let r = IdealFifo.run(&w, &cluster, 0, &RunOptions::default());
        assert!((r.t_total - 4.0).abs() < 1e-9);
        // U = (12/4) / 4 = 0.75
        assert!((r.utilization() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn dag_chain_is_exactly_serial() {
        let cluster = ClusterSpec::homogeneous(1, 4, 32 * 1024, 1);
        // 8 tasks of 2 s in chains of 4 on 4 slots: two chains run in
        // parallel, each strictly serial -> exactly 8 s.
        let w = WorkloadBuilder::constant(2.0).tasks(8).dag_chains(4).build();
        let r = IdealFifo.run(&w, &cluster, 0, &RunOptions::default());
        assert!((r.t_total - 8.0).abs() < 1e-9, "t_total={}", r.t_total);
    }

    #[test]
    fn node_failure_requeues_onto_survivors_exactly() {
        use crate::cluster::FaultPlan;
        let cluster = ClusterSpec::homogeneous(2, 4, 32 * 1024, 2);
        // 8 tasks of 4 s fill all 8 slots at t=0. Node 0 (slots 0..4)
        // dies at t=1: its 4 tasks lose 1 s each and requeue; node 1's
        // tasks finish at 4, freeing slots for the retries -> exactly 8.
        let w = WorkloadBuilder::constant(4.0).tasks(8).label("f").build();
        let mut options = RunOptions::default();
        options.faults = FaultPlan::none().fail(1.0, 0);
        let r = IdealFifo.run(&w, &cluster, 0, &options);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 4);
        assert_eq!(r.failed, 0);
        assert!((r.wasted_core_seconds - 4.0).abs() < 1e-9);
        assert!((r.t_total - 8.0).abs() < 1e-9, "t_total={}", r.t_total);
    }

    #[test]
    fn recovery_redispatches_pending_retries_immediately() {
        use crate::cluster::FaultPlan;
        let cluster = ClusterSpec::homogeneous(2, 4, 32 * 1024, 2);
        // Same failure, but the node returns at t=2: the 4 retries must
        // restart on the recovered capacity at t=2 (ending at 6), not
        // wait for node 1's completions at t=4.
        let w = WorkloadBuilder::constant(4.0).tasks(8).label("r").build();
        let mut options = RunOptions::default();
        options.faults = FaultPlan::none().fail(1.0, 0).recover(2.0, 0);
        let r = IdealFifo.run(&w, &cluster, 0, &options);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 4);
        assert!((r.t_total - 6.0).abs() < 1e-9, "t_total={}", r.t_total);
    }

    #[test]
    fn gang_makespan_matches_rigid_packing() {
        let cluster = ClusterSpec::homogeneous(1, 4, 32 * 1024, 1);
        // Two gangs of 4 × 3 s on 4 slots: strictly one gang at a time.
        let w = WorkloadBuilder::constant(3.0).tasks(8).gangs(4).build();
        let r = IdealFifo.run(&w, &cluster, 0, &RunOptions::default());
        assert!((r.t_total - 6.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert!((r.utilization() - 1.0).abs() < 1e-9);
    }
}
